"""Synthetic, deterministic image-classification datasets.

The reproduction band for this paper is 0/5: no CIFAR/ImageNet and no
pretrained checkpoints are available in this environment. Per the
substitution rule (DESIGN.md §1) we build separable-but-nontrivial
synthetic datasets whose *difficulty gradient* mirrors the paper's
CIFAR-10 → CIFAR-100 → ImageNet ladder:

  synth-c10   10 classes, 16x16x3   (easy — CIFAR-10 stand-in)
  synth-c100  100 classes, 16x16x3  (harder — CIFAR-100 stand-in)
  synth-inet  50 classes, 24x24x3   (hardest — ImageNet stand-in)

A class is a deterministic (orientation, spatial-frequency, colour-mix)
triple rendered as an oriented grating; samples add per-sample phase,
orientation jitter and pixel noise, so the task requires real feature
extraction rather than template matching.
"""

from __future__ import annotations

import numpy as np

DATASETS = {
    # name: (classes, H, W, noise, jitter)
    "synth-c10": (10, 16, 16, 0.30, 0.12),
    "synth-c100": (100, 16, 16, 0.10, 0.04),
    "synth-inet": (50, 24, 24, 0.16, 0.06),
}

_PALETTE = np.array(
    [
        [1.0, 0.3, 0.3],
        [0.3, 1.0, 0.3],
        [0.3, 0.3, 1.0],
        [1.0, 1.0, 0.2],
        [0.2, 1.0, 1.0],
        [1.0, 0.2, 1.0],
        [0.9, 0.6, 0.2],
        [0.6, 0.9, 0.5],
    ],
    dtype=np.float32,
)


def class_params(n_classes: int):
    """Deterministic per-class (theta, freq, colour) grid."""
    n_orient = int(np.ceil(np.sqrt(n_classes)))
    n_freq = int(np.ceil(n_classes / n_orient))
    thetas, freqs, colours = [], [], []
    for c in range(n_classes):
        oi, fi = c % n_orient, c // n_orient
        thetas.append(np.pi * oi / n_orient)
        freqs.append(1.5 + 3.5 * fi / max(1, n_freq - 1))
        colours.append(_PALETTE[c % len(_PALETTE)])
    return (
        np.array(thetas, dtype=np.float32),
        np.array(freqs, dtype=np.float32),
        np.stack(colours),
    )


def make_split(name: str, n: int, seed: int):
    """Render `n` samples of dataset `name`. Returns (X[n,H,W,3] in [0,1], y[n])."""
    n_classes, h, w, noise, jitter = DATASETS[name]
    thetas, freqs, colours = class_params(n_classes)
    rng = np.random.default_rng(seed)
    y = rng.integers(0, n_classes, size=n)
    phase = rng.uniform(0, 2 * np.pi, size=n).astype(np.float32)
    dth = rng.normal(0, jitter, size=n).astype(np.float32)
    dfr = rng.normal(0, 0.08, size=n).astype(np.float32)

    yy, xx = np.meshgrid(
        np.linspace(-0.5, 0.5, h, dtype=np.float32),
        np.linspace(-0.5, 0.5, w, dtype=np.float32),
        indexing="ij",
    )
    th = thetas[y] + dth  # [n]
    fr = freqs[y] * (1.0 + dfr)
    proj = (
        xx[None] * np.cos(th)[:, None, None] + yy[None] * np.sin(th)[:, None, None]
    )  # [n,h,w]
    grating = np.sin(2 * np.pi * fr[:, None, None] * proj + phase[:, None, None])
    col = colours[y]  # [n,3]
    img = 0.5 + 0.45 * grating[..., None] * col[:, None, None, :]
    img += rng.normal(0, noise, size=img.shape).astype(np.float32)
    X = np.clip(img, 0.0, 1.0).astype(np.float32)
    return X, y.astype(np.int32)


def splits(name: str, n_train: int, n_val: int, n_test: int, seed: int = 0):
    """Disjoint-seeded train/val/test splits."""
    return (
        make_split(name, n_train, seed * 1000 + 1),
        make_split(name, n_val, seed * 1000 + 2),
        make_split(name, n_test, seed * 1000 + 3),
    )
