"""L2: functional JAX forward pass for the mini-CNN zoo.

A single graph interpreter executes the arch specs from `arch.py` in two
modes:

  * float training mode (`act_bits=None`) — used by `train.py`;
  * quantized inference mode — the AOT-exported graph. Every prunable
    layer fake-quantizes its *input* activations to `act_bits[i]` using
    the per-layer Laplace scale measured at calibration (paper §4.1:
    same precision for weights and activations of a layer; weights are
    fake-quantized on the Rust side before being fed in).

`conv_impl` selects the convolution path:
  * "lax"    — XLA's native conv (fast; default export);
  * "pallas" — im2col + the L1 fused quant-matmul kernel, proving the
    three-layer composition (exported for vgg11 and unit-tested).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import ref as kref
from .kernels.qmatmul import qmatmul


def init_params(spec, seed=0):
    """He-normal init; returns {layer_name: (w, b)} for prunable layers."""
    key = jax.random.PRNGKey(seed)
    params = {}
    for L in spec["layers"]:
        if L["op"] == "conv":
            k, cin, cout = L["k"], L["in_ch"], L["out_ch"]
            key, sub = jax.random.split(key)
            fan_in = k * k * cin
            w = jax.random.normal(sub, (k, k, cin, cout)) * jnp.sqrt(2.0 / fan_in)
            params[L["name"]] = (w.astype(jnp.float32), jnp.zeros((cout,), jnp.float32))
        elif L["op"] == "dwconv":
            # HW1C: lax group-conv expects rhs I = lhs_C/groups = 1, O = C
            k, c = L["k"], L["in_ch"]
            key, sub = jax.random.split(key)
            w = jax.random.normal(sub, (k, k, 1, c)) * jnp.sqrt(2.0 / (k * k))
            params[L["name"]] = (w.astype(jnp.float32), jnp.zeros((c,), jnp.float32))
        elif L["op"] == "fc":
            fin, fout = L["in_ch"], L["out_ch"]
            key, sub = jax.random.split(key)
            w = jax.random.normal(sub, (fin, fout)) * jnp.sqrt(2.0 / fin)
            params[L["name"]] = (w.astype(jnp.float32), jnp.zeros((fout,), jnp.float32))
    return params


def _same_pad(h, k, s):
    """Explicit SAME padding (lo, hi) for one spatial dim."""
    out = (h + s - 1) // s
    pad = max(0, (out - 1) * s + k - h)
    return (pad // 2, pad - pad // 2)


def _conv_lax(x, w, stride, groups=1):
    h, wdim = x.shape[1], x.shape[2]
    k = w.shape[0]
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride),
        [_same_pad(h, k, stride), _same_pad(wdim, k, stride)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
    )


def _im2col(x, k, stride):
    """[B,H,W,C] -> patches [B*OH*OW, k*k*C], matching HWIO weight flatten."""
    b, h, w, c = x.shape
    ph, pw = _same_pad(h, k, stride), _same_pad(w, k, stride)
    xp = jnp.pad(x, ((0, 0), ph, pw, (0, 0)))
    oh, ow = (h + stride - 1) // stride, (w + stride - 1) // stride
    cols = []
    for i in range(k):
        for j in range(k):
            cols.append(
                jax.lax.slice(
                    xp, (0, i, j, 0),
                    (b, i + (oh - 1) * stride + 1, j + (ow - 1) * stride + 1, c),
                    (1, stride, stride, 1),
                )
            )
    patches = jnp.stack(cols, axis=3)  # [B,OH,OW,k*k,C]
    return patches.reshape(b * oh * ow, k * k * c), (b, oh, ow)


def _conv_pallas(x, w, stride, lo, hi, step):
    k, _, cin, cout = w.shape
    patches, (b, oh, ow) = _im2col(x, k, stride)
    out = qmatmul(patches, w.reshape(k * k * cin, cout), lo, hi, step)
    return out.reshape(b, oh, ow, cout)


def forward(spec, params, x, act_bits=None, act_scales=None, act_signed=None,
            conv_impl="lax"):
    """Run the graph. `act_bits`: f32[n_prunable] (traced OK); None = float.

    `act_signed`: static per-prunable-layer bools — True when the layer's
    input can be negative (e.g. after a linear-bottleneck add), selecting
    the symmetric quantization grid.
    """
    outs = {"input": x}
    prunable = spec["prunable"]
    pidx = {n: i for i, n in enumerate(prunable)}
    if act_signed is None:
        act_signed = spec.get("act_signed", [False] * len(prunable))
    for L in spec["layers"]:
        name, op = L["name"], L["op"]
        ins = [outs[i] for i in L["inputs"]]
        if op in ("conv", "dwconv", "fc"):
            xin = ins[0]
            quantize = act_bits is not None
            if quantize:
                i = pidx[name]
                lo, hi, step = kref.quant_params(
                    act_bits[i], act_scales[i], signed=bool(act_signed[i])
                )
            w, bvec = params[name]
            if op == "conv":
                if quantize and conv_impl == "pallas":
                    y = _conv_pallas(xin, w, L["stride"], lo, hi, step)
                else:
                    if quantize:
                        xin = kref.fake_quant(xin, lo, hi, step)
                    y = _conv_lax(xin, w, L["stride"])
                y = y + bvec
            elif op == "dwconv":
                if quantize:
                    xin = kref.fake_quant(xin, lo, hi, step)
                # HW1C with groups=C
                y = _conv_lax(xin, w, L["stride"], groups=xin.shape[-1]) + bvec
            else:  # fc
                flat = xin.reshape(xin.shape[0], -1)
                if quantize:
                    if conv_impl == "pallas":
                        y = qmatmul(flat, w, lo, hi, step) + bvec
                    else:
                        y = kref.fake_quant(flat, lo, hi, step) @ w + bvec
                else:
                    y = flat @ w + bvec
            if L.get("relu"):
                y = jax.nn.relu(y)
        elif op == "maxpool":
            k = L["k"]
            y = jax.lax.reduce_window(
                ins[0], -jnp.inf, jax.lax.max, (1, k, k, 1), (1, k, k, 1), "VALID"
            )
        elif op == "gap":
            y = jnp.mean(ins[0], axis=(1, 2))
        elif op == "flatten":
            y = ins[0].reshape(ins[0].shape[0], -1)
        elif op == "add":
            y = ins[0] + ins[1]
            if L.get("relu"):
                y = jax.nn.relu(y)
        elif op == "concat":
            y = jnp.concatenate(ins, axis=-1)
        else:
            raise ValueError(op)
        outs[name] = y
    return outs[spec["layers"][-1]["name"]]


def forward_with_taps(spec, params, x):
    """Float forward that also returns every named intermediate (calibration)."""
    outs = {"input": x}
    saved = {}
    for L in spec["layers"]:
        ins = [outs[i] for i in L["inputs"]]
        name, op = L["name"], L["op"]
        if op in ("conv", "dwconv", "fc"):
            saved[f"in:{name}"] = ins[0]
        # reuse forward() math via a one-layer spec is wasteful; inline:
        outs[name] = _apply_float(L, params, ins)
        if op in ("conv", "dwconv", "fc"):
            saved[f"out:{name}"] = outs[name]
    return outs[spec["layers"][-1]["name"]], saved


def _apply_float(L, params, ins):
    op = L["op"]
    if op == "conv":
        w, b = params[L["name"]]
        y = _conv_lax(ins[0], w, L["stride"]) + b
    elif op == "dwconv":
        w, b = params[L["name"]]
        y = _conv_lax(ins[0], w, L["stride"], groups=ins[0].shape[-1]) + b
    elif op == "fc":
        w, b = params[L["name"]]
        y = ins[0].reshape(ins[0].shape[0], -1) @ w + b
    elif op == "maxpool":
        k = L["k"]
        return jax.lax.reduce_window(
            ins[0], -jnp.inf, jax.lax.max, (1, k, k, 1), (1, k, k, 1), "VALID"
        )
    elif op == "gap":
        return jnp.mean(ins[0], axis=(1, 2))
    elif op == "flatten":
        return ins[0].reshape(ins[0].shape[0], -1)
    elif op == "add":
        y = ins[0] + ins[1]
        if L.get("relu"):
            y = jax.nn.relu(y)
        return y
    elif op == "concat":
        return jnp.concatenate(ins, axis=-1)
    else:
        raise ValueError(op)
    if L.get("relu"):
        y = jax.nn.relu(y)
    return y
