"""AOT exporter: train → calibrate → lower to HLO text → write artifacts.

Python runs ONCE (`make artifacts`); the Rust coordinator is then fully
self-contained. Interchange is HLO *text* — jax ≥ 0.5 emits protos with
64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids (rationale in the rustdoc of rust/src/runtime/pjrt.rs).

Exported graph signature (DESIGN.md §5), one executable per model:

    f(w0, b0, …, wP, bP, act_bits[f32; P], images[B,H,W,C]) -> (logits,)

so Rust feeds pruned + fake-quantized weights and per-layer activation
precisions at every RL step without retracing or recompiling.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import arch as archmod
from . import datasets as dsmod
from .model import forward
from .train import calibrate, eval_quantized, train

BATCH = 256  # fixed inference batch of the exported executable


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_hlo(spec, act_scales, batch, conv_impl="lax"):
    """Lower the quantized-inference graph; returns HLO text."""
    prunable = spec["prunable"]
    by_name = {L["name"]: L for L in spec["layers"]}
    nP = len(prunable)
    sc = jnp.asarray(act_scales)

    def fn(*args):
        params = {
            name: (args[2 * i], args[2 * i + 1]) for i, name in enumerate(prunable)
        }
        act_bits = args[2 * nP]
        images = args[2 * nP + 1]
        return (
            forward(spec, params, images, act_bits=act_bits, act_scales=sc,
                    conv_impl=conv_impl),
        )

    specs = []
    for name in prunable:
        L = by_name[name]
        if L["op"] == "conv":
            wshape = (L["k"], L["k"], L["in_ch"], L["out_ch"])
        elif L["op"] == "dwconv":
            wshape = (L["k"], L["k"], 1, L["out_ch"])  # HW1C
        else:
            wshape = (L["in_ch"], L["out_ch"])
        specs.append(jax.ShapeDtypeStruct(wshape, jnp.float32))
        specs.append(jax.ShapeDtypeStruct((L["out_ch"],), jnp.float32))
    specs.append(jax.ShapeDtypeStruct((nP,), jnp.float32))
    h, w, c = spec["input"]
    specs.append(jax.ShapeDtypeStruct((batch, h, w, c), jnp.float32))
    return to_hlo_text(jax.jit(fn).lower(*specs))


def export_qmatmul(out_dir):
    """Standalone L1 kernel HLO for the Rust runtime unit test."""
    from .kernels.qmatmul import qmatmul

    def fn(x, w, lo, hi, step):
        return (qmatmul(x, w, lo, hi, step),)

    specs = (
        jax.ShapeDtypeStruct((64, 48), jnp.float32),
        jax.ShapeDtypeStruct((48, 32), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    text = to_hlo_text(jax.jit(fn).lower(*specs))
    with open(os.path.join(out_dir, "qmatmul_pallas.hlo.txt"), "w") as f:
        f.write(text)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default=os.environ.get("HAPQ_MODELS", ""))
    ap.add_argument(
        "--steps", type=int, default=int(os.environ.get("HAPQ_TRAIN_STEPS", "600"))
    )
    args = ap.parse_args()
    out = args.out
    os.makedirs(out, exist_ok=True)
    model_names = (
        [m for m in args.models.split(",") if m]
        if args.models
        else list(archmod.MODELS.keys())
    )

    # ---- datasets ----------------------------------------------------------
    needed = {archmod.MODELS[m][1] for m in model_names}
    data = {}
    for ds in sorted(needed):
        t0 = time.time()
        n_train = 12288 if ds == "synth-c100" else 8192
        tr, va, te = dsmod.splits(ds, n_train, 512, 1024, seed=7)
        data[ds] = (tr, va, te)
        np.savez(
            os.path.join(out, f"{ds}.data.npz"),
            X_val=va[0], y_val=va[1].astype(np.int32),
            X_test=te[0], y_test=te[1].astype(np.int32),
        )
        print(f"[data] {ds}: train {len(tr[0])} val {len(va[0])} test {len(te[0])} "
              f"({time.time()-t0:.1f}s)")

    # merge with an existing manifest so partial (--models) rebuilds keep
    # the untouched entries
    manifest_path = os.path.join(out, "manifest.json")
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f)
        manifest["models"] = [
            m for m in manifest.get("models", []) if m["model"] not in model_names
        ]
    else:
        manifest = {"batch": BATCH, "models": [], "datasets": {}}
    for ds in sorted(needed):
        classes, h, w, _, _ = dsmod.DATASETS[ds]
        manifest["datasets"][ds] = {
            "data": f"{ds}.data.npz", "input": [h, w, 3], "classes": classes,
        }

    # ---- models ------------------------------------------------------------
    for name in model_names:
        spec = archmod.build(name)
        ds = spec["dataset"]
        tr, va, te = data[ds]
        nparams = 0
        t0 = time.time()
        print(f"[train] {name} on {ds} ({len(spec['prunable'])} prunable layers)")
        # harder datasets get proportionally more optimisation steps; deep
        # plain-VGG stacks (no BN) need a gentler learning rate to escape
        # the dead-ReLU plateau
        mult = {"synth-c10": 1, "synth-c100": 3, "synth-inet": 2}[ds]
        mult *= {"vgg16": 2, "vgg19": 3, "resnet34": 2, "squeezenet": 2}.get(name, 1)
        lr = 1e-3 if name in ("vgg16", "vgg19") else 2e-3
        params, hist = train(spec, tr, va, steps=args.steps * mult, lr=lr, seed=42)
        act_scales, act_signed, sal, chsq = calibrate(
            spec, params, tr[0][:256], tr[1][:256]
        )
        spec["act_signed"] = act_signed  # static: baked into the export
        acc8 = eval_quantized(spec, params, act_scales, te[0], te[1], bits=8.0)
        print(f"[train] {name}: test acc @8bit-act {acc8:.3f} "
              f"({time.time()-t0:.1f}s)")

        # weights + calibration npz
        blobs = {"act_scale": act_scales}
        for lname in spec["prunable"]:
            wq, bq = params[lname]
            blobs[f"w:{lname}"] = np.asarray(wq, dtype=np.float32)
            blobs[f"b:{lname}"] = np.asarray(bq, dtype=np.float32)
            blobs[f"sal:{lname}"] = sal[lname]
            blobs[f"chsq:{lname}"] = chsq[lname]
            nparams += wq.size + bq.size
        np.savez(os.path.join(out, f"{name}__{ds}.weights.npz"), **blobs)

        # arch json (+ calibration metadata for Rust)
        spec_out = dict(spec)
        spec_out["act_scales"] = [float(x) for x in act_scales]
        spec_out["acc_int8"] = acc8
        spec_out["batch"] = BATCH
        spec_out["n_params"] = int(nparams)
        with open(os.path.join(out, f"{name}__{ds}.arch.json"), "w") as f:
            json.dump(spec_out, f, indent=1)

        # HLO export (lax conv path; plus Pallas path for vgg11)
        text = export_hlo(spec, act_scales, BATCH)
        with open(os.path.join(out, f"{name}__{ds}.hlo.txt"), "w") as f:
            f.write(text)
        entry = {
            "model": name, "dataset": ds,
            "hlo": f"{name}__{ds}.hlo.txt",
            "weights": f"{name}__{ds}.weights.npz",
            "arch": f"{name}__{ds}.arch.json",
            "acc_int8": acc8,
        }
        if name == "vgg11":
            tp = export_hlo(spec, act_scales, 64, conv_impl="pallas")
            with open(os.path.join(out, f"{name}__{ds}.pallas.hlo.txt"), "w") as f:
                f.write(tp)
            entry["pallas_hlo"] = f"{name}__{ds}.pallas.hlo.txt"
            entry["pallas_batch"] = 64
        manifest["models"].append(entry)
        print(f"[aot] {name}: HLO {len(text)/1e6:.2f} MB, {nparams} params")

    export_qmatmul(out)
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote manifest with {len(manifest['models'])} models -> {out}")


if __name__ == "__main__":
    main()
