"""Architecture specs for the nine mini CNNs (DESIGN.md §1 substitution).

A spec is a JSON-serialisable dict shared verbatim with the Rust side
(`rust/src/model/`). Layers form a DAG over named tensors; ops:

  conv    — 2D conv, NHWC, weights HWIO, optional ReLU
  dwconv  — depthwise conv (channels = input channels), weights HWC1
  fc      — dense, weights [in, out]
  maxpool / gap / flatten / add / concat — parameter-free plumbing

Prunable layers are those with weights (conv/dwconv/fc). `dep_groups`
lists sets of prunable layers whose *output-channel* coarse-pruning
masks must be identical (residual adds; depthwise convs couple to their
producer) — the §4.1 dependency-resolution input for the Rust env.
"""

from __future__ import annotations

from .datasets import DATASETS


def _conv(name, inp, out_ch, k=3, stride=1, relu=True):
    return {
        "name": name, "op": "conv", "inputs": [inp], "out_ch": out_ch,
        "k": k, "stride": stride, "relu": relu,
    }


def _dwconv(name, inp, k=3, stride=1, relu=True):
    return {"name": name, "op": "dwconv", "inputs": [inp], "k": k,
            "stride": stride, "relu": relu}


def _fc(name, inp, out, relu=False):
    return {"name": name, "op": "fc", "inputs": [inp], "out": out, "relu": relu}


def _pool(name, inp, k=2):
    return {"name": name, "op": "maxpool", "inputs": [inp], "k": k, "stride": k}


def _gap(name, inp):
    return {"name": name, "op": "gap", "inputs": [inp]}


def _flat(name, inp):
    return {"name": name, "op": "flatten", "inputs": [inp]}


def _add(name, a, b, relu=False):
    # relu=True is the classic ResNet post-add ReLU; MobileNetV2 keeps
    # linear bottleneck adds (relu=False) — its consumers then need
    # *signed* activation quantization (see calibrate()).
    return {"name": name, "op": "add", "inputs": [a, b], "relu": relu}


def _concat(name, a, b):
    return {"name": name, "op": "concat", "inputs": [a, b]}


# ----------------------------------------------------------------------------
# VGG family — width ladder scaled /4 from the originals, capped at 128.
# 'M' = maxpool. Two FC layers at the head (fine-prunable, per paper Fig 8).
_VGG_CFG = {
    "vgg11": [16, "M", 32, "M", 64, 64, "M", 96, 96, "M", 128, 128],
    "vgg13": [16, 16, "M", 32, 32, "M", 64, 64, "M", 96, 96, "M", 128, 128],
    "vgg16": [16, 16, "M", 32, 32, "M", 64, 64, 64, "M", 96, 96, 96, "M", 128, 128, 128],
    "vgg19": [16, 16, "M", 32, 32, "M", 64, 64, 64, 64, "M", 96, 96, 96, 96, "M",
              128, 128, 128, 128],
}


def vgg(kind, classes):
    layers, prev, i = [], "input", 0
    for v in _VGG_CFG[kind]:
        if v == "M":
            layers.append(_pool(f"pool{i}", prev)); prev = f"pool{i}"
        else:
            layers.append(_conv(f"conv{i}", prev, v)); prev = f"conv{i}"
        i += 1
    layers += [_gap("gap", prev), _flat("flat", "gap"),
               _fc("fc1", "flat", 96, relu=True), _fc("fc2", "fc1", classes)]
    return layers, []


# ----------------------------------------------------------------------------
# ResNet family — real residual topology (identity + 1x1-conv shortcuts).
def resnet(blocks, widths, classes, bottleneck=False, expansion=2):
    layers = [_conv("stem", "input", widths[0])]
    prev, prev_ch = "stem", widths[0]
    groups = []
    bi = 0
    for si, (n, w) in enumerate(zip(blocks, widths)):
        for j in range(n):
            stride = 2 if (si > 0 and j == 0) else 1
            out_ch = w * expansion if bottleneck else w
            pre = prev
            if bottleneck:
                layers.append(_conv(f"b{bi}_c1", prev, w, k=1))
                layers.append(_conv(f"b{bi}_c2", f"b{bi}_c1", w, k=3, stride=stride))
                layers.append(_conv(f"b{bi}_c3", f"b{bi}_c2", out_ch, k=1, relu=False))
                last = f"b{bi}_c3"
            else:
                layers.append(_conv(f"b{bi}_c1", prev, w, k=3, stride=stride))
                layers.append(_conv(f"b{bi}_c2", f"b{bi}_c1", out_ch, k=3, relu=False))
                last = f"b{bi}_c2"
            if stride != 1 or prev_ch != out_ch:
                layers.append(_conv(f"b{bi}_sc", pre, out_ch, k=1, stride=stride,
                                    relu=False))
                sc = f"b{bi}_sc"
                groups.append([last, sc])
            else:
                sc = pre
                # identity shortcut: add couples `last` with the producer of
                # `pre` — handled generically below via the add-op scan.
            layers.append(_add(f"b{bi}_add", last, sc, relu=True))
            prev, prev_ch = f"b{bi}_add", out_ch
            bi += 1
    layers += [_gap("gap", prev), _flat("flat", "gap"),
               _fc("fc1", "flat", 96, relu=True), _fc("fc2", "fc1", classes)]
    return layers, groups


# ----------------------------------------------------------------------------
# MobileNetV2-mini — inverted residuals with depthwise convs.
def mobilenetv2(classes):
    # (expand t, out channels c, repeats n, stride s) — width-scaled
    cfg = [(1, 8, 1, 1), (4, 12, 2, 2), (4, 16, 2, 2), (4, 24, 2, 1)]
    layers = [_conv("stem", "input", 8)]
    prev, prev_ch, bi = "stem", 8, 0
    groups = []
    for t, c, n, s in cfg:
        for j in range(n):
            stride = s if j == 0 else 1
            pre = prev
            hid = prev_ch * t
            if t != 1:
                layers.append(_conv(f"m{bi}_ex", prev, hid, k=1))
                prev = f"m{bi}_ex"
            layers.append(_dwconv(f"m{bi}_dw", prev, k=3, stride=stride))
            layers.append(_conv(f"m{bi}_pj", f"m{bi}_dw", c, k=1, relu=False))
            last = f"m{bi}_pj"
            if stride == 1 and prev_ch == c:
                layers.append(_add(f"m{bi}_add", last, pre))
                prev = f"m{bi}_add"
            else:
                prev = last
            prev_ch = c
            bi += 1
    layers += [_conv("head", prev, 64, k=1), _gap("gap", "head"),
               _flat("flat", "gap"), _fc("fc", "flat", classes)]
    return layers, groups


# ----------------------------------------------------------------------------
# SqueezeNet-mini — fire modules (squeeze 1x1 → expand 1x1 ∥ 3x3, concat).
def squeezenet(classes):
    def fire(i, inp, s, e):
        return [
            _conv(f"f{i}_sq", inp, s, k=1),
            _conv(f"f{i}_e1", f"f{i}_sq", e, k=1),
            _conv(f"f{i}_e3", f"f{i}_sq", e, k=3),
            _concat(f"f{i}_cat", f"f{i}_e1", f"f{i}_e3"),
        ]

    layers = [_conv("stem", "input", 16, stride=2)]
    layers += fire(0, "stem", 4, 8) + fire(1, "f0_cat", 4, 8)
    layers.append(_pool("pool1", "f1_cat"))
    layers += fire(2, "pool1", 8, 16) + fire(3, "f2_cat", 8, 16)
    layers.append(_pool("pool2", "f3_cat"))
    layers += fire(4, "pool2", 12, 24)
    layers += [_conv("head", "f4_cat", classes, k=1), _gap("gap", "head"),
               _flat("flat", "gap")]
    return layers, []


# ----------------------------------------------------------------------------
MODELS = {
    # model name -> (builder, dataset)   — mirrors the paper's §5.1 grid
    "vgg11": (lambda c: vgg("vgg11", c), "synth-c10"),
    "vgg13": (lambda c: vgg("vgg13", c), "synth-c10"),
    "resnet18": (lambda c: resnet([2, 2, 2, 2], [16, 24, 32, 48], c), "synth-c10"),
    "vgg16": (lambda c: vgg("vgg16", c), "synth-c100"),
    "resnet34": (lambda c: resnet([3, 4, 6, 3], [16, 24, 32, 48], c), "synth-c100"),
    "mobilenetv2": (mobilenetv2, "synth-c100"),
    "vgg19": (lambda c: vgg("vgg19", c), "synth-inet"),
    "resnet50": (lambda c: resnet([3, 4, 6, 3], [12, 16, 24, 32], c,
                                  bottleneck=True), "synth-inet"),
    "squeezenet": (squeezenet, "synth-inet"),
}


def infer_shapes(layers, input_hw, in_ch=3):
    """Annotate each layer with in/out shapes [H, W, C] (or [F] post-flatten)."""
    shapes = {"input": (input_hw[0], input_hw[1], in_ch)}
    for L in layers:
        ins = [shapes[i] for i in L["inputs"]]
        op = L["op"]
        if op == "conv":
            h, w, c = ins[0]
            s = L["stride"]
            oh, ow = (h + s - 1) // s, (w + s - 1) // s  # SAME padding
            L["in_shape"], L["out_shape"] = list(ins[0]), [oh, ow, L["out_ch"]]
            L["in_ch"] = c
            shapes[L["name"]] = (oh, ow, L["out_ch"])
        elif op == "dwconv":
            h, w, c = ins[0]
            s = L["stride"]
            oh, ow = (h + s - 1) // s, (w + s - 1) // s
            L["in_shape"], L["out_shape"] = list(ins[0]), [oh, ow, c]
            L["in_ch"], L["out_ch"] = c, c
            shapes[L["name"]] = (oh, ow, c)
        elif op == "fc":
            f = ins[0][0] if len(ins[0]) == 1 else ins[0][0] * ins[0][1] * ins[0][2]
            L["in_shape"], L["out_shape"] = [f], [L["out"]]
            L["in_ch"], L["out_ch"] = f, L["out"]
            shapes[L["name"]] = (L["out"],)
        elif op == "maxpool":
            h, w, c = ins[0]
            k = L["k"]
            shapes[L["name"]] = (max(1, h // k), max(1, w // k), c)
            L["in_shape"] = list(ins[0])
            L["out_shape"] = list(shapes[L["name"]])
        elif op == "gap":
            h, w, c = ins[0]
            shapes[L["name"]] = (c,)
            L["in_shape"], L["out_shape"] = list(ins[0]), [c]
        elif op == "flatten":
            t = ins[0]
            f = t[0] if len(t) == 1 else t[0] * t[1] * t[2]
            shapes[L["name"]] = (f,)
            L["in_shape"], L["out_shape"] = list(t), [f]
        elif op == "add":
            assert ins[0] == ins[1], f"add shape mismatch {L['name']}: {ins}"
            shapes[L["name"]] = ins[0]
            L["in_shape"], L["out_shape"] = list(ins[0]), list(ins[0])
        elif op == "concat":
            (h, w, c1), (h2, w2, c2) = ins
            assert (h, w) == (h2, w2)
            shapes[L["name"]] = (h, w, c1 + c2)
            L["in_shape"], L["out_shape"] = [h, w, c1 + c2], [h, w, c1 + c2]
        else:
            raise ValueError(op)
    return layers


def weight_producers(layers, tensor, by_name):
    """Nearest prunable ancestors that determine `tensor`'s channel layout."""
    if tensor == "input":
        return []
    L = by_name[tensor]
    if L["op"] in ("conv", "dwconv", "fc"):
        return [L["name"]]
    if L["op"] == "concat":
        return []  # concat decouples channel masks
    out = []
    for i in L["inputs"]:
        out += weight_producers(layers, i, by_name)
    return out


def dep_groups(layers, extra):
    """Union-find over coarse-pruning channel couplings (DESIGN.md §6)."""
    by_name = {L["name"]: L for L in layers}
    parent = {}

    def find(x):
        parent.setdefault(x, x)
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    def union(a, b):
        ra, rb = find(a), find(b)
        if ra != rb:
            parent[ra] = rb

    for L in layers:
        if L["op"] == "add":
            prods = []
            for i in L["inputs"]:
                prods += weight_producers(layers, i, by_name)
            for a, b in zip(prods, prods[1:]):
                union(a, b)
        if L["op"] == "dwconv":
            # depthwise channels == producer's output channels
            prods = weight_producers(layers, L["inputs"][0], by_name)
            for p in prods:
                union(L["name"], p)
    for g in extra:
        for a, b in zip(g, g[1:]):
            union(a, b)
    groups = {}
    for x in parent:
        groups.setdefault(find(x), []).append(x)
    return [sorted(g) for g in groups.values() if len(g) > 1]


def build(model_name: str):
    """Full spec dict for one (model, dataset) pair."""
    builder, ds = MODELS[model_name]
    classes, h, w, _, _ = DATASETS[ds]
    layers, extra = builder(classes)
    layers = infer_shapes(layers, (h, w))
    prunable = [L["name"] for L in layers if L["op"] in ("conv", "dwconv", "fc")]
    return {
        "name": model_name,
        "dataset": ds,
        "input": [h, w, 3],
        "classes": classes,
        "layers": layers,
        "prunable": prunable,
        "dep_groups": dep_groups(layers, extra),
    }
