"""Build-time trainer + calibration pass (hand-rolled Adam; no optax here).

Trains each mini CNN on its synthetic dataset, then measures the three
calibration statistics the Rust side needs (DESIGN.md §5):

  * act_scale  — Laplace scale (mean |x|) of every prunable layer's
                 *input* activations → in-graph clipping (Banner [21]);
  * sal:<l>    — |w ⊙ ∂L/∂w| saliency on a calibration batch → the
                 "Sensitivity"/SNIP pruning criterion (Table 2);
  * chsq:<l>   — per-output-channel mean-square feature-map energy → the
                 "FM Reconstruction" pruning criterion (Table 2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .model import forward, forward_with_taps


def _loss(params, spec, X, y):
    logits = forward(spec, params, X)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def _accuracy(params, spec, X, y, bs=256):
    correct = 0
    for i in range(0, len(X), bs):
        logits = forward(spec, params, X[i : i + bs])
        correct += int(jnp.sum(jnp.argmax(logits, -1) == y[i : i + bs]))
    return correct / len(X)


def train(spec, train_xy, val_xy, steps=600, bs=64, lr=2e-3, seed=0, log=print):
    """Adam training loop; returns (params, history)."""
    from .model import init_params

    Xtr, ytr = train_xy
    params = init_params(spec, seed)
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    b1, b2, eps = 0.9, 0.999, 1e-8

    @jax.jit
    def step_fn(params, m, v, t, X, y):
        loss, g = jax.value_and_grad(_loss)(params, spec, X, y)
        m = jax.tree.map(lambda a, b: b1 * a + (1 - b1) * b, m, g)
        v = jax.tree.map(lambda a, b: b2 * a + (1 - b2) * b * b, v, g)
        mh = jax.tree.map(lambda a: a / (1 - b1**t), m)
        vh = jax.tree.map(lambda a: a / (1 - b2**t), v)
        # linear LR warm-up over the first 100 steps — deep plain-VGG
        # stacks (no BN) otherwise die to a single early oversized update
        lr_t = lr * jnp.minimum(1.0, t / 100.0)
        params = jax.tree.map(
            lambda p, a, b: p - lr_t * a / (jnp.sqrt(b) + eps), params, mh, vh
        )
        return params, m, v, loss

    rng = np.random.default_rng(seed)
    history = []
    n = len(Xtr)
    for t in range(1, steps + 1):
        idx = rng.integers(0, n, size=bs)
        params, m, v, loss = step_fn(params, m, v, jnp.float32(t), Xtr[idx], ytr[idx])
        if t % 200 == 0 or t == steps:
            acc = _accuracy(params, spec, *val_xy)
            history.append((t, float(loss), acc))
            log(f"    step {t:5d}  loss {float(loss):.3f}  val acc {acc:.3f}")
    return params, history


def calibrate(spec, params, Xcal, ycal):
    """Compute act scales, SNIP saliencies and channel FM energies."""
    _, taps = forward_with_taps(spec, params, Xcal)
    act_scales, act_signed, chsq = [], [], {}
    for name in spec["prunable"]:
        xin = taps[f"in:{name}"]
        # Without BatchNorm the post-add activations of deep nets are
        # heavy-tailed: a pure Laplace mean-|x| scale under-clips badly
        # (observed 10-30x clipping on ResNet34). Calibrate the scale so
        # the 8-bit clip sits at the 99.9th percentile; lower precisions
        # then shrink the clip by Banner's relative schedule in-graph.
        p999 = float(jnp.percentile(jnp.abs(xin), 99.9))
        act_scales.append(p999 / 9.90)
        act_signed.append(bool(jnp.min(xin) < -1e-6))
        out = taps[f"out:{name}"]
        axes = tuple(range(out.ndim - 1))
        chsq[name] = np.asarray(jnp.mean(out * out, axis=axes), dtype=np.float32)
    grads = jax.grad(_loss)(params, spec, Xcal, ycal)
    sal = {
        name: np.asarray(jnp.abs(params[name][0] * grads[name][0]), dtype=np.float32)
        for name in spec["prunable"]
    }
    return np.array(act_scales, dtype=np.float32), act_signed, sal, chsq


def eval_quantized(spec, params, act_scales, X, y, bits=8.0, bs=256,
                   conv_impl="lax"):
    """Top-1 accuracy of the activation-quantized graph (weights float)."""
    nP = len(spec["prunable"])
    ab = jnp.full((nP,), bits, dtype=jnp.float32)
    sc = jnp.asarray(act_scales)
    correct = 0
    for i in range(0, len(X), bs):
        logits = forward(spec, params, X[i : i + bs], act_bits=ab, act_scales=sc,
                         conv_impl=conv_impl)
        correct += int(jnp.sum(jnp.argmax(logits, -1) == y[i : i + bs]))
    return correct / len(X)
