"""L1 Pallas kernel: tiled matmul with fused activation fake-quantization.

The paper's reward oracle runs validation inference at *every* RL step
(§4.2.3); its hot-spot is the im2col convolution matmul. The kernel
fuses the per-layer activation fake-quantization (paper §4.1) into the
tile load, so activations never round-trip to HBM at full precision.

TPU mapping (DESIGN.md §2/§8): grid over (M/bm, N/bn) output tiles; each
program holds an (bm, K) activation tile and (K, bn) weight tile in VMEM
(BlockSpec), accumulates in f32 — MXU-shaped, bf16-ready. On this image
Pallas MUST run interpret=True (CPU PJRT cannot execute Mosaic
custom-calls); real-TPU perf is estimated from the BlockSpec footprint
in DESIGN.md §8.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes: VMEM footprint = (bm*K + K*bn + bm*bn) * 4B.
# For K <= 1152 (3x3x128 im2col) and bm=bn=128: ~1.3 MB — well under VMEM.
BM, BN = 128, 128


def _kernel(x_ref, w_ref, lo_ref, hi_ref, step_ref, o_ref):
    lo = lo_ref[0, 0]
    hi = hi_ref[0, 0]
    step = step_ref[0, 0]
    x = x_ref[...]
    xq = jnp.round((jnp.clip(x, lo, hi) - lo) / step) * step + lo
    o_ref[...] = xq @ w_ref[...]


def _pad_to(x, m, axis):
    r = (-x.shape[axis]) % m
    if r == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, r)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def qmatmul(x, w, lo, hi, step, bm=BM, bn=BN):
    """Fused fake-quant(x) @ w via Pallas. x:[M,K] w:[K,N] -> [M,N].

    M and N are padded up to the tile grid. Padding the *activation* rows
    with zeros is safe for any quantization grid: fake_quant(0) lands on
    some grid value q0, those rows are sliced away below; padded weight
    columns are zero so extra N columns are sliced away likewise.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    xp = _pad_to(x, bm, 0)
    wp = _pad_to(w, bn, 1)
    mp, np_ = xp.shape[0], wp.shape[1]
    lo2 = jnp.reshape(jnp.asarray(lo, jnp.float32), (1, 1))
    hi2 = jnp.reshape(jnp.asarray(hi, jnp.float32), (1, 1))
    step2 = jnp.reshape(jnp.asarray(step, jnp.float32), (1, 1))
    out = pl.pallas_call(
        _kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xp, wp, lo2, hi2, step2)
    return out[:m, :n]


def vmem_bytes(k, bm=BM, bn=BN):
    """VMEM footprint estimate of one program instance (DESIGN.md §8)."""
    return 4 * (bm * k + k * bn + bm * bn + 2)


def mxu_utilization(m, n, k, bm=BM, bn=BN, mxu=128):
    """Fraction of MXU-issue slots doing useful work for this shape."""
    import math

    useful = m * n * k
    issued = (
        math.ceil(m / bm) * math.ceil(n / bn) * bm * bn
        * math.ceil(k / mxu) * mxu
    )
    return useful / issued
