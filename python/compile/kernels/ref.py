"""Pure-jnp oracle for the fused quantize-matmul kernel (L1 correctness).

This is the reference semantics the Pallas kernel must reproduce bit-for
-bit (up to f32 accumulation order): asymmetric, clipped, linear
fake-quantization of the activation operand (paper §4.1 — per-layer
precision, Laplace clipping after Banner et al. [21]) fused with the
matmul that consumes it.
"""

from __future__ import annotations

import jax.numpy as jnp

# Optimal clipping ratio alpha*/b for a Laplace(b) distribution, bits 2..8
# (Banner et al., "Post training 4-bit quantization", NeurIPS 2019).
LAPLACE_CLIP = jnp.array([2.83, 3.89, 5.03, 6.20, 7.41, 8.64, 9.90],
                         dtype=jnp.float32)


def quant_params(bits, act_scale, signed=False):
    """(lo, hi, step) for fake-quantizing an activation tensor.

    `bits` may be a traced f32 scalar; it is rounded and clamped to [2, 8]
    in-graph so a single compiled executable serves every precision.
    Post-ReLU tensors use the one-sided grid [0, alpha]; signed tensors
    (e.g. MobileNetV2 linear-bottleneck outputs) use [-alpha, alpha].
    """
    b = jnp.clip(jnp.round(bits), 2.0, 8.0)
    idx = (b - 2.0).astype(jnp.int32)
    alpha = act_scale * jnp.take(LAPLACE_CLIP, idx, mode="clip")
    levels = jnp.exp2(b) - 1.0
    if signed:
        return -alpha, alpha, 2.0 * alpha / levels
    return jnp.zeros_like(alpha), alpha, alpha / levels


def fake_quant(x, lo, hi, step):
    """Asymmetric clipped linear fake-quant onto the [lo, hi] grid."""
    return jnp.round((jnp.clip(x, lo, hi) - lo) / step) * step + lo


def qmatmul_ref(x, w, lo, hi, step):
    """Reference: fake-quantize `x`, then x @ w. x:[M,K] w:[K,N]."""
    return fake_quant(x, lo, hi, step) @ w
