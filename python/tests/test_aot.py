"""AOT export path: HLO-text lowering of the quantized-inference graph."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import arch as archmod
from compile.aot import export_hlo, to_hlo_text
from compile.model import forward, init_params


def _tiny_spec():
    """A hand-rolled 3-layer spec (conv -> gap -> fc) for fast lowering."""
    layers = [
        {"name": "c1", "op": "conv", "inputs": ["input"], "out_ch": 4, "k": 3,
         "stride": 1, "relu": True},
        {"name": "gap", "op": "gap", "inputs": ["c1"]},
        {"name": "f1", "op": "fc", "inputs": ["gap"], "out": 5, "relu": False},
    ]
    layers = archmod.infer_shapes(layers, (8, 8))
    return {
        "name": "tiny", "dataset": "synth-c10", "input": [8, 8, 3],
        "classes": 5, "layers": layers, "prunable": ["c1", "f1"],
        "dep_groups": [], "act_signed": [False, False],
    }


def test_export_hlo_text_is_loadable_hlo():
    spec = _tiny_spec()
    text = export_hlo(spec, np.array([0.5, 0.4], np.float32), batch=4)
    assert text.startswith("HloModule")
    assert "custom-call" not in text  # CPU PJRT cannot run custom-calls
    # signature: 2*(w,b) + act_bits + images = 6 params
    assert "(f32[3,3,3,4]" in text.replace(" ", "")[:400] or "f32[3,3,3,4]" in text


def test_exported_graph_matches_eager_forward():
    """Lowered-graph semantics == eager forward (same act_bits)."""
    spec = _tiny_spec()
    params = init_params(spec, 3)
    scales = np.array([0.5, 0.4], np.float32)
    bits = jnp.array([6.0, 4.0], jnp.float32)
    x = jnp.abs(jnp.sin(jnp.arange(4 * 8 * 8 * 3, dtype=jnp.float32))).reshape(
        4, 8, 8, 3
    )
    eager = forward(spec, params, x, act_bits=bits, act_scales=jnp.asarray(scales))

    def fn(w0, b0, w1, b1, act_bits, images):
        p = {"c1": (w0, b0), "f1": (w1, b1)}
        return (
            forward(spec, p, images, act_bits=act_bits,
                    act_scales=jnp.asarray(scales)),
        )

    jitted = jax.jit(fn)
    (got,) = jitted(params["c1"][0], params["c1"][1], params["f1"][0],
                    params["f1"][1], bits, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(eager), rtol=1e-5,
                               atol=1e-5)
    # and the HLO-text conversion of that exact lowering round-trips
    text = to_hlo_text(jitted.lower(params["c1"][0], params["c1"][1],
                                    params["f1"][0], params["f1"][1], bits, x))
    assert "HloModule" in text


def test_all_manifest_archs_lower():
    """Every model in the zoo traces through the quantized graph."""
    for name in archmod.MODELS:
        spec = archmod.build(name)
        nP = len(spec["prunable"])
        spec["act_signed"] = [False] * nP
        params = init_params(spec, 0)
        h, w, c = spec["input"]
        x = jnp.ones((2, h, w, c), jnp.float32) * 0.3
        y = forward(spec, params, x, act_bits=jnp.full((nP,), 8.0),
                    act_scales=jnp.full((nP,), 0.5))
        assert y.shape == (2, spec["classes"])
        assert bool(jnp.all(jnp.isfinite(y)))
