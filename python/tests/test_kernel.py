"""L1 correctness: Pallas fused quant-matmul vs the pure-jnp oracle.

Hypothesis sweeps shapes and quantization parameters; assert_allclose
against ref.py is THE core correctness signal for the kernel.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.qmatmul import mxu_utilization, qmatmul, vmem_bytes
from compile.kernels.ref import fake_quant, qmatmul_ref, quant_params


def _run(m, k, n, bits, scale, seed, signed=False, bm=32, bn=32):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (m, k), jnp.float32) * scale
    if not signed:
        x = jax.nn.relu(x)
    w = jax.random.normal(k2, (k, n), jnp.float32)
    lo, hi, step = quant_params(jnp.float32(bits), jnp.float32(scale), signed=signed)
    got = qmatmul(x, w, lo, hi, step, bm=bm, bn=bn)
    want = qmatmul_ref(x, w, lo, hi, step)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_exact_small():
    _run(8, 16, 8, 8.0, 1.0, 0)


def test_tile_divisible():
    _run(64, 48, 64, 4.0, 0.7, 1)


def test_needs_padding():
    # M, N not multiples of the tile — padding path must be exact
    _run(37, 21, 19, 5.0, 1.3, 2)


def test_signed_grid():
    _run(33, 16, 9, 4.0, 1.0, 3, signed=True)


@pytest.mark.parametrize("bits", [2, 3, 4, 5, 6, 7, 8])
def test_all_precisions(bits):
    _run(33, 24, 17, float(bits), 0.9, bits)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 64),
    n=st.integers(1, 70),
    bits=st.floats(2.0, 8.0),
    scale=st.floats(0.05, 4.0),
    signed=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_hypothesis_sweep(m, k, n, bits, scale, signed, seed):
    _run(m, k, n, bits, scale, seed, signed=signed)


def test_quant_params_monotone():
    """More bits -> finer step, same-or-larger clip range."""
    scale = jnp.float32(1.0)
    steps, his = [], []
    for b in range(2, 9):
        _, hi, s = quant_params(jnp.float32(b), scale)
        his.append(float(hi))
        steps.append(float(s))
    assert all(s1 > s2 for s1, s2 in zip(steps, steps[1:]))
    assert all(a1 <= a2 for a1, a2 in zip(his, his[1:]))


def test_signed_grid_symmetric():
    lo, hi, step = quant_params(jnp.float32(5), jnp.float32(2.0), signed=True)
    assert float(lo) == -float(hi)
    assert float(step) == pytest.approx(2 * float(hi) / (2**5 - 1))


def test_fake_quant_idempotent():
    lo, hi, step = quant_params(jnp.float32(4), jnp.float32(1.0))
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(3), (128,)))
    q1 = fake_quant(x, lo, hi, step)
    q2 = fake_quant(q1, lo, hi, step)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), atol=1e-6)


def test_fake_quant_levels():
    """Quantized values land on the step grid within [lo, hi]."""
    lo, hi, step = quant_params(jnp.float32(3), jnp.float32(0.5))
    x = jnp.linspace(-1, 5, 257)
    q = np.asarray(fake_quant(x, lo, hi, step))
    ratio = (q - float(lo)) / float(step)
    np.testing.assert_allclose(ratio, np.round(ratio), atol=1e-4)
    assert q.min() >= float(lo) - 1e-6 and q.max() <= float(hi) + 1e-6


def test_vmem_estimate_within_budget():
    assert vmem_bytes(1152) < 16 * 1024 * 1024  # BlockSpec fits VMEM


def test_mxu_utilization_bounds():
    u = mxu_utilization(256, 128, 1152)
    assert 0.0 < u <= 1.0
    assert mxu_utilization(128, 128, 128) == 1.0
