"""L2 checks: arch specs, shape inference, quantized forward, dep groups."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import arch as archmod
from compile import datasets as dsmod
from compile.model import forward, init_params


@pytest.mark.parametrize("name", list(archmod.MODELS.keys()))
def test_build_and_shapes(name):
    spec = archmod.build(name)
    assert spec["classes"] == dsmod.DATASETS[spec["dataset"]][0]
    assert len(spec["prunable"]) >= 8, "paper needs per-layer decisions"
    # every layer input resolves
    names = {"input"} | {L["name"] for L in spec["layers"]}
    for L in spec["layers"]:
        for i in L["inputs"]:
            assert i in names


@pytest.mark.parametrize("name", ["vgg11", "resnet18", "mobilenetv2", "squeezenet"])
def test_forward_float(name):
    spec = archmod.build(name)
    params = init_params(spec, 0)
    h, w, c = spec["input"]
    x = jnp.ones((2, h, w, c), jnp.float32) * 0.5
    y = forward(spec, params, x)
    assert y.shape == (2, spec["classes"])
    assert bool(jnp.all(jnp.isfinite(y)))


@pytest.mark.parametrize("name", ["vgg11", "resnet18"])
def test_forward_quantized_matches_float_at_high_bits(name):
    """8-bit activation quant with *calibrated* clip scales should barely
    move the logits (arbitrary scales clip deep nets — that's exactly the
    ResNet34 collapse the percentile calibration fixed)."""
    from compile.model import forward_with_taps

    spec = archmod.build(name)
    params = init_params(spec, 0)
    h, w, c = spec["input"]
    x = jnp.abs(jnp.sin(jnp.arange(2 * h * w * c, dtype=jnp.float32))).reshape(
        2, h, w, c
    )
    _, taps = forward_with_taps(spec, params, x)
    scales = jnp.array(
        [
            float(jnp.percentile(jnp.abs(taps[f"in:{n}"]), 99.9)) / 9.90
            for n in spec["prunable"]
        ],
        jnp.float32,
    )
    nP = len(spec["prunable"])
    yf = forward(spec, params, x)
    yq = forward(spec, params, x, act_bits=jnp.full((nP,), 8.0), act_scales=scales)
    scale = float(jnp.max(jnp.abs(yf))) + 1e-6
    assert float(jnp.max(jnp.abs(yf - yq))) < 0.05 * scale + 0.05


def test_pallas_path_matches_lax_path():
    """conv_impl='pallas' (L1 kernel) must equal conv_impl='lax' (XLA conv)."""
    spec = archmod.build("vgg11")
    params = init_params(spec, 1)
    h, w, c = spec["input"]
    x = jnp.abs(jnp.cos(jnp.arange(2 * h * w * c, dtype=jnp.float32))).reshape(
        2, h, w, c
    )
    nP = len(spec["prunable"])
    bits = jnp.full((nP,), 5.0)
    scales = jnp.full((nP,), 0.6, jnp.float32)
    y1 = forward(spec, params, x, act_bits=bits, act_scales=scales, conv_impl="lax")
    y2 = forward(spec, params, x, act_bits=bits, act_scales=scales,
                 conv_impl="pallas")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-4)


def test_dep_groups_resnet():
    """Downsample blocks couple main-path last conv with the shortcut conv."""
    spec = archmod.build("resnet18")
    groups = spec["dep_groups"]
    flat = [set(g) for g in groups]
    assert any({"b2_c2", "b2_sc"} <= g for g in flat), groups


def test_dep_groups_mobilenet_dwconv():
    """Depthwise convs couple to their producing expansion conv."""
    spec = archmod.build("mobilenetv2")
    flat = [set(g) for g in spec["dep_groups"]]
    assert any({"m1_ex", "m1_dw"} <= g for g in flat), spec["dep_groups"]


def test_datasets_deterministic_and_separable():
    X1, y1 = dsmod.make_split("synth-c10", 64, 5)
    X2, y2 = dsmod.make_split("synth-c10", 64, 5)
    np.testing.assert_array_equal(X1, X2)
    np.testing.assert_array_equal(y1, y2)
    assert X1.min() >= 0.0 and X1.max() <= 1.0
    assert X1.shape == (64, 16, 16, 3)


def test_dataset_classes_cover():
    _, y = dsmod.make_split("synth-c100", 4000, 1)
    assert len(np.unique(y)) == 100
