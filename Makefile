# Convenience targets. The Rust side never needs Python at run time;
# `artifacts` is the one-time L2/L1 export (needs a JAX environment).

ARTIFACTS ?= artifacts

.PHONY: artifacts build test doc bench

artifacts:
	cd python && python -m compile.aot --out ../$(ARTIFACTS)

build:
	cargo build --release

test:
	cargo test -q

doc:
	cargo doc --no-deps

bench:
	cargo bench
