//! Quickstart: load a model artifact, inspect it, run a handful of
//! compression episodes and print what the framework found.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use hapq::config::RunConfig;
use hapq::coordinator::Coordinator;

fn main() -> Result<()> {
    let cfg = RunConfig {
        episodes: 30,
        warmup: 6,
        reward_subset: 128,
        out: "results/quickstart".into(),
        ..RunConfig::default()
    };
    let coord = Coordinator::new(cfg)?;

    println!("== models in artifacts/ ==");
    for e in &coord.models {
        println!("  {:<14} ({})", e.model, e.dataset);
    }

    let model = "vgg11";
    let (arch, weights, _) = coord.load_arch(model)?;
    println!(
        "\n== {model} == {} prunable layers, {} params, dense 8-bit acc {:.3}",
        arch.prunable.len(),
        weights.n_params(),
        arch.acc_int8
    );

    println!("\ncompressing ({} episodes)...", coord.cfg.episodes);
    let report = coord.compress(model, true)?;
    println!(
        "\nbest: energy gain {:.1}%, val acc loss {:.2}%, test acc {:.3} (dense {:.3})",
        report.best.energy_gain * 100.0,
        report.best.acc_loss * 100.0,
        report.test_acc,
        report.test_acc_dense,
    );
    println!("\nper-layer policy:");
    for (i, a) in report.best.per_layer.iter().enumerate() {
        println!(
            "  layer {i:2}  {:<12} sparsity {:.2}  bits {}",
            a.alg.name(),
            a.sparsity,
            a.bits
        );
    }
    let path = coord.save_report(&report)?;
    println!("\nreport -> {}", path.display());
    Ok(())
}
