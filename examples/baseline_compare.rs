//! Head-to-head on one model: OURS vs the four state-of-the-art
//! baselines plus NSGA-II — a one-model slice of Fig 7 + Fig 9.
//!
//! ```bash
//! cargo run --release --example baseline_compare -- [model] [episodes]
//! ```

use anyhow::Result;
use hapq::config::RunConfig;
use hapq::coordinator::Coordinator;

fn main() -> Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "vgg11".into());
    let episodes: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(60);
    let cfg = RunConfig {
        episodes,
        warmup: (episodes / 10).max(4),
        reward_subset: 128,
        out: "results/compare".into(),
        ..RunConfig::default()
    };
    let coord = Coordinator::new(cfg)?;

    println!(
        "{:<8} {:>11} {:>13} {:>12} {:>8} {:>8}",
        "method", "energy-gain", "test-acc-loss", "val-acc-loss", "evals", "secs"
    );
    for method in ["ours", "amc", "haq", "asqj", "opq", "nsga2"] {
        let report = if method == "ours" {
            coord.compress(&model, false)?
        } else {
            coord.run_baseline(&model, method)?
        };
        coord.save_report(&report)?;
        println!(
            "{:<8} {:>10.1}% {:>12.2}% {:>11.2}% {:>8} {:>7.1}s",
            method,
            report.best.energy_gain * 100.0,
            report.test_acc_loss() * 100.0,
            report.best.acc_loss * 100.0,
            report.evals,
            report.wall_secs
        );
    }
    Ok(())
}
