//! Fig-1-style standalone study: how each of the SEVEN pruning
//! algorithms of Table 2 trades accuracy against energy on one model —
//! the motivation experiment for using a *diverse* algorithm set.
//!
//! ```bash
//! cargo run --release --example pruning_sweep -- [model]
//! ```

use anyhow::Result;
use hapq::config::RunConfig;
use hapq::coordinator::Coordinator;
use hapq::env::Action;
use hapq::pruning::PruneAlg;

fn main() -> Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "resnet18".into());
    let cfg = RunConfig { reward_subset: 128, ..RunConfig::default() };
    let coord = Coordinator::new(cfg)?;
    let mut env = coord.build_env(&model)?;
    let n = env.n_layers();

    println!("# {model}: all 7 pruning algorithms, uniform sparsity, 8-bit");
    println!("{:<13} {:>9} {:>10} {:>12}", "alg", "sparsity", "acc-loss", "energy-gain");
    for alg in PruneAlg::ALL {
        for sp in [0.2, 0.4, 0.6] {
            let actions = vec![
                Action {
                    ratio: sp / hapq::env::MAX_RATIO,
                    bits: 1.0,
                    alg: alg.index(),
                };
                n
            ];
            let sol = env.evaluate_config(&actions)?;
            println!(
                "{:<13} {:>9.1} {:>9.2}% {:>11.2}%",
                alg.name(),
                sp,
                sol.acc_loss * 100.0,
                sol.energy_gain * 100.0
            );
        }
    }
    println!("\n(no single algorithm dominates — the motivation for the");
    println!(" composite agent's per-layer algorithm selection, paper §3.1)");
    Ok(())
}
