//! END-TO-END DRIVER (DESIGN.md §4, row E2E — the required full-system
//! validation): compress vgg11/synth-c10 with the complete composite-RL
//! stack, logging the per-episode reward curve, then verify the final
//! policy on the held-out test split. When built with `--features
//! pjrt` (and a real PJRT binding linked), it additionally cross-checks
//! the L1 Pallas-path executable against the default XLA-conv
//! executable.
//!
//! Proves all layers compose: Pallas kernel (L1) → JAX graph (L2) → HLO
//! text → inference backend → pruning/quantization/energy/RL (L3).
//!
//! ```bash
//! make artifacts && cargo run --release --example compress_e2e
//! # env knobs: HAPQ_EPISODES (default 120), HAPQ_BACKEND (native|pjrt)
//! ```

use anyhow::Result;
use hapq::config::RunConfig;
use hapq::coordinator::Coordinator;
use hapq::runtime::BackendKind;

fn main() -> Result<()> {
    let episodes: usize = std::env::var("HAPQ_EPISODES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(120);
    let backend = match std::env::var("HAPQ_BACKEND") {
        Ok(s) => BackendKind::parse(&s)?,
        Err(_) => BackendKind::Native,
    };
    let cfg = RunConfig {
        episodes,
        warmup: (episodes / 10).max(5),
        reward_subset: 128,
        out: "results/e2e".into(),
        backend,
        ..RunConfig::default()
    };
    let coord = Coordinator::new(cfg)?;
    let model = "vgg11";
    println!("backend: {}", coord.cfg.backend.name());

    // --- full compression run, logging the loss/reward curve ---
    let t0 = std::time::Instant::now();
    let report = coord.compress(model, true)?;
    println!("\n== reward curve (episode, reward) ==");
    for (i, r) in report.reward_curve.iter().enumerate() {
        if i % (episodes / 20).max(1) == 0 || i + 1 == report.reward_curve.len() {
            println!("  {i:4}  {r:8.2}");
        }
    }
    println!(
        "\n== result == energy gain {:.1}% | val loss {:.2}% | test acc {:.3} (dense {:.3}) | {:.1}s",
        report.best.energy_gain * 100.0,
        report.best.acc_loss * 100.0,
        report.test_acc,
        report.test_acc_dense,
        t0.elapsed().as_secs_f64()
    );

    pallas_crosscheck(&coord, model)?;

    let path = coord.save_report(&report)?;
    println!("\nreport -> {}", path.display());
    Ok(())
}

/// L1 composition proof: the Pallas-kernel executable must agree with
/// the XLA-conv executable on identical examples. PJRT-only — the
/// native interpreter has no separate Pallas path to compare.
#[cfg(feature = "pjrt")]
fn pallas_crosscheck(coord: &Coordinator, model: &str) -> Result<()> {
    use hapq::runtime::{InferenceSession, Split};
    let entry = coord.entry(model)?.clone();
    let Some(pallas_hlo) = entry.pallas_hlo.clone() else {
        println!("\n(no pallas artifact — skipping cross-check)");
        return Ok(());
    };
    println!("\n== verifying Pallas-path executable ==");
    let (arch, weights, e) = coord.load_arch(model)?;
    let data = coord.cfg.artifacts.join(format!("{}.data.npz", e.dataset));
    let hlo = coord.cfg.artifacts.join(&e.hlo);
    let n = arch.prunable.len();
    let bits = vec![6.0f32; n];
    let lax = InferenceSession::open(
        BackendKind::Pjrt, &arch, Some(&hlo), &data, Split::Test, 128, None, 1,
    )?;
    let pal = InferenceSession::open(
        BackendKind::Pjrt,
        &arch,
        Some(&coord.cfg.artifacts.join(&pallas_hlo)),
        &data,
        Split::Test,
        128,
        Some(entry.pallas_batch),
        1,
    )?;
    let acc_lax = lax.accuracy(&weights, &bits)?;
    let acc_pal = pal.accuracy(&weights, &bits)?;
    println!("  XLA-conv path acc@6bit: {acc_lax:.4}");
    println!("  Pallas-path  acc@6bit: {acc_pal:.4}");
    anyhow::ensure!(
        (acc_lax - acc_pal).abs() < 0.02,
        "Pallas and XLA paths disagree"
    );
    println!("  MATCH — L1 kernel composes through the full stack");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn pallas_crosscheck(_coord: &Coordinator, _model: &str) -> Result<()> {
    println!("\n(built without --features pjrt — skipping Pallas cross-check)");
    Ok(())
}
