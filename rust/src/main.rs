//! `hapq` — CLI for the HAPQ compression framework.
//!
//! Subcommands map 1:1 onto the paper's experiments (DESIGN.md §4):
//!
//! ```text
//! hapq list                                  # models in the artifact manifest
//! hapq compress  --model vgg11 [--episodes N]   # ours (Fig 7a)
//! hapq baseline  --model vgg11 --method amc|haq|asqj|opq|nsga2
//! hapq compare   [--models a,b] [--methods ...] # Fig 7 grid
//! hapq fig1      --model vgg16                  # sparsity sweep
//! hapq fig2a                                    # quantization energy grid
//! hapq fig2b     --model resnet18               # uniform vs mixed
//! hapq fig5                                     # reward LUT heatmap
//! hapq fig8      --model resnet18               # per-layer policy dump
//! hapq ablate    --model vgg11                  # agent-design ablations
//! hapq perf      --model vgg11                  # hot-path latency metrics
//! hapq hw        --model vgg11                  # per-target cost breakdown
//! hapq trace     out/trace.jsonl                # analyze a --trace file
//! hapq pareto    [--hw mcu --max-acc-loss 0.012]  # query the Pareto archive
//! ```
//!
//! `compare --jobs N` fans out over N worker processes.
//!
//! Every command accepts `--hw NAME` (default `HAPQ_HW` or
//! `eyeriss-64`) selecting the hardware target the cost model prices
//! against — built-ins: `eyeriss-64`, `eyeriss-128`, `bitfusion`
//! (bit-serial), `mcu` — or `--hw-file PATH` loading a JSON
//! accelerator profile. `compare --hw a,b` fans the grid out over a
//! target list for cross-hardware sweeps (reports land under
//! `out/hw-<target>/`).
//!
//! Search runs (`compress`, `baseline`, `compare`) additionally accept:
//!
//! * `--seeds N` — search N consecutive seeds (one worker process per
//!   seed, fanned across the `--jobs` pool) and merge the reports into
//!   one best-of JSON;
//! * `--checkpoint [PATH]` + `--checkpoint-every K` — periodic
//!   resumable search checkpoints (default path
//!   `<out>/<model>__<method>.ckpt`);
//! * `--resume` — restore from the checkpoint and continue;
//! * `--stop-after N` — suspend (checkpoint + exit 0) after N episodes
//!   this session; a later `--resume` run reproduces the uninterrupted
//!   run's report exactly.
//!
//! Every command accepts `--backend {native,pjrt}` selecting the
//! accuracy-oracle executor: `native` (default) interprets the model
//! graph in pure Rust; `pjrt` runs the AOT-compiled HLO through the
//! XLA PJRT C API and needs a binary built with `--features pjrt`.
//! `--threads N` (default: `HAPQ_THREADS` or 1) sizes the native
//! engine's evaluation worker pool — results are bit-identical at any
//! thread count. `--kernel {f32,int}` (default: `HAPQ_KERNEL` or
//! `int`) picks the native compute kernel: `int` is the quantized
//! fast path, `f32` the reference — logits are bit-identical either
//! way (`rust/tests/kernel_conformance.rs`), so the flag is purely a
//! performance knob. `--gemm-tile N` (default: `HAPQ_GEMM_TILE` or 64)
//! sets the blocked integer GEMM's column tile width — also purely a
//! perf/testing knob, bit-identical at every width. `--memo {on,off}`
//! (default: `HAPQ_MEMO` or `on`) toggles search-loop memoization —
//! config-fingerprinted eval/pack caches plus the kernel scratch
//! arenas — with `--memo-pack-cap N` / `--memo-eval-cap N` sizing the
//! two LRU caches; results are bit-identical either way (memo hits
//! replay exactly the value a cold eval computed). `--sched
//! {static,steal}` (default: `HAPQ_SCHED` or `steal`) picks the shard
//! scheduler: `steal` lets drained workers claim shards from loaded
//! ones (and fans dirty-layer packing across the idle pool), `static`
//! keeps the fixed round-robin ownership — logits are bit-identical
//! at every thread count and steal order, so the flag is purely a
//! performance knob.
//!
//! `--trace PATH` (default: `HAPQ_TRACE`) records a structured JSONL
//! trace of the run — search step/episode events, env phase spans,
//! exec-pool shard spans — without perturbing results (bit-identical
//! on/off; `rust/tests/telemetry.rs`). `hapq trace PATH` renders the
//! file as reward-curve / per-phase / hottest-layer tables, `--chrome
//! OUT.json` exports it for `chrome://tracing`, and `--canon` prints
//! the clock-stripped canonical stream (determinism diffs). `hapq perf
//! --json` / `hapq hw --json` emit the matching `MetricsRegistry`
//! snapshot instead of human tables.
//!
//! Every finished run also folds its best solution into the
//! cross-run Pareto archive at `<out>/pareto.json` (non-dominated per
//! model fingerprint × hw target; launcher fan-outs fold worker reports
//! into the leader's archive deterministically). `hapq pareto` prints
//! the per-group front tables and a cross-target summary, answers
//! constrained queries (`--max-acc-loss FRAC` with `--metric
//! energy|latency`, filters `--model`/`--hw`), exports byte-stable
//! front JSON (`--export OUT.json`), and emits archive counters as a
//! `MetricsRegistry` snapshot (`--json`). `--archive PATH` points it at
//! a non-default archive file.

use std::time::Instant;

use anyhow::Result;
use hapq::config::{Cli, RunConfig};
use hapq::coordinator::{figures, Coordinator};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        print_help();
        std::process::exit(2);
    }
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    eprintln!(
        "hapq — Hardware-Aware DNN Compression via Diverse Pruning and \
         Mixed-Precision Quantization\n\
         commands: list, compress, baseline, compare, fig1, fig2a, fig2b, \
         fig5, fig8, ablate, report, perf, hw, trace, pareto\n\
         common flags: --artifacts DIR --out DIR --episodes N --seed N \
         --reward-subset N --model NAME --backend native|pjrt \
         --kernel f32|int --threads N --gemm-tile N \
         --memo on|off --memo-pack-cap N --memo-eval-cap N \
         --sched static|steal \
         --hw eyeriss-64|eyeriss-128|bitfusion|mcu --hw-file PROFILE.json \
         --trace PATH (JSONL telemetry; default HAPQ_TRACE)\n\
         search flags: --seeds N (best-of multi-seed; with compare/--jobs) \
         --checkpoint [PATH] --checkpoint-every K --resume --stop-after N\n\
         compare flags: --models a,b|all --methods ours,amc,... --jobs N \
         --hw a,b (cross-target sweep)\n\
         hw flags: --model NAME --sparsity S --bits B (reference config \
         for the per-layer breakdown and the cross-target table)\n\
         perf/hw flags: --json (print the MetricsRegistry snapshot)\n\
         trace flags: FILE.jsonl [--top N] [--chrome OUT.json] [--canon]\n\
         pareto flags: [--archive PATH] [--model NAME] [--hw TARGET] \
         [--max-acc-loss FRAC] [--metric energy|latency] \
         [--export OUT.json] [--json]"
    );
}

/// Run a multi-seed sweep over (model, method) pairs and print the
/// merged best-of summary table (one worker process per pair × seed).
fn print_multi_seed(
    coord: &Coordinator,
    pairs: &[(String, String)],
    jobs: usize,
) -> Result<()> {
    let results = hapq::coordinator::launcher::run_multi_seed(&coord.cfg, pairs, jobs)?;
    println!(
        "{:<12} {:<8} {:>5} {:>9} {:>11} {:>13}",
        "model", "method", "seeds", "best-seed", "energy-gain", "test-acc-loss"
    );
    for ((model, method), res) in results {
        match res {
            Ok(v) => println!(
                "{:<12} {:<8} {:>5} {:>9} {:>10.1}% {:>12.2}%",
                model,
                method,
                v.req("seeds")?.as_f64()?,
                v.req("seed")?.as_f64()?,
                v.req("energy_gain")?.as_f64()? * 100.0,
                v.req("test_acc_loss")?.as_f64()? * 100.0
            ),
            Err(e) => println!("{model:<12} {method:<8} FAILED: {e}"),
        }
    }
    Ok(())
}

fn run(args: &[String]) -> Result<()> {
    let cli = Cli::parse(args)?;
    let cfg: RunConfig = cli.run_config()?;
    if let Some(tile) = cfg.gemm_tile {
        hapq::nn::mat::set_gemm_tile(tile);
    }
    // the scratch arenas follow the memo switch: one process-wide knob
    // so `--memo off` disables every reuse path at once
    hapq::runtime::native::set_scratch_arena(cfg.memo.enabled);
    // fan-out commands delegate tracing to the launcher (each child
    // writes its own trace; the parent aggregates them into the --trace
    // path) — enabling the in-process sink here would clobber that
    // file. `hapq trace` reads traces, it never records one.
    let fan_out = cfg.seeds > 1 || cli.usize_flag("jobs", 1)? > 1;
    if !fan_out && cli.cmd != "trace" {
        if let Some(path) = &cfg.trace {
            hapq::telemetry::init(path);
        }
    }
    let result = dispatch(&cli, cfg);
    match hapq::telemetry::finish() {
        Ok(Some(path)) => eprintln!("trace written: {}", path.display()),
        Ok(None) => {}
        Err(e) => {
            if result.is_ok() {
                return Err(e);
            }
            // the run error is the interesting one — don't mask it
            eprintln!("warning: trace write failed: {e:#}");
        }
    }
    result
}

fn dispatch(cli: &Cli, cfg: RunConfig) -> Result<()> {
    match cli.cmd.as_str() {
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        "list" => {
            let coord = Coordinator::new(cfg)?;
            println!("{:<14} {:<12} {:>9}", "model", "dataset", "acc@8bit");
            for e in &coord.models {
                let arch = hapq::model::ModelArch::load(&coord.cfg.artifacts.join(&e.arch))?;
                println!("{:<14} {:<12} {:>9.3}", e.model, e.dataset, arch.acc_int8);
            }
            Ok(())
        }
        "compress" => {
            let model = cli.str_flag("model", "vgg11");
            let coord = Coordinator::new(cfg)?;
            if coord.cfg.seeds > 1 {
                let jobs = cli.usize_flag("jobs", coord.cfg.seeds)?;
                let pairs = vec![(model, "ours".to_string())];
                return print_multi_seed(&coord, &pairs, jobs);
            }
            match coord.compress_search(&model, true, hapq::coordinator::Variant::Full)? {
                hapq::coordinator::SearchRun::Suspended { episode, checkpoint } => {
                    println!(
                        "{model}: suspended after {episode} episodes -> {} (continue with --resume)",
                        checkpoint.display()
                    );
                    Ok(())
                }
                hapq::coordinator::SearchRun::Complete(report) => {
                    let path = coord.save_report(&report)?;
                    println!(
                        "{}: energy gain {:.1}% | test acc {:.3} (dense {:.3}, loss {:.2}%) | {} evals | {:.1}s -> {}",
                        model,
                        report.best.energy_gain * 100.0,
                        report.test_acc,
                        report.test_acc_dense,
                        report.test_acc_loss() * 100.0,
                        report.evals,
                        report.wall_secs,
                        path.display()
                    );
                    Ok(())
                }
            }
        }
        "baseline" => {
            let model = cli.str_flag("model", "vgg11");
            let method = cli.str_flag("method", "amc");
            let coord = Coordinator::new(cfg)?;
            if coord.cfg.seeds > 1 {
                let jobs = cli.usize_flag("jobs", coord.cfg.seeds)?;
                let pairs = vec![(model, method)];
                return print_multi_seed(&coord, &pairs, jobs);
            }
            match coord.baseline_search(&model, &method)? {
                hapq::coordinator::SearchRun::Suspended { episode, checkpoint } => {
                    println!(
                        "{model} [{method}]: suspended after {episode} episodes -> {} (continue with --resume)",
                        checkpoint.display()
                    );
                    Ok(())
                }
                hapq::coordinator::SearchRun::Complete(report) => {
                    let path = coord.save_report(&report)?;
                    println!(
                        "{} [{}]: energy gain {:.1}% | test loss {:.2}% | {} evals | {:.1}s -> {}",
                        model,
                        method,
                        report.best.energy_gain * 100.0,
                        report.test_acc_loss() * 100.0,
                        report.evals,
                        report.wall_secs,
                        path.display()
                    );
                    Ok(())
                }
            }
        }
        "compare" => {
            let coord = Coordinator::new(cfg)?;
            let models: Vec<String> = match cli.flags.get("models") {
                Some(ms) if ms != "all" => ms.split(',').map(str::to_string).collect(),
                _ => coord.models.iter().map(|e| e.model.clone()).collect(),
            };
            let methods: Vec<String> = cli
                .str_flag("methods", "ours,amc,haq,asqj,opq")
                .split(',')
                .map(str::to_string)
                .collect();
            let jobs = cli.usize_flag("jobs", 1)?;
            // cross-hardware sweep: `--hw a,b` fans every (model,
            // method) pair over the target list, one report per target
            // under `out/hw-<target>/`
            let targets: Vec<String> =
                coord.cfg.hw.split(',').map(str::to_string).collect();
            if targets.len() > 1 {
                if coord.cfg.seeds > 1 {
                    anyhow::bail!(
                        "--seeds and a multi-target --hw list do not compose; \
                         sweep one target at a time"
                    );
                }
                if coord.cfg.hw_file.is_some() {
                    anyhow::bail!(
                        "--hw-file selects a single profile; it cannot combine \
                         with a multi-target --hw list"
                    );
                }
                // validate every name before any work starts
                for t in &targets {
                    hapq::hw::target::HwTarget::resolve(t, None)?;
                }
                if jobs > 1 {
                    let mut grid: Vec<hapq::coordinator::launcher::Job> = Vec::new();
                    for t in &targets {
                        for m in &models {
                            for me in &methods {
                                grid.push(hapq::coordinator::launcher::Job {
                                    model: m.clone(),
                                    method: me.clone(),
                                    seed: None,
                                    hw: Some(t.clone()),
                                });
                            }
                        }
                    }
                    let results =
                        hapq::coordinator::launcher::run_grid(&coord.cfg, grid, jobs)?;
                    println!(
                        "{:<12} {:<12} {:<8} {:>11} {:>13}",
                        "hw", "model", "method", "energy-gain", "test-acc-loss"
                    );
                    for (job, res) in results {
                        let hw = job.hw.as_deref().unwrap_or("-");
                        match res {
                            Ok(v) => println!(
                                "{:<12} {:<12} {:<8} {:>10.1}% {:>12.2}%",
                                hw,
                                job.model,
                                job.method,
                                v.req("energy_gain")?.as_f64()? * 100.0,
                                v.req("test_acc_loss")?.as_f64()? * 100.0
                            ),
                            Err(e) => println!(
                                "{:<12} {:<12} {:<8} FAILED: {e}",
                                hw, job.model, job.method
                            ),
                        }
                    }
                    return Ok(());
                }
                println!(
                    "{:<12} {:<12} {:<8} {:>11} {:>10} {:>8}",
                    "hw", "model", "method", "energy-gain", "acc-loss", "evals"
                );
                for t in &targets {
                    let mut tcfg = coord.cfg.clone();
                    tcfg.hw = t.clone();
                    tcfg.out = coord.cfg.out.join(format!("hw-{t}"));
                    // the R_Q table and manifest are target-independent:
                    // reuse the leader's instead of re-simulating per target
                    let tcoord = Coordinator {
                        cfg: tcfg,
                        rq: coord.rq.clone(),
                        models: coord.models.clone(),
                    };
                    for model in &models {
                        for method in &methods {
                            let report = if method == "ours" {
                                tcoord.compress(model, false)?
                            } else {
                                tcoord.run_baseline(model, method)?
                            };
                            tcoord.save_report(&report)?;
                            // save_report archived into the per-target
                            // subdir; the sequential sweep additionally
                            // folds every target's winner into the
                            // leader archive, exactly like the --jobs
                            // fan-out does, so both paths populate one
                            // cumulative `<out>/pareto.json`
                            hapq::search::archive::record_report(
                                &coord.cfg.out.join(hapq::search::archive::ARCHIVE_FILE),
                                &report.to_json(),
                            )?;
                            println!(
                                "{:<12} {:<12} {:<8} {:>10.1}% {:>9.2}% {:>8}",
                                t,
                                model,
                                method,
                                report.best.energy_gain * 100.0,
                                report.test_acc_loss() * 100.0,
                                report.evals
                            );
                        }
                    }
                }
                return Ok(());
            }
            if coord.cfg.seeds > 1 {
                // multi-seed grid: every (model, method) pair sweeps
                // --seeds consecutive seeds across the worker pool and
                // reports the merged best-of
                let pairs: Vec<(String, String)> = models
                    .iter()
                    .flat_map(|m| methods.iter().map(move |me| (m.clone(), me.clone())))
                    .collect();
                return print_multi_seed(&coord, &pairs, jobs.max(1));
            }
            if jobs > 1 {
                // multi-process fan-out (coordinator::launcher)
                let grid: Vec<hapq::coordinator::launcher::Job> = models
                    .iter()
                    .flat_map(|m| {
                        methods.iter().map(move |me| hapq::coordinator::launcher::Job {
                            model: m.clone(),
                            method: me.clone(),
                            seed: None,
                            hw: None,
                        })
                    })
                    .collect();
                let results =
                    hapq::coordinator::launcher::run_grid(&coord.cfg, grid, jobs)?;
                println!(
                    "{:<12} {:<8} {:>11} {:>13}",
                    "model", "method", "energy-gain", "test-acc-loss"
                );
                for (job, res) in results {
                    match res {
                        Ok(v) => println!(
                            "{:<12} {:<8} {:>10.1}% {:>12.2}%",
                            job.model,
                            job.method,
                            v.req("energy_gain")?.as_f64()? * 100.0,
                            v.req("test_acc_loss")?.as_f64()? * 100.0
                        ),
                        Err(e) => println!("{:<12} {:<8} FAILED: {e}", job.model, job.method),
                    }
                }
                return Ok(());
            }
            println!(
                "{:<12} {:<8} {:>11} {:>10} {:>8} {:>9}",
                "model", "method", "energy-gain", "acc-loss", "evals", "secs"
            );
            for model in &models {
                for method in &methods {
                    let report = if method == "ours" {
                        coord.compress(model, false)?
                    } else {
                        coord.run_baseline(model, method)?
                    };
                    coord.save_report(&report)?;
                    println!(
                        "{:<12} {:<8} {:>10.1}% {:>9.2}% {:>8} {:>8.1}s",
                        model,
                        method,
                        report.best.energy_gain * 100.0,
                        report.test_acc_loss() * 100.0,
                        report.evals,
                        report.wall_secs
                    );
                }
            }
            Ok(())
        }
        "fig1" => {
            let coord = Coordinator::new(cfg)?;
            let model = cli.str_flag("model", "vgg16");
            let mut env = coord.build_env(&model)?;
            let pts: Vec<f64> = (1..=9).map(|i| i as f64 / 10.0).collect();
            println!("# Fig 1 — {model}: sparsity vs (acc loss, energy gain)");
            println!("{:<12} {:>9} {:>10} {:>12}", "alg", "sparsity", "acc-loss", "energy-gain");
            for r in figures::fig1_sweep(&mut env, &pts)? {
                println!(
                    "{:<12} {:>9.1} {:>9.2}% {:>11.2}%",
                    r.alg,
                    r.sparsity,
                    r.acc_loss * 100.0,
                    r.energy_gain * 100.0
                );
            }
            Ok(())
        }
        "fig2a" => {
            let coord = Coordinator::new(cfg)?;
            let model = cli.str_flag("model", "vgg11");
            let env = coord.build_env(&model)?;
            println!("# Fig 2a — accelerator energy reduction vs (Qw, Qa), model {model}");
            println!("{:>3} {:>3} {:>10}", "Qw", "Qa", "reduction");
            for (qw, qa, red) in figures::fig2a_grid(&env) {
                println!("{qw:>3} {qa:>3} {:>9.2}%", red * 100.0);
            }
            Ok(())
        }
        "fig2b" => {
            let coord = Coordinator::new(cfg)?;
            let model = cli.str_flag("model", "resnet18");
            let samples = cli.usize_flag("samples", 40)?;
            let mut env = coord.build_env(&model)?;
            println!("# Fig 2b — uniform vs mixed precision, model {model}");
            for p in figures::fig2b_points(&mut env, samples, coord.cfg.seed)? {
                println!(
                    "{:<8} loss {:>6.2}%  gain {:>6.2}%",
                    p.kind,
                    p.acc_loss * 100.0,
                    p.energy_gain * 100.0
                );
            }
            Ok(())
        }
        "fig5" => {
            println!("# Fig 5 — reward LUT heatmap (sub-sampled 10x10 of 40x40)");
            for row in figures::fig5_heatmap(4) {
                let cells: Vec<String> = row.iter().map(|v| format!("{v:6.2}")).collect();
                println!("{}", cells.join(" "));
            }
            Ok(())
        }
        "fig8" => {
            let model = cli.str_flag("model", "resnet18");
            let coord = Coordinator::new(cfg)?;
            let report = coord.compress(&model, true)?;
            println!("# Fig 8 — per-layer policy, {model}");
            println!("{:<6} {:<12} {:>9} {:>6}", "layer", "alg", "sparsity", "bits");
            for (i, alg, sp, bits) in figures::fig8_rows(&report) {
                println!("{i:<6} {alg:<12} {sp:>9.2} {bits:>6}");
            }
            coord.save_report(&report)?;
            Ok(())
        }
        "ablate" => {
            // ablations of the composite agent's design choices + the
            // §4.2.3 alternative-metric extension
            use hapq::coordinator::Variant;
            use hapq::env::Metric;
            use hapq::pruning::PruneAlg;
            let model = cli.str_flag("model", "vgg11");
            let coord = Coordinator::new(cfg)?;
            let variants: Vec<(&str, Variant)> = vec![
                ("full composite (paper)", Variant::Full),
                ("no Rainbow (random algs)", Variant::NoRainbow),
                ("single alg: l1-ranked", Variant::SingleAlg(PruneAlg::L1Ranked)),
                ("single alg: level", Variant::SingleAlg(PruneAlg::Level)),
                ("latency-driven reward", Variant::WithMetric(Metric::Latency)),
                ("EDP-driven reward", Variant::WithMetric(Metric::Edp)),
            ];
            println!(
                "{:<26} {:>11} {:>13} {:>12}",
                "variant", "energy-gain", "latency-gain", "acc-loss"
            );
            for (name, v) in variants {
                let r = coord.compress_with(&model, false, v)?;
                coord.save_report(&r)?;
                println!(
                    "{:<26} {:>10.1}% {:>12.1}% {:>11.2}%",
                    name,
                    r.best.energy_gain * 100.0,
                    r.best.latency_gain * 100.0,
                    r.test_acc_loss() * 100.0
                );
            }
            Ok(())
        }
        "report" => {
            // per-layer energy breakdown of a configuration (hw::report)
            let model = cli.str_flag("model", "vgg11");
            let coord = Coordinator::new(cfg)?;
            let env = coord.build_env(&model)?;
            let em = env.cost.model();
            let n = env.n_layers();
            let dense = vec![hapq::hw::energy::Compression::dense(); n];
            println!(
                "# {model} on {}: dense-baseline energy breakdown",
                em.target.name
            );
            println!(
                "{:<6} {:>12} {:>12} {:>12} {:>8}",
                "layer", "MACs", "DRAM-words", "E(dense)", "share"
            );
            for r in hapq::hw::report::breakdown(em, &dense) {
                println!(
                    "{:<6} {:>12} {:>12} {:>12.0} {:>7.1}%",
                    r.layer, r.macs, r.dram, r.e_dense, r.dense_share * 100.0
                );
            }
            let hs = hapq::hw::report::hotspots(em, &dense, 0.5);
            println!("
hotspots holding 50% of energy: {hs:?}");
            Ok(())
        }
        "hw" => {
            // per-layer cost breakdown + cross-target comparison: pure
            // cost-model analysis, no weights or inference involved
            use hapq::hw::cost::CostModel;
            use hapq::hw::energy::{Compression, EnergyModel};
            use hapq::hw::target::{HwTarget, BUILTIN_TARGETS};
            let model = cli.str_flag("model", "vgg11");
            let sparsity = cli.f64_flag("sparsity", 0.5)?;
            let bits = cli.usize_flag("bits", 4)? as u32;
            if !(0.0..=1.0).contains(&sparsity) || !(2..=8).contains(&bits) {
                anyhow::bail!("--sparsity must be in [0,1] and --bits in [2,8]");
            }
            let coord = Coordinator::new(cfg)?;
            let entry = coord.entry(&model)?;
            let arch =
                hapq::model::ModelArch::load(&coord.cfg.artifacts.join(&entry.arch))?;
            let dims = arch.layer_dims()?;
            let n = dims.len();
            let reference = Compression { sparsity, coarse: true, bits };
            let cfgs = vec![reference; n];
            let dense = vec![Compression::dense(); n];

            let json_out = cli.bool_flag("json");
            let target = coord.hw_target()?;
            let em = EnergyModel::for_target(dims.clone(), &target, coord.rq.clone());
            if !json_out {
                println!("# {model} on {} — {}", target.name, target.description);
                println!(
                    "# per-layer breakdown at s={sparsity:.2} (structured), {bits}-bit"
                );
                println!(
                    "{:<6} {:>12} {:>12} {:>14} {:>7} {:>14} {:>7} {:>14}",
                    "layer", "MACs", "DRAM-words", "E(dense)", "share", "E(cfg)", "gain",
                    "cycles(cfg)"
                );
                for r in hapq::hw::report::breakdown(&em, &cfgs) {
                    println!(
                        "{:<6} {:>12} {:>12} {:>14.0} {:>6.1}% {:>14.0} {:>6.1}% {:>14.0}",
                        r.layer,
                        r.macs,
                        r.dram,
                        r.e_dense,
                        r.dense_share * 100.0,
                        r.e_compressed,
                        r.layer_gain * 100.0,
                        r.cycles
                    );
                }
                let hs = hapq::hw::report::hotspots(&em, &cfgs, 0.5);
                println!("hotspots holding 50% of remaining energy: {hs:?}");

                println!();
                println!(
                    "# cross-target comparison at s={sparsity:.2} (structured), {bits}-bit"
                );
                println!(
                    "{:<12} {:>16} {:>16} {:>12} {:>13}",
                    "target", "E(dense)", "cycles(dense)", "energy-gain", "latency-gain"
                );
            }
            let mut table: Vec<(String, HwTarget)> = BUILTIN_TARGETS
                .iter()
                .map(|name| (name.to_string(), HwTarget::builtin(name).expect("builtin")))
                .collect();
            // a loaded profile always gets its own row (marked `*`),
            // even when its name shadows a built-in — the built-in row
            // keeps the built-in numbers
            let custom = coord.cfg.hw_file.is_some()
                || !BUILTIN_TARGETS.contains(&target.name.as_str());
            if custom {
                table.push((format!("{}*", target.name), target.clone()));
            }
            let selected_label =
                if custom { format!("{}*", target.name) } else { target.name.clone() };
            let mut reg = hapq::telemetry::MetricsRegistry::new();
            for (label, t) in &table {
                // the selected target was already mapped for the
                // breakdown above — reuse it instead of re-running the
                // dataflow tile search over every layer
                let mut tm = if *label == selected_label {
                    em.clone()
                } else {
                    EnergyModel::for_target(dims.clone(), t, coord.rq.clone())
                };
                let e0 = tm.baseline();
                let cy0 = tm.cycles(&dense);
                let eg = tm.energy_gain(&cfgs);
                let lg = tm.latency_gain(&cfgs);
                if json_out {
                    // the `*` suffix survives into the key so a custom
                    // profile shadowing a built-in name keeps both rows
                    reg.gauge(&format!("hw.{label}.baseline_energy"), e0);
                    reg.gauge(&format!("hw.{label}.dense_cycles"), cy0);
                    reg.gauge(&format!("hw.{label}.energy_gain"), eg);
                    reg.gauge(&format!("hw.{label}.latency_gain"), lg);
                } else {
                    println!(
                        "{:<12} {:>16.0} {:>16.0} {:>11.1}% {:>12.1}%",
                        label,
                        e0,
                        cy0,
                        eg * 100.0,
                        lg * 100.0
                    );
                }
            }
            if json_out {
                reg.label("hw.target", &target.name);
                reg.label("hw.model", &model);
                reg.gauge("hw.reference.sparsity", sparsity);
                reg.gauge("hw.reference.bits", bits as f64);
                println!("{}", reg.snapshot().to_string());
            } else if custom {
                println!("(* the --hw/--hw-file selection the breakdown above used)");
            }
            Ok(())
        }
        "perf" => {
            let coord = Coordinator::new(cfg)?;
            let model = cli.str_flag("model", "vgg11");
            let mut env = coord.build_env(&model)?;
            let n = env.n_layers();
            // reward-oracle latency, phase-accounted (EXPERIMENTS.md §Perf)
            let t0 = Instant::now();
            let iters = 10;
            let mut iter_secs = Vec::with_capacity(iters);
            for i in 0..iters {
                let it0 = Instant::now();
                let actions: Vec<hapq::env::Action> = (0..n)
                    .map(|l| hapq::env::Action {
                        ratio: 0.3,
                        bits: 0.8,
                        alg: (l + i) % 7,
                    })
                    .collect();
                env.evaluate_config(&actions)?;
                iter_secs.push(it0.elapsed().as_secs_f64());
            }
            let per_ep = t0.elapsed().as_secs_f64() / iters as f64;
            let t = env.timers;
            let steps = t.steps.max(1) as f64;
            let stats = env.session_stats();
            if cli.bool_flag("json") {
                // one MetricsRegistry snapshot over every stat source —
                // the same schema `hapq hw --json` and (later) `hapq
                // serve` emit
                let mut reg = hapq::telemetry::MetricsRegistry::new();
                reg.collect(&env.timers);
                reg.collect(&stats);
                reg.collect(&env.cost);
                // unified cache counters: cost, act-checkpoint, pack,
                // eval-memo under one `cache.*` group
                reg.collect(&env.cache_counters());
                for s in &iter_secs {
                    reg.observe("perf.episode_secs", *s);
                }
                reg.gauge("perf.layers", n as f64);
                reg.gauge("perf.rss_kib", hapq::coordinator::rss_kib() as f64);
                reg.label("perf.model", &model);
                reg.label("perf.backend", coord.cfg.backend.name());
                println!("{}", reg.snapshot().to_string());
                return Ok(());
            }
            println!(
                "{model}: episode {:.1} ms ({} layers, {:.2} ms/step), backend {}, kernel {}, threads {}, rss {} MiB",
                per_ep * 1e3,
                n,
                per_ep * 1e3 / n as f64,
                coord.cfg.backend.name(),
                stats.kernel.name(),
                stats.threads,
                hapq::coordinator::rss_kib() / 1024
            );
            println!(
                "  per-step phases: prune {:.3} ms | quant {:.3} ms | hw {:.3} ms | inference {:.3} ms",
                t.prune_s * 1e3 / steps,
                t.quant_s * 1e3 / steps,
                t.hw_s * 1e3 / steps,
                t.infer_s * 1e3 / steps
            );
            println!(
                "  oracle cache: hit-rate {:.1}% ({} layers computed, {} reused)",
                stats.cache_hit_rate() * 100.0,
                stats.layers_computed,
                stats.layers_reused
            );
            println!(
                "  cost model [{}]: hit-rate {:.1}% ({} layer terms re-priced, {} reused)",
                env.cost.model().target.name,
                env.cost.hit_rate() * 100.0,
                env.cost.recomputed(),
                env.cost.reused()
            );
            println!(
                "  oracle kernel phases: pack {:.1} ms | prunable-layer eval {:.1} ms (cumulative)",
                stats.pack_secs * 1e3,
                stats.gemm_secs * 1e3
            );
            println!(
                "  memo [{}]: eval hits {} / misses {} | pack-cache hit-rate {:.1}% ({} hits, {} misses) | overhead {:.3} ms",
                if env.memo().enabled { "on" } else { "off" },
                env.memo_hits,
                env.memo_misses,
                stats.pack_cache_hit_rate() * 100.0,
                stats.pack_hits,
                stats.pack_misses,
                t.memo_s * 1e3
            );
            println!(
                "  sched [{}]: {} shards stolen",
                stats.sched.name(),
                stats.steals
            );
            Ok(())
        }
        "trace" => {
            let file = cli
                .flags
                .get("file")
                .cloned()
                .or_else(|| cli.positional.first().cloned())
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "usage: hapq trace FILE.jsonl [--top N] [--chrome OUT.json] [--canon]"
                    )
                })?;
            let tr = hapq::telemetry::analyze::load(std::path::Path::new(&file))?;
            if cli.bool_flag("canon") {
                // clock-stripped canonical stream — byte-diffable across
                // same-seed runs (the CI determinism check)
                print!("{}", tr.canonical());
                return Ok(());
            }
            if let Some(out) = cli.flags.get("chrome") {
                let v = tr.chrome()?;
                let n = v.req("traceEvents")?.as_arr()?.len();
                std::fs::write(out, v.to_string())
                    .map_err(|e| anyhow::anyhow!("writing {out}: {e}"))?;
                println!("wrote {out} ({n} trace events) — load in chrome://tracing");
                return Ok(());
            }
            let top = cli.usize_flag("top", 5)?;
            println!("# reward curve ({file})");
            print!("{}", tr.reward_table()?);
            println!();
            println!("# per-phase rollup");
            print!("{}", tr.phase_rollup()?);
            println!();
            println!("# top-{top} hottest layers");
            print!("{}", tr.hottest_layers(top)?);
            Ok(())
        }
        "pareto" => {
            // query the cross-run Pareto archive: pure file analysis —
            // no artifacts, weights or inference involved
            use hapq::io::json;
            use hapq::search::archive::{self, ParetoArchive, QueryMetric};
            let path = match cli.flags.get("archive") {
                Some(p) => std::path::PathBuf::from(p),
                None => cfg.out.join(archive::ARCHIVE_FILE),
            };
            let a = ParetoArchive::load(&path)?;
            if a.entries().is_empty() {
                anyhow::bail!(
                    "archive {} is empty or missing — finished search runs feed \
                     <out>/pareto.json automatically (run compress/baseline/compare \
                     first, or point --archive at an existing file)",
                    path.display()
                );
            }
            let model = cli.flags.get("model").map(String::as_str);
            // the raw --hw flag, NOT cfg.hw: the config default
            // (eyeriss-64) must not silently filter the tables
            let hw = cli.flags.get("hw").map(String::as_str);
            let metric = QueryMetric::parse(&cli.str_flag("metric", "energy"))?;
            let cap = match cli.flags.get("max-acc-loss") {
                None => None,
                Some(_) => {
                    let c = cli.f64_flag("max-acc-loss", 0.0)?;
                    if !(0.0..=1.0).contains(&c) {
                        anyhow::bail!(
                            "--max-acc-loss is an accuracy-loss fraction in [0,1], got {c}"
                        );
                    }
                    Some(c)
                }
            };
            if let Some(out) = cli.flags.get("export") {
                // canonical front JSON (filters + cap applied): bytes
                // depend only on the archived set and the query, never
                // on run order — CI diffs two exports for equality
                let entries: Vec<json::Value> =
                    a.front(model, hw, cap).iter().map(|e| e.to_json()).collect();
                let n = entries.len();
                let mut query = vec![("metric", json::s(metric.name()))];
                if let Some(m) = model {
                    query.push(("model", json::s(m)));
                }
                if let Some(h) = hw {
                    query.push(("hw", json::s(h)));
                }
                if let Some(c) = cap {
                    query.push(("max_acc_loss", json::num(c)));
                }
                let doc = json::obj(vec![
                    ("schema", json::num(archive::SCHEMA as f64)),
                    ("kind", json::s("hapq-pareto-front")),
                    ("query", json::obj(query)),
                    ("entries", json::arr(entries)),
                ]);
                std::fs::write(out, doc.to_string())
                    .map_err(|e| anyhow::anyhow!("writing {out}: {e}"))?;
                println!("front exported: {out} ({n} entries)");
                return Ok(());
            }
            if cli.bool_flag("json") {
                // archive counters/gauges in the same MetricsRegistry
                // snapshot schema as `hapq perf --json` / `hapq hw --json`
                let mut reg = hapq::telemetry::MetricsRegistry::new();
                reg.collect(&a);
                reg.label("archive.path", &path.display().to_string());
                println!("{}", reg.snapshot().to_string());
                return Ok(());
            }
            if let Some(cap) = cap {
                // constrained query: best gain subject to the loss cap
                let Some(best) = a.query(model, hw, cap, metric) else {
                    anyhow::bail!(
                        "no archived config satisfies acc_loss <= {:.2}%{}{} — \
                         relax the cap or archive more runs",
                        cap * 100.0,
                        model.map(|m| format!(" for model {m}")).unwrap_or_default(),
                        hw.map(|h| format!(" on {h}")).unwrap_or_default()
                    );
                };
                println!(
                    "# best {}-gain config with acc-loss <= {:.2}% (model {}, hw {})",
                    metric.name(),
                    cap * 100.0,
                    model.unwrap_or("any"),
                    hw.unwrap_or("any")
                );
                println!(
                    "{:<12} {:<18} {:<12} {:<10} {:>6} {:>9} {:>12} {:>13} {:>8}",
                    "model", "fingerprint", "hw", "method", "seed", "acc-loss",
                    "energy-gain", "latency-gain", "reward"
                );
                println!(
                    "{:<12} {:<18} {:<12} {:<10} {:>6} {:>8.2}% {:>11.1}% {:>12.1}% {:>8.2}",
                    best.model,
                    best.fingerprint,
                    best.hw,
                    best.method,
                    best.seed,
                    best.acc_loss * 100.0,
                    best.energy_gain * 100.0,
                    best.latency_gain * 100.0,
                    best.reward
                );
                println!("# per-layer policy");
                println!("{:<6} {:<14} {:>9} {:>5}", "layer", "alg", "sparsity", "bits");
                for (i, l) in best.per_layer.iter().enumerate() {
                    println!("{:<6} {:<14} {:>9.2} {:>5}", i, l.alg, l.sparsity, l.bits);
                }
                return Ok(());
            }
            // no cap: per-group front tables + a cross-target summary
            // extending `hapq hw`'s comparison with archived real runs
            let groups: Vec<(String, String, String)> = a
                .groups()
                .into_iter()
                .filter(|(m, _, _)| model.map_or(true, |f| m == f))
                .filter(|(_, _, h)| hw.map_or(true, |f| h == f))
                .collect();
            if groups.is_empty() {
                anyhow::bail!(
                    "no archived entries match the filters (model {}, hw {})",
                    model.unwrap_or("any"),
                    hw.unwrap_or("any")
                );
            }
            println!(
                "# pareto archive {} — {} entries, {} groups",
                path.display(),
                a.entries().len(),
                a.groups().len()
            );
            for (m, fp, h) in &groups {
                let entries: Vec<&archive::ArchiveEntry> = a
                    .front(Some(m.as_str()), Some(h.as_str()), None)
                    .into_iter()
                    .filter(|e| &e.fingerprint == fp)
                    .collect();
                println!();
                println!("## {m} [{fp}] on {h} — {} non-dominated", entries.len());
                println!(
                    "{:<10} {:>6} {:>9} {:>12} {:>13} {:>8}",
                    "method", "seed", "acc-loss", "energy-gain", "latency-gain", "reward"
                );
                for e in entries {
                    println!(
                        "{:<10} {:>6} {:>8.2}% {:>11.1}% {:>12.1}% {:>8.2}",
                        e.method,
                        e.seed,
                        e.acc_loss * 100.0,
                        e.energy_gain * 100.0,
                        e.latency_gain * 100.0,
                        e.reward
                    );
                }
            }
            println!();
            println!("# cross-target summary");
            println!(
                "{:<12} {:<12} {:>8} {:>13} {:>17} {:>18}",
                "model", "hw", "entries", "min-acc-loss", "best-energy-gain",
                "best-latency-gain"
            );
            for (m, fp, h) in &groups {
                let entries: Vec<&archive::ArchiveEntry> = a
                    .front(Some(m.as_str()), Some(h.as_str()), None)
                    .into_iter()
                    .filter(|e| &e.fingerprint == fp)
                    .collect();
                let min_loss = entries.iter().map(|e| e.acc_loss).fold(f64::INFINITY, f64::min);
                let best_eg =
                    entries.iter().map(|e| e.energy_gain).fold(f64::NEG_INFINITY, f64::max);
                let best_lg =
                    entries.iter().map(|e| e.latency_gain).fold(f64::NEG_INFINITY, f64::max);
                println!(
                    "{:<12} {:<12} {:>8} {:>12.2}% {:>16.1}% {:>17.1}%",
                    m,
                    h,
                    entries.len(),
                    min_loss * 100.0,
                    best_eg * 100.0,
                    best_lg * 100.0
                );
            }
            Ok(())
        }
        other => {
            eprintln!("unknown command `{other}`");
            print_help();
            std::process::exit(2);
        }
    }
}
