//! The LUT-based hardware-aware reward (paper §4.2.3, Fig 5).
//!
//! A 40×40 table indexed by (accuracy loss, energy gain), built once.
//! Shape requirements from the paper:
//!   * reward is *significantly* higher for accuracy loss < 10 % — the
//!     realistic target region of a no-retraining framework;
//!   * within that region it grows with energy gain;
//!   * small negative for (gain < 5 %, loss < 5 %) to discourage
//!     close-to-zero compression actions;
//!   * large and increasingly negative beyond 10 % loss.

/// Table resolution (paper: 40×40).
pub const N: usize = 40;

/// The precomputed reward lookup table.
#[derive(Clone, Debug)]
pub struct RewardLut {
    /// grid[loss_bin][gain_bin]
    pub grid: Vec<Vec<f64>>,
}

impl RewardLut {
    /// The paper's reward surface (Fig 5).
    pub fn paper() -> RewardLut {
        let mut grid = vec![vec![0.0; N]; N];
        for (li, row) in grid.iter_mut().enumerate() {
            // bin centres over [0, 1]
            let loss = (li as f64 + 0.5) / N as f64;
            for (gi, cell) in row.iter_mut().enumerate() {
                let gain = (gi as f64 + 0.5) / N as f64;
                *cell = if loss < 0.10 {
                    let quality = (0.10 - loss) / 0.10; // 1 at zero loss
                    if gain < 0.05 && loss < 0.05 {
                        // §4.2.3: slightly discourage do-nothing actions
                        -0.1
                    } else {
                        quality * (1.0 + 9.0 * gain)
                    }
                } else {
                    // outside the useful region: strongly negative,
                    // monotonically worse with loss
                    -1.0 - 8.0 * (loss - 0.10)
                };
            }
        }
        RewardLut { grid }
    }

    /// Look up the reward for (accuracy-loss, energy-gain), both fractions.
    pub fn reward(&self, acc_loss: f64, energy_gain: f64) -> f64 {
        let li = ((acc_loss.clamp(0.0, 1.0)) * N as f64) as usize;
        let gi = ((energy_gain.clamp(0.0, 1.0)) * N as f64) as usize;
        self.grid[li.min(N - 1)][gi.min(N - 1)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dimensions_are_40x40() {
        let l = RewardLut::paper();
        assert_eq!(l.grid.len(), 40);
        assert!(l.grid.iter().all(|r| r.len() == 40));
    }

    #[test]
    fn favours_low_loss() {
        let l = RewardLut::paper();
        // same gain, less loss -> more reward inside the useful region
        assert!(l.reward(0.01, 0.4) > l.reward(0.05, 0.4));
        assert!(l.reward(0.05, 0.4) > l.reward(0.09, 0.4));
        // 10 %+ loss is sharply penalised
        assert!(l.reward(0.12, 0.9) < 0.0);
        assert!(l.reward(0.30, 0.9) < l.reward(0.12, 0.9));
    }

    #[test]
    fn favours_energy_gain_in_region() {
        let l = RewardLut::paper();
        assert!(l.reward(0.02, 0.6) > l.reward(0.02, 0.2));
    }

    #[test]
    fn discourages_nop_compression() {
        let l = RewardLut::paper();
        let r = l.reward(0.0, 0.0);
        assert!(r < 0.0 && r > -0.5, "small negative, got {r}");
    }

    #[test]
    fn clamps_out_of_range() {
        let l = RewardLut::paper();
        assert_eq!(l.reward(-1.0, 2.0), l.reward(0.0, 1.0));
    }
}
