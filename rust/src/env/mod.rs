//! The compression environment (paper §4.1–§4.2.3).
//!
//! One episode walks the prunable layers of the target DNN in order; at
//! each step the composite agent supplies (pruning ratio, precision,
//! pruning algorithm) for layer *t*, the env applies them to a working
//! copy of the weights (dependency-resolved, §4.1), quantizes, queries
//! the hardware cost oracle (the [`CostModel`] seam — an incremental
//! [`CostCache`] over the selected target's energy/latency model),
//! runs validation inference through the configured
//! [`InferenceSession`] backend (native interpreter or PJRT), and
//! returns the LUT-based hardware-aware reward — exactly the loop of
//! Fig 3. Rewards arrive at *every* step (§4.2.2: Rainbow requires an
//! update before each action).

pub mod lut;

use std::collections::HashMap;

use anyhow::Result;

use crate::hw::cost::{CostCache, CostModel};
use crate::hw::energy::{Compression, EnergyModel};
use crate::model::{ModelArch, Op, Weights};
use crate::pruning::{prune, prune_channels, PruneAlg, PruneCtx};
use crate::quant::{config_fingerprint, quantize_weights};
use crate::runtime::{Candidate, InferenceSession, MemoConfig};
use crate::util::rng::Rng;
use lut::RewardLut;

/// Lowest precision the agent can pick (paper §4.1).
pub const MIN_BITS: u32 = 2;
/// Highest precision — also the dense baseline's activation precision.
pub const MAX_BITS: u32 = 8;
/// Never prune more than this fraction of one layer (no retraining to recover).
pub const MAX_RATIO: f64 = 0.9;

/// State vector dimension — the paper's 13-feature layer embedding
/// (eq. 1/2) with the 2-d previous action appended.
pub const STATE_DIM: usize = 14;

/// Cumulative wall-clock of each [`CompressionEnv::step`] phase, the
/// substrate of `hapq perf`'s per-phase breakdown (EXPERIMENTS.md
/// §Perf). Timing costs two `Instant::now` calls per phase — noise
/// next to even the cheapest phase.
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseTimers {
    /// §4.1 resolution + pruning, seconds
    pub prune_s: f64,
    /// post-prune weight quantization, seconds
    pub quant_s: f64,
    /// hardware cost-model (energy/latency) queries, seconds — timed
    /// inside the [`CostCache`] and drained into this slot every step
    pub hw_s: f64,
    /// validation inference (the accuracy oracle), seconds — memo-hit
    /// steps contribute ~0 here (the skipped inference is the win)
    pub infer_s: f64,
    /// eval-memoization overhead (fingerprinting + cache probes),
    /// seconds — reported separately so the memo's cost is visible
    /// next to the inference time it saves
    pub memo_s: f64,
    /// steps accumulated into the totals above
    pub steps: u64,
}

impl crate::telemetry::MetricsSource for PhaseTimers {
    fn record(&self, reg: &mut crate::telemetry::MetricsRegistry) {
        reg.counter("env.steps", self.steps);
        reg.gauge("env.prune_s", self.prune_s);
        reg.gauge("env.quant_s", self.quant_s);
        reg.gauge("env.hw_s", self.hw_s);
        reg.gauge("env.infer_s", self.infer_s);
        reg.gauge("env.memo_s", self.memo_s);
    }
}

/// Bounded-LRU memo of full-config oracle results: key = the
/// whole-network per-layer [`config_fingerprint`] vector (exact
/// `Vec<u64>` equality — no truncation, no tolerance), value = the
/// accuracy the oracle returned for that exact configuration. A hit
/// replays the *identical* `f64`, draws no RNG and reorders no float
/// arithmetic, which is what keeps a memoized run bitwise-equal to a
/// cold one (the exec-engine proptest and the `HAPQ_MEMO=0` CI lane
/// both pin this).
struct EvalCache {
    cap: usize,
    tick: u64,
    map: HashMap<Vec<u64>, (u64, f64)>,
}

impl EvalCache {
    fn new(cap: usize) -> EvalCache {
        EvalCache { cap, tick: 0, map: HashMap::new() }
    }

    fn get(&mut self, key: &[u64]) -> Option<f64> {
        self.tick += 1;
        let tick = self.tick;
        self.map.get_mut(key).map(|e| {
            e.0 = tick;
            e.1
        })
    }

    fn insert(&mut self, key: Vec<u64>, acc: f64) {
        if self.cap == 0 {
            return;
        }
        if self.map.len() >= self.cap {
            // LRU: evict the stalest tick (O(len) scan — one miss also
            // pays a full inference, so the scan is noise)
            if let Some(oldest) =
                self.map.iter().min_by_key(|(_, (t, _))| *t).map(|(k, _)| k.clone())
            {
                self.map.remove(&oldest);
            }
        }
        self.tick += 1;
        self.map.insert(key, (self.tick, acc));
    }
}

/// One snapshot of every cache seam's counters, under a single `cache.*`
/// metrics namespace so `hapq perf --json` reports them uniformly
/// (hardware cost model, activation checkpoints, pack cache, eval memo).
/// Built by [`CompressionEnv::cache_counters`].
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheCounters {
    /// hardware cost-model layer terms re-priced / served from cache
    pub cost_recomputed: u64,
    /// hardware cost-model layer terms reused
    pub cost_reused: u64,
    /// graph-layer activations recomputed by the exec engine
    pub act_computed: u64,
    /// graph-layer activations served from checkpoint caches
    pub act_reused: u64,
    /// packs served from the config-fingerprinted pack cache
    pub pack_hits: u64,
    /// packs actually (re)built
    pub pack_misses: u64,
    /// full-config oracle evals answered by the eval memo
    pub eval_hits: u64,
    /// full-config oracle evals that ran real inference (memo on)
    pub eval_misses: u64,
}

impl CacheCounters {
    fn rate(hits: u64, misses: u64) -> f64 {
        let total = hits + misses;
        if total == 0 {
            0.0
        } else {
            hits as f64 / total as f64
        }
    }
}

impl crate::telemetry::MetricsSource for CacheCounters {
    fn record(&self, reg: &mut crate::telemetry::MetricsRegistry) {
        reg.counter("cache.cost.hits", self.cost_reused);
        reg.counter("cache.cost.misses", self.cost_recomputed);
        reg.gauge("cache.cost.hit_rate", Self::rate(self.cost_reused, self.cost_recomputed));
        reg.counter("cache.act.hits", self.act_reused);
        reg.counter("cache.act.misses", self.act_computed);
        reg.gauge("cache.act.hit_rate", Self::rate(self.act_reused, self.act_computed));
        reg.counter("cache.pack.hits", self.pack_hits);
        reg.counter("cache.pack.misses", self.pack_misses);
        reg.gauge("cache.pack.hit_rate", Self::rate(self.pack_hits, self.pack_misses));
        reg.counter("cache.eval.hits", self.eval_hits);
        reg.counter("cache.eval.misses", self.eval_misses);
        reg.gauge("cache.eval.hit_rate", Self::rate(self.eval_hits, self.eval_misses));
    }
}

/// Hardware metric driving the reward (§4.2.3: "any other hardware
/// metric (e.g., latency) is seamlessly supported").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Metric {
    /// accelerator energy (the paper's default)
    Energy,
    /// roofline-model latency
    Latency,
    /// energy-delay product (gain = 1 - (E/E0)·(T/T0))
    Edp,
}

/// Raw agent action for one layer.
#[derive(Clone, Copy, Debug)]
pub struct Action {
    /// pruning ratio control ∈ [0,1] → sparsity target [0, MAX_RATIO]
    pub ratio: f64,
    /// precision control ∈ [0,1] → bits [MIN_BITS, MAX_BITS]
    pub bits: f64,
    /// pruning-technique index (Rainbow's discrete action)
    pub alg: usize,
}

impl Action {
    /// Target sparsity the ratio control maps to (`ratio · MAX_RATIO`).
    pub fn sparsity(&self) -> f64 {
        self.ratio.clamp(0.0, 1.0) * MAX_RATIO
    }

    /// Precision in bits the continuous control maps to (2..=8).
    pub fn precision(&self) -> u32 {
        let span = (MAX_BITS - MIN_BITS) as f64;
        (MIN_BITS as f64 + self.bits.clamp(0.0, 1.0) * span).round() as u32
    }
}

/// What the env reports after each step.
#[derive(Clone, Debug)]
pub struct StepResult {
    /// next layer's state embedding (zeros when the episode is done)
    pub state: Vec<f32>,
    /// LUT reward for this step (paper §4.2.3)
    pub reward: f64,
    /// true when every prunable layer has been visited
    pub done: bool,
    /// top-1 accuracy of the partially-compressed model (reward subset)
    pub accuracy: f64,
    /// accuracy loss vs the dense 8-bit baseline (fraction)
    pub acc_loss: f64,
    /// energy gain vs the dense 8-bit baseline (fraction)
    pub energy_gain: f64,
    /// latency gain vs the dense baseline (fraction)
    pub latency_gain: f64,
    /// the gain fed to the reward LUT (depends on the chosen [`Metric`])
    pub hw_gain: f64,
    /// what was actually applied after dependency resolution
    pub applied: Applied,
}

/// What the env actually applied to one layer (post §4.1 resolution).
#[derive(Clone, Copy, Debug)]
pub struct Applied {
    /// pruning algorithm that ran
    pub alg: PruneAlg,
    /// achieved weight sparsity
    pub sparsity: f64,
    /// applied precision (weights & activations, §4.1)
    pub bits: u32,
    /// true when the §4.1 rule rewrote the agent's choice
    pub overridden: bool,
}

/// A finished configuration (one point of Fig 7/8/9).
#[derive(Clone, Debug)]
pub struct Solution {
    /// what was applied to each prunable layer
    pub per_layer: Vec<Applied>,
    /// the raw actions that produced it (replayable via evaluate_config)
    pub actions: Vec<Action>,
    /// top-1 accuracy on the reward subset
    pub accuracy: f64,
    /// accuracy loss vs the dense 8-bit baseline (fraction)
    pub acc_loss: f64,
    /// energy gain vs the dense baseline (fraction)
    pub energy_gain: f64,
    /// latency gain vs the dense baseline (fraction)
    pub latency_gain: f64,
    /// final-step LUT reward
    pub reward: f64,
}

/// The environment.
pub struct CompressionEnv {
    /// the target model's architecture descriptor
    pub arch: ModelArch,
    dense: Weights,
    /// the hardware cost oracle: an incremental per-layer cache over
    /// the selected target's energy/latency model (eqs 3–8)
    pub cost: CostCache,
    session: InferenceSession,
    /// the reward lookup table (Fig 5)
    pub lut: RewardLut,
    /// dense 8-bit accuracy on the reward subset (loss reference)
    pub baseline_acc: f64,
    /// which hardware gain feeds the reward (default: energy, as the paper)
    pub metric: Metric,
    /// per-phase step wall-clock (`hapq perf` breakdown)
    pub timers: PhaseTimers,
    group_of: Vec<usize>,

    // episode state
    work: Weights,
    cfgs: Vec<Compression>,
    act_bits: Vec<f32>,
    applied: Vec<Applied>,
    actions_taken: Vec<Action>,
    group_mask: Vec<Option<(f64, Vec<usize>)>>,
    t: usize,
    last_action: (f64, f64),
    rng: Rng,

    // normalisation constants for the state embedding
    norm: StateNorm,
    /// count of reward-oracle invocations (Table 3/4 accounting) —
    /// memo hits still count: the budget is over *logical* evals
    pub n_evals: u64,

    // search-loop memoization (the --memo family)
    memo: MemoConfig,
    eval_cache: EvalCache,
    /// lazily maintained per-layer config fingerprints of `work` +
    /// `act_bits` (`None` = dirty, recomputed at the next memo probe);
    /// dirtied exactly where the session is invalidated, so the memo
    /// key always describes what the oracle would see
    fps: Vec<Option<u64>>,
    /// full-config evals answered from the memo instead of inference
    pub memo_hits: u64,
    /// full-config evals that ran real inference while the memo was on
    pub memo_misses: u64,
}

struct StateNorm {
    max_ch: f64,
    max_hw: f64,
    max_e: f64,
    max_p: f64,
}

impl CompressionEnv {
    /// Build the environment; scores the dense baseline once up front.
    pub fn new(
        arch: ModelArch,
        weights: Weights,
        energy: EnergyModel,
        session: InferenceSession,
        seed: u64,
    ) -> Result<CompressionEnv> {
        let n = arch.prunable.len();
        let baseline_acc =
            session.accuracy(&weights, &vec![MAX_BITS as f32; n])?;
        let norm = {
            let mut max_ch = 1f64;
            let mut max_hw = 1f64;
            let mut max_e = 1e-12f64;
            let mut max_p = 1f64;
            for i in 0..n {
                let d = energy.dims(i);
                max_ch = max_ch.max(d.co as f64).max(d.ci as f64);
                max_hw = max_hw.max(d.ih as f64).max(d.iw as f64);
                max_e = max_e.max(energy.dense_layer(i));
                max_p = max_p.max(d.weights() as f64);
            }
            StateNorm { max_ch, max_hw, max_e, max_p }
        };
        let group_of = arch.group_of();
        let n_groups = arch.dep_groups.len();
        let work = weights.clone();
        Ok(CompressionEnv {
            arch,
            cost: CostCache::new(energy),
            session,
            lut: RewardLut::paper(),
            baseline_acc,
            metric: Metric::Energy,
            timers: PhaseTimers::default(),
            group_of,
            work,
            cfgs: vec![Compression::dense(); n],
            act_bits: vec![MAX_BITS as f32; n],
            applied: Vec::new(),
            actions_taken: Vec::new(),
            group_mask: vec![None; n_groups],
            t: 0,
            last_action: (0.0, 1.0),
            rng: Rng::new(seed),
            norm,
            dense: weights,
            n_evals: 0,
            memo: MemoConfig::default(),
            eval_cache: EvalCache::new(MemoConfig::default().eval_cap),
            fps: vec![None; n],
            memo_hits: 0,
            memo_misses: 0,
        })
    }

    /// Replace the memoization config (the CLI's `--memo` family). The
    /// eval cache restarts empty at the new capacity; counters keep
    /// accumulating. Purely a speed knob — memoized results are the
    /// exact previously computed values.
    pub fn set_memo(&mut self, memo: MemoConfig) {
        self.eval_cache = EvalCache::new(if memo.enabled { memo.eval_cap } else { 0 });
        self.memo = memo;
    }

    /// The active memoization config.
    pub fn memo(&self) -> MemoConfig {
        self.memo
    }

    /// Snapshot every cache seam's counters under the unified `cache.*`
    /// namespace (cost model, activation checkpoints, pack cache, eval
    /// memo) — collected into `hapq perf --json` and the run report.
    pub fn cache_counters(&self) -> CacheCounters {
        let stats = self.session.stats();
        CacheCounters {
            cost_recomputed: self.cost.recomputed(),
            cost_reused: self.cost.reused(),
            act_computed: stats.layers_computed,
            act_reused: stats.layers_reused,
            pack_hits: stats.pack_hits,
            pack_misses: stats.pack_misses,
            eval_hits: self.memo_hits,
            eval_misses: self.memo_misses,
        }
    }

    /// Answer one full-config oracle query through the eval memo.
    /// Returns `(accuracy, memo_overhead_secs)`; the overhead is also
    /// accumulated into [`PhaseTimers::memo_s`] so the caller can
    /// subtract it from its own inference-phase attribution. On a hit
    /// the session is *not* queried — its staged state stays stale and
    /// the pending invalidate marks remain, which is safe: the engine
    /// re-diffs dirty layers against the weights at the next real eval.
    fn memo_accuracy(&mut self) -> Result<(f64, f64)> {
        if !self.memo.enabled || self.memo.eval_cap == 0 {
            let acc = self.session.accuracy(&self.work, &self.act_bits)?;
            return Ok((acc, 0.0));
        }
        let m0 = std::time::Instant::now();
        for (i, fp) in self.fps.iter_mut().enumerate() {
            if fp.is_none() {
                *fp = Some(config_fingerprint(&self.work.w[i], self.act_bits[i]));
            }
        }
        let key: Vec<u64> = self.fps.iter().map(|fp| fp.unwrap()).collect();
        if let Some(acc) = self.eval_cache.get(&key) {
            self.memo_hits += 1;
            let memo_secs = m0.elapsed().as_secs_f64();
            self.timers.memo_s += memo_secs;
            if crate::telemetry::enabled() {
                crate::telemetry::span_at("env.memo", m0, memo_secs, None);
                crate::telemetry::count("env.memo.hits", 1);
            }
            return Ok((acc, memo_secs));
        }
        self.memo_misses += 1;
        let probe_secs = m0.elapsed().as_secs_f64();
        let acc = self.session.accuracy(&self.work, &self.act_bits)?;
        let m1 = std::time::Instant::now();
        self.eval_cache.insert(key, acc);
        let memo_secs = probe_secs + m1.elapsed().as_secs_f64();
        self.timers.memo_s += memo_secs;
        if crate::telemetry::enabled() {
            crate::telemetry::count("env.memo.misses", 1);
        }
        Ok((acc, memo_secs))
    }

    /// Number of prunable layers (= episode length).
    pub fn n_layers(&self) -> usize {
        self.arch.prunable.len()
    }

    /// Begin a new episode; returns the layer-0 state.
    pub fn reset(&mut self) -> Vec<f32> {
        let n = self.n_layers();
        self.work = self.dense.clone();
        self.cfgs = vec![Compression::dense(); n];
        self.act_bits = vec![MAX_BITS as f32; n];
        self.applied.clear();
        self.actions_taken.clear();
        self.group_mask.iter_mut().for_each(|m| *m = None);
        self.t = 0;
        self.last_action = (0.0, 1.0);
        self.session.invalidate_all();
        // every layer is back to dense/8-bit: recompute fingerprints at
        // the next memo probe (mirrors the invalidate_all above)
        self.fps.iter_mut().for_each(|fp| *fp = None);
        self.state(0)
    }

    /// The paper's layer embedding (eq. 1/2), min-max normalised.
    pub fn state(&self, t: usize) -> Vec<f32> {
        let em = self.cost.model();
        let d = em.dims(t);
        let layer = self.arch.layer(&self.arch.prunable[t]).unwrap();
        let is_fc = matches!(layer.op, Op::Fc) as u32 as f32;
        let e_dense = em.dense_layer(t);
        let e_now = em.layer(t, &self.cfgs[t]);
        let n = self.n_layers() as f32;
        vec![
            t as f32 / n,                                      // layer index
            is_fc,                                             // layer kind
            d.co as f32 / self.norm.max_ch as f32,             // C_out / N
            d.ci as f32 / self.norm.max_ch as f32,             // C_in / M
            d.ih as f32 / self.norm.max_hw as f32,             // h_in
            d.iw as f32 / self.norm.max_hw as f32,             // w_in
            d.stride as f32 / 4.0,                             // stride
            d.k as f32 / 7.0,                                  // kernel
            (e_dense / self.norm.max_e) as f32,                // E_t
            (d.weights() as f64 / self.norm.max_p) as f32,     // P_t
            (d.weights() as f64 * 32.0 / (self.norm.max_p * 32.0)) as f32, // M_t
            ((e_dense - e_now) / self.norm.max_e) as f32,      // E_t^red
            self.last_action.0 as f32,                         // a_{t-1} ratio
            self.last_action.1 as f32,                         // a_{t-1} bits
        ]
    }

    /// §4.1 dependency + sanity resolution: returns the algorithm that
    /// will actually run, and an optional forced channel mask.
    fn resolve(&self, t: usize, alg: PruneAlg) -> (PruneAlg, Option<(f64, Vec<usize>)>, bool) {
        let layer = self.arch.layer(&self.arch.prunable[t]).unwrap();
        // classifier output layer: structured pruning would drop classes
        let is_classifier = t == self.n_layers() - 1;
        if alg.coarse() && is_classifier {
            return (PruneAlg::Level, None, true);
        }
        let g = self.group_of[t];
        if alg.coarse() && g != usize::MAX {
            if let Some(mask) = &self.group_mask[g] {
                // a group member already fixed the structured mask — the
                // dependent layer inherits it (resolved at first dependent
                // layer, §4.1)
                return (alg, Some(mask.clone()), true);
            }
        }
        // depthwise convs inherit channel structure from their group; a
        // standalone coarse prune on them is fine (mask recorded below)
        let _ = layer;
        (alg, None, false)
    }

    /// Apply one layer's action; returns reward & next state (Fig 3 loop).
    pub fn step(&mut self, action: Action) -> Result<StepResult> {
        let t = self.t;
        let n = self.n_layers();
        assert!(t < n, "episode finished; call reset()");
        let want_alg = PruneAlg::from_index(action.alg);
        let sparsity_target = action.sparsity();
        let bits = action.precision();

        let ph0 = std::time::Instant::now();
        let (alg, forced_mask, mut overridden) = self.resolve(t, want_alg);
        let result = if let Some((ratio, chans)) = forced_mask {
            let _ = ratio;
            prune_channels(&mut self.work.w[t], &chans)
        } else {
            let mut ctx = PruneCtx {
                saliency: &self.dense.sal[t],
                chsq: &self.dense.chsq[t],
                dwconv: false,
                rng: &mut self.rng,
            };
            let r = prune(&mut self.work.w[t], alg, sparsity_target, &mut ctx);
            // record a fresh structured mask for the group
            if let (Some(ch), g) = (&r.channels, self.group_of[t]) {
                if g != usize::MAX && self.group_mask[g].is_none() {
                    self.group_mask[g] = Some((sparsity_target, ch.clone()));
                }
            }
            r
        };
        // §4.1: quantization second, on the pruned weights
        let ph1 = std::time::Instant::now();
        quantize_weights(&mut self.work.w[t], bits);
        let ph2 = std::time::Instant::now();
        self.session.invalidate(t);
        self.fps[t] = None; // layer t's (weights, bits) just changed
        self.act_bits[t] = bits as f32;
        let sparsity = result.sparsity;
        if alg.coarse() && result.channels.is_none() {
            overridden = true;
        }
        self.cfgs[t] = Compression { sparsity, coarse: alg.coarse(), bits };
        let applied = Applied { alg, sparsity, bits, overridden };
        self.applied.push(applied);
        self.actions_taken.push(action);

        // hardware feedback: incremental cost cache + validation
        // inference (only layer t's terms re-price — CostCache)
        let (rc0, ru0) = (self.cost.recomputed(), self.cost.reused());
        let energy_gain = self.cost.energy_gain(&self.cfgs);
        let latency_gain = self.cost.latency_gain(&self.cfgs);
        let hw_gain = match self.metric {
            Metric::Energy => energy_gain,
            Metric::Latency => latency_gain,
            Metric::Edp => 1.0 - (1.0 - energy_gain) * (1.0 - latency_gain),
        };
        let ph3 = std::time::Instant::now();
        let (accuracy, memo_secs) = self.memo_accuracy()?;
        let ph4 = std::time::Instant::now();
        let infer_secs = ((ph4 - ph3).as_secs_f64() - memo_secs).max(0.0);
        let hw_secs = self.cost.take_secs();
        self.timers.prune_s += (ph1 - ph0).as_secs_f64();
        self.timers.quant_s += (ph2 - ph1).as_secs_f64();
        self.timers.hw_s += hw_secs;
        self.timers.infer_s += infer_secs;
        self.timers.steps += 1;
        self.n_evals += 1;
        if crate::telemetry::enabled() {
            // retrospective spans reuse the phase clock readings above —
            // tracing adds zero extra `Instant::now` calls to this path
            use crate::telemetry::{count, span_at};
            span_at("env.prune", ph0, (ph1 - ph0).as_secs_f64(), Some(t));
            span_at("env.quant", ph1, (ph2 - ph1).as_secs_f64(), Some(t));
            span_at("env.hw", ph2, hw_secs, Some(t));
            span_at("env.infer", ph3, infer_secs, Some(t));
            span_at("env.step", ph0, (ph4 - ph0).as_secs_f64(), Some(t));
            count("hw.cache.recomputed", self.cost.recomputed() - rc0);
            count("hw.cache.reused", self.cost.reused() - ru0);
        }
        let acc_loss = (self.baseline_acc - accuracy).max(0.0);
        let reward = self.lut.reward(acc_loss, hw_gain);

        self.last_action = (action.ratio.clamp(0.0, 1.0), action.bits.clamp(0.0, 1.0));
        self.t += 1;
        let done = self.t == n;
        let state = if done { vec![0.0; STATE_DIM] } else { self.state(self.t) };
        Ok(StepResult {
            state,
            reward,
            done,
            accuracy,
            acc_loss,
            energy_gain,
            latency_gain,
            hw_gain,
            applied,
        })
    }

    /// Price a batch of candidate actions for the *current* layer
    /// without advancing the episode: for each action, replicate
    /// exactly what [`Self::step`] would apply (resolution → pruning →
    /// quantization → cost query → accuracy) on clones, and return the
    /// LUT reward each action would earn. Episode state — working
    /// weights, configs, act bits, group masks, the step counter, and
    /// crucially the pruning RNG stream — is left untouched, so a
    /// subsequent [`Self::step`] behaves bit-identically whether or not
    /// candidates were priced first (the search-driver parity test
    /// pins this).
    ///
    /// Each candidate sees a *clone* of the episode RNG, i.e. exactly
    /// the draws `step` would make for it; the accuracies come from one
    /// batched oracle query ([`InferenceSession::accuracy_batch`]),
    /// which amortizes the shared activation-checkpoint prefix across
    /// the batch. Speculative [`CostCache`] queries are safe: the
    /// incremental cache is bit-exact along any query walk.
    pub fn price_candidates(&mut self, actions: &[Action]) -> Result<Vec<f64>> {
        let t = self.t;
        assert!(t < self.n_layers(), "episode finished; call reset()");
        if actions.is_empty() {
            return Ok(Vec::new());
        }
        let ph0 = std::time::Instant::now();
        let mut cands = Vec::with_capacity(actions.len());
        let mut hw_gains = Vec::with_capacity(actions.len());
        for &action in actions {
            let want_alg = PruneAlg::from_index(action.alg);
            let sparsity_target = action.sparsity();
            let bits = action.precision();
            let (alg, forced_mask, _) = self.resolve(t, want_alg);
            let mut wt = self.work.w[t].clone();
            let mut rng = self.rng.clone();
            let result = if let Some((_ratio, chans)) = forced_mask {
                prune_channels(&mut wt, &chans)
            } else {
                let mut ctx = PruneCtx {
                    saliency: &self.dense.sal[t],
                    chsq: &self.dense.chsq[t],
                    dwconv: false,
                    rng: &mut rng,
                };
                prune(&mut wt, alg, sparsity_target, &mut ctx)
            };
            quantize_weights(&mut wt, bits);
            let mut cfgs = self.cfgs.clone();
            cfgs[t] =
                Compression { sparsity: result.sparsity, coarse: alg.coarse(), bits };
            let energy_gain = self.cost.energy_gain(&cfgs);
            let latency_gain = self.cost.latency_gain(&cfgs);
            hw_gains.push(match self.metric {
                Metric::Energy => energy_gain,
                Metric::Latency => latency_gain,
                Metric::Edp => 1.0 - (1.0 - energy_gain) * (1.0 - latency_gain),
            });
            cands.push(Candidate {
                layer: t,
                w: std::sync::Arc::new(wt),
                b: std::sync::Arc::new(self.work.b[t].clone()),
                bits: bits as f32,
            });
        }
        let ph1 = std::time::Instant::now();
        let accs = self.session.accuracy_batch(&self.work, &self.act_bits, &cands)?;
        let ph2 = std::time::Instant::now();
        // the cost queries ran inside the prep loop: attribute their
        // share to hw_s and the remainder (prune + quant) to prune_s
        let hw = self.cost.take_secs();
        self.timers.hw_s += hw;
        self.timers.prune_s += ((ph1 - ph0).as_secs_f64() - hw).max(0.0);
        self.timers.infer_s += (ph2 - ph1).as_secs_f64();
        self.n_evals += actions.len() as u64;
        Ok(accs
            .iter()
            .zip(&hw_gains)
            .map(|(&acc, &hw)| self.lut.reward((self.baseline_acc - acc).max(0.0), hw))
            .collect())
    }

    /// Snapshot the finished episode as a solution record.
    pub fn solution(&self, last: &StepResult) -> Solution {
        Solution {
            per_layer: self.applied.clone(),
            actions: self.actions_taken.clone(),
            accuracy: last.accuracy,
            acc_loss: last.acc_loss,
            energy_gain: last.energy_gain,
            latency_gain: last.latency_gain,
            reward: last.reward,
        }
    }

    /// Current compressed weights + act bits (for test-set evaluation).
    pub fn compressed(&self) -> (&Weights, &[f32]) {
        (&self.work, &self.act_bits)
    }

    /// The untouched dense weights (analytical baselines read these).
    pub fn dense_weights(&self) -> &Weights {
        &self.dense
    }

    /// Execution statistics of the accuracy oracle serving this env
    /// (threads, activation-cache hit rate) — recorded in run JSON.
    pub fn session_stats(&self) -> crate::runtime::RuntimeStats {
        self.session.stats()
    }

    /// Serialise the env's RNG stream (Bernoulli pruning draws) — part
    /// of a [`crate::search::checkpoint::SearchCheckpoint`]: a resumed
    /// run must continue the exact pruning-randomness stream or its
    /// episodes diverge from the uninterrupted run.
    pub fn save_rng(&self, w: &mut crate::io::bin::BinWriter) {
        self.rng.save_state(w);
    }

    /// Restore an RNG stream written by [`Self::save_rng`].
    pub fn restore_rng(&mut self, r: &mut crate::io::bin::BinReader) -> Result<()> {
        self.rng.load_state(r)
    }

    /// Evaluate an arbitrary full configuration in one shot (used by the
    /// NSGA-II / OPQ / ASQJ baselines — same oracle as the RL path).
    pub fn evaluate_config(&mut self, actions: &[Action]) -> Result<Solution> {
        assert_eq!(actions.len(), self.n_layers());
        self.reset();
        let mut last = None;
        for &a in actions {
            last = Some(self.step(a)?);
        }
        let last = last.unwrap();
        Ok(self.solution(&last))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn action_mapping() {
        let a = Action { ratio: 0.5, bits: 0.0, alg: 0 };
        assert!((a.sparsity() - 0.45).abs() < 1e-9);
        assert_eq!(a.precision(), 2);
        let b = Action { ratio: 2.0, bits: 1.0, alg: 0 };
        assert!((b.sparsity() - MAX_RATIO).abs() < 1e-9);
        assert_eq!(b.precision(), 8);
        let c = Action { ratio: 0.0, bits: 0.5, alg: 0 };
        assert_eq!(c.precision(), 5);
    }

    #[test]
    fn eval_cache_lru_exact_keys() {
        let mut c = EvalCache::new(2);
        assert!(c.get(&[1, 2]).is_none());
        c.insert(vec![1, 2], 0.5);
        assert_eq!(c.get(&[1, 2]), Some(0.5));
        c.insert(vec![3, 4], 0.25);
        c.get(&[1, 2]); // refresh: [3,4] is now the LRU entry
        c.insert(vec![5, 6], 0.75); // at capacity -> evicts [3,4]
        assert!(c.get(&[3, 4]).is_none());
        assert_eq!(c.get(&[1, 2]), Some(0.5));
        assert_eq!(c.get(&[5, 6]), Some(0.75));
        // a different fingerprint vector is a different config
        assert!(c.get(&[1, 2, 3]).is_none());
        // cap 0 retains nothing (--memo off)
        let mut off = EvalCache::new(0);
        off.insert(vec![1], 0.1);
        assert!(off.get(&[1]).is_none());
    }

    #[test]
    fn cache_counters_rates_handle_zero_totals() {
        let c = CacheCounters::default();
        assert_eq!(CacheCounters::rate(c.eval_hits, c.eval_misses), 0.0);
        assert_eq!(CacheCounters::rate(3, 1), 0.75);
    }
}
