//! Minimal n-d f32 tensor substrate.
//!
//! HAPQ only needs what the compression path touches: contiguous f32
//! storage, shape bookkeeping, channel-major views for pruning
//! (conv weights are HWIO, fc weights are [in, out] — matching the JAX
//! export), and a handful of reductions. This is deliberately *not* a
//! general autodiff tensor — the RL networks live in [`crate::nn`] on
//! flat matrices.

/// Dense, contiguous, row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    /// dimension sizes, outermost first
    pub shape: Vec<usize>,
    /// row-major contiguous storage
    pub data: Vec<f32>,
}

impl Tensor {
    /// Build from a shape and matching data (panics on size mismatch).
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} vs data len {}",
            shape,
            data.len()
        );
        Tensor { shape, data }
    }

    /// All-zero tensor of the given shape.
    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    /// Constant-filled tensor of the given shape.
    pub fn full(shape: Vec<usize>, v: f32) -> Self {
        let n = shape.iter().product();
        Tensor { shape, data: vec![v; n] }
    }

    /// Total number of elements.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Number of dimensions.
    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Number of output channels under the export layout:
    /// conv HWIO -> last dim; dwconv HWC1 -> dim 2; fc [in,out] -> last dim.
    pub fn out_channels(&self, dwconv: bool) -> usize {
        if dwconv {
            self.shape[self.shape.len() - 2]
        } else {
            *self.shape.last().unwrap()
        }
    }

    /// Sum of |x|.
    pub fn l1(&self) -> f32 {
        self.data.iter().map(|x| x.abs()).sum()
    }

    /// sqrt(sum x^2).
    pub fn l2(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Smallest element (+inf when empty).
    pub fn min(&self) -> f32 {
        self.data.iter().copied().fold(f32::INFINITY, f32::min)
    }

    /// Largest element (-inf when empty).
    pub fn max(&self) -> f32 {
        self.data.iter().copied().fold(f32::NEG_INFINITY, f32::max)
    }

    /// Fraction of exact zeros (post-pruning sparsity).
    pub fn sparsity(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().filter(|x| **x == 0.0).count() as f32 / self.data.len() as f32
    }

    /// Iterate (flat_index, output_channel) pairs for the export layouts.
    /// `ch_stride` semantics: for HWIO / [in,out] the channel is
    /// `idx % out_ch`; for dwconv HWC1 it is `(idx / 1) % C` (last dim 1).
    pub fn channel_of(&self, idx: usize, dwconv: bool) -> usize {
        if dwconv {
            // HWC1: dims [k, k, C, 1] -> channel = (idx) % C (last dim 1)
            let c = self.shape[self.shape.len() - 2];
            idx % c
        } else {
            idx % self.shape.last().unwrap()
        }
    }

    /// Per-output-channel L1 norms.
    pub fn channel_l1(&self, dwconv: bool) -> Vec<f32> {
        let c = self.out_channels(dwconv);
        let mut out = vec![0.0f32; c];
        for (i, x) in self.data.iter().enumerate() {
            out[self.channel_of(i, dwconv)] += x.abs();
        }
        out
    }

    /// Per-output-channel L2 norms.
    pub fn channel_l2(&self, dwconv: bool) -> Vec<f32> {
        let c = self.out_channels(dwconv);
        let mut out = vec![0.0f32; c];
        for (i, x) in self.data.iter().enumerate() {
            out[self.channel_of(i, dwconv)] += x * x;
        }
        out.iter_mut().for_each(|v| *v = v.sqrt());
        out
    }

    /// Zero all weights belonging to the given output channels.
    pub fn zero_channels(&mut self, channels: &[usize], dwconv: bool) {
        let dead: std::collections::HashSet<usize> = channels.iter().copied().collect();
        for i in 0..self.data.len() {
            if dead.contains(&self.channel_of(i, dwconv)) {
                self.data[i] = 0.0;
            }
        }
    }

    /// Per-output-channel (min, max) over the *non-zero* weights —
    /// the per-channel asymmetric quantization grid (paper §4.1).
    pub fn channel_minmax(&self, dwconv: bool) -> Vec<(f32, f32)> {
        let c = self.out_channels(dwconv);
        let mut mm = vec![(f32::INFINITY, f32::NEG_INFINITY); c];
        for (i, &x) in self.data.iter().enumerate() {
            if x == 0.0 {
                continue;
            }
            let ch = self.channel_of(i, dwconv);
            if x < mm[ch].0 {
                mm[ch].0 = x;
            }
            if x > mm[ch].1 {
                mm[ch].1 = x;
            }
        }
        mm
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t4() -> Tensor {
        // HWIO: [1,1,2,3]
        Tensor::new(vec![1, 1, 2, 3], vec![1., -2., 3., 4., 5., -6.])
    }

    #[test]
    fn norms() {
        let t = t4();
        assert_eq!(t.l1(), 21.0);
        assert!((t.l2() - (1. + 4. + 9. + 16. + 25. + 36f32).sqrt()).abs() < 1e-6);
    }

    #[test]
    fn channel_l1_hwio() {
        let t = t4();
        // channels (last dim 3): ch0 = |1|+|4|, ch1 = |-2|+|5|, ch2 = |3|+|-6|
        assert_eq!(t.channel_l1(false), vec![5.0, 7.0, 9.0]);
    }

    #[test]
    fn zero_channels_sparsity() {
        let mut t = t4();
        t.zero_channels(&[1], false);
        assert_eq!(t.data, vec![1., 0., 3., 4., 0., -6.]);
        assert!((t.sparsity() - 2.0 / 6.0).abs() < 1e-6);
    }

    #[test]
    fn dwconv_channels() {
        // HWC1: [1,1,3,1]
        let mut t = Tensor::new(vec![1, 1, 3, 1], vec![1., 2., 3.]);
        assert_eq!(t.out_channels(true), 3);
        t.zero_channels(&[0, 2], true);
        assert_eq!(t.data, vec![0., 2., 0.]);
    }

    #[test]
    fn minmax_skips_zeros() {
        let mut t = t4();
        t.data[0] = 0.0;
        let mm = t.channel_minmax(false);
        assert_eq!(mm[0], (4.0, 4.0));
        assert_eq!(mm[1], (-2.0, 5.0));
    }
}
