//! Pure-Rust inference backend: a direct interpreter of the
//! [`ModelArch`] graph over [`Weights`] — the default reward oracle.
//!
//! Semantics mirror the exported HLO graphs (`python/compile/model.py`)
//! operator for operator: NHWC activations, HWIO conv weights with SAME
//! padding, `[k,k,1,C]` depthwise weights with `groups = C`, `[in,out]`
//! fc weights, k×k/VALID max-pooling, global average pooling, residual
//! add and channel concat. Every prunable layer fake-quantizes its
//! *input* activations to `act_bits[i]` on the per-layer Laplace grid
//! measured at calibration (paper §4.1; grid math shared with
//! `python/compile/kernels/ref.py`) — weights arrive already
//! fake-quantized from the Rust side, exactly as on the PJRT path.
//!
//! Convolutions run as im2col + the row-skipping [`Mat`] matmul from
//! [`crate::nn`] (post-ReLU activations are ~50% zeros, so the skip
//! pays); depthwise convs use a direct loop (k is tiny). Accuracy
//! queries are answered by the incremental, multi-threaded
//! [`Engine`](super::exec::Engine) (`runtime/exec`): per-shard
//! activation checkpoint caches resume the forward pass from the first
//! layer dirtied by an [`invalidate`](super::InferenceBackend::invalidate)
//! hint, and shards evaluate in parallel across a std-only worker pool
//! — bit-identical at any thread count. [`NativeBackend::logits`] keeps
//! a stateless from-scratch forward as the reference path the engine is
//! tested against (EXPERIMENTS.md §Perf).
//!
//! ## The kernel seam (`--kernel {f32,int}`)
//!
//! Prunable layers evaluate through one of two kernels
//! ([`KernelKind`](super::KernelKind)), selected per engine and
//! recorded in [`RuntimeStats`]:
//!
//! * **f32** — the reference: clone the input feature map, `fake_quant`
//!   it in place, then f32 im2col + GEMM over the raw weight tensor
//!   (re-materialised every query).
//! * **int** (default) — the quantized fast path: `pack_layer` builds a
//!   per-layer `PackedLayer` once at stage time (weight plane with
//!   pruned rows/columns dropped + the activation grid's dequant LUT),
//!   re-packed only when that layer is invalidated; evaluation then
//!   extracts i16 activation *codes* while building the patch matrix
//!   (quantization fused into im2col, half the memory traffic) and runs
//!   the packed code-GEMM ([`crate::nn::mat::PackedMat::code_matmul`]).
//!   Requantization at the next layer boundary is the next layer's own
//!   code extraction — the grid math is shared
//!   ([`crate::quant::QuantGrid`]), so the logits are **bit-identical**
//!   to the f32 reference at every bit-width
//!   (`rust/tests/kernel_conformance.rs`). Layers whose grid is
//!   degenerate (zero calibration scale) fall back to the f32 kernel.
//!
//! A true i32 accumulator is deliberately *not* used: f32 addition
//! rounds after every product, so exact integer accumulation would
//! diverge from the reference bits — the speedup here comes from
//! packing, fused quantization, i16 code planes and pruning-mask
//! row/column skipping instead (see `nn/mat.rs` for the proof sketch).
//!
//! Bit-identity is guaranteed for **finite** activations (`±inf`
//! clamps to the grid boundary identically on both kernels). A `NaN`
//! activation — reachable only from a numerically diverged forward,
//! e.g. `inf + -inf` in a residual add — has no integer code: the int
//! path clamps it to the grid's low end while the f32 reference
//! propagates the `NaN` into the logits. Such a candidate is garbage
//! under either kernel, but the bits may differ there.
//!
//! One deliberate numeric divergence: `jnp.round` rounds half to even,
//! `f32::round` rounds half away from zero. The difference only
//! surfaces for activations landing exactly on a grid midpoint, which
//! calibration-scaled real data essentially never does.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU8, Ordering};

use anyhow::{bail, Result};

use super::exec::{default_threads, Engine};
use super::{
    default_kernel, default_memo, default_sched, Candidate, EvalData, InferenceBackend,
    KernelKind, MemoConfig, RuntimeStats, SchedKind,
};
use crate::model::{Layer, ModelArch, Op, Weights};
use crate::nn::mat::{CodeMat, Mat, PackedMat};
use crate::quant::QuantGrid;
use crate::tensor::Tensor;

/// Process-wide scratch-arena override set by [`set_scratch_arena`]
/// (0 = unset → follow [`default_memo`], 1 = off, 2 = on).
static SCRATCH_ARENA_OVERRIDE: AtomicU8 = AtomicU8::new(0);

/// Enable/disable the thread-local i16 code-plane scratch arena
/// process-wide (wired from `--memo` / `HAPQ_MEMO` in `main.rs`, and
/// toggled directly by the arena micro-benchmark). Purely an allocation
/// strategy: results are bit-identical either way — the arena hands the
/// int kernel the same code values, just in a reused buffer.
pub fn set_scratch_arena(on: bool) {
    SCRATCH_ARENA_OVERRIDE.store(if on { 2 } else { 1 }, Ordering::Relaxed);
}

/// Whether the code-plane scratch arena is active: the explicit
/// [`set_scratch_arena`] override when one was made, else the
/// [`default_memo`] resolution (`HAPQ_MEMO`, default on).
pub fn scratch_arena_enabled() -> bool {
    match SCRATCH_ARENA_OVERRIDE.load(Ordering::Relaxed) {
        1 => false,
        2 => true,
        _ => default_memo(),
    }
}

thread_local! {
    /// Per-thread reusable i16 code-plane buffer: every int-kernel
    /// layer evaluation on a worker thread codes its input feature map
    /// into this arena instead of a fresh allocation (the single
    /// biggest allocation churn in the oracle hot loop — one plane per
    /// prunable layer per shard per step).
    static CODE_ARENA: RefCell<Vec<i16>> = const { RefCell::new(Vec::new()) };
}

/// Code `x` through `grid` into an i16 plane and hand it to `f`. With
/// the scratch arena enabled the plane lives in the thread-local
/// [`CODE_ARENA`] buffer (cleared, not reallocated, between calls);
/// otherwise it is a fresh `Vec`. The values are identical either way,
/// so both int-kernel consumers ([`im2col_codes`], [`dwconv2d_codes`])
/// stay bit-identical to the f32 reference regardless of the toggle.
fn code_plane<R>(x: &[f32], grid: &QuantGrid, f: impl FnOnce(&[i16]) -> R) -> R {
    if scratch_arena_enabled() {
        CODE_ARENA.with(|a| {
            let mut buf = a.borrow_mut();
            buf.clear();
            buf.extend(x.iter().map(|&v| grid.code(v)));
            f(&buf)
        })
    } else {
        let codes: Vec<i16> = x.iter().map(|&v| grid.code(v)).collect();
        f(&codes)
    }
}

/// Optimal clipping ratio α*/b for a Laplace(b) distribution, bits 2..8
/// (Banner et al., NeurIPS 2019) — same table as the Python exporter.
pub const LAPLACE_CLIP: [f32; 7] = [2.83, 3.89, 5.03, 6.20, 7.41, 8.64, 9.90];

/// The `(lo, hi, step)` grid for fake-quantizing one layer's input
/// activations: `bits` is rounded and clamped to `[2, 8]`, the clip
/// point is `act_scale · LAPLACE_CLIP[bits-2]`, and signed tensors use
/// the symmetric grid `[-α, α]` (post-ReLU tensors `[0, α]`).
pub fn quant_params(bits: f32, act_scale: f32, signed: bool) -> (f32, f32, f32) {
    let b = bits.round().clamp(2.0, 8.0);
    let idx = (b - 2.0) as usize;
    let alpha = act_scale * LAPLACE_CLIP[idx.min(6)];
    let levels = b.exp2() - 1.0;
    if signed {
        (-alpha, alpha, 2.0 * alpha / levels)
    } else {
        (0.0, alpha, alpha / levels)
    }
}

/// Asymmetric clipped linear fake-quant of a buffer onto `[lo, hi]` —
/// the snap itself lives in the shared [`QuantGrid`] (`quant/grid.rs`),
/// the same math the weight quantizer and the int kernel use.
pub fn fake_quant(data: &mut [f32], lo: f32, hi: f32, step: f32) {
    let grid = QuantGrid::new(lo, hi, step);
    if grid.degenerate() {
        return; // degenerate grid (zero calibration scale): pass through
    }
    for x in data.iter_mut() {
        *x = grid.snap(*x);
    }
}

/// Explicit SAME padding `(lo, hi)` for one spatial dim.
fn same_pad(h: usize, k: usize, s: usize) -> (usize, usize) {
    let out = h.div_ceil(s);
    let pad = ((out - 1) * s + k).saturating_sub(h);
    (pad / 2, pad - pad / 2)
}

/// One intermediate activation: shape (leading dim = batch) + data.
pub(crate) struct Feat {
    /// dimension sizes, batch first
    pub shape: Vec<usize>,
    /// row-major contiguous storage
    pub data: Vec<f32>,
}

impl Feat {
    fn nhwc(&self) -> Result<(usize, usize, usize, usize)> {
        match self.shape[..] {
            [b, h, w, c] => Ok((b, h, w, c)),
            _ => bail!("expected NHWC tensor, got shape {:?}", self.shape),
        }
    }
}

fn relu(data: &mut [f32]) {
    data.iter_mut().for_each(|x| *x = x.max(0.0));
}

/// SAME-padded patch gather shared by BOTH kernels: collects
/// `[B·OH·OW, k·k·C]` patches from an NHWC plane, filling padding
/// positions with `pad`. Column order `(ki, kj, ci)` matches the
/// row-major HWIO weight flatten. Keeping the f32 and int paths on
/// this single copy of the stride/padding geometry is what makes their
/// bit-parity contract maintainable — fix indexing here, both move.
fn gather_patches<T: Copy>(
    data: &[T],
    (b, h, w, c): (usize, usize, usize, usize),
    k: usize,
    stride: usize,
    pad: T,
) -> (Vec<T>, usize, usize) {
    let (ph, _) = same_pad(h, k, stride);
    let (pw, _) = same_pad(w, k, stride);
    let oh = h.div_ceil(stride);
    let ow = w.div_ceil(stride);
    let cols = k * k * c;
    let mut d = vec![pad; b * oh * ow * cols];
    for bi in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                let row = ((bi * oh + oy) * ow + ox) * cols;
                for ki in 0..k {
                    let iy = (oy * stride + ki) as isize - ph as isize;
                    if iy < 0 || iy >= h as isize {
                        continue; // padding: fill value stays
                    }
                    for kj in 0..k {
                        let ix = (ox * stride + kj) as isize - pw as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let src = ((bi * h + iy as usize) * w + ix as usize) * c;
                        let dst = row + (ki * k + kj) * c;
                        d[dst..dst + c].copy_from_slice(&data[src..src + c]);
                    }
                }
            }
        }
    }
    (d, oh, ow)
}

/// im2col: NHWC input → patch matrix `[B·OH·OW, k·k·C]` (zero padding).
fn im2col(x: &Feat, k: usize, stride: usize) -> Result<(Mat, usize, usize)> {
    let (b, h, w, c) = x.nhwc()?;
    let (d, oh, ow) = gather_patches(&x.data, (b, h, w, c), k, stride, 0.0f32);
    Ok((Mat::from_vec(b * oh * ow, k * k * c, d), oh, ow))
}

/// SAME-padded strided convolution via im2col + matmul; HWIO weights.
fn conv2d(x: &Feat, w: &Tensor, bias: &[f32], stride: usize) -> Result<Feat> {
    let (b, _, _, c) = x.nhwc()?;
    let [k, k2, cin, cout] = match w.shape[..] {
        [a, b2, c2, d2] => [a, b2, c2, d2],
        _ => bail!("conv weight must be HWIO, got {:?}", w.shape),
    };
    if k != k2 || cin != c {
        bail!("conv weight {:?} does not fit input C={c}", w.shape);
    }
    let (patches, oh, ow) = im2col(x, k, stride)?;
    // HWIO row-major is already the [k·k·Cin, Cout] matmul operand
    let wmat = Mat::from_vec(k * k * cin, cout, w.data.clone());
    let mut y = patches.matmul(&wmat);
    y.add_row(bias);
    Ok(Feat { shape: vec![b, oh, ow, cout], data: y.d })
}

/// Depthwise convolution: `[k,k,1,C]` weights, `groups = C` — the
/// shared [`dwconv2d_any`] geometry reading the plane directly.
fn dwconv2d(x: &Feat, w: &Tensor, bias: &[f32], stride: usize) -> Result<Feat> {
    let dims = x.nhwc()?;
    dwconv2d_any(|i| x.data[i], dims, w, bias, stride)
}

/// Fused im2col + input quantization for the int kernel: codes the
/// feature map **once** (one `grid.code` per element — overlapping
/// patches then copy i16 codes, not re-quantize), then gathers
/// SAME-padded patches through the same [`gather_patches`] geometry as
/// the f32 path. Padding positions keep the `-1` sentinel, which
/// dequantizes to the exact `0.0` the f32 im2col inserts.
fn im2col_codes(
    x: &Feat,
    k: usize,
    stride: usize,
    grid: &QuantGrid,
) -> Result<(CodeMat, usize, usize)> {
    let (b, h, w, c) = x.nhwc()?;
    let (d, oh, ow) =
        code_plane(&x.data, grid, |codes| gather_patches(codes, (b, h, w, c), k, stride, -1i16));
    Ok((CodeMat { r: b * oh * ow, c: k * k * c, d }, oh, ow))
}

/// The one copy of the depthwise-conv geometry, parameterised over the
/// input load: the f32 kernel reads a fake-quantized plane directly,
/// the int kernel dequantizes i16 codes through the grid LUT. Same
/// loops → same f32 accumulation order → bit-identical outputs.
fn dwconv2d_any<F: Fn(usize) -> f32>(
    load: F,
    (b, h, wd, c): (usize, usize, usize, usize),
    w: &Tensor,
    bias: &[f32],
    stride: usize,
) -> Result<Feat> {
    let [k, k2, one, cw] = match w.shape[..] {
        [a, b2, c2, d2] => [a, b2, c2, d2],
        _ => bail!("dwconv weight must be [k,k,1,C], got {:?}", w.shape),
    };
    if k != k2 || one != 1 || cw != c {
        bail!("dwconv weight {:?} does not fit input C={c}", w.shape);
    }
    let (ph, _) = same_pad(h, k, stride);
    let (pw, _) = same_pad(wd, k, stride);
    let oh = h.div_ceil(stride);
    let ow = wd.div_ceil(stride);
    let mut out = vec![0.0f32; b * oh * ow * c];
    for bi in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                let dst = ((bi * oh + oy) * ow + ox) * c;
                for ki in 0..k {
                    let iy = (oy * stride + ki) as isize - ph as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kj in 0..k {
                        let ix = (ox * stride + kj) as isize - pw as isize;
                        if ix < 0 || ix >= wd as isize {
                            continue;
                        }
                        let src = ((bi * h + iy as usize) * wd + ix as usize) * c;
                        let wrow = (ki * k + kj) * c;
                        for ch in 0..c {
                            out[dst + ch] += load(src + ch) * w.data[wrow + ch];
                        }
                    }
                }
                for ch in 0..c {
                    out[dst + ch] += bias[ch];
                }
            }
        }
    }
    Ok(Feat { shape: vec![b, oh, ow, c], data: out })
}

/// Depthwise convolution on activation codes: [`dwconv2d_any`] with the
/// input dequantized through the grid LUT instead of read from a
/// fake-quantized copy — bit-identical output, half the staging memory.
fn dwconv2d_codes(
    x: &Feat,
    grid: &QuantGrid,
    lut: &[f32],
    w: &Tensor,
    bias: &[f32],
    stride: usize,
) -> Result<Feat> {
    let dims = x.nhwc()?;
    code_plane(&x.data, grid, |codes| {
        dwconv2d_any(|i| lut[(codes[i] + 1) as usize], dims, w, bias, stride)
    })
}

/// Pack-time state of one prunable layer on the int kernel: the
/// input-activation grid, its dequant LUT, and — for the GEMM ops —
/// the packed weight plane. Built by [`pack_layer`] once per (layer,
/// staged weights, bits) and shared with every worker via `Arc`; the
/// engine re-packs only layers its dirty set touched.
pub(crate) struct PackedLayer {
    /// the input-activation quantization grid this pack encodes for
    pub grid: QuantGrid,
    /// dequant LUT (`lut[0]` = structural zero, `lut[n+1]` = code `n`)
    pub lut: Vec<f32>,
    /// packed GEMM operand — conv (`[k·k·C_in, C_out]` from HWIO) and
    /// fc (`[in, out]`); `None` for depthwise convs (direct loop)
    pub gemm: Option<PackedMat>,
}

/// Build the int-kernel pack for one prunable layer, or `None` when the
/// layer must fall back to the f32 kernel: degenerate grid (zero
/// calibration scale — `fake_quant` passes values through, so there are
/// no codes to extract) or a weight shape the packer does not recognise
/// (the f32 path owns the error reporting for those).
pub(crate) fn pack_layer(
    layer: &Layer,
    w: &Tensor,
    grid: (f32, f32, f32),
) -> Option<PackedLayer> {
    let (lo, hi, step) = grid;
    let g = QuantGrid::new(lo, hi, step);
    let lut = g.lut()?;
    let gemm = match layer.op {
        Op::Conv => match w.shape[..] {
            [k, k2, cin, cout] if k == k2 => Some(PackedMat::pack(k * k2 * cin, cout, &w.data)),
            _ => return None,
        },
        Op::Fc => match w.shape[..] {
            [fin, fout] => Some(PackedMat::pack(fin, fout, &w.data)),
            _ => return None,
        },
        Op::DwConv => None,
        _ => return None, // weightless op: nothing to pack
    };
    Some(PackedLayer { grid: g, lut, gemm })
}

/// Evaluate one prunable layer on the int kernel. Callers guarantee
/// `pack` was built by [`pack_layer`] for this layer's op and the
/// current `(weights, bits)`; output is bit-identical to
/// [`eval_layer`] with the same parameters (kernel-conformance suite).
pub(crate) fn eval_layer_int(
    layer: &Layer,
    pack: &PackedLayer,
    w: &Tensor,
    bias: &[f32],
    ins: &[&Feat],
) -> Result<Feat> {
    let x0 = *ins
        .first()
        .ok_or_else(|| anyhow::anyhow!("layer `{}` has no inputs", layer.name))?;
    let mut out = match layer.op {
        Op::Conv => {
            let (b, _, _, c) = x0.nhwc()?;
            let [k, k2, cin, cout] = match w.shape[..] {
                [a, b2, c2, d2] => [a, b2, c2, d2],
                _ => bail!("conv weight must be HWIO, got {:?}", w.shape),
            };
            if k != k2 || cin != c {
                bail!("conv weight {:?} does not fit input C={c}", w.shape);
            }
            let pm = pack.gemm.as_ref().ok_or_else(|| {
                anyhow::anyhow!("conv `{}` is missing its packed weight plane", layer.name)
            })?;
            let (codes, oh, ow) = im2col_codes(x0, k, layer.stride, &pack.grid)?;
            let mut y = pm.code_matmul(&codes, &pack.lut);
            y.add_row(bias);
            Feat { shape: vec![b, oh, ow, cout], data: y.d }
        }
        Op::DwConv => dwconv2d_codes(x0, &pack.grid, &pack.lut, w, bias, layer.stride)?,
        Op::Fc => {
            let b = x0.shape[0];
            let n: usize = x0.shape[1..].iter().product();
            let (fin, fout) = match w.shape[..] {
                [fin, fout] => (fin, fout),
                _ => bail!("fc `{}` weight must be [in,out], got {:?}", layer.name, w.shape),
            };
            if fin != n {
                bail!("fc `{}` weight {:?} does not fit input [{b}, {n}]", layer.name, w.shape);
            }
            let pm = pack.gemm.as_ref().ok_or_else(|| {
                anyhow::anyhow!("fc `{}` is missing its packed weight plane", layer.name)
            })?;
            let codes = CodeMat {
                r: b,
                c: n,
                d: x0.data.iter().map(|&v| pack.grid.code(v)).collect(),
            };
            let mut y = pm.code_matmul(&codes, &pack.lut);
            y.add_row(bias);
            Feat { shape: vec![b, fout], data: y.d }
        }
        _ => bail!("int kernel asked to evaluate weightless layer `{}`", layer.name),
    };
    if layer.relu {
        relu(&mut out.data);
    }
    Ok(out)
}

/// k×k max-pooling, stride k, VALID (matches `jax.lax.reduce_window`).
fn maxpool(x: &Feat, k: usize) -> Result<Feat> {
    let (b, h, w, c) = x.nhwc()?;
    if h < k || w < k {
        bail!("maxpool k={k} larger than input {h}x{w}");
    }
    let oh = (h - k) / k + 1;
    let ow = (w - k) / k + 1;
    let mut out = vec![f32::NEG_INFINITY; b * oh * ow * c];
    for bi in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                let dst = ((bi * oh + oy) * ow + ox) * c;
                for ky in 0..k {
                    for kx in 0..k {
                        let src = ((bi * h + oy * k + ky) * w + ox * k + kx) * c;
                        for ch in 0..c {
                            if x.data[src + ch] > out[dst + ch] {
                                out[dst + ch] = x.data[src + ch];
                            }
                        }
                    }
                }
            }
        }
    }
    Ok(Feat { shape: vec![b, oh, ow, c], data: out })
}

/// Global average pooling: `[B,H,W,C] → [B,C]`.
fn gap(x: &Feat) -> Result<Feat> {
    let (b, h, w, c) = x.nhwc()?;
    let mut out = vec![0.0f32; b * c];
    let norm = (h * w) as f32;
    for bi in 0..b {
        for p in 0..h * w {
            let src = (bi * h * w + p) * c;
            for ch in 0..c {
                out[bi * c + ch] += x.data[src + ch];
            }
        }
    }
    out.iter_mut().for_each(|v| *v /= norm);
    Ok(Feat { shape: vec![b, c], data: out })
}

/// Concatenate along the channel (last) axis.
fn concat(ins: &[&Feat]) -> Result<Feat> {
    let first = ins.first().copied().expect("concat needs inputs");
    let lead = &first.shape[..first.shape.len() - 1];
    let mut c_total = 0usize;
    for f in ins {
        if &f.shape[..f.shape.len() - 1] != lead {
            bail!("concat inputs disagree on leading dims");
        }
        c_total += *f.shape.last().unwrap();
    }
    let outer: usize = lead.iter().product();
    let mut out = Vec::with_capacity(outer * c_total);
    for o in 0..outer {
        for f in ins {
            let c = *f.shape.last().unwrap();
            out.extend_from_slice(&f.data[o * c..(o + 1) * c]);
        }
    }
    let mut shape = lead.to_vec();
    shape.push(c_total);
    Ok(Feat { shape, data: out })
}

/// Per-layer parameters for evaluating one prunable op: the (possibly
/// staged) weight/bias tensors and the input-activation fake-quant grid.
pub(crate) struct LayerParams<'a> {
    /// weight tensor (HWIO / `[k,k,1,C]` / `[in,out]`)
    pub w: &'a Tensor,
    /// bias vector
    pub bias: &'a [f32],
    /// `(lo, hi, step)` grid from [`quant_params`]
    pub grid: (f32, f32, f32),
}

/// Evaluate one graph layer given its resolved input feature maps.
/// `params` must be `Some` exactly for prunable ops (conv/dwconv/fc).
/// Every operator treats batch rows independently, which is what makes
/// the exec engine's sharding bit-identical at any thread count.
pub(crate) fn eval_layer(
    layer: &Layer,
    params: Option<LayerParams<'_>>,
    ins: &[&Feat],
) -> Result<Feat> {
    let x0 = *ins
        .first()
        .ok_or_else(|| anyhow::anyhow!("layer `{}` has no inputs", layer.name))?;
    let mut out = match layer.op {
        Op::Conv | Op::DwConv | Op::Fc => {
            let p = params.ok_or_else(|| {
                anyhow::anyhow!("prunable layer `{}` evaluated without parameters", layer.name)
            })?;
            let (lo, hi, step) = p.grid;
            match layer.op {
                Op::Conv => {
                    let mut xq = Feat { shape: x0.shape.clone(), data: x0.data.clone() };
                    fake_quant(&mut xq.data, lo, hi, step);
                    conv2d(&xq, p.w, p.bias, layer.stride)?
                }
                Op::DwConv => {
                    let mut xq = Feat { shape: x0.shape.clone(), data: x0.data.clone() };
                    fake_quant(&mut xq.data, lo, hi, step);
                    dwconv2d(&xq, p.w, p.bias, layer.stride)?
                }
                _ => {
                    // fc: flatten then fake-quantize, like the exporter
                    let b = x0.shape[0];
                    let n: usize = x0.shape[1..].iter().product();
                    let mut flat = x0.data.clone();
                    fake_quant(&mut flat, lo, hi, step);
                    let (fin, fout) = match p.w.shape[..] {
                        [fin, fout] => (fin, fout),
                        _ => bail!(
                            "fc `{}` weight must be [in,out], got {:?}",
                            layer.name,
                            p.w.shape
                        ),
                    };
                    if fin != n {
                        bail!(
                            "fc `{}` weight {:?} does not fit input [{b}, {n}]",
                            layer.name,
                            p.w.shape
                        );
                    }
                    let x = Mat::from_vec(b, n, flat);
                    let wm = Mat::from_vec(fin, fout, p.w.data.clone());
                    let mut y = x.matmul(&wm);
                    y.add_row(p.bias);
                    Feat { shape: vec![b, fout], data: y.d }
                }
            }
        }
        Op::MaxPool => maxpool(x0, layer.k)?,
        Op::Gap => gap(x0)?,
        Op::Flatten => {
            let b = x0.shape[0];
            let n: usize = x0.shape[1..].iter().product();
            Feat { shape: vec![b, n], data: x0.data.clone() }
        }
        Op::Add => {
            let x1 = *ins.get(1).ok_or_else(|| {
                anyhow::anyhow!("add `{}` needs two inputs", layer.name)
            })?;
            if x0.shape != x1.shape {
                bail!("add `{}` shape mismatch {:?} vs {:?}", layer.name, x0.shape, x1.shape);
            }
            let data = x0.data.iter().zip(&x1.data).map(|(a, b)| a + b).collect();
            Feat { shape: x0.shape.clone(), data }
        }
        Op::Concat => concat(ins)?,
    };
    if layer.relu {
        relu(&mut out.data);
    }
    Ok(out)
}

/// Resolve a layer's named inputs against the feats computed so far.
fn resolve_inputs<'a>(layer: &Layer, feats: &'a [(String, Feat)]) -> Result<Vec<&'a Feat>> {
    layer
        .inputs
        .iter()
        .map(|name| {
            feats
                .iter()
                .find(|(n, _)| n == name)
                .map(|(_, f)| f)
                .ok_or_else(|| anyhow::anyhow!("layer input `{name}` not computed yet"))
        })
        .collect()
}

/// The pure-Rust accuracy oracle (see module docs): a from-scratch
/// reference forward plus the incremental, multi-threaded
/// [`Engine`] that answers every accuracy query.
///
/// Memory note: the engine's shards own one copy of the evaluation
/// images (moved into the workers' caches); `data` keeps a second one
/// so the [`Self::logits`] reference path stays available — a
/// deliberate trade at current subset sizes, and the first thing to
/// Arc-share if image RSS ever matters.
pub struct NativeBackend {
    arch: ModelArch,
    data: EvalData,
    engine: Engine,
}

impl NativeBackend {
    /// Build from an arch descriptor and pre-batched evaluation data,
    /// with [`default_threads`] workers (the `HAPQ_THREADS` env var,
    /// else 1) and the [`default_kernel`] (the `HAPQ_KERNEL` env var,
    /// else the int fast path).
    pub fn new(arch: &ModelArch, data: EvalData) -> Result<NativeBackend> {
        Self::with_threads(arch, data, default_threads())
    }

    /// Build with an explicit worker count (the `--threads` flag) and
    /// the [`default_kernel`]. Results are bit-identical at any thread
    /// count. The engine validates the arch's calibration vectors.
    pub fn with_threads(
        arch: &ModelArch,
        data: EvalData,
        threads: usize,
    ) -> Result<NativeBackend> {
        Self::with_options(arch, data, threads, default_kernel())
    }

    /// Build with an explicit worker count *and* compute kernel (the
    /// `--kernel` flag). Both kernels produce bit-identical logits
    /// (`rust/tests/kernel_conformance.rs`); `f32` is the oracle
    /// reference, `int` the fast path.
    pub fn with_options(
        arch: &ModelArch,
        data: EvalData,
        threads: usize,
        kernel: KernelKind,
    ) -> Result<NativeBackend> {
        Self::with_memo(arch, data, threads, kernel, MemoConfig::default())
    }

    /// Build with an explicit memoization configuration (`--memo` and
    /// the cache-capacity flags) on top of [`Self::with_options`]. The
    /// memo config sizes the engine's `PackCache`; caching is purely
    /// a speed knob — results are bit-identical with it on or off.
    pub fn with_memo(
        arch: &ModelArch,
        data: EvalData,
        threads: usize,
        kernel: KernelKind,
        memo: MemoConfig,
    ) -> Result<NativeBackend> {
        Self::with_sched(arch, data, threads, kernel, memo, default_sched())
    }

    /// Build with an explicit shard scheduler (`--sched`) on top of
    /// [`Self::with_memo`]. `steal` (the default) lets idle workers
    /// claim shards from loaded ones; `static` is the fixed round-robin
    /// ownership. Both are bit-identical at every thread count — the
    /// scheduler only changes which worker evaluates a shard.
    pub fn with_sched(
        arch: &ModelArch,
        data: EvalData,
        threads: usize,
        kernel: KernelKind,
        memo: MemoConfig,
        sched: SchedKind,
    ) -> Result<NativeBackend> {
        let engine = Engine::with_sched(arch, &data, threads, kernel, memo, sched)?;
        Ok(NativeBackend { arch: arch.clone(), data, engine })
    }

    /// Convenience: load a dataset artifact and build the backend.
    pub fn from_npz(
        arch: &ModelArch,
        data_npz: &std::path::Path,
        split: super::Split,
        limit: usize,
    ) -> Result<NativeBackend> {
        let data = EvalData::load(arch, data_npz, split, limit, arch.batch)?;
        Self::new(arch, data)
    }

    /// Run the graph on one stored image batch; returns logits
    /// `[batch, classes]` row-major (padded tail rows included).
    ///
    /// This is the stateless from-scratch reference path — the
    /// incremental engine is tested bit-identical against it.
    pub fn logits(
        &self,
        weights: &Weights,
        act_bits: &[f32],
        batch_idx: usize,
    ) -> Result<Vec<f32>> {
        let images = &self.data.image_batches[batch_idx];
        self.forward(weights, act_bits, images).map(|f| f.data)
    }

    /// Final-layer logits for every real example via the incremental
    /// engine, concatenated in example order (no padded rows).
    pub fn engine_logits(&self, weights: &Weights, act_bits: &[f32]) -> Result<Vec<f32>> {
        self.engine.logits(weights, act_bits)
    }

    /// Batched-oracle logits: per candidate layer-config, the
    /// final-layer logits in example order — the conformance suite
    /// compares these bitwise against serial per-candidate
    /// [`Self::engine_logits`] evaluation.
    pub fn engine_logits_batch(
        &self,
        weights: &Weights,
        act_bits: &[f32],
        cands: &[Candidate],
    ) -> Result<Vec<Vec<f32>>> {
        self.engine.logits_batch(weights, act_bits, cands)
    }

    fn forward(&self, weights: &Weights, act_bits: &[f32], images: &[f32]) -> Result<Feat> {
        let [h, w, c] = self.data.input;
        let b = self.data.batch;
        let mut feats: Vec<(String, Feat)> = vec![(
            "input".to_string(),
            Feat { shape: vec![b, h, w, c], data: images.to_vec() },
        )];
        for layer in &self.arch.layers {
            let out = {
                let ins = resolve_inputs(layer, &feats)?;
                let params = self.layer_params(layer, weights, act_bits);
                eval_layer(layer, params, &ins)?
            };
            feats.push((layer.name.clone(), out));
        }
        Ok(feats.pop().expect("graph has layers").1)
    }

    fn layer_params<'a>(
        &self,
        layer: &Layer,
        weights: &'a Weights,
        act_bits: &[f32],
    ) -> Option<LayerParams<'a>> {
        if !layer.op.prunable() {
            return None;
        }
        let i = self.arch.pidx(&layer.name);
        Some(LayerParams {
            w: &weights.w[i],
            bias: &weights.b[i].data,
            grid: quant_params(act_bits[i], self.arch.act_scales[i], self.arch.act_signed[i]),
        })
    }
}

impl InferenceBackend for NativeBackend {
    fn accuracy(&self, weights: &Weights, act_bits: &[f32]) -> Result<f64> {
        self.engine.accuracy(weights, act_bits)
    }

    fn accuracy_batch(
        &self,
        weights: &Weights,
        act_bits: &[f32],
        cands: &[Candidate],
    ) -> Result<Vec<f64>> {
        // shared-prefix fast path: one broadcast prices every candidate
        // against the synced activation-checkpoint caches, bitwise-equal
        // to the trait's serial definition (kernel_conformance.rs)
        self.engine.accuracy_batch(weights, act_bits, cands)
    }

    fn invalidate(&self, layer: usize) {
        self.engine.invalidate(layer);
    }

    fn invalidate_all(&self) {
        self.engine.invalidate_all();
    }

    fn n_examples(&self) -> usize {
        self.data.n_examples
    }

    fn batch(&self) -> usize {
        self.data.batch
    }

    fn n_prunable(&self) -> usize {
        self.arch.prunable.len()
    }

    fn name(&self) -> &'static str {
        "native"
    }

    fn stats(&self) -> RuntimeStats {
        self.engine.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn code_plane_arena_matches_fresh_alloc() {
        // the arena is an allocation strategy, not a numeric path: the
        // coded plane must be identical with it forced on, forced off,
        // and repeated (reused buffer fully overwritten)
        let grid = QuantGrid::new(0.0, 1.0, 0.25);
        let data: Vec<f32> = (0..64).map(|i| ((i as f32) * 0.37).sin()).collect();
        let fresh: Vec<i16> = data.iter().map(|&v| grid.code(v)).collect();
        set_scratch_arena(true);
        let on = code_plane(&data, &grid, |c| c.to_vec());
        let on_again = code_plane(&data[..32], &grid, |c| c.to_vec());
        set_scratch_arena(false);
        let off = code_plane(&data, &grid, |c| c.to_vec());
        // restore the env-default resolution for the rest of the process
        SCRATCH_ARENA_OVERRIDE.store(0, Ordering::Relaxed);
        assert_eq!(on, fresh);
        assert_eq!(off, fresh);
        assert_eq!(on_again, fresh[..32].to_vec());
    }

    #[test]
    fn same_pad_matches_exporter() {
        // h=8, k=3, s=1 -> out 8, pad (1,1); h=8, k=3, s=2 -> out 4, pad (0,1)
        assert_eq!(same_pad(8, 3, 1), (1, 1));
        assert_eq!(same_pad(8, 3, 2), (0, 1));
        assert_eq!(same_pad(4, 1, 1), (0, 0));
        assert_eq!(same_pad(5, 5, 5), (0, 0));
    }

    #[test]
    fn quant_params_hand_values() {
        // bits=2, scale=1, unsigned: alpha=2.83, levels=3, step=alpha/3
        let (lo, hi, step) = quant_params(2.0, 1.0, false);
        assert_eq!(lo, 0.0);
        assert!((hi - 2.83).abs() < 1e-6);
        assert!((step - 2.83 / 3.0).abs() < 1e-6);
        // signed grid is symmetric with doubled step
        let (lo, hi, step) = quant_params(3.0, 0.5, true);
        assert!((lo + 0.5 * 3.89).abs() < 1e-6);
        assert!((hi - 0.5 * 3.89).abs() < 1e-6);
        assert!((step - 2.0 * 0.5 * 3.89 / 7.0).abs() < 1e-6);
        // bits clamp to [2, 8]
        let (_, hi_low, _) = quant_params(0.0, 1.0, false);
        assert!((hi_low - 2.83).abs() < 1e-6);
        let (_, hi_high, _) = quant_params(12.0, 1.0, false);
        assert!((hi_high - 9.90).abs() < 1e-6);
    }

    #[test]
    fn fake_quant_snaps_and_clips() {
        // grid [0, 2] step 0.5: 0.6 -> 0.5, 0.76 -> 1.0, 3.0 clips to 2.0
        let mut v = [0.6f32, 0.76, 3.0, -1.0];
        fake_quant(&mut v, 0.0, 2.0, 0.5);
        assert_eq!(v, [0.5, 1.0, 2.0, 0.0]);
        // degenerate grid passes through
        let mut v = [0.3f32];
        fake_quant(&mut v, 0.0, 0.0, 0.0);
        assert_eq!(v, [0.3]);
    }

    #[test]
    fn conv_identity_1x1() {
        // 1x1 conv with weight 2.0, bias 0.5 on a 2x2x1 input
        let x = Feat {
            shape: vec![1, 2, 2, 1],
            data: vec![1.0, 2.0, 3.0, 4.0],
        };
        let w = Tensor::new(vec![1, 1, 1, 1], vec![2.0]);
        let y = conv2d(&x, &w, &[0.5], 1).unwrap();
        assert_eq!(y.shape, vec![1, 2, 2, 1]);
        assert_eq!(y.data, vec![2.5, 4.5, 6.5, 8.5]);
    }

    #[test]
    fn conv_3x3_same_padding_hand_value() {
        // all-ones 3x3 kernel on a 2x2 all-ones input, SAME padding:
        // every output sums its in-bounds 3x3 window -> all windows see
        // the full 2x2 input = 4
        let x = Feat { shape: vec![1, 2, 2, 1], data: vec![1.0; 4] };
        let w = Tensor::new(vec![3, 3, 1, 1], vec![1.0; 9]);
        let y = conv2d(&x, &w, &[0.0], 1).unwrap();
        assert_eq!(y.shape, vec![1, 2, 2, 1]);
        assert_eq!(y.data, vec![4.0; 4]);
    }

    #[test]
    fn dwconv_separates_channels() {
        // 1x1 dwconv: channel 0 scaled by 10, channel 1 by 100
        let x = Feat {
            shape: vec![1, 1, 2, 2],
            data: vec![1.0, 2.0, 3.0, 4.0], // (x=0: c0=1,c1=2) (x=1: c0=3,c1=4)
        };
        let w = Tensor::new(vec![1, 1, 1, 2], vec![10.0, 100.0]);
        let y = dwconv2d(&x, &w, &[0.0, 0.0], 1).unwrap();
        assert_eq!(y.data, vec![10.0, 200.0, 30.0, 400.0]);
    }

    #[test]
    fn maxpool_and_gap_hand_values() {
        let x = Feat {
            shape: vec![1, 2, 2, 1],
            data: vec![1.0, 5.0, 3.0, 2.0],
        };
        let p = maxpool(&x, 2).unwrap();
        assert_eq!(p.shape, vec![1, 1, 1, 1]);
        assert_eq!(p.data, vec![5.0]);
        let g = gap(&x).unwrap();
        assert_eq!(g.shape, vec![1, 1]);
        assert_eq!(g.data, vec![11.0 / 4.0]);
    }

    #[test]
    fn concat_interleaves_channels() {
        let a = Feat { shape: vec![1, 2, 1, 1], data: vec![1.0, 2.0] };
        let b = Feat { shape: vec![1, 2, 1, 2], data: vec![10.0, 11.0, 20.0, 21.0] };
        let y = concat(&[&a, &b]).unwrap();
        assert_eq!(y.shape, vec![1, 2, 1, 3]);
        assert_eq!(y.data, vec![1.0, 10.0, 11.0, 2.0, 20.0, 21.0]);
    }

    #[test]
    fn eval_layer_requires_params_for_prunable_ops() {
        let layer = Layer {
            name: "c".into(),
            op: Op::Conv,
            inputs: vec!["input".into()],
            k: 1,
            stride: 1,
            relu: false,
            in_shape: vec![2, 2, 1],
            out_shape: vec![2, 2, 1],
            in_ch: 1,
            out_ch: 1,
        };
        let x = Feat { shape: vec![1, 2, 2, 1], data: vec![1.0; 4] };
        assert!(eval_layer(&layer, None, &[&x]).is_err());
        let w = Tensor::new(vec![1, 1, 1, 1], vec![2.0]);
        let p = LayerParams { w: &w, bias: &[0.0], grid: (0.0, 0.0, 0.0) };
        let y = eval_layer(&layer, Some(p), &[&x]).unwrap();
        assert_eq!(y.data, vec![2.0; 4]); // degenerate grid passes through
    }

    fn conv_layer(name: &str, k: usize, relu: bool, in_ch: usize, out_ch: usize) -> Layer {
        Layer {
            name: name.into(),
            op: Op::Conv,
            inputs: vec!["input".into()],
            k,
            stride: 1,
            relu,
            in_shape: vec![4, 4, in_ch],
            out_shape: vec![4, 4, out_ch],
            in_ch,
            out_ch,
        }
    }

    #[test]
    fn pack_layer_falls_back_on_degenerate_grids() {
        let layer = conv_layer("c", 1, false, 1, 1);
        let w = Tensor::new(vec![1, 1, 1, 1], vec![2.0]);
        // zero calibration scale -> degenerate grid -> f32 fallback
        assert!(pack_layer(&layer, &w, (0.0, 0.0, 0.0)).is_none());
        // malformed weight shape -> f32 path owns the error
        let bad = Tensor::new(vec![2, 2], vec![1.0; 4]);
        assert!(pack_layer(&layer, &bad, (0.0, 1.0, 0.25)).is_none());
        // a healthy grid packs
        let p = pack_layer(&layer, &w, (0.0, 1.0, 0.25)).unwrap();
        assert_eq!(p.lut.len(), 2 + 4);
        assert!(p.gemm.is_some());
    }

    #[test]
    fn int_conv_matches_f32_reference_bitwise() {
        // 3x3 SAME conv with pruning-style zeros in the weights, a
        // signed input grid, and ReLU — the int path must reproduce the
        // f32 reference exactly, padding and zero-skips included
        let layer = conv_layer("c", 3, true, 2, 3);
        let mut wdata = vec![0.0f32; 3 * 3 * 2 * 3];
        for (i, v) in wdata.iter_mut().enumerate() {
            // scatter zeros (pruned weights) and kill output channel 1
            if i % 3 == 1 || i % 5 == 0 {
                continue;
            }
            *v = ((i as f32) * 0.37).sin();
        }
        let w = Tensor::new(vec![3, 3, 2, 3], wdata);
        let bias = [0.1f32, -0.2, 0.05];
        let grid = quant_params(3.0, 0.8, true);
        let x = Feat {
            shape: vec![2, 4, 4, 2],
            data: (0..2 * 4 * 4 * 2).map(|i| ((i as f32) * 0.61).cos()).collect(),
        };
        let p32 = LayerParams { w: &w, bias: &bias, grid };
        let want = eval_layer(&layer, Some(p32), &[&x]).unwrap();
        let pack = pack_layer(&layer, &w, grid).unwrap();
        let got = eval_layer_int(&layer, &pack, &w, &bias, &[&x]).unwrap();
        assert_eq!(got.shape, want.shape);
        assert_eq!(got.data, want.data);
    }

    #[test]
    fn int_dwconv_and_fc_match_f32_reference_bitwise() {
        // depthwise: direct code loop, unsigned grid
        let dw_layer = Layer {
            name: "d".into(),
            op: Op::DwConv,
            inputs: vec!["input".into()],
            k: 3,
            stride: 1,
            relu: false,
            in_shape: vec![4, 4, 2],
            out_shape: vec![4, 4, 2],
            in_ch: 2,
            out_ch: 2,
        };
        let wd = Tensor::new(
            vec![3, 3, 1, 2],
            (0..18).map(|i| ((i as f32) * 0.29).sin()).collect(),
        );
        let bias = [0.3f32, -0.1];
        let grid = quant_params(4.0, 0.5, false);
        let x = Feat {
            shape: vec![1, 4, 4, 2],
            data: (0..32).map(|i| ((i as f32) * 0.47).sin()).collect(),
        };
        let want = eval_layer(
            &dw_layer,
            Some(LayerParams { w: &wd, bias: &bias, grid }),
            &[&x],
        )
        .unwrap();
        let pack = pack_layer(&dw_layer, &wd, grid).unwrap();
        assert!(pack.gemm.is_none()); // dwconv runs the direct loop
        let got = eval_layer_int(&dw_layer, &pack, &wd, &bias, &[&x]).unwrap();
        assert_eq!(got.data, want.data);

        // fc on a flattened input, 2-bit grid
        let fc_layer = Layer {
            name: "f".into(),
            op: Op::Fc,
            inputs: vec!["x".into()],
            k: 1,
            stride: 1,
            relu: false,
            in_shape: vec![6],
            out_shape: vec![3],
            in_ch: 6,
            out_ch: 3,
        };
        let wf = Tensor::new(
            vec![6, 3],
            (0..18).map(|i| if i % 4 == 0 { 0.0 } else { ((i as f32) * 0.53).cos() }).collect(),
        );
        let bf = [0.0f32, 0.5, -0.5];
        let gridf = quant_params(2.0, 1.0, false);
        let xf = Feat {
            shape: vec![2, 6],
            data: (0..12).map(|i| ((i as f32) * 0.31).sin().abs()).collect(),
        };
        let want = eval_layer(
            &fc_layer,
            Some(LayerParams { w: &wf, bias: &bf, grid: gridf }),
            &[&xf],
        )
        .unwrap();
        let packf = pack_layer(&fc_layer, &wf, gridf).unwrap();
        let got = eval_layer_int(&fc_layer, &packf, &wf, &bf, &[&xf]).unwrap();
        assert_eq!(got.data, want.data);
    }
}
