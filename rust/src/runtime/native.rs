//! Pure-Rust inference backend: a direct interpreter of the
//! [`ModelArch`] graph over [`Weights`] — the default reward oracle.
//!
//! Semantics mirror the exported HLO graphs (`python/compile/model.py`)
//! operator for operator: NHWC activations, HWIO conv weights with SAME
//! padding, `[k,k,1,C]` depthwise weights with `groups = C`, `[in,out]`
//! fc weights, k×k/VALID max-pooling, global average pooling, residual
//! add and channel concat. Every prunable layer fake-quantizes its
//! *input* activations to `act_bits[i]` on the per-layer Laplace grid
//! measured at calibration (paper §4.1; grid math shared with
//! `python/compile/kernels/ref.py`) — weights arrive already
//! fake-quantized from the Rust side, exactly as on the PJRT path.
//!
//! Convolutions run as im2col + the row-skipping [`Mat`] matmul from
//! [`crate::nn`] (post-ReLU activations are ~50% zeros, so the skip
//! pays); depthwise convs use a direct loop (k is tiny). Accuracy
//! queries are answered by the incremental, multi-threaded
//! [`Engine`](super::exec::Engine) (`runtime/exec`): per-shard
//! activation checkpoint caches resume the forward pass from the first
//! layer dirtied by an [`invalidate`](super::InferenceBackend::invalidate)
//! hint, and shards evaluate in parallel across a std-only worker pool
//! — bit-identical at any thread count. [`NativeBackend::logits`] keeps
//! a stateless from-scratch forward as the reference path the engine is
//! tested against (EXPERIMENTS.md §Perf).
//!
//! One deliberate numeric divergence: `jnp.round` rounds half to even,
//! `f32::round` rounds half away from zero. The difference only
//! surfaces for activations landing exactly on a grid midpoint, which
//! calibration-scaled real data essentially never does.

use anyhow::{bail, Result};

use super::exec::{default_threads, Engine};
use super::{EvalData, InferenceBackend, RuntimeStats};
use crate::model::{Layer, ModelArch, Op, Weights};
use crate::nn::mat::Mat;
use crate::tensor::Tensor;

/// Optimal clipping ratio α*/b for a Laplace(b) distribution, bits 2..8
/// (Banner et al., NeurIPS 2019) — same table as the Python exporter.
pub const LAPLACE_CLIP: [f32; 7] = [2.83, 3.89, 5.03, 6.20, 7.41, 8.64, 9.90];

/// The `(lo, hi, step)` grid for fake-quantizing one layer's input
/// activations: `bits` is rounded and clamped to `[2, 8]`, the clip
/// point is `act_scale · LAPLACE_CLIP[bits-2]`, and signed tensors use
/// the symmetric grid `[-α, α]` (post-ReLU tensors `[0, α]`).
pub fn quant_params(bits: f32, act_scale: f32, signed: bool) -> (f32, f32, f32) {
    let b = bits.round().clamp(2.0, 8.0);
    let idx = (b - 2.0) as usize;
    let alpha = act_scale * LAPLACE_CLIP[idx.min(6)];
    let levels = b.exp2() - 1.0;
    if signed {
        (-alpha, alpha, 2.0 * alpha / levels)
    } else {
        (0.0, alpha, alpha / levels)
    }
}

/// Asymmetric clipped linear fake-quant of a buffer onto `[lo, hi]`.
pub fn fake_quant(data: &mut [f32], lo: f32, hi: f32, step: f32) {
    if step <= 0.0 || !step.is_finite() {
        return; // degenerate grid (zero calibration scale): pass through
    }
    for x in data.iter_mut() {
        *x = ((x.clamp(lo, hi) - lo) / step).round() * step + lo;
    }
}

/// Explicit SAME padding `(lo, hi)` for one spatial dim.
fn same_pad(h: usize, k: usize, s: usize) -> (usize, usize) {
    let out = h.div_ceil(s);
    let pad = ((out - 1) * s + k).saturating_sub(h);
    (pad / 2, pad - pad / 2)
}

/// One intermediate activation: shape (leading dim = batch) + data.
pub(crate) struct Feat {
    /// dimension sizes, batch first
    pub shape: Vec<usize>,
    /// row-major contiguous storage
    pub data: Vec<f32>,
}

impl Feat {
    fn nhwc(&self) -> Result<(usize, usize, usize, usize)> {
        match self.shape[..] {
            [b, h, w, c] => Ok((b, h, w, c)),
            _ => bail!("expected NHWC tensor, got shape {:?}", self.shape),
        }
    }
}

fn relu(data: &mut [f32]) {
    data.iter_mut().for_each(|x| *x = x.max(0.0));
}

/// im2col: NHWC input → patch matrix `[B·OH·OW, k·k·C]` whose column
/// order `(ki, kj, ci)` matches the row-major HWIO weight flatten.
fn im2col(x: &Feat, k: usize, stride: usize) -> Result<(Mat, usize, usize)> {
    let (b, h, w, c) = x.nhwc()?;
    let (ph, _) = same_pad(h, k, stride);
    let (pw, _) = same_pad(w, k, stride);
    let oh = h.div_ceil(stride);
    let ow = w.div_ceil(stride);
    let cols = k * k * c;
    let mut d = vec![0.0f32; b * oh * ow * cols];
    for bi in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                let row = ((bi * oh + oy) * ow + ox) * cols;
                for ki in 0..k {
                    let iy = (oy * stride + ki) as isize - ph as isize;
                    if iy < 0 || iy >= h as isize {
                        continue; // zero padding
                    }
                    for kj in 0..k {
                        let ix = (ox * stride + kj) as isize - pw as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let src = ((bi * h + iy as usize) * w + ix as usize) * c;
                        let dst = row + (ki * k + kj) * c;
                        d[dst..dst + c].copy_from_slice(&x.data[src..src + c]);
                    }
                }
            }
        }
    }
    Ok((Mat::from_vec(b * oh * ow, cols, d), oh, ow))
}

/// SAME-padded strided convolution via im2col + matmul; HWIO weights.
fn conv2d(x: &Feat, w: &Tensor, bias: &[f32], stride: usize) -> Result<Feat> {
    let (b, _, _, c) = x.nhwc()?;
    let [k, k2, cin, cout] = match w.shape[..] {
        [a, b2, c2, d2] => [a, b2, c2, d2],
        _ => bail!("conv weight must be HWIO, got {:?}", w.shape),
    };
    if k != k2 || cin != c {
        bail!("conv weight {:?} does not fit input C={c}", w.shape);
    }
    let (patches, oh, ow) = im2col(x, k, stride)?;
    // HWIO row-major is already the [k·k·Cin, Cout] matmul operand
    let wmat = Mat::from_vec(k * k * cin, cout, w.data.clone());
    let mut y = patches.matmul(&wmat);
    y.add_row(bias);
    Ok(Feat { shape: vec![b, oh, ow, cout], data: y.d })
}

/// Depthwise convolution: `[k,k,1,C]` weights, `groups = C`.
fn dwconv2d(x: &Feat, w: &Tensor, bias: &[f32], stride: usize) -> Result<Feat> {
    let (b, h, wd, c) = x.nhwc()?;
    let [k, k2, one, cw] = match w.shape[..] {
        [a, b2, c2, d2] => [a, b2, c2, d2],
        _ => bail!("dwconv weight must be [k,k,1,C], got {:?}", w.shape),
    };
    if k != k2 || one != 1 || cw != c {
        bail!("dwconv weight {:?} does not fit input C={c}", w.shape);
    }
    let (ph, _) = same_pad(h, k, stride);
    let (pw, _) = same_pad(wd, k, stride);
    let oh = h.div_ceil(stride);
    let ow = wd.div_ceil(stride);
    let mut out = vec![0.0f32; b * oh * ow * c];
    for bi in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                let dst = ((bi * oh + oy) * ow + ox) * c;
                for ki in 0..k {
                    let iy = (oy * stride + ki) as isize - ph as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kj in 0..k {
                        let ix = (ox * stride + kj) as isize - pw as isize;
                        if ix < 0 || ix >= wd as isize {
                            continue;
                        }
                        let src = ((bi * h + iy as usize) * wd + ix as usize) * c;
                        let wrow = (ki * k + kj) * c;
                        for ch in 0..c {
                            out[dst + ch] += x.data[src + ch] * w.data[wrow + ch];
                        }
                    }
                }
                for ch in 0..c {
                    out[dst + ch] += bias[ch];
                }
            }
        }
    }
    Ok(Feat { shape: vec![b, oh, ow, c], data: out })
}

/// k×k max-pooling, stride k, VALID (matches `jax.lax.reduce_window`).
fn maxpool(x: &Feat, k: usize) -> Result<Feat> {
    let (b, h, w, c) = x.nhwc()?;
    if h < k || w < k {
        bail!("maxpool k={k} larger than input {h}x{w}");
    }
    let oh = (h - k) / k + 1;
    let ow = (w - k) / k + 1;
    let mut out = vec![f32::NEG_INFINITY; b * oh * ow * c];
    for bi in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                let dst = ((bi * oh + oy) * ow + ox) * c;
                for ky in 0..k {
                    for kx in 0..k {
                        let src = ((bi * h + oy * k + ky) * w + ox * k + kx) * c;
                        for ch in 0..c {
                            if x.data[src + ch] > out[dst + ch] {
                                out[dst + ch] = x.data[src + ch];
                            }
                        }
                    }
                }
            }
        }
    }
    Ok(Feat { shape: vec![b, oh, ow, c], data: out })
}

/// Global average pooling: `[B,H,W,C] → [B,C]`.
fn gap(x: &Feat) -> Result<Feat> {
    let (b, h, w, c) = x.nhwc()?;
    let mut out = vec![0.0f32; b * c];
    let norm = (h * w) as f32;
    for bi in 0..b {
        for p in 0..h * w {
            let src = (bi * h * w + p) * c;
            for ch in 0..c {
                out[bi * c + ch] += x.data[src + ch];
            }
        }
    }
    out.iter_mut().for_each(|v| *v /= norm);
    Ok(Feat { shape: vec![b, c], data: out })
}

/// Concatenate along the channel (last) axis.
fn concat(ins: &[&Feat]) -> Result<Feat> {
    let first = ins.first().copied().expect("concat needs inputs");
    let lead = &first.shape[..first.shape.len() - 1];
    let mut c_total = 0usize;
    for f in ins {
        if &f.shape[..f.shape.len() - 1] != lead {
            bail!("concat inputs disagree on leading dims");
        }
        c_total += *f.shape.last().unwrap();
    }
    let outer: usize = lead.iter().product();
    let mut out = Vec::with_capacity(outer * c_total);
    for o in 0..outer {
        for f in ins {
            let c = *f.shape.last().unwrap();
            out.extend_from_slice(&f.data[o * c..(o + 1) * c]);
        }
    }
    let mut shape = lead.to_vec();
    shape.push(c_total);
    Ok(Feat { shape, data: out })
}

/// Per-layer parameters for evaluating one prunable op: the (possibly
/// staged) weight/bias tensors and the input-activation fake-quant grid.
pub(crate) struct LayerParams<'a> {
    /// weight tensor (HWIO / `[k,k,1,C]` / `[in,out]`)
    pub w: &'a Tensor,
    /// bias vector
    pub bias: &'a [f32],
    /// `(lo, hi, step)` grid from [`quant_params`]
    pub grid: (f32, f32, f32),
}

/// Evaluate one graph layer given its resolved input feature maps.
/// `params` must be `Some` exactly for prunable ops (conv/dwconv/fc).
/// Every operator treats batch rows independently, which is what makes
/// the exec engine's sharding bit-identical at any thread count.
pub(crate) fn eval_layer(
    layer: &Layer,
    params: Option<LayerParams<'_>>,
    ins: &[&Feat],
) -> Result<Feat> {
    let x0 = *ins
        .first()
        .ok_or_else(|| anyhow::anyhow!("layer `{}` has no inputs", layer.name))?;
    let mut out = match layer.op {
        Op::Conv | Op::DwConv | Op::Fc => {
            let p = params.ok_or_else(|| {
                anyhow::anyhow!("prunable layer `{}` evaluated without parameters", layer.name)
            })?;
            let (lo, hi, step) = p.grid;
            match layer.op {
                Op::Conv => {
                    let mut xq = Feat { shape: x0.shape.clone(), data: x0.data.clone() };
                    fake_quant(&mut xq.data, lo, hi, step);
                    conv2d(&xq, p.w, p.bias, layer.stride)?
                }
                Op::DwConv => {
                    let mut xq = Feat { shape: x0.shape.clone(), data: x0.data.clone() };
                    fake_quant(&mut xq.data, lo, hi, step);
                    dwconv2d(&xq, p.w, p.bias, layer.stride)?
                }
                _ => {
                    // fc: flatten then fake-quantize, like the exporter
                    let b = x0.shape[0];
                    let n: usize = x0.shape[1..].iter().product();
                    let mut flat = x0.data.clone();
                    fake_quant(&mut flat, lo, hi, step);
                    let (fin, fout) = match p.w.shape[..] {
                        [fin, fout] => (fin, fout),
                        _ => bail!(
                            "fc `{}` weight must be [in,out], got {:?}",
                            layer.name,
                            p.w.shape
                        ),
                    };
                    if fin != n {
                        bail!(
                            "fc `{}` weight {:?} does not fit input [{b}, {n}]",
                            layer.name,
                            p.w.shape
                        );
                    }
                    let x = Mat::from_vec(b, n, flat);
                    let wm = Mat::from_vec(fin, fout, p.w.data.clone());
                    let mut y = x.matmul(&wm);
                    y.add_row(p.bias);
                    Feat { shape: vec![b, fout], data: y.d }
                }
            }
        }
        Op::MaxPool => maxpool(x0, layer.k)?,
        Op::Gap => gap(x0)?,
        Op::Flatten => {
            let b = x0.shape[0];
            let n: usize = x0.shape[1..].iter().product();
            Feat { shape: vec![b, n], data: x0.data.clone() }
        }
        Op::Add => {
            let x1 = *ins.get(1).ok_or_else(|| {
                anyhow::anyhow!("add `{}` needs two inputs", layer.name)
            })?;
            if x0.shape != x1.shape {
                bail!("add `{}` shape mismatch {:?} vs {:?}", layer.name, x0.shape, x1.shape);
            }
            let data = x0.data.iter().zip(&x1.data).map(|(a, b)| a + b).collect();
            Feat { shape: x0.shape.clone(), data }
        }
        Op::Concat => concat(ins)?,
    };
    if layer.relu {
        relu(&mut out.data);
    }
    Ok(out)
}

/// Resolve a layer's named inputs against the feats computed so far.
fn resolve_inputs<'a>(layer: &Layer, feats: &'a [(String, Feat)]) -> Result<Vec<&'a Feat>> {
    layer
        .inputs
        .iter()
        .map(|name| {
            feats
                .iter()
                .find(|(n, _)| n == name)
                .map(|(_, f)| f)
                .ok_or_else(|| anyhow::anyhow!("layer input `{name}` not computed yet"))
        })
        .collect()
}

/// The pure-Rust accuracy oracle (see module docs): a from-scratch
/// reference forward plus the incremental, multi-threaded
/// [`Engine`] that answers every accuracy query.
///
/// Memory note: the engine's shards own one copy of the evaluation
/// images (moved into the workers' caches); `data` keeps a second one
/// so the [`Self::logits`] reference path stays available — a
/// deliberate trade at current subset sizes, and the first thing to
/// Arc-share if image RSS ever matters.
pub struct NativeBackend {
    arch: ModelArch,
    data: EvalData,
    engine: Engine,
}

impl NativeBackend {
    /// Build from an arch descriptor and pre-batched evaluation data,
    /// with [`default_threads`] workers (the `HAPQ_THREADS` env var,
    /// else 1).
    pub fn new(arch: &ModelArch, data: EvalData) -> Result<NativeBackend> {
        Self::with_threads(arch, data, default_threads())
    }

    /// Build with an explicit worker count (the `--threads` flag).
    /// Results are bit-identical at any thread count. The engine
    /// validates the arch's calibration vectors.
    pub fn with_threads(
        arch: &ModelArch,
        data: EvalData,
        threads: usize,
    ) -> Result<NativeBackend> {
        let engine = Engine::new(arch, &data, threads)?;
        Ok(NativeBackend { arch: arch.clone(), data, engine })
    }

    /// Convenience: load a dataset artifact and build the backend.
    pub fn from_npz(
        arch: &ModelArch,
        data_npz: &std::path::Path,
        split: super::Split,
        limit: usize,
    ) -> Result<NativeBackend> {
        let data = EvalData::load(arch, data_npz, split, limit, arch.batch)?;
        Self::new(arch, data)
    }

    /// Run the graph on one stored image batch; returns logits
    /// `[batch, classes]` row-major (padded tail rows included).
    ///
    /// This is the stateless from-scratch reference path — the
    /// incremental engine is tested bit-identical against it.
    pub fn logits(
        &self,
        weights: &Weights,
        act_bits: &[f32],
        batch_idx: usize,
    ) -> Result<Vec<f32>> {
        let images = &self.data.image_batches[batch_idx];
        self.forward(weights, act_bits, images).map(|f| f.data)
    }

    /// Final-layer logits for every real example via the incremental
    /// engine, concatenated in example order (no padded rows).
    pub fn engine_logits(&self, weights: &Weights, act_bits: &[f32]) -> Result<Vec<f32>> {
        self.engine.logits(weights, act_bits)
    }

    fn forward(&self, weights: &Weights, act_bits: &[f32], images: &[f32]) -> Result<Feat> {
        let [h, w, c] = self.data.input;
        let b = self.data.batch;
        let mut feats: Vec<(String, Feat)> = vec![(
            "input".to_string(),
            Feat { shape: vec![b, h, w, c], data: images.to_vec() },
        )];
        for layer in &self.arch.layers {
            let out = {
                let ins = resolve_inputs(layer, &feats)?;
                let params = self.layer_params(layer, weights, act_bits);
                eval_layer(layer, params, &ins)?
            };
            feats.push((layer.name.clone(), out));
        }
        Ok(feats.pop().expect("graph has layers").1)
    }

    fn layer_params<'a>(
        &self,
        layer: &Layer,
        weights: &'a Weights,
        act_bits: &[f32],
    ) -> Option<LayerParams<'a>> {
        if !layer.op.prunable() {
            return None;
        }
        let i = self.arch.pidx(&layer.name);
        Some(LayerParams {
            w: &weights.w[i],
            bias: &weights.b[i].data,
            grid: quant_params(act_bits[i], self.arch.act_scales[i], self.arch.act_signed[i]),
        })
    }
}

impl InferenceBackend for NativeBackend {
    fn accuracy(&self, weights: &Weights, act_bits: &[f32]) -> Result<f64> {
        self.engine.accuracy(weights, act_bits)
    }

    fn invalidate(&self, layer: usize) {
        self.engine.invalidate(layer);
    }

    fn invalidate_all(&self) {
        self.engine.invalidate_all();
    }

    fn n_examples(&self) -> usize {
        self.data.n_examples
    }

    fn batch(&self) -> usize {
        self.data.batch
    }

    fn n_prunable(&self) -> usize {
        self.arch.prunable.len()
    }

    fn name(&self) -> &'static str {
        "native"
    }

    fn stats(&self) -> RuntimeStats {
        self.engine.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_pad_matches_exporter() {
        // h=8, k=3, s=1 -> out 8, pad (1,1); h=8, k=3, s=2 -> out 4, pad (0,1)
        assert_eq!(same_pad(8, 3, 1), (1, 1));
        assert_eq!(same_pad(8, 3, 2), (0, 1));
        assert_eq!(same_pad(4, 1, 1), (0, 0));
        assert_eq!(same_pad(5, 5, 5), (0, 0));
    }

    #[test]
    fn quant_params_hand_values() {
        // bits=2, scale=1, unsigned: alpha=2.83, levels=3, step=alpha/3
        let (lo, hi, step) = quant_params(2.0, 1.0, false);
        assert_eq!(lo, 0.0);
        assert!((hi - 2.83).abs() < 1e-6);
        assert!((step - 2.83 / 3.0).abs() < 1e-6);
        // signed grid is symmetric with doubled step
        let (lo, hi, step) = quant_params(3.0, 0.5, true);
        assert!((lo + 0.5 * 3.89).abs() < 1e-6);
        assert!((hi - 0.5 * 3.89).abs() < 1e-6);
        assert!((step - 2.0 * 0.5 * 3.89 / 7.0).abs() < 1e-6);
        // bits clamp to [2, 8]
        let (_, hi_low, _) = quant_params(0.0, 1.0, false);
        assert!((hi_low - 2.83).abs() < 1e-6);
        let (_, hi_high, _) = quant_params(12.0, 1.0, false);
        assert!((hi_high - 9.90).abs() < 1e-6);
    }

    #[test]
    fn fake_quant_snaps_and_clips() {
        // grid [0, 2] step 0.5: 0.6 -> 0.5, 0.76 -> 1.0, 3.0 clips to 2.0
        let mut v = [0.6f32, 0.76, 3.0, -1.0];
        fake_quant(&mut v, 0.0, 2.0, 0.5);
        assert_eq!(v, [0.5, 1.0, 2.0, 0.0]);
        // degenerate grid passes through
        let mut v = [0.3f32];
        fake_quant(&mut v, 0.0, 0.0, 0.0);
        assert_eq!(v, [0.3]);
    }

    #[test]
    fn conv_identity_1x1() {
        // 1x1 conv with weight 2.0, bias 0.5 on a 2x2x1 input
        let x = Feat {
            shape: vec![1, 2, 2, 1],
            data: vec![1.0, 2.0, 3.0, 4.0],
        };
        let w = Tensor::new(vec![1, 1, 1, 1], vec![2.0]);
        let y = conv2d(&x, &w, &[0.5], 1).unwrap();
        assert_eq!(y.shape, vec![1, 2, 2, 1]);
        assert_eq!(y.data, vec![2.5, 4.5, 6.5, 8.5]);
    }

    #[test]
    fn conv_3x3_same_padding_hand_value() {
        // all-ones 3x3 kernel on a 2x2 all-ones input, SAME padding:
        // every output sums its in-bounds 3x3 window -> all windows see
        // the full 2x2 input = 4
        let x = Feat { shape: vec![1, 2, 2, 1], data: vec![1.0; 4] };
        let w = Tensor::new(vec![3, 3, 1, 1], vec![1.0; 9]);
        let y = conv2d(&x, &w, &[0.0], 1).unwrap();
        assert_eq!(y.shape, vec![1, 2, 2, 1]);
        assert_eq!(y.data, vec![4.0; 4]);
    }

    #[test]
    fn dwconv_separates_channels() {
        // 1x1 dwconv: channel 0 scaled by 10, channel 1 by 100
        let x = Feat {
            shape: vec![1, 1, 2, 2],
            data: vec![1.0, 2.0, 3.0, 4.0], // (x=0: c0=1,c1=2) (x=1: c0=3,c1=4)
        };
        let w = Tensor::new(vec![1, 1, 1, 2], vec![10.0, 100.0]);
        let y = dwconv2d(&x, &w, &[0.0, 0.0], 1).unwrap();
        assert_eq!(y.data, vec![10.0, 200.0, 30.0, 400.0]);
    }

    #[test]
    fn maxpool_and_gap_hand_values() {
        let x = Feat {
            shape: vec![1, 2, 2, 1],
            data: vec![1.0, 5.0, 3.0, 2.0],
        };
        let p = maxpool(&x, 2).unwrap();
        assert_eq!(p.shape, vec![1, 1, 1, 1]);
        assert_eq!(p.data, vec![5.0]);
        let g = gap(&x).unwrap();
        assert_eq!(g.shape, vec![1, 1]);
        assert_eq!(g.data, vec![11.0 / 4.0]);
    }

    #[test]
    fn concat_interleaves_channels() {
        let a = Feat { shape: vec![1, 2, 1, 1], data: vec![1.0, 2.0] };
        let b = Feat { shape: vec![1, 2, 1, 2], data: vec![10.0, 11.0, 20.0, 21.0] };
        let y = concat(&[&a, &b]).unwrap();
        assert_eq!(y.shape, vec![1, 2, 1, 3]);
        assert_eq!(y.data, vec![1.0, 10.0, 11.0, 2.0, 20.0, 21.0]);
    }

    #[test]
    fn eval_layer_requires_params_for_prunable_ops() {
        let layer = Layer {
            name: "c".into(),
            op: Op::Conv,
            inputs: vec!["input".into()],
            k: 1,
            stride: 1,
            relu: false,
            in_shape: vec![2, 2, 1],
            out_shape: vec![2, 2, 1],
            in_ch: 1,
            out_ch: 1,
        };
        let x = Feat { shape: vec![1, 2, 2, 1], data: vec![1.0; 4] };
        assert!(eval_layer(&layer, None, &[&x]).is_err());
        let w = Tensor::new(vec![1, 1, 1, 1], vec![2.0]);
        let p = LayerParams { w: &w, bias: &[0.0], grid: (0.0, 0.0, 0.0) };
        let y = eval_layer(&layer, Some(p), &[&x]).unwrap();
        assert_eq!(y.data, vec![2.0; 4]); // degenerate grid passes through
    }
}
