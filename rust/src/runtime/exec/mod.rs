//! Incremental, multi-threaded evaluation engine for the accuracy
//! oracle — the machinery behind [`NativeBackend`](super::NativeBackend).
//!
//! The RL loop (Fig 3) mutates exactly ONE layer's weights per step and
//! then asks for top-1 accuracy over the whole reward subset. The old
//! interpreter recomputed the full forward pass, single-threaded, on
//! every query; this engine exploits the two structural facts of that
//! workload instead:
//!
//! 1. **Incremental re-inference** (`actcache`): every shard of the
//!    evaluation data keeps an *activation checkpoint cache* — the
//!    post-op feature map of every graph node, recorded along the
//!    exported topological order. `invalidate(layer)` hints mark
//!    layers dirty; the next query resumes the forward pass from the
//!    first dirty layer, and dirtiness propagates through every
//!    consumer, so branches (residual adds, channel concats) recompute
//!    exactly when one of their inputs did.
//! 2. **Data parallelism** (`pool`): evaluation examples are
//!    independent, so the engine shards them across a long-lived,
//!    std-only worker pool (no new dependencies — the crate's vendoring
//!    policy). Shards and their caches live in a shared slab; workers
//!    claim them through atomic ticket counters, preferring their own
//!    round-robin slice and stealing from other workers only when it
//!    is drained (`--sched steal`, the default; `--sched static` is
//!    the fixed pre-stealing ownership). One query is a broadcast of
//!    the staged weights + dirty set, and the reduction sorts partials
//!    by shard index and sums per-shard `top1_correct` counts. Every
//!    operator in the interpreter treats examples independently, so
//!    the result is **bit-identical at any thread count and any steal
//!    order** (asserted by the property tests in
//!    `tests/exec_engine.rs`).
//!
//! Weight staging mirrors the PJRT literal cache: the engine keeps an
//! `Arc` snapshot per prunable layer and re-clones only layers that
//! were invalidated (or whose activation precision changed — the
//! engine diffs `act_bits` itself, so a forgotten hint on a pure
//! precision change cannot produce stale results).
//!
//! On the int kernel (`--kernel int`, the default) staging additionally
//! builds one `PackedLayer` (`runtime/native.rs`) per prunable layer —
//! the packed weight plane + activation dequant LUT — and, like the
//! weight snapshots, re-packs **only** layers the dirty set touched, so
//! an incremental dirty-layer resume re-packs exactly the invalidated
//! layers and nothing else. Pack wall-clock accumulates into
//! [`RuntimeStats::pack_secs`]; the workers report their
//! prunable-layer (GEMM) evaluation time into
//! [`RuntimeStats::gemm_secs`].

pub(crate) mod actcache;
pub(crate) mod pool;

use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::model::{ModelArch, Weights};
use crate::quant::config_fingerprint;
use crate::runtime::native::{pack_layer, quant_params, PackedLayer};
use crate::runtime::{Candidate, EvalData, KernelKind, MemoConfig, RuntimeStats, SchedKind};
use crate::tensor::Tensor;

use pool::{CandJob, Job, PackTask, Pool};

/// Worker-thread default for new sessions: the `HAPQ_THREADS`
/// environment variable when set to a positive integer, else 1. The
/// engine is bit-identical at any thread count; EXPERIMENTS.md §Perf
/// discusses when more threads pay.
pub fn default_threads() -> usize {
    std::env::var("HAPQ_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(1)
}

/// Immutable per-model execution plan shared by every worker: the graph
/// in topological order plus the index maps the hot loop needs.
pub(crate) struct Plan {
    /// the architecture descriptor (layers, prunable order, act grids)
    pub arch: ModelArch,
    /// input geometry `[H, W, C]`
    pub input: [usize; 3],
    /// graph-layer index → feat-slot indices of its inputs (slot 0 = images)
    pub input_slots: Vec<Vec<usize>>,
    /// graph-layer index → prunable index (None for weightless ops)
    pub prunable_of_layer: Vec<Option<usize>>,
    /// prunable index → graph-layer index
    pub layer_of_prunable: Vec<usize>,
}

impl Plan {
    /// Number of feature-map slots: one per graph layer plus the input.
    pub fn n_slots(&self) -> usize {
        self.arch.layers.len() + 1
    }

    /// Resolve the graph topology once, up front. Errors on inputs that
    /// are not defined before their consumers (the exporter guarantees
    /// topological order) and on prunable ops missing from the
    /// prunable list.
    pub fn build(arch: &ModelArch, input: [usize; 3]) -> Result<Plan> {
        let mut slot_of: HashMap<&str, usize> = HashMap::new();
        slot_of.insert("input", 0);
        let mut input_slots = Vec::with_capacity(arch.layers.len());
        let mut prunable_of_layer = Vec::with_capacity(arch.layers.len());
        for (li, layer) in arch.layers.iter().enumerate() {
            let slots = layer
                .inputs
                .iter()
                .map(|n| {
                    slot_of.get(n.as_str()).copied().ok_or_else(|| {
                        anyhow!(
                            "layer `{}` input `{n}` is not defined before it \
                             (graph must be topologically ordered)",
                            layer.name
                        )
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            input_slots.push(slots);
            prunable_of_layer.push(if layer.op.prunable() {
                Some(arch.prunable_idx.get(&layer.name).copied().ok_or_else(|| {
                    anyhow!("prunable-op layer `{}` missing from the prunable list", layer.name)
                })?)
            } else {
                None
            });
            slot_of.insert(layer.name.as_str(), li + 1);
        }
        let layer_of_prunable = arch
            .prunable
            .iter()
            .map(|n| {
                arch.layers
                    .iter()
                    .position(|l| &l.name == n)
                    .ok_or_else(|| anyhow!("prunable layer `{n}` not in the graph"))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Plan {
            arch: arch.clone(),
            input,
            input_slots,
            prunable_of_layer,
            layer_of_prunable,
        })
    }
}

/// One slab-resident slice of the evaluation data: a contiguous run of
/// real (non-padded) examples with their labels.
pub(crate) struct Shard {
    /// number of examples in this shard
    pub rows: usize,
    /// flattened `[rows, H, W, C]` images; moved into the shard's
    /// activation cache's slot 0 on first claim (single resident copy
    /// per shard)
    pub images: Vec<f32>,
    /// ground-truth labels, length `rows`
    pub labels: Vec<i64>,
}

/// Split the batched evaluation data into at least `threads` shards
/// (where the row counts allow), preserving example order. Padded tail
/// rows are dropped — the engine never computes them.
fn build_shards(data: &EvalData, threads: usize) -> Vec<Shard> {
    let [h, w, c] = data.input;
    let per = h * w * c;
    let n_units = data.label_batches.len().max(1);
    let chunks_per_unit = threads.div_ceil(n_units).max(1);
    let mut shards = Vec::new();
    for (bi, labels) in data.label_batches.iter().enumerate() {
        let rows = labels.len();
        if rows == 0 {
            continue;
        }
        let images = &data.image_batches[bi];
        let k = chunks_per_unit.min(rows);
        let base = rows / k;
        let extra = rows % k;
        let mut start = 0usize;
        for ci in 0..k {
            let len = base + usize::from(ci < extra);
            shards.push(Shard {
                rows: len,
                images: images[start * per..(start + len) * per].to_vec(),
                labels: labels[start..start + len].to_vec(),
            });
            start += len;
        }
    }
    shards
}

/// Bounded-LRU cache of int-kernel packs keyed by
/// `(prunable index, config fingerprint)` — the search loop's discrete
/// action space revisits identical `(mask, values, bits)` layer configs
/// constantly, and a [`PackedLayer`] is a pure function of
/// `(weights, grid)` where the grid is itself a pure function of
/// `(bits, act_scale, act_signed)` with the latter two constant per
/// layer. So one [`config_fingerprint`] key identifies one pack
/// exactly, and a hit hands back the very same `Arc` a fresh
/// [`pack_layer`] call would rebuild — bit-identical by construction.
/// Degenerate-grid layers cache their `None` (f32 fallback) too.
///
/// Eviction is least-recently-used via an index-linked recency list
/// over a slot arena (`entries` + free list): hits unlink/relink in
/// `O(1)` and eviction pops the tail in `O(1)`, replacing the old
/// monotone-tick `O(len)` min-scan. Hit/miss semantics are unchanged
/// (the memo bit-identity proptest is the guard). `cap == 0` disables
/// caching entirely (`--memo off`): every call builds fresh, nothing
/// is retained.
struct PackEntry {
    key: (usize, u64),
    pack: Option<Arc<PackedLayer>>,
    /// neighbor toward the most-recently-used end (`NIL` at the head)
    prev: usize,
    /// neighbor toward the least-recently-used end (`NIL` at the tail)
    next: usize,
}

/// Sentinel slot index terminating the recency list.
const NIL: usize = usize::MAX;

struct PackCache {
    cap: usize,
    /// key → slot index into `entries`
    map: HashMap<(usize, u64), usize>,
    entries: Vec<PackEntry>,
    /// slots vacated by eviction, reused before growing `entries`
    free: Vec<usize>,
    /// most-recently-used slot (`NIL` when empty)
    head: usize,
    /// least-recently-used slot — the eviction victim (`NIL` when empty)
    tail: usize,
    hits: u64,
    misses: u64,
}

impl PackCache {
    fn new(cap: usize) -> PackCache {
        PackCache {
            cap,
            map: HashMap::new(),
            entries: Vec::new(),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
            hits: 0,
            misses: 0,
        }
    }

    /// Is `(pi, fp)` currently resident? Non-mutating (no recency
    /// refresh, no stats) — the parallel pack fan-out peeks with this
    /// to predict which keys the serial walk of record will miss.
    fn contains(&self, pi: usize, fp: u64) -> bool {
        self.cap > 0 && self.map.contains_key(&(pi, fp))
    }

    fn unlink(&mut self, s: usize) {
        let (p, nx) = (self.entries[s].prev, self.entries[s].next);
        if p == NIL {
            self.head = nx;
        } else {
            self.entries[p].next = nx;
        }
        if nx == NIL {
            self.tail = p;
        } else {
            self.entries[nx].prev = p;
        }
    }

    fn push_front(&mut self, s: usize) {
        self.entries[s].prev = NIL;
        self.entries[s].next = self.head;
        if self.head != NIL {
            self.entries[self.head].prev = s;
        }
        self.head = s;
        if self.tail == NIL {
            self.tail = s;
        }
    }

    /// Look up `(pi, fp)`, building (and retaining) via `build` on a
    /// miss. The returned pack is shared: hits clone the cached `Arc`.
    fn get_or_pack(
        &mut self,
        pi: usize,
        fp: u64,
        build: impl FnOnce() -> Option<Arc<PackedLayer>>,
    ) -> Option<Arc<PackedLayer>> {
        if self.cap == 0 {
            self.misses += 1;
            return build();
        }
        if let Some(&s) = self.map.get(&(pi, fp)) {
            self.hits += 1;
            self.unlink(s);
            self.push_front(s);
            return self.entries[s].pack.clone();
        }
        self.misses += 1;
        let pack = build();
        if self.map.len() >= self.cap {
            let lru = self.tail;
            self.unlink(lru);
            self.map.remove(&self.entries[lru].key);
            self.entries[lru].pack = None;
            self.free.push(lru);
        }
        let entry = PackEntry { key: (pi, fp), pack: pack.clone(), prev: NIL, next: NIL };
        let s = match self.free.pop() {
            Some(s) => {
                self.entries[s] = entry;
                s
            }
            None => {
                self.entries.push(entry);
                self.entries.len() - 1
            }
        };
        self.map.insert((pi, fp), s);
        self.push_front(s);
        pack
    }
}

/// Mutable engine state behind the `&self` backend API: the staged
/// weight snapshot (plus, on the int kernel, the per-layer packs), the
/// pending dirty hints, and the cache statistics.
struct EngineState {
    staged_w: Vec<Arc<Tensor>>,
    staged_b: Vec<Arc<Tensor>>,
    /// int-kernel packs, prunable order (`None` = f32 fallback layer)
    staged_pack: Vec<Option<Arc<PackedLayer>>>,
    last_bits: Vec<f32>,
    marked: Vec<bool>,
    all_dirty: bool,
    computed: u64,
    reused: u64,
    pack_s: f64,
    gemm_s: f64,
    /// shards claimed off another worker's preference list, cumulative
    steals: u64,
    pack_cache: PackCache,
}

/// What one engine evaluation produces.
struct EvalOut {
    correct: usize,
    logits: Vec<f32>,
    /// per-candidate correct counts (batched oracle mode)
    cand_correct: Vec<usize>,
    /// per-candidate logits in example order (batched + want_logits)
    cand_logits: Vec<Vec<f32>>,
}

/// The evaluation engine: an execution plan, a worker pool holding
/// per-shard activation caches, and the staged-weights state.
pub struct Engine {
    plan: Arc<Plan>,
    pool: Pool,
    state: Mutex<EngineState>,
    threads: usize,
    kernel: KernelKind,
    sched: SchedKind,
    n_examples: usize,
    n_prunable: usize,
}

impl Engine {
    /// Build the engine: resolve the plan, shard the data, spawn the
    /// worker pool (`threads` is clamped to ≥ 1). `kernel` selects the
    /// prunable-layer compute path (`--kernel`); both kernels are
    /// bit-identical, so this is purely a performance knob.
    pub fn new(
        arch: &ModelArch,
        data: &EvalData,
        threads: usize,
        kernel: KernelKind,
    ) -> Result<Engine> {
        Self::with_memo(arch, data, threads, kernel, MemoConfig::default())
    }

    /// [`Engine::new`] with an explicit memoization config: sizes the
    /// pack cache (`--memo-pack-cap`), or disables pack caching
    /// entirely when `memo.enabled` is false — a pure speed knob; the
    /// cached pack is the same `Arc` a rebuild would produce. Uses the
    /// process-default scheduler ([`crate::runtime::default_sched`]).
    pub fn with_memo(
        arch: &ModelArch,
        data: &EvalData,
        threads: usize,
        kernel: KernelKind,
        memo: MemoConfig,
    ) -> Result<Engine> {
        Self::with_sched(arch, data, threads, kernel, memo, crate::runtime::default_sched())
    }

    /// [`Engine::with_memo`] with an explicit shard scheduler (the
    /// CLI's `--sched`). Both schedulers are bit-identical at every
    /// thread count — `steal` only changes which worker evaluates a
    /// shard, never what the reduction folds.
    pub fn with_sched(
        arch: &ModelArch,
        data: &EvalData,
        threads: usize,
        kernel: KernelKind,
        memo: MemoConfig,
        sched: SchedKind,
    ) -> Result<Engine> {
        let threads = threads.max(1);
        let n = arch.prunable.len();
        // the engine consumes the calibration vectors, so it owns the
        // one authoritative length check
        if arch.act_scales.len() != n {
            bail!(
                "arch `{}` has {} act_scales for {n} prunable layers — \
                 the native backend needs the calibration scales from the \
                 arch descriptor",
                arch.name,
                arch.act_scales.len()
            );
        }
        if arch.act_signed.len() != n {
            bail!("arch `{}` act_signed length mismatch", arch.name);
        }
        let plan = Arc::new(Plan::build(arch, data.input)?);
        let shards = build_shards(data, threads);
        let mut sets: Vec<Vec<(usize, Shard)>> = (0..threads).map(|_| Vec::new()).collect();
        for (gi, shard) in shards.into_iter().enumerate() {
            sets[gi % threads].push((gi, shard));
        }
        let pool = Pool::spawn(plan.clone(), sets, sched);
        Ok(Engine {
            plan,
            pool,
            state: Mutex::new(EngineState {
                staged_w: Vec::new(),
                staged_b: Vec::new(),
                staged_pack: Vec::new(),
                last_bits: Vec::new(),
                marked: vec![false; n],
                all_dirty: true,
                computed: 0,
                reused: 0,
                pack_s: 0.0,
                gemm_s: 0.0,
                steals: 0,
                pack_cache: PackCache::new(if memo.enabled { memo.pack_cap } else { 0 }),
            }),
            threads,
            kernel,
            sched,
            n_examples: data.n_examples,
            n_prunable: n,
        })
    }

    /// Top-1 accuracy of `weights` + `act_bits` over every shard.
    /// The hot path: no logits are copied out of the workers.
    pub fn accuracy(&self, weights: &Weights, act_bits: &[f32]) -> Result<f64> {
        let out = self.eval(weights, act_bits, false, &[])?;
        Ok(out.correct as f64 / self.n_examples as f64)
    }

    /// Final-layer logits for every real example, concatenated in
    /// example order (tests compare this bitwise across thread counts
    /// and against the from-scratch reference forward).
    pub fn logits(&self, weights: &Weights, act_bits: &[f32]) -> Result<Vec<f32>> {
        Ok(self.eval(weights, act_bits, true, &[])?.logits)
    }

    /// Batched oracle: price every candidate layer-config in one
    /// broadcast. The base config runs first (syncing every shard's
    /// checkpoint cache exactly as [`Self::accuracy`] would), then each
    /// candidate recomputes only its suffix against the shared prefix,
    /// with its pack built once engine-side. Returns one top-1 accuracy
    /// per candidate, bitwise-equal to evaluating each candidate
    /// serially via invalidate + [`Self::accuracy`] + restore. Engine
    /// state afterwards is identical to a plain base evaluation.
    pub fn accuracy_batch(
        &self,
        weights: &Weights,
        act_bits: &[f32],
        cands: &[Candidate],
    ) -> Result<Vec<f64>> {
        let out = self.eval(weights, act_bits, false, cands)?;
        Ok(out
            .cand_correct
            .iter()
            .map(|&c| c as f64 / self.n_examples as f64)
            .collect())
    }

    /// Batched-oracle logits: per candidate, the final-layer logits in
    /// example order (the conformance suite compares these bitwise
    /// against serial per-candidate evaluation).
    pub fn logits_batch(
        &self,
        weights: &Weights,
        act_bits: &[f32],
        cands: &[Candidate],
    ) -> Result<Vec<Vec<f32>>> {
        Ok(self.eval(weights, act_bits, true, cands)?.cand_logits)
    }

    /// Mark one prunable layer's staged weights dirty.
    pub fn invalidate(&self, layer: usize) {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        if layer < st.marked.len() {
            st.marked[layer] = true;
        }
    }

    /// Mark everything dirty (episode reset / unknown provenance).
    pub fn invalidate_all(&self) {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        st.all_dirty = true;
    }

    /// Worker count, kernel, phase timings and cumulative cache
    /// statistics.
    pub fn stats(&self) -> RuntimeStats {
        let st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        RuntimeStats {
            threads: self.threads,
            kernel: self.kernel,
            layers_computed: st.computed,
            layers_reused: st.reused,
            pack_secs: st.pack_s,
            gemm_secs: st.gemm_s,
            pack_hits: st.pack_cache.hits,
            pack_misses: st.pack_cache.misses,
            sched: self.sched,
            steals: st.steals,
        }
    }

    /// Worker threads serving this engine.
    pub fn threads(&self) -> usize {
        self.threads
    }

    fn eval(
        &self,
        weights: &Weights,
        act_bits: &[f32],
        want_logits: bool,
        cands: &[Candidate],
    ) -> Result<EvalOut> {
        let n = self.n_prunable;
        if act_bits.len() != n {
            bail!("act_bits len {} vs {n} prunable", act_bits.len());
        }
        if weights.w.len() != n {
            bail!("weights hold {} layers vs {n} prunable", weights.w.len());
        }
        if weights.b.len() != n {
            bail!("weights hold {} biases vs {n} prunable", weights.b.len());
        }
        for c in cands {
            if c.layer >= n {
                bail!("candidate layer {} out of range ({n} prunable)", c.layer);
            }
        }
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        let fresh = st.all_dirty || st.staged_w.len() != n;
        let dirty_p: Vec<bool> = if fresh {
            vec![true; n]
        } else {
            (0..n).map(|i| st.marked[i] || st.last_bits[i] != act_bits[i]).collect()
        };
        // restage: re-clone only dirty layers (first call stages all)
        if st.staged_w.len() != n {
            st.staged_w = weights.w.iter().map(|t| Arc::new(t.clone())).collect();
            st.staged_b = weights.b.iter().map(|t| Arc::new(t.clone())).collect();
        } else {
            for (i, dirty) in dirty_p.iter().enumerate() {
                if *dirty {
                    st.staged_w[i] = Arc::new(weights.w[i].clone());
                    st.staged_b[i] = Arc::new(weights.b[i].clone());
                }
            }
        }
        st.last_bits = act_bits.to_vec();
        st.marked.iter_mut().for_each(|m| *m = false);
        st.all_dirty = false;

        // int kernel: (re)stage exactly the dirty layers' packs — an
        // incremental resume never touches clean ones, and a revisited
        // (mask, values, bits) config pulls its pack from the LRU
        // cache instead of rebuilding it
        let cand_fps: Vec<u64> = if self.kernel == KernelKind::Int {
            cands.iter().map(|c| config_fingerprint(&c.w, c.bits)).collect()
        } else {
            Vec::new()
        };
        let mut prebuilt: HashMap<(usize, u64), Option<Arc<PackedLayer>>> = HashMap::new();
        if self.kernel == KernelKind::Int {
            let t0 = Instant::now();
            if st.staged_pack.len() != n {
                st.staged_pack = vec![None; n];
            }
            // fingerprint each dirty layer once, shared by the fan-out
            // prediction and the serial walk of record
            let fps: Vec<Option<u64>> = (0..n)
                .map(|i| dirty_p[i].then(|| config_fingerprint(&st.staged_w[i], act_bits[i])))
                .collect();
            // work-stealing pack fan-out: predict which keys the walk
            // below will miss (base restage + candidate batch), build
            // those on the idle pool, then let the walk consume the
            // prebuilt results. The walk replays the exact get_or_pack
            // sequence, so recency order, hit/miss counts, eviction
            // and insertion order stay byte-identical to serial
            // packing; a mispredicted entry just builds inline.
            if self.sched == SchedKind::Steal && self.threads >= 2 {
                let mut tasks: Vec<PackTask> = Vec::new();
                let mut keys: Vec<(usize, u64)> = Vec::new();
                let mut scheduled: HashSet<(usize, u64)> = HashSet::new();
                for (i, fp) in fps.iter().enumerate() {
                    if let Some(fp) = *fp {
                        if !st.pack_cache.contains(i, fp) && scheduled.insert((i, fp)) {
                            tasks.push(PackTask {
                                pi: i,
                                w: st.staged_w[i].clone(),
                                bits: act_bits[i],
                            });
                            keys.push((i, fp));
                        }
                    }
                }
                for (c, &fp) in cands.iter().zip(&cand_fps) {
                    if !st.pack_cache.contains(c.layer, fp) && scheduled.insert((c.layer, fp)) {
                        tasks.push(PackTask { pi: c.layer, w: c.w.clone(), bits: c.bits });
                        keys.push((c.layer, fp));
                    }
                }
                if tasks.len() >= 2 {
                    let t1 = Instant::now();
                    for (key, r) in
                        keys.into_iter().zip(self.pool.pack_parallel(&self.plan, tasks))
                    {
                        // a failed parallel build falls back to the
                        // inline build in the walk of record
                        if let Ok(pack) = r {
                            prebuilt.insert(key, pack);
                        }
                    }
                    if crate::telemetry::enabled() {
                        crate::telemetry::span_at(
                            "exec.pack_fanout",
                            t1,
                            t1.elapsed().as_secs_f64(),
                            None,
                        );
                    }
                }
            }
            let EngineState { staged_w, staged_pack, pack_cache, .. } = &mut *st;
            for (i, dirty) in dirty_p.iter().enumerate() {
                if *dirty {
                    let li = self.plan.layer_of_prunable[i];
                    let layer = &self.plan.arch.layers[li];
                    let grid = quant_params(
                        act_bits[i],
                        self.plan.arch.act_scales[i],
                        self.plan.arch.act_signed[i],
                    );
                    let fp = fps[i].expect("dirty layers were fingerprinted above");
                    let w = &staged_w[i];
                    staged_pack[i] = pack_cache.get_or_pack(i, fp, || {
                        prebuilt
                            .remove(&(i, fp))
                            .unwrap_or_else(|| pack_layer(layer, w, grid).map(Arc::new))
                    });
                }
            }
            let pack_secs = t0.elapsed().as_secs_f64();
            st.pack_s += pack_secs;
            if crate::telemetry::enabled() {
                crate::telemetry::span_at("exec.pack", t0, pack_secs, None);
            }
        }

        // batched oracle: build each candidate's pack once, engine-side
        // (shared by every worker via Arc), timed into pack_s like the
        // base restage packs
        let cand_jobs: Vec<CandJob> = {
            let t0 = Instant::now();
            let mut jobs = Vec::with_capacity(cands.len());
            for (ci, c) in cands.iter().enumerate() {
                let pack = if self.kernel == KernelKind::Int {
                    let li = self.plan.layer_of_prunable[c.layer];
                    let layer = &self.plan.arch.layers[li];
                    let grid = quant_params(
                        c.bits,
                        self.plan.arch.act_scales[c.layer],
                        self.plan.arch.act_signed[c.layer],
                    );
                    // candidates share the staged packs' cache keyspace:
                    // an accepted candidate's next staging is a hit, and
                    // re-priced candidates stop re-packing
                    let fp = cand_fps[ci];
                    st.pack_cache.get_or_pack(c.layer, fp, || {
                        prebuilt
                            .remove(&(c.layer, fp))
                            .unwrap_or_else(|| pack_layer(layer, &c.w, grid).map(Arc::new))
                    })
                } else {
                    None
                };
                jobs.push(CandJob {
                    pi: c.layer,
                    w: c.w.clone(),
                    b: c.b.clone(),
                    bits: c.bits,
                    pack,
                });
            }
            if !cands.is_empty() {
                let pack_secs = t0.elapsed().as_secs_f64();
                st.pack_s += pack_secs;
                if crate::telemetry::enabled() {
                    crate::telemetry::span_at("exec.pack_cands", t0, pack_secs, None);
                }
            }
            jobs
        };

        let mut dirty_layers = vec![false; self.plan.arch.layers.len()];
        for (i, dirty) in dirty_p.iter().enumerate() {
            if *dirty {
                dirty_layers[self.plan.layer_of_prunable[i]] = true;
            }
        }
        let job = Arc::new(Job {
            w: st.staged_w.clone(),
            b: st.staged_b.clone(),
            packs: st.staged_pack.clone(),
            bits: st.last_bits.clone(),
            dirty_layers,
            want_logits,
            cands: cand_jobs,
            hooks: Default::default(),
        });
        match self.pool.run(job) {
            Ok(agg) => {
                st.computed += agg.computed;
                st.reused += agg.reused;
                st.gemm_s += agg.gemm_s;
                st.steals += agg.stolen;
                if crate::telemetry::enabled() && !agg.worker_shards.is_empty() {
                    let max = *agg.worker_shards.iter().max().expect("non-empty") as f64;
                    let mean = agg.worker_shards.iter().sum::<usize>() as f64
                        / agg.worker_shards.len() as f64;
                    if mean > 0.0 {
                        crate::telemetry::gauge("exec.imbalance", max / mean);
                    }
                }
                Ok(EvalOut {
                    correct: agg.correct,
                    logits: agg.logits,
                    cand_correct: agg.cand_correct,
                    cand_logits: agg.cand_logits,
                })
            }
            Err(e) => {
                // a failed query leaves worker caches in unknown states;
                // force a full recompute on the next one
                st.all_dirty = true;
                Err(e)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_resolves_toy_graph_topology() {
        let arch = crate::model::tests::toy_arch();
        let plan = Plan::build(&arch, [8, 8, 3]).unwrap();
        // toy graph: input -> c1 -> d1 -> gap -> f1
        assert_eq!(plan.n_slots(), 5);
        assert_eq!(plan.input_slots[0], vec![0]); // c1 <- input
        assert_eq!(plan.input_slots[3], vec![3]); // f1 <- gap
        assert_eq!(plan.layer_of_prunable, vec![0, 1, 3]);
        assert_eq!(plan.prunable_of_layer, vec![Some(0), Some(1), None, Some(2)]);
    }

    #[test]
    fn shards_cover_examples_in_order_and_split_for_threads() {
        let arch = crate::model::tests::toy_arch();
        let per = 8 * 8 * 3;
        let n = 5;
        let images = crate::tensor::Tensor::new(
            vec![n, 8, 8, 3],
            (0..n * per).map(|i| i as f32).collect(),
        );
        let labels = vec![0i64, 1, 2, 3, 4];
        let data = EvalData::from_arrays(&arch, &images, &labels, 100, 2).unwrap();
        // 3 batches of real rows [2, 2, 1]; 2 threads keep them whole
        let s2 = build_shards(&data, 2);
        assert_eq!(s2.iter().map(|s| s.rows).collect::<Vec<_>>(), vec![2, 2, 1]);
        // 4 threads split each 2-row batch into single-row shards
        let s4 = build_shards(&data, 4);
        assert_eq!(s4.iter().map(|s| s.rows).collect::<Vec<_>>(), vec![1, 1, 1, 1, 1]);
        // example order and content survive any sharding
        let flat: Vec<i64> = s4.iter().flat_map(|s| s.labels.clone()).collect();
        assert_eq!(flat, labels);
        assert_eq!(s4[1].images, images.data[per..2 * per]);
        // padded tail rows are dropped, never computed
        assert_eq!(s2.iter().map(|s| s.rows).sum::<usize>(), 5);
    }

    #[test]
    fn default_threads_is_at_least_one() {
        assert!(default_threads() >= 1);
    }

    #[test]
    fn pack_cache_lru_hits_and_evicts() {
        let none = || None;
        let mut pc = PackCache::new(2);
        assert!(pc.get_or_pack(0, 1, none).is_none()); // miss: builds
        // a hit must not invoke the builder — it returns the cached
        // entry (here the cached `None` of a degenerate-grid layer)
        assert!(pc.get_or_pack(0, 1, || panic!("hit rebuilt")).is_none());
        assert_eq!((pc.hits, pc.misses), (1, 1));
        pc.get_or_pack(0, 2, none); // miss: cache now full
        pc.get_or_pack(0, 1, || panic!("hit rebuilt")); // refreshes (0,1)
        pc.get_or_pack(1, 3, none); // miss: evicts LRU (0,2)
        pc.get_or_pack(0, 2, none); // miss again — it was evicted
        assert_eq!((pc.hits, pc.misses), (2, 4));
        assert_eq!(pc.map.len(), 2);
        // cap 0 disables retention entirely (--memo off)
        let mut off = PackCache::new(0);
        off.get_or_pack(0, 1, none);
        off.get_or_pack(0, 1, none);
        assert_eq!((off.hits, off.misses), (0, 2));
        assert!(off.map.is_empty());
    }
}
