//! Per-shard activation checkpoint cache: records every graph node's
//! post-op feature map along the exported topological order and resumes
//! the forward pass from the first dirty layer on the next query. The
//! cache lives in the shard's slab slot (`pool`), primed lazily on the
//! shard's first claim, so whichever worker claims the shard — its
//! preferred owner or a stealer — evaluates against the same state.
//!
//! Correctness across branches: a slot is recomputed iff its layer was
//! invalidated, it was never computed, or **any** of its input slots
//! was recomputed this query. Because the graph is walked in
//! topological order, dirtiness propagates through residual adds and
//! channel concats exactly as data does — a dirty layer dirties
//! everything downstream, and nothing else.

use anyhow::Result;

use crate::runtime::native::{eval_layer, eval_layer_int, quant_params, Feat, LayerParams};
use crate::runtime::top1_correct;

use super::pool::{CandJob, Job};
use super::{Plan, Shard};

/// What one shard evaluation returns to the pool.
pub(crate) struct ShardOutcome {
    /// rows whose argmax matched the label
    pub correct: usize,
    /// graph layers recomputed this query
    pub computed: u64,
    /// graph layers served from the checkpoint cache
    pub reused: u64,
    /// seconds spent evaluating prunable (GEMM) layers this query
    pub gemm_s: f64,
    /// final-layer activations, `[rows, classes]` row-major — empty
    /// unless the job asked for them (`Job::want_logits`)
    pub logits: Vec<f32>,
}

/// The checkpoint cache: one feature-map slot per graph node
/// (slot 0 = the shard's images, slot `li + 1` = layer `li`'s output).
pub(crate) struct ActCache {
    feats: Vec<Option<Feat>>,
}

impl ActCache {
    /// Build the cache for one shard, moving the shard's image buffer
    /// into the immutable slot 0 — the images never change, so the
    /// engine side keeps a single copy per shard (the backend's
    /// reference-forward path retains its own, see `NativeBackend`).
    pub fn primed(plan: &Plan, shard: &mut Shard) -> ActCache {
        let [h, w, c] = plan.input;
        let images = std::mem::take(&mut shard.images);
        let mut feats: Vec<Option<Feat>> = (0..plan.n_slots()).map(|_| None).collect();
        feats[0] = Some(Feat { shape: vec![shard.rows, h, w, c], data: images });
        ActCache { feats }
    }

    /// Evaluate the graph over one shard, resuming from the first
    /// layer marked in `job.dirty_layers`. Prunable layers run on the
    /// int kernel whenever the job carries a pack for them
    /// (`Job::packs`); a missing pack is the per-layer f32 fallback.
    pub fn eval(&mut self, plan: &Plan, shard: &Shard, job: &Job) -> Result<ShardOutcome> {
        let n_slots = plan.n_slots();
        let mut dirty = vec![false; n_slots];
        let mut computed = 0u64;
        let mut reused = 0u64;
        let mut gemm_s = 0.0f64;
        for (li, layer) in plan.arch.layers.iter().enumerate() {
            let slot = li + 1;
            let needs = job.dirty_layers[li]
                || self.feats[slot].is_none()
                || plan.input_slots[li].iter().any(|&s| dirty[s]);
            dirty[slot] = needs;
            if !needs {
                reused += 1;
                continue;
            }
            let out = {
                let ins: Vec<&Feat> = plan.input_slots[li]
                    .iter()
                    .map(|&s| {
                        self.feats[s]
                            .as_ref()
                            .expect("topological order guarantees inputs are computed")
                    })
                    .collect();
                match plan.prunable_of_layer[li] {
                    Some(i) => {
                        let t0 = std::time::Instant::now();
                        let pack = job.packs.get(i).and_then(|p| p.as_ref());
                        let y = match pack {
                            Some(pack) => {
                                eval_layer_int(layer, pack, &job.w[i], &job.b[i].data, &ins)?
                            }
                            None => eval_layer(
                                layer,
                                Some(LayerParams {
                                    w: &job.w[i],
                                    bias: &job.b[i].data,
                                    grid: quant_params(
                                        job.bits[i],
                                        plan.arch.act_scales[i],
                                        plan.arch.act_signed[i],
                                    ),
                                }),
                                &ins,
                            )?,
                        };
                        gemm_s += t0.elapsed().as_secs_f64();
                        y
                    }
                    None => eval_layer(layer, None, &ins)?,
                }
            };
            self.feats[slot] = Some(out);
            computed += 1;
        }
        let last = self.feats[n_slots - 1]
            .as_ref()
            .expect("final slot is computed or cached");
        let classes = last.data.len() / shard.rows;
        let correct = top1_correct(&last.data, classes, &shard.labels);
        let logits = if job.want_logits { last.data.clone() } else { Vec::new() };
        Ok(ShardOutcome { correct, computed, reused, gemm_s, logits })
    }

    /// Price one candidate layer-config against the shard's cached
    /// activations: recompute only the suffix reachable from the
    /// proposed layer into scratch slots, resolving inputs
    /// scratch-first-else-cache. The checkpoint cache is **never**
    /// mutated, so the engine's state after a batched query is
    /// identical to after the plain base query — which is what makes
    /// batched pricing bitwise-equal to serial one-at-a-time
    /// evaluation (`tests/kernel_conformance.rs`).
    ///
    /// Requires [`Self::eval`] to have run with the same `job` first
    /// (the pool guarantees this ordering), so every input slot the
    /// suffix reads is populated.
    pub fn eval_candidate(
        &self,
        plan: &Plan,
        shard: &Shard,
        job: &Job,
        cand: &CandJob,
        want_logits: bool,
    ) -> Result<ShardOutcome> {
        let n_slots = plan.n_slots();
        let cli = plan.layer_of_prunable[cand.pi];
        let mut scratch: Vec<Option<Feat>> = (0..n_slots).map(|_| None).collect();
        let mut computed = 0u64;
        // the whole prefix before the proposed layer is served from the
        // shared checkpoint cache
        let mut reused = cli as u64;
        let mut gemm_s = 0.0f64;
        for (li, layer) in plan.arch.layers.iter().enumerate().skip(cli) {
            let needs =
                li == cli || plan.input_slots[li].iter().any(|&s| scratch[s].is_some());
            if !needs {
                reused += 1;
                continue;
            }
            let out = {
                let ins: Vec<&Feat> = plan.input_slots[li]
                    .iter()
                    .map(|&s| {
                        scratch[s]
                            .as_ref()
                            .or(self.feats[s].as_ref())
                            .expect("base eval leaves every input slot computed")
                    })
                    .collect();
                match plan.prunable_of_layer[li] {
                    Some(i) => {
                        let t0 = std::time::Instant::now();
                        // the proposed layer uses the candidate's
                        // weights/pack; every other prunable layer in
                        // the suffix re-evaluates with the job's base
                        // parameters
                        let (pack, w, bias, bits) = if i == cand.pi {
                            (cand.pack.as_ref(), &cand.w, &cand.b.data, cand.bits)
                        } else {
                            (
                                job.packs.get(i).and_then(|p| p.as_ref()),
                                &job.w[i],
                                &job.b[i].data,
                                job.bits[i],
                            )
                        };
                        let y = match pack {
                            Some(pack) => eval_layer_int(layer, pack, w, bias, &ins)?,
                            None => eval_layer(
                                layer,
                                Some(LayerParams {
                                    w,
                                    bias,
                                    grid: quant_params(
                                        bits,
                                        plan.arch.act_scales[i],
                                        plan.arch.act_signed[i],
                                    ),
                                }),
                                &ins,
                            )?,
                        };
                        gemm_s += t0.elapsed().as_secs_f64();
                        y
                    }
                    None => eval_layer(layer, None, &ins)?,
                }
            };
            scratch[li + 1] = Some(out);
            computed += 1;
        }
        let last = scratch[n_slots - 1]
            .as_ref()
            .or(self.feats[n_slots - 1].as_ref())
            .expect("final slot is computed or cached");
        let classes = last.data.len() / shard.rows;
        let correct = top1_correct(&last.data, classes, &shard.labels);
        let logits = if want_logits { last.data.clone() } else { Vec::new() };
        Ok(ShardOutcome { correct, computed, reused, gemm_s, logits })
    }
}
