//! Std-only long-lived worker pool with a work-stealing shard
//! scheduler. Shards — and their activation caches — live in per-shard
//! slots of a shared slab; workers claim slots through per-worker
//! atomic ticket counters, preferring their own round-robin slice (so
//! a shard's cache stays warm on the thread that evaluated it last)
//! and stealing from other workers' preference lists only once their
//! own is drained (`--sched steal`, the default). `--sched static`
//! stops after the worker's own list — exactly the pre-stealing
//! assignment. A query is a broadcast of one [`Job`] over per-worker
//! channels; the reduction sorts partials by shard index and sums
//! integer counts, so results are **bit-identical at every thread
//! count and every steal order** (`tests/exec_engine.rs`,
//! `tests/kernel_conformance.rs`).
//!
//! Every dispatch carries a sequence number and replies echo it, so a
//! late reply from an abandoned (failed) query can never be folded
//! into the next one; a bumped `current_seq` additionally tells a
//! worker still chewing on an abandoned job to stop claiming slots.
//! The same channels also carry [`PackBatch`] messages — the engine
//! fans dirty-layer pack builds out across the idle pool before the
//! eval broadcast. No external dependencies — `std::sync::mpsc` +
//! `std::thread`, matching the crate's vendoring policy.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::runtime::native::{pack_layer, quant_params, PackedLayer};
use crate::runtime::SchedKind;
use crate::tensor::Tensor;

use super::actcache::ActCache;
use super::{Plan, Shard};

/// One candidate layer-config riding along a broadcast job: the
/// proposal's weights/bias/precision for a single prunable layer,
/// priced against the job's shared activation-checkpoint prefix
/// without touching any cached state.
pub(crate) struct CandJob {
    /// prunable index of the proposed layer
    pub pi: usize,
    /// proposed weight tensor for that layer
    pub w: Arc<Tensor>,
    /// proposed bias tensor for that layer
    pub b: Arc<Tensor>,
    /// proposed activation precision for that layer
    pub bits: f32,
    /// int-kernel pack of the proposal (built once engine-side);
    /// `None` = f32 path, exactly like a missing entry in `Job::packs`
    pub pack: Option<Arc<PackedLayer>>,
}

/// Fault-injection hooks for the pool's own regression tests: delay or
/// panic while evaluating a specific shard. Always present (two
/// `Option`s per job, set only from `#[cfg(test)]` code) so production
/// and test jobs build the same struct.
#[derive(Default)]
pub(crate) struct TestHooks {
    /// panic while evaluating this shard index (exercises the
    /// worker-panic → error-reply conversion and the fail-fast fold)
    pub panic_on_shard: Option<usize>,
    /// sleep this many milliseconds before evaluating this shard index
    /// (holds a worker mid-job so late replies can be provoked)
    pub delay_ms_on_shard: Option<(usize, u64)>,
}

/// One broadcast evaluation request: the engine's staged per-layer
/// weight snapshot (and, on the int kernel, the per-layer packs) plus
/// the dirty set for this query.
pub(crate) struct Job {
    /// staged weight tensors, prunable order
    pub w: Vec<Arc<Tensor>>,
    /// staged bias tensors, prunable order
    pub b: Vec<Arc<Tensor>>,
    /// int-kernel packed layers, prunable order — empty on the f32
    /// kernel; a `None` entry is a per-layer f32 fallback (degenerate
    /// grid)
    pub packs: Vec<Option<Arc<PackedLayer>>>,
    /// activation precisions, prunable order
    pub bits: Vec<f32>,
    /// per graph layer: invalidated since the last query
    pub dirty_layers: Vec<bool>,
    /// collect final-layer logits? accuracy queries (the RL hot path)
    /// leave this false and skip the per-example copy entirely
    pub want_logits: bool,
    /// candidate layer-configs priced against the shared cache prefix
    /// after the base pass (batched oracle mode); empty on plain
    /// queries
    pub cands: Vec<CandJob>,
    /// test-only fault injection (defaulted everywhere else)
    pub hooks: TestHooks,
}

/// One pack-build task the engine fans out before an int-kernel eval:
/// exactly the inputs of the serial restage's `pack_layer` call.
pub(crate) struct PackTask {
    /// prunable index of the layer to pack
    pub pi: usize,
    /// the staged (or candidate) weight tensor to pack
    pub w: Arc<Tensor>,
    /// activation precision selecting the dequant grid
    pub bits: f32,
}

/// A batch of pack tasks claimed via an atomic cursor by workers *and*
/// the engine thread; each claimed task sends its `(index, pack)`
/// result exactly once over `out`.
pub(crate) struct PackBatch {
    tasks: Vec<PackTask>,
    cursor: AtomicUsize,
    out: Sender<(usize, Result<Option<Arc<PackedLayer>>>)>,
}

impl PackBatch {
    /// Claim and build tasks until the cursor is exhausted. A panic
    /// inside `pack_layer` becomes an error result so the collector
    /// never starves.
    fn drain(&self, plan: &Plan) {
        loop {
            let i = self.cursor.fetch_add(1, Ordering::SeqCst);
            if i >= self.tasks.len() {
                return;
            }
            let t = &self.tasks[i];
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                build_pack(plan, t)
            }))
            .map_err(|_| anyhow!("pack worker panicked"));
            // send fails only when the engine already gave up on the
            // batch and dropped the receiver — nothing left to do
            if self.out.send((i, result)).is_err() {
                return;
            }
        }
    }
}

/// The one authoritative pack-build recipe, shared by the engine's
/// serial walk and the parallel pack fan-out: grid from the plan's
/// calibration constants, pack from [`pack_layer`].
fn build_pack(plan: &Plan, t: &PackTask) -> Option<Arc<PackedLayer>> {
    let li = plan.layer_of_prunable[t.pi];
    let layer = &plan.arch.layers[li];
    let grid = quant_params(t.bits, plan.arch.act_scales[t.pi], plan.arch.act_signed[t.pi]);
    pack_layer(layer, &t.w, grid).map(Arc::new)
}

/// One worker's fold over the shards it claimed.
#[derive(Default)]
pub(crate) struct Partial {
    /// correctly classified rows
    pub correct: usize,
    /// graph layers recomputed
    pub computed: u64,
    /// graph layers served from cache
    pub reused: u64,
    /// seconds spent in prunable-layer (GEMM) evaluation
    pub gemm_s: f64,
    /// `(shard index, final-layer logits)` per claimed shard
    pub shards: Vec<(usize, Vec<f32>)>,
    /// per-candidate correct counts, `Job::cands` order
    pub cand_correct: Vec<usize>,
    /// `(shard index, per-candidate final-layer logits)` per claimed
    /// shard — populated only when the job wants logits and carries
    /// candidates
    pub cand_shards: Vec<(usize, Vec<Vec<f32>>)>,
    /// shards this worker claimed for this job
    pub shards_done: usize,
    /// shards claimed from another worker's preference list
    pub stolen: u64,
}

struct Reply {
    /// dispatch sequence number this reply answers — the fold discards
    /// replies from abandoned earlier queries
    seq: u64,
    result: Result<Partial>,
}

/// The reduction of every worker's [`Partial`] for one query.
pub(crate) struct Aggregate {
    /// correctly classified rows over all shards
    pub correct: usize,
    /// graph layers recomputed over all shards
    pub computed: u64,
    /// graph layers served from cache over all shards
    pub reused: u64,
    /// CPU-seconds in prunable-layer (GEMM) evaluation over all workers
    pub gemm_s: f64,
    /// final-layer logits concatenated in example order
    pub logits: Vec<f32>,
    /// per-candidate correct counts over all shards, `Job::cands` order
    pub cand_correct: Vec<usize>,
    /// per-candidate final-layer logits concatenated in example order
    pub cand_logits: Vec<Vec<f32>>,
    /// shards claimed from another worker's preference list (total)
    pub stolen: u64,
    /// shards evaluated per worker reply (unordered) — the imbalance
    /// telemetry input
    pub worker_shards: Vec<usize>,
}

/// One slab slot: a shard and its (lazily primed) activation cache.
/// The mutex makes a claim exclusive; under the static scheduler each
/// slot is only ever touched by its preferred worker, so the lock is
/// uncontended.
struct Slot {
    gi: usize,
    shard: Shard,
    cache: Option<ActCache>,
}

/// One dispatched query: the job plus this dispatch's claim state.
/// Cursors are allocated fresh per dispatch, so an abandoned query's
/// half-consumed cursors can never leak into the next one.
struct Dispatch {
    seq: u64,
    /// one ticket counter per worker preference list
    cursors: Vec<AtomicUsize>,
    job: Arc<Job>,
}

enum Msg {
    Eval(Arc<Dispatch>),
    Pack(Arc<PackBatch>),
}

/// The pool: job senders, the shared reply channel, the shard slab and
/// join handles.
pub(crate) struct Pool {
    txs: Vec<Sender<Msg>>,
    rx: Receiver<Reply>,
    handles: Vec<JoinHandle<()>>,
    /// monotone dispatch counter; the next dispatch gets `seq + 1`
    seq: AtomicU64,
    /// the seq workers must match to keep claiming slots (stale-abort)
    current_seq: Arc<AtomicU64>,
}

impl Pool {
    /// Spawn one worker per shard set. `sets[w]` becomes worker `w`'s
    /// preference list (the static scheduler's exact ownership);
    /// shards live in the shared slab and caches are primed on first
    /// claim.
    pub fn spawn(plan: Arc<Plan>, sets: Vec<Vec<(usize, Shard)>>, sched: SchedKind) -> Pool {
        let n_workers = sets.len();
        let mut slots = Vec::new();
        let mut prefs: Vec<Vec<usize>> = Vec::with_capacity(n_workers);
        for set in sets {
            let mut list = Vec::with_capacity(set.len());
            for (gi, shard) in set {
                list.push(slots.len());
                slots.push(Mutex::new(Slot { gi, shard, cache: None }));
            }
            prefs.push(list);
        }
        let slab: Arc<Vec<Mutex<Slot>>> = Arc::new(slots);
        let prefs: Arc<Vec<Vec<usize>>> = Arc::new(prefs);
        let current_seq = Arc::new(AtomicU64::new(0));
        let (rtx, rx) = channel();
        let mut txs = Vec::with_capacity(n_workers);
        let mut handles = Vec::with_capacity(n_workers);
        for wi in 0..n_workers {
            let (tx, mrx) = channel::<Msg>();
            let plan = plan.clone();
            let slab = slab.clone();
            let prefs = prefs.clone();
            let cur = current_seq.clone();
            let rtx = rtx.clone();
            handles.push(std::thread::spawn(move || {
                worker_loop(wi, plan, slab, prefs, sched, cur, mrx, rtx)
            }));
            txs.push(tx);
        }
        Pool { txs, rx, handles, seq: AtomicU64::new(0), current_seq }
    }

    /// Broadcast one job to every worker and fold the partial results.
    /// The fold counts exactly one reply per worker *for this
    /// dispatch's sequence number*; late replies from an abandoned
    /// earlier query are discarded, and the first error fails the
    /// query immediately (the engine marks everything dirty, so any
    /// cache state the stragglers still write is recomputed next time).
    pub fn run(&self, job: Arc<Job>) -> Result<Aggregate> {
        let seq = self.seq.fetch_add(1, Ordering::SeqCst) + 1;
        // publish before broadcasting: a worker still on an abandoned
        // job observes the bump and stops claiming slots
        self.current_seq.store(seq, Ordering::SeqCst);
        let cursors = (0..self.txs.len()).map(|_| AtomicUsize::new(0)).collect();
        let d = Arc::new(Dispatch { seq, cursors, job: job.clone() });
        for tx in &self.txs {
            tx.send(Msg::Eval(d.clone()))
                .map_err(|_| anyhow!("evaluation worker channel closed"))?;
        }
        let mut correct = 0usize;
        let mut computed = 0u64;
        let mut reused = 0u64;
        let mut gemm_s = 0.0f64;
        let mut stolen = 0u64;
        let mut worker_shards = Vec::with_capacity(self.txs.len());
        let mut parts: Vec<(usize, Vec<f32>)> = Vec::new();
        let mut cand_correct = vec![0usize; job.cands.len()];
        let mut cand_parts: Vec<(usize, Vec<Vec<f32>>)> = Vec::new();
        while worker_shards.len() < self.txs.len() {
            match self.rx.recv() {
                Ok(reply) => {
                    if reply.seq != seq {
                        continue; // late reply from an abandoned query
                    }
                    match reply.result {
                        Ok(p) => {
                            correct += p.correct;
                            computed += p.computed;
                            reused += p.reused;
                            gemm_s += p.gemm_s;
                            stolen += p.stolen;
                            worker_shards.push(p.shards_done);
                            parts.extend(p.shards);
                            for (a, &b) in cand_correct.iter_mut().zip(&p.cand_correct) {
                                *a += b;
                            }
                            cand_parts.extend(p.cand_shards);
                        }
                        // fail fast: stragglers of this query abort at
                        // the next seq bump and their replies are
                        // discarded by the seq check above
                        Err(e) => return Err(e),
                    }
                }
                Err(_) => {
                    return Err(anyhow!("evaluation worker terminated unexpectedly"));
                }
            }
        }
        parts.sort_by_key(|(gi, _)| *gi);
        let logits = parts.into_iter().flat_map(|(_, l)| l).collect();
        cand_parts.sort_by_key(|(gi, _)| *gi);
        let mut cand_logits: Vec<Vec<f32>> = vec![Vec::new(); job.cands.len()];
        for (_, per_cand) in cand_parts {
            for (ci, l) in per_cand.into_iter().enumerate() {
                cand_logits[ci].extend(l);
            }
        }
        Ok(Aggregate {
            correct,
            computed,
            reused,
            gemm_s,
            logits,
            cand_correct,
            cand_logits,
            stolen,
            worker_shards,
        })
    }

    /// Build a batch of packs on the pool, the engine thread included:
    /// fan the batch out, claim tasks alongside the workers, then
    /// collect every task's result (indexed like `tasks`). Callers
    /// only use this while no eval query is in flight (the engine's
    /// state lock serializes both).
    pub fn pack_parallel(
        &self,
        plan: &Plan,
        tasks: Vec<PackTask>,
    ) -> Vec<Result<Option<Arc<PackedLayer>>>> {
        let n = tasks.len();
        let (otx, orx) = channel();
        let batch = Arc::new(PackBatch { tasks, cursor: AtomicUsize::new(0), out: otx });
        for tx in &self.txs {
            // a closed channel only means that worker is gone; the
            // engine's own drain below still covers every task
            let _ = tx.send(Msg::Pack(batch.clone()));
        }
        batch.drain(plan);
        let mut out: Vec<Option<Result<Option<Arc<PackedLayer>>>>> =
            (0..n).map(|_| None).collect();
        for _ in 0..n {
            match orx.recv() {
                Ok((i, r)) => out[i] = Some(r),
                Err(_) => break,
            }
        }
        out.into_iter()
            .map(|o| o.unwrap_or_else(|| Err(anyhow!("pack worker terminated unexpectedly"))))
            .collect()
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        // closing the job channels ends every worker's recv loop
        self.txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Evaluate one claimed slot (priming its cache on first claim) and
/// fold the outcome into the worker's partial.
fn eval_slot(plan: &Plan, slot: &mut Slot, job: &Job, p: &mut Partial) -> Result<()> {
    let Slot { gi, shard, cache } = slot;
    let gi = *gi;
    let _span = crate::telemetry::span("exec.shard").shard(gi);
    if let Some((dgi, ms)) = job.hooks.delay_ms_on_shard {
        if dgi == gi {
            std::thread::sleep(std::time::Duration::from_millis(ms));
        }
    }
    if job.hooks.panic_on_shard == Some(gi) {
        panic!("injected test panic on shard {gi}");
    }
    if cache.is_none() {
        *cache = Some(ActCache::primed(plan, shard));
    }
    let cache = cache.as_mut().expect("cache primed above");
    let out = cache.eval(plan, shard, job)?;
    p.correct += out.correct;
    p.computed += out.computed;
    p.reused += out.reused;
    p.gemm_s += out.gemm_s;
    if job.want_logits {
        p.shards.push((gi, out.logits));
    }
    // batched oracle: the base pass above synced this shard's
    // checkpoint cache, so every candidate reuses the shared prefix
    // and recomputes only its own suffix (scratch slots — the cache
    // itself is never touched)
    if !job.cands.is_empty() {
        let mut per_cand: Vec<Vec<f32>> = Vec::new();
        for (ci, cand) in job.cands.iter().enumerate() {
            let co = cache.eval_candidate(plan, shard, job, cand, job.want_logits)?;
            p.cand_correct[ci] += co.correct;
            p.computed += co.computed;
            p.reused += co.reused;
            p.gemm_s += co.gemm_s;
            if job.want_logits {
                per_cand.push(co.logits);
            }
        }
        if job.want_logits {
            p.cand_shards.push((gi, per_cand));
        }
    }
    Ok(())
}

/// Claim and evaluate slots for one dispatch: the worker's own
/// preference list first (warm caches), then — under the stealing
/// scheduler — the other workers' lists in circular order. The
/// stale-abort check runs before each claim *and again under the slot
/// lock*: the engine bumps `current_seq` before broadcasting a new
/// dispatch, and any fresh claimer must acquire the slot lock after
/// that bump is visible, so a stale worker can never overwrite
/// fresh-query cache state.
fn eval_claimed(
    wi: usize,
    plan: &Plan,
    slab: &[Mutex<Slot>],
    prefs: &[Vec<usize>],
    sched: SchedKind,
    current_seq: &AtomicU64,
    d: &Dispatch,
) -> Result<Partial> {
    let job = &*d.job;
    let mut p = Partial {
        cand_correct: vec![0usize; job.cands.len()],
        ..Partial::default()
    };
    let n_workers = prefs.len();
    let lists = match sched {
        SchedKind::Static => 1,
        SchedKind::Steal => n_workers,
    };
    'outer: for k in 0..lists {
        let src = (wi + k) % n_workers;
        loop {
            let i = d.cursors[src].fetch_add(1, Ordering::SeqCst);
            if i >= prefs[src].len() {
                break;
            }
            if current_seq.load(Ordering::SeqCst) != d.seq {
                break 'outer; // the engine moved on — stop claiming
            }
            let mut slot = slab[prefs[src][i]].lock().unwrap_or_else(|e| e.into_inner());
            if current_seq.load(Ordering::SeqCst) != d.seq {
                break 'outer; // re-check under the lock (see above)
            }
            eval_slot(plan, &mut slot, job, &mut p)?;
            p.shards_done += 1;
            if src != wi {
                p.stolen += 1;
            }
        }
    }
    // gauges, not counts: a zero is part of the balance picture, and
    // emitting unconditionally keeps the trace schema independent of
    // whether this particular query happened to steal
    crate::telemetry::gauge("exec.steal", p.stolen as f64);
    crate::telemetry::gauge("exec.worker_shards", p.shards_done as f64);
    Ok(p)
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    wi: usize,
    plan: Arc<Plan>,
    slab: Arc<Vec<Mutex<Slot>>>,
    prefs: Arc<Vec<Vec<usize>>>,
    sched: SchedKind,
    current_seq: Arc<AtomicU64>,
    msgs: Receiver<Msg>,
    replies: Sender<Reply>,
) {
    crate::telemetry::set_thread_tag(&format!("worker{wi:02}"));
    while let Ok(msg) = msgs.recv() {
        match msg {
            Msg::Pack(batch) => {
                batch.drain(&plan);
            }
            Msg::Eval(d) => {
                // a panic must not starve the engine's reply count —
                // convert it into an error reply instead
                let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    eval_claimed(wi, &plan, &slab, &prefs, sched, &current_seq, &d)
                }))
                .unwrap_or_else(|_| Err(anyhow!("evaluation worker panicked")));
                // flush before replying: once the engine has every
                // reply it may drain the sink, and this thread's spans
                // must already be there
                crate::telemetry::flush_thread();
                if replies.send(Reply { seq: d.seq, result }).is_err() {
                    return; // engine dropped — shut down
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelArch;

    /// Minimal 2-layer graph (gap → fc) with one prunable layer: small
    /// enough that the pool tests can hand-build jobs and shards.
    const POOL_ARCH: &str = r#"{
      "name": "pooltoy", "dataset": "synth", "input": [2, 2, 1], "classes": 2,
      "batch": 4,
      "layers": [
        {"name": "gap", "op": "gap", "inputs": ["input"], "in_shape": [2,2,1],
         "out_shape": [1]},
        {"name": "f1", "op": "fc", "inputs": ["gap"], "relu": false,
         "in_shape": [1], "out_shape": [2], "in_ch": 1, "out_ch": 2}
      ],
      "prunable": ["f1"],
      "dep_groups": [],
      "act_scales": [0.5],
      "act_signed": [true],
      "acc_int8": 0.0, "n_params": 0
    }"#;

    fn pool_plan() -> Arc<Plan> {
        let arch = ModelArch::from_json(&crate::io::json::parse(POOL_ARCH).unwrap()).unwrap();
        Arc::new(Plan::build(&arch, [2, 2, 1]).unwrap())
    }

    /// Two 2-row shards with asymmetric labels, so swapping the fc
    /// weight sign flips which shard scores correct rows.
    fn pool_sets() -> Vec<Vec<(usize, Shard)>> {
        let mk = |base: f32, labels: Vec<i64>| Shard {
            rows: 2,
            images: (0..2 * 4).map(|i| base + 0.1 * i as f32).collect(),
            labels,
        };
        vec![
            vec![(0, mk(1.0, vec![1, 1]))],
            vec![(1, mk(2.0, vec![0, 1]))],
        ]
    }

    /// A job whose fc weights make class `cls` the argmax everywhere
    /// (positive gap output times a signed weight pair).
    fn pool_job(cls: usize, hooks: TestHooks) -> Arc<Job> {
        let wdata = if cls == 0 { vec![1.0f32, -1.0] } else { vec![-1.0f32, 1.0] };
        Arc::new(Job {
            w: vec![Arc::new(Tensor::new(vec![1, 2], wdata))],
            b: vec![Arc::new(Tensor::new(vec![2], vec![0.0, 0.0]))],
            packs: vec![None],
            bits: vec![8.0],
            dirty_layers: vec![true, true],
            want_logits: true,
            cands: Vec::new(),
            hooks,
        })
    }

    #[test]
    fn steal_and_static_agree_bitwise() {
        for sched in [SchedKind::Static, SchedKind::Steal] {
            let pool = Pool::spawn(pool_plan(), pool_sets(), sched);
            let a = pool.run(pool_job(0, TestHooks::default())).unwrap();
            assert_eq!(a.correct, 1, "class-0 weights vs labels [1,1] + [0,1]");
            let b = pool.run(pool_job(1, TestHooks::default())).unwrap();
            assert_eq!(b.correct, 3, "class-1 weights vs labels [1,1] + [0,1]");
            assert_eq!(b.worker_shards.iter().sum::<usize>(), 2);
        }
        // bitwise parity of the logits across schedulers
        let ps = Pool::spawn(pool_plan(), pool_sets(), SchedKind::Static);
        let pw = Pool::spawn(pool_plan(), pool_sets(), SchedKind::Steal);
        let ls = ps.run(pool_job(1, TestHooks::default())).unwrap().logits;
        let lw = pw.run(pool_job(1, TestHooks::default())).unwrap().logits;
        assert_eq!(
            ls.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            lw.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    /// Stealing must actually happen when a worker stalls: worker 1
    /// owns no shards, so any shard it evaluates is by definition a
    /// steal, and the stolen/worker_shards accounting must record it.
    #[test]
    fn worker_with_empty_preference_list_steals_its_work() {
        // worker 1 owns nothing, so every shard it evaluates is by
        // definition stolen; holding shard 0's claimer asleep for
        // 200 ms guarantees worker 1 wakes in time to claim at least
        // one of the remaining tickets, whatever the interleaving
        let mk = |base: f32, labels: Vec<i64>| Shard {
            rows: 2,
            images: (0..2 * 4).map(|i| base + 0.1 * i as f32).collect(),
            labels,
        };
        let sets = vec![
            vec![
                (0, mk(1.0, vec![1, 1])),
                (1, mk(2.0, vec![0, 1])),
                (2, mk(3.0, vec![1, 0])),
            ],
            vec![],
        ];
        let pool = Pool::spawn(pool_plan(), sets, SchedKind::Steal);
        let agg = pool
            .run(pool_job(
                1,
                TestHooks { panic_on_shard: None, delay_ms_on_shard: Some((0, 200)) },
            ))
            .unwrap();
        assert_eq!(agg.correct, 4, "class-1 weights vs labels [1,1]+[0,1]+[1,0]");
        assert_eq!(agg.worker_shards.iter().sum::<usize>(), 3);
        assert!(agg.stolen >= 1, "idle worker never claimed off-list work");
    }

    /// Regression for the reply-correlation bug: a worker still
    /// *processing* a failed job replies late, and that reply must not
    /// be folded into the next query. Job A panics on shard 0 (fails
    /// the query fast) while shard 1's worker is held mid-job; job B
    /// then runs immediately and must see only its own replies.
    #[test]
    fn late_reply_from_failed_job_is_discarded() {
        let pool = Pool::spawn(pool_plan(), pool_sets(), SchedKind::Steal);
        let job_a = pool_job(
            0,
            TestHooks { panic_on_shard: Some(0), delay_ms_on_shard: Some((1, 200)) },
        );
        let err = pool.run(job_a).expect_err("injected panic must fail the query");
        assert!(err.to_string().contains("panicked"), "unexpected error: {err}");
        // worker 1 is still asleep inside job A; its late Ok reply
        // lands during job B's fold and must be discarded by seq
        let agg = pool.run(pool_job(1, TestHooks::default())).unwrap();
        let fresh = Pool::spawn(pool_plan(), pool_sets(), SchedKind::Steal)
            .run(pool_job(1, TestHooks::default()))
            .unwrap();
        assert_eq!(agg.correct, fresh.correct);
        assert_eq!(
            agg.logits.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            fresh.logits.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        // and the pool stays healthy for further queries
        let again = pool.run(pool_job(0, TestHooks::default())).unwrap();
        assert_eq!(again.correct, 1);
    }

    #[test]
    fn pack_parallel_builds_every_task() {
        let plan = pool_plan();
        let pool = Pool::spawn(plan.clone(), pool_sets(), SchedKind::Steal);
        let w = Arc::new(Tensor::new(vec![1, 2], vec![0.5f32, -0.5]));
        let tasks: Vec<PackTask> = (0..5)
            .map(|k| PackTask { pi: 0, w: w.clone(), bits: 2.0 + k as f32 })
            .collect();
        let results = pool.pack_parallel(&plan, tasks);
        assert_eq!(results.len(), 5);
        for (k, r) in results.into_iter().enumerate() {
            let built = r.unwrap();
            // parity with the serial recipe, task by task
            let serial = build_pack(
                &plan,
                &PackTask { pi: 0, w: w.clone(), bits: 2.0 + k as f32 },
            );
            assert_eq!(built.is_some(), serial.is_some());
        }
    }
}
