//! Std-only long-lived worker pool: one thread per shard set, each
//! owning its shards' activation caches for the lifetime of the
//! engine. A query is a broadcast of one [`Job`] (staged weights +
//! dirty layers) over per-worker channels; the reduction sums the
//! per-shard `top1_correct` counts and cache statistics. No external
//! dependencies — `std::sync::mpsc` + `std::thread`, matching the
//! crate's vendoring policy.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::runtime::native::PackedLayer;
use crate::tensor::Tensor;

use super::actcache::ActCache;
use super::{Plan, Shard};

/// One candidate layer-config riding along a broadcast job: the
/// proposal's weights/bias/precision for a single prunable layer,
/// priced against the job's shared activation-checkpoint prefix
/// without touching any cached state.
pub(crate) struct CandJob {
    /// prunable index of the proposed layer
    pub pi: usize,
    /// proposed weight tensor for that layer
    pub w: Arc<Tensor>,
    /// proposed bias tensor for that layer
    pub b: Arc<Tensor>,
    /// proposed activation precision for that layer
    pub bits: f32,
    /// int-kernel pack of the proposal (built once engine-side);
    /// `None` = f32 path, exactly like a missing entry in `Job::packs`
    pub pack: Option<Arc<PackedLayer>>,
}

/// One broadcast evaluation request: the engine's staged per-layer
/// weight snapshot (and, on the int kernel, the per-layer packs) plus
/// the dirty set for this query.
pub(crate) struct Job {
    /// staged weight tensors, prunable order
    pub w: Vec<Arc<Tensor>>,
    /// staged bias tensors, prunable order
    pub b: Vec<Arc<Tensor>>,
    /// int-kernel packed layers, prunable order — empty on the f32
    /// kernel; a `None` entry is a per-layer f32 fallback (degenerate
    /// grid)
    pub packs: Vec<Option<Arc<PackedLayer>>>,
    /// activation precisions, prunable order
    pub bits: Vec<f32>,
    /// per graph layer: invalidated since the last query
    pub dirty_layers: Vec<bool>,
    /// collect final-layer logits? accuracy queries (the RL hot path)
    /// leave this false and skip the per-example copy entirely
    pub want_logits: bool,
    /// candidate layer-configs priced against the shared cache prefix
    /// after the base pass (batched oracle mode); empty on plain
    /// queries
    pub cands: Vec<CandJob>,
}

/// One worker's fold over its shards.
#[derive(Default)]
pub(crate) struct Partial {
    /// correctly classified rows
    pub correct: usize,
    /// graph layers recomputed
    pub computed: u64,
    /// graph layers served from cache
    pub reused: u64,
    /// seconds spent in prunable-layer (GEMM) evaluation
    pub gemm_s: f64,
    /// `(shard index, final-layer logits)` per owned shard
    pub shards: Vec<(usize, Vec<f32>)>,
    /// per-candidate correct counts, `Job::cands` order
    pub cand_correct: Vec<usize>,
    /// `(shard index, per-candidate final-layer logits)` per owned
    /// shard — populated only when the job wants logits and carries
    /// candidates
    pub cand_shards: Vec<(usize, Vec<Vec<f32>>)>,
}

struct Reply {
    result: Result<Partial>,
}

/// The reduction of every worker's [`Partial`] for one query.
pub(crate) struct Aggregate {
    /// correctly classified rows over all shards
    pub correct: usize,
    /// graph layers recomputed over all shards
    pub computed: u64,
    /// graph layers served from cache over all shards
    pub reused: u64,
    /// CPU-seconds in prunable-layer (GEMM) evaluation over all workers
    pub gemm_s: f64,
    /// final-layer logits concatenated in example order
    pub logits: Vec<f32>,
    /// per-candidate correct counts over all shards, `Job::cands` order
    pub cand_correct: Vec<usize>,
    /// per-candidate final-layer logits concatenated in example order
    pub cand_logits: Vec<Vec<f32>>,
}

/// The pool: job senders + the shared reply channel + join handles.
pub(crate) struct Pool {
    txs: Vec<Sender<Arc<Job>>>,
    rx: Receiver<Reply>,
    handles: Vec<JoinHandle<()>>,
}

impl Pool {
    /// Spawn one worker per shard set. Workers build their caches once
    /// and then serve queries until the pool is dropped.
    pub fn spawn(plan: Arc<Plan>, sets: Vec<Vec<(usize, Shard)>>) -> Pool {
        let (rtx, rx) = channel();
        let mut txs = Vec::with_capacity(sets.len());
        let mut handles = Vec::with_capacity(sets.len());
        for (wi, set) in sets.into_iter().enumerate() {
            let (tx, jrx) = channel::<Arc<Job>>();
            let plan = plan.clone();
            let rtx = rtx.clone();
            handles.push(std::thread::spawn(move || worker_loop(wi, plan, set, jrx, rtx)));
            txs.push(tx);
        }
        Pool { txs, rx, handles }
    }

    /// Broadcast one job to every worker and fold the partial results.
    /// Exactly one reply per worker is consumed, so queries cannot
    /// interleave (the engine additionally serializes callers).
    pub fn run(&self, job: Arc<Job>) -> Result<Aggregate> {
        // drop any stale replies a previously failed dispatch left behind
        while self.rx.try_recv().is_ok() {}
        for tx in &self.txs {
            tx.send(job.clone())
                .map_err(|_| anyhow!("evaluation worker channel closed"))?;
        }
        let mut correct = 0usize;
        let mut computed = 0u64;
        let mut reused = 0u64;
        let mut gemm_s = 0.0f64;
        let mut parts: Vec<(usize, Vec<f32>)> = Vec::new();
        let mut cand_correct = vec![0usize; job.cands.len()];
        let mut cand_parts: Vec<(usize, Vec<Vec<f32>>)> = Vec::new();
        let mut first_err: Option<anyhow::Error> = None;
        for _ in 0..self.txs.len() {
            match self.rx.recv() {
                Ok(reply) => match reply.result {
                    Ok(p) => {
                        correct += p.correct;
                        computed += p.computed;
                        reused += p.reused;
                        gemm_s += p.gemm_s;
                        parts.extend(p.shards);
                        for (a, &b) in cand_correct.iter_mut().zip(&p.cand_correct) {
                            *a += b;
                        }
                        cand_parts.extend(p.cand_shards);
                    }
                    Err(e) => {
                        if first_err.is_none() {
                            first_err = Some(e);
                        }
                    }
                },
                Err(_) => {
                    if first_err.is_none() {
                        first_err = Some(anyhow!("evaluation worker terminated unexpectedly"));
                    }
                    break;
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        parts.sort_by_key(|(gi, _)| *gi);
        let logits = parts.into_iter().flat_map(|(_, l)| l).collect();
        cand_parts.sort_by_key(|(gi, _)| *gi);
        let mut cand_logits: Vec<Vec<f32>> = vec![Vec::new(); job.cands.len()];
        for (_, per_cand) in cand_parts {
            for (ci, l) in per_cand.into_iter().enumerate() {
                cand_logits[ci].extend(l);
            }
        }
        Ok(Aggregate { correct, computed, reused, gemm_s, logits, cand_correct, cand_logits })
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        // closing the job channels ends every worker's recv loop
        self.txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Fold one job over a worker's shards, updating the caches in place.
fn eval_set(
    plan: &Plan,
    set: &[(usize, Shard)],
    caches: &mut [ActCache],
    job: &Job,
) -> Result<Partial> {
    let mut p = Partial {
        cand_correct: vec![0usize; job.cands.len()],
        ..Partial::default()
    };
    for ((gi, shard), cache) in set.iter().zip(caches.iter_mut()) {
        let _span = crate::telemetry::span("exec.shard").shard(*gi);
        let out = cache.eval(plan, shard, job)?;
        p.correct += out.correct;
        p.computed += out.computed;
        p.reused += out.reused;
        p.gemm_s += out.gemm_s;
        if job.want_logits {
            p.shards.push((*gi, out.logits));
        }
        // batched oracle: the base pass above synced this shard's
        // checkpoint cache, so every candidate reuses the shared
        // prefix and recomputes only its own suffix (scratch slots —
        // the cache itself is never touched)
        if !job.cands.is_empty() {
            let mut per_cand: Vec<Vec<f32>> = Vec::new();
            for (ci, cand) in job.cands.iter().enumerate() {
                let co = cache.eval_candidate(plan, shard, job, cand, job.want_logits)?;
                p.cand_correct[ci] += co.correct;
                p.computed += co.computed;
                p.reused += co.reused;
                p.gemm_s += co.gemm_s;
                if job.want_logits {
                    per_cand.push(co.logits);
                }
            }
            if job.want_logits {
                p.cand_shards.push((*gi, per_cand));
            }
        }
    }
    Ok(p)
}

fn worker_loop(
    wi: usize,
    plan: Arc<Plan>,
    mut set: Vec<(usize, Shard)>,
    jobs: Receiver<Arc<Job>>,
    replies: Sender<Reply>,
) {
    crate::telemetry::set_thread_tag(&format!("worker{wi:02}"));
    let mut caches: Vec<ActCache> =
        set.iter_mut().map(|(_, s)| ActCache::primed(&plan, s)).collect();
    while let Ok(job) = jobs.recv() {
        // a panic must not starve the engine's reply count — convert it
        // into an error reply instead
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            eval_set(&plan, &set, &mut caches, &job)
        }))
        .unwrap_or_else(|_| Err(anyhow!("evaluation worker panicked")));
        // flush before replying: once the engine has every reply it may
        // drain the sink, and this thread's spans must already be there
        crate::telemetry::flush_thread();
        if replies.send(Reply { result }).is_err() {
            return; // engine dropped — shut down
        }
    }
}
