//! Std-only long-lived worker pool: one thread per shard set, each
//! owning its shards' activation caches for the lifetime of the
//! engine. A query is a broadcast of one [`Job`] (staged weights +
//! dirty layers) over per-worker channels; the reduction sums the
//! per-shard `top1_correct` counts and cache statistics. No external
//! dependencies — `std::sync::mpsc` + `std::thread`, matching the
//! crate's vendoring policy.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::runtime::native::PackedLayer;
use crate::tensor::Tensor;

use super::actcache::ActCache;
use super::{Plan, Shard};

/// One broadcast evaluation request: the engine's staged per-layer
/// weight snapshot (and, on the int kernel, the per-layer packs) plus
/// the dirty set for this query.
pub(crate) struct Job {
    /// staged weight tensors, prunable order
    pub w: Vec<Arc<Tensor>>,
    /// staged bias tensors, prunable order
    pub b: Vec<Arc<Tensor>>,
    /// int-kernel packed layers, prunable order — empty on the f32
    /// kernel; a `None` entry is a per-layer f32 fallback (degenerate
    /// grid)
    pub packs: Vec<Option<Arc<PackedLayer>>>,
    /// activation precisions, prunable order
    pub bits: Vec<f32>,
    /// per graph layer: invalidated since the last query
    pub dirty_layers: Vec<bool>,
    /// collect final-layer logits? accuracy queries (the RL hot path)
    /// leave this false and skip the per-example copy entirely
    pub want_logits: bool,
}

/// One worker's fold over its shards.
#[derive(Default)]
pub(crate) struct Partial {
    /// correctly classified rows
    pub correct: usize,
    /// graph layers recomputed
    pub computed: u64,
    /// graph layers served from cache
    pub reused: u64,
    /// seconds spent in prunable-layer (GEMM) evaluation
    pub gemm_s: f64,
    /// `(shard index, final-layer logits)` per owned shard
    pub shards: Vec<(usize, Vec<f32>)>,
}

struct Reply {
    result: Result<Partial>,
}

/// The reduction of every worker's [`Partial`] for one query.
pub(crate) struct Aggregate {
    /// correctly classified rows over all shards
    pub correct: usize,
    /// graph layers recomputed over all shards
    pub computed: u64,
    /// graph layers served from cache over all shards
    pub reused: u64,
    /// CPU-seconds in prunable-layer (GEMM) evaluation over all workers
    pub gemm_s: f64,
    /// final-layer logits concatenated in example order
    pub logits: Vec<f32>,
}

/// The pool: job senders + the shared reply channel + join handles.
pub(crate) struct Pool {
    txs: Vec<Sender<Arc<Job>>>,
    rx: Receiver<Reply>,
    handles: Vec<JoinHandle<()>>,
}

impl Pool {
    /// Spawn one worker per shard set. Workers build their caches once
    /// and then serve queries until the pool is dropped.
    pub fn spawn(plan: Arc<Plan>, sets: Vec<Vec<(usize, Shard)>>) -> Pool {
        let (rtx, rx) = channel();
        let mut txs = Vec::with_capacity(sets.len());
        let mut handles = Vec::with_capacity(sets.len());
        for set in sets {
            let (tx, jrx) = channel::<Arc<Job>>();
            let plan = plan.clone();
            let rtx = rtx.clone();
            handles.push(std::thread::spawn(move || worker_loop(plan, set, jrx, rtx)));
            txs.push(tx);
        }
        Pool { txs, rx, handles }
    }

    /// Broadcast one job to every worker and fold the partial results.
    /// Exactly one reply per worker is consumed, so queries cannot
    /// interleave (the engine additionally serializes callers).
    pub fn run(&self, job: Arc<Job>) -> Result<Aggregate> {
        // drop any stale replies a previously failed dispatch left behind
        while self.rx.try_recv().is_ok() {}
        for tx in &self.txs {
            tx.send(job.clone())
                .map_err(|_| anyhow!("evaluation worker channel closed"))?;
        }
        let mut correct = 0usize;
        let mut computed = 0u64;
        let mut reused = 0u64;
        let mut gemm_s = 0.0f64;
        let mut parts: Vec<(usize, Vec<f32>)> = Vec::new();
        let mut first_err: Option<anyhow::Error> = None;
        for _ in 0..self.txs.len() {
            match self.rx.recv() {
                Ok(reply) => match reply.result {
                    Ok(p) => {
                        correct += p.correct;
                        computed += p.computed;
                        reused += p.reused;
                        gemm_s += p.gemm_s;
                        parts.extend(p.shards);
                    }
                    Err(e) => {
                        if first_err.is_none() {
                            first_err = Some(e);
                        }
                    }
                },
                Err(_) => {
                    if first_err.is_none() {
                        first_err = Some(anyhow!("evaluation worker terminated unexpectedly"));
                    }
                    break;
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        parts.sort_by_key(|(gi, _)| *gi);
        let logits = parts.into_iter().flat_map(|(_, l)| l).collect();
        Ok(Aggregate { correct, computed, reused, gemm_s, logits })
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        // closing the job channels ends every worker's recv loop
        self.txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Fold one job over a worker's shards, updating the caches in place.
fn eval_set(
    plan: &Plan,
    set: &[(usize, Shard)],
    caches: &mut [ActCache],
    job: &Job,
) -> Result<Partial> {
    let mut p = Partial::default();
    for ((gi, shard), cache) in set.iter().zip(caches.iter_mut()) {
        let out = cache.eval(plan, shard, job)?;
        p.correct += out.correct;
        p.computed += out.computed;
        p.reused += out.reused;
        p.gemm_s += out.gemm_s;
        if job.want_logits {
            p.shards.push((*gi, out.logits));
        }
    }
    Ok(p)
}

fn worker_loop(
    plan: Arc<Plan>,
    mut set: Vec<(usize, Shard)>,
    jobs: Receiver<Arc<Job>>,
    replies: Sender<Reply>,
) {
    let mut caches: Vec<ActCache> =
        set.iter_mut().map(|(_, s)| ActCache::primed(&plan, s)).collect();
    while let Ok(job) = jobs.recv() {
        // a panic must not starve the engine's reply count — convert it
        // into an error reply instead
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            eval_set(&plan, &set, &mut caches, &job)
        }))
        .unwrap_or_else(|_| Err(anyhow!("evaluation worker panicked")));
        if replies.send(Reply { result }).is_err() {
            return; // engine dropped — shut down
        }
    }
}
