//! PJRT inference backend (`--features pjrt`): load AOT-compiled HLO
//! text, execute it through the XLA PJRT C API.
//!
//! Wraps the `xla` crate (`PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`).
//!
//! ## Why HLO *text* is the interchange format
//!
//! The exporter (`python/compile/aot.py`) lowers through StableHLO and
//! serialises the computation as HLO **text**, not a binary proto.
//! jax ≥ 0.5 emits protos with 64-bit instruction ids, which the
//! `xla_extension 0.5.1` proto deserialiser rejects outright; the HLO
//! text parser, by contrast, reassigns instruction ids while parsing,
//! so the same artifact loads across XLA revisions. Text is also
//! diffable and survives toolchain skew between the Python export
//! environment and this consumer — worth the larger files.
//!
//! [`PjrtBackend`] owns one compiled executable per model plus the
//! pre-marshalled image batches, and answers an accuracy query in a
//! single PJRT call per batch — compiled once, executed at every RL
//! step, Python never involved.
//!
//! Note: the default in-tree `xla` crate is a type-compatible stub
//! (rust/vendor/README.md) — this module compiles and its literal
//! tests run everywhere, but executing HLO needs a real PJRT binding.

use std::cell::RefCell;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{top1_correct, EvalData, InferenceBackend};
use crate::model::{ModelArch, Weights};

/// Thin wrapper over the PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Connect to the CPU PJRT plugin.
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime { client: xla::PjRtClient::cpu()? })
    }

    /// Platform name reported by the client (e.g. `cpu`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an HLO-text artifact into an executable.
    pub fn load_hlo(&self, path: &Path) -> Result<Executable> {
        let path_str = path.to_str().context("non-utf8 path")?;
        let proto = xla::HloModuleProto::from_text_file(path_str)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(Executable { exe })
    }
}

/// One compiled model graph.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute; unwraps the 1-tuple the exporter emits (return_tuple=True).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        let result = self.exe.execute::<xla::Literal>(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple1()?)
    }
}

/// Build an f32 literal of the given shape from a slice.
pub fn literal_f32(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("literal shape {shape:?} vs data len {}", data.len());
    }
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        shape,
        bytes,
    )?)
}

/// The PJRT accuracy oracle for one model.
///
/// Perf note (EXPERIMENTS.md §Perf): the RL loop changes exactly ONE
/// layer's weights per step, so the backend keeps the marshalled weight
/// literals in a per-layer cache; [`InferenceBackend::invalidate`]
/// marks a layer dirty and only dirty layers are re-marshalled on the
/// next accuracy call. Image batches are marshalled once at
/// construction.
pub struct PjrtBackend {
    /// the owning client — MUST outlive `exe` (the executable runs on
    /// this client; dropping the client first is a use-after-free in
    /// bindings whose executables do not refcount it)
    _rt: Runtime,
    exe: Executable,
    batch: usize,
    n_prunable: usize,
    /// pre-marshalled image literals, one per batch
    image_batches: Vec<xla::Literal>,
    /// labels per batch
    label_batches: Vec<Vec<i64>>,
    n_examples: usize,
    /// per-layer (w, b) literal cache
    wcache: RefCell<Vec<Option<(xla::Literal, xla::Literal)>>>,
}

impl PjrtBackend {
    /// Compile `hlo_path` on `rt` (taking ownership — the client must
    /// live as long as the executable) and marshal the evaluation
    /// batches. One client per backend; workers in a `compare --jobs`
    /// sweep are separate processes, so this stays one client per
    /// process-and-model as in the original design.
    pub fn new(
        rt: Runtime,
        arch: &ModelArch,
        hlo_path: &Path,
        data: EvalData,
    ) -> Result<PjrtBackend> {
        let exe = rt.load_hlo(hlo_path)?;
        let [h, w, c] = data.input;
        let batch = data.batch;
        let image_batches = data
            .image_batches
            .iter()
            .map(|buf| literal_f32(&[batch, h, w, c], buf))
            .collect::<Result<Vec<_>>>()?;
        Ok(PjrtBackend {
            _rt: rt,
            exe,
            batch,
            n_prunable: arch.prunable.len(),
            image_batches,
            label_batches: data.label_batches,
            n_examples: data.n_examples,
            wcache: RefCell::new(vec![None; arch.prunable.len()]),
        })
    }
}

impl InferenceBackend for PjrtBackend {
    fn accuracy(&self, weights: &Weights, act_bits: &[f32]) -> Result<f64> {
        if act_bits.len() != self.n_prunable {
            bail!("act_bits len {} vs {} prunable", act_bits.len(), self.n_prunable);
        }
        // only dirty layers are re-marshalled (see struct-level perf note)
        {
            let mut cache = self.wcache.borrow_mut();
            for i in 0..self.n_prunable {
                if cache[i].is_none() {
                    cache[i] = Some((
                        literal_f32(&weights.w[i].shape, &weights.w[i].data)?,
                        literal_f32(&weights.b[i].shape, &weights.b[i].data)?,
                    ));
                }
            }
        }
        let cache = self.wcache.borrow();
        let mut base: Vec<xla::Literal> = Vec::with_capacity(2 * self.n_prunable + 2);
        for entry in cache.iter() {
            let (w, b) = entry.as_ref().unwrap();
            base.push(w.clone());
            base.push(b.clone());
        }
        base.push(literal_f32(&[self.n_prunable], act_bits)?);

        let mut correct = 0usize;
        for (img, labels) in self.image_batches.iter().zip(&self.label_batches) {
            let mut inputs: Vec<xla::Literal> = base.clone();
            inputs.push(img.clone());
            let logits = self.exe.run(&inputs)?;
            let vals: Vec<f32> = logits.to_vec()?;
            let classes = vals.len() / self.batch;
            correct += top1_correct(&vals, classes, labels);
        }
        Ok(correct as f64 / self.n_examples as f64)
    }

    fn invalidate(&self, layer: usize) {
        self.wcache.borrow_mut()[layer] = None;
    }

    fn invalidate_all(&self) {
        self.wcache.borrow_mut().iter_mut().for_each(|c| *c = None);
    }

    fn n_examples(&self) -> usize {
        self.n_examples
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn n_prunable(&self) -> usize {
        self.n_prunable
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Runtime round-trip tests that need artifacts live in
    // rust/tests/integration.rs; here we only exercise the literal helper
    // (fully functional even on the in-tree stub).
    #[test]
    fn literal_shape_checks() {
        assert!(literal_f32(&[2, 3], &[0.0; 5]).is_err());
        let l = literal_f32(&[2, 3], &[1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(l.element_count(), 6);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1., 2., 3., 4., 5., 6.]);
    }
}
