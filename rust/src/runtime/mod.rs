//! PJRT runtime: load AOT-compiled HLO text, execute it on the hot path.
//!
//! Wraps the `xla` crate (`PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`).
//! HLO *text* is the interchange format: jax ≥ 0.5 emits protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! [`InferenceSession`] is the reward oracle: it owns one compiled
//! executable per model plus the validation/test batches, and answers
//! "top-1 accuracy of (pruned+quantized weights, per-layer act bits)"
//! in a single PJRT call per batch — compiled once, executed at every
//! RL step, Python never involved.

use std::cell::RefCell;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::io::npz::Npz;
use crate::model::{ModelArch, Weights};

/// Thin wrapper over the PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime { client: xla::PjRtClient::cpu()? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an HLO-text artifact into an executable.
    pub fn load_hlo(&self, path: &Path) -> Result<Executable> {
        let path_str = path.to_str().context("non-utf8 path")?;
        let proto = xla::HloModuleProto::from_text_file(path_str)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(Executable { exe })
    }
}

/// One compiled model graph.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute; unwraps the 1-tuple the exporter emits (return_tuple=True).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        let result = self.exe.execute::<xla::Literal>(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple1()?)
    }
}

/// Build an f32 literal of the given shape from a slice.
pub fn literal_f32(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("literal shape {shape:?} vs data len {}", data.len());
    }
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        shape,
        bytes,
    )?)
}

/// Which split of the dataset artifact to evaluate on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    /// reward subset (paper §5.1: a slice of the validation set)
    Val,
    /// final top-1 reporting
    Test,
}

/// The accuracy oracle for one model.
///
/// Perf note (EXPERIMENTS.md §Perf): the RL loop changes exactly ONE
/// layer's weights per step, so the session keeps the marshalled weight
/// literals in a per-layer cache; [`Self::invalidate`] marks a layer
/// dirty and only dirty layers are re-marshalled on the next
/// [`Self::accuracy`] call. Image batches are marshalled once at
/// construction.
pub struct InferenceSession {
    exe: Executable,
    pub batch: usize,
    pub n_prunable: usize,
    /// pre-marshalled image literals, one per batch
    image_batches: Vec<xla::Literal>,
    /// labels per batch
    label_batches: Vec<Vec<i64>>,
    pub n_examples: usize,
    /// per-layer (w, b) literal cache
    wcache: RefCell<Vec<Option<(xla::Literal, xla::Literal)>>>,
}

impl InferenceSession {
    /// `limit` truncates the number of examples (reward subset size).
    pub fn new(
        rt: &Runtime,
        arch: &ModelArch,
        hlo_path: &Path,
        data_npz: &Path,
        split: Split,
        limit: usize,
    ) -> Result<InferenceSession> {
        Self::with_batch(rt, arch, hlo_path, data_npz, split, limit, arch.batch)
    }

    /// Like [`Self::new`] but with an explicit executable batch size
    /// (the Pallas-path artifact is exported at a smaller batch).
    #[allow(clippy::too_many_arguments)]
    pub fn with_batch(
        rt: &Runtime,
        arch: &ModelArch,
        hlo_path: &Path,
        data_npz: &Path,
        split: Split,
        limit: usize,
        batch: usize,
    ) -> Result<InferenceSession> {
        let exe = rt.load_hlo(hlo_path)?;
        let npz = Npz::load(data_npz)?;
        let (xk, yk) = match split {
            Split::Val => ("X_val", "y_val"),
            Split::Test => ("X_test", "y_test"),
        };
        let images = npz.tensor(xk)?;
        let labels = npz.i64s(yk)?;
        let [h, w, c] = arch.input;
        let per = h * w * c;
        let total = labels.len().min(limit.max(1));
        let mut image_batches = Vec::new();
        let mut label_batches = Vec::new();
        let mut i = 0;
        while i < total {
            let n = (total - i).min(batch);
            // pad the tail batch by repeating the first example; padded
            // rows are ignored at scoring time
            let mut buf = Vec::with_capacity(batch * per);
            buf.extend_from_slice(&images.data[i * per..(i + n) * per]);
            while buf.len() < batch * per {
                buf.extend_from_slice(&images.data[i * per..i * per + per]);
            }
            image_batches.push(literal_f32(&[batch, h, w, c], &buf)?);
            label_batches.push(labels[i..i + n].to_vec());
            i += n;
        }
        Ok(InferenceSession {
            exe,
            batch,
            n_prunable: arch.prunable.len(),
            image_batches,
            label_batches,
            n_examples: total,
            wcache: RefCell::new(vec![None; arch.prunable.len()]),
        })
    }

    /// Mark one layer's cached weight literal dirty (its tensor changed).
    pub fn invalidate(&self, layer: usize) {
        self.wcache.borrow_mut()[layer] = None;
    }

    /// Mark everything dirty (episode reset / unknown provenance).
    pub fn invalidate_all(&self) {
        self.wcache.borrow_mut().iter_mut().for_each(|c| *c = None);
    }

    /// Top-1 accuracy of the given compressed weights + activation bits.
    pub fn accuracy(&self, weights: &Weights, act_bits: &[f32]) -> Result<f64> {
        if act_bits.len() != self.n_prunable {
            bail!("act_bits len {} vs {} prunable", act_bits.len(), self.n_prunable);
        }
        // only dirty layers are re-marshalled (see struct-level perf note)
        {
            let mut cache = self.wcache.borrow_mut();
            for i in 0..self.n_prunable {
                if cache[i].is_none() {
                    cache[i] = Some((
                        literal_f32(&weights.w[i].shape, &weights.w[i].data)?,
                        literal_f32(&weights.b[i].shape, &weights.b[i].data)?,
                    ));
                }
            }
        }
        let cache = self.wcache.borrow();
        let mut base: Vec<xla::Literal> = Vec::with_capacity(2 * self.n_prunable + 2);
        for entry in cache.iter() {
            let (w, b) = entry.as_ref().unwrap();
            base.push(w.clone());
            base.push(b.clone());
        }
        base.push(literal_f32(&[self.n_prunable], act_bits)?);

        let mut correct = 0usize;
        for (img, labels) in self.image_batches.iter().zip(&self.label_batches) {
            let mut inputs: Vec<xla::Literal> = base.clone();
            inputs.push(img.clone());
            let logits = self.exe.run(&inputs)?;
            let vals: Vec<f32> = logits.to_vec()?;
            let classes = vals.len() / self.batch;
            for (r, &y) in labels.iter().enumerate() {
                let row = &vals[r * classes..(r + 1) * classes];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i as i64)
                    .unwrap_or(-1);
                if pred == y {
                    correct += 1;
                }
            }
        }
        Ok(correct as f64 / self.n_examples as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Runtime round-trip tests that need artifacts live in
    // rust/tests/integration.rs; here we only exercise the literal helper.
    #[test]
    fn literal_shape_checks() {
        assert!(literal_f32(&[2, 3], &[0.0; 5]).is_err());
        let l = literal_f32(&[2, 3], &[1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(l.element_count(), 6);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1., 2., 3., 4., 5., 6.]);
    }
}
