//! The accuracy oracle — pluggable inference backends.
//!
//! The RL loop (paper Fig 3) asks one question at *every* step: "what
//! is the top-1 accuracy of (pruned + fake-quantized weights, per-layer
//! activation bits)?". This module owns that question behind the
//! [`InferenceBackend`] trait so the answer can come from different
//! executors:
//!
//! * [`native::NativeBackend`] (default, pure Rust, zero FFI) — a
//!   direct interpreter of the [`ModelArch`] graph over [`Weights`],
//!   with the same fake-quant activation semantics the exported HLO
//!   graphs encode (`python/compile/kernels/ref.py`), driven by the
//!   incremental, multi-threaded [`exec::Engine`] (activation
//!   checkpoint cache + std-only worker pool, `--threads N`);
//! * `pjrt::PjrtBackend` (`--features pjrt`) — the AOT-compiled HLO
//!   executed through the XLA PJRT C API, kept behind a feature gate
//!   because the `xla` binding cannot be vendored.
//!
//! [`InferenceSession`] is the concrete handle the environment holds:
//! a thin owner of one boxed backend plus the batch/example metadata
//! every caller needs. Backends are constructed through
//! [`InferenceSession::open`], keyed by [`BackendKind`] (the CLI's
//! `--backend` flag).

pub mod exec;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

pub use native::NativeBackend;
#[cfg(feature = "pjrt")]
pub use pjrt::{literal_f32, Executable, PjrtBackend, Runtime};

use crate::io::npz::Npz;
use crate::model::{ModelArch, Weights};
use crate::tensor::Tensor;

/// Which split of the dataset artifact to evaluate on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    /// reward subset (paper §5.1: a slice of the validation set)
    Val,
    /// final top-1 reporting
    Test,
}

/// Which executor answers accuracy queries (the CLI's `--backend`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust graph interpreter — works everywhere, no FFI.
    #[default]
    Native,
    /// AOT-compiled HLO through the XLA PJRT C API (`--features pjrt`).
    Pjrt,
}

impl BackendKind {
    /// Parse a `--backend` flag value (`native` | `pjrt`).
    pub fn parse(s: &str) -> Result<BackendKind> {
        match s {
            "native" => Ok(BackendKind::Native),
            "pjrt" => Ok(BackendKind::Pjrt),
            other => bail!("unknown backend `{other}` (expected `native` or `pjrt`)"),
        }
    }

    /// Flag-style name of the backend.
    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

/// Which compute kernel the native engine evaluates prunable layers
/// with (the CLI's `--kernel`). Both produce **bit-identical** logits —
/// enforced by `rust/tests/kernel_conformance.rs` — so this is purely a
/// performance knob.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KernelKind {
    /// The reference path: fake-quantize a copy of the input
    /// activations, then f32 im2col + GEMM over the raw weight tensor.
    F32,
    /// The integer fast path (default): i16 activation-code planes
    /// extracted while packing patches, per-layer dequant LUT, and
    /// pack-once weight planes with pruned rows/columns dropped
    /// (`nn::mat::PackedMat`), re-packed only for invalidated layers.
    #[default]
    Int,
}

impl KernelKind {
    /// Parse a `--kernel` flag value (`f32` | `int`).
    pub fn parse(s: &str) -> Result<KernelKind> {
        match s {
            "f32" => Ok(KernelKind::F32),
            "int" => Ok(KernelKind::Int),
            other => bail!("unknown kernel `{other}` (expected `f32` or `int`)"),
        }
    }

    /// Flag-style name of the kernel.
    pub fn name(&self) -> &'static str {
        match self {
            KernelKind::F32 => "f32",
            KernelKind::Int => "int",
        }
    }
}

/// Kernel default for new sessions: the `HAPQ_KERNEL` environment
/// variable when set to a valid kernel name, else [`KernelKind::Int`].
/// The CI kernel-parity matrix drives the whole suite through both
/// values of this knob.
pub fn default_kernel() -> KernelKind {
    std::env::var("HAPQ_KERNEL")
        .ok()
        .and_then(|v| KernelKind::parse(&v).ok())
        .unwrap_or_default()
}

/// How the native engine's worker pool schedules cache shards (the
/// CLI's `--sched`). Both produce **bit-identical** results at every
/// thread count and every steal order — the reduction sorts partials by
/// shard index and sums integer counts, so evaluation order never leaks
/// into the fold (pinned by `rust/tests/exec_engine.rs` and
/// `rust/tests/kernel_conformance.rs`). Purely a performance knob.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SchedKind {
    /// The pre-stealing assignment: worker `w` evaluates exactly the
    /// shards `gi % threads == w` (round-robin), never touching another
    /// worker's slots. A straggler stalls the reduction barrier.
    Static,
    /// Work stealing (default): workers claim shards from a shared slab
    /// via atomic ticket counters, preferring their round-robin slots
    /// (warm `ActCache`s) and stealing from other workers' preference
    /// lists only once their own is drained. Dirty-layer packing also
    /// fans out across the idle pool before the eval broadcast.
    #[default]
    Steal,
}

impl SchedKind {
    /// Parse a `--sched` flag value (`static` | `steal`).
    pub fn parse(s: &str) -> Result<SchedKind> {
        match s {
            "static" => Ok(SchedKind::Static),
            "steal" => Ok(SchedKind::Steal),
            other => bail!("unknown scheduler `{other}` (expected `static` or `steal`)"),
        }
    }

    /// Flag-style name of the scheduler.
    pub fn name(&self) -> &'static str {
        match self {
            SchedKind::Static => "static",
            SchedKind::Steal => "steal",
        }
    }
}

/// Scheduler default for new sessions: the `HAPQ_SCHED` environment
/// variable when set to a valid scheduler name, else
/// [`SchedKind::Steal`]. The `HAPQ_SCHED=static` CI lane drives the
/// whole suite through the static assignment.
pub fn default_sched() -> SchedKind {
    std::env::var("HAPQ_SCHED")
        .ok()
        .and_then(|v| SchedKind::parse(&v).ok())
        .unwrap_or_default()
}

/// Parse a `--memo` flag value / `HAPQ_MEMO` setting (`on`/`off`,
/// `1`/`0`, `true`/`false`).
pub fn parse_memo(s: &str) -> Result<bool> {
    match s {
        "on" | "1" | "true" => Ok(true),
        "off" | "0" | "false" => Ok(false),
        other => bail!("unknown memo setting `{other}` (expected `on` or `off`)"),
    }
}

/// Memoization default for new sessions: the `HAPQ_MEMO` environment
/// variable when set to a valid value, else **on**. Like the kernel
/// knob this is purely a performance switch — memoized results are the
/// *exact* previously computed values, so runs are bit-identical with
/// it on or off (the `HAPQ_MEMO=0` CI lane drives the whole suite
/// through the cold path).
pub fn default_memo() -> bool {
    std::env::var("HAPQ_MEMO").ok().and_then(|v| parse_memo(&v).ok()).unwrap_or(true)
}

/// Search-loop memoization configuration (the CLI's `--memo` /
/// `--memo-pack-cap` / `--memo-eval-cap`), threaded from `RunConfig`
/// through the coordinator into the exec engine (pack cache, scratch
/// arenas) and the compression environment (eval cache).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MemoConfig {
    /// master switch: false disables the pack cache, the eval cache and
    /// the scratch arenas (fresh allocations / re-packs everywhere)
    pub enabled: bool,
    /// bounded-LRU capacity of the engine's `PackedLayer` cache
    /// (entries, across all prunable layers)
    pub pack_cap: usize,
    /// bounded-LRU capacity of the environment's full-config eval cache
    /// (entries; one entry = one whole-network fingerprint vector)
    pub eval_cap: usize,
}

impl Default for MemoConfig {
    /// Environment-resolved default: `HAPQ_MEMO` for the switch
    /// ([`default_memo`]), 256 pack entries, 4096 eval entries.
    fn default() -> Self {
        MemoConfig { enabled: default_memo(), pack_cap: 256, eval_cap: 4096 }
    }
}

impl MemoConfig {
    /// A disabled configuration (the `--memo off` cold path).
    pub fn off() -> MemoConfig {
        MemoConfig { enabled: false, pack_cap: 0, eval_cap: 0 }
    }
}

/// Execution statistics a backend may expose for perf reporting and
/// the run-JSON measurement conventions (EXPERIMENTS.md).
#[derive(Clone, Copy, Debug)]
pub struct RuntimeStats {
    /// worker threads answering accuracy queries
    pub threads: usize,
    /// compute kernel evaluating prunable layers (`--kernel`; backends
    /// without the native engine report the reference [`KernelKind::F32`])
    pub kernel: KernelKind,
    /// graph-layer activations recomputed across all queries so far
    pub layers_computed: u64,
    /// graph-layer activations served from the checkpoint cache
    pub layers_reused: u64,
    /// cumulative seconds spent (re)packing weight planes for the int
    /// kernel — engine-side, once per dirty layer per query
    pub pack_secs: f64,
    /// cumulative CPU-seconds inside prunable-layer (GEMM) evaluation,
    /// summed across workers — compare at equal `threads` only
    pub gemm_secs: f64,
    /// packs served from the config-fingerprinted `PackCache` instead
    /// of being rebuilt (0 with `--memo off` or the f32 kernel)
    pub pack_hits: u64,
    /// packs actually (re)built — the pack-cache miss count
    pub pack_misses: u64,
    /// shard scheduler answering accuracy queries (`--sched`; backends
    /// without the native engine report [`SchedKind::Static`])
    pub sched: SchedKind,
    /// shards claimed from another worker's preference list, summed
    /// across all queries so far (0 under `--sched static`)
    pub steals: u64,
}

impl Default for RuntimeStats {
    fn default() -> Self {
        RuntimeStats {
            threads: 1,
            kernel: KernelKind::F32,
            layers_computed: 0,
            layers_reused: 0,
            pack_secs: 0.0,
            gemm_secs: 0.0,
            pack_hits: 0,
            pack_misses: 0,
            sched: SchedKind::Static,
            steals: 0,
        }
    }
}

impl RuntimeStats {
    /// Fraction of layer evaluations served from the activation cache
    /// (0 when no query has run yet).
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.layers_computed + self.layers_reused;
        if total == 0 {
            0.0
        } else {
            self.layers_reused as f64 / total as f64
        }
    }

    /// Fraction of pack requests served from the `PackCache` (0 when no
    /// pack was ever requested — the f32 kernel or `--memo off`).
    pub fn pack_cache_hit_rate(&self) -> f64 {
        let total = self.pack_hits + self.pack_misses;
        if total == 0 {
            0.0
        } else {
            self.pack_hits as f64 / total as f64
        }
    }
}

impl crate::telemetry::MetricsSource for RuntimeStats {
    fn record(&self, reg: &mut crate::telemetry::MetricsRegistry) {
        reg.counter("exec.layers_computed", self.layers_computed);
        reg.counter("exec.layers_reused", self.layers_reused);
        reg.counter("exec.pack_hits", self.pack_hits);
        reg.counter("exec.pack_misses", self.pack_misses);
        reg.counter("exec.steals", self.steals);
        reg.gauge("exec.threads", self.threads as f64);
        reg.gauge("exec.pack_secs", self.pack_secs);
        reg.gauge("exec.gemm_secs", self.gemm_secs);
        reg.gauge("exec.cache_hit_rate", self.cache_hit_rate());
        reg.gauge("exec.pack_cache_hit_rate", self.pack_cache_hit_rate());
        reg.label("exec.kernel", self.kernel.name());
        reg.label("exec.sched", self.sched.name());
    }
}

/// One proposed layer-config for batched oracle pricing: the
/// candidate's weights/bias/activation-precision for a single prunable
/// layer, evaluated against the current base weights with every other
/// layer unchanged. `Arc` so the engine can share the tensors with its
/// worker pool without re-cloning per worker.
#[derive(Clone)]
pub struct Candidate {
    /// prunable-layer index the proposal replaces
    pub layer: usize,
    /// proposed weight tensor
    pub w: Arc<Tensor>,
    /// proposed bias tensor
    pub b: Arc<Tensor>,
    /// proposed activation precision (bits, 2..=8)
    pub bits: f32,
}

/// An executor that can score compressed weights — the reward oracle.
///
/// Contract shared by all backends: one call evaluates the *whole*
/// model on every held batch and returns top-1 accuracy over the
/// split's examples. [`InferenceBackend::invalidate`] is a cache hint —
/// the RL loop changes exactly one layer's weights per step, so a
/// backend that marshals or stages per-layer state may keep it between
/// calls and refresh only invalidated layers (the PJRT literal cache
/// does; the native engine additionally resumes the forward pass from
/// the first dirty layer and re-stages only dirty weight tensors).
pub trait InferenceBackend {
    /// Top-1 accuracy of `weights` with per-layer activation precisions
    /// `act_bits` (length = number of prunable layers, values 2..=8).
    fn accuracy(&self, weights: &Weights, act_bits: &[f32]) -> Result<f64>;

    /// Mark one prunable layer's staged state dirty (its tensor changed).
    fn invalidate(&self, layer: usize);

    /// Mark every layer dirty (episode reset / unknown provenance).
    fn invalidate_all(&self);

    /// Number of examples actually scored (after the `limit` truncation).
    fn n_examples(&self) -> usize;

    /// Inference batch size of the executor.
    fn batch(&self) -> usize;

    /// Number of prunable layers (= expected `act_bits` length).
    fn n_prunable(&self) -> usize;

    /// Human-readable backend name for logs and reports.
    fn name(&self) -> &'static str;

    /// Execution statistics (threads, activation-cache hit rate).
    /// Backends without an incremental engine keep the default.
    fn stats(&self) -> RuntimeStats {
        RuntimeStats::default()
    }

    /// Price a batch of candidate layer-configs against the base
    /// `(weights, act_bits)`: one top-1 accuracy per candidate, each as
    /// if only that candidate's layer had been replaced. After the
    /// call, staged/cached backend state must be as if only the base
    /// config had been evaluated.
    ///
    /// The default is the *serial semantics definition* any batched
    /// implementation must match bitwise: clone the base, swap one
    /// layer in, invalidate around the query, restore. Correct for any
    /// incremental backend; engines with a shared-prefix fast path
    /// (the native [`exec::Engine`]) override it.
    fn accuracy_batch(
        &self,
        weights: &Weights,
        act_bits: &[f32],
        cands: &[Candidate],
    ) -> Result<Vec<f64>> {
        let mut w = weights.clone();
        let mut bits = act_bits.to_vec();
        let mut out = Vec::with_capacity(cands.len());
        for c in cands {
            let (orig_w, orig_b, orig_bits) =
                (w.w[c.layer].clone(), w.b[c.layer].clone(), bits[c.layer]);
            self.invalidate(c.layer);
            w.w[c.layer] = (*c.w).clone();
            w.b[c.layer] = (*c.b).clone();
            bits[c.layer] = c.bits;
            let acc = self.accuracy(&w, &bits);
            w.w[c.layer] = orig_w;
            w.b[c.layer] = orig_b;
            bits[c.layer] = orig_bits;
            self.invalidate(c.layer);
            out.push(acc?);
        }
        Ok(out)
    }
}

/// Batched evaluation data shared by every backend: images split into
/// fixed-size batches (tail padded by repeating the first example —
/// padded rows are ignored at scoring time) plus per-batch labels.
pub struct EvalData {
    /// executor batch size every image batch is padded to
    pub batch: usize,
    /// input geometry `[H, W, C]` (from the arch descriptor)
    pub input: [usize; 3],
    /// flattened `[batch, H, W, C]` image buffers, one per batch
    pub image_batches: Vec<Vec<f32>>,
    /// ground-truth labels per batch (length = real rows, ≤ batch)
    pub label_batches: Vec<Vec<i64>>,
    /// total examples scored
    pub n_examples: usize,
}

impl EvalData {
    /// Load a split from a dataset artifact (`<dataset>.data.npz`).
    /// `limit` truncates the number of examples (reward-subset size).
    pub fn load(
        arch: &ModelArch,
        data_npz: &Path,
        split: Split,
        limit: usize,
        batch: usize,
    ) -> Result<EvalData> {
        let npz = Npz::load(data_npz)?;
        let (xk, yk) = match split {
            Split::Val => ("X_val", "y_val"),
            Split::Test => ("X_test", "y_test"),
        };
        let images = npz.tensor(xk).context("dataset artifact")?;
        let labels = npz.i64s(yk).context("dataset artifact")?;
        Self::from_arrays(arch, &images, &labels, limit, batch)
    }

    /// Build directly from in-memory arrays (tests, synthetic probes).
    /// `images` is `[N, H, W, C]` row-major.
    pub fn from_arrays(
        arch: &ModelArch,
        images: &Tensor,
        labels: &[i64],
        limit: usize,
        batch: usize,
    ) -> Result<EvalData> {
        let [h, w, c] = arch.input;
        let per = h * w * c;
        if images.data.len() < labels.len() * per {
            bail!(
                "image buffer holds {} values but {} examples of {per} need {}",
                images.data.len(),
                labels.len(),
                labels.len() * per
            );
        }
        let total = labels.len().min(limit.max(1));
        let mut image_batches = Vec::new();
        let mut label_batches = Vec::new();
        let mut i = 0;
        while i < total {
            let n = (total - i).min(batch);
            // pad the tail batch by repeating the first example; padded
            // rows are ignored at scoring time
            let mut buf = Vec::with_capacity(batch * per);
            buf.extend_from_slice(&images.data[i * per..(i + n) * per]);
            while buf.len() < batch * per {
                buf.extend_from_slice(&images.data[i * per..i * per + per]);
            }
            image_batches.push(buf);
            label_batches.push(labels[i..i + n].to_vec());
            i += n;
        }
        Ok(EvalData {
            batch,
            input: [h, w, c],
            image_batches,
            label_batches,
            n_examples: total,
        })
    }
}

/// Count rows of `logits` (`[batch, classes]` row-major, possibly with
/// padded tail rows) whose argmax matches the label. Only the first
/// `labels.len()` rows are scored.
pub fn top1_correct(logits: &[f32], classes: usize, labels: &[i64]) -> usize {
    let mut correct = 0usize;
    for (r, &y) in labels.iter().enumerate() {
        let row = &logits[r * classes..(r + 1) * classes];
        let pred = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
            .map(|(i, _)| i as i64)
            .unwrap_or(-1);
        if pred == y {
            correct += 1;
        }
    }
    correct
}

/// The accuracy oracle handle for one model: a boxed
/// [`InferenceBackend`] plus the metadata every caller reads.
///
/// Perf note (EXPERIMENTS.md §Perf): the RL loop changes exactly ONE
/// layer's weights per step; [`Self::invalidate`] forwards that hint so
/// caching backends refresh only dirty state on the next
/// [`Self::accuracy`] call — the native engine resumes the forward
/// pass from the first dirty layer, PJRT re-marshals dirty literals.
pub struct InferenceSession {
    backend: Box<dyn InferenceBackend>,
    /// executor batch size
    pub batch: usize,
    /// number of prunable layers (= expected `act_bits` length)
    pub n_prunable: usize,
    /// examples scored per accuracy query
    pub n_examples: usize,
}

impl InferenceSession {
    /// Wrap an already-constructed backend.
    pub fn from_backend(backend: Box<dyn InferenceBackend>) -> InferenceSession {
        InferenceSession {
            batch: backend.batch(),
            n_prunable: backend.n_prunable(),
            n_examples: backend.n_examples(),
            backend,
        }
    }

    /// Open a session on the chosen backend with the process-default
    /// kernel ([`default_kernel`]).
    ///
    /// `hlo` is the AOT-compiled HLO-text artifact — required by
    /// [`BackendKind::Pjrt`], ignored by [`BackendKind::Native`].
    /// `batch` overrides the arch's executor batch size (the Pallas-path
    /// artifact is exported at a smaller batch); `None` uses
    /// `arch.batch`. `threads` sizes the native engine's worker pool
    /// (`--threads`; clamped to ≥ 1, ignored by PJRT).
    #[allow(clippy::too_many_arguments)]
    pub fn open(
        kind: BackendKind,
        arch: &ModelArch,
        hlo: Option<&Path>,
        data_npz: &Path,
        split: Split,
        limit: usize,
        batch: Option<usize>,
        threads: usize,
    ) -> Result<InferenceSession> {
        Self::open_with(
            kind,
            arch,
            hlo,
            data_npz,
            split,
            limit,
            batch,
            threads,
            default_kernel(),
            MemoConfig::default(),
            default_sched(),
        )
    }

    /// [`Self::open`] with an explicit compute kernel (the CLI's
    /// `--kernel`), memoization config (the CLI's `--memo` family) and
    /// shard scheduler (the CLI's `--sched`); all ignored by PJRT,
    /// whose executor is the AOT graph.
    #[allow(clippy::too_many_arguments)]
    pub fn open_with(
        kind: BackendKind,
        arch: &ModelArch,
        hlo: Option<&Path>,
        data_npz: &Path,
        split: Split,
        limit: usize,
        batch: Option<usize>,
        threads: usize,
        kernel: KernelKind,
        memo: MemoConfig,
        sched: SchedKind,
    ) -> Result<InferenceSession> {
        let batch = batch.unwrap_or(arch.batch);
        match kind {
            BackendKind::Native => {
                let data = EvalData::load(arch, data_npz, split, limit, batch)?;
                Ok(Self::from_backend(Box::new(NativeBackend::with_sched(
                    arch, data, threads, kernel, memo, sched,
                )?)))
            }
            #[cfg(feature = "pjrt")]
            BackendKind::Pjrt => {
                let hlo = hlo.context("pjrt backend needs an HLO artifact path")?;
                let rt = pjrt::Runtime::cpu()?;
                let data = EvalData::load(arch, data_npz, split, limit, batch)?;
                Ok(Self::from_backend(Box::new(pjrt::PjrtBackend::new(
                    rt, arch, hlo, data,
                )?)))
            }
            #[cfg(not(feature = "pjrt"))]
            BackendKind::Pjrt => {
                let _ = hlo;
                bail!(
                    "this binary was built without the `pjrt` feature; \
                     rebuild with `cargo build --features pjrt` or use \
                     `--backend native`"
                )
            }
        }
    }

    /// Mark one layer's staged state dirty (its tensor changed).
    pub fn invalidate(&self, layer: usize) {
        self.backend.invalidate(layer);
    }

    /// Mark everything dirty (episode reset / unknown provenance).
    pub fn invalidate_all(&self) {
        self.backend.invalidate_all();
    }

    /// Top-1 accuracy of the given compressed weights + activation bits.
    pub fn accuracy(&self, weights: &Weights, act_bits: &[f32]) -> Result<f64> {
        self.backend.accuracy(weights, act_bits)
    }

    /// Price a batch of candidate layer-configs in one call — one
    /// accuracy per candidate, bitwise-equal to serial one-at-a-time
    /// evaluation (see [`InferenceBackend::accuracy_batch`]). The
    /// native engine amortizes the shared activation-checkpoint prefix
    /// across the batch.
    pub fn accuracy_batch(
        &self,
        weights: &Weights,
        act_bits: &[f32],
        cands: &[Candidate],
    ) -> Result<Vec<f64>> {
        self.backend.accuracy_batch(weights, act_bits, cands)
    }

    /// Execution statistics of the backend (threads, cache hit rate) —
    /// recorded in every run JSON and printed by `hapq perf`.
    pub fn stats(&self) -> RuntimeStats {
        self.backend.stats()
    }

    /// Name of the executing backend (`native` / `pjrt`).
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_parses() {
        assert_eq!(BackendKind::parse("native").unwrap(), BackendKind::Native);
        assert_eq!(BackendKind::parse("pjrt").unwrap(), BackendKind::Pjrt);
        assert!(BackendKind::parse("tpu").is_err());
        assert_eq!(BackendKind::default().name(), "native");
    }

    #[test]
    fn kernel_kind_parses() {
        assert_eq!(KernelKind::parse("f32").unwrap(), KernelKind::F32);
        assert_eq!(KernelKind::parse("int").unwrap(), KernelKind::Int);
        assert!(KernelKind::parse("i8").is_err());
        // the fast path is the default; HAPQ_KERNEL can override it
        assert_eq!(KernelKind::default(), KernelKind::Int);
        assert_eq!(KernelKind::default().name(), "int");
        // backends without the native engine report the f32 reference
        assert_eq!(RuntimeStats::default().kernel, KernelKind::F32);
        assert_eq!(RuntimeStats::default().pack_secs, 0.0);
    }

    #[test]
    fn sched_kind_parses() {
        assert_eq!(SchedKind::parse("static").unwrap(), SchedKind::Static);
        assert_eq!(SchedKind::parse("steal").unwrap(), SchedKind::Steal);
        assert!(SchedKind::parse("greedy").is_err());
        // stealing is the default; HAPQ_SCHED can override it
        assert_eq!(SchedKind::default(), SchedKind::Steal);
        assert_eq!(SchedKind::default().name(), "steal");
        // backends without the native engine report the static scheduler
        assert_eq!(RuntimeStats::default().sched, SchedKind::Static);
        assert_eq!(RuntimeStats::default().steals, 0);
    }

    #[test]
    fn memo_flag_parses() {
        assert!(parse_memo("on").unwrap());
        assert!(parse_memo("1").unwrap());
        assert!(parse_memo("true").unwrap());
        assert!(!parse_memo("off").unwrap());
        assert!(!parse_memo("0").unwrap());
        assert!(!parse_memo("false").unwrap());
        assert!(parse_memo("maybe").is_err());
        let off = MemoConfig::off();
        assert!(!off.enabled);
        assert_eq!((off.pack_cap, off.eval_cap), (0, 0));
        // the disabled stats report a 0 pack hit rate, not NaN
        assert_eq!(RuntimeStats::default().pack_cache_hit_rate(), 0.0);
    }

    #[test]
    fn top1_scores_only_labelled_rows() {
        // 3 rows of 2 classes; only 2 labels -> padded row ignored
        let logits = [0.1, 0.9, 0.8, 0.2, 0.5, 0.5];
        assert_eq!(top1_correct(&logits, 2, &[1, 0]), 2);
        assert_eq!(top1_correct(&logits, 2, &[0, 0]), 1);
    }

    #[test]
    fn eval_data_batches_and_pads() {
        let arch = crate::model::tests::toy_arch();
        let per = 8 * 8 * 3;
        let n = 5;
        let images = Tensor::new(
            vec![n, 8, 8, 3],
            (0..n * per).map(|i| i as f32).collect(),
        );
        let labels = vec![0i64, 1, 2, 3, 0];
        let d = EvalData::from_arrays(&arch, &images, &labels, 100, 2).unwrap();
        assert_eq!(d.n_examples, 5);
        assert_eq!(d.image_batches.len(), 3);
        assert_eq!(d.label_batches[2], vec![0]); // tail batch: 1 real row
        assert_eq!(d.image_batches[2].len(), 2 * per); // padded to batch
        // padded row repeats the first example of the tail batch
        assert_eq!(d.image_batches[2][per..], d.image_batches[2][..per]);
        // limit truncation
        let d2 = EvalData::from_arrays(&arch, &images, &labels, 3, 2).unwrap();
        assert_eq!(d2.n_examples, 3);
    }
}
