//! The diverse pruning-algorithm set (paper Table 2).
//!
//! | Algorithm         | Granularity | Criterion                                |
//! |-------------------|-------------|------------------------------------------|
//! | Level [4]         | fine        | weight magnitude                         |
//! | Sensitivity [5]   | fine        | SNIP saliency |w ⊙ ∂L/∂w| (calibration)  |
//! | Splicing [6]      | fine        | magnitude + recoverable band arbitration |
//! | L1-Ranked [7]     | coarse      | filter/neuron L1 norm                    |
//! | L2-Ranked [7]     | coarse      | filter/neuron L2 norm                    |
//! | Bernoulli [36]    | coarse      | random filter dropping (DropFilter)      |
//! | FM Recon. [35]    | coarse      | output feature-map energy (calibration)  |
//!
//! One-shot adaptations (no training data on this path): Sensitivity
//! uses the calibration-batch saliency exported by the L2 trainer;
//! Splicing approximates Dynamic Network Surgery's recoverable band by
//! arbitrating the borderline magnitude band with saliency; FM
//! Reconstruction ranks channels by the calibration feature-map energy
//! (the reconstruction-error proxy). All documented in DESIGN.md §1.

use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Pruning algorithm id — the Rainbow agent's discrete action space.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PruneAlg {
    /// fine: weight-magnitude threshold [4]
    Level,
    /// fine: SNIP saliency from the calibration batch [5]
    Sensitivity,
    /// fine: magnitude + recoverable-band saliency arbitration [6]
    Splicing,
    /// coarse: filter/neuron L1 norm [7]
    L1Ranked,
    /// coarse: filter/neuron L2 norm [7]
    L2Ranked,
    /// coarse: random filter dropping (DropFilter) [36]
    Bernoulli,
    /// coarse: output feature-map energy [35]
    FmRecon,
}

impl PruneAlg {
    /// Every algorithm, in the Rainbow action-index order.
    pub const ALL: [PruneAlg; 7] = [
        PruneAlg::Sensitivity,
        PruneAlg::Level,
        PruneAlg::Splicing,
        PruneAlg::L1Ranked,
        PruneAlg::L2Ranked,
        PruneAlg::Bernoulli,
        PruneAlg::FmRecon,
    ];

    /// Algorithm for a (wrapped) Rainbow action index.
    pub fn from_index(i: usize) -> PruneAlg {
        Self::ALL[i % Self::ALL.len()]
    }

    /// This algorithm's Rainbow action index.
    pub fn index(&self) -> usize {
        Self::ALL.iter().position(|a| a == self).unwrap()
    }

    /// Structured (filter/channel) pruning? Drives eq (7) vs (8) and the
    /// §4.1 dependency rule.
    pub fn coarse(&self) -> bool {
        matches!(
            self,
            PruneAlg::L1Ranked | PruneAlg::L2Ranked | PruneAlg::Bernoulli | PruneAlg::FmRecon
        )
    }

    /// Short name used in reports and figures.
    pub fn name(&self) -> &'static str {
        match self {
            PruneAlg::Level => "level",
            PruneAlg::Sensitivity => "sensitivity",
            PruneAlg::Splicing => "splicing",
            PruneAlg::L1Ranked => "l1-ranked",
            PruneAlg::L2Ranked => "l2-ranked",
            PruneAlg::Bernoulli => "bernoulli",
            PruneAlg::FmRecon => "fm-recon",
        }
    }
}

/// Per-layer inputs the criteria need beyond the weights themselves.
pub struct PruneCtx<'a> {
    /// SNIP saliency tensor (same shape as weights)
    pub saliency: &'a Tensor,
    /// per-output-channel feature-map energy
    pub chsq: &'a [f32],
    /// depthwise layer? (affects nothing under HW1C layout, kept for clarity)
    pub dwconv: bool,
    /// randomness source (Bernoulli pruning)
    pub rng: &'a mut Rng,
}

/// What a pruning call did.
#[derive(Clone, Debug, Default)]
pub struct PruneResult {
    /// fraction of weights now zero
    pub sparsity: f64,
    /// channels removed (coarse only) — propagated across dep groups
    pub channels: Option<Vec<usize>>,
}

/// Apply `alg` at `ratio` to `w` in place. `ratio` is the target fraction
/// of zeroed weights (fine) or of removed channels (coarse).
pub fn prune(w: &mut Tensor, alg: PruneAlg, ratio: f64, ctx: &mut PruneCtx) -> PruneResult {
    let ratio = ratio.clamp(0.0, 0.95); // never fully erase a layer
    if ratio == 0.0 || w.is_empty() {
        return PruneResult { sparsity: w.sparsity() as f64, channels: None };
    }
    match alg {
        PruneAlg::Level => fine_by_score(w, ratio, |i, x| {
            let _ = i;
            x.abs()
        }),
        PruneAlg::Sensitivity => {
            let sal = &ctx.saliency.data;
            fine_by_score(w, ratio, |i, _| sal.get(i).copied().unwrap_or(0.0))
        }
        PruneAlg::Splicing => splice(w, ratio, ctx),
        PruneAlg::L1Ranked => coarse_by_score(w, ratio, &w.channel_l1(false)),
        PruneAlg::L2Ranked => coarse_by_score(w, ratio, &w.channel_l2(false)),
        PruneAlg::Bernoulli => {
            let c = w.out_channels(false);
            let n_drop = target_channels(c, ratio);
            let chans = ctx.rng.choose_k(c, n_drop);
            apply_channels(w, chans)
        }
        PruneAlg::FmRecon => {
            let c = w.out_channels(false);
            let mut score = ctx.chsq.to_vec();
            score.resize(c, 0.0);
            coarse_by_score(w, ratio, &score)
        }
    }
}

/// Zero the lowest-scoring weights until `ratio` of the tensor is zero.
fn fine_by_score<F: Fn(usize, f32) -> f32>(w: &mut Tensor, ratio: f64, score: F) -> PruneResult {
    let n = w.len();
    let k = ((n as f64) * ratio).round() as usize;
    if k == 0 {
        return PruneResult { sparsity: w.sparsity() as f64, channels: None };
    }
    // selection, not a full sort: O(n) expected vs O(n log n) — this runs
    // on the RL hot path for every fine-grained action (§Perf)
    let mut idx: Vec<u32> = (0..n as u32).collect();
    let cmp = |a: &u32, b: &u32| {
        let sa = score(*a as usize, w.data[*a as usize]);
        let sb = score(*b as usize, w.data[*b as usize]);
        sa.partial_cmp(&sb).unwrap_or(std::cmp::Ordering::Equal)
    };
    if k < n {
        idx.select_nth_unstable_by(k, cmp);
    }
    for &i in idx.iter().take(k) {
        w.data[i as usize] = 0.0;
    }
    PruneResult { sparsity: w.sparsity() as f64, channels: None }
}

/// Dynamic-network-surgery-style: certain prune below 0.9·t, keep above
/// 1.1·t, and arbitrate the "recoverable" band by saliency (splice back
/// the half of the band the calibration batch says matters).
fn splice(w: &mut Tensor, ratio: f64, ctx: &mut PruneCtx) -> PruneResult {
    let n = w.len();
    let k = ((n as f64) * ratio).round() as usize;
    if k == 0 {
        return PruneResult { sparsity: w.sparsity() as f64, channels: None };
    }
    let mut mags: Vec<f32> = w.data.iter().map(|x| x.abs()).collect();
    mags.sort_unstable_by(|a, b| a.total_cmp(b));
    let t = mags[(k - 1).min(n - 1)];
    let (t_lo, t_hi) = (0.9 * t, 1.1 * t);
    let sal = &ctx.saliency.data;
    // median saliency inside the band
    let mut band_sal: Vec<f32> = w
        .data
        .iter()
        .enumerate()
        .filter(|(_, x)| {
            let a = x.abs();
            a > t_lo && a <= t_hi && **x != 0.0
        })
        .map(|(i, _)| sal.get(i).copied().unwrap_or(0.0))
        .collect();
    band_sal.sort_unstable_by(|a, b| a.total_cmp(b));
    let med = band_sal.get(band_sal.len() / 2).copied().unwrap_or(0.0);
    for i in 0..n {
        let a = w.data[i].abs();
        if a <= t_lo {
            w.data[i] = 0.0;
        } else if a <= t_hi && sal.get(i).copied().unwrap_or(0.0) < med {
            w.data[i] = 0.0;
        }
    }
    PruneResult { sparsity: w.sparsity() as f64, channels: None }
}

fn target_channels(c: usize, ratio: f64) -> usize {
    (((c as f64) * ratio).round() as usize).min(c.saturating_sub(1))
}

/// Zero the lowest-scoring output channels.
fn coarse_by_score(w: &mut Tensor, ratio: f64, score: &[f32]) -> PruneResult {
    let c = w.out_channels(false);
    let n_drop = target_channels(c, ratio);
    let mut order: Vec<usize> = (0..c).collect();
    order.sort_unstable_by(|&a, &b| {
        score[a].partial_cmp(&score[b]).unwrap_or(std::cmp::Ordering::Equal)
    });
    apply_channels(w, order.into_iter().take(n_drop).collect())
}

fn apply_channels(w: &mut Tensor, mut chans: Vec<usize>) -> PruneResult {
    chans.sort_unstable();
    chans.dedup();
    w.zero_channels(&chans, false);
    PruneResult { sparsity: w.sparsity() as f64, channels: Some(chans) }
}

/// Force a specific channel mask (dependency-group propagation, §4.1).
pub fn prune_channels(w: &mut Tensor, chans: &[usize]) -> PruneResult {
    w.zero_channels(chans, false);
    PruneResult { sparsity: w.sparsity() as f64, channels: Some(chans.to_vec()) }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(n_per_ch: usize, c: usize) -> Tensor {
        // values 1..=n so magnitude ordering is known; layout [n_per_ch, c]
        let data: Vec<f32> = (0..n_per_ch * c).map(|i| (i + 1) as f32).collect();
        Tensor::new(vec![n_per_ch, c], data)
    }

    fn ctx_for<'a>(sal: &'a Tensor, chsq: &'a [f32], rng: &'a mut Rng) -> PruneCtx<'a> {
        PruneCtx { saliency: sal, chsq, dwconv: false, rng }
    }

    #[test]
    fn level_prunes_smallest_magnitudes() {
        let mut w = toy(4, 3); // 12 weights: 1..12
        let sal = Tensor::zeros(vec![12]);
        let mut rng = Rng::new(0);
        let r = prune(&mut w, PruneAlg::Level, 0.5, &mut ctx_for(&sal, &[], &mut rng));
        assert!((r.sparsity - 0.5).abs() < 1e-6);
        // smallest six (1..6) zeroed
        assert!(w.data[..6].iter().all(|&x| x == 0.0));
        assert!(w.data[6..].iter().all(|&x| x != 0.0));
    }

    #[test]
    fn sensitivity_follows_saliency_not_magnitude() {
        let mut w = toy(4, 3);
        // saliency inverted: big weights have LOW saliency
        let sal = Tensor::new(vec![12], (0..12).map(|i| 12.0 - i as f32).collect());
        let mut rng = Rng::new(0);
        prune(&mut w, PruneAlg::Sensitivity, 0.25, &mut ctx_for(&sal, &[], &mut rng));
        // the three HIGHEST-magnitude weights got pruned (lowest saliency)
        assert_eq!(w.data[9..], [0.0, 0.0, 0.0]);
        assert!(w.data[..9].iter().all(|&x| x != 0.0));
    }

    #[test]
    fn l1_ranked_removes_weakest_channels() {
        let mut w = toy(4, 3); // ch0 sums 1+4+7+10=22 < ch1=26 < ch2=30
        let sal = Tensor::zeros(vec![12]);
        let mut rng = Rng::new(0);
        let r = prune(&mut w, PruneAlg::L1Ranked, 0.34, &mut ctx_for(&sal, &[], &mut rng));
        assert_eq!(r.channels.unwrap(), vec![0]);
        assert_eq!(w.channel_l1(false)[0], 0.0);
    }

    #[test]
    fn coarse_never_kills_all_channels() {
        let mut w = toy(2, 4);
        let sal = Tensor::zeros(vec![8]);
        let mut rng = Rng::new(0);
        let r = prune(&mut w, PruneAlg::L2Ranked, 0.99, &mut ctx_for(&sal, &[], &mut rng));
        let ch = r.channels.unwrap();
        assert!(ch.len() < 4, "must keep >= 1 channel, pruned {ch:?}");
    }

    #[test]
    fn bernoulli_is_random_but_sized() {
        let mut w1 = toy(2, 8);
        let mut w2 = toy(2, 8);
        let sal = Tensor::zeros(vec![16]);
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(2);
        let a = prune(&mut w1, PruneAlg::Bernoulli, 0.5, &mut ctx_for(&sal, &[], &mut r1));
        let b = prune(&mut w2, PruneAlg::Bernoulli, 0.5, &mut ctx_for(&sal, &[], &mut r2));
        assert_eq!(a.channels.as_ref().unwrap().len(), 4);
        assert_eq!(b.channels.as_ref().unwrap().len(), 4);
        assert_ne!(a.channels, b.channels, "different seeds, different filters");
    }

    #[test]
    fn fm_recon_uses_feature_map_energy() {
        let mut w = toy(4, 3);
        let sal = Tensor::zeros(vec![12]);
        let chsq = [5.0, 0.1, 9.0]; // channel 1 has least FM energy
        let mut rng = Rng::new(0);
        let r = prune(&mut w, PruneAlg::FmRecon, 0.34, &mut ctx_for(&sal, &chsq, &mut rng));
        assert_eq!(r.channels.unwrap(), vec![1]);
    }

    #[test]
    fn splicing_prunes_band_by_saliency() {
        let mut w = toy(4, 3);
        let sal = Tensor::new(vec![12], (0..12).map(|i| i as f32).collect());
        let mut rng = Rng::new(0);
        let r = prune(&mut w, PruneAlg::Splicing, 0.5, &mut ctx_for(&sal, &[], &mut rng));
        // sparsity close to target (band arbitration wiggles it slightly)
        assert!(r.sparsity > 0.3 && r.sparsity < 0.7, "{}", r.sparsity);
    }

    #[test]
    fn property_sparsity_reaches_target_fine() {
        use crate::util::proptest::{forall, gen_sparsity, gen_weights};
        forall(
            "fine pruning hits requested sparsity",
            |r| (gen_weights(r, 256), gen_sparsity(r)),
            |(wdata, s)| {
                let mut w = Tensor::new(vec![wdata.len()], wdata.clone());
                let sal = Tensor::zeros(vec![wdata.len()]);
                let mut rng = Rng::new(1);
                let res = prune(
                    &mut w,
                    PruneAlg::Level,
                    *s as f64,
                    &mut ctx_for(&sal, &[], &mut rng),
                );
                // achieved >= requested (ties/zeros can only add)
                res.sparsity + 1.0 / wdata.len() as f64 >= *s as f64
            },
        );
    }

    #[test]
    fn property_coarse_sparsity_matches_channel_fraction() {
        use crate::util::proptest::forall;
        forall(
            "coarse sparsity == dropped/total channels",
            |r| (2 + r.below(16), 1 + r.below(8), r.range(0.0, 0.9)),
            |&(c, rows, ratio)| {
                let mut w = Tensor::new(
                    vec![rows, c],
                    (0..rows * c).map(|i| 1.0 + i as f32).collect(),
                );
                let sal = Tensor::zeros(vec![rows * c]);
                let mut rng = Rng::new(2);
                let res = prune(
                    &mut w,
                    PruneAlg::L1Ranked,
                    ratio,
                    &mut ctx_for(&sal, &[], &mut rng),
                );
                let dropped = res.channels.unwrap().len();
                (res.sparsity - dropped as f64 / c as f64).abs() < 1e-6
            },
        );
    }
}
