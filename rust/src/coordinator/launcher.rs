//! Multi-process launcher — the distributed-runtime face of the
//! coordinator. `hapq compare --jobs N` fans the (model × method) grid
//! out over N child `hapq` processes (one leader, N workers), collects
//! their result JSON from the shared output directory and merges the
//! summary. Process isolation (rather than threads) keeps one inference
//! backend per worker (one PJRT client each on `--backend pjrt`),
//! mirrors how the paper's per-model optimizations are independent, and
//! sidesteps FFI thread-safety questions. The configured `--backend`
//! and `--threads` are forwarded to every worker. Finished children are
//! reaped under an adaptive poll ([`ReapBackoff`]): 1 ms after a reap,
//! doubling to a 16 ms ceiling while everyone keeps running.

use std::collections::VecDeque;
use std::path::{Path, PathBuf};
use std::process::{Child, Command};

use anyhow::{Context, Result};

use crate::io::json;

/// One unit of work for a child process.
#[derive(Clone, Debug)]
pub struct Job {
    /// model to compress
    pub model: String,
    /// method to run (`ours` or a baseline name)
    pub method: String,
}

impl Job {
    /// CLI args for the child (`compress` for ours, `baseline` otherwise).
    fn args(&self, cfg: &crate::config::RunConfig) -> Vec<String> {
        let mut v = if self.method == "ours" {
            vec!["compress".into(), "--model".into(), self.model.clone()]
        } else {
            vec![
                "baseline".into(),
                "--model".into(),
                self.model.clone(),
                "--method".into(),
                self.method.clone(),
            ]
        };
        v.extend([
            "--artifacts".into(),
            cfg.artifacts.display().to_string(),
            "--out".into(),
            cfg.out.display().to_string(),
            "--episodes".into(),
            cfg.episodes.to_string(),
            "--warmup".into(),
            cfg.warmup.to_string(),
            "--reward-subset".into(),
            cfg.reward_subset.to_string(),
            "--seed".into(),
            cfg.seed.to_string(),
            "--backend".into(),
            cfg.backend.name().to_string(),
            "--threads".into(),
            cfg.threads.to_string(),
        ]);
        v
    }

    /// Where the child process writes its result JSON.
    pub fn report_path(&self, out: &Path) -> PathBuf {
        out.join(format!("{}__{}.json", self.model, self.method))
    }
}

/// Adaptive backoff for the reap loop: polling restarts at 1 ms after
/// every successful reap and doubles up to a 16 ms ceiling while
/// children keep running. Worst-case dead time between a child exiting
/// and its reap is one ceiling interval — the previous fixed 200 ms
/// poll cost up to 200 ms of dead time per worker exit.
#[derive(Debug)]
pub struct ReapBackoff {
    next_ms: u64,
}

impl ReapBackoff {
    /// Poll-interval ceiling in milliseconds.
    pub const MAX_MS: u64 = 16;

    /// Start at the 1 ms floor.
    pub fn new() -> ReapBackoff {
        ReapBackoff { next_ms: 1 }
    }

    /// The duration to sleep before the next poll; doubles up to
    /// [`Self::MAX_MS`].
    pub fn step(&mut self) -> std::time::Duration {
        let d = std::time::Duration::from_millis(self.next_ms);
        self.next_ms = (self.next_ms * 2).min(Self::MAX_MS);
        d
    }

    /// A child was reaped — drop back to the floor.
    pub fn reset(&mut self) {
        self.next_ms = 1;
    }
}

impl Default for ReapBackoff {
    fn default() -> Self {
        Self::new()
    }
}

/// Run the grid with at most `jobs` children alive at once. Returns the
/// merged per-job result JSON (jobs that failed are reported as errors
/// in the summary rather than aborting the sweep).
pub fn run_grid(
    cfg: &crate::config::RunConfig,
    grid: Vec<Job>,
    jobs: usize,
) -> Result<Vec<(Job, Result<json::Value>)>> {
    let exe = std::env::current_exe().context("locating hapq binary")?;
    run_grid_with(cfg, grid, jobs, &exe)
}

/// Like [`run_grid`] but with an explicit worker executable — the
/// launcher tests substitute a stub binary to measure reap overhead
/// without running real compressions.
pub fn run_grid_with(
    cfg: &crate::config::RunConfig,
    grid: Vec<Job>,
    jobs: usize,
    exe: &Path,
) -> Result<Vec<(Job, Result<json::Value>)>> {
    std::fs::create_dir_all(&cfg.out)?;
    let mut pending: VecDeque<Job> = grid.into();
    let mut running: Vec<(Job, Child)> = Vec::new();
    let mut done: Vec<(Job, Result<json::Value>)> = Vec::new();

    let mut backoff = ReapBackoff::new();
    while !pending.is_empty() || !running.is_empty() {
        while running.len() < jobs.max(1) {
            let Some(job) = pending.pop_front() else { break };
            let child = Command::new(exe)
                .args(job.args(cfg))
                .stdout(std::process::Stdio::null())
                .stderr(std::process::Stdio::null())
                .spawn()
                .with_context(|| format!("spawning worker for {job:?}"))?;
            eprintln!("[launcher] started {} [{}] (pid {})", job.model, job.method, child.id());
            running.push((job, child));
        }
        // reap any finished child
        let mut i = 0;
        let mut reaped = false;
        while i < running.len() {
            if let Some(status) = running[i].1.try_wait()? {
                let (job, _) = running.remove(i);
                let res = if status.success() {
                    std::fs::read_to_string(job.report_path(&cfg.out))
                        .map_err(anyhow::Error::from)
                        .and_then(|t| json::parse(&t))
                } else {
                    Err(anyhow::anyhow!("worker exited with {status}"))
                };
                eprintln!(
                    "[launcher] finished {} [{}]: {}",
                    job.model,
                    job.method,
                    if res.is_ok() { "ok" } else { "FAILED" }
                );
                done.push((job, res));
                reaped = true;
            } else {
                i += 1;
            }
        }
        if reaped {
            backoff.reset();
        } else if !running.is_empty() {
            std::thread::sleep(backoff.step());
        }
    }
    Ok(done)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_args_shape() {
        let cfg = crate::config::RunConfig::default();
        let ours = Job { model: "vgg11".into(), method: "ours".into() };
        let a = ours.args(&cfg);
        assert_eq!(a[0], "compress");
        assert!(a.contains(&"--episodes".to_string()));
        // workers inherit the leader's backend and thread choices
        assert!(a.contains(&"--backend".to_string()));
        assert!(a.contains(&"native".to_string()));
        assert!(a.contains(&"--threads".to_string()));
        assert!(a.contains(&cfg.threads.to_string()));
        let base = Job { model: "vgg11".into(), method: "amc".into() };
        let b = base.args(&cfg);
        assert_eq!(b[0], "baseline");
        assert!(b.contains(&"amc".to_string()));
    }

    #[test]
    fn report_path_convention_matches_save_report() {
        let j = Job { model: "m".into(), method: "ours".into() };
        assert_eq!(
            j.report_path(Path::new("out")),
            PathBuf::from("out/m__ours.json")
        );
    }

    #[test]
    fn reap_backoff_is_bounded_and_resets() {
        let mut b = ReapBackoff::new();
        // every poll interval is capped at the ceiling…
        let mut total = std::time::Duration::ZERO;
        for _ in 0..50 {
            let d = b.step();
            assert!(d <= std::time::Duration::from_millis(ReapBackoff::MAX_MS));
            total += d;
        }
        // …so 50 consecutive misses sleep ≤ 1+2+4+8 + 46·16 = 751 ms
        assert!(total <= std::time::Duration::from_millis(751), "{total:?}");
        // a reap drops back to the 1 ms floor
        b.reset();
        assert_eq!(b.step(), std::time::Duration::from_millis(1));
        assert_eq!(b.step(), std::time::Duration::from_millis(2));
    }

    #[test]
    fn reap_loop_completes_a_grid_with_bounded_overhead() {
        // `true` exits instantly and ignores the job arguments. The
        // deterministic proof that reap dead time is bounded lives in
        // `reap_backoff_is_bounded_and_resets`; this test exercises the
        // real spawn/reap loop end to end, and its coarse wall-clock
        // ceiling (backoff cap × 125, wide headroom for loaded CI
        // machines) only guards against pathological stalls such as a
        // blocking wait that never wakes.
        let out = std::env::temp_dir().join(format!("hapq-launcher-reap-{}", std::process::id()));
        let cfg = crate::config::RunConfig { out: out.clone(), ..Default::default() };
        let grid: Vec<Job> = (0..4)
            .map(|i| Job { model: format!("m{i}"), method: "ours".into() })
            .collect();
        let t0 = std::time::Instant::now();
        let done = run_grid_with(&cfg, grid, 2, Path::new("true")).unwrap();
        let elapsed = t0.elapsed();
        assert_eq!(done.len(), 4);
        // every job result is an Err (no report JSON), not a crash
        assert!(done.iter().all(|(_, r)| r.is_err()));
        let ceiling = std::time::Duration::from_millis(ReapBackoff::MAX_MS * 125);
        assert!(elapsed < ceiling, "reap overhead too high: {elapsed:?}");
        let _ = std::fs::remove_dir_all(out);
    }
}
