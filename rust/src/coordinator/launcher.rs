//! Multi-process launcher — the distributed-runtime face of the
//! coordinator. `hapq compare --jobs N` fans the (model × method) grid
//! out over N child `hapq` processes (one leader, N workers), collects
//! their result JSON from the shared output directory and merges the
//! summary. Process isolation (rather than threads) keeps one inference
//! backend per worker (one PJRT client each on `--backend pjrt`),
//! mirrors how the paper's per-model optimizations are independent, and
//! sidesteps FFI thread-safety questions. The configured `--backend` is
//! forwarded to every worker.

use std::collections::VecDeque;
use std::path::{Path, PathBuf};
use std::process::{Child, Command};

use anyhow::{Context, Result};

use crate::io::json;

/// One unit of work for a child process.
#[derive(Clone, Debug)]
pub struct Job {
    /// model to compress
    pub model: String,
    /// method to run (`ours` or a baseline name)
    pub method: String,
}

impl Job {
    /// CLI args for the child (`compress` for ours, `baseline` otherwise).
    fn args(&self, cfg: &crate::config::RunConfig) -> Vec<String> {
        let mut v = if self.method == "ours" {
            vec!["compress".into(), "--model".into(), self.model.clone()]
        } else {
            vec![
                "baseline".into(),
                "--model".into(),
                self.model.clone(),
                "--method".into(),
                self.method.clone(),
            ]
        };
        v.extend([
            "--artifacts".into(),
            cfg.artifacts.display().to_string(),
            "--out".into(),
            cfg.out.display().to_string(),
            "--episodes".into(),
            cfg.episodes.to_string(),
            "--warmup".into(),
            cfg.warmup.to_string(),
            "--reward-subset".into(),
            cfg.reward_subset.to_string(),
            "--seed".into(),
            cfg.seed.to_string(),
            "--backend".into(),
            cfg.backend.name().to_string(),
        ]);
        v
    }

    /// Where the child process writes its result JSON.
    pub fn report_path(&self, out: &Path) -> PathBuf {
        out.join(format!("{}__{}.json", self.model, self.method))
    }
}

/// Run the grid with at most `jobs` children alive at once. Returns the
/// merged per-job result JSON (jobs that failed are reported as errors
/// in the summary rather than aborting the sweep).
pub fn run_grid(
    cfg: &crate::config::RunConfig,
    grid: Vec<Job>,
    jobs: usize,
) -> Result<Vec<(Job, Result<json::Value>)>> {
    std::fs::create_dir_all(&cfg.out)?;
    let exe = std::env::current_exe().context("locating hapq binary")?;
    let mut pending: VecDeque<Job> = grid.into();
    let mut running: Vec<(Job, Child)> = Vec::new();
    let mut done: Vec<(Job, Result<json::Value>)> = Vec::new();

    while !pending.is_empty() || !running.is_empty() {
        while running.len() < jobs.max(1) {
            let Some(job) = pending.pop_front() else { break };
            let child = Command::new(&exe)
                .args(job.args(cfg))
                .stdout(std::process::Stdio::null())
                .stderr(std::process::Stdio::null())
                .spawn()
                .with_context(|| format!("spawning worker for {job:?}"))?;
            eprintln!("[launcher] started {} [{}] (pid {})", job.model, job.method, child.id());
            running.push((job, child));
        }
        // reap any finished child
        let mut i = 0;
        let mut reaped = false;
        while i < running.len() {
            if let Some(status) = running[i].1.try_wait()? {
                let (job, _) = running.remove(i);
                let res = if status.success() {
                    std::fs::read_to_string(job.report_path(&cfg.out))
                        .map_err(anyhow::Error::from)
                        .and_then(|t| json::parse(&t))
                } else {
                    Err(anyhow::anyhow!("worker exited with {status}"))
                };
                eprintln!(
                    "[launcher] finished {} [{}]: {}",
                    job.model,
                    job.method,
                    if res.is_ok() { "ok" } else { "FAILED" }
                );
                done.push((job, res));
                reaped = true;
            } else {
                i += 1;
            }
        }
        if !reaped {
            std::thread::sleep(std::time::Duration::from_millis(200));
        }
    }
    Ok(done)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_args_shape() {
        let cfg = crate::config::RunConfig::default();
        let ours = Job { model: "vgg11".into(), method: "ours".into() };
        let a = ours.args(&cfg);
        assert_eq!(a[0], "compress");
        assert!(a.contains(&"--episodes".to_string()));
        // workers inherit the leader's backend choice
        assert!(a.contains(&"--backend".to_string()));
        assert!(a.contains(&"native".to_string()));
        let base = Job { model: "vgg11".into(), method: "amc".into() };
        let b = base.args(&cfg);
        assert_eq!(b[0], "baseline");
        assert!(b.contains(&"amc".to_string()));
    }

    #[test]
    fn report_path_convention_matches_save_report() {
        let j = Job { model: "m".into(), method: "ours".into() };
        assert_eq!(
            j.report_path(Path::new("out")),
            PathBuf::from("out/m__ours.json")
        );
    }
}
