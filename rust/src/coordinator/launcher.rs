//! Multi-process launcher — the distributed-runtime face of the
//! coordinator. `hapq compare --jobs N` fans the (model × method) grid
//! out over N child `hapq` processes (one leader, N workers), collects
//! their result JSON from the shared output directory and merges the
//! summary. Process isolation (rather than threads) keeps one inference
//! backend per worker (one PJRT client each on `--backend pjrt`),
//! mirrors how the paper's per-model optimizations are independent, and
//! sidesteps FFI thread-safety questions. Every run-shaping flag the
//! leader was given — backend, kernel, threads, subset sizes, GEMM
//! tile, memoization mode and cache caps — is forwarded to every
//! worker, so a child process reproduces exactly the leader's
//! configuration (`worker_args_inherit_every_run_shaping_flag` pins
//! the full list against drift). Finished children are
//! reaped under an adaptive poll ([`ReapBackoff`]): 1 ms after a reap,
//! doubling to a 16 ms ceiling while everyone keeps running.
//!
//! **Multi-seed search** (`--seeds N`, HAQ-style sweeps) reuses the
//! same pool: [`run_multi_seed`] fans one worker per (model, method,
//! seed) — each writing under `out/seed<K>/` — and
//! [`merge_seed_reports`] folds the per-seed reports into one best-of
//! JSON (winner's full report + `seeds`/`seed_rewards` provenance)
//! under the plain `out/<model>__<method>.json` name.

use std::collections::VecDeque;
use std::path::{Path, PathBuf};
use std::process::{Child, Command};

use anyhow::{anyhow, bail, Context, Result};

use crate::io::json;

/// One unit of work for a child process.
#[derive(Clone, Debug)]
pub struct Job {
    /// model to compress
    pub model: String,
    /// method to run (`ours` or a baseline name)
    pub method: String,
    /// seed override for multi-seed sweeps (`None`: inherit the
    /// leader's seed and write to the shared output directory)
    pub seed: Option<u64>,
    /// hardware-target override for cross-target sweeps (`compare
    /// --hw a,b`; `None`: inherit the leader's `--hw`/`--hw-file`)
    pub hw: Option<String>,
}

impl Job {
    /// The output directory this job writes to (per-target and
    /// per-seed jobs get isolated `hw-<T>/` / `seed<K>/` subdirectories
    /// so sweeps cannot collide).
    fn out_dir(&self, out: &Path) -> PathBuf {
        let mut dir = out.to_path_buf();
        if let Some(hw) = &self.hw {
            dir = dir.join(format!("hw-{hw}"));
        }
        if let Some(s) = self.seed {
            dir = dir.join(format!("seed{s}"));
        }
        dir
    }

    /// CLI args for the child (`compress` for ours, `baseline` otherwise).
    fn args(&self, cfg: &crate::config::RunConfig) -> Vec<String> {
        let mut v = if self.method == "ours" {
            vec!["compress".into(), "--model".into(), self.model.clone()]
        } else {
            vec![
                "baseline".into(),
                "--model".into(),
                self.model.clone(),
                "--method".into(),
                self.method.clone(),
            ]
        };
        v.extend([
            "--artifacts".into(),
            cfg.artifacts.display().to_string(),
            "--out".into(),
            self.out_dir(&cfg.out).display().to_string(),
            "--episodes".into(),
            cfg.episodes.to_string(),
            "--warmup".into(),
            cfg.warmup.to_string(),
            "--reward-subset".into(),
            cfg.reward_subset.to_string(),
            "--seed".into(),
            self.seed.unwrap_or(cfg.seed).to_string(),
            "--backend".into(),
            cfg.backend.name().to_string(),
            "--kernel".into(),
            cfg.kernel.name().to_string(),
            "--threads".into(),
            cfg.threads.to_string(),
            "--test-subset".into(),
            cfg.test_subset.to_string(),
            "--mac-samples".into(),
            cfg.mac_samples.to_string(),
            "--memo".into(),
            if cfg.memo.enabled { "on" } else { "off" }.to_string(),
            "--memo-pack-cap".into(),
            cfg.memo.pack_cap.to_string(),
            "--memo-eval-cap".into(),
            cfg.memo.eval_cap.to_string(),
            "--sched".into(),
            cfg.sched.name().to_string(),
        ]);
        if let Some(tile) = cfg.gemm_tile {
            v.extend(["--gemm-tile".into(), tile.to_string()]);
        }
        // hardware target: an explicit per-job override (cross-target
        // sweeps) beats the leader's profile file, which beats the
        // leader's --hw name
        match (&self.hw, &cfg.hw_file) {
            (Some(hw), _) => v.extend(["--hw".into(), hw.clone()]),
            (None, Some(file)) => {
                v.extend(["--hw-file".into(), file.display().to_string()])
            }
            (None, None) => v.extend(["--hw".into(), cfg.hw.clone()]),
        }
        // tracing leader: each child records its own per-job trace file
        // (an explicit --trace also overrides any inherited HAPQ_TRACE,
        // which would otherwise point every child at the same path);
        // the launcher aggregates them after the sweep
        if cfg.trace.is_some() {
            v.extend(["--trace".into(), self.trace_path(&cfg.out).display().to_string()]);
        }
        v
    }

    /// Where the child process writes its per-job trace (next to its
    /// report, inside the job's isolated output directory).
    pub fn trace_path(&self, out: &Path) -> PathBuf {
        self.out_dir(out).join("trace.jsonl")
    }

    /// Where the child process writes its result JSON.
    pub fn report_path(&self, out: &Path) -> PathBuf {
        self.out_dir(out)
            .join(format!("{}__{}.json", self.model, self.method))
    }
}

/// Adaptive backoff for the reap loop: polling restarts at 1 ms after
/// every successful reap and doubles up to a 16 ms ceiling while
/// children keep running. Worst-case dead time between a child exiting
/// and its reap is one ceiling interval — the previous fixed 200 ms
/// poll cost up to 200 ms of dead time per worker exit.
#[derive(Debug)]
pub struct ReapBackoff {
    next_ms: u64,
}

impl ReapBackoff {
    /// Poll-interval ceiling in milliseconds.
    pub const MAX_MS: u64 = 16;

    /// Start at the 1 ms floor.
    pub fn new() -> ReapBackoff {
        ReapBackoff { next_ms: 1 }
    }

    /// The duration to sleep before the next poll; doubles up to
    /// [`Self::MAX_MS`].
    pub fn step(&mut self) -> std::time::Duration {
        let d = std::time::Duration::from_millis(self.next_ms);
        self.next_ms = (self.next_ms * 2).min(Self::MAX_MS);
        d
    }

    /// A child was reaped — drop back to the floor.
    pub fn reset(&mut self) {
        self.next_ms = 1;
    }
}

impl Default for ReapBackoff {
    fn default() -> Self {
        Self::new()
    }
}

/// Run the grid with at most `jobs` children alive at once. Returns the
/// merged per-job result JSON (jobs that failed are reported as errors
/// in the summary rather than aborting the sweep).
pub fn run_grid(
    cfg: &crate::config::RunConfig,
    grid: Vec<Job>,
    jobs: usize,
) -> Result<Vec<(Job, Result<json::Value>)>> {
    let exe = std::env::current_exe().context("locating hapq binary")?;
    run_grid_with(cfg, grid, jobs, &exe)
}

/// Like [`run_grid`] but with an explicit worker executable — the
/// launcher tests substitute a stub binary to measure reap overhead
/// without running real compressions.
pub fn run_grid_with(
    cfg: &crate::config::RunConfig,
    grid: Vec<Job>,
    jobs: usize,
    exe: &Path,
) -> Result<Vec<(Job, Result<json::Value>)>> {
    std::fs::create_dir_all(&cfg.out)?;
    let mut pending: VecDeque<Job> = grid.into();
    let mut running: Vec<(Job, Child)> = Vec::new();
    let mut done: Vec<(Job, Result<json::Value>)> = Vec::new();

    let mut backoff = ReapBackoff::new();
    while !pending.is_empty() || !running.is_empty() {
        while running.len() < jobs.max(1) {
            let Some(job) = pending.pop_front() else { break };
            let child = Command::new(exe)
                .args(job.args(cfg))
                .stdout(std::process::Stdio::null())
                .stderr(std::process::Stdio::null())
                .spawn()
                .with_context(|| format!("spawning worker for {job:?}"))?;
            eprintln!("[launcher] started {} [{}] (pid {})", job.model, job.method, child.id());
            running.push((job, child));
        }
        // reap any finished child
        let mut i = 0;
        let mut reaped = false;
        while i < running.len() {
            if let Some(status) = running[i].1.try_wait()? {
                let (job, _) = running.remove(i);
                let res = if status.success() {
                    std::fs::read_to_string(job.report_path(&cfg.out))
                        .map_err(anyhow::Error::from)
                        .and_then(|t| json::parse(&t))
                } else {
                    Err(anyhow::anyhow!("worker exited with {status}"))
                };
                eprintln!(
                    "[launcher] finished {} [{}]: {}",
                    job.model,
                    job.method,
                    if res.is_ok() { "ok" } else { "FAILED" }
                );
                done.push((job, res));
                reaped = true;
            } else {
                i += 1;
            }
        }
        if reaped {
            backoff.reset();
        } else if !running.is_empty() {
            std::thread::sleep(backoff.step());
        }
    }
    if let Some(dest) = &cfg.trace {
        match aggregate_traces(cfg, &done, dest) {
            Ok(n) if n > 0 => {
                eprintln!("[launcher] aggregated {n} child traces -> {}", dest.display())
            }
            Ok(_) => {}
            Err(e) => eprintln!("[launcher] trace aggregation failed: {e:#}"),
        }
    }
    archive_reports(cfg, &done)?;
    Ok(done)
}

/// Fold every successful worker report into the leader's cross-run
/// Pareto archive (`<out>/pareto.json`) in deterministic
/// (model, method, hw, seed) order. Workers already archived into
/// their own isolated out dirs; this leader-side fold is what makes
/// `--jobs`/`--seeds` fan-outs land in *one* cumulative archive, with
/// bytes identical to the equivalent sequential runs (and it re-heals
/// any insert a concurrent same-dir worker may have overwritten).
fn archive_reports(
    cfg: &crate::config::RunConfig,
    done: &[(Job, Result<json::Value>)],
) -> Result<()> {
    let mut ok: Vec<(&Job, &json::Value)> =
        done.iter().filter_map(|(j, r)| r.as_ref().ok().map(|v| (j, v))).collect();
    if ok.is_empty() {
        return Ok(());
    }
    ok.sort_by(|(a, _), (b, _)| {
        (&a.model, &a.method, &a.hw, a.seed).cmp(&(&b.model, &b.method, &b.hw, b.seed))
    });
    let reports: Vec<&json::Value> = ok.iter().map(|(_, v)| *v).collect();
    let path = cfg.out.join(crate::search::archive::ARCHIVE_FILE);
    crate::search::archive::record_reports(&path, &reports)
        .with_context(|| format!("archiving sweep reports into {path:?}"))?;
    Ok(())
}

/// Merge the children's per-job trace files into one JSONL at `dest`:
/// a fresh leader `meta` header, then every child's events — jobs in
/// deterministic (model, method, hw, seed) order, each event annotated
/// with a `job` label so `hapq trace` can tell the streams apart.
/// Returns the number of child traces merged; children that wrote no
/// trace (or unparsable lines) are skipped, not fatal.
fn aggregate_traces(
    cfg: &crate::config::RunConfig,
    done: &[(Job, Result<json::Value>)],
    dest: &Path,
) -> Result<usize> {
    let mut out = String::new();
    out.push_str(
        &json::obj(vec![
            ("kind", json::s("meta")),
            ("schema", json::num(crate::telemetry::SCHEMA as f64)),
            ("source", json::s("hapq-launcher")),
        ])
        .to_string(),
    );
    out.push('\n');
    let mut jobs: Vec<&Job> = done.iter().map(|(j, _)| j).collect();
    jobs.sort_by(|a, b| {
        (&a.model, &a.method, &a.hw, a.seed).cmp(&(&b.model, &b.method, &b.hw, b.seed))
    });
    let mut merged = 0usize;
    for job in jobs {
        let path = job.trace_path(&cfg.out);
        let Ok(text) = std::fs::read_to_string(&path) else { continue };
        let mut label = format!("{}/{}", job.model, job.method);
        if let Some(hw) = &job.hw {
            label.push_str(&format!("/hw-{hw}"));
        }
        if let Some(s) = job.seed {
            label.push_str(&format!("/seed{s}"));
        }
        let mut any = false;
        for line in text.lines() {
            let Ok(mut v) = json::parse(line) else { continue };
            if v.get("kind").and_then(|k| k.as_str().ok()) == Some("meta") {
                continue;
            }
            set_field(&mut v, "job", json::s(&label))?;
            out.push_str(&v.to_string());
            out.push('\n');
            any = true;
        }
        if any {
            merged += 1;
        }
    }
    if merged > 0 {
        if let Some(dir) = dest.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(dest, out).with_context(|| format!("writing trace {dest:?}"))?;
    }
    Ok(merged)
}

/// Overwrite-or-append one field of a report object.
fn set_field(v: &mut json::Value, key: &str, val: json::Value) -> Result<()> {
    if let json::Value::Obj(kv) = v {
        if let Some(slot) = kv.iter_mut().find(|(k, _)| k == key) {
            slot.1 = val;
        } else {
            kv.push((key.to_string(), val));
        }
        Ok(())
    } else {
        bail!("report JSON is not an object")
    }
}

/// Fold per-seed run reports into one best-of report: the winner (the
/// paper's selection rule — highest reward, first entry wins ties, so
/// pass reports in ascending-seed order for a deterministic
/// lowest-seed tie-break) is kept verbatim, annotated with `seed` (the
/// winning seed), `seeds` (reports merged) and `seed_rewards`
/// (per-seed rewards, input order). [`run_multi_seed`] additionally
/// overwrites `seeds` with the requested sweep width and records
/// `failed_seeds`, so partial sweeps stay auditable from the merged
/// JSON alone.
pub fn merge_seed_reports(per_seed: &[(u64, json::Value)]) -> Result<json::Value> {
    if per_seed.is_empty() {
        bail!("no per-seed reports to merge");
    }
    let mut best_i = 0usize;
    let mut best_r = f64::NEG_INFINITY;
    let mut rewards = Vec::with_capacity(per_seed.len());
    let mut non_finite: Vec<u64> = Vec::new();
    for (i, (seed, v)) in per_seed.iter().enumerate() {
        let r = v.req("reward")?.as_f64()?;
        if !r.is_finite() {
            // NaN can never win `r > best_r`, so without this check an
            // all-NaN sweep would silently crown the first seed
            non_finite.push(*seed);
        }
        rewards.push(r);
        if r > best_r {
            best_r = r;
            best_i = i;
        }
    }
    if !non_finite.is_empty() {
        bail!(
            "non-finite reward in seed report(s) {non_finite:?} — refusing to merge \
             a corrupt sweep (re-run the offending seed(s) or drop their reports)"
        );
    }
    let (seed, best) = &per_seed[best_i];
    let mut merged = best.clone();
    set_field(&mut merged, "seed", json::num(*seed as f64))?;
    set_field(&mut merged, "seeds", json::num(per_seed.len() as f64))?;
    set_field(
        &mut merged,
        "seed_rewards",
        json::arr(rewards.iter().map(|&r| json::num(r)).collect()),
    )?;
    Ok(merged)
}

/// Per-(model, method) outcome of a multi-seed sweep: the merged
/// best-of report, or an error when every seed failed.
pub type SeedSweepResults = Vec<((String, String), Result<json::Value>)>;

/// Multi-seed search over a set of (model, method) pairs: fans one
/// worker per (pair × seed) across the pool (`cfg.seeds` consecutive
/// seeds starting at `cfg.seed`, at most `jobs` children alive), then
/// merges each pair's per-seed reports into one best-of JSON written to
/// `out/<model>__<method>.json`. A pair fails only when *every* seed
/// failed; partial sweeps merge what succeeded.
pub fn run_multi_seed(
    cfg: &crate::config::RunConfig,
    pairs: &[(String, String)],
    jobs: usize,
) -> Result<SeedSweepResults> {
    let exe = std::env::current_exe().context("locating hapq binary")?;
    run_multi_seed_with(cfg, pairs, jobs, &exe)
}

/// Like [`run_multi_seed`] but with an explicit worker executable (the
/// launcher tests substitute a stub binary).
pub fn run_multi_seed_with(
    cfg: &crate::config::RunConfig,
    pairs: &[(String, String)],
    jobs: usize,
    exe: &Path,
) -> Result<SeedSweepResults> {
    let mut grid = Vec::with_capacity(pairs.len() * cfg.seeds);
    for (model, method) in pairs {
        for i in 0..cfg.seeds {
            grid.push(Job {
                model: model.clone(),
                method: method.clone(),
                seed: Some(cfg.seed + i as u64),
                hw: None,
            });
        }
    }
    let done = run_grid_with(cfg, grid, jobs, exe)?;
    let mut merged_all = Vec::with_capacity(pairs.len());
    for (model, method) in pairs {
        let mut per_seed: Vec<(u64, json::Value)> = Vec::new();
        let mut failed: Vec<u64> = Vec::new();
        let mut errors: Vec<String> = Vec::new();
        for (job, res) in &done {
            if &job.model == model && &job.method == method {
                let seed = job.seed.unwrap_or(cfg.seed);
                match res {
                    Ok(v) => per_seed.push((seed, v.clone())),
                    Err(e) => {
                        failed.push(seed);
                        errors.push(format!("seed {seed}: {e}"));
                    }
                }
            }
        }
        // `done` is in worker-completion order — restore seed order so
        // seed_rewards is positional and equal-reward ties break to the
        // lowest seed, deterministically
        per_seed.sort_by_key(|(seed, _)| *seed);
        failed.sort_unstable();
        let merged = if per_seed.is_empty() {
            Err(anyhow!(
                "all {} seeds failed for {model}/{method}: {}",
                cfg.seeds,
                errors.join("; ")
            ))
        } else {
            merge_seed_reports(&per_seed).and_then(|mut m| {
                // record the *requested* sweep width and any failed
                // seeds, so a partial sweep is auditable from the JSON
                set_field(&mut m, "seeds", json::num(cfg.seeds as f64))?;
                if !failed.is_empty() {
                    set_field(
                        &mut m,
                        "failed_seeds",
                        json::arr(failed.iter().map(|&s| json::num(s as f64)).collect()),
                    )?;
                }
                let path = cfg.out.join(format!("{model}__{method}.json"));
                std::fs::write(&path, m.to_string())
                    .with_context(|| format!("writing merged report {path:?}"))?;
                Ok(m)
            })
        };
        merged_all.push(((model.clone(), method.clone()), merged));
    }
    Ok(merged_all)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_args_shape() {
        let cfg = crate::config::RunConfig::default();
        let ours = Job { model: "vgg11".into(), method: "ours".into(), seed: None, hw: None };
        let a = ours.args(&cfg);
        assert_eq!(a[0], "compress");
        assert!(a.contains(&"--episodes".to_string()));
        // workers inherit the leader's backend, kernel, thread and
        // hardware-target choices
        assert!(a.contains(&"--backend".to_string()));
        assert!(a.contains(&"native".to_string()));
        assert!(a.contains(&"--kernel".to_string()));
        assert!(a.contains(&cfg.kernel.name().to_string()));
        assert!(a.contains(&"--threads".to_string()));
        assert!(a.contains(&cfg.threads.to_string()));
        assert!(a.contains(&"--hw".to_string()));
        assert!(a.contains(&cfg.hw));
        let base = Job { model: "vgg11".into(), method: "amc".into(), seed: None, hw: None };
        let b = base.args(&cfg);
        assert_eq!(b[0], "baseline");
        assert!(b.contains(&"amc".to_string()));
    }

    #[test]
    fn worker_args_inherit_every_run_shaping_flag() {
        // one table for the whole inherit list: when a flag that shapes
        // the run is added to RunConfig, it must be forwarded here too,
        // or workers silently run a different configuration than the
        // leader (this is exactly how --gemm-tile / --test-subset /
        // --mac-samples once drifted)
        let mut cfg = crate::config::RunConfig::default();
        cfg.episodes = 123;
        cfg.warmup = 17;
        cfg.reward_subset = 640;
        cfg.test_subset = 1280;
        cfg.mac_samples = 4096;
        cfg.seed = 99;
        cfg.threads = 3;
        cfg.gemm_tile = Some(32);
        cfg.memo.enabled = false;
        cfg.memo.pack_cap = 77;
        cfg.memo.eval_cap = 888;
        cfg.sched = crate::runtime::SchedKind::Static;
        let j = Job { model: "vgg11".into(), method: "ours".into(), seed: None, hw: None };
        let a = j.args(&cfg);
        let expect: &[(&str, String)] = &[
            ("--artifacts", cfg.artifacts.display().to_string()),
            ("--out", cfg.out.display().to_string()),
            ("--episodes", "123".into()),
            ("--warmup", "17".into()),
            ("--reward-subset", "640".into()),
            ("--test-subset", "1280".into()),
            ("--mac-samples", "4096".into()),
            ("--seed", "99".into()),
            ("--backend", cfg.backend.name().into()),
            ("--kernel", cfg.kernel.name().into()),
            ("--threads", "3".into()),
            ("--gemm-tile", "32".into()),
            ("--memo", "off".into()),
            ("--memo-pack-cap", "77".into()),
            ("--memo-eval-cap", "888".into()),
            ("--sched", "static".into()),
            ("--hw", cfg.hw.clone()),
        ];
        for (flag, want) in expect {
            let i = a
                .iter()
                .position(|x| x == flag)
                .unwrap_or_else(|| panic!("{flag} not forwarded to workers"));
            assert_eq!(&a[i + 1], want, "{flag} forwarded with the wrong value");
        }
        // a default config has no tile override, so the flag is omitted
        // and the worker falls back to the same HAPQ_GEMM_TILE default
        cfg.gemm_tile = None;
        assert!(!j.args(&cfg).contains(&"--gemm-tile".to_string()));
        // memo on forwards as the literal `on`
        cfg.memo.enabled = true;
        let a = j.args(&cfg);
        let mi = a.iter().position(|x| x == "--memo").unwrap();
        assert_eq!(a[mi + 1], "on");
    }

    #[test]
    fn hw_override_and_profile_file_forwarding() {
        let mut cfg = crate::config::RunConfig::default();
        // a per-job target override wins and isolates the out dir
        let j = Job { model: "vgg11".into(), method: "ours".into(), seed: None, hw: Some("mcu".into()) };
        let a = j.args(&cfg);
        let hi = a.iter().position(|x| x == "--hw").unwrap();
        assert_eq!(a[hi + 1], "mcu");
        let oi = a.iter().position(|x| x == "--out").unwrap();
        assert_eq!(a[oi + 1], cfg.out.join("hw-mcu").display().to_string());
        assert_eq!(
            j.report_path(Path::new("out")),
            PathBuf::from("out/hw-mcu/vgg11__ours.json")
        );
        // a leader --hw-file is forwarded verbatim to non-override jobs
        cfg.hw_file = Some(PathBuf::from("profiles/npu.json"));
        let j = Job { model: "vgg11".into(), method: "ours".into(), seed: None, hw: None };
        let a = j.args(&cfg);
        let fi = a.iter().position(|x| x == "--hw-file").unwrap();
        assert_eq!(a[fi + 1], "profiles/npu.json");
        assert!(!a.contains(&"--hw".to_string()));
        // ...but a per-job override still beats the file
        let j = Job { model: "vgg11".into(), method: "ours".into(), seed: None, hw: Some("bitfusion".into()) };
        let a = j.args(&cfg);
        assert!(a.contains(&"--hw".to_string()));
        assert!(!a.contains(&"--hw-file".to_string()));
        // target + seed compose into nested isolation dirs
        let j = Job { model: "m".into(), method: "haq".into(), seed: Some(7), hw: Some("mcu".into()) };
        assert_eq!(
            j.report_path(Path::new("out")),
            PathBuf::from("out/hw-mcu/seed7/m__haq.json")
        );
    }

    #[test]
    fn trace_flag_forwards_per_job_paths_and_aggregates() {
        // a tracing leader hands every child its own --trace path…
        let mut cfg = crate::config::RunConfig::default();
        cfg.trace = None;
        let j = Job { model: "m".into(), method: "ours".into(), seed: Some(7), hw: None };
        assert!(!j.args(&cfg).contains(&"--trace".to_string()));
        cfg.trace = Some(PathBuf::from("out/trace.jsonl"));
        let a = j.args(&cfg);
        let ti = a.iter().position(|x| x == "--trace").unwrap();
        assert_eq!(a[ti + 1], cfg.out.join("seed7/trace.jsonl").display().to_string());
        // …and folds the child files back into one labelled stream
        let out = std::env::temp_dir().join(format!("hapq-launcher-trace-{}", std::process::id()));
        let dest = out.join("trace.jsonl");
        let cfg =
            crate::config::RunConfig { out: out.clone(), trace: Some(dest.clone()), ..Default::default() };
        let mk = |seed: u64| Job { model: "m".into(), method: "haq".into(), seed: Some(seed), hw: None };
        for seed in [43u64, 42] {
            let p = mk(seed).trace_path(&out);
            std::fs::create_dir_all(p.parent().unwrap()).unwrap();
            std::fs::write(
                &p,
                format!(
                    "{{\"kind\":\"meta\",\"schema\":1,\"source\":\"hapq\"}}\n\
                     {{\"kind\":\"count\",\"name\":\"c\",\"thread\":\"main\",\"seq\":0,\"n\":{seed}}}\n"
                ),
            )
            .unwrap();
        }
        let done: Vec<(Job, Result<json::Value>)> =
            vec![(mk(43), Err(anyhow!("x"))), (mk(42), Err(anyhow!("x")))];
        assert_eq!(aggregate_traces(&cfg, &done, &dest).unwrap(), 2);
        let text = std::fs::read_to_string(&dest).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        // leader meta + one event per child, child metas dropped, and
        // the jobs land in seed order regardless of completion order
        assert_eq!(lines.len(), 3, "{text}");
        let meta = json::parse(lines[0]).unwrap();
        assert_eq!(meta.req("source").unwrap().as_str().unwrap(), "hapq-launcher");
        let e1 = json::parse(lines[1]).unwrap();
        assert_eq!(e1.req("job").unwrap().as_str().unwrap(), "m/haq/seed42");
        assert_eq!(e1.req("n").unwrap().as_f64().unwrap(), 42.0);
        let e2 = json::parse(lines[2]).unwrap();
        assert_eq!(e2.req("job").unwrap().as_str().unwrap(), "m/haq/seed43");
        let _ = std::fs::remove_dir_all(out);
    }

    #[test]
    fn seeded_jobs_get_isolated_seed_and_out_dir() {
        let cfg = crate::config::RunConfig::default();
        let j = Job { model: "vgg11".into(), method: "haq".into(), seed: Some(43), hw: None };
        let a = j.args(&cfg);
        // the seed override replaces the leader's seed…
        let si = a.iter().position(|x| x == "--seed").unwrap();
        assert_eq!(a[si + 1], "43");
        // …and the report lands in a per-seed subdirectory
        let oi = a.iter().position(|x| x == "--out").unwrap();
        assert_eq!(a[oi + 1], cfg.out.join("seed43").display().to_string());
        assert_eq!(
            j.report_path(Path::new("out")),
            PathBuf::from("out/seed43/vgg11__haq.json")
        );
    }

    #[test]
    fn report_path_convention_matches_save_report() {
        let j = Job { model: "m".into(), method: "ours".into(), seed: None, hw: None };
        assert_eq!(
            j.report_path(Path::new("out")),
            PathBuf::from("out/m__ours.json")
        );
    }

    #[test]
    fn merge_picks_highest_reward_and_annotates_provenance() {
        let report = |seed: u64, reward: f64| {
            (
                seed,
                json::parse(&format!(
                    r#"{{"model":"m","method":"haq","seed":{seed},"reward":{reward},"energy_gain":0.4}}"#
                ))
                .unwrap(),
            )
        };
        let merged =
            merge_seed_reports(&[report(42, 1.5), report(43, 2.25), report(44, 2.25)]).unwrap();
        // strict > keeps the first of equal-reward seeds (the paper's
        // better() rule), and the winner's fields survive verbatim
        assert_eq!(merged.req("seed").unwrap().as_f64().unwrap(), 43.0);
        assert_eq!(merged.req("reward").unwrap().as_f64().unwrap(), 2.25);
        assert_eq!(merged.req("seeds").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(
            merged.req("seed_rewards").unwrap().f64_vec().unwrap(),
            vec![1.5, 2.25, 2.25]
        );
        assert!(merge_seed_reports(&[]).is_err());
    }

    #[test]
    fn merge_rejects_non_finite_rewards_naming_the_seeds() {
        // json::parse cannot produce NaN, so build the reports
        // programmatically — exactly what a corrupt worker report
        // deserialises to before the reward comparison
        let report = |seed: u64, reward: f64| {
            (
                seed,
                json::obj(vec![
                    ("model", json::s("m")),
                    ("method", json::s("haq")),
                    ("seed", json::num(seed as f64)),
                    ("reward", json::num(reward)),
                ]),
            )
        };
        // mixed: one NaN seed must abort the merge and be named, even
        // though a finite winner exists
        let err = merge_seed_reports(&[report(42, 1.5), report(43, f64::NAN)])
            .unwrap_err()
            .to_string();
        assert!(err.contains("[43]"), "offending seed not named: {err}");
        // all-NaN: the old `r > best_r` scan silently crowned seed
        // index 0 here — now every seed is listed
        let err = merge_seed_reports(&[report(42, f64::NAN), report(43, f64::NAN)])
            .unwrap_err()
            .to_string();
        assert!(err.contains("[42, 43]"), "{err}");
        // infinities are just as un-mergeable as NaN
        assert!(merge_seed_reports(&[report(7, f64::INFINITY)]).is_err());
        assert!(merge_seed_reports(&[report(7, f64::NEG_INFINITY)]).is_err());
    }

    #[test]
    fn grid_archives_successful_reports_into_one_leader_archive() {
        use crate::search::archive;
        let out =
            std::env::temp_dir().join(format!("hapq-launcher-archive-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&out);
        let cfg = crate::config::RunConfig { out: out.clone(), ..Default::default() };
        let mk = |hw: &str, seed: u64, eg: f64| {
            let job = Job {
                model: "m".into(),
                method: "ours".into(),
                seed: Some(seed),
                hw: Some(hw.to_string()),
            };
            let v = json::obj(vec![
                ("model", json::s("m")),
                ("fingerprint", json::s("00000000000000aa")),
                ("hw", json::s(hw)),
                ("method", json::s("ours")),
                ("seed", json::num(seed as f64)),
                ("test_acc", json::num(0.88)),
                ("test_acc_loss", json::num(0.02)),
                ("val_acc_loss", json::num(0.018)),
                ("energy_gain", json::num(eg)),
                ("latency_gain", json::num(0.4)),
                ("reward", json::num(1.0 + eg)),
                ("per_layer", json::arr(vec![])),
            ]);
            (job, Ok(v))
        };
        // two targets + one failed job: the failure is skipped, the two
        // successes land in one leader archive, one group per target
        let done: Vec<(Job, Result<json::Value>)> = vec![
            mk("mcu", 7, 0.6),
            (
                Job { model: "m".into(), method: "amc".into(), seed: None, hw: None },
                Err(anyhow!("worker exploded")),
            ),
            mk("eyeriss-64", 3, 0.5),
        ];
        archive_reports(&cfg, &done).unwrap();
        let a = archive::ParetoArchive::load(&out.join(archive::ARCHIVE_FILE)).unwrap();
        assert_eq!(a.entries().len(), 2);
        assert_eq!(a.groups().len(), 2);
        assert!(archive::agrees_with_nondominated_sort(&a));
        // re-folding the same reports is idempotent (byte-stable file)
        let before = std::fs::read_to_string(out.join(archive::ARCHIVE_FILE)).unwrap();
        archive_reports(&cfg, &done).unwrap();
        let after = std::fs::read_to_string(out.join(archive::ARCHIVE_FILE)).unwrap();
        assert_eq!(before, after);
        let _ = std::fs::remove_dir_all(out);
    }

    #[test]
    fn reap_backoff_is_bounded_and_resets() {
        let mut b = ReapBackoff::new();
        // every poll interval is capped at the ceiling…
        let mut total = std::time::Duration::ZERO;
        for _ in 0..50 {
            let d = b.step();
            assert!(d <= std::time::Duration::from_millis(ReapBackoff::MAX_MS));
            total += d;
        }
        // …so 50 consecutive misses sleep ≤ 1+2+4+8 + 46·16 = 751 ms
        assert!(total <= std::time::Duration::from_millis(751), "{total:?}");
        // a reap drops back to the 1 ms floor
        b.reset();
        assert_eq!(b.step(), std::time::Duration::from_millis(1));
        assert_eq!(b.step(), std::time::Duration::from_millis(2));
    }

    #[test]
    fn multi_seed_sweep_surfaces_all_seed_failures() {
        // the stub worker produces no report JSON, so every seed fails
        // and the pair must come back as one aggregated error (not a
        // crash, and no merged file)
        let out =
            std::env::temp_dir().join(format!("hapq-launcher-seeds-{}", std::process::id()));
        let cfg = crate::config::RunConfig { out: out.clone(), seeds: 2, ..Default::default() };
        let pairs = vec![("m0".to_string(), "haq".to_string())];
        let done = run_multi_seed_with(&cfg, &pairs, 2, Path::new("true")).unwrap();
        assert_eq!(done.len(), 1);
        assert!(done[0].1.is_err());
        assert!(!out.join("m0__haq.json").exists());
        let _ = std::fs::remove_dir_all(out);
    }

    #[test]
    fn reap_loop_completes_a_grid_with_bounded_overhead() {
        // `true` exits instantly and ignores the job arguments. The
        // deterministic proof that reap dead time is bounded lives in
        // `reap_backoff_is_bounded_and_resets`; this test exercises the
        // real spawn/reap loop end to end, and its coarse wall-clock
        // ceiling (backoff cap × 125, wide headroom for loaded CI
        // machines) only guards against pathological stalls such as a
        // blocking wait that never wakes.
        let out = std::env::temp_dir().join(format!("hapq-launcher-reap-{}", std::process::id()));
        let cfg = crate::config::RunConfig { out: out.clone(), ..Default::default() };
        let grid: Vec<Job> = (0..4)
            .map(|i| Job { model: format!("m{i}"), method: "ours".into(), seed: None, hw: None })
            .collect();
        let t0 = std::time::Instant::now();
        let done = run_grid_with(&cfg, grid, 2, Path::new("true")).unwrap();
        let elapsed = t0.elapsed();
        assert_eq!(done.len(), 4);
        // every job result is an Err (no report JSON), not a crash
        assert!(done.iter().all(|(_, r)| r.is_err()));
        let ceiling = std::time::Duration::from_millis(ReapBackoff::MAX_MS * 125);
        assert!(elapsed < ceiling, "reap overhead too high: {elapsed:?}");
        let _ = std::fs::remove_dir_all(out);
    }
}
