//! Figure/table regeneration logic (shared by `hapq <fig>` CLI commands
//! and the `cargo bench` harnesses). Each function returns printable
//! rows mirroring what the paper plots; EXPERIMENTS.md records the
//! paper-vs-measured comparison.

use anyhow::Result;

use crate::env::{Action, CompressionEnv};
use crate::env::lut::RewardLut;
use crate::pruning::PruneAlg;
use crate::util::rng::Rng;

use super::Coordinator;

/// Fig 1: accuracy loss & energy gain vs sparsity, fine (Level) vs
/// coarse (L1-Ranked), at 8-bit precision.
pub struct Fig1Row {
    /// uniform per-layer sparsity applied
    pub sparsity: f64,
    /// pruning algorithm name
    pub alg: &'static str,
    /// accuracy loss vs the dense baseline (fraction)
    pub acc_loss: f64,
    /// energy gain vs the dense baseline (fraction)
    pub energy_gain: f64,
}

/// Evaluate the Fig 1 sweep on `points` sparsity levels.
pub fn fig1_sweep(env: &mut CompressionEnv, points: &[f64]) -> Result<Vec<Fig1Row>> {
    let n = env.n_layers();
    let mut rows = Vec::new();
    for &alg in &[PruneAlg::Level, PruneAlg::L1Ranked] {
        for &sp in points {
            let actions = vec![
                Action {
                    ratio: sp / crate::env::MAX_RATIO,
                    bits: 1.0,
                    alg: alg.index(),
                };
                n
            ];
            let sol = env.evaluate_config(&actions)?;
            rows.push(Fig1Row {
                sparsity: sp,
                alg: alg.name(),
                acc_loss: sol.acc_loss,
                energy_gain: sol.energy_gain,
            });
        }
    }
    Ok(rows)
}

/// Fig 2a: whole-accelerator energy reduction for (Qw, Qa) pairs on a
/// fixed-precision MAC accelerator (weights stay dense). R_Q follows
/// the env's hardware target — the MAC-sim table on `mac-sim` targets,
/// the bit-width product on bit-serial ones.
pub fn fig2a_grid(env: &CompressionEnv) -> Vec<(u32, u32, f64)> {
    let em = env.cost.model();
    let mut e_mem = 0.0;
    let mut e_comp = 0.0;
    for l in 0..env.n_layers() {
        let m = em.mapping(l);
        e_mem += m.mem_energy(em.acc());
        e_comp += m.macs as f64 * em.acc().e_mac;
    }
    let total = e_mem + e_comp;
    let mut out = Vec::new();
    for qw in 2..=8u32 {
        for qa in 2..=8u32 {
            let rq = em.rq_pair(qw, qa);
            let reduced = e_mem + e_comp * rq;
            out.push((qw, qa, 1.0 - reduced / total));
        }
    }
    out
}

/// Fig 2b: uniform vs per-layer mixed precision energy/accuracy points
/// (no pruning). Mixed points come from a seeded random search, which
/// is what populates the paper's richer Pareto front.
pub struct Fig2bPoint {
    /// `uniform` or `mixed`
    pub kind: &'static str,
    /// accuracy loss vs the dense baseline (fraction)
    pub acc_loss: f64,
    /// energy gain vs the dense baseline (fraction)
    pub energy_gain: f64,
}

/// Evaluate the Fig 2b uniform sweep + mixed-precision samples.
pub fn fig2b_points(
    env: &mut CompressionEnv,
    mixed_samples: usize,
    seed: u64,
) -> Result<Vec<Fig2bPoint>> {
    let n = env.n_layers();
    let mut pts = Vec::new();
    for bits in 2..=8u32 {
        let b = (bits - 2) as f64 / 6.0;
        let actions = vec![Action { ratio: 0.0, bits: b, alg: 0 }; n];
        let sol = env.evaluate_config(&actions)?;
        pts.push(Fig2bPoint {
            kind: "uniform",
            acc_loss: sol.acc_loss,
            energy_gain: sol.energy_gain,
        });
    }
    // Mixed points: biased sampling toward high precision with a few
    // aggressive layers — the region an actual mixed-precision *search*
    // (Fig 2b's point) explores; uniform-random bit vectors almost never
    // land in the low-loss band on a no-retraining model.
    let mut rng = Rng::new(seed);
    for s in 0..mixed_samples {
        let n_low = 1 + s % (n / 2).max(1);
        let low_layers = rng.choose_k(n, n_low);
        let actions: Vec<Action> = (0..n)
            .map(|l| {
                let bits = if low_layers.contains(&l) {
                    rng.range(0.0, 0.6) // 2-5.5 bits on the chosen few
                } else {
                    rng.range(0.7, 1.0) // 6-8 bits elsewhere
                };
                Action { ratio: 0.0, bits, alg: 0 }
            })
            .collect();
        let sol = env.evaluate_config(&actions)?;
        pts.push(Fig2bPoint {
            kind: "mixed",
            acc_loss: sol.acc_loss,
            energy_gain: sol.energy_gain,
        });
    }
    Ok(pts)
}

/// Keep only Pareto-optimal (min loss, max gain) points.
pub fn pareto(points: &[(f64, f64)]) -> Vec<(f64, f64)> {
    let mut out: Vec<(f64, f64)> = Vec::new();
    for &(l, g) in points {
        if !points
            .iter()
            .any(|&(l2, g2)| (l2 <= l && g2 > g) || (l2 < l && g2 >= g))
        {
            out.push((l, g));
        }
    }
    out.sort_by(|a, b| a.0.total_cmp(&b.0));
    out
}

/// Fig 5: the reward LUT heatmap (sub-sampled like the paper's plot).
pub fn fig5_heatmap(stride: usize) -> Vec<Vec<f64>> {
    let lut = RewardLut::paper();
    lut.grid
        .iter()
        .step_by(stride)
        .map(|row| row.iter().step_by(stride).copied().collect())
        .collect()
}

/// Fig 8 rows: the per-layer policy of a finished run.
pub fn fig8_rows(report: &super::RunReport) -> Vec<(usize, String, f64, u32)> {
    report
        .best
        .per_layer
        .iter()
        .enumerate()
        .map(|(i, a)| (i, a.alg.name().to_string(), a.sparsity, a.bits))
        .collect()
}

/// Convenience: build env + run fig1 for the three paper models that
/// exist in the manifest (VGG16, ResNet50, MobileNetV2 — Fig 1 uses
/// their CIFAR variants; we use the manifest datasets).
pub fn fig1_models(coord: &Coordinator) -> Vec<String> {
    ["vgg16", "resnet50", "mobilenetv2"]
        .iter()
        .filter(|m| coord.entry(m).is_ok())
        .map(|m| m.to_string())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pareto_filters_dominated() {
        let pts = vec![(0.01, 0.3), (0.02, 0.2), (0.02, 0.5), (0.05, 0.4)];
        let p = pareto(&pts);
        assert!(p.contains(&(0.01, 0.3)));
        assert!(p.contains(&(0.02, 0.5)));
        assert!(!p.contains(&(0.02, 0.2)));
        assert!(!p.contains(&(0.05, 0.4)));
    }

    #[test]
    fn fig5_shape() {
        let h = fig5_heatmap(4);
        assert_eq!(h.len(), 10);
        assert_eq!(h[0].len(), 10);
    }
}
