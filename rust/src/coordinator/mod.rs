//! The coordinator — HAPQ's L3 driver.
//!
//! Owns the artifact manifest, the shared R_Q table, the backend
//! selection, and the search glue: it builds a [`CompressionEnv`] per
//! model, wires the method (composite agent or a baseline) into a
//! [`crate::search::SearchStrategy`], runs it through the unified
//! [`SearchDriver`] (budgets, best tracking, `--resume` checkpointing,
//! `--stop-after` suspension), re-scores the winner on the held-out
//! test split and emits result JSON + metrics. Everything the CLI, the
//! examples and the benches do goes through this module; multi-seed
//! fan-out (`--seeds N`) lives in [`launcher`].
//!
//! Accuracy queries go through [`InferenceSession::open`], so the same
//! driver serves the pure-Rust [`crate::runtime::NativeBackend`]
//! (default) and the feature-gated PJRT executor (`--backend pjrt`).

pub mod figures;
pub mod launcher;

use std::path::PathBuf;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::config::RunConfig;
use crate::env::{CompressionEnv, Metric, Solution};
use crate::hw::energy::EnergyModel;
use crate::hw::mac_sim::RqTable;
use crate::hw::target::HwTarget;
use crate::io::json::{self, arr, num, obj, s, Value};
use crate::model::{ModelArch, Weights};
use crate::rl::composite::{CompositeAgent, CompositeConfig, CompositeStrategy};
use crate::runtime::{InferenceSession, Split};
use crate::search::{DriverConfig, SearchDriver, SearchOutcome, SearchStrategy};

/// One manifest entry.
#[derive(Clone, Debug)]
pub struct ModelEntry {
    /// model name (`vgg11`, `resnet18`, …)
    pub model: String,
    /// dataset the model was trained on
    pub dataset: String,
    /// HLO-text artifact file (relative to the artifact dir)
    pub hlo: String,
    /// weights + calibration `.npz` file
    pub weights: String,
    /// arch descriptor `.json` file
    pub arch: String,
    /// optional Pallas-path HLO artifact (exported for vgg11 only)
    pub pallas_hlo: Option<String>,
    /// executor batch size of the Pallas-path artifact
    pub pallas_batch: usize,
}

/// The coordinator.
pub struct Coordinator {
    /// the shared run configuration (backend, budgets, paths)
    pub cfg: RunConfig,
    /// precomputed MAC-sim R_Q table shared by every model's energy model
    pub rq: RqTable,
    /// models available in the artifact manifest
    pub models: Vec<ModelEntry>,
}

/// Full record of one compression run (one Fig 7 point).
#[derive(Clone, Debug)]
pub struct RunReport {
    /// model name
    pub model: String,
    /// dataset name
    pub dataset: String,
    /// method that produced the solution (`ours`, `amc`, …)
    pub method: String,
    /// RNG seed of the run (multi-seed merges report the winner's)
    pub seed: u64,
    /// dense-weight fingerprint of the compressed artifact
    /// ([`crate::search::archive::model_fingerprint`]) — the Pareto
    /// archive's group key, so retrained weights under the same model
    /// name never share a front
    pub fingerprint: String,
    /// the best solution found (per-layer policy + metrics)
    pub best: Solution,
    /// dense 8-bit baseline accuracy on the test split
    pub test_acc_dense: f64,
    /// compressed-model accuracy on the test split
    pub test_acc: f64,
    /// training episodes spent
    pub episodes: usize,
    /// reward-oracle invocations consumed (Table 3 accounting)
    pub evals: u64,
    /// wall-clock seconds of the whole run
    pub wall_secs: f64,
    /// oracle worker threads that served the reward queries
    pub threads: usize,
    /// native compute kernel that evaluated prunable layers (`--kernel`)
    pub kernel: crate::runtime::KernelKind,
    /// shard scheduler that served the oracle queries (`--sched`)
    pub sched: crate::runtime::SchedKind,
    /// shards evaluated by a non-preferred worker over the run
    /// (work-stealing claims; always 0 under `--sched static`)
    pub steals: u64,
    /// hardware target the cost model priced the run against (`--hw`)
    pub hw: String,
    /// cumulative seconds spent in hardware cost-model queries
    /// (`PhaseTimers::hw_s`, timed inside the cost cache)
    pub hw_s: f64,
    /// activation-cache hit rate of the reward oracle over the run (0..1)
    pub cache_hit_rate: f64,
    /// cumulative seconds the oracle spent (re)packing int-kernel
    /// weight planes
    pub pack_secs: f64,
    /// cumulative CPU-seconds the oracle spent in prunable-layer (GEMM)
    /// evaluation, summed over workers
    pub gemm_secs: f64,
    /// whether search-loop memoization was enabled (`--memo`)
    pub memo: bool,
    /// cumulative seconds of eval-memo overhead (fingerprinting +
    /// cache probes; `PhaseTimers::memo_s`)
    pub memo_s: f64,
    /// full-config oracle evals answered by the eval memo
    pub memo_hits: u64,
    /// packs served from the config-fingerprinted pack cache
    pub pack_cache_hits: u64,
    /// packs actually (re)built by the engine
    pub pack_cache_misses: u64,
    /// episode-reward curve (ours only)
    pub reward_curve: Vec<f64>,
}

impl RunReport {
    /// Accuracy loss on the held-out test split (fraction, clamped ≥ 0).
    pub fn test_acc_loss(&self) -> f64 {
        (self.test_acc_dense - self.test_acc).max(0.0)
    }

    /// Serialise the full report to the result-JSON schema.
    pub fn to_json(&self) -> Value {
        let layers: Vec<Value> = self
            .best
            .per_layer
            .iter()
            .map(|a| {
                obj(vec![
                    ("alg", s(a.alg.name())),
                    ("sparsity", num(a.sparsity)),
                    ("bits", num(a.bits as f64)),
                    ("overridden", Value::Bool(a.overridden)),
                ])
            })
            .collect();
        obj(vec![
            ("model", s(&self.model)),
            ("dataset", s(&self.dataset)),
            ("method", s(&self.method)),
            ("seed", num(self.seed as f64)),
            ("fingerprint", s(&self.fingerprint)),
            ("energy_gain", num(self.best.energy_gain)),
            ("latency_gain", num(self.best.latency_gain)),
            ("val_acc_loss", num(self.best.acc_loss)),
            ("test_acc_dense", num(self.test_acc_dense)),
            ("test_acc", num(self.test_acc)),
            ("test_acc_loss", num(self.test_acc_loss())),
            ("reward", num(self.best.reward)),
            ("episodes", num(self.episodes as f64)),
            ("evals", num(self.evals as f64)),
            ("wall_secs", num(self.wall_secs)),
            ("threads", num(self.threads as f64)),
            ("kernel", s(self.kernel.name())),
            ("sched", s(self.sched.name())),
            ("steals", num(self.steals as f64)),
            ("hw", s(&self.hw)),
            ("hw_s", num(self.hw_s)),
            ("cache_hit_rate", num(self.cache_hit_rate)),
            ("pack_secs", num(self.pack_secs)),
            ("gemm_secs", num(self.gemm_secs)),
            ("memo", s(if self.memo { "on" } else { "off" })),
            ("memo_s", num(self.memo_s)),
            ("memo_hits", num(self.memo_hits as f64)),
            ("pack_cache_hits", num(self.pack_cache_hits as f64)),
            ("pack_cache_misses", num(self.pack_cache_misses as f64)),
            ("per_layer", arr(layers)),
            (
                "reward_curve",
                arr(self.reward_curve.iter().map(|&r| num(r)).collect()),
            ),
        ])
    }
}

impl Coordinator {
    /// Load the artifact manifest and precompute the shared R_Q table.
    pub fn new(cfg: RunConfig) -> Result<Coordinator> {
        let manifest_path = cfg.artifacts.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} — run `make artifacts` first"))?;
        let v = json::parse(&text)?;
        let mut models = Vec::new();
        for m in v.req("models")?.as_arr()? {
            models.push(ModelEntry {
                model: m.req("model")?.as_str()?.to_string(),
                dataset: m.req("dataset")?.as_str()?.to_string(),
                hlo: m.req("hlo")?.as_str()?.to_string(),
                weights: m.req("weights")?.as_str()?.to_string(),
                arch: m.req("arch")?.as_str()?.to_string(),
                pallas_hlo: m.get("pallas_hlo").and_then(|x| x.as_str().ok()).map(str::to_string),
                pallas_batch: m
                    .get("pallas_batch")
                    .and_then(|x| x.as_usize().ok())
                    .unwrap_or(64),
            });
        }
        let rq = RqTable::compute(cfg.mac_samples, 0xEC0);
        Ok(Coordinator { cfg, rq, models })
    }

    /// Manifest entry for one model (error lists what exists).
    pub fn entry(&self, model: &str) -> Result<&ModelEntry> {
        self.models
            .iter()
            .find(|m| m.model == model)
            .ok_or_else(|| anyhow!("model `{model}` not in manifest; have: {:?}",
                self.models.iter().map(|m| &m.model).collect::<Vec<_>>()))
    }

    /// Load arch descriptor + weights for one model.
    pub fn load_arch(&self, model: &str) -> Result<(ModelArch, Weights, &ModelEntry)> {
        let e = self.entry(model)?;
        let arch = ModelArch::load(&self.cfg.artifacts.join(&e.arch))?;
        let weights = Weights::load(&arch, &self.cfg.artifacts.join(&e.weights))?;
        Ok((arch, weights, e))
    }

    fn data_path(&self, e: &ModelEntry) -> PathBuf {
        self.cfg.artifacts.join(format!("{}.data.npz", e.dataset))
    }

    /// Open an accuracy-oracle session on the configured backend.
    pub fn session(
        &self,
        arch: &ModelArch,
        e: &ModelEntry,
        split: Split,
        limit: usize,
    ) -> Result<InferenceSession> {
        InferenceSession::open_with(
            self.cfg.backend,
            arch,
            Some(&self.cfg.artifacts.join(&e.hlo)),
            &self.data_path(e),
            split,
            limit,
            None,
            self.cfg.threads,
            self.cfg.kernel,
            self.cfg.memo,
            self.cfg.sched,
        )
    }

    /// Resolve the configured hardware target (`--hw` name or
    /// `--hw-file` profile; the file wins when both are given).
    pub fn hw_target(&self) -> Result<HwTarget> {
        HwTarget::resolve(&self.cfg.hw, self.cfg.hw_file.as_deref())
    }

    /// Build the reward-oracle environment for one model on the
    /// configured hardware target.
    pub fn build_env(&self, model: &str) -> Result<CompressionEnv> {
        let (arch, weights, e) = self.load_arch(model)?;
        let target = self.hw_target()?;
        let energy = EnergyModel::for_target(arch.layer_dims()?, &target, self.rq.clone());
        let session = self.session(&arch, e, Split::Val, self.cfg.reward_subset)?;
        let mut env = CompressionEnv::new(arch, weights, energy, session, self.cfg.seed)?;
        env.set_memo(self.cfg.memo);
        Ok(env)
    }

    /// Test-split session for final reporting.
    pub fn test_session(&self, model: &str) -> Result<InferenceSession> {
        let (arch, _, e) = self.load_arch(model)?;
        self.session(&arch, e, Split::Test, self.cfg.test_subset)
    }

    /// Re-apply a solution and score it on the test split.
    pub fn score_on_test(
        &self,
        env: &mut CompressionEnv,
        test: &InferenceSession,
        sol: &Solution,
    ) -> Result<(f64, f64)> {
        let n = env.n_layers();
        test.invalidate_all(); // different weight sets share this session
        let dense_acc = test.accuracy(env.dense_weights(), &vec![8.0f32; n])?;
        env.evaluate_config(&sol.actions)?;
        let (w, bits) = env.compressed();
        test.invalidate_all();
        let acc = test.accuracy(w, bits)?;
        Ok((dense_acc, acc))
    }

    /// The search checkpoint this run reads/writes: an explicit
    /// `--checkpoint PATH` wins; a bare `--checkpoint`, `--resume` or
    /// `--stop-after` derives `<out>/<model>__<method>.ckpt`.
    pub fn effective_checkpoint(&self, model: &str, method: &str) -> Option<PathBuf> {
        let derived = || self.cfg.out.join(format!("{model}__{method}.ckpt"));
        match &self.cfg.checkpoint {
            Some(p) if p.as_os_str().is_empty() => Some(derived()),
            Some(p) => Some(p.clone()),
            None if self.cfg.resume || self.cfg.stop_after.is_some() => Some(derived()),
            None => None,
        }
    }

    /// Build the unified search driver for one (model, method) run.
    fn driver(&self, model: &str, method: &str, progress: bool) -> SearchDriver {
        SearchDriver::new(DriverConfig {
            model: model.to_string(),
            seed: self.cfg.seed,
            progress,
            checkpoint: self.effective_checkpoint(model, method),
            checkpoint_every: self.cfg.checkpoint_every,
            resume: self.cfg.resume,
            stop_after: self.cfg.stop_after,
        })
    }

    /// Score a completed search on the test split and assemble the
    /// report — identical accounting for all six methods: `evals` is
    /// the env's total oracle-invocation count (search episodes, greedy
    /// rollout, and the test-scoring replay, as the historical loops
    /// counted it) and `wall_secs` spans search + scoring across all
    /// resumed sessions.
    fn finish_report(
        &self,
        model: &str,
        method: &str,
        env: &mut CompressionEnv,
        outcome: SearchOutcome,
    ) -> Result<RunReport> {
        let best = outcome
            .best
            .ok_or_else(|| anyhow!("search `{method}` on {model} produced no solution"))?;
        let t_score = Instant::now();
        let test = self.test_session(model)?;
        let (dense_acc, test_acc) = self.score_on_test(env, &test, &best)?;
        let stats = env.session_stats();
        let e = self.entry(model)?;
        Ok(RunReport {
            model: model.to_string(),
            dataset: e.dataset.clone(),
            method: method.to_string(),
            seed: self.cfg.seed,
            fingerprint: crate::search::archive::model_fingerprint(env.dense_weights()),
            best,
            test_acc_dense: dense_acc,
            test_acc,
            episodes: self.cfg.episodes,
            evals: env.n_evals,
            wall_secs: outcome.wall_secs + t_score.elapsed().as_secs_f64(),
            threads: stats.threads,
            kernel: stats.kernel,
            sched: stats.sched,
            steals: stats.steals,
            hw: env.cost.model().target.name.clone(),
            hw_s: env.timers.hw_s,
            cache_hit_rate: stats.cache_hit_rate(),
            pack_secs: stats.pack_secs,
            gemm_secs: stats.gemm_secs,
            memo: env.memo().enabled,
            memo_s: env.timers.memo_s,
            memo_hits: env.memo_hits,
            pack_cache_hits: stats.pack_hits,
            pack_cache_misses: stats.pack_misses,
            reward_curve: outcome.curve,
        })
    }

    fn suspended_run(driver: &SearchDriver, outcome: &SearchOutcome) -> SearchRun {
        SearchRun::Suspended {
            episode: outcome.episodes_run,
            checkpoint: driver
                .cfg
                .checkpoint
                .clone()
                .expect("suspension requires a checkpoint path"),
        }
    }

    /// Run OUR composite-agent compression on one model (Fig 7a).
    pub fn compress(&self, model: &str, progress: bool) -> Result<RunReport> {
        self.compress_with(model, progress, Variant::Full)
    }

    /// Ablation-aware compression (DESIGN.md ablations: the composite
    /// agent's pieces, and the §4.2.3 alternative metric). Errors if
    /// the run suspends (`--stop-after`); CLI paths that support
    /// suspension use [`Self::compress_search`].
    pub fn compress_with(
        &self,
        model: &str,
        progress: bool,
        variant: Variant,
    ) -> Result<RunReport> {
        match self.compress_search(model, progress, variant)? {
            SearchRun::Complete(report) => Ok(*report),
            SearchRun::Suspended { episode, checkpoint } => Err(anyhow!(
                "run suspended at episode {episode}; resume with --resume \
                 --checkpoint {}",
                checkpoint.display()
            )),
        }
    }

    /// Composite-agent compression through the unified
    /// [`SearchDriver`]: supports `--resume` / `--stop-after` and
    /// periodic checkpointing.
    pub fn compress_search(
        &self,
        model: &str,
        progress: bool,
        variant: Variant,
    ) -> Result<SearchRun> {
        let mut env = self.build_env(model)?;
        if let Variant::WithMetric(m) = variant {
            env.metric = m;
        }
        let episodes = self.cfg.episodes;
        let mut agent_cfg = CompositeConfig {
            warmup_episodes: self.cfg.warmup,
            ..CompositeConfig::default()
        };
        agent_cfg.monitor_window = (episodes / 6).clamp(6, 40);
        agent_cfg.max_frozen_episodes = episodes / 2;
        let agent = CompositeAgent::new(agent_cfg, self.cfg.seed);
        let method = variant.method_name();
        let mut strategy = CompositeStrategy::new(agent, episodes).with_method(method);
        if let Variant::SingleAlg(alg) = variant {
            strategy = strategy.with_greedy_alg(alg);
        }
        let driver = self.driver(model, method, progress);
        let outcome = driver.run(&mut env, &mut strategy)?;
        if outcome.suspended {
            return Ok(Self::suspended_run(&driver, &outcome));
        }

        // optional agent policy checkpoint (resume-on-device story, §4)
        if let Ok(ckpt) = std::env::var("HAPQ_CHECKPOINT") {
            crate::rl::checkpoint::save(&strategy.agent, std::path::Path::new(&ckpt))?;
            if progress {
                eprintln!("[{model}] agent checkpoint -> {ckpt}");
            }
        }

        Ok(SearchRun::Complete(Box::new(
            self.finish_report(model, method, &mut env, outcome)?,
        )))
    }

    /// Build the [`SearchStrategy`] for one baseline with the budget
    /// mapping the comparison has always used (`--episodes` scales
    /// every method's oracle budget comparably).
    pub fn baseline_strategy(
        &self,
        method: &str,
        env: &CompressionEnv,
    ) -> Result<Box<dyn SearchStrategy>> {
        use crate::baselines as b;
        let episodes = self.cfg.episodes;
        let seed = self.cfg.seed;
        Ok(match method {
            "amc" => Box::new(b::amc::AmcStrategy::new(&b::amc::AmcConfig {
                episodes,
                warmup: self.cfg.warmup,
                seed,
            })),
            "haq" => Box::new(b::haq::HaqStrategy::new(&b::haq::HaqConfig {
                episodes,
                warmup: self.cfg.warmup,
                seed,
            })),
            "asqj" => Box::new(b::asqj::AsqjStrategy::new(
                &b::asqj::AsqjConfig { iters: (episodes / 4).max(10), ..Default::default() },
                env.n_layers(),
            )),
            "opq" => Box::new(b::opq::OpqStrategy::new(env, &b::opq::OpqConfig::default())),
            "nsga2" => Box::new(b::nsga2::Nsga2Strategy::new(
                &b::nsga2::Nsga2Config {
                    pop: 20,
                    generations: (episodes / 20).max(2),
                    seed,
                    ..Default::default()
                },
                env.n_layers(),
            )),
            other => anyhow::bail!("unknown baseline `{other}`"),
        })
    }

    /// Run one of the comparison baselines on one model (Fig 7b–e, 9).
    /// Errors if the run suspends; CLI paths that support suspension
    /// use [`Self::baseline_search`].
    pub fn run_baseline(&self, model: &str, method: &str) -> Result<RunReport> {
        match self.baseline_search(model, method)? {
            SearchRun::Complete(report) => Ok(*report),
            SearchRun::Suspended { episode, checkpoint } => Err(anyhow!(
                "run suspended at episode {episode}; resume with --resume \
                 --checkpoint {}",
                checkpoint.display()
            )),
        }
    }

    /// Baseline compression through the unified [`SearchDriver`]:
    /// supports `--resume` / `--stop-after` and periodic checkpointing.
    pub fn baseline_search(&self, model: &str, method: &str) -> Result<SearchRun> {
        let mut env = self.build_env(model)?;
        let mut strategy = self.baseline_strategy(method, &env)?;
        let driver = self.driver(model, method, false);
        let outcome = driver.run(&mut env, strategy.as_mut())?;
        if outcome.suspended {
            return Ok(Self::suspended_run(&driver, &outcome));
        }
        Ok(SearchRun::Complete(Box::new(
            self.finish_report(model, method, &mut env, outcome)?,
        )))
    }

    /// Persist a report under `out/` and fold it into the cross-run
    /// Pareto archive (`<out>/pareto.json`) — the hook that makes every
    /// single-process run cumulative; launcher fan-outs additionally
    /// fold worker reports into the *leader's* archive after the sweep.
    pub fn save_report(&self, report: &RunReport) -> Result<PathBuf> {
        std::fs::create_dir_all(&self.cfg.out)?;
        let path = self
            .cfg
            .out
            .join(format!("{}__{}.json", report.model, report.method));
        std::fs::write(&path, report.to_json().to_string())?;
        crate::search::archive::record_report(
            &self.cfg.out.join(crate::search::archive::ARCHIVE_FILE),
            &report.to_json(),
        )
        .with_context(|| format!("archiving report for {}/{}", report.model, report.method))?;
        Ok(path)
    }
}

/// Outcome of a checkpointable search: either a finished report, or a
/// cooperative suspension (`--stop-after`) whose state lives in the
/// checkpoint file until a `--resume` run picks it up.
#[derive(Debug)]
pub enum SearchRun {
    /// the run finished; the report is ready to persist (boxed: a
    /// report is an order of magnitude bigger than the suspension arm)
    Complete(Box<RunReport>),
    /// the run suspended after `episode` episodes
    Suspended {
        /// episodes completed so far (across sessions)
        episode: usize,
        /// where the resumable state was written
        checkpoint: PathBuf,
    },
}

/// Ablation / extension variants of the main compression loop.
#[derive(Clone, Copy, Debug)]
pub enum Variant {
    /// the paper's full composite agent, energy metric
    Full,
    /// Rainbow never unlocks — pruning algorithms stay randomly sampled
    NoRainbow,
    /// a single monolithic pruning algorithm (paper §3.1 motivation)
    SingleAlg(crate::pruning::PruneAlg),
    /// alternative hardware metric in the reward (§4.2.3)
    WithMetric(Metric),
}

impl Variant {
    /// Method string recorded in reports (`ours`, `ours-latency`, …).
    pub fn method_name(&self) -> &'static str {
        match self {
            Variant::Full => "ours",
            Variant::NoRainbow => "ours-norainbow",
            Variant::SingleAlg(_) => "ours-singlealg",
            Variant::WithMetric(Metric::Latency) => "ours-latency",
            Variant::WithMetric(Metric::Edp) => "ours-edp",
            Variant::WithMetric(Metric::Energy) => "ours",
        }
    }
}

/// Peak resident-set size of this process in KiB (Table 4 accounting).
pub fn max_rss_kib() -> u64 {
    if let Ok(text) = std::fs::read_to_string("/proc/self/status") {
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("VmHWM:") {
                return rest.trim().trim_end_matches(" kB").trim().parse().unwrap_or(0);
            }
        }
    }
    0
}

/// Current resident-set size in KiB.
pub fn rss_kib() -> u64 {
    if let Ok(text) = std::fs::read_to_string("/proc/self/status") {
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("VmRSS:") {
                return rest.trim().trim_end_matches(" kB").trim().parse().unwrap_or(0);
            }
        }
    }
    0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rss_readable() {
        assert!(rss_kib() > 0);
        assert!(max_rss_kib() >= rss_kib() / 2);
    }

    #[test]
    fn report_json_records_threads_and_cache_hit_rate() {
        // measurement conventions (EXPERIMENTS.md): every run JSON must
        // carry the oracle's thread count and cache hit rate so
        // Table 3/4-style wall-clock comparisons stay honest
        let r = RunReport {
            model: "m".into(),
            dataset: "d".into(),
            method: "ours".into(),
            seed: 42,
            fingerprint: "00000000000000aa".into(),
            best: Solution {
                per_layer: vec![],
                actions: vec![],
                accuracy: 0.5,
                acc_loss: 0.1,
                energy_gain: 0.2,
                latency_gain: 0.15,
                reward: 1.0,
            },
            test_acc_dense: 0.9,
            test_acc: 0.8,
            episodes: 1,
            evals: 2,
            wall_secs: 0.1,
            threads: 4,
            kernel: crate::runtime::KernelKind::Int,
            sched: crate::runtime::SchedKind::Steal,
            steals: 5,
            hw: "eyeriss-64".into(),
            hw_s: 0.002,
            cache_hit_rate: 0.75,
            pack_secs: 0.01,
            gemm_secs: 0.05,
            memo: true,
            memo_s: 0.003,
            memo_hits: 6,
            pack_cache_hits: 9,
            pack_cache_misses: 3,
            reward_curve: vec![],
        };
        let v = json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(v.req("threads").unwrap().as_f64().unwrap(), 4.0);
        let hit = v.req("cache_hit_rate").unwrap().as_f64().unwrap();
        assert!((hit - 0.75).abs() < 1e-9);
        // the kernel and its pack/GEMM phase timings ride along so
        // wall-clock comparisons can control for the compute path
        assert_eq!(v.req("kernel").unwrap().as_str().unwrap(), "int");
        assert!(v.req("pack_secs").unwrap().as_f64().unwrap() > 0.0);
        assert!(v.req("gemm_secs").unwrap().as_f64().unwrap() > 0.0);
        // the shard scheduler and its steal count ride along so
        // steal-vs-static wall-clock diffs can control for claim order
        assert_eq!(v.req("sched").unwrap().as_str().unwrap(), "steal");
        assert_eq!(v.req("steals").unwrap().as_f64().unwrap(), 5.0);
        // the hardware target and its cost-query phase timer ride along
        // so cross-target sweeps stay auditable from the JSON alone
        assert_eq!(v.req("hw").unwrap().as_str().unwrap(), "eyeriss-64");
        assert!(v.req("hw_s").unwrap().as_f64().unwrap() > 0.0);
        // the memoization mode and its hit counters ride along so
        // memo-on/off wall-clock diffs can strip exactly these fields
        assert_eq!(v.req("memo").unwrap().as_str().unwrap(), "on");
        assert!(v.req("memo_s").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(v.req("memo_hits").unwrap().as_f64().unwrap(), 6.0);
        assert_eq!(v.req("pack_cache_hits").unwrap().as_f64().unwrap(), 9.0);
        assert_eq!(v.req("pack_cache_misses").unwrap().as_f64().unwrap(), 3.0);
        // the dense-weight fingerprint and latency gain ride along so
        // the Pareto archive can group and judge dominance from the
        // run JSON alone
        assert_eq!(
            v.req("fingerprint").unwrap().as_str().unwrap(),
            "00000000000000aa"
        );
        assert!((v.req("latency_gain").unwrap().as_f64().unwrap() - 0.15).abs() < 1e-12);
        // uniform accounting: every run JSON (ours AND baselines)
        // carries seed, evals and wall_secs
        assert_eq!(v.req("seed").unwrap().as_f64().unwrap(), 42.0);
        assert_eq!(v.req("evals").unwrap().as_f64().unwrap(), 2.0);
        assert!(v.req("wall_secs").unwrap().as_f64().unwrap() > 0.0);
    }
}
