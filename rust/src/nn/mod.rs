//! Tiny neural-network substrate with manual backprop — powers the RL
//! agents (DDPG actor/critic, Rainbow dueling/noisy/C51 heads).
//!
//! Design: flat row-major [`Mat`] matrices, explicit
//! forward/backward on [`Dense`]/[`NoisyDense`], Adam per layer, and an
//! [`Mlp`] convenience wrapper with activation bookkeeping. The
//! networks are small (3×300 per the paper §5.1) so a cache-friendly
//! blocked matmul is all the performance this path needs; gradients are
//! verified against finite differences in the tests below.

pub mod mat;

use mat::Mat;

use crate::util::rng::Rng;

/// Activation functions used by the agents.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Act {
    /// max(0, x)
    Relu,
    /// tanh(x)
    Tanh,
    /// 1 / (1 + e^-x)
    Sigmoid,
    /// identity
    None,
}

/// Apply an activation in place.
pub fn act_forward(a: Act, m: &mut Mat) {
    match a {
        Act::Relu => m.d.iter_mut().for_each(|x| *x = x.max(0.0)),
        Act::Tanh => m.d.iter_mut().for_each(|x| *x = x.tanh()),
        Act::Sigmoid => m.d.iter_mut().for_each(|x| *x = 1.0 / (1.0 + (-*x).exp())),
        Act::None => {}
    }
}

/// dL/dpre from dL/dpost given the *post-activation* values y.
pub fn act_backward(a: Act, y: &Mat, dy: &mut Mat) {
    match a {
        Act::Relu => {
            for (g, &v) in dy.d.iter_mut().zip(&y.d) {
                if v <= 0.0 {
                    *g = 0.0;
                }
            }
        }
        Act::Tanh => {
            for (g, &v) in dy.d.iter_mut().zip(&y.d) {
                *g *= 1.0 - v * v;
            }
        }
        Act::Sigmoid => {
            for (g, &v) in dy.d.iter_mut().zip(&y.d) {
                *g *= v * (1.0 - v);
            }
        }
        Act::None => {}
    }
}

/// Adam state for one parameter blob.
#[derive(Clone, Debug, Default)]
struct AdamState {
    m: Vec<f32>,
    v: Vec<f32>,
}

impl AdamState {
    fn sized(n: usize) -> Self {
        AdamState { m: vec![0.0; n], v: vec![0.0; n] }
    }

    fn save_state(&self, w: &mut crate::io::bin::BinWriter) {
        w.f32s(&self.m);
        w.f32s(&self.v);
    }

    fn load_state(&mut self, r: &mut crate::io::bin::BinReader) -> anyhow::Result<()> {
        let m = r.f32s()?;
        let v = r.f32s()?;
        anyhow::ensure!(
            m.len() == self.m.len() && v.len() == self.v.len(),
            "adam state size mismatch"
        );
        self.m = m;
        self.v = v;
        Ok(())
    }

    fn step(&mut self, p: &mut [f32], g: &[f32], lr: f32, t: f32) {
        const B1: f32 = 0.9;
        const B2: f32 = 0.999;
        const EPS: f32 = 1e-8;
        let c1 = 1.0 - B1.powf(t);
        let c2 = 1.0 - B2.powf(t);
        for i in 0..p.len() {
            self.m[i] = B1 * self.m[i] + (1.0 - B1) * g[i];
            self.v[i] = B2 * self.v[i] + (1.0 - B2) * g[i] * g[i];
            let mh = self.m[i] / c1;
            let vh = self.v[i] / c2;
            p[i] -= lr * mh / (vh.sqrt() + EPS);
        }
    }
}

/// Fully-connected layer, weights [in, out].
#[derive(Clone, Debug)]
pub struct Dense {
    /// weights `[in, out]`
    pub w: Mat,
    /// bias, length `out`
    pub b: Vec<f32>,
    /// accumulated weight gradient
    pub gw: Mat,
    /// accumulated bias gradient
    pub gb: Vec<f32>,
    aw: AdamState,
    ab: AdamState,
}

impl Dense {
    /// Uniform fan-in init (DDPG paper style).
    pub fn new(fan_in: usize, fan_out: usize, rng: &mut Rng) -> Self {
        // uniform fan-in init (DDPG paper style)
        let lim = 1.0 / (fan_in as f32).sqrt();
        let w = Mat::from_fn(fan_in, fan_out, |_, _| rng.range(-lim as f64, lim as f64) as f32);
        Dense {
            gw: Mat::zeros(fan_in, fan_out),
            gb: vec![0.0; fan_out],
            aw: AdamState::sized(fan_in * fan_out),
            ab: AdamState::sized(fan_out),
            w,
            b: vec![0.0; fan_out],
        }
    }

    /// y = x·W + b, x: [B, in] -> [B, out]
    pub fn forward(&self, x: &Mat) -> Mat {
        let mut y = x.matmul(&self.w);
        y.add_row(&self.b);
        y
    }

    /// Accumulate grads; return dx.
    pub fn backward(&mut self, x: &Mat, dy: &Mat) -> Mat {
        self.gw.add_assign(&x.t_matmul(dy)); // [in,B]·[B,out]
        for r in 0..dy.r {
            for c in 0..dy.c {
                self.gb[c] += dy.at(r, c);
            }
        }
        dy.matmul_t(&self.w) // [B,out]·[out,in]
    }

    /// Reset accumulated gradients.
    pub fn zero_grad(&mut self) {
        self.gw.d.iter_mut().for_each(|x| *x = 0.0);
        self.gb.iter_mut().for_each(|x| *x = 0.0);
    }

    /// One Adam step on weights and bias (`t` = 1-based step count).
    pub fn adam(&mut self, lr: f32, t: f32) {
        self.aw.step(&mut self.w.d, &self.gw.d, lr, t);
        self.ab.step(&mut self.b, &self.gb, lr, t);
    }

    /// Polyak averaging toward `src`: θ ← τ·θ_src + (1−τ)·θ.
    pub fn soft_update_from(&mut self, src: &Dense, tau: f32) {
        for (a, b) in self.w.d.iter_mut().zip(&src.w.d) {
            *a = tau * b + (1.0 - tau) * *a;
        }
        for (a, b) in self.b.iter_mut().zip(&src.b) {
            *a = tau * b + (1.0 - tau) * *a;
        }
    }

    /// Parameter count (weights + bias).
    pub fn n_params(&self) -> usize {
        self.w.d.len() + self.b.len()
    }

    /// Export parameters as named tensors (checkpointing).
    pub fn export(&self, prefix: &str, out: &mut Vec<(String, crate::tensor::Tensor)>) {
        out.push((
            format!("{prefix}.w"),
            crate::tensor::Tensor::new(vec![self.w.r, self.w.c], self.w.d.clone()),
        ));
        out.push((
            format!("{prefix}.b"),
            crate::tensor::Tensor::new(vec![self.b.len()], self.b.clone()),
        ));
    }

    /// Import parameters from a checkpoint map (shape-checked).
    pub fn import(
        &mut self,
        prefix: &str,
        get: &dyn Fn(&str) -> anyhow::Result<crate::tensor::Tensor>,
    ) -> anyhow::Result<()> {
        let w = get(&format!("{prefix}.w"))?;
        anyhow::ensure!(w.shape == vec![self.w.r, self.w.c], "{prefix}.w shape");
        self.w.d = w.data;
        let b = get(&format!("{prefix}.b"))?;
        anyhow::ensure!(b.data.len() == self.b.len(), "{prefix}.b len");
        self.b = b.data;
        Ok(())
    }

    /// Serialise the *full* optimisation state (weights, bias, Adam
    /// moments) for bit-exact search resume. The NPZ policy export
    /// ([`Self::export`]) persists only weights; a resumed training run
    /// additionally needs the optimiser moments or the next Adam step
    /// diverges. Accumulated gradients are not stored: every consumer
    /// calls `zero_grad` before `backward`, so they are dead between
    /// updates.
    pub fn save_state(&self, w: &mut crate::io::bin::BinWriter) {
        w.usize(self.w.r);
        w.usize(self.w.c);
        w.f32s(&self.w.d);
        w.f32s(&self.b);
        self.aw.save_state(w);
        self.ab.save_state(w);
    }

    /// Restore a state written by [`Self::save_state`] (shape-checked
    /// against this layer's dimensions).
    pub fn load_state(&mut self, r: &mut crate::io::bin::BinReader) -> anyhow::Result<()> {
        let rows = r.usize()?;
        let cols = r.usize()?;
        anyhow::ensure!(
            rows == self.w.r && cols == self.w.c,
            "dense checkpoint shape [{rows},{cols}] != [{},{}]",
            self.w.r,
            self.w.c
        );
        let wd = r.f32s()?;
        anyhow::ensure!(wd.len() == self.w.d.len(), "dense weight length mismatch");
        self.w.d = wd;
        let b = r.f32s()?;
        anyhow::ensure!(b.len() == self.b.len(), "dense bias length mismatch");
        self.b = b;
        self.aw.load_state(r)?;
        self.ab.load_state(r)?;
        Ok(())
    }
}

/// Factorized-Gaussian noisy layer (Rainbow): w = μ + σ⊙(f(εo)f(εi)ᵀ).
#[derive(Clone, Debug)]
pub struct NoisyDense {
    /// weight means `[in, out]`
    pub mu_w: Mat,
    /// weight noise scales `[in, out]`
    pub sig_w: Mat,
    /// bias means
    pub mu_b: Vec<f32>,
    /// bias noise scales
    pub sig_b: Vec<f32>,
    /// current factorized input noise
    pub eps_in: Vec<f32>,
    /// current factorized output noise
    pub eps_out: Vec<f32>,
    g_mu_w: Mat,
    g_sig_w: Mat,
    g_mu_b: Vec<f32>,
    g_sig_b: Vec<f32>,
    a_mu_w: AdamState,
    a_sig_w: AdamState,
    a_mu_b: AdamState,
    a_sig_b: AdamState,
    /// when false, behaves as a plain μ-only layer (evaluation mode)
    pub noisy: bool,
}

fn fnoise(x: f32) -> f32 {
    x.signum() * x.abs().sqrt()
}

impl NoisyDense {
    /// Init per the noisy-nets paper (σ₀ = 0.5/√fan_in).
    pub fn new(fan_in: usize, fan_out: usize, rng: &mut Rng) -> Self {
        let lim = 1.0 / (fan_in as f32).sqrt();
        let sigma0 = 0.5 / (fan_in as f32).sqrt();
        NoisyDense {
            mu_w: Mat::from_fn(fan_in, fan_out, |_, _| rng.range(-lim as f64, lim as f64) as f32),
            sig_w: Mat::full(fan_in, fan_out, sigma0),
            mu_b: (0..fan_out).map(|_| rng.range(-lim as f64, lim as f64) as f32).collect(),
            sig_b: vec![sigma0; fan_out],
            eps_in: vec![0.0; fan_in],
            eps_out: vec![0.0; fan_out],
            g_mu_w: Mat::zeros(fan_in, fan_out),
            g_sig_w: Mat::zeros(fan_in, fan_out),
            g_mu_b: vec![0.0; fan_out],
            g_sig_b: vec![0.0; fan_out],
            a_mu_w: AdamState::sized(fan_in * fan_out),
            a_sig_w: AdamState::sized(fan_in * fan_out),
            a_mu_b: AdamState::sized(fan_out),
            a_sig_b: AdamState::sized(fan_out),
            noisy: true,
        }
    }

    /// Draw fresh factorized noise for both factors.
    pub fn resample(&mut self, rng: &mut Rng) {
        for e in self.eps_in.iter_mut() {
            *e = fnoise(rng.normal() as f32);
        }
        for e in self.eps_out.iter_mut() {
            *e = fnoise(rng.normal() as f32);
        }
    }

    fn eff_w(&self) -> Mat {
        let mut w = self.mu_w.clone();
        if self.noisy {
            for i in 0..w.r {
                for o in 0..w.c {
                    let e = self.eps_in[i] * self.eps_out[o];
                    *w.at_mut(i, o) += self.sig_w.at(i, o) * e;
                }
            }
        }
        w
    }

    /// `y = x·(μ_w + σ_w⊙ε) + μ_b + σ_b⊙ε_out` (noise off in eval mode).
    pub fn forward(&self, x: &Mat) -> Mat {
        let w = self.eff_w();
        let mut y = x.matmul(&w);
        for r in 0..y.r {
            for c in 0..y.c {
                let noise = if self.noisy { self.sig_b[c] * self.eps_out[c] } else { 0.0 };
                *y.at_mut(r, c) += self.mu_b[c] + noise;
            }
        }
        y
    }

    /// Accumulate grads for μ and σ; returns dL/dx.
    pub fn backward(&mut self, x: &Mat, dy: &Mat) -> Mat {
        let gw = x.t_matmul(dy); // [in,out] grad wrt effective w
        for i in 0..gw.r {
            for o in 0..gw.c {
                let g = gw.at(i, o);
                *self.g_mu_w.at_mut(i, o) += g;
                if self.noisy {
                    *self.g_sig_w.at_mut(i, o) += g * self.eps_in[i] * self.eps_out[o];
                }
            }
        }
        for r in 0..dy.r {
            for c in 0..dy.c {
                let g = dy.at(r, c);
                self.g_mu_b[c] += g;
                if self.noisy {
                    self.g_sig_b[c] += g * self.eps_out[c];
                }
            }
        }
        let w = self.eff_w();
        dy.matmul_t(&w)
    }

    /// Reset accumulated gradients.
    pub fn zero_grad(&mut self) {
        self.g_mu_w.d.iter_mut().for_each(|x| *x = 0.0);
        self.g_sig_w.d.iter_mut().for_each(|x| *x = 0.0);
        self.g_mu_b.iter_mut().for_each(|x| *x = 0.0);
        self.g_sig_b.iter_mut().for_each(|x| *x = 0.0);
    }

    /// One Adam step on all four parameter blobs.
    pub fn adam(&mut self, lr: f32, t: f32) {
        self.a_mu_w.step(&mut self.mu_w.d, &self.g_mu_w.d, lr, t);
        self.a_sig_w.step(&mut self.sig_w.d, &self.g_sig_w.d, lr, t);
        self.a_mu_b.step(&mut self.mu_b, &self.g_mu_b, lr, t);
        self.a_sig_b.step(&mut self.sig_b, &self.g_sig_b, lr, t);
    }

    /// Polyak averaging toward `src`: θ ← τ·θ_src + (1−τ)·θ.
    pub fn soft_update_from(&mut self, src: &NoisyDense, tau: f32) {
        for (a, b) in self.mu_w.d.iter_mut().zip(&src.mu_w.d) {
            *a = tau * b + (1.0 - tau) * *a;
        }
        for (a, b) in self.sig_w.d.iter_mut().zip(&src.sig_w.d) {
            *a = tau * b + (1.0 - tau) * *a;
        }
        for (a, b) in self.mu_b.iter_mut().zip(&src.mu_b) {
            *a = tau * b + (1.0 - tau) * *a;
        }
        for (a, b) in self.sig_b.iter_mut().zip(&src.sig_b) {
            *a = tau * b + (1.0 - tau) * *a;
        }
    }

    /// Export parameters as named tensors (checkpointing).
    pub fn export(&self, prefix: &str, out: &mut Vec<(String, crate::tensor::Tensor)>) {
        use crate::tensor::Tensor;
        out.push((format!("{prefix}.mu_w"),
            Tensor::new(vec![self.mu_w.r, self.mu_w.c], self.mu_w.d.clone())));
        out.push((format!("{prefix}.sig_w"),
            Tensor::new(vec![self.sig_w.r, self.sig_w.c], self.sig_w.d.clone())));
        out.push((format!("{prefix}.mu_b"),
            Tensor::new(vec![self.mu_b.len()], self.mu_b.clone())));
        out.push((format!("{prefix}.sig_b"),
            Tensor::new(vec![self.sig_b.len()], self.sig_b.clone())));
    }

    /// Import parameters from a checkpoint map.
    pub fn import(
        &mut self,
        prefix: &str,
        get: &dyn Fn(&str) -> anyhow::Result<crate::tensor::Tensor>,
    ) -> anyhow::Result<()> {
        let mw = get(&format!("{prefix}.mu_w"))?;
        anyhow::ensure!(mw.shape == vec![self.mu_w.r, self.mu_w.c], "{prefix}.mu_w");
        self.mu_w.d = mw.data;
        let sw = get(&format!("{prefix}.sig_w"))?;
        self.sig_w.d = sw.data;
        self.mu_b = get(&format!("{prefix}.mu_b"))?.data;
        self.sig_b = get(&format!("{prefix}.sig_b"))?.data;
        Ok(())
    }

    /// Serialise the full state (μ/σ parameters, the *current* factorized
    /// noise draw, all four Adam moment pairs, and the eval-mode flag)
    /// for bit-exact search resume.
    pub fn save_state(&self, w: &mut crate::io::bin::BinWriter) {
        w.usize(self.mu_w.r);
        w.usize(self.mu_w.c);
        w.f32s(&self.mu_w.d);
        w.f32s(&self.sig_w.d);
        w.f32s(&self.mu_b);
        w.f32s(&self.sig_b);
        w.f32s(&self.eps_in);
        w.f32s(&self.eps_out);
        self.a_mu_w.save_state(w);
        self.a_sig_w.save_state(w);
        self.a_mu_b.save_state(w);
        self.a_sig_b.save_state(w);
        w.bool(self.noisy);
    }

    /// Restore a state written by [`Self::save_state`].
    pub fn load_state(&mut self, r: &mut crate::io::bin::BinReader) -> anyhow::Result<()> {
        let rows = r.usize()?;
        let cols = r.usize()?;
        anyhow::ensure!(
            rows == self.mu_w.r && cols == self.mu_w.c,
            "noisy-dense checkpoint shape [{rows},{cols}] != [{},{}]",
            self.mu_w.r,
            self.mu_w.c
        );
        let mu_w = r.f32s()?;
        let sig_w = r.f32s()?;
        anyhow::ensure!(
            mu_w.len() == self.mu_w.d.len() && sig_w.len() == self.sig_w.d.len(),
            "noisy-dense weight length mismatch"
        );
        self.mu_w.d = mu_w;
        self.sig_w.d = sig_w;
        let mu_b = r.f32s()?;
        let sig_b = r.f32s()?;
        anyhow::ensure!(
            mu_b.len() == self.mu_b.len() && sig_b.len() == self.sig_b.len(),
            "noisy-dense bias length mismatch"
        );
        self.mu_b = mu_b;
        self.sig_b = sig_b;
        let eps_in = r.f32s()?;
        let eps_out = r.f32s()?;
        anyhow::ensure!(
            eps_in.len() == self.eps_in.len() && eps_out.len() == self.eps_out.len(),
            "noisy-dense noise length mismatch"
        );
        self.eps_in = eps_in;
        self.eps_out = eps_out;
        self.a_mu_w.load_state(r)?;
        self.a_sig_w.load_state(r)?;
        self.a_mu_b.load_state(r)?;
        self.a_sig_b.load_state(r)?;
        self.noisy = r.bool()?;
        Ok(())
    }
}

/// Sequential MLP with per-layer activations and a forward cache.
#[derive(Clone, Debug)]
pub struct Mlp {
    /// the dense layers, input to output
    pub layers: Vec<Dense>,
    /// per-layer activation functions
    pub acts: Vec<Act>,
}

/// Forward cache: post-activation outputs of every layer (+ input).
pub struct MlpCache {
    /// `outs[0]` is the input; `outs[i+1]` is layer i's output
    pub outs: Vec<Mat>,
}

impl Mlp {
    /// Build from layer widths + one activation per layer.
    pub fn new(dims: &[usize], acts: &[Act], rng: &mut Rng) -> Self {
        assert_eq!(dims.len() - 1, acts.len());
        let layers = dims
            .windows(2)
            .map(|w| Dense::new(w[0], w[1], rng))
            .collect();
        Mlp { layers, acts: acts.to_vec() }
    }

    /// Plain forward pass.
    pub fn forward(&self, x: &Mat) -> Mat {
        let mut cur = x.clone();
        for (l, a) in self.layers.iter().zip(&self.acts) {
            cur = l.forward(&cur);
            act_forward(*a, &mut cur);
        }
        cur
    }

    /// Forward pass that keeps every intermediate for backprop.
    pub fn forward_cached(&self, x: &Mat) -> MlpCache {
        let mut outs = vec![x.clone()];
        for (l, a) in self.layers.iter().zip(&self.acts) {
            let mut y = l.forward(outs.last().unwrap());
            act_forward(*a, &mut y);
            outs.push(y);
        }
        MlpCache { outs }
    }

    /// Backprop dL/d(output); returns dL/d(input). Grads accumulate.
    pub fn backward(&mut self, cache: &MlpCache, dout: &Mat) -> Mat {
        let mut dy = dout.clone();
        for i in (0..self.layers.len()).rev() {
            act_backward(self.acts[i], &cache.outs[i + 1], &mut dy);
            dy = self.layers[i].backward(&cache.outs[i], &dy);
        }
        dy
    }

    /// Reset accumulated gradients in every layer.
    pub fn zero_grad(&mut self) {
        self.layers.iter_mut().for_each(Dense::zero_grad);
    }

    /// One Adam step on every layer.
    pub fn adam(&mut self, lr: f32, t: f32) {
        self.layers.iter_mut().for_each(|l| l.adam(lr, t));
    }

    /// Polyak averaging of every layer toward `src`.
    pub fn soft_update_from(&mut self, src: &Mlp, tau: f32) {
        for (a, b) in self.layers.iter_mut().zip(&src.layers) {
            a.soft_update_from(b, tau);
        }
    }

    /// Output of hidden layer `k` (post-activation) — the composite
    /// agent taps the DDPG actor's last hidden layer as Rainbow input.
    pub fn hidden(&self, x: &Mat, k: usize) -> Mat {
        let mut cur = x.clone();
        for (i, (l, a)) in self.layers.iter().zip(&self.acts).enumerate() {
            cur = l.forward(&cur);
            act_forward(*a, &mut cur);
            if i == k {
                break;
            }
        }
        cur
    }

    /// Total parameter count.
    pub fn n_params(&self) -> usize {
        self.layers.iter().map(Dense::n_params).sum()
    }

    /// Export all layers (checkpointing).
    pub fn export(&self, prefix: &str, out: &mut Vec<(String, crate::tensor::Tensor)>) {
        for (i, l) in self.layers.iter().enumerate() {
            l.export(&format!("{prefix}.{i}"), out);
        }
    }

    /// Import all layers from a checkpoint map.
    pub fn import(
        &mut self,
        prefix: &str,
        get: &dyn Fn(&str) -> anyhow::Result<crate::tensor::Tensor>,
    ) -> anyhow::Result<()> {
        for (i, l) in self.layers.iter_mut().enumerate() {
            l.import(&format!("{prefix}.{i}"), get)?;
        }
        Ok(())
    }

    /// Serialise every layer's full state (weights + Adam moments).
    pub fn save_state(&self, w: &mut crate::io::bin::BinWriter) {
        w.usize(self.layers.len());
        for l in &self.layers {
            l.save_state(w);
        }
    }

    /// Restore a state written by [`Self::save_state`].
    pub fn load_state(&mut self, r: &mut crate::io::bin::BinReader) -> anyhow::Result<()> {
        let n = r.usize()?;
        anyhow::ensure!(n == self.layers.len(), "mlp checkpoint layer count mismatch");
        for l in self.layers.iter_mut() {
            l.load_state(r)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn numeric_grad<F: FnMut() -> f32>(p: &mut f32, mut f: F) -> f32 {
        let h = 1e-3;
        let orig = *p;
        *p = orig + h;
        let fp = f();
        *p = orig - h;
        let fm = f();
        *p = orig;
        (fp - fm) / (2.0 * h)
    }

    /// loss = sum(y^2)/2 so dL/dy = y.
    fn loss_and_grad(net: &Mlp, x: &Mat) -> (f32, Mat) {
        let y = net.forward(x);
        let loss = 0.5 * y.d.iter().map(|v| v * v).sum::<f32>();
        (loss, y)
    }

    #[test]
    fn dense_gradcheck() {
        let mut rng = Rng::new(11);
        let mut net = Mlp::new(&[4, 8, 3], &[Act::Tanh, Act::None], &mut rng);
        let x = Mat::from_fn(2, 4, |r, c| ((r * 4 + c) as f32 * 0.3).sin());
        let cache = net.forward_cached(&x);
        let (_, dy) = loss_and_grad(&net, &x);
        net.zero_grad();
        net.backward(&cache, &dy);
        // check a scatter of weight grads against finite differences
        for (li, wi) in [(0usize, 0usize), (0, 17), (1, 5), (1, 23)] {
            let analytic = net.layers[li].gw.d[wi];
            let mut net2 = net.clone();
            let x2 = x.clone();
            let num = {
                let f = |n: &Mlp| loss_and_grad(n, &x2).0;
                let h = 1e-3f32;
                let orig = net2.layers[li].w.d[wi];
                net2.layers[li].w.d[wi] = orig + h;
                let fp = f(&net2);
                net2.layers[li].w.d[wi] = orig - h;
                let fm = f(&net2);
                net2.layers[li].w.d[wi] = orig;
                (fp - fm) / (2.0 * h)
            };
            assert!(
                (analytic - num).abs() < 2e-2 * (1.0 + num.abs()),
                "layer {li} w[{wi}]: analytic {analytic} vs numeric {num}"
            );
        }
    }

    #[test]
    fn relu_sigmoid_gradcheck() {
        let mut rng = Rng::new(5);
        let mut net = Mlp::new(&[3, 6, 2], &[Act::Relu, Act::Sigmoid], &mut rng);
        let x = Mat::from_fn(4, 3, |r, c| ((r + c) as f32 * 0.7).cos());
        let cache = net.forward_cached(&x);
        let (_, dy) = loss_and_grad(&net, &x);
        net.zero_grad();
        net.backward(&cache, &dy);
        let analytic = net.layers[0].gb[2];
        let mut net2 = net.clone();
        let h = 1e-3f32;
        net2.layers[0].b[2] += h;
        let fp = loss_and_grad(&net2, &x).0;
        net2.layers[0].b[2] -= 2.0 * h;
        let fm = loss_and_grad(&net2, &x).0;
        let num = (fp - fm) / (2.0 * h);
        assert!((analytic - num).abs() < 2e-2 * (1.0 + num.abs()));
    }

    #[test]
    fn noisy_dense_grad_and_eval_mode() {
        let mut rng = Rng::new(9);
        let mut nl = NoisyDense::new(5, 4, &mut rng);
        nl.resample(&mut rng);
        let x = Mat::from_fn(3, 5, |r, c| ((r * 5 + c) as f32).sin());
        let y = nl.forward(&x);
        nl.zero_grad();
        let dy = y.clone();
        let _ = nl.backward(&x, &dy);
        // numeric vs analytic for mu_w[7] and sig_w[7]
        let f = |nl: &NoisyDense| {
            let y = nl.forward(&x);
            0.5 * y.d.iter().map(|v| v * v).sum::<f32>()
        };
        let h = 1e-3f32;
        for (blob, grad) in [(true, nl.g_mu_w.d[7]), (false, nl.g_sig_w.d[7])] {
            let mut n2 = nl.clone();
            let p = if blob { &mut n2.mu_w.d[7] } else { &mut n2.sig_w.d[7] };
            let orig = *p;
            *p = orig + h;
            let fp = f(&n2);
            let p = if blob { &mut n2.mu_w.d[7] } else { &mut n2.sig_w.d[7] };
            *p = orig - h;
            let fm = f(&n2);
            let num = (fp - fm) / (2.0 * h);
            assert!(
                (grad - num).abs() < 2e-2 * (1.0 + num.abs()),
                "mu? {blob}: {grad} vs {num}"
            );
        }
        // eval mode: noise off => same as mu-only layer
        let mut nl2 = nl.clone();
        nl2.noisy = false;
        let y1 = nl2.forward(&x);
        nl2.resample(&mut rng);
        let y2 = nl2.forward(&x);
        assert_eq!(y1.d, y2.d);
    }

    #[test]
    fn adam_reduces_loss() {
        let mut rng = Rng::new(2);
        let mut net = Mlp::new(&[2, 16, 1], &[Act::Relu, Act::None], &mut rng);
        // fit y = x0 + 2*x1 on a fixed batch
        let x = Mat::from_fn(16, 2, |r, c| ((r * 2 + c) as f32 * 0.37).sin());
        let target: Vec<f32> = (0..16).map(|r| x.at(r, 0) + 2.0 * x.at(r, 1)).collect();
        let mut first = None;
        let mut last = 0.0;
        for t in 1..=400 {
            let cache = net.forward_cached(&x);
            let y = cache.outs.last().unwrap();
            let mut dy = y.clone();
            let mut loss = 0.0;
            for r in 0..16 {
                let e = y.at(r, 0) - target[r];
                loss += 0.5 * e * e;
                *dy.at_mut(r, 0) = e;
            }
            net.zero_grad();
            net.backward(&cache, &dy);
            net.adam(1e-2, t as f32);
            if first.is_none() {
                first = Some(loss);
            }
            last = loss;
        }
        assert!(last < 0.05 * first.unwrap(), "loss {last} vs {first:?}");
    }

    #[test]
    fn soft_update_moves_toward_source() {
        let mut rng = Rng::new(3);
        let a = Mlp::new(&[2, 3], &[Act::None], &mut rng);
        let mut b = Mlp::new(&[2, 3], &[Act::None], &mut rng);
        let before = (b.layers[0].w.d[0] - a.layers[0].w.d[0]).abs();
        b.soft_update_from(&a, 0.5);
        let after = (b.layers[0].w.d[0] - a.layers[0].w.d[0]).abs();
        assert!(after < before);
        b.soft_update_from(&a, 1.0);
        assert!((b.layers[0].w.d[0] - a.layers[0].w.d[0]).abs() < 1e-7);
    }
}
