//! Flat row-major matrix with the three GEMM variants backprop needs.
//!
//! Sizes here are tiny (≤ 64×300·300), so the win is cache order + auto
//! vectorisation: all three products are written as row-major SAXPY
//! loops over contiguous slices.

/// Row-major matrix [r, c].
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    /// rows
    pub r: usize,
    /// columns
    pub c: usize,
    /// row-major storage, length `r * c`
    pub d: Vec<f32>,
}

impl Mat {
    /// All-zero matrix.
    pub fn zeros(r: usize, c: usize) -> Self {
        Mat { r, c, d: vec![0.0; r * c] }
    }

    /// Constant-filled matrix.
    pub fn full(r: usize, c: usize, v: f32) -> Self {
        Mat { r, c, d: vec![v; r * c] }
    }

    /// Wrap an existing row-major buffer (panics on size mismatch).
    pub fn from_vec(r: usize, c: usize, d: Vec<f32>) -> Self {
        assert_eq!(r * c, d.len());
        Mat { r, c, d }
    }

    /// Build element-wise from `f(row, col)`.
    pub fn from_fn<F: FnMut(usize, usize) -> f32>(r: usize, c: usize, mut f: F) -> Self {
        let mut d = Vec::with_capacity(r * c);
        for i in 0..r {
            for j in 0..c {
                d.push(f(i, j));
            }
        }
        Mat { r, c, d }
    }

    /// Single row as a 1×c matrix view (copy).
    pub fn row(&self, i: usize) -> Mat {
        Mat { r: 1, c: self.c, d: self.d[i * self.c..(i + 1) * self.c].to_vec() }
    }

    /// Borrow one row as a slice.
    pub fn row_slice(&self, i: usize) -> &[f32] {
        &self.d[i * self.c..(i + 1) * self.c]
    }

    /// Element read.
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.d[i * self.c + j]
    }

    /// Element write access.
    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        &mut self.d[i * self.c + j]
    }

    /// self[r,k] · b[k,c] -> [r,c]
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.c, b.r, "matmul {}x{} · {}x{}", self.r, self.c, b.r, b.c);
        let mut out = Mat::zeros(self.r, b.c);
        for i in 0..self.r {
            let arow = &self.d[i * self.c..(i + 1) * self.c];
            let orow = &mut out.d[i * b.c..(i + 1) * b.c];
            for (k, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue; // post-ReLU inputs: ~50% zeros, row skip pays
                }
                let brow = &b.d[k * b.c..(k + 1) * b.c];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += a * bv;
                }
            }
        }
        out
    }

    /// selfᵀ[k,r]ᵀ… i.e. selfᵀ · b: self[B,in], b[B,out] -> [in,out]
    pub fn t_matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.r, b.r);
        let mut out = Mat::zeros(self.c, b.c);
        for bi in 0..self.r {
            let xrow = &self.d[bi * self.c..(bi + 1) * self.c];
            let yrow = &b.d[bi * b.c..(bi + 1) * b.c];
            for (i, &xv) in xrow.iter().enumerate() {
                if xv == 0.0 {
                    continue; // post-ReLU activations: ~50% zeros, row skip pays
                }
                let orow = &mut out.d[i * b.c..(i + 1) * b.c];
                for (o, &yv) in orow.iter_mut().zip(yrow) {
                    *o += xv * yv;
                }
            }
        }
        out
    }

    /// self · bᵀ: self[B,out], b[in,out] -> [B,in]
    pub fn matmul_t(&self, b: &Mat) -> Mat {
        assert_eq!(self.c, b.c);
        let mut out = Mat::zeros(self.r, b.r);
        for i in 0..self.r {
            let arow = &self.d[i * self.c..(i + 1) * self.c];
            let orow = &mut out.d[i * b.r..(i + 1) * b.r];
            for (j, o) in orow.iter_mut().enumerate() {
                let brow = &b.d[j * b.c..(j + 1) * b.c];
                let mut acc = 0.0f32;
                for (&a, &bv) in arow.iter().zip(brow) {
                    acc += a * bv;
                }
                *o = acc;
            }
        }
        out
    }

    /// Add a bias row to every row.
    pub fn add_row(&mut self, bias: &[f32]) {
        assert_eq!(bias.len(), self.c);
        for i in 0..self.r {
            let row = &mut self.d[i * self.c..(i + 1) * self.c];
            for (x, &b) in row.iter_mut().zip(bias) {
                *x += b;
            }
        }
    }

    /// Elementwise `self += other` (shapes must match).
    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.r, self.c), (other.r, other.c));
        for (a, &b) in self.d.iter_mut().zip(&other.d) {
            *a += b;
        }
    }

    /// Multiply every element by `s`.
    pub fn scale(&mut self, s: f32) {
        self.d.iter_mut().for_each(|x| *x *= s);
    }

    /// Stack rows of many 1×c mats into one [n, c] batch.
    pub fn stack_rows(rows: &[Vec<f32>]) -> Mat {
        let c = rows[0].len();
        let mut d = Vec::with_capacity(rows.len() * c);
        for r in rows {
            assert_eq!(r.len(), c);
            d.extend_from_slice(r);
        }
        Mat { r: rows.len(), c, d }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_hand_values() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Mat::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.d, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn transpose_products_agree() {
        // t_matmul(a, b) == transpose(a) · b ; matmul_t(a, b) == a · bᵀ
        let a = Mat::from_fn(4, 3, |i, j| (i * 3 + j) as f32 * 0.5 - 2.0);
        let b = Mat::from_fn(4, 2, |i, j| (i + j) as f32);
        let t1 = a.t_matmul(&b);
        // brute force
        let mut want = Mat::zeros(3, 2);
        for i in 0..3 {
            for j in 0..2 {
                for k in 0..4 {
                    *want.at_mut(i, j) += a.at(k, i) * b.at(k, j);
                }
            }
        }
        assert_eq!(t1, want);

        let c = Mat::from_fn(4, 3, |i, j| (i as f32 - j as f32) * 0.3);
        let t2 = b.matmul_t(&Mat::from_fn(5, 2, |i, j| (i * 2 + j) as f32 * 0.1));
        assert_eq!(t2.r, 4);
        assert_eq!(t2.c, 5);
        let _ = c;
    }

    #[test]
    fn bias_and_stack() {
        let mut m = Mat::zeros(2, 3);
        m.add_row(&[1., 2., 3.]);
        assert_eq!(m.d, vec![1., 2., 3., 1., 2., 3.]);
        let s = Mat::stack_rows(&[vec![1., 2.], vec![3., 4.]]);
        assert_eq!((s.r, s.c), (2, 2));
    }
}
