//! Flat row-major matrix with the three GEMM variants backprop needs,
//! plus the integer fast-path GEMM of the accuracy oracle
//! ([`CodeMat`] · [`PackedMat`]).
//!
//! Sizes here are tiny (≤ 64×300·300), so the win is cache order + auto
//! vectorisation: all products are written as row-major SAXPY loops
//! over contiguous slices.
//!
//! ## Why the int kernel is bit-identical to `fake_quant` + [`Mat::matmul`]
//!
//! [`PackedMat::code_matmul`] reproduces the f32 reference GEMM bit for
//! bit by construction, not by tolerance:
//!
//! * activation codes dequantize through a LUT whose entries are the
//!   **exact** f32 values `fake_quant` produces (see
//!   [`crate::quant::grid::QuantGrid::value`]), and the structural-zero
//!   sentinel maps to the same `0.0` the SAME-padding inserts;
//! * each output accumulator consumes its nonzero products in the same
//!   ascending-`k` order as [`Mat::matmul`], which skips `a == 0.0`
//!   exactly as the reference does;
//! * dropping all-zero weight **rows** is IEEE-exact for finite
//!   activations: every skipped product is `a · (+0.0) = ±0.0`, and
//!   `x + (±0.0) == x` for every accumulator value reachable here
//!   (accumulators start at `+0.0` and `+0.0 + (-0.0) = +0.0` under
//!   round-to-nearest) — a non-finite `a` cannot reach this GEMM, as
//!   it has no grid code (see `runtime/native.rs` on the NaN caveat);
//! * dropping all-zero weight **columns** is IEEE-exact for the same
//!   reason: the reference leaves those accumulators at `+0.0`, which
//!   is what [`Mat::zeros`] initialises and the scatter never touches.
//!
//! An i32 accumulator would be *faster* still but cannot match the
//! reference: f32 addition rounds after every product, so any exact
//! integer accumulation diverges from the reference bits. The int
//! kernel's wins come from the i16 patch matrix (half the memory
//! traffic of f32), the fused quantize-while-packing pass, pack-once
//! weights (the f32 path re-clones the weight tensor every query), and
//! the pruning-mask row/column skipping.
//!
//! ## The blocked/tiled variant ([`PackedMat::code_matmul_tiled`])
//!
//! The default `--kernel int` entry point is a cache-blocked GEMM with
//! explicit fixed-width lanes: per code row the nonzero dequantized
//! activations are gathered once (`nz`, ascending `k`), then the live
//! output columns are walked in `tile`-wide blocks, each block split
//! into a 4×[`GEMM_LANES`]-wide register micro-kernel (four independent
//! 8-lane accumulator arrays, so four independent FMA dependency chains
//! per `k` step), an 8-wide remainder, and a scalar tail.
//!
//! Blocking reorders only *memory traversal* — never arithmetic. Each
//! output element still owns exactly one f32 accumulator that consumes
//! its nonzero products in the same ascending-`k` order as the scalar
//! path and as [`Mat::matmul`], with the same `a == 0.0` skip (hoisted
//! into the `nz` gather). That is the full set of conditions for IEEE
//! bit-identity, so no relaxed `int-fast` variant is needed: there is
//! no reordering left to gate behind a tolerance contract. The
//! conformance suite pins `code_matmul_tiled == code_matmul_scalar ==`
//! f32 reference bitwise across tile sizes (including tiles {1, 3, 17}
//! that force every remainder path).
//!
//! The tile width defaults to [`DEFAULT_GEMM_TILE`] and can be
//! overridden per process via [`set_gemm_tile`] (the `--gemm-tile` CLI
//! flag) or the `HAPQ_GEMM_TILE` env var — a testing/tuning knob only;
//! results are bit-identical at every tile width.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Lane width of the register micro-kernel in
/// [`PackedMat::code_matmul_tiled`] (8 × f32 = one AVX2 vector; the
/// compiler maps each `[f32; 8]` accumulator onto one SIMD register).
pub const GEMM_LANES: usize = 8;

/// Default output-column tile width of the blocked integer GEMM: two
/// 4×[`GEMM_LANES`] register blocks, sized so a tile of the packed
/// weight operand stays resident in L1 across the `k` loop.
pub const DEFAULT_GEMM_TILE: usize = 64;

/// Process-wide tile override set by [`set_gemm_tile`] (0 = unset).
static GEMM_TILE_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Override the GEMM tile width process-wide (the `--gemm-tile` CLI
/// flag lands here). Passing 0 clears the override, restoring the
/// `HAPQ_GEMM_TILE`-then-[`DEFAULT_GEMM_TILE`] resolution.
pub fn set_gemm_tile(tile: usize) {
    GEMM_TILE_OVERRIDE.store(tile, Ordering::Relaxed);
}

/// Tile width [`PackedMat::code_matmul`] uses: the [`set_gemm_tile`]
/// override if set, else `HAPQ_GEMM_TILE`, else [`DEFAULT_GEMM_TILE`].
pub fn default_gemm_tile() -> usize {
    let o = GEMM_TILE_OVERRIDE.load(Ordering::Relaxed);
    if o != 0 {
        return o;
    }
    std::env::var("HAPQ_GEMM_TILE")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&t| t > 0)
        .unwrap_or(DEFAULT_GEMM_TILE)
}

/// Row-major matrix [r, c].
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    /// rows
    pub r: usize,
    /// columns
    pub c: usize,
    /// row-major storage, length `r * c`
    pub d: Vec<f32>,
}

impl Mat {
    /// All-zero matrix.
    pub fn zeros(r: usize, c: usize) -> Self {
        Mat { r, c, d: vec![0.0; r * c] }
    }

    /// Constant-filled matrix.
    pub fn full(r: usize, c: usize, v: f32) -> Self {
        Mat { r, c, d: vec![v; r * c] }
    }

    /// Wrap an existing row-major buffer (panics on size mismatch).
    pub fn from_vec(r: usize, c: usize, d: Vec<f32>) -> Self {
        assert_eq!(r * c, d.len());
        Mat { r, c, d }
    }

    /// Build element-wise from `f(row, col)`.
    pub fn from_fn<F: FnMut(usize, usize) -> f32>(r: usize, c: usize, mut f: F) -> Self {
        let mut d = Vec::with_capacity(r * c);
        for i in 0..r {
            for j in 0..c {
                d.push(f(i, j));
            }
        }
        Mat { r, c, d }
    }

    /// Single row as a 1×c matrix view (copy).
    pub fn row(&self, i: usize) -> Mat {
        Mat { r: 1, c: self.c, d: self.d[i * self.c..(i + 1) * self.c].to_vec() }
    }

    /// Borrow one row as a slice.
    pub fn row_slice(&self, i: usize) -> &[f32] {
        &self.d[i * self.c..(i + 1) * self.c]
    }

    /// Element read.
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.d[i * self.c + j]
    }

    /// Element write access.
    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        &mut self.d[i * self.c + j]
    }

    /// self[r,k] · b[k,c] -> [r,c]
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.c, b.r, "matmul {}x{} · {}x{}", self.r, self.c, b.r, b.c);
        let mut out = Mat::zeros(self.r, b.c);
        for i in 0..self.r {
            let arow = &self.d[i * self.c..(i + 1) * self.c];
            let orow = &mut out.d[i * b.c..(i + 1) * b.c];
            for (k, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue; // post-ReLU inputs: ~50% zeros, row skip pays
                }
                let brow = &b.d[k * b.c..(k + 1) * b.c];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += a * bv;
                }
            }
        }
        out
    }

    /// selfᵀ[k,r]ᵀ… i.e. selfᵀ · b: self[B,in], b[B,out] -> [in,out]
    pub fn t_matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.r, b.r);
        let mut out = Mat::zeros(self.c, b.c);
        for bi in 0..self.r {
            let xrow = &self.d[bi * self.c..(bi + 1) * self.c];
            let yrow = &b.d[bi * b.c..(bi + 1) * b.c];
            for (i, &xv) in xrow.iter().enumerate() {
                if xv == 0.0 {
                    continue; // post-ReLU activations: ~50% zeros, row skip pays
                }
                let orow = &mut out.d[i * b.c..(i + 1) * b.c];
                for (o, &yv) in orow.iter_mut().zip(yrow) {
                    *o += xv * yv;
                }
            }
        }
        out
    }

    /// self · bᵀ: self[B,out], b[in,out] -> [B,in]
    pub fn matmul_t(&self, b: &Mat) -> Mat {
        assert_eq!(self.c, b.c);
        let mut out = Mat::zeros(self.r, b.r);
        for i in 0..self.r {
            let arow = &self.d[i * self.c..(i + 1) * self.c];
            let orow = &mut out.d[i * b.r..(i + 1) * b.r];
            for (j, o) in orow.iter_mut().enumerate() {
                let brow = &b.d[j * b.c..(j + 1) * b.c];
                let mut acc = 0.0f32;
                for (&a, &bv) in arow.iter().zip(brow) {
                    acc += a * bv;
                }
                *o = acc;
            }
        }
        out
    }

    /// Add a bias row to every row.
    pub fn add_row(&mut self, bias: &[f32]) {
        assert_eq!(bias.len(), self.c);
        for i in 0..self.r {
            let row = &mut self.d[i * self.c..(i + 1) * self.c];
            for (x, &b) in row.iter_mut().zip(bias) {
                *x += b;
            }
        }
    }

    /// Elementwise `self += other` (shapes must match).
    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.r, self.c), (other.r, other.c));
        for (a, &b) in self.d.iter_mut().zip(&other.d) {
            *a += b;
        }
    }

    /// Multiply every element by `s`.
    pub fn scale(&mut self, s: f32) {
        self.d.iter_mut().for_each(|x| *x *= s);
    }

    /// Stack rows of many 1×c mats into one [n, c] batch.
    pub fn stack_rows(rows: &[Vec<f32>]) -> Mat {
        let c = rows[0].len();
        let mut d = Vec::with_capacity(rows.len() * c);
        for r in rows {
            assert_eq!(r.len(), c);
            d.extend_from_slice(r);
        }
        Mat { r: rows.len(), c, d }
    }
}

/// Row-major matrix of activation grid codes — the integer kernel's
/// left GEMM operand. Entries are codes `0..=levels` (≤ 255) of one
/// layer's input-activation [`crate::quant::QuantGrid`]; the sentinel
/// `-1` marks a structural zero (a SAME-padding position), which
/// dequantizes to the exact `0.0` the f32 im2col inserts.
#[derive(Clone, Debug, PartialEq)]
pub struct CodeMat {
    /// rows (im2col patches / batch rows)
    pub r: usize,
    /// columns (`k·k·C_in` patch width / fc fan-in)
    pub c: usize,
    /// row-major code storage, length `r * c`
    pub d: Vec<i16>,
}

impl CodeMat {
    /// Matrix filled with one code (`-1` primes an all-padding patch
    /// buffer that im2col then overwrites in-bounds).
    pub fn filled(r: usize, c: usize, code: i16) -> CodeMat {
        CodeMat { r, c, d: vec![code; r * c] }
    }
}

/// Pack-time weight plane for the integer kernel: the dense `[k, n]`
/// GEMM operand with all-zero rows and all-zero columns dropped, built
/// once per (layer, weights) and reused across every query until the
/// layer is invalidated. The f32 path re-materialises this matrix from
/// the weight tensor on every evaluation; packing hoists that work out
/// of the hot loop and turns pruning sparsity into skipped arithmetic.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedMat {
    /// rows of the dense operand (`k·k·C_in` / fc fan-in)
    pub k: usize,
    /// columns of the dense operand (output channels)
    pub n: usize,
    /// ascending indices of rows with at least one nonzero weight
    pub live_rows: Vec<u32>,
    /// ascending indices of columns with at least one nonzero weight;
    /// `None` when every column is live (the common dense case)
    pub live_cols: Option<Vec<u32>>,
    /// packed row-major storage, `[live_rows.len(), live col count]`
    pub d: Vec<f32>,
}

impl PackedMat {
    /// Pack a dense row-major `[k, n]` weight buffer, dropping rows and
    /// columns that are entirely zero (pruned). Panics on size
    /// mismatch, like [`Mat::from_vec`].
    pub fn pack(k: usize, n: usize, data: &[f32]) -> PackedMat {
        assert_eq!(k * n, data.len(), "pack {k}x{n} vs {} values", data.len());
        let mut col_live = vec![false; n];
        let mut live_rows: Vec<u32> = Vec::new();
        for (kk, row) in data.chunks_exact(n.max(1)).enumerate() {
            let mut any = false;
            for (live, &v) in col_live.iter_mut().zip(row) {
                if v != 0.0 {
                    *live = true;
                    any = true;
                }
            }
            if any {
                live_rows.push(kk as u32);
            }
        }
        let live_cols: Option<Vec<u32>> = if col_live.iter().all(|&b| b) {
            None
        } else {
            Some(
                col_live
                    .iter()
                    .enumerate()
                    .filter(|(_, &b)| b)
                    .map(|(c, _)| c as u32)
                    .collect(),
            )
        };
        let lc = live_cols.as_ref().map_or(n, Vec::len);
        let mut d = Vec::with_capacity(live_rows.len() * lc);
        for &kk in &live_rows {
            let row = &data[kk as usize * n..kk as usize * n + n];
            match &live_cols {
                None => d.extend_from_slice(row),
                Some(cols) => d.extend(cols.iter().map(|&c| row[c as usize])),
            }
        }
        PackedMat { k, n, live_rows, live_cols, d }
    }

    /// Number of live (non-pruned) output columns.
    pub fn live_col_count(&self) -> usize {
        self.live_cols.as_ref().map_or(self.n, Vec::len)
    }

    /// `codes[r, k] · self[k, n] → [r, n]`, dequantizing activation
    /// codes through `lut` (indexed `code + 1`; entry 0 is the
    /// structural zero). Bit-identical to `fake_quant` + [`Mat::matmul`]
    /// on the dense operand — see the module docs for the argument.
    ///
    /// Delegates to the blocked kernel at [`default_gemm_tile`]; the
    /// scalar variant stays available as [`Self::code_matmul_scalar`]
    /// for conformance and benchmarking.
    pub fn code_matmul(&self, codes: &CodeMat, lut: &[f32]) -> Mat {
        self.code_matmul_tiled(codes, lut, default_gemm_tile())
    }

    /// Scalar reference variant of [`Self::code_matmul`]: one SAXPY row
    /// sweep per nonzero activation, no blocking. Kept as the
    /// bit-parity anchor the blocked kernel is conformance-tested
    /// against (and as the baseline of the blocked-vs-scalar bench
    /// row).
    pub fn code_matmul_scalar(&self, codes: &CodeMat, lut: &[f32]) -> Mat {
        assert_eq!(
            codes.c, self.k,
            "code_matmul {}x{} · {}x{}",
            codes.r, codes.c, self.k, self.n
        );
        let lc = self.live_col_count();
        let mut out = Mat::zeros(codes.r, self.n);
        let mut scratch = vec![0.0f32; lc];
        for i in 0..codes.r {
            let crow = &codes.d[i * codes.c..(i + 1) * codes.c];
            scratch.fill(0.0);
            for (ri, &kk) in self.live_rows.iter().enumerate() {
                let a = lut[(crow[kk as usize] + 1) as usize];
                if a == 0.0 {
                    continue; // same zero-activation skip as Mat::matmul
                }
                let brow = &self.d[ri * lc..(ri + 1) * lc];
                for (o, &bv) in scratch.iter_mut().zip(brow) {
                    *o += a * bv;
                }
            }
            self.scatter_row(&mut out, i, &scratch);
        }
        out
    }

    /// Cache-blocked, lane-unrolled variant of [`Self::code_matmul`]:
    /// per code row the nonzero dequantized activations are gathered
    /// once (ascending `k`), then live output columns are processed in
    /// `tile`-wide blocks — a 4×[`GEMM_LANES`] register micro-kernel,
    /// an 8-wide remainder, and a scalar tail. Bitwise-identical to
    /// [`Self::code_matmul_scalar`] at every `tile` width (module docs
    /// carry the argument); `tile` is clamped to ≥ 1.
    pub fn code_matmul_tiled(&self, codes: &CodeMat, lut: &[f32], tile: usize) -> Mat {
        assert_eq!(
            codes.c, self.k,
            "code_matmul {}x{} · {}x{}",
            codes.r, codes.c, self.k, self.n
        );
        let tile = tile.max(1);
        let lc = self.live_col_count();
        let mut out = Mat::zeros(codes.r, self.n);
        let mut scratch = vec![0.0f32; lc];
        // (packed row index, dequantized activation) pairs, ascending k
        let mut nz: Vec<(u32, f32)> = Vec::with_capacity(self.live_rows.len());
        for i in 0..codes.r {
            let crow = &codes.d[i * codes.c..(i + 1) * codes.c];
            nz.clear();
            for (ri, &kk) in self.live_rows.iter().enumerate() {
                let a = lut[(crow[kk as usize] + 1) as usize];
                if a != 0.0 {
                    // same zero-activation skip as Mat::matmul, hoisted
                    // out of the column loops
                    nz.push((ri as u32, a));
                }
            }
            // every scratch position is stored exactly once per row
            // below (accumulators start at +0.0), so no fill needed
            let mut j0 = 0usize;
            while j0 < lc {
                let j1 = (j0 + tile).min(lc);
                let mut j = j0;
                while j + 4 * GEMM_LANES <= j1 {
                    // four independent 8-lane accumulator groups: four
                    // FMA dependency chains per k step instead of one
                    let mut acc = [[0.0f32; GEMM_LANES]; 4];
                    for &(ri, a) in &nz {
                        let base = ri as usize * lc + j;
                        let brow = &self.d[base..base + 4 * GEMM_LANES];
                        for (grp, chunk) in
                            acc.iter_mut().zip(brow.chunks_exact(GEMM_LANES))
                        {
                            for (o, &bv) in grp.iter_mut().zip(chunk) {
                                *o += a * bv;
                            }
                        }
                    }
                    for (grp, dst) in
                        acc.iter().zip(scratch[j..j + 4 * GEMM_LANES].chunks_exact_mut(GEMM_LANES))
                    {
                        dst.copy_from_slice(grp);
                    }
                    j += 4 * GEMM_LANES;
                }
                while j + GEMM_LANES <= j1 {
                    let mut acc = [0.0f32; GEMM_LANES];
                    for &(ri, a) in &nz {
                        let base = ri as usize * lc + j;
                        let brow = &self.d[base..base + GEMM_LANES];
                        for (o, &bv) in acc.iter_mut().zip(brow) {
                            *o += a * bv;
                        }
                    }
                    scratch[j..j + GEMM_LANES].copy_from_slice(&acc);
                    j += GEMM_LANES;
                }
                while j < j1 {
                    let mut acc = 0.0f32;
                    for &(ri, a) in &nz {
                        acc += a * self.d[ri as usize * lc + j];
                    }
                    scratch[j] = acc;
                    j += 1;
                }
                j0 = j1;
            }
            self.scatter_row(&mut out, i, &scratch);
        }
        out
    }

    /// Scatter one scratch row (live columns only) into output row `i`
    /// of the full-width `[r, n]` result.
    fn scatter_row(&self, out: &mut Mat, i: usize, scratch: &[f32]) {
        let orow = &mut out.d[i * self.n..(i + 1) * self.n];
        match &self.live_cols {
            None => orow.copy_from_slice(scratch),
            Some(cols) => {
                for (&c, &v) in cols.iter().zip(scratch) {
                    orow[c as usize] = v;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_hand_values() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Mat::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.d, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn transpose_products_agree() {
        // t_matmul(a, b) == transpose(a) · b ; matmul_t(a, b) == a · bᵀ
        let a = Mat::from_fn(4, 3, |i, j| (i * 3 + j) as f32 * 0.5 - 2.0);
        let b = Mat::from_fn(4, 2, |i, j| (i + j) as f32);
        let t1 = a.t_matmul(&b);
        // brute force
        let mut want = Mat::zeros(3, 2);
        for i in 0..3 {
            for j in 0..2 {
                for k in 0..4 {
                    *want.at_mut(i, j) += a.at(k, i) * b.at(k, j);
                }
            }
        }
        assert_eq!(t1, want);

        let c = Mat::from_fn(4, 3, |i, j| (i as f32 - j as f32) * 0.3);
        let t2 = b.matmul_t(&Mat::from_fn(5, 2, |i, j| (i * 2 + j) as f32 * 0.1));
        assert_eq!(t2.r, 4);
        assert_eq!(t2.c, 5);
        let _ = c;
    }

    #[test]
    fn bias_and_stack() {
        let mut m = Mat::zeros(2, 3);
        m.add_row(&[1., 2., 3.]);
        assert_eq!(m.d, vec![1., 2., 3., 1., 2., 3.]);
        let s = Mat::stack_rows(&[vec![1., 2.], vec![3., 4.]]);
        assert_eq!((s.r, s.c), (2, 2));
    }

    #[test]
    fn pack_drops_zero_rows_and_columns() {
        // [3, 3] with row 1 and column 2 entirely zero
        let w = vec![
            1.0, 2.0, 0.0, //
            0.0, 0.0, 0.0, //
            3.0, 0.0, 0.0,
        ];
        let p = PackedMat::pack(3, 3, &w);
        assert_eq!(p.live_rows, vec![0, 2]);
        assert_eq!(p.live_cols, Some(vec![0, 1]));
        assert_eq!(p.live_col_count(), 2);
        assert_eq!(p.d, vec![1.0, 2.0, 3.0, 0.0]);
        // fully dense operand keeps everything (live_cols = None)
        let dense = PackedMat::pack(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(dense.live_rows, vec![0, 1]);
        assert_eq!(dense.live_cols, None);
        assert_eq!(dense.d, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn code_matmul_matches_dense_f32_matmul_bitwise() {
        // grid {0, 0.5, 1.0, 1.5}: lut[0] = padding zero, lut[n+1] = n*0.5
        let lut = [0.0f32, 0.0, 0.5, 1.0, 1.5];
        // codes row 0: [2, 0, -1] -> values [1.0, 0.0, 0.0]
        // codes row 1: [3, 1, 2]  -> values [1.5, 0.5, 1.0]
        let codes = CodeMat { r: 2, c: 3, d: vec![2, 0, -1, 3, 1, 2] };
        let w = vec![
            1.0, -2.0, 0.0, //
            0.0, 0.0, 0.0, // dead row
            4.0, 0.5, 0.0, // column 2 dead overall
        ];
        let packed = PackedMat::pack(3, 3, &w);
        let got = packed.code_matmul(&codes, &lut);
        // the f32 reference: dequantized values through the dense matmul
        let a = Mat::from_vec(2, 3, vec![1.0, 0.0, 0.0, 1.5, 0.5, 1.0]);
        let b = Mat::from_vec(3, 3, w);
        assert_eq!(got, a.matmul(&b));
    }

    #[test]
    fn code_matmul_all_pruned_leaves_exact_zeros() {
        let lut = [0.0f32, 0.0, 1.0];
        let codes = CodeMat::filled(2, 2, 1);
        let packed = PackedMat::pack(2, 3, &[0.0; 6]);
        assert!(packed.live_rows.is_empty());
        assert_eq!(packed.live_cols, Some(vec![]));
        let y = packed.code_matmul(&codes, &lut);
        assert_eq!(y.d, vec![0.0; 6]);
    }

    /// Build a deterministic (codes, packed weights, lut) triple with
    /// mixed magnitudes, ~50% zero activations, and some pruned
    /// rows/columns — enough structure that a reordered accumulation
    /// would change bits.
    fn tiled_fixture(r: usize, k: usize, n: usize) -> (CodeMat, PackedMat, Vec<f32>) {
        let levels = 7usize;
        let mut lut = vec![0.0f32; levels + 2];
        for (q, v) in lut.iter_mut().enumerate().skip(1) {
            // irregular mantissas so additions actually round
            *v = ((q as f32) - 4.0) * 0.337 + if q % 2 == 0 { 1e-3 } else { 0.0 };
        }
        lut[1] = 0.0; // grid zero level
        let codes = CodeMat {
            r,
            c: k,
            d: (0..r * k)
                .map(|i| {
                    let h = (i * 2654435761) % 13;
                    if h < 4 {
                        -1 // structural zero / padding
                    } else {
                        (h % (levels + 1)) as i16
                    }
                })
                .collect(),
        };
        let w: Vec<f32> = (0..k * n)
            .map(|i| {
                let (row, col) = (i / n, i % n);
                if row % 5 == 3 || col % 7 == 6 {
                    0.0 // pruned rows/columns
                } else {
                    (((i * 40503) % 997) as f32 - 498.0) * 7.3e-3
                }
            })
            .collect();
        (codes, PackedMat::pack(k, n, &w), lut)
    }

    #[test]
    fn code_matmul_tiled_matches_scalar_bitwise_across_tiles() {
        // shapes chosen so tiles {1, 3, 8, 17} and the default each hit
        // different mixes of the 32-wide / 8-wide / scalar paths,
        // including non-multiple remainder columns
        for &(r, k, n) in &[(5usize, 37usize, 70usize), (3, 9, 8), (4, 16, 33), (2, 6, 1)] {
            let (codes, packed, lut) = tiled_fixture(r, k, n);
            let want = packed.code_matmul_scalar(&codes, &lut);
            for &tile in &[1usize, 3, 8, 17, DEFAULT_GEMM_TILE, 1000] {
                let got = packed.code_matmul_tiled(&codes, &lut, tile);
                assert_eq!((got.r, got.c), (want.r, want.c));
                for (g, w) in got.d.iter().zip(&want.d) {
                    assert_eq!(g.to_bits(), w.to_bits(), "tile {tile} shape {r}x{k}x{n}");
                }
            }
        }
    }

    #[test]
    fn code_matmul_tiled_degenerate_shapes() {
        // zero live columns: blocked loop never runs, scatter is a no-op
        let lut = [0.0f32, 0.0, 1.0];
        let codes = CodeMat::filled(2, 2, 1);
        let packed = PackedMat::pack(2, 3, &[0.0; 6]);
        for &tile in &[1usize, 8, 64] {
            let y = packed.code_matmul_tiled(&codes, &lut, tile);
            assert_eq!(y.d, vec![0.0; 6]);
        }
        // zero code rows
        let empty = CodeMat { r: 0, c: 2, d: vec![] };
        let dense = PackedMat::pack(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        let y = dense.code_matmul_tiled(&empty, &lut, 0); // tile clamps to 1
        assert_eq!((y.r, y.c), (0, 2));
    }

    #[test]
    fn gemm_tile_default_resolution() {
        // the override is process-wide state: exercise set + read back,
        // then restore the unset sentinel for other tests (the env
        // fallback itself is covered by the HAPQ_GEMM_TILE=3 CI lane)
        assert!(default_gemm_tile() >= 1);
        set_gemm_tile(17);
        assert_eq!(default_gemm_tile(), 17);
        set_gemm_tile(0); // 0 clears the override...
        assert!(default_gemm_tile() >= 1); // ...back to env/default resolution
    }
}
