//! Model graph: the Rust-side view of an exported architecture.
//!
//! Loads the `*.arch.json` descriptor and `*.weights.npz` blobs written
//! by `python/compile/aot.py` (artifact contract, DESIGN.md §5). The
//! prunable-layer ordering here *is* the HLO parameter ordering — the
//! runtime feeds `[w0, b0, …, wP, bP, act_bits, images]` positionally.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::hw::dataflow::LayerDims;
use crate::io::json::{self, Value};
use crate::io::npz::Npz;
use crate::tensor::Tensor;

/// Layer operator (mirrors python/compile/arch.py).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    /// 2-d convolution (HWIO weights, SAME padding)
    Conv,
    /// depthwise convolution (`[k,k,1,C]` weights, groups = C)
    DwConv,
    /// fully-connected layer (`[in,out]` weights, input flattened)
    Fc,
    /// k×k max-pooling, stride k, VALID
    MaxPool,
    /// global average pooling over H,W
    Gap,
    /// reshape to `[B, -1]`
    Flatten,
    /// elementwise residual add (optionally followed by ReLU)
    Add,
    /// channel-axis concatenation
    Concat,
}

impl Op {
    /// Parse the exporter's op string (`conv`, `dwconv`, `fc`, …).
    pub fn parse(s: &str) -> Result<Op> {
        Ok(match s {
            "conv" => Op::Conv,
            "dwconv" => Op::DwConv,
            "fc" => Op::Fc,
            "maxpool" => Op::MaxPool,
            "gap" => Op::Gap,
            "flatten" => Op::Flatten,
            "add" => Op::Add,
            "concat" => Op::Concat,
            other => bail!("unknown op `{other}`"),
        })
    }

    /// Does this op carry prunable weights (conv/dwconv/fc)?
    pub fn prunable(&self) -> bool {
        matches!(self, Op::Conv | Op::DwConv | Op::Fc)
    }
}

/// One layer of the graph (shape-annotated by the exporter).
#[derive(Clone, Debug)]
pub struct Layer {
    /// unique layer name (referenced by `inputs` of later layers)
    pub name: String,
    /// operator kind
    pub op: Op,
    /// names of the layers feeding this one (`input` = the images)
    pub inputs: Vec<String>,
    /// kernel size (convs and pooling; 1 otherwise)
    pub k: usize,
    /// spatial stride (1 for non-spatial ops)
    pub stride: usize,
    /// apply ReLU after the op?
    pub relu: bool,
    /// input activation shape (without the batch dim)
    pub in_shape: Vec<usize>,
    /// output activation shape (without the batch dim)
    pub out_shape: Vec<usize>,
    /// input channels (fan-in for fc)
    pub in_ch: usize,
    /// output channels (fan-out for fc)
    pub out_ch: usize,
}

/// Full architecture descriptor.
#[derive(Clone, Debug)]
pub struct ModelArch {
    /// model name (`vgg11`, `resnet18`, …)
    pub name: String,
    /// dataset the model was trained on
    pub dataset: String,
    /// input geometry `[H, W, C]`
    pub input: [usize; 3],
    /// number of output classes
    pub classes: usize,
    /// executor batch size the graph was exported at
    pub batch: usize,
    /// the full layer graph, topologically ordered
    pub layers: Vec<Layer>,
    /// prunable layer names, in HLO parameter order
    pub prunable: Vec<String>,
    /// prunable name → prunable index
    pub prunable_idx: HashMap<String, usize>,
    /// sets of prunable layers whose coarse channel masks must match (§4.1)
    pub dep_groups: Vec<Vec<String>>,
    /// per-prunable-layer Laplace calibration scale (activation quant)
    pub act_scales: Vec<f32>,
    /// per-prunable-layer signedness of the input activations
    pub act_signed: Vec<bool>,
    /// test accuracy of the dense 8-bit-activation model (the baseline)
    pub acc_int8: f64,
    /// total parameter count recorded by the exporter
    pub n_params: usize,
}

impl ModelArch {
    /// Load a `*.arch.json` descriptor from disk.
    pub fn load(path: &Path) -> Result<ModelArch> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path:?}"))?;
        Self::from_json(&json::parse(&text)?)
    }

    /// Build from parsed JSON (the exporter's schema).
    pub fn from_json(v: &Value) -> Result<ModelArch> {
        let layers = v
            .req("layers")?
            .as_arr()?
            .iter()
            .map(layer_from_json)
            .collect::<Result<Vec<_>>>()?;
        let prunable = v.req("prunable")?.str_vec()?;
        let prunable_idx: HashMap<String, usize> = prunable
            .iter()
            .enumerate()
            .map(|(i, n)| (n.clone(), i))
            .collect();
        let input = v.req("input")?.usize_vec()?;
        if input.len() != 3 {
            bail!("input shape must be [H, W, C]");
        }
        let act_signed = match v.get("act_signed") {
            Some(a) => a
                .as_arr()?
                .iter()
                .map(|x| x.as_bool())
                .collect::<Result<Vec<_>>>()?,
            None => vec![false; prunable.len()],
        };
        Ok(ModelArch {
            name: v.req("name")?.as_str()?.to_string(),
            dataset: v.req("dataset")?.as_str()?.to_string(),
            input: [input[0], input[1], input[2]],
            classes: v.req("classes")?.as_usize()?,
            batch: v.get("batch").map(|b| b.as_usize()).transpose()?.unwrap_or(256),
            dep_groups: v
                .req("dep_groups")?
                .as_arr()?
                .iter()
                .map(|g| g.str_vec())
                .collect::<Result<Vec<_>>>()?,
            act_scales: v
                .get("act_scales")
                .map(|a| a.f64_vec())
                .transpose()?
                .unwrap_or_default()
                .into_iter()
                .map(|x| x as f32)
                .collect(),
            act_signed,
            acc_int8: v.get("acc_int8").map(|a| a.as_f64()).transpose()?.unwrap_or(0.0),
            n_params: v.get("n_params").map(|a| a.as_usize()).transpose()?.unwrap_or(0),
            layers,
            prunable,
            prunable_idx,
        })
    }

    /// Look up a layer by name.
    pub fn layer(&self, name: &str) -> Result<&Layer> {
        self.layers
            .iter()
            .find(|l| l.name == name)
            .ok_or_else(|| anyhow!("no layer `{name}`"))
    }

    /// Prunable-layer index of `name` (panics on non-prunable).
    pub fn pidx(&self, name: &str) -> usize {
        self.prunable_idx[name]
    }

    /// Dataflow dims of every prunable layer, in prunable order —
    /// the energy model's input.
    pub fn layer_dims(&self) -> Result<Vec<LayerDims>> {
        self.prunable
            .iter()
            .map(|n| {
                let l = self.layer(n)?;
                Ok(match l.op {
                    Op::Conv => LayerDims::conv(
                        l.in_shape[0], l.in_shape[1], l.in_ch,
                        l.out_shape[0], l.out_shape[1], l.out_ch,
                        l.k, l.stride,
                    ),
                    Op::DwConv => LayerDims::dwconv(
                        l.in_shape[0], l.in_shape[1], l.in_ch,
                        l.out_shape[0], l.out_shape[1],
                        l.k, l.stride,
                    ),
                    Op::Fc => LayerDims::fc(l.in_ch, l.out_ch),
                    _ => unreachable!("non-prunable in prunable list"),
                })
            })
            .collect()
    }

    /// Group id per prunable layer (usize::MAX = ungrouped).
    pub fn group_of(&self) -> Vec<usize> {
        let mut g = vec![usize::MAX; self.prunable.len()];
        for (gi, group) in self.dep_groups.iter().enumerate() {
            for name in group {
                if let Some(&i) = self.prunable_idx.get(name) {
                    g[i] = gi;
                }
            }
        }
        g
    }
}

fn layer_from_json(v: &Value) -> Result<Layer> {
    let op = Op::parse(v.req("op")?.as_str()?)?;
    let get_us = |k: &str| -> usize { v.get(k).and_then(|x| x.as_usize().ok()).unwrap_or(0) };
    Ok(Layer {
        name: v.req("name")?.as_str()?.to_string(),
        op,
        inputs: v.req("inputs")?.str_vec()?,
        k: get_us("k").max(1),
        stride: get_us("stride").max(1),
        relu: v.get("relu").and_then(|x| x.as_bool().ok()).unwrap_or(false),
        in_shape: v.get("in_shape").map(|x| x.usize_vec()).transpose()?.unwrap_or_default(),
        out_shape: v.get("out_shape").map(|x| x.usize_vec()).transpose()?.unwrap_or_default(),
        in_ch: get_us("in_ch"),
        out_ch: get_us("out_ch"),
    })
}

/// Loaded weights + calibration stats, indexed by prunable order.
#[derive(Clone, Debug)]
pub struct Weights {
    /// weight tensors, prunable order (HWIO / `[k,k,1,C]` / `[in,out]`)
    pub w: Vec<Tensor>,
    /// bias vectors, prunable order
    pub b: Vec<Tensor>,
    /// SNIP saliency |w ⊙ ∂L/∂w| per weight tensor (Sensitivity pruning)
    pub sal: Vec<Tensor>,
    /// per-output-channel feature-map energy (FM-Reconstruction pruning)
    pub chsq: Vec<Vec<f32>>,
}

impl Weights {
    /// Load a `*.weights.npz` artifact for `arch`.
    pub fn load(arch: &ModelArch, path: &Path) -> Result<Weights> {
        let npz = Npz::load(path)?;
        Self::from_npz(arch, &npz)
    }

    /// Extract the per-layer blobs from an already-open archive.
    pub fn from_npz(arch: &ModelArch, npz: &Npz) -> Result<Weights> {
        let mut w = Vec::new();
        let mut b = Vec::new();
        let mut sal = Vec::new();
        let mut chsq = Vec::new();
        for name in &arch.prunable {
            w.push(npz.tensor(&format!("w:{name}"))?);
            b.push(npz.tensor(&format!("b:{name}"))?);
            sal.push(npz.tensor(&format!("sal:{name}"))?);
            chsq.push(npz.tensor(&format!("chsq:{name}"))?.data);
        }
        Ok(Weights { w, b, sal, chsq })
    }

    /// Total parameter count (weights + biases).
    pub fn n_params(&self) -> usize {
        self.w.iter().map(Tensor::len).sum::<usize>()
            + self.b.iter().map(Tensor::len).sum::<usize>()
    }

    /// Overall weight sparsity.
    pub fn sparsity(&self) -> f64 {
        let zeros: usize = self
            .w
            .iter()
            .map(|t| t.data.iter().filter(|x| **x == 0.0).count())
            .sum();
        let total: usize = self.w.iter().map(Tensor::len).sum();
        zeros as f64 / total.max(1) as f64
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    pub(crate) const TOY_ARCH: &str = r#"{
      "name": "toy", "dataset": "synth-c10", "input": [8, 8, 3], "classes": 4,
      "batch": 16,
      "layers": [
        {"name": "c1", "op": "conv", "inputs": ["input"], "out_ch": 4, "k": 3,
         "stride": 1, "relu": true, "in_shape": [8,8,3], "out_shape": [8,8,4],
         "in_ch": 3},
        {"name": "d1", "op": "dwconv", "inputs": ["c1"], "k": 3, "stride": 1,
         "relu": true, "in_shape": [8,8,4], "out_shape": [8,8,4], "in_ch": 4,
         "out_ch": 4},
        {"name": "gap", "op": "gap", "inputs": ["d1"], "in_shape": [8,8,4],
         "out_shape": [4]},
        {"name": "f1", "op": "fc", "inputs": ["gap"], "out": 4, "relu": false,
         "in_shape": [4], "out_shape": [4], "in_ch": 4, "out_ch": 4}
      ],
      "prunable": ["c1", "d1", "f1"],
      "dep_groups": [["c1", "d1"]],
      "act_scales": [0.5, 0.4, 0.3],
      "act_signed": [false, false, false],
      "acc_int8": 0.9, "n_params": 200
    }"#;

    pub(crate) fn toy_arch() -> ModelArch {
        ModelArch::from_json(&crate::io::json::parse(TOY_ARCH).unwrap()).unwrap()
    }

    #[test]
    fn parse_toy_arch() {
        let arch = toy_arch();
        assert_eq!(arch.prunable, vec!["c1", "d1", "f1"]);
        assert_eq!(arch.layer("d1").unwrap().op, Op::DwConv);
        let dims = arch.layer_dims().unwrap();
        assert_eq!(dims.len(), 3);
        assert_eq!(dims[1].groups, 4); // depthwise
        assert_eq!(dims[2].macs(), 16);
        let groups = arch.group_of();
        assert_eq!(groups[0], groups[1]);
        assert_eq!(groups[2], usize::MAX);
    }

    #[test]
    fn rejects_bad_ops() {
        assert!(Op::parse("conv3d").is_err());
    }
}
