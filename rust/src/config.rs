//! Run configuration + the hand-rolled CLI argument parser (clap is not
//! in the vendored registry — DESIGN.md §1).

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{bail, Result};

use crate::runtime::BackendKind;

/// Options shared by every HAPQ run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// artifact directory (`make artifacts` output)
    pub artifacts: PathBuf,
    /// output directory for result JSON
    pub out: PathBuf,
    /// RL training episodes (paper: 1100; default scaled for 1 core)
    pub episodes: usize,
    /// warm-up episodes (paper: 100)
    pub warmup: usize,
    /// reward-oracle validation subset size (paper: 10% of validation)
    pub reward_subset: usize,
    /// test-set size for final reporting
    pub test_subset: usize,
    /// RNG seed shared by every sampled component (runs are reproducible)
    pub seed: u64,
    /// MAC-sim sample count (R_Q table fidelity)
    pub mac_samples: usize,
    /// which inference backend answers accuracy queries (`--backend`)
    pub backend: BackendKind,
    /// oracle worker threads (`--threads`; default `HAPQ_THREADS` or 1)
    pub threads: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            artifacts: PathBuf::from("artifacts"),
            out: PathBuf::from("results"),
            episodes: 150,
            warmup: 15,
            reward_subset: 256,
            test_subset: 1024,
            seed: 42,
            mac_samples: 4000,
            backend: BackendKind::Native,
            threads: crate::runtime::exec::default_threads(),
        }
    }
}

/// Parsed command line: subcommand, flags, positionals.
#[derive(Clone, Debug, Default)]
pub struct Cli {
    /// the subcommand (first argument)
    pub cmd: String,
    /// `--flag value` pairs (`--flag` alone stores `"true"`)
    pub flags: HashMap<String, String>,
    /// arguments that are neither the subcommand nor flags
    pub positional: Vec<String>,
}

impl Cli {
    /// Parse raw arguments (without the binary name).
    pub fn parse(args: &[String]) -> Result<Cli> {
        let mut cli = Cli::default();
        let mut it = args.iter().peekable();
        if let Some(cmd) = it.next() {
            cli.cmd = cmd.clone();
        }
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                let val = match it.peek() {
                    Some(v) if !v.starts_with("--") => it.next().unwrap().clone(),
                    _ => "true".to_string(),
                };
                cli.flags.insert(name.to_string(), val);
            } else {
                cli.positional.push(a.clone());
            }
        }
        Ok(cli)
    }

    /// String flag with a default.
    pub fn str_flag(&self, name: &str, default: &str) -> String {
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Integer flag with a default; errors on non-numeric values.
    pub fn usize_flag(&self, name: &str, default: usize) -> Result<usize> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => match v.parse() {
                Ok(x) => Ok(x),
                Err(_) => bail!("--{name} expects an integer, got `{v}`"),
            },
        }
    }

    /// `u64` flag with a default; errors on non-numeric values.
    pub fn u64_flag(&self, name: &str, default: u64) -> Result<u64> {
        Ok(self.usize_flag(name, default as usize)? as u64)
    }

    /// Build the shared RunConfig from flags.
    pub fn run_config(&self) -> Result<RunConfig> {
        let d = RunConfig::default();
        Ok(RunConfig {
            artifacts: PathBuf::from(self.str_flag("artifacts", "artifacts")),
            out: PathBuf::from(self.str_flag("out", "results")),
            episodes: self.usize_flag("episodes", d.episodes)?,
            warmup: self.usize_flag("warmup", d.warmup)?,
            reward_subset: self.usize_flag("reward-subset", d.reward_subset)?,
            test_subset: self.usize_flag("test-subset", d.test_subset)?,
            seed: self.u64_flag("seed", d.seed)?,
            mac_samples: self.usize_flag("mac-samples", d.mac_samples)?,
            backend: BackendKind::parse(&self.str_flag("backend", d.backend.name()))?,
            threads: self.usize_flag("threads", d.threads)?.max(1),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn parses_flags_and_positionals() {
        let c = Cli::parse(&args("compress --model vgg11 --episodes 50 extra")).unwrap();
        assert_eq!(c.cmd, "compress");
        assert_eq!(c.str_flag("model", ""), "vgg11");
        assert_eq!(c.usize_flag("episodes", 0).unwrap(), 50);
        assert_eq!(c.positional, vec!["extra"]);
    }

    #[test]
    fn boolean_flags() {
        let c = Cli::parse(&args("bench --quick --model x")).unwrap();
        assert_eq!(c.str_flag("quick", ""), "true");
    }

    #[test]
    fn bad_integer_rejected() {
        let c = Cli::parse(&args("x --episodes soon")).unwrap();
        assert!(c.usize_flag("episodes", 1).is_err());
    }

    #[test]
    fn backend_flag_threads_into_config() {
        let c = Cli::parse(&args("compress --backend native")).unwrap();
        assert_eq!(c.run_config().unwrap().backend, BackendKind::Native);
        let c = Cli::parse(&args("compress --backend pjrt")).unwrap();
        assert_eq!(c.run_config().unwrap().backend, BackendKind::Pjrt);
        let c = Cli::parse(&args("compress --backend vax")).unwrap();
        assert!(c.run_config().is_err());
        // default is native
        let c = Cli::parse(&args("compress")).unwrap();
        assert_eq!(c.run_config().unwrap().backend, BackendKind::Native);
    }

    #[test]
    fn threads_flag_threads_into_config() {
        let c = Cli::parse(&args("compress --threads 3")).unwrap();
        assert_eq!(c.run_config().unwrap().threads, 3);
        // zero is clamped to one worker
        let c = Cli::parse(&args("compress --threads 0")).unwrap();
        assert_eq!(c.run_config().unwrap().threads, 1);
        // default comes from HAPQ_THREADS (or 1) — always at least one
        let c = Cli::parse(&args("compress")).unwrap();
        assert!(c.run_config().unwrap().threads >= 1);
    }
}
