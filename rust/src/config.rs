//! Run configuration + the hand-rolled CLI argument parser (clap is not
//! in the vendored registry — DESIGN.md §1).

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{bail, Result};

use crate::runtime::{BackendKind, KernelKind, MemoConfig, SchedKind};

/// Options shared by every HAPQ run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// artifact directory (`make artifacts` output)
    pub artifacts: PathBuf,
    /// output directory for result JSON
    pub out: PathBuf,
    /// RL training episodes (paper: 1100; default scaled for 1 core)
    pub episodes: usize,
    /// warm-up episodes (paper: 100)
    pub warmup: usize,
    /// reward-oracle validation subset size (paper: 10% of validation)
    pub reward_subset: usize,
    /// test-set size for final reporting
    pub test_subset: usize,
    /// RNG seed shared by every sampled component (runs are reproducible)
    pub seed: u64,
    /// MAC-sim sample count (R_Q table fidelity)
    pub mac_samples: usize,
    /// which inference backend answers accuracy queries (`--backend`)
    pub backend: BackendKind,
    /// which native compute kernel evaluates prunable layers
    /// (`--kernel`; default `HAPQ_KERNEL` or the int fast path —
    /// bit-identical to `f32`, so purely a performance knob)
    pub kernel: KernelKind,
    /// oracle worker threads (`--threads`; default `HAPQ_THREADS` or 1)
    pub threads: usize,
    /// blocked-GEMM column tile width (`--gemm-tile`; default
    /// `HAPQ_GEMM_TILE` or `nn::mat::DEFAULT_GEMM_TILE` — a perf/testing
    /// knob only, results are bit-identical at every width)
    pub gemm_tile: Option<usize>,
    /// hardware-target name driving the cost model (`--hw`; default
    /// `HAPQ_HW` or `eyeriss-64` — see `hw::target::BUILTIN_TARGETS`)
    pub hw: String,
    /// JSON accelerator-profile file; when set it overrides `--hw`
    /// (`--hw-file`, schema in `hw::target::HwTarget::from_json`)
    pub hw_file: Option<PathBuf>,
    /// independent seeds to search and merge best-of (`--seeds`)
    pub seeds: usize,
    /// search-checkpoint file (`--checkpoint [PATH]`); an empty path
    /// means "derive `<out>/<model>__<method>.ckpt`" (bare flag)
    pub checkpoint: Option<PathBuf>,
    /// episodes between periodic checkpoints (`--checkpoint-every`)
    pub checkpoint_every: usize,
    /// restore from the checkpoint before searching (`--resume`)
    pub resume: bool,
    /// suspend after N episodes this session (`--stop-after`)
    pub stop_after: Option<usize>,
    /// structured-trace output file (`--trace PATH`; default
    /// `HAPQ_TRACE`) — JSONL, `telemetry::SCHEMA` = 1, read back by
    /// `hapq trace`; `None` keeps telemetry disabled (a near-no-op)
    pub trace: Option<PathBuf>,
    /// search-loop memoization (`--memo {on,off}`, `--memo-pack-cap N`,
    /// `--memo-eval-cap N`; default `HAPQ_MEMO` or on) — eval memo,
    /// pack cache and scratch arenas; bit-identical on or off, so
    /// purely a performance switch
    pub memo: MemoConfig,
    /// oracle shard scheduler (`--sched {static,steal}`; default
    /// `HAPQ_SCHED` or steal) — work-stealing claim order over the
    /// shard slab; bit-identical to the static broadcast at every
    /// thread count, so purely a performance switch
    pub sched: SchedKind,
}

/// `HAPQ_TRACE` (non-empty) as the default `--trace` path.
fn default_trace() -> Option<PathBuf> {
    std::env::var("HAPQ_TRACE").ok().filter(|v| !v.is_empty()).map(PathBuf::from)
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            artifacts: PathBuf::from("artifacts"),
            out: PathBuf::from("results"),
            episodes: 150,
            warmup: 15,
            reward_subset: 256,
            test_subset: 1024,
            seed: 42,
            mac_samples: 4000,
            backend: BackendKind::Native,
            kernel: crate::runtime::default_kernel(),
            threads: crate::runtime::exec::default_threads(),
            gemm_tile: None,
            hw: crate::hw::target::default_hw(),
            hw_file: None,
            seeds: 1,
            checkpoint: None,
            checkpoint_every: 25,
            resume: false,
            stop_after: None,
            trace: default_trace(),
            memo: MemoConfig::default(),
            sched: crate::runtime::default_sched(),
        }
    }
}

/// Parsed command line: subcommand, flags, positionals.
#[derive(Clone, Debug, Default)]
pub struct Cli {
    /// the subcommand (first argument)
    pub cmd: String,
    /// `--flag value` pairs (`--flag` alone stores `"true"`)
    pub flags: HashMap<String, String>,
    /// arguments that are neither the subcommand nor flags
    pub positional: Vec<String>,
}

impl Cli {
    /// Parse raw arguments (without the binary name).
    pub fn parse(args: &[String]) -> Result<Cli> {
        let mut cli = Cli::default();
        let mut it = args.iter().peekable();
        if let Some(cmd) = it.next() {
            cli.cmd = cmd.clone();
        }
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                let val = match it.peek() {
                    Some(v) if !v.starts_with("--") => it.next().unwrap().clone(),
                    _ => "true".to_string(),
                };
                cli.flags.insert(name.to_string(), val);
            } else {
                cli.positional.push(a.clone());
            }
        }
        Ok(cli)
    }

    /// String flag with a default.
    pub fn str_flag(&self, name: &str, default: &str) -> String {
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Integer flag with a default; errors on non-numeric values.
    pub fn usize_flag(&self, name: &str, default: usize) -> Result<usize> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => match v.parse() {
                Ok(x) => Ok(x),
                Err(_) => bail!("--{name} expects an integer, got `{v}`"),
            },
        }
    }

    /// `u64` flag with a default; errors on non-numeric values.
    pub fn u64_flag(&self, name: &str, default: u64) -> Result<u64> {
        Ok(self.usize_flag(name, default as usize)? as u64)
    }

    /// Float flag with a default; errors on non-numeric and non-finite
    /// values (`NaN`/`inf` would otherwise flow silently into reward
    /// and cost-model math).
    pub fn f64_flag(&self, name: &str, default: f64) -> Result<f64> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => match v.parse::<f64>() {
                Ok(x) if x.is_finite() => Ok(x),
                Ok(_) => bail!("--{name} expects a finite number, got `{v}`"),
                Err(_) => bail!("--{name} expects a number, got `{v}`"),
            },
        }
    }

    /// Optional integer flag (`None` when absent).
    pub fn opt_usize_flag(&self, name: &str) -> Result<Option<usize>> {
        match self.flags.get(name) {
            None => Ok(None),
            Some(v) => match v.parse() {
                Ok(x) => Ok(Some(x)),
                Err(_) => bail!("--{name} expects an integer, got `{v}`"),
            },
        }
    }

    /// True when `--flag` was given (with or without a value).
    pub fn bool_flag(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    /// Build the shared RunConfig from flags.
    pub fn run_config(&self) -> Result<RunConfig> {
        let d = RunConfig::default();
        // `--checkpoint` without a value stores "true": keep an empty
        // path so the coordinator derives `<out>/<model>__<method>.ckpt`
        let checkpoint = self.flags.get("checkpoint").map(|v| {
            if v == "true" { PathBuf::new() } else { PathBuf::from(v) }
        });
        let cfg = RunConfig {
            artifacts: PathBuf::from(self.str_flag("artifacts", "artifacts")),
            out: PathBuf::from(self.str_flag("out", "results")),
            episodes: self.usize_flag("episodes", d.episodes)?,
            warmup: self.usize_flag("warmup", d.warmup)?,
            reward_subset: self.usize_flag("reward-subset", d.reward_subset)?,
            test_subset: self.usize_flag("test-subset", d.test_subset)?,
            seed: self.u64_flag("seed", d.seed)?,
            mac_samples: self.usize_flag("mac-samples", d.mac_samples)?,
            backend: BackendKind::parse(&self.str_flag("backend", d.backend.name()))?,
            kernel: KernelKind::parse(&self.str_flag("kernel", d.kernel.name()))?,
            threads: self.usize_flag("threads", d.threads)?.max(1),
            gemm_tile: self.opt_usize_flag("gemm-tile")?.map(|t| t.max(1)),
            hw: self.str_flag("hw", &d.hw),
            hw_file: self.flags.get("hw-file").map(PathBuf::from),
            seeds: self.usize_flag("seeds", d.seeds)?.max(1),
            checkpoint,
            checkpoint_every: self.usize_flag("checkpoint-every", d.checkpoint_every)?,
            resume: self.bool_flag("resume"),
            stop_after: self.opt_usize_flag("stop-after")?,
            trace: self.flags.get("trace").map(PathBuf::from).or(d.trace),
            memo: MemoConfig {
                enabled: match self.flags.get("memo") {
                    Some(v) => crate::runtime::parse_memo(v)?,
                    None => d.memo.enabled,
                },
                pack_cap: self.usize_flag("memo-pack-cap", d.memo.pack_cap)?,
                eval_cap: self.usize_flag("memo-eval-cap", d.memo.eval_cap)?,
            },
            sched: SchedKind::parse(&self.str_flag("sched", d.sched.name()))?,
        };
        if cfg.seeds > 1 && (cfg.resume || cfg.stop_after.is_some() || cfg.checkpoint.is_some()) {
            bail!(
                "--seeds fans out worker processes, which do not inherit \
                 --checkpoint/--resume/--stop-after; run (and resume) individual \
                 seeds with explicit --seed/--out instead"
            );
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn parses_flags_and_positionals() {
        let c = Cli::parse(&args("compress --model vgg11 --episodes 50 extra")).unwrap();
        assert_eq!(c.cmd, "compress");
        assert_eq!(c.str_flag("model", ""), "vgg11");
        assert_eq!(c.usize_flag("episodes", 0).unwrap(), 50);
        assert_eq!(c.positional, vec!["extra"]);
    }

    #[test]
    fn boolean_flags() {
        let c = Cli::parse(&args("bench --quick --model x")).unwrap();
        assert_eq!(c.str_flag("quick", ""), "true");
    }

    #[test]
    fn bad_integer_rejected() {
        let c = Cli::parse(&args("x --episodes soon")).unwrap();
        assert!(c.usize_flag("episodes", 1).is_err());
    }

    #[test]
    fn backend_flag_threads_into_config() {
        let c = Cli::parse(&args("compress --backend native")).unwrap();
        assert_eq!(c.run_config().unwrap().backend, BackendKind::Native);
        let c = Cli::parse(&args("compress --backend pjrt")).unwrap();
        assert_eq!(c.run_config().unwrap().backend, BackendKind::Pjrt);
        let c = Cli::parse(&args("compress --backend vax")).unwrap();
        assert!(c.run_config().is_err());
        // default is native
        let c = Cli::parse(&args("compress")).unwrap();
        assert_eq!(c.run_config().unwrap().backend, BackendKind::Native);
    }

    #[test]
    fn search_flags_thread_into_config() {
        let c = Cli::parse(&args("compress --seeds 4 --checkpoint-every 5")).unwrap();
        let cfg = c.run_config().unwrap();
        assert_eq!(cfg.seeds, 4);
        assert_eq!(cfg.checkpoint_every, 5);
        assert!(cfg.checkpoint.is_none());
        assert!(!cfg.resume);
        assert_eq!(cfg.stop_after, None);
        // bare --checkpoint derives the default path (empty sentinel)
        let c = Cli::parse(&args("compress --checkpoint")).unwrap();
        assert_eq!(c.run_config().unwrap().checkpoint, Some(PathBuf::new()));
        let c = Cli::parse(&args("compress --checkpoint run.ckpt --resume --stop-after 2"))
            .unwrap();
        let cfg = c.run_config().unwrap();
        assert_eq!(cfg.checkpoint, Some(PathBuf::from("run.ckpt")));
        assert!(cfg.resume);
        assert_eq!(cfg.stop_after, Some(2));
        // --seeds 0 clamps to 1; bad integers are rejected
        let c = Cli::parse(&args("compress --seeds 0")).unwrap();
        assert_eq!(c.run_config().unwrap().seeds, 1);
        let c = Cli::parse(&args("compress --stop-after soon")).unwrap();
        assert!(c.run_config().is_err());
        // multi-seed fan-out excludes the single-run checkpoint flags
        // (workers would silently drop them otherwise)
        let c = Cli::parse(&args("compress --seeds 2 --resume")).unwrap();
        assert!(c.run_config().is_err());
        let c = Cli::parse(&args("compress --seeds 2 --checkpoint")).unwrap();
        assert!(c.run_config().is_err());
        let c = Cli::parse(&args("compress --seeds 2 --stop-after 3")).unwrap();
        assert!(c.run_config().is_err());
    }

    #[test]
    fn kernel_flag_threads_into_config() {
        let c = Cli::parse(&args("compress --kernel f32")).unwrap();
        assert_eq!(c.run_config().unwrap().kernel, KernelKind::F32);
        let c = Cli::parse(&args("compress --kernel int")).unwrap();
        assert_eq!(c.run_config().unwrap().kernel, KernelKind::Int);
        let c = Cli::parse(&args("compress --kernel i8")).unwrap();
        assert!(c.run_config().is_err());
        // default is the process default (HAPQ_KERNEL or int)
        let c = Cli::parse(&args("compress")).unwrap();
        assert_eq!(c.run_config().unwrap().kernel, crate::runtime::default_kernel());
    }

    #[test]
    fn hw_flags_thread_into_config() {
        let c = Cli::parse(&args("compress --hw mcu")).unwrap();
        let cfg = c.run_config().unwrap();
        assert_eq!(cfg.hw, "mcu");
        assert_eq!(cfg.hw_file, None);
        let c = Cli::parse(&args("compress --hw-file profiles/npu.json")).unwrap();
        let cfg = c.run_config().unwrap();
        assert_eq!(cfg.hw_file, Some(PathBuf::from("profiles/npu.json")));
        // the default is the env-derived target name (HAPQ_HW or
        // eyeriss-64); the name is validated at resolve time, not here,
        // so `compare` can carry a comma-list through this field
        let c = Cli::parse(&args("compress")).unwrap();
        assert_eq!(c.run_config().unwrap().hw, crate::hw::target::default_hw());
        let c = Cli::parse(&args("compare --hw eyeriss-64,mcu")).unwrap();
        assert_eq!(c.run_config().unwrap().hw, "eyeriss-64,mcu");
    }

    #[test]
    fn trace_flag_threads_into_config() {
        let c = Cli::parse(&args("compress --trace out/t.jsonl")).unwrap();
        assert_eq!(c.run_config().unwrap().trace, Some(PathBuf::from("out/t.jsonl")));
        // absent falls back to HAPQ_TRACE; with neither set, telemetry
        // stays disabled (env-dependent, so only pin the flagged case
        // plus the flag-wins-over-default ordering)
        let c = Cli::parse(&args("compress")).unwrap();
        assert_eq!(c.run_config().unwrap().trace, super::default_trace());
    }

    #[test]
    fn f64_flag_parses_and_rejects() {
        let c = Cli::parse(&args("hw --sparsity 0.25")).unwrap();
        assert!((c.f64_flag("sparsity", 0.5).unwrap() - 0.25).abs() < 1e-12);
        assert!((c.f64_flag("missing", 0.5).unwrap() - 0.5).abs() < 1e-12);
        let c = Cli::parse(&args("hw --sparsity lots")).unwrap();
        assert!(c.f64_flag("sparsity", 0.5).is_err());
        // non-finite values parse as f64 but are rejected here: NaN or
        // inf sparsity would silently corrupt the hw-breakdown math
        for bad in ["NaN", "nan", "inf", "infinity"] {
            let c = Cli::parse(&["hw".to_string(), "--sparsity".into(), bad.into()]).unwrap();
            let err = c.f64_flag("sparsity", 0.5).unwrap_err().to_string();
            assert!(err.contains("finite"), "`{bad}` not rejected: {err}");
        }
        // `-inf` is consumed as a flag value (only `--` marks flags)
        let c = Cli::parse(&["hw".into(), "--sparsity".into(), "-inf".into()]).unwrap();
        assert!(c.f64_flag("sparsity", 0.5).is_err());
        // a non-finite *default* is still returned untouched: callers
        // use NAN defaults as an "unset" sentinel
        let c = Cli::parse(&args("hw")).unwrap();
        assert!(c.f64_flag("sparsity", f64::NAN).unwrap().is_nan());
    }

    #[test]
    fn gemm_tile_flag_threads_into_config() {
        let c = Cli::parse(&args("compress --gemm-tile 3")).unwrap();
        assert_eq!(c.run_config().unwrap().gemm_tile, Some(3));
        // zero-width tiles clamp to 1
        let c = Cli::parse(&args("compress --gemm-tile 0")).unwrap();
        assert_eq!(c.run_config().unwrap().gemm_tile, Some(1));
        // absent means "use HAPQ_GEMM_TILE / the built-in default"
        let c = Cli::parse(&args("compress")).unwrap();
        assert_eq!(c.run_config().unwrap().gemm_tile, None);
        let c = Cli::parse(&args("compress --gemm-tile wide")).unwrap();
        assert!(c.run_config().is_err());
    }

    #[test]
    fn memo_flag_threads_into_config() {
        let c = Cli::parse(&args("compress --memo off")).unwrap();
        let cfg = c.run_config().unwrap();
        assert!(!cfg.memo.enabled);
        let c = Cli::parse(&args("compress --memo on --memo-pack-cap 7 --memo-eval-cap 9"))
            .unwrap();
        let cfg = c.run_config().unwrap();
        assert!(cfg.memo.enabled);
        assert_eq!((cfg.memo.pack_cap, cfg.memo.eval_cap), (7, 9));
        // bad values are rejected, absent falls back to the env default
        let c = Cli::parse(&args("compress --memo sometimes")).unwrap();
        assert!(c.run_config().is_err());
        let c = Cli::parse(&args("compress --memo-pack-cap big")).unwrap();
        assert!(c.run_config().is_err());
        let c = Cli::parse(&args("compress")).unwrap();
        assert_eq!(c.run_config().unwrap().memo, MemoConfig::default());
    }

    #[test]
    fn sched_flag_threads_into_config() {
        let c = Cli::parse(&args("compress --sched static")).unwrap();
        assert_eq!(c.run_config().unwrap().sched, SchedKind::Static);
        let c = Cli::parse(&args("compress --sched steal")).unwrap();
        assert_eq!(c.run_config().unwrap().sched, SchedKind::Steal);
        let c = Cli::parse(&args("compress --sched greedy")).unwrap();
        assert!(c.run_config().is_err());
        // default is the process default (HAPQ_SCHED or steal)
        let c = Cli::parse(&args("compress")).unwrap();
        assert_eq!(c.run_config().unwrap().sched, crate::runtime::default_sched());
    }

    #[test]
    fn threads_flag_threads_into_config() {
        let c = Cli::parse(&args("compress --threads 3")).unwrap();
        assert_eq!(c.run_config().unwrap().threads, 3);
        // zero is clamped to one worker
        let c = Cli::parse(&args("compress --threads 0")).unwrap();
        assert_eq!(c.run_config().unwrap().threads, 1);
        // default comes from HAPQ_THREADS (or 1) — always at least one
        let c = Cli::parse(&args("compress")).unwrap();
        assert!(c.run_config().unwrap().threads >= 1);
    }
}
