//! NPZ/NPY reader (and a small writer for checkpoints).
//!
//! `np.savez` produces a ZIP archive of `.npy` members with compression
//! method 0 (stored) — exactly what the artifact contract uses. We
//! parse the ZIP end-of-central-directory + central directory + local
//! headers ourselves (the vendored `zip` crate drags in crypto/zstd
//! deps we don't need) and the NPY v1/v2 header dict by hand.
//!
//! Supported dtypes: `<f4`, `<f8`, `<i4`, `<i8` — everything the
//! exporter emits.

use std::collections::HashMap;
use std::io::Write;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::tensor::Tensor;

/// One array loaded from an archive.
#[derive(Clone, Debug)]
pub struct Npy {
    /// dimension sizes, outermost first
    pub shape: Vec<usize>,
    /// the payload, widened to one of two host types
    pub data: NpyData,
}

/// Array payload: floats widen to f32-compatible, ints to i64.
#[derive(Clone, Debug)]
pub enum NpyData {
    /// `<f4` / `<f8` sources
    F32(Vec<f32>),
    /// `<i4` / `<i8` sources
    I64(Vec<i64>),
}

impl Npy {
    /// Convert to a float [`Tensor`] (errors on integer arrays).
    pub fn to_tensor(&self) -> Result<Tensor> {
        match &self.data {
            NpyData::F32(v) => Ok(Tensor::new(self.shape.clone(), v.clone())),
            NpyData::I64(_) => bail!("integer array where f32 expected"),
        }
    }

    /// Borrow as integers (errors on float arrays).
    pub fn as_i64(&self) -> Result<&[i64]> {
        match &self.data {
            NpyData::I64(v) => Ok(v),
            NpyData::F32(_) => bail!("float array where integers expected"),
        }
    }
}

/// Parsed NPZ archive: name -> array.
pub struct Npz {
    /// member name (without `.npy`) → parsed array
    pub entries: HashMap<String, Npy>,
}

impl Npz {
    /// Read and parse an archive from disk.
    pub fn load(path: &Path) -> Result<Npz> {
        let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
        Self::from_bytes(&bytes)
    }

    /// Parse an archive from memory.
    pub fn from_bytes(bytes: &[u8]) -> Result<Npz> {
        let mut entries = HashMap::new();
        for (name, data) in zip_entries(bytes)? {
            let name = name.strip_suffix(".npy").unwrap_or(&name).to_string();
            entries.insert(name, parse_npy(data)?);
        }
        Ok(Npz { entries })
    }

    /// Required float member as a [`Tensor`].
    pub fn tensor(&self, key: &str) -> Result<Tensor> {
        self.entries
            .get(key)
            .ok_or_else(|| anyhow!("npz missing key `{key}`"))?
            .to_tensor()
    }

    /// Required integer member.
    pub fn i64s(&self, key: &str) -> Result<Vec<i64>> {
        Ok(self
            .entries
            .get(key)
            .ok_or_else(|| anyhow!("npz missing key `{key}`"))?
            .as_i64()?
            .to_vec())
    }
}

// ---------------------------------------------------------------------------
// ZIP (stored entries only)

fn rd_u16(b: &[u8], o: usize) -> usize {
    u16::from_le_bytes([b[o], b[o + 1]]) as usize
}

fn rd_u32(b: &[u8], o: usize) -> usize {
    u32::from_le_bytes([b[o], b[o + 1], b[o + 2], b[o + 3]]) as usize
}

/// Iterate (name, raw bytes) of all stored entries.
fn zip_entries(b: &[u8]) -> Result<Vec<(String, &[u8])>> {
    // find End Of Central Directory record (sig 0x06054b50), scanning back
    let eocd = (0..=b.len().saturating_sub(22))
        .rev()
        .find(|&i| b[i..i + 4] == [0x50, 0x4b, 0x05, 0x06])
        .ok_or_else(|| anyhow!("not a zip: EOCD not found"))?;
    let n_entries = rd_u16(b, eocd + 10);
    let cd_off = rd_u32(b, eocd + 16);
    let mut out = Vec::with_capacity(n_entries);
    let mut o = cd_off;
    for _ in 0..n_entries {
        if b[o..o + 4] != [0x50, 0x4b, 0x01, 0x02] {
            bail!("bad central directory signature at {o}");
        }
        let method = rd_u16(b, o + 10);
        let mut size = rd_u32(b, o + 20); // compressed == uncompressed (stored)
        let name_len = rd_u16(b, o + 28);
        let extra_len = rd_u16(b, o + 30);
        let comment_len = rd_u16(b, o + 32);
        let lho = rd_u32(b, o + 42);
        let name = String::from_utf8_lossy(&b[o + 46..o + 46 + name_len]).to_string();
        if method != 0 {
            bail!("zip entry `{name}` uses compression method {method}; only stored (0) supported — use np.savez, not savez_compressed");
        }
        if size == 0xFFFF_FFFF {
            // zip64: real size lives in the extra field (tag 0x0001)
            let mut e = o + 46 + name_len;
            let end = e + extra_len;
            let mut found = false;
            while e + 4 <= end {
                let tag = rd_u16(b, e);
                let len = rd_u16(b, e + 2);
                if tag == 0x0001 && len >= 8 {
                    size = u64::from_le_bytes(b[e + 4..e + 12].try_into().unwrap()) as usize;
                    found = true;
                    break;
                }
                e += 4 + len;
            }
            if !found {
                bail!("zip64 entry `{name}` without zip64 extra field");
            }
        }
        // local header only locates the payload; sizes come from the CD
        // (numpy writes zip64 placeholders in local headers)
        if b[lho..lho + 4] != [0x50, 0x4b, 0x03, 0x04] {
            bail!("bad local header signature for `{name}`");
        }
        let l_name = rd_u16(b, lho + 26);
        let l_extra = rd_u16(b, lho + 28);
        let start = lho + 30 + l_name + l_extra;
        if start + size > b.len() {
            bail!("zip entry `{name}` overruns archive ({start}+{size} > {})", b.len());
        }
        out.push((name, &b[start..start + size]));
        o += 46 + name_len + extra_len + comment_len;
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// NPY

fn parse_npy(b: &[u8]) -> Result<Npy> {
    if b.len() < 10 || &b[0..6] != b"\x93NUMPY" {
        bail!("bad npy magic");
    }
    let major = b[6];
    let (header, data_off) = if major == 1 {
        let hlen = rd_u16(b, 8);
        (std::str::from_utf8(&b[10..10 + hlen])?, 10 + hlen)
    } else {
        let hlen = rd_u32(b, 8);
        (std::str::from_utf8(&b[12..12 + hlen])?, 12 + hlen)
    };
    let descr = dict_str(header, "descr")?;
    if dict_bool(header, "fortran_order")? {
        bail!("fortran_order arrays unsupported");
    }
    let shape = dict_shape(header)?;
    let n: usize = shape.iter().product();
    let raw = &b[data_off..];
    let data = match descr.as_str() {
        "<f4" => NpyData::F32(
            raw.chunks_exact(4)
                .take(n)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect(),
        ),
        "<f8" => NpyData::F32(
            raw.chunks_exact(8)
                .take(n)
                .map(|c| f64::from_le_bytes(c.try_into().unwrap()) as f32)
                .collect(),
        ),
        "<i4" => NpyData::I64(
            raw.chunks_exact(4)
                .take(n)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]) as i64)
                .collect(),
        ),
        "<i8" => NpyData::I64(
            raw.chunks_exact(8)
                .take(n)
                .map(|c| i64::from_le_bytes(c.try_into().unwrap()))
                .collect(),
        ),
        d => bail!("unsupported npy dtype `{d}`"),
    };
    let got = match &data {
        NpyData::F32(v) => v.len(),
        NpyData::I64(v) => v.len(),
    };
    if got != n {
        bail!("npy truncated: want {n} elements, got {got}");
    }
    Ok(Npy { shape, data })
}

fn dict_str(h: &str, key: &str) -> Result<String> {
    let pat = format!("'{key}':");
    let i = h.find(&pat).ok_or_else(|| anyhow!("npy header missing {key}"))?;
    let rest = &h[i + pat.len()..];
    let q1 = rest.find('\'').ok_or_else(|| anyhow!("bad {key}"))?;
    let q2 = rest[q1 + 1..].find('\'').ok_or_else(|| anyhow!("bad {key}"))?;
    Ok(rest[q1 + 1..q1 + 1 + q2].to_string())
}

fn dict_bool(h: &str, key: &str) -> Result<bool> {
    let pat = format!("'{key}':");
    let i = h.find(&pat).ok_or_else(|| anyhow!("npy header missing {key}"))?;
    let rest = h[i + pat.len()..].trim_start();
    Ok(rest.starts_with("True"))
}

fn dict_shape(h: &str) -> Result<Vec<usize>> {
    let i = h.find("'shape':").ok_or_else(|| anyhow!("npy header missing shape"))?;
    let rest = &h[i + 8..];
    let o = rest.find('(').ok_or_else(|| anyhow!("bad shape"))?;
    let c = rest.find(')').ok_or_else(|| anyhow!("bad shape"))?;
    let inner = &rest[o + 1..c];
    let mut out = Vec::new();
    for part in inner.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        out.push(part.parse::<usize>()?);
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Writer (checkpoints): stored-zip of f32 npy members.

/// Write f32 tensors as a stored-zip NPZ (checkpoint format).
pub fn save_npz(path: &Path, arrays: &[(String, &Tensor)]) -> Result<()> {
    let mut zip_buf: Vec<u8> = Vec::new();
    let mut central: Vec<u8> = Vec::new();
    let mut n = 0u16;
    for (name, t) in arrays {
        let fname = format!("{name}.npy");
        let member = npy_bytes(t);
        let crc = crc32(&member);
        let off = zip_buf.len() as u32;
        // local header
        zip_buf.extend_from_slice(&[0x50, 0x4b, 0x03, 0x04, 20, 0, 0, 0, 0, 0, 0, 0, 0, 0]);
        zip_buf.extend_from_slice(&crc.to_le_bytes());
        zip_buf.extend_from_slice(&(member.len() as u32).to_le_bytes());
        zip_buf.extend_from_slice(&(member.len() as u32).to_le_bytes());
        zip_buf.extend_from_slice(&(fname.len() as u16).to_le_bytes());
        zip_buf.extend_from_slice(&0u16.to_le_bytes());
        zip_buf.extend_from_slice(fname.as_bytes());
        zip_buf.extend_from_slice(&member);
        // central directory entry
        central.extend_from_slice(&[0x50, 0x4b, 0x01, 0x02, 20, 0, 20, 0, 0, 0, 0, 0, 0, 0, 0, 0]);
        central.extend_from_slice(&crc.to_le_bytes());
        central.extend_from_slice(&(member.len() as u32).to_le_bytes());
        central.extend_from_slice(&(member.len() as u32).to_le_bytes());
        central.extend_from_slice(&(fname.len() as u16).to_le_bytes());
        central.extend_from_slice(&[0u8; 12]);
        central.extend_from_slice(&off.to_le_bytes());
        central.extend_from_slice(fname.as_bytes());
        n += 1;
    }
    let cd_off = zip_buf.len() as u32;
    let cd_len = central.len() as u32;
    zip_buf.extend_from_slice(&central);
    zip_buf.extend_from_slice(&[0x50, 0x4b, 0x05, 0x06, 0, 0, 0, 0]);
    zip_buf.extend_from_slice(&n.to_le_bytes());
    zip_buf.extend_from_slice(&n.to_le_bytes());
    zip_buf.extend_from_slice(&cd_len.to_le_bytes());
    zip_buf.extend_from_slice(&cd_off.to_le_bytes());
    zip_buf.extend_from_slice(&0u16.to_le_bytes());
    let mut f = std::fs::File::create(path)?;
    f.write_all(&zip_buf)?;
    Ok(())
}

fn npy_bytes(t: &Tensor) -> Vec<u8> {
    let shape = t
        .shape
        .iter()
        .map(|d| d.to_string())
        .collect::<Vec<_>>()
        .join(", ");
    let trail = if t.shape.len() == 1 { "," } else { "" };
    let mut header = format!(
        "{{'descr': '<f4', 'fortran_order': False, 'shape': ({shape}{trail}), }}"
    );
    let total = 10 + header.len() + 1;
    let pad = (64 - total % 64) % 64;
    header.push_str(&" ".repeat(pad));
    header.push('\n');
    let mut out = Vec::with_capacity(10 + header.len() + t.data.len() * 4);
    out.extend_from_slice(b"\x93NUMPY\x01\x00");
    out.extend_from_slice(&(header.len() as u16).to_le_bytes());
    out.extend_from_slice(header.as_bytes());
    for x in &t.data {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn crc32(data: &[u8]) -> u32 {
    // standard CRC-32 (IEEE), small table-less implementation
    let mut crc = 0xFFFF_FFFFu32;
    for &byte in data {
        crc ^= byte as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_via_writer() {
        let t1 = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let t2 = Tensor::new(vec![4], vec![-1., 0., 1., 2.]);
        let dir = std::env::temp_dir().join("hapq_npz_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("rt.npz");
        save_npz(&p, &[("a".into(), &t1), ("b".into(), &t2)]).unwrap();
        let npz = Npz::load(&p).unwrap();
        assert_eq!(npz.tensor("a").unwrap(), t1);
        assert_eq!(npz.tensor("b").unwrap(), t2);
    }

    #[test]
    fn rejects_non_zip() {
        assert!(Npz::from_bytes(b"hello world, definitely not a zip").is_err());
    }

    #[test]
    fn crc_known_value() {
        // CRC-32("123456789") = 0xCBF43926
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
    }
}
