//! IO substrates: minimal JSON (serde is not vendored), NPZ/NPY
//! readers for the artifact contract (DESIGN.md §5), and the exact
//! binary writer/reader behind resumable search checkpoints.

pub mod bin;
pub mod json;
pub mod npz;
