//! IO substrates: minimal JSON (serde is not vendored) and NPZ/NPY
//! readers for the artifact contract (DESIGN.md §5).

pub mod json;
pub mod npz;
