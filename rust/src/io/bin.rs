//! Exact binary state serialization — the substrate of the
//! method-agnostic [`crate::search::checkpoint::SearchCheckpoint`].
//!
//! The NPZ policy checkpoint ([`crate::rl::checkpoint`]) is f32-only
//! and deliberately lossy (it persists *policies*, not mid-run search
//! state). Resumable search needs more: every `f64` (rewards, duals,
//! replay priorities, RNG spare), every `u64` (xoshiro lanes, step
//! counters) and every Adam moment must round-trip **bit-exactly**, or
//! a resumed run diverges from the uninterrupted one. This module is a
//! tiny little-endian writer/reader pair over `Vec<u8>` with no
//! external deps: floats travel as their IEEE-754 bit patterns, so
//! save → load is the identity on every value.

use anyhow::{bail, Result};

/// Append-only little-endian byte writer.
#[derive(Debug, Default)]
pub struct BinWriter {
    /// the accumulated bytes
    pub buf: Vec<u8>,
}

impl BinWriter {
    /// Empty writer.
    pub fn new() -> BinWriter {
        BinWriter { buf: Vec::new() }
    }

    /// Write one byte.
    pub fn u8(&mut self, x: u8) {
        self.buf.push(x);
    }

    /// Write a `u32` (little-endian).
    pub fn u32(&mut self, x: u32) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    /// Write a `u64` (little-endian).
    pub fn u64(&mut self, x: u64) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    /// Write a `usize` as `u64`.
    pub fn usize(&mut self, x: usize) {
        self.u64(x as u64);
    }

    /// Write an `f32` as its exact bit pattern.
    pub fn f32(&mut self, x: f32) {
        self.u32(x.to_bits());
    }

    /// Write an `f64` as its exact bit pattern.
    pub fn f64(&mut self, x: f64) {
        self.u64(x.to_bits());
    }

    /// Write a bool as one byte.
    pub fn bool(&mut self, x: bool) {
        self.u8(x as u8);
    }

    /// Write a length-prefixed UTF-8 string.
    pub fn str(&mut self, s: &str) {
        self.usize(s.len());
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Write a length-prefixed `f32` slice (bit patterns).
    pub fn f32s(&mut self, xs: &[f32]) {
        self.usize(xs.len());
        for &x in xs {
            self.f32(x);
        }
    }

    /// Write a length-prefixed `f64` slice (bit patterns).
    pub fn f64s(&mut self, xs: &[f64]) {
        self.usize(xs.len());
        for &x in xs {
            self.f64(x);
        }
    }
}

/// Cursor-based reader over bytes produced by [`BinWriter`]. Every
/// accessor checks bounds and fails with a clear error instead of
/// panicking, so truncated/corrupt checkpoints surface as `Err`.
#[derive(Debug)]
pub struct BinReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> BinReader<'a> {
    /// Reader positioned at the start of `buf`.
    pub fn new(buf: &'a [u8]) -> BinReader<'a> {
        BinReader { buf, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!(
                "checkpoint truncated: need {n} bytes at offset {}, have {}",
                self.pos,
                self.remaining()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Read one byte.
    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    /// Read a `u32`.
    pub fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Read a `u64`.
    pub fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    /// Read a `usize` (stored as `u64`).
    pub fn usize(&mut self) -> Result<usize> {
        Ok(self.u64()? as usize)
    }

    /// Read an `f32` bit pattern.
    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }

    /// Read an `f64` bit pattern.
    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Read a bool.
    pub fn bool(&mut self) -> Result<bool> {
        Ok(self.u8()? != 0)
    }

    /// Read a length-prefixed UTF-8 string.
    pub fn str(&mut self) -> Result<String> {
        let n = self.usize()?;
        let b = self.take(n)?;
        Ok(String::from_utf8(b.to_vec())?)
    }

    /// Read a length-prefixed `f32` slice.
    pub fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.usize()?;
        let mut v = Vec::with_capacity(n.min(self.remaining() / 4 + 1));
        for _ in 0..n {
            v.push(self.f32()?);
        }
        Ok(v)
    }

    /// Read a length-prefixed `f64` slice.
    pub fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.usize()?;
        let mut v = Vec::with_capacity(n.min(self.remaining() / 8 + 1));
        for _ in 0..n {
            v.push(self.f64()?);
        }
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_is_bit_exact() {
        let mut w = BinWriter::new();
        w.u8(7);
        w.u32(0xDEAD_BEEF);
        w.u64(u64::MAX - 3);
        w.f32(f32::from_bits(0x7F80_0001)); // a signalling NaN pattern
        w.f64(-0.1);
        w.f64(f64::NEG_INFINITY);
        w.bool(true);
        w.str("hapq ✓");
        w.f32s(&[1.5, -0.0, f32::MIN_POSITIVE]);
        w.f64s(&[std::f64::consts::PI]);

        let mut r = BinReader::new(&w.buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.f32().unwrap().to_bits(), 0x7F80_0001);
        assert_eq!(r.f64().unwrap().to_bits(), (-0.1f64).to_bits());
        assert!(r.f64().unwrap().is_infinite());
        assert!(r.bool().unwrap());
        assert_eq!(r.str().unwrap(), "hapq ✓");
        let xs = r.f32s().unwrap();
        assert_eq!(xs.len(), 3);
        assert_eq!(xs[1].to_bits(), (-0.0f32).to_bits());
        assert_eq!(r.f64s().unwrap(), vec![std::f64::consts::PI]);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn truncated_reads_error_not_panic() {
        let mut w = BinWriter::new();
        w.u64(42);
        let mut r = BinReader::new(&w.buf[..5]);
        assert!(r.u64().is_err());
        // bogus length prefix on a string must not over-read
        let mut w2 = BinWriter::new();
        w2.usize(1 << 40);
        let mut r2 = BinReader::new(&w2.buf);
        assert!(r2.str().is_err());
        // same for slice readers (capacity hint must not allocate 2^40)
        let mut r3 = BinReader::new(&w2.buf);
        assert!(r3.f64s().is_err());
    }
}
