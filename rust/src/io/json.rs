//! Minimal JSON parser + emitter (serde/serde_json are not in the
//! vendored registry — DESIGN.md §1). Covers the full JSON grammar the
//! artifact contract uses: objects, arrays, strings with escapes,
//! numbers, bools, null. Object key order is preserved (Vec-backed) so
//! emitted configs diff cleanly.

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// any JSON number (f64-backed)
    Num(f64),
    /// a string
    Str(String),
    /// an array
    Arr(Vec<Value>),
    /// an object; key order preserved
    Obj(Vec<(String, Value)>),
}

impl Value {
    /// Object field lookup (None on missing key or non-object).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Required object field (error names the missing key).
    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key).ok_or_else(|| anyhow!("missing key `{key}`"))
    }

    /// Read as a number.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(x) => Ok(*x),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    /// Read as a non-negative integer (truncating).
    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    /// Read as a string.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    /// Read as a bool.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    /// Read as an array slice.
    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(v) => Ok(v),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    /// Read as an array of strings.
    pub fn str_vec(&self) -> Result<Vec<String>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_str().map(str::to_string))
            .collect()
    }

    /// Read as an array of numbers.
    pub fn f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    /// Read as an array of non-negative integers.
    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    /// Compact serialisation.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Value::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Value::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Value::Obj(kv) => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Value::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for building result JSON.
pub fn obj(kv: Vec<(&str, Value)>) -> Value {
    Value::Obj(kv.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Number value.
pub fn num(x: f64) -> Value {
    Value::Num(x)
}

/// String value.
pub fn s(x: &str) -> Value {
    Value::Str(x.to_string())
}

/// Array value.
pub fn arr(v: Vec<Value>) -> Value {
    Value::Arr(v)
}

/// Parse a complete JSON document (trailing garbage is an error).
pub fn parse(text: &str) -> Result<Value> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        bail!("trailing garbage at byte {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected `{}` at byte {}, found `{}`",
                  c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.eat(b'{')?;
        let mut kv = Vec::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Obj(kv));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            kv.push((k, v));
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Obj(kv));
                }
                c => bail!("expected , or }} got `{}` at {}", c as char, self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Value::Arr(v));
                }
                c => bail!("expected , or ] got `{}` at {}", c as char, self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape `\\{}`", e as char),
                    }
                }
                c => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let chunk = std::str::from_utf8(&self.b[start..start + len])?;
                        s.push_str(chunk);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Value::Num(txt.parse::<f64>().map_err(|e| anyhow!("bad number `{txt}`: {e}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": true, "d": null}, "e": "x\n\"y\""}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        assert_eq!(v.get("a").unwrap().f64_vec().unwrap(), vec![1.0, 2.5, -300.0]);
        assert!(v.get("b").unwrap().get("c").unwrap().as_bool().unwrap());
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("123abc").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn unicode_strings() {
        let v = parse(r#""é café λ""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é café λ");
    }

    #[test]
    fn nested_deep() {
        let v = parse("[[[[[[1]]]]]]").unwrap();
        let mut cur = &v;
        for _ in 0..6 {
            cur = &cur.as_arr().unwrap()[0];
        }
        assert_eq!(cur.as_f64().unwrap(), 1.0);
    }

    #[test]
    fn integers_emitted_without_fraction() {
        assert_eq!(num(42.0).to_string(), "42");
        assert_eq!(num(0.5).to_string(), "0.5");
    }
}
