//! Trace-file analysis — the engine behind `hapq trace`.
//!
//! Reads the JSONL written by [`super::finish`] (schema 1: `meta`
//! header + `span`/`count`/`gauge`/`step`/`episode` events), and
//! renders:
//!
//! * a per-episode **reward-curve table** (Fig 5/8 provenance: episode
//!   → summed reward, accuracy loss, energy gain),
//! * a per-phase **rollup** (flamegraph-style: total/mean time and
//!   share per span name),
//! * the **top-N hottest layers** (span time attributed to a
//!   prunable-layer index),
//! * a **Chrome trace-event export** (`--chrome`) loadable by
//!   `chrome://tracing` / Perfetto,
//! * a **canonical form** (`--canon`) with the wall-clock-only
//!   `ts`/`dur` fields stripped — byte-diffable across same-seed runs
//!   (the determinism comparator of `rust/tests/telemetry.rs` and CI).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::io::json::{self, Value};

/// A parsed trace: the event objects of every non-`meta` line, in file
/// order.
pub struct Trace {
    /// non-`meta` event objects, file order
    pub events: Vec<Value>,
}

/// Load and validate a JSONL trace file: line 1 must be a `meta` header
/// carrying a supported `schema`.
pub fn load(path: &Path) -> Result<Trace> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace {path:?}"))?;
    let mut events = Vec::new();
    let mut saw_meta = false;
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = json::parse(line)
            .with_context(|| format!("trace {path:?} line {}", i + 1))?;
        let kind = v.req("kind")?.as_str()?.to_string();
        if kind == "meta" {
            let schema = v.req("schema")?.as_usize()?;
            if schema as u64 != super::SCHEMA {
                bail!(
                    "trace {path:?} has schema {schema}, this build reads schema {}",
                    super::SCHEMA
                );
            }
            saw_meta = true;
        } else {
            events.push(v);
        }
    }
    if !saw_meta {
        bail!("trace {path:?} has no `meta` header line (not a hapq trace?)");
    }
    Ok(Trace { events })
}

fn kind(v: &Value) -> &str {
    v.get("kind").and_then(|k| k.as_str().ok()).unwrap_or("")
}

fn fname(v: &Value) -> &str {
    v.get("name").and_then(|k| k.as_str().ok()).unwrap_or("")
}

impl Trace {
    /// Events of one kind, file order.
    fn of_kind<'a>(&'a self, k: &str) -> impl Iterator<Item = &'a Value> {
        let k = k.to_string();
        self.events.iter().filter(move |v| kind(v) == k)
    }

    /// Per-episode reward-curve table (one row per `episode` event,
    /// with the step count folded in from `step` events).
    pub fn reward_table(&self) -> Result<String> {
        let mut steps_of: BTreeMap<usize, usize> = BTreeMap::new();
        for s in self.of_kind("step") {
            *steps_of.entry(s.req("episode")?.as_usize()?).or_insert(0) += 1;
        }
        let mut out = format!(
            "{:<8} {:>6} {:>10} {:>10} {:>12} {:>8}\n",
            "episode", "steps", "reward", "acc-loss", "energy-gain", "evals"
        );
        let mut rows = 0usize;
        for e in self.of_kind("episode") {
            let ep = e.req("episode")?.as_usize()?;
            out.push_str(&format!(
                "{:<8} {:>6} {:>10.3} {:>9.2}% {:>11.2}% {:>8}\n",
                ep,
                steps_of.get(&ep).copied().unwrap_or(0),
                e.req("reward")?.as_f64()?,
                e.req("acc_loss")?.as_f64()? * 100.0,
                e.req("energy_gain")?.as_f64()? * 100.0,
                e.req("evals")?.as_usize()?,
            ));
            rows += 1;
        }
        if rows == 0 {
            out.push_str("(no episode events — not a search trace?)\n");
        }
        Ok(out)
    }

    /// Per-phase rollup: every span name with call count, total and
    /// mean time, and share of the summed span time — sorted by total,
    /// descending (flamegraph-style, one level deep).
    pub fn phase_rollup(&self) -> Result<String> {
        let mut agg: BTreeMap<String, (u64, f64)> = BTreeMap::new();
        for s in self.of_kind("span") {
            let e = agg.entry(fname(s).to_string()).or_insert((0, 0.0));
            e.0 += 1;
            e.1 += s.req("dur")?.as_f64()?;
        }
        let total: f64 = agg.values().map(|(_, d)| *d).sum();
        let mut rows: Vec<(String, u64, f64)> =
            agg.into_iter().map(|(n, (c, d))| (n, c, d)).collect();
        // stable across runs: equal durations fall back to name order
        rows.sort_by(|a, b| b.2.total_cmp(&a.2).then(a.0.cmp(&b.0)));
        let mut out = format!(
            "{:<16} {:>8} {:>12} {:>12} {:>7}\n",
            "span", "count", "total-ms", "mean-us", "share"
        );
        for (name, count, dur_us) in &rows {
            out.push_str(&format!(
                "{:<16} {:>8} {:>12.3} {:>12.1} {:>6.1}%\n",
                name,
                count,
                dur_us / 1e3,
                dur_us / *count as f64,
                if total > 0.0 { dur_us / total * 100.0 } else { 0.0 },
            ));
        }
        if rows.is_empty() {
            out.push_str("(no span events)\n");
        }
        Ok(out)
    }

    /// The `n` prunable layers holding the most span time (spans
    /// carrying a `layer` field — `env.step` et al.), sorted by total
    /// time, descending.
    pub fn hottest_layers(&self, n: usize) -> Result<String> {
        let mut agg: BTreeMap<usize, (u64, f64)> = BTreeMap::new();
        for s in self.of_kind("span") {
            if let Some(l) = s.get("layer") {
                let e = agg.entry(l.as_usize()?).or_insert((0, 0.0));
                e.0 += 1;
                e.1 += s.req("dur")?.as_f64()?;
            }
        }
        let mut rows: Vec<(usize, u64, f64)> =
            agg.into_iter().map(|(l, (c, d))| (l, c, d)).collect();
        rows.sort_by(|a, b| b.2.total_cmp(&a.2).then(a.0.cmp(&b.0)));
        rows.truncate(n);
        let mut out = format!("{:<6} {:>8} {:>12}\n", "layer", "spans", "total-ms");
        for (layer, count, dur_us) in &rows {
            out.push_str(&format!("{layer:<6} {count:>8} {:>12.3}\n", dur_us / 1e3));
        }
        if rows.is_empty() {
            out.push_str("(no layer-tagged spans)\n");
        }
        Ok(out)
    }

    /// Chrome trace-event JSON (`chrome://tracing` / Perfetto): spans
    /// become complete (`ph:"X"`) events on integer thread ids (with
    /// `thread_name` metadata), `step` events become a `reward` counter
    /// track (`ph:"C"`).
    pub fn chrome(&self) -> Result<Value> {
        // stable tag → tid mapping, in first-appearance order
        let mut tid_of: BTreeMap<String, usize> = BTreeMap::new();
        for v in &self.events {
            if let Some(t) = v.get("thread").and_then(|t| t.as_str().ok()) {
                let next = tid_of.len();
                tid_of.entry(t.to_string()).or_insert(next);
            }
        }
        let mut evs: Vec<Value> = Vec::new();
        for (tag, tid) in &tid_of {
            evs.push(json::obj(vec![
                ("name", json::s("thread_name")),
                ("ph", json::s("M")),
                ("pid", json::num(1.0)),
                ("tid", json::num(*tid as f64)),
                ("args", json::obj(vec![("name", json::s(tag))])),
            ]));
        }
        for v in &self.events {
            let tid = v
                .get("thread")
                .and_then(|t| t.as_str().ok())
                .and_then(|t| tid_of.get(t).copied())
                .unwrap_or(0);
            match kind(v) {
                "span" => {
                    let mut args: Vec<(&str, Value)> = Vec::new();
                    if let Some(l) = v.get("layer") {
                        args.push(("layer", json::num(l.as_f64()?)));
                    }
                    if let Some(s) = v.get("shard") {
                        args.push(("shard", json::num(s.as_f64()?)));
                    }
                    evs.push(json::obj(vec![
                        ("name", json::s(fname(v))),
                        ("ph", json::s("X")),
                        ("ts", json::num(v.req("ts")?.as_f64()?)),
                        ("dur", json::num(v.req("dur")?.as_f64()?)),
                        ("pid", json::num(1.0)),
                        ("tid", json::num(tid as f64)),
                        ("args", json::obj(args)),
                    ]));
                }
                "step" => {
                    evs.push(json::obj(vec![
                        ("name", json::s("reward")),
                        ("ph", json::s("C")),
                        ("ts", json::num(v.req("ts")?.as_f64()?)),
                        ("pid", json::num(1.0)),
                        ("tid", json::num(tid as f64)),
                        (
                            "args",
                            json::obj(vec![("reward", json::num(v.req("reward")?.as_f64()?))]),
                        ),
                    ]));
                }
                _ => {}
            }
        }
        Ok(json::obj(vec![("traceEvents", json::arr(evs))]))
    }

    /// Canonical event stream with the wall-clock-only `ts`/`dur`
    /// fields stripped: one JSON object per line, byte-identical across
    /// same-seed runs at a fixed (threads, kernel) configuration.
    pub fn canonical(&self) -> String {
        let mut out = String::new();
        for v in &self.events {
            let stripped = match v {
                Value::Obj(kv) => Value::Obj(
                    kv.iter()
                        .filter(|(k, _)| k != "ts" && k != "dur")
                        .cloned()
                        .collect(),
                ),
                other => other.clone(),
            };
            out.push_str(&stripped.to_string());
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture() -> Trace {
        let lines = [
            r#"{"kind":"span","name":"env.prune","thread":"main","seq":0,"ts":10.0,"dur":4.5,"layer":0}"#,
            r#"{"kind":"span","name":"env.infer","thread":"main","seq":1,"ts":20.0,"dur":95.5,"layer":0}"#,
            r#"{"kind":"span","name":"env.infer","thread":"main","seq":2,"ts":130.0,"dur":104.5,"layer":1}"#,
            r#"{"kind":"span","name":"exec.shard","thread":"worker00","seq":0,"ts":21.0,"dur":90.0,"shard":0}"#,
            r#"{"kind":"step","thread":"main","seq":3,"ts":120.0,"episode":0,"step":0,"reward":1.5,"acc":0.9,"energy_gain":0.4}"#,
            r#"{"kind":"step","thread":"main","seq":4,"ts":240.0,"episode":0,"step":1,"reward":2.0,"acc":0.88,"energy_gain":0.5}"#,
            r#"{"kind":"episode","thread":"main","seq":5,"ts":250.0,"episode":0,"reward":3.5,"acc_loss":0.02,"energy_gain":0.5,"evals":2}"#,
        ];
        Trace {
            events: lines.iter().map(|l| json::parse(l).unwrap()).collect(),
        }
    }

    #[test]
    fn reward_table_rolls_up_steps_per_episode() {
        let t = fixture().reward_table().unwrap();
        assert!(t.contains("episode"), "{t}");
        // episode 0: 2 steps, reward 3.5, 2 evals
        let row = t.lines().nth(1).unwrap();
        assert!(row.starts_with('0'), "{row}");
        assert!(row.contains("3.500"), "{row}");
        assert!(row.split_whitespace().nth(1) == Some("2"), "{row}");
    }

    #[test]
    fn rollup_sorts_by_total_and_layers_rank() {
        let r = fixture().phase_rollup().unwrap();
        let infer_line = r.lines().position(|l| l.starts_with("env.infer")).unwrap();
        let prune_line = r.lines().position(|l| l.starts_with("env.prune")).unwrap();
        assert!(infer_line < prune_line, "biggest total first:\n{r}");
        let h = fixture().hottest_layers(1).unwrap();
        // layer 1 (104.5us) beats layer 0 (100us total), top-1 keeps it
        assert!(h.lines().nth(1).unwrap().starts_with('1'), "{h}");
        assert!(!h.contains("\n0 "), "{h}");
    }

    #[test]
    fn chrome_export_is_valid_and_complete() {
        let c = fixture().chrome().unwrap();
        let back = json::parse(&c.to_string()).unwrap();
        let evs = back.req("traceEvents").unwrap().as_arr().unwrap();
        // 2 thread_name metadata + 4 spans + 2 counters
        assert_eq!(evs.len(), 8);
        let complete: Vec<&str> = evs
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str().ok()) == Some("X"))
            .map(|e| e.req("name").unwrap().as_str().unwrap())
            .collect();
        assert!(complete.contains(&"env.prune"));
        assert!(complete.contains(&"env.infer"));
        assert!(complete.contains(&"exec.shard"));
    }

    #[test]
    fn canonical_strips_exactly_the_clock_fields() {
        let c = fixture().canonical();
        assert!(!c.contains("\"ts\""), "{c}");
        assert!(!c.contains("\"dur\""), "{c}");
        // everything else survives
        assert!(c.contains("\"reward\":1.5"), "{c}");
        assert!(c.contains("\"shard\":0"), "{c}");
        assert_eq!(c.lines().count(), 7);
    }
}
