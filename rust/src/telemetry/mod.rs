//! Unified telemetry: structured trace events + a metrics registry —
//! the observation seam under `hapq serve` (ROADMAP).
//!
//! Two complementary views of one run live here:
//!
//! * **Trace events** — a process-global [`TraceSink`]-style facade
//!   ([`init`] / [`span`] / [`count`] / [`step_event`] / [`finish`])
//!   buffering span/counter/gauge/step/episode events per thread and
//!   draining them to a JSONL file at exit (`--trace PATH`, or the
//!   `HAPQ_TRACE` environment variable). The schema is versioned
//!   ([`SCHEMA`], currently 1): line 1 is a `meta` header, every other
//!   line is one event object with a `kind` of `span`, `count`,
//!   `gauge`, `step` or `episode`. **Wall-clock readings appear only in
//!   the `ts`/`dur` fields** (microseconds since the sink epoch), so a
//!   comparator that strips exactly those two keys sees a fully
//!   deterministic event sequence for a fixed seed
//!   (`rust/tests/telemetry.rs` pins this). `hapq trace` renders the
//!   file ([`analyze`]); `--chrome` exports it for `chrome://tracing`.
//! * **Metrics** — a [`MetricsRegistry`] snapshotting named counters,
//!   gauges and histograms (p50/p95/max via [`crate::util::percentile`])
//!   from [`MetricsSource`]s: today's `PhaseTimers`, `RuntimeStats` and
//!   `CostCache` register themselves instead of growing more parallel
//!   stat structs. [`metrics_snapshot`] is the JSON call `hapq perf
//!   --json` / `hapq hw --json` print and a future `hapq serve` will
//!   wire to an endpoint.
//!
//! **Observation-only, by hard constraint**: a disabled sink costs one
//! relaxed atomic load per call site — no clock reads, no allocation,
//! no locks — and an enabled one never draws RNG, never reorders float
//! accumulation, and never touches run results. The golden test pins
//! that searching with tracing on is bit-identical to tracing off.
//!
//! Thread model: every thread buffers its events in thread-local
//! storage under a tag (`main`, or `workerNN` set by the exec pool);
//! [`flush_thread`] moves the buffer into the global sink (pool workers
//! flush before answering each job, so the main thread always drains a
//! complete set). [`finish`] serialises buffers grouped by tag in
//! lexicographic order, each thread's events in emission order with a
//! per-thread `seq`. The layout is deterministic whenever shard→worker
//! assignment is — under `--sched static` at any thread count, or
//! under `--sched steal` single-threaded. Multi-thread stealing claims
//! shards in a timing-dependent order by design, so there the trace
//! faithfully records whichever worker ran each shard (run results
//! stay bit-identical regardless; `rust/tests/telemetry.rs`).

pub mod analyze;

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::io::json::{self, Value};

/// Trace-file schema version (the `meta` header's `schema` field).
pub const SCHEMA: u64 = 1;

/// One buffered telemetry event. Serialised as a single JSONL object
/// with `kind`/`thread`/`seq` envelope fields added at drain time.
/// Wall-clock readings live only in the `ts`/`dur` fields.
#[derive(Clone, Debug)]
pub enum TraceEvent {
    /// a completed timed region (`ts`/`dur` in µs since the sink epoch)
    Span {
        /// region name (`env.prune`, `exec.shard`, …)
        name: &'static str,
        /// start, µs since the sink epoch
        ts_us: f64,
        /// duration, µs
        dur_us: f64,
        /// prunable-layer index the region worked on, when meaningful
        layer: Option<usize>,
        /// evaluation-shard index, when meaningful
        shard: Option<usize>,
    },
    /// a monotonic counter increment
    Count {
        /// counter name (`hw.cache.reused`, …)
        name: &'static str,
        /// increment amount
        n: u64,
    },
    /// an instantaneous sampled value
    Gauge {
        /// gauge name
        name: &'static str,
        /// sampled value
        value: f64,
    },
    /// one search step (emitted by the `SearchDriver` per `env.step`)
    Step {
        /// episode index
        episode: usize,
        /// step (= layer) index within the episode
        step: usize,
        /// µs since the sink epoch at emission
        ts_us: f64,
        /// LUT reward of the step
        reward: f64,
        /// reward-subset accuracy after the step
        accuracy: f64,
        /// energy gain vs the dense baseline after the step
        energy_gain: f64,
    },
    /// one finished episode (emitted by the `SearchDriver`)
    Episode {
        /// episode index
        episode: usize,
        /// µs since the sink epoch at emission
        ts_us: f64,
        /// summed step reward of the episode
        reward: f64,
        /// final accuracy loss of the episode's configuration
        acc_loss: f64,
        /// final energy gain of the episode's configuration
        energy_gain: f64,
        /// cumulative reward-oracle evaluations after the episode
        evals: u64,
    },
}

impl TraceEvent {
    /// Serialise with the envelope fields (`kind`, `thread`, `seq`).
    fn to_json(&self, thread: &str, seq: usize) -> Value {
        let mut kv: Vec<(&str, Value)> = Vec::with_capacity(10);
        match self {
            TraceEvent::Span { name, ts_us, dur_us, layer, shard } => {
                kv.push(("kind", json::s("span")));
                kv.push(("name", json::s(name)));
                kv.push(("thread", json::s(thread)));
                kv.push(("seq", json::num(seq as f64)));
                kv.push(("ts", json::num(*ts_us)));
                kv.push(("dur", json::num(*dur_us)));
                if let Some(l) = layer {
                    kv.push(("layer", json::num(*l as f64)));
                }
                if let Some(s) = shard {
                    kv.push(("shard", json::num(*s as f64)));
                }
            }
            TraceEvent::Count { name, n } => {
                kv.push(("kind", json::s("count")));
                kv.push(("name", json::s(name)));
                kv.push(("thread", json::s(thread)));
                kv.push(("seq", json::num(seq as f64)));
                kv.push(("n", json::num(*n as f64)));
            }
            TraceEvent::Gauge { name, value } => {
                kv.push(("kind", json::s("gauge")));
                kv.push(("name", json::s(name)));
                kv.push(("thread", json::s(thread)));
                kv.push(("seq", json::num(seq as f64)));
                kv.push(("value", json::num(*value)));
            }
            TraceEvent::Step { episode, step, ts_us, reward, accuracy, energy_gain } => {
                kv.push(("kind", json::s("step")));
                kv.push(("thread", json::s(thread)));
                kv.push(("seq", json::num(seq as f64)));
                kv.push(("ts", json::num(*ts_us)));
                kv.push(("episode", json::num(*episode as f64)));
                kv.push(("step", json::num(*step as f64)));
                kv.push(("reward", json::num(*reward)));
                kv.push(("acc", json::num(*accuracy)));
                kv.push(("energy_gain", json::num(*energy_gain)));
            }
            TraceEvent::Episode { episode, ts_us, reward, acc_loss, energy_gain, evals } => {
                kv.push(("kind", json::s("episode")));
                kv.push(("thread", json::s(thread)));
                kv.push(("seq", json::num(seq as f64)));
                kv.push(("ts", json::num(*ts_us)));
                kv.push(("episode", json::num(*episode as f64)));
                kv.push(("reward", json::num(*reward)));
                kv.push(("acc_loss", json::num(*acc_loss)));
                kv.push(("energy_gain", json::num(*energy_gain)));
                kv.push(("evals", json::num(*evals as f64)));
            }
        }
        json::obj(kv)
    }
}

/// The one-branch fast path: false = every telemetry call is a no-op.
static ENABLED: AtomicBool = AtomicBool::new(false);
/// Monotonic reference point every `ts` is relative to (set at first
/// [`init`]; any fixed point works — `ts` is wall-clock-only anyway).
static EPOCH: OnceLock<Instant> = OnceLock::new();
/// Flushed per-thread buffers, keyed by thread tag.
static SINK: Mutex<Option<SinkState>> = Mutex::new(None);

struct SinkState {
    path: PathBuf,
    buffers: BTreeMap<String, Vec<TraceEvent>>,
}

thread_local! {
    /// (thread tag, locally buffered events) — no lock on the hot path.
    static LOCAL: RefCell<(String, Vec<TraceEvent>)> =
        RefCell::new((String::from("main"), Vec::new()));
}

/// Enable the global trace sink, draining to `path` (JSONL) at
/// [`finish`]. Call once near process start (`--trace` / `HAPQ_TRACE`).
pub fn init(path: &Path) {
    EPOCH.get_or_init(Instant::now);
    let mut g = SINK.lock().unwrap_or_else(|e| e.into_inner());
    *g = Some(SinkState { path: path.to_path_buf(), buffers: BTreeMap::new() });
    ENABLED.store(true, Ordering::Release);
}

/// Is the sink collecting? One relaxed atomic load — cheap enough for
/// every call site to check (and every emitting call checks itself).
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Tag this thread's buffered events (`main` by default; the exec pool
/// tags its workers `workerNN`). Cheap; safe to call when disabled.
pub fn set_thread_tag(tag: &str) {
    LOCAL.with(|l| l.borrow_mut().0 = tag.to_string());
}

fn push(ev: TraceEvent) {
    LOCAL.with(|l| l.borrow_mut().1.push(ev));
}

fn micros_since_epoch(t: Instant) -> f64 {
    let e = EPOCH.get().copied().unwrap_or(t);
    t.saturating_duration_since(e).as_secs_f64() * 1e6
}

/// RAII span guard: times from construction to drop. When the sink is
/// disabled the guard holds no clock reading and drop is a no-op.
pub struct SpanGuard {
    name: &'static str,
    start: Option<Instant>,
    layer: Option<usize>,
    shard: Option<usize>,
}

impl SpanGuard {
    /// Attach a prunable-layer index to the span.
    pub fn layer(mut self, l: usize) -> SpanGuard {
        self.layer = Some(l);
        self
    }

    /// Attach an evaluation-shard index to the span.
    pub fn shard(mut self, s: usize) -> SpanGuard {
        self.shard = Some(s);
        self
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(start) = self.start {
            let dur = start.elapsed().as_secs_f64();
            push(TraceEvent::Span {
                name: self.name,
                ts_us: micros_since_epoch(start),
                dur_us: dur * 1e6,
                layer: self.layer,
                shard: self.shard,
            });
        }
    }
}

/// Open a named span ending (and recording) when the guard drops.
#[must_use = "the span ends when the guard drops"]
pub fn span(name: &'static str) -> SpanGuard {
    let start = if enabled() { Some(Instant::now()) } else { None };
    SpanGuard { name, start, layer: None, shard: None }
}

/// Record a span retrospectively from an already-taken `Instant` and an
/// already-measured duration — lets instrumented code reuse the clock
/// readings it takes anyway (zero extra `Instant::now` calls).
pub fn span_at(name: &'static str, start: Instant, dur_s: f64, layer: Option<usize>) {
    if enabled() {
        push(TraceEvent::Span {
            name,
            ts_us: micros_since_epoch(start),
            dur_us: dur_s * 1e6,
            layer,
            shard: None,
        });
    }
}

/// Record a counter increment (skipped when `n == 0`).
pub fn count(name: &'static str, n: u64) {
    if enabled() && n > 0 {
        push(TraceEvent::Count { name, n });
    }
}

/// Record a gauge sample.
pub fn gauge(name: &'static str, value: f64) {
    if enabled() {
        push(TraceEvent::Gauge { name, value });
    }
}

/// Record one search step (reward / accuracy / energy gain).
pub fn step_event(episode: usize, step: usize, reward: f64, accuracy: f64, energy_gain: f64) {
    if enabled() {
        push(TraceEvent::Step {
            episode,
            step,
            ts_us: micros_since_epoch(Instant::now()),
            reward,
            accuracy,
            energy_gain,
        });
    }
}

/// Record one finished episode's summary.
pub fn episode_event(episode: usize, reward: f64, acc_loss: f64, energy_gain: f64, evals: u64) {
    if enabled() {
        push(TraceEvent::Episode {
            episode,
            ts_us: micros_since_epoch(Instant::now()),
            reward,
            acc_loss,
            energy_gain,
            evals,
        });
    }
}

/// Move this thread's buffered events into the global sink. Pool
/// workers call this before answering each job; the main thread is
/// flushed by [`finish`].
pub fn flush_thread() {
    if !enabled() {
        return;
    }
    let (tag, events) = LOCAL.with(|l| {
        let mut l = l.borrow_mut();
        let tag = l.0.clone();
        (tag, std::mem::take(&mut l.1))
    });
    if events.is_empty() {
        return;
    }
    let mut g = SINK.lock().unwrap_or_else(|e| e.into_inner());
    if let Some(state) = g.as_mut() {
        state.buffers.entry(tag).or_default().extend(events);
    }
}

/// Drain every buffered event to the configured JSONL file and disable
/// the sink. Returns the written path, or `None` when the sink was
/// never enabled. Layout: one `meta` header line, then every thread's
/// events grouped by tag (lexicographic) in emission order.
pub fn finish() -> Result<Option<PathBuf>> {
    if !enabled() {
        return Ok(None);
    }
    flush_thread();
    ENABLED.store(false, Ordering::Release);
    let state = SINK.lock().unwrap_or_else(|e| e.into_inner()).take();
    let Some(state) = state else {
        return Ok(None);
    };
    let mut out = String::new();
    out.push_str(
        &json::obj(vec![
            ("kind", json::s("meta")),
            ("schema", json::num(SCHEMA as f64)),
            ("source", json::s("hapq")),
        ])
        .to_string(),
    );
    out.push('\n');
    for (tag, events) in &state.buffers {
        for (seq, ev) in events.iter().enumerate() {
            out.push_str(&ev.to_json(tag, seq).to_string());
            out.push('\n');
        }
    }
    if let Some(dir) = state.path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating trace dir {dir:?}"))?;
        }
    }
    std::fs::write(&state.path, out)
        .with_context(|| format!("writing trace {:?}", state.path))?;
    Ok(Some(state.path))
}

/// A component that can report its current metrics into a registry —
/// implemented by `PhaseTimers`, `RuntimeStats` and `CostCache` so
/// `hapq perf --json` / the future `hapq serve` read one schema instead
/// of three parallel stat structs.
pub trait MetricsSource {
    /// Write this source's counters/gauges/histograms into `reg`.
    fn record(&self, reg: &mut MetricsRegistry);
}

/// Named counters, gauges and histograms with a JSON snapshot
/// (`schema:1`) — the metrics half of the telemetry seam.
#[derive(Clone, Debug, Default)]
pub struct MetricsRegistry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Vec<f64>>,
    labels: BTreeMap<String, String>,
}

impl MetricsRegistry {
    /// Empty registry.
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Add `n` to a named counter (created at 0).
    pub fn counter(&mut self, name: &str, n: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += n;
    }

    /// Set a named gauge to its latest value.
    pub fn gauge(&mut self, name: &str, value: f64) {
        self.gauges.insert(name.to_string(), value);
    }

    /// Append one observation to a named histogram.
    pub fn observe(&mut self, name: &str, value: f64) {
        self.histograms.entry(name.to_string()).or_default().push(value);
    }

    /// Set a named string label (kernel name, target name, …).
    pub fn label(&mut self, name: &str, value: &str) {
        self.labels.insert(name.to_string(), value.to_string());
    }

    /// Let a [`MetricsSource`] record itself.
    pub fn collect(&mut self, source: &dyn MetricsSource) {
        source.record(self);
    }

    /// JSON snapshot: `{schema, counters, gauges, histograms, labels}`;
    /// each histogram summarises as `{count, p50, p95, max}` via
    /// [`crate::util::percentile`].
    pub fn snapshot(&self) -> Value {
        let counters: Vec<(String, Value)> = self
            .counters
            .iter()
            .map(|(k, v)| (k.clone(), json::num(*v as f64)))
            .collect();
        let gauges: Vec<(String, Value)> =
            self.gauges.iter().map(|(k, v)| (k.clone(), json::num(*v))).collect();
        let histograms: Vec<(String, Value)> = self
            .histograms
            .iter()
            .map(|(k, xs)| {
                (
                    k.clone(),
                    json::obj(vec![
                        ("count", json::num(xs.len() as f64)),
                        ("p50", json::num(crate::util::percentile(xs, 50.0))),
                        ("p95", json::num(crate::util::percentile(xs, 95.0))),
                        ("max", json::num(xs.iter().cloned().fold(f64::NAN, f64::max))),
                    ]),
                )
            })
            .collect();
        let labels: Vec<(String, Value)> =
            self.labels.iter().map(|(k, v)| (k.clone(), json::s(v))).collect();
        json::obj(vec![
            ("schema", json::num(SCHEMA as f64)),
            ("counters", Value::Obj(counters)),
            ("gauges", Value::Obj(gauges)),
            ("histograms", Value::Obj(histograms)),
            ("labels", Value::Obj(labels)),
        ])
    }
}

/// One-shot snapshot over a set of sources — the `metrics_snapshot()`
/// call `hapq perf --json` prints and `hapq serve` will expose.
pub fn metrics_snapshot(sources: &[&dyn MetricsSource]) -> Value {
    let mut reg = MetricsRegistry::new();
    for s in sources {
        reg.collect(*s);
    }
    reg.snapshot()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The sink is process-global; tests touching it must not overlap.
    static GUARD: Mutex<()> = Mutex::new(());

    #[test]
    fn disabled_sink_is_inert() {
        let _g = GUARD.lock().unwrap_or_else(|e| e.into_inner());
        assert!(!enabled());
        // all no-ops: nothing panics, nothing is buffered
        let sp = span("noop");
        assert!(sp.start.is_none());
        drop(sp);
        count("noop", 3);
        gauge("noop", 1.0);
        step_event(0, 0, 1.0, 0.9, 0.5);
        assert!(finish().unwrap().is_none());
        LOCAL.with(|l| assert!(l.borrow().1.is_empty()));
    }

    #[test]
    fn init_buffer_finish_roundtrip() {
        let _g = GUARD.lock().unwrap_or_else(|e| e.into_inner());
        let dir = std::env::temp_dir().join("hapq-telemetry-test");
        let path = dir.join("t.jsonl");
        init(&path);
        assert!(enabled());
        {
            let _sp = span("unit.work").layer(2).shard(1);
        }
        count("unit.count", 2);
        count("unit.count", 0); // zero increments are skipped
        gauge("unit.gauge", 0.25);
        step_event(0, 1, 3.5, 0.875, 0.5);
        episode_event(0, 3.5, 0.125, 0.5, 7);
        let written = finish().unwrap().expect("sink was enabled");
        assert_eq!(written, path);
        assert!(!enabled());
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 6, "meta + 5 events: {text}");
        let meta = json::parse(lines[0]).unwrap();
        assert_eq!(meta.req("kind").unwrap().as_str().unwrap(), "meta");
        assert_eq!(meta.req("schema").unwrap().as_usize().unwrap(), 1);
        let sp = json::parse(lines[1]).unwrap();
        assert_eq!(sp.req("kind").unwrap().as_str().unwrap(), "span");
        assert_eq!(sp.req("name").unwrap().as_str().unwrap(), "unit.work");
        assert_eq!(sp.req("thread").unwrap().as_str().unwrap(), "main");
        assert_eq!(sp.req("layer").unwrap().as_usize().unwrap(), 2);
        assert_eq!(sp.req("shard").unwrap().as_usize().unwrap(), 1);
        assert!(sp.req("dur").unwrap().as_f64().unwrap() >= 0.0);
        let ct = json::parse(lines[2]).unwrap();
        assert_eq!(ct.req("n").unwrap().as_usize().unwrap(), 2);
        let ep = json::parse(lines[5]).unwrap();
        assert_eq!(ep.req("kind").unwrap().as_str().unwrap(), "episode");
        assert_eq!(ep.req("evals").unwrap().as_usize().unwrap(), 7);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn registry_snapshot_schema() {
        let mut reg = MetricsRegistry::new();
        reg.counter("a.count", 2);
        reg.counter("a.count", 3);
        reg.gauge("a.gauge", 0.5);
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            reg.observe("a.hist", x);
        }
        reg.label("a.label", "int");
        let snap = reg.snapshot();
        // the snapshot must survive its own serialisation (the `--json`
        // path prints exactly this string)
        let back = json::parse(&snap.to_string()).unwrap();
        assert_eq!(back.req("schema").unwrap().as_usize().unwrap(), 1);
        assert_eq!(
            back.req("counters").unwrap().req("a.count").unwrap().as_usize().unwrap(),
            5
        );
        let h = back.req("histograms").unwrap().req("a.hist").unwrap();
        assert_eq!(h.req("count").unwrap().as_usize().unwrap(), 5);
        assert_eq!(h.req("p50").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(h.req("max").unwrap().as_f64().unwrap(), 5.0);
        assert_eq!(
            back.req("labels").unwrap().req("a.label").unwrap().as_str().unwrap(),
            "int"
        );
    }
}
