//! # HAPQ — Hardware-Aware DNN Compression via Diverse Pruning and
//! Mixed-Precision Quantization
//!
//! Rust (L3) side of the three-layer reproduction of Balaskas et al.,
//! IEEE TETC 2023 (DOI 10.1109/TETC.2023.3346944). This crate owns the
//! *entire request path*: the composite RL agent (DDPG + Rainbow), the
//! seven pruning algorithms of Table 2, per-channel post-training
//! quantization, the Eyeriss-style energy model (gate-level MAC
//! switching simulator + dataflow mapper), the LUT-based hardware-aware
//! reward, all five comparison baselines and the coordinator/CLI.
//! Every method — ours and the baselines — runs through one unified
//! [`search::SearchDriver`] loop (checkpointable, resumable,
//! multi-seed; see [`search`]).
//!
//! The accuracy term of the reward is answered by a pluggable
//! [`runtime::InferenceBackend`]:
//!
//! * the default [`runtime::NativeBackend`] interprets the exported
//!   model graph in pure Rust — no FFI, works everywhere;
//! * with `--features pjrt`, the AOT-exported HLO (produced by the
//!   JAX/Pallas L2/L1 layers at `make artifacts` time: HLO text +
//!   weights + arch descriptors) executes through the XLA PJRT C API.
//!
//! Either way Python is never on the hot path. See
//! `docs/ARCHITECTURE.md` (repository root) for the module map, the
//! Fig 3 step loop, and where the backend seam sits.

#![warn(missing_docs)]

pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod env;
pub mod hw;
pub mod io;
pub mod model;
pub mod nn;
pub mod pruning;
pub mod quant;
pub mod rl;
pub mod runtime;
pub mod search;
pub mod telemetry;
pub mod tensor;
pub mod util;
