//! # HAPQ — Hardware-Aware DNN Compression via Diverse Pruning and
//! Mixed-Precision Quantization
//!
//! Rust (L3) side of the three-layer reproduction of Balaskas et al.,
//! IEEE TETC 2023 (DOI 10.1109/TETC.2023.3346944). This crate owns the
//! *entire request path*: the composite RL agent (DDPG + Rainbow), the
//! seven pruning algorithms of Table 2, per-channel post-training
//! quantization, the Eyeriss-style energy model (gate-level MAC
//! switching simulator + dataflow mapper), the LUT-based hardware-aware
//! reward, all five comparison baselines and the coordinator/CLI.
//!
//! The JAX/Pallas layers (L2/L1) run only at build time (`make
//! artifacts`); their output — HLO text + weights + arch descriptors —
//! is loaded by [`runtime`] through the PJRT C API and executed for the
//! accuracy term of the reward at every RL step. Python is never on
//! this path.

pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod env;
pub mod hw;
pub mod io;
pub mod model;
pub mod nn;
pub mod pruning;
pub mod quant;
pub mod rl;
pub mod runtime;
pub mod tensor;
pub mod util;
