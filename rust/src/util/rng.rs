//! Deterministic RNG substrate (no `rand` crate in the vendor set).
//!
//! xoshiro256** seeded via SplitMix64 — the same generator family JAX
//! and NumPy use for reproducible experiment pipelines. Everything in
//! HAPQ that samples (exploration noise, replay, pruning, NSGA-II,
//! MAC-sim operand streams) goes through this type, so runs are
//! bit-reproducible given a seed.

/// xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box-Muller sample
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed via SplitMix64 (any u64 gives a well-mixed state).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent stream (for per-worker/per-layer rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    /// Truncated normal on [lo, hi] (paper §4.2.1 exploration noise) —
    /// rejection sampling with a clamp fallback for extreme bounds.
    pub fn trunc_normal(&mut self, mean: f64, std: f64, lo: f64, hi: f64) -> f64 {
        for _ in 0..64 {
            let x = mean + std * self.normal();
            if x >= lo && x <= hi {
                return x;
            }
        }
        (mean + std * self.normal()).clamp(lo, hi)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k.min(n));
        idx
    }

    /// Serialise the full generator state (4 xoshiro lanes + the cached
    /// Box-Muller spare) — required for bit-exact search resume.
    pub fn save_state(&self, w: &mut crate::io::bin::BinWriter) {
        for &lane in &self.s {
            w.u64(lane);
        }
        match self.spare {
            Some(z) => {
                w.bool(true);
                w.f64(z);
            }
            None => w.bool(false),
        }
    }

    /// Restore a state written by [`Self::save_state`]; the generator
    /// continues the exact sample stream of the saved one.
    pub fn load_state(&mut self, r: &mut crate::io::bin::BinReader) -> anyhow::Result<()> {
        for lane in self.s.iter_mut() {
            *lane = r.u64()?;
        }
        self.spare = if r.bool()? { Some(r.f64()?) } else { None };
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn trunc_normal_bounds() {
        let mut r = Rng::new(4);
        for _ in 0..5_000 {
            let x = r.trunc_normal(0.5, 0.6, 0.0, 1.0);
            assert!((0.0..=1.0).contains(&x));
        }
    }

    #[test]
    fn state_roundtrip_continues_stream() {
        let mut a = Rng::new(42);
        // advance with a mix of draws so `spare` is populated
        for _ in 0..7 {
            a.normal();
            a.next_u64();
        }
        let mut w = crate::io::bin::BinWriter::new();
        a.save_state(&mut w);
        let mut b = Rng::new(0);
        let mut r = crate::io::bin::BinReader::new(&w.buf);
        b.load_state(&mut r).unwrap();
        for _ in 0..100 {
            assert_eq!(a.normal().to_bits(), b.normal().to_bits());
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn choose_k_distinct() {
        let mut r = Rng::new(5);
        let ks = r.choose_k(10, 5);
        let mut s = ks.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 5);
    }
}
