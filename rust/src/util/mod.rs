//! Shared substrates: deterministic RNG, property-test harness, misc.

pub mod proptest;
pub mod rng;

/// Simple percentile on a copy (used by benches/metrics).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((v.len() - 1) as f64 * p / 100.0).round() as usize;
    v[idx]
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_basics() {
        let v = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 5.0);
        assert_eq!(percentile(&v, 50.0), 3.0);
    }

    #[test]
    fn mean_basics() {
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert!(mean(&[]).is_nan());
    }
}
