//! Shared substrates: deterministic RNG, property-test harness, misc.

pub mod proptest;
pub mod rng;

/// Simple percentile on a copy (used by benches/metrics).
///
/// Samples are ordered with `f64::total_cmp` — the IEEE total order —
/// so NaN samples (e.g. a hit-rate gauge observed with zero lookups)
/// can never panic the sort. NaN-present semantics: positive NaN sorts
/// after every finite value, so mid-range percentiles of mostly-finite
/// data stay finite, while a percentile whose rank lands on a NaN slot
/// returns NaN (and an all-NaN input returns NaN at every rank).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let idx = ((v.len() - 1) as f64 * p / 100.0).round() as usize;
    v[idx]
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_basics() {
        let v = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 5.0);
        assert_eq!(percentile(&v, 50.0), 3.0);
    }

    #[test]
    fn percentile_tolerates_nan_samples() {
        // regression: the old unwrapped-partial_cmp sort panicked on
        // any NaN sample; total_cmp sorts NaN after the finite values
        let v = [1.0, f64::NAN, 2.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 50.0), 2.0);
        assert!(percentile(&v, 100.0).is_nan());
        assert!(percentile(&[f64::NAN], 50.0).is_nan());
    }

    #[test]
    fn mean_basics() {
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert!(mean(&[]).is_nan());
    }
}
