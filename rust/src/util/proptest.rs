//! Hand-rolled property-test harness (the `proptest` crate is not in
//! the vendored registry — DESIGN.md §1). Provides seeded generators
//! and a `forall` runner with failure reporting including the seed, so
//! a failing property is reproducible with `Rng::new(seed)`.

use super::rng::Rng;

/// Number of cases per property (kept moderate: single-core CI box).
pub const CASES: usize = 64;

/// Run `prop` on `CASES` generated inputs; panic with the failing seed.
pub fn forall<T, G, P>(name: &str, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> bool,
{
    for case in 0..CASES {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!("property `{name}` failed on case {case} (seed {seed:#x}): {input:?}");
        }
    }
}

/// Generate a random weight-like vector with mixed magnitudes & signs.
pub fn gen_weights(rng: &mut Rng, max_len: usize) -> Vec<f32> {
    let n = 1 + rng.below(max_len);
    (0..n)
        .map(|_| {
            let scale = 10f64.powf(rng.range(-3.0, 0.5));
            (rng.normal() * scale) as f32
        })
        .collect()
}

/// Generate a sparsity target in [0, 1).
pub fn gen_sparsity(rng: &mut Rng) -> f32 {
    rng.range(0.0, 0.95) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial() {
        forall("x*x >= 0", |r| r.normal(), |x| x * x >= 0.0);
    }

    #[test]
    #[should_panic(expected = "property `always false`")]
    fn forall_reports_failure() {
        forall("always false", |r| r.uniform(), |_| false);
    }

    #[test]
    fn generators_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..100 {
            let w = gen_weights(&mut r, 64);
            assert!(!w.is_empty() && w.len() <= 64);
            let s = gen_sparsity(&mut r);
            assert!((0.0..0.95).contains(&s));
        }
    }
}
