//! Gate-level-ish switching-activity simulator for an 8-bit MAC unit.
//!
//! The paper derives its computational-energy reduction ratio R_Q (eq. 6)
//! and the fine-pruning penalty P_FG (§4.3, value 0.2) from Synopsys
//! gate-level power simulation of an 8-bit multiplier + 32-bit
//! accumulator mapped to ASAP7. Neither the toolchain nor the netlist is
//! available here (repro band 0), so we rebuild the *measurement*: an
//! array multiplier (AND partial products + carry-propagate reduction)
//! and a 32-bit accumulator are simulated bit-exactly on operand streams
//! drawn from quantized-network value distributions, and dynamic power
//! is taken proportional to weighted node toggle counts plus a static
//! leakage floor. Only the *ratio* to the 8/8 baseline is consumed by
//! the energy model — the same normalisation the ASIC flow used.
//!
//! The table models fixed parallel multipliers, i.e. `mac-sim` scaling
//! targets ([`crate::hw::target::ComputeScaling::MacSim`]); bit-serial
//! targets bypass it with an analytic bit-width-product law
//! ([`crate::hw::energy::EnergyModel::rq_pair`]).

use crate::util::rng::Rng;

/// Toggle-count weights (relative node capacitance) + leakage floor.
const W_PP: f64 = 1.0; // partial-product AND plane
const W_SUM: f64 = 2.0; // multiplier reduction/carry nodes
const W_ACC: f64 = 1.5; // 32-bit accumulator register + adder
const LEAKAGE: f64 = 14.0; // static energy per cycle (fraction of a toggle)

/// Simulated state of the MAC datapath for one cycle.
#[derive(Clone, Copy, Default)]
struct MacState {
    pp: u64,     // 8x8 partial-product plane, bit (i*8+j)
    prod: u32,   // 16-bit product
    acc: u32,    // 32-bit accumulator
}

fn mac_cycle(a: u8, b: u8, acc_prev: u32) -> MacState {
    let mut pp = 0u64;
    for i in 0..8 {
        for j in 0..8 {
            if (a >> i) & 1 == 1 && (b >> j) & 1 == 1 {
                pp |= 1 << (i * 8 + j);
            }
        }
    }
    let prod = (a as u32) * (b as u32);
    MacState { pp, prod, acc: acc_prev.wrapping_add(prod) }
}

fn toggles(prev: &MacState, cur: &MacState) -> f64 {
    let t_pp = (prev.pp ^ cur.pp).count_ones() as f64;
    let t_prod = (prev.prod ^ cur.prod).count_ones() as f64;
    let t_acc = (prev.acc ^ cur.acc).count_ones() as f64;
    W_PP * t_pp + W_SUM * t_prod + W_ACC * t_acc
}

/// Draw a `bits`-precision operand code: Laplace-distributed magnitude
/// quantized to [0, 2^bits - 1] (activations/weights of real quantized
/// networks are heavily zero-biased — this is what makes low precision
/// cheap in practice).
fn sample_code(rng: &mut Rng, bits: u32) -> u8 {
    let max = (1u32 << bits) - 1;
    // |Laplace(0, 0.25·max)| truncated
    let u: f64 = rng.uniform() - 0.5;
    let mag = -(0.25 * max as f64) * (1.0 - 2.0 * u.abs()).ln() * u.signum();
    mag.abs().min(max as f64).round() as u8
}

/// Average per-cycle energy (arbitrary units) of the MAC on a stream of
/// (wa `wbits`, act `abits`) operands. `zero_act` forces the activation
/// operand to 0 — the fine-pruned-weight case of §4.3.
pub fn mac_power(wbits: u32, abits: u32, zero_act: bool, n: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed ^ ((wbits as u64) << 8) ^ abits as u64);
    let mut prev = MacState::default();
    let mut total = 0.0;
    for _ in 0..n {
        let w = sample_code(&mut rng, wbits);
        let a = if zero_act { 0 } else { sample_code(&mut rng, abits) };
        let cur = mac_cycle(w, a, prev.acc);
        total += toggles(&prev, &cur) + LEAKAGE;
        prev = cur;
    }
    total / n as f64
}

/// Precomputed R_Q table (eq. 6) + fine-pruning penalty P_FG (§4.3).
#[derive(Clone, Debug)]
pub struct RqTable {
    /// rq[w-2][a-2] = P(w,a) / P(8,8), bits 2..=8
    pub rq: [[f64; 7]; 7],
    /// energy of a MAC with a zeroed operand, relative to 8/8 (paper: 0.2)
    pub p_fg: f64,
}

impl RqTable {
    /// Simulate the MAC on `samples` operand pairs per precision pair.
    pub fn compute(samples: usize, seed: u64) -> Self {
        let base = mac_power(8, 8, false, samples, seed);
        let mut rq = [[0.0; 7]; 7];
        for w in 2..=8u32 {
            for a in 2..=8u32 {
                rq[(w - 2) as usize][(a - 2) as usize] =
                    mac_power(w, a, false, samples, seed) / base;
            }
        }
        let p_fg = mac_power(8, 8, true, samples, seed) / base;
        RqTable { rq, p_fg }
    }

    /// R_Q for a (weights, activations) precision pair; bits clamped to [2,8].
    pub fn rq(&self, wbits: u32, abits: u32) -> f64 {
        let w = wbits.clamp(2, 8) as usize - 2;
        let a = abits.clamp(2, 8) as usize - 2;
        self.rq[w][a]
    }
}

impl Default for RqTable {
    fn default() -> Self {
        Self::compute(4000, 0xEC0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_is_one() {
        let t = RqTable::compute(1500, 1);
        assert!((t.rq(8, 8) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn monotone_in_precision() {
        let t = RqTable::compute(3000, 2);
        // fewer bits on either operand must not increase power
        for b in 2..8u32 {
            assert!(
                t.rq(b, 8) <= t.rq(b + 1, 8) + 0.02,
                "w{b} {} vs w{} {}",
                t.rq(b, 8),
                b + 1,
                t.rq(b + 1, 8)
            );
            assert!(t.rq(8, b) <= t.rq(8, b + 1) + 0.02);
        }
        // and strictly cheaper end-to-end
        assert!(t.rq(2, 2) < 0.75 * t.rq(8, 8));
    }

    #[test]
    fn zero_operand_penalty_small_but_nonzero() {
        // §4.3: multiplying by zero still burns accumulator/static energy;
        // the paper's gate-level flow measured ~0.2 of a full MAC.
        let t = RqTable::compute(3000, 3);
        assert!(t.p_fg > 0.02, "p_fg {}", t.p_fg);
        assert!(t.p_fg < 0.5, "p_fg {}", t.p_fg);
    }

    #[test]
    fn deterministic() {
        let a = RqTable::compute(800, 9);
        let b = RqTable::compute(800, 9);
        assert_eq!(a.rq, b.rq);
    }

    #[test]
    fn five_bit_reduction_ballpark() {
        // paper Fig 2a: 5-bit W/A gives ~29% energy reduction vs 8/8 on the
        // whole accelerator; the MAC-only ratio should show a clear cut too.
        let t = RqTable::default();
        let r = t.rq(5, 5);
        assert!(r < 0.85 && r > 0.3, "rq(5,5) = {r}");
    }
}
