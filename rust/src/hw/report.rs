//! Per-layer energy breakdown reports — the analysis view behind the
//! paper's Fig 8 narrative ("quantization drives the gains on the
//! barely-pruned shortcut layer", etc.).

use super::energy::{Compression, EnergyModel};

/// One row of the breakdown table.
#[derive(Clone, Debug)]
pub struct LayerReport {
    /// prunable layer index
    pub layer: usize,
    /// MAC count of the mapped layer
    pub macs: u64,
    /// DRAM word accesses of the mapped layer
    pub dram: u64,
    /// energy at the dense 8-bit reference
    pub e_dense: f64,
    /// energy under the evaluated configuration
    pub e_compressed: f64,
    /// share of the *dense model's* total energy this layer holds
    pub dense_share: f64,
    /// fraction of this layer's energy removed by the config
    pub layer_gain: f64,
    /// latency (cycles) of the layer under the evaluated configuration
    pub cycles: f64,
}

/// Full breakdown for a configuration.
pub fn breakdown(model: &EnergyModel, cfgs: &[Compression]) -> Vec<LayerReport> {
    let baseline = model.baseline();
    (0..model.n_layers())
        .map(|l| {
            let e_dense = model.dense_layer(l);
            let e_c = model.layer(l, &cfgs[l]);
            LayerReport {
                layer: l,
                macs: model.mapping(l).macs,
                dram: model.mapping(l).dram,
                e_dense,
                e_compressed: e_c,
                dense_share: e_dense / baseline,
                layer_gain: 1.0 - e_c / e_dense.max(1e-12),
                cycles: model.layer_cycles(l, &cfgs[l]),
            }
        })
        .collect()
}

/// The layers responsible for ≥`frac` of remaining energy, biggest first
/// — the perf-pass "where to look next" helper.
pub fn hotspots(model: &EnergyModel, cfgs: &[Compression], frac: f64) -> Vec<usize> {
    let rows = breakdown(model, cfgs);
    let total: f64 = rows.iter().map(|r| r.e_compressed).sum();
    let mut order: Vec<usize> = (0..rows.len()).collect();
    order.sort_by(|&a, &b| rows[b].e_compressed.total_cmp(&rows[a].e_compressed));
    let mut acc = 0.0;
    let mut out = Vec::new();
    for &l in &order {
        out.push(l);
        acc += rows[l].e_compressed;
        if acc >= frac * total {
            break;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::dataflow::LayerDims;
    use crate::hw::mac_sim::RqTable;
    use crate::hw::Accel;

    fn model() -> EnergyModel {
        EnergyModel::new(
            vec![
                LayerDims::conv(16, 16, 3, 16, 16, 16, 3, 1),
                LayerDims::conv(16, 16, 16, 8, 8, 64, 3, 2),
                LayerDims::fc(256, 10),
            ],
            Accel::default(),
            RqTable::compute(1000, 3),
        )
    }

    #[test]
    fn shares_sum_to_one() {
        let m = model();
        let rows = breakdown(&m, &vec![Compression::dense(); 3]);
        let s: f64 = rows.iter().map(|r| r.dense_share).sum();
        assert!((s - 1.0).abs() < 1e-9);
        assert!(rows.iter().all(|r| r.layer_gain.abs() < 1e-9));
        assert!(rows.iter().all(|r| r.cycles > 0.0));
    }

    #[test]
    fn gain_shows_up_per_layer() {
        let m = model();
        let mut cfgs = vec![Compression::dense(); 3];
        cfgs[1] = Compression { sparsity: 0.5, coarse: true, bits: 4 };
        let rows = breakdown(&m, &cfgs);
        assert!(rows[1].layer_gain > 0.3);
        assert!(rows[0].layer_gain.abs() < 1e-9);
    }

    #[test]
    fn hotspots_ordered_and_cover() {
        let m = model();
        let cfgs = vec![Compression::dense(); 3];
        let hs = hotspots(&m, &cfgs, 0.99);
        assert!(!hs.is_empty());
        let rows = breakdown(&m, &cfgs);
        // first hotspot is the most expensive layer
        let max = (0..3)
            .max_by(|&a, &b| rows[a].e_compressed.total_cmp(&rows[b].e_compressed))
            .unwrap();
        assert_eq!(hs[0], max);
    }
}
