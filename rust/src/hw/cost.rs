//! The `CostModel` seam between `env/` and `hw/`, and the incremental
//! per-layer cost cache behind it.
//!
//! [`crate::env::CompressionEnv::step`] queries hardware gains at
//! *every* RL step, and historically re-summed energy and latency over
//! all layers each time even though one step changes exactly one
//! layer's [`Compression`] — the same access pattern PRs 2–4 exploited
//! in the accuracy oracle. [`CostCache`] gives the hardware oracle the
//! same treatment: per-layer `(energy, cycles)` terms are cached keyed
//! by that layer's `Compression` and recomputed only for layers whose
//! configuration changed; totals are summed in fixed layer order, so
//! every gain is **bit-identical** to the scratch recompute (same f64
//! values added in the same sequence) — property-tested under random
//! invalidate sequences in `rust/tests/proptests.rs`.
//!
//! [`CostModel`] is the trait the environment programs against; the
//! scratch [`EnergyModel`] implements it too, so alternative cost
//! oracles (measured latency tables, remote estimators) plug in
//! without touching `env/`.

use std::time::Instant;

use super::energy::{Compression, EnergyModel};
use super::report::{self, LayerReport};

/// Hardware cost oracle for one model on one target — the seam between
/// the compression environment and the `hw/` subsystem.
pub trait CostModel {
    /// Number of modelled layers.
    fn n_layers(&self) -> usize;

    /// Energy gain (fraction) of a full configuration vs the dense
    /// 8-bit baseline (eq. 3 over eqs. 4–8).
    fn energy_gain(&mut self, cfgs: &[Compression]) -> f64;

    /// Latency gain (fraction) vs the dense baseline (§4.2.3).
    fn latency_gain(&mut self, cfgs: &[Compression]) -> f64;

    /// Per-layer energy/latency breakdown of a configuration.
    fn breakdown(&self, cfgs: &[Compression]) -> Vec<LayerReport>;

    /// Drop any cached terms for `layer` (its config will be re-priced
    /// on the next query).
    fn invalidate(&mut self, layer: usize);

    /// Drop every cached term.
    fn invalidate_all(&mut self);
}

/// The scratch oracle is itself a [`CostModel`]: every query recomputes
/// all layers. The reference the cache is property-tested against.
impl CostModel for EnergyModel {
    fn n_layers(&self) -> usize {
        EnergyModel::n_layers(self)
    }

    fn energy_gain(&mut self, cfgs: &[Compression]) -> f64 {
        self.gain(cfgs)
    }

    fn latency_gain(&mut self, cfgs: &[Compression]) -> f64 {
        EnergyModel::latency_gain(self, cfgs)
    }

    fn breakdown(&self, cfgs: &[Compression]) -> Vec<LayerReport> {
        report::breakdown(self, cfgs)
    }

    fn invalidate(&mut self, _layer: usize) {}

    fn invalidate_all(&mut self) {}
}

/// Incremental per-layer cost cache over an [`EnergyModel`].
///
/// Caches each layer's `(energy, cycles)` keyed by that layer's
/// [`Compression`]; a query re-prices only layers whose key changed
/// (or was invalidated) and sums the per-layer terms in fixed layer
/// order — bit-identical to the scratch path by construction. The
/// dense baselines (energy and cycles denominators) are priced once at
/// construction; the scratch path recomputes them per query.
#[derive(Clone, Debug)]
pub struct CostCache {
    model: EnergyModel,
    keys: Vec<Option<Compression>>,
    energy: Vec<f64>,
    cycles: Vec<f64>,
    baseline_energy: f64,
    dense_cycles: f64,
    secs: f64,
    queries: u64,
    recomputed: u64,
    reused: u64,
}

impl CostCache {
    /// Wrap a priced model; the dense baselines are computed here once.
    pub fn new(model: EnergyModel) -> CostCache {
        let n = EnergyModel::n_layers(&model);
        let baseline_energy = model.baseline();
        let dense = vec![Compression::dense(); n];
        let dense_cycles = model.cycles(&dense);
        CostCache {
            model,
            keys: vec![None; n],
            energy: vec![0.0; n],
            cycles: vec![0.0; n],
            baseline_energy,
            dense_cycles,
            secs: 0.0,
            queries: 0,
            recomputed: 0,
            reused: 0,
        }
    }

    /// The underlying scratch oracle (dims, mappings, target, R_Q).
    pub fn model(&self) -> &EnergyModel {
        &self.model
    }

    /// Drain the wall-clock seconds spent inside cost queries since the
    /// last call — the `hw_s` phase-timer feed (`hapq perf`).
    pub fn take_secs(&mut self) -> f64 {
        std::mem::take(&mut self.secs)
    }

    /// Gain queries served.
    pub fn queries(&self) -> u64 {
        self.queries
    }

    /// Per-layer terms re-priced across all queries.
    pub fn recomputed(&self) -> u64 {
        self.recomputed
    }

    /// Per-layer terms served from cache across all queries.
    pub fn reused(&self) -> u64 {
        self.reused
    }

    /// Fraction of per-layer term *lookups* served from cache (0..1).
    /// Note the denominator counts every lookup: one env step issues
    /// two gain queries (energy then latency) that each scan all `n`
    /// layers, so the steady-state RL value approaches `(2n−1)/2n` —
    /// read the raw [`Self::recomputed`]/[`Self::reused`] counts for
    /// per-step arithmetic.
    pub fn hit_rate(&self) -> f64 {
        let total = self.recomputed + self.reused;
        if total == 0 {
            0.0
        } else {
            self.reused as f64 / total as f64
        }
    }

    /// Re-price layers whose configuration no longer matches the cache.
    fn refresh(&mut self, cfgs: &[Compression]) {
        assert_eq!(cfgs.len(), self.keys.len());
        for (l, cfg) in cfgs.iter().enumerate() {
            if self.keys[l] == Some(*cfg) {
                self.reused += 1;
            } else {
                self.energy[l] = self.model.layer(l, cfg);
                self.cycles[l] = self.model.layer_cycles(l, cfg);
                self.keys[l] = Some(*cfg);
                self.recomputed += 1;
            }
        }
    }
}

impl crate::telemetry::MetricsSource for CostCache {
    fn record(&self, reg: &mut crate::telemetry::MetricsRegistry) {
        reg.counter("hw.queries", self.queries);
        reg.counter("hw.recomputed", self.recomputed);
        reg.counter("hw.reused", self.reused);
        reg.gauge("hw.cache_hit_rate", self.hit_rate());
        reg.label("hw.target", &self.model.target.name);
    }
}

impl CostModel for CostCache {
    fn n_layers(&self) -> usize {
        self.keys.len()
    }

    fn energy_gain(&mut self, cfgs: &[Compression]) -> f64 {
        let t0 = Instant::now();
        self.queries += 1;
        self.refresh(cfgs);
        let total: f64 = self.energy.iter().sum();
        let gain = 1.0 - total / self.baseline_energy;
        self.secs += t0.elapsed().as_secs_f64();
        gain
    }

    fn latency_gain(&mut self, cfgs: &[Compression]) -> f64 {
        let t0 = Instant::now();
        self.queries += 1;
        self.refresh(cfgs);
        let total: f64 = self.cycles.iter().sum();
        let gain = 1.0 - total / self.dense_cycles;
        self.secs += t0.elapsed().as_secs_f64();
        gain
    }

    fn breakdown(&self, cfgs: &[Compression]) -> Vec<LayerReport> {
        report::breakdown(&self.model, cfgs)
    }

    fn invalidate(&mut self, layer: usize) {
        self.keys[layer] = None;
    }

    fn invalidate_all(&mut self) {
        self.keys.iter_mut().for_each(|k| *k = None);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::dataflow::LayerDims;
    use crate::hw::mac_sim::RqTable;
    use crate::hw::Accel;

    fn model() -> EnergyModel {
        EnergyModel::new(
            vec![
                LayerDims::conv(16, 16, 3, 16, 16, 16, 3, 1),
                LayerDims::conv(16, 16, 16, 8, 8, 32, 3, 2),
                LayerDims::fc(256, 10),
            ],
            Accel::default(),
            RqTable::compute(600, 3),
        )
    }

    #[test]
    fn cache_matches_scratch_and_counts_reuse() {
        let mut scratch = model();
        let mut cache = CostCache::new(model());
        let mut cfgs = vec![Compression::dense(); 3];
        // an RL-style walk: one layer changes per step
        for (t, bits) in [(0usize, 4u32), (1, 6), (2, 2)] {
            cfgs[t] = Compression { sparsity: 0.3 + t as f64 / 10.0, coarse: t % 2 == 0, bits };
            assert_eq!(
                cache.energy_gain(&cfgs).to_bits(),
                scratch.energy_gain(&cfgs).to_bits()
            );
            assert_eq!(
                cache.latency_gain(&cfgs).to_bits(),
                scratch.latency_gain(&cfgs).to_bits()
            );
        }
        // 6 queries over 3 layers: the walk re-priced 3 + the initial 2
        // dense fills; everything else came from cache
        assert_eq!(cache.queries(), 6);
        assert!(cache.reused() > cache.recomputed(), "{cache:?}");
        assert!(cache.hit_rate() > 0.5);
    }

    #[test]
    fn invalidate_forces_reprice_with_identical_numbers() {
        let mut scratch = model();
        let mut cache = CostCache::new(model());
        let cfgs = vec![Compression { sparsity: 0.5, coarse: true, bits: 4 }; 3];
        let g0 = cache.energy_gain(&cfgs);
        let before = cache.recomputed();
        cache.invalidate(1);
        let g1 = cache.energy_gain(&cfgs);
        assert_eq!(cache.recomputed(), before + 1, "layer 1 must re-price");
        cache.invalidate_all();
        let g2 = cache.energy_gain(&cfgs);
        assert_eq!(cache.recomputed(), before + 4, "all 3 must re-price");
        assert_eq!(g0.to_bits(), g1.to_bits());
        assert_eq!(g0.to_bits(), g2.to_bits());
        assert_eq!(g0.to_bits(), scratch.energy_gain(&cfgs).to_bits());
    }

    #[test]
    fn take_secs_drains_and_breakdown_matches_report() {
        let mut cache = CostCache::new(model());
        let cfgs = vec![Compression::dense(); 3];
        let _ = cache.energy_gain(&cfgs);
        assert!(cache.take_secs() >= 0.0);
        assert_eq!(cache.take_secs(), 0.0, "drained");
        let rows = CostModel::breakdown(&cache, &cfgs);
        let direct = report::breakdown(cache.model(), &cfgs);
        assert_eq!(rows.len(), direct.len());
        for (a, b) in rows.iter().zip(&direct) {
            assert_eq!(a.e_compressed.to_bits(), b.e_compressed.to_bits());
            assert_eq!(a.cycles.to_bits(), b.cycles.to_bits());
        }
    }
}
