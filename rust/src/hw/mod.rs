//! Hardware substrates: the gate-level MAC switching-activity simulator
//! (Synopsys-flow substitute), the Eyeriss-style dataflow mapper
//! (NN-Dataflow substitute), the paper's energy model (eqs 3–8), and
//! the pluggable target subsystem on top — named accelerator profiles
//! ([`target::HwTarget`], `--hw`/`--hw-file`) behind the
//! [`cost::CostModel`] seam with an incremental per-layer cost cache
//! ([`cost::CostCache`]) serving the RL hot path.

pub mod cost;
pub mod dataflow;
pub mod energy;
pub mod latency;
pub mod mac_sim;
pub mod report;
pub mod target;

/// One accelerator's PE array, memory hierarchy and access energies —
/// the numeric core of a [`target::HwTarget`]. The default is the
/// paper's Eyeriss-based configuration (§5.1, Fig 6), also available
/// by name as the `eyeriss-64` target.
#[derive(Clone, Debug)]
pub struct Accel {
    /// PE array rows per tile (paper: 64×64)
    pub pe_rows: usize,
    /// PE array columns per tile
    pub pe_cols: usize,
    /// per-PE register file bytes (paper: 64 B). Descriptive only for
    /// now: the dataflow mapper derives RF *traffic* from spatial
    /// reuse, not RF capacity, so this knob does not move any cost —
    /// only `e_rf` (the per-access energy) does.
    pub rf_bytes: usize,
    /// shared global buffer bytes (paper: 32 KB)
    pub gb_bytes: usize,
    /// native MAC precision in bits (paper: 8)
    pub mac_bits: u32,
    /// normalised access energies (Eyeriss: RF 1×, GB 6×, DRAM 200× a MAC)
    pub e_mac: f64,
    /// register-file access energy (relative to a MAC)
    pub e_rf: f64,
    /// global-buffer access energy (relative to a MAC)
    pub e_gb: f64,
    /// DRAM access energy (relative to a MAC)
    pub e_dram: f64,
}

impl Default for Accel {
    fn default() -> Self {
        Accel {
            pe_rows: 64,
            pe_cols: 64,
            rf_bytes: 64,
            gb_bytes: 32 * 1024,
            mac_bits: 8,
            e_mac: 1.0,
            e_rf: 1.0,
            e_gb: 6.0,
            e_dram: 200.0,
        }
    }
}
