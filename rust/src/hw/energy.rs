//! The paper's energy model, eqs (3)–(8), generalised over hardware
//! targets.
//!
//! E_total = Σ_l E_mem^l + E_comp^l            (3)
//! E_mem   = #acc  · e_mem  · R_mem            (4)
//! E_comp  = #comp · e_comp · (R_pruned + R_unpruned)   (5)
//!
//! with reduction coefficients (7) for fine-grained pruning
//! (R_mem = 1, R_pruned = P_FG·S, R_unpruned = (1−S)·R_Q) and (8) for
//! coarse-grained (R_mem = 1−S, R_pruned = 0, R_unpruned = (1−S)·R_Q).
//! #acc/#comp come from the dataflow mapper; R_Q/P_FG come from the
//! target's [`ComputeScaling`] rule — the MAC switching simulator for
//! fixed parallel multipliers (the paper's accelerator), an analytic
//! bit-width-product law for bit-serial arrays — both a handful of
//! multiplies on the RL hot path. The incremental per-layer cache
//! wrapping this oracle lives in [`super::cost`].

use super::dataflow::{map_layer, LayerDims, Mapping};
use super::mac_sim::RqTable;
use super::target::{ComputeScaling, HwTarget};
use super::Accel;

/// Per-layer compression configuration chosen by the agent.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Compression {
    /// fraction of zeroed parameters, S ∈ [0, 1]
    pub sparsity: f64,
    /// true → structured (filter/channel) pruning, eq (8); false → eq (7)
    pub coarse: bool,
    /// operand precision (weights & activations share it, §4.1), 2..=8
    pub bits: u32,
}

impl Compression {
    /// The uncompressed reference config (S = 0, 8 bits).
    pub fn dense() -> Self {
        Compression { sparsity: 0.0, coarse: false, bits: 8 }
    }
}

/// Cached energy oracle for one model on one hardware target.
#[derive(Clone, Debug)]
pub struct EnergyModel {
    /// the hardware target being modelled (accelerator + scaling rule)
    pub target: HwTarget,
    /// the MAC-sim R_Q / P_FG table (consulted on mac-sim targets)
    pub rq: RqTable,
    /// (dims, mapping, weighted mem energy, comp energy) per layer — dense/8-bit
    layers: Vec<(LayerDims, Mapping, f64, f64)>,
}

impl EnergyModel {
    /// Map every layer once against a bare accelerator config — the
    /// historical constructor: equivalent to an anonymous mac-sim
    /// target ([`HwTarget::custom`]) and bit-identical to the
    /// pre-refactor hardcoded path when `acc` is `Accel::default()`.
    pub fn new(dims: Vec<LayerDims>, acc: Accel, rq: RqTable) -> Self {
        Self::for_target(dims, &HwTarget::custom(acc), rq)
    }

    /// Map every layer once against a named hardware target and cache
    /// its dense access/energy numbers.
    pub fn for_target(dims: Vec<LayerDims>, target: &HwTarget, rq: RqTable) -> Self {
        let acc = &target.accel;
        let layers = dims
            .into_iter()
            .map(|d| {
                let m = map_layer(&d, acc);
                let e_mem = m.mem_energy(acc);
                let e_comp = m.macs as f64 * acc.e_mac;
                (d, m, e_mem, e_comp)
            })
            .collect();
        EnergyModel { target: target.clone(), rq, layers }
    }

    /// The target's accelerator configuration.
    pub fn acc(&self) -> &Accel {
        &self.target.accel
    }

    /// Number of modelled layers.
    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Dataflow dims of layer `l`.
    pub fn dims(&self, l: usize) -> &LayerDims {
        &self.layers[l].0
    }

    /// Chosen loop blocking of layer `l`.
    pub fn mapping(&self, l: usize) -> &Mapping {
        &self.layers[l].1
    }

    /// R_Q (eq. 6) for a (weights, activations) precision pair under
    /// the target's scaling rule: the MAC-sim table for fixed parallel
    /// multipliers, the bit-width product for bit-serial arrays. Both
    /// rules are normalised to the paper's dense 8/8-bit reference
    /// (`rq_pair(8, 8) == 1`), which is what makes `gain(dense) == 0`
    /// hold on every target — the dense baseline (eq. 3 denominator)
    /// carries no precision scaling.
    pub fn rq_pair(&self, wbits: u32, abits: u32) -> f64 {
        match self.target.scaling {
            ComputeScaling::MacSim => self.rq.rq(wbits, abits),
            ComputeScaling::BitSerial => {
                let w = wbits.clamp(2, 8) as f64;
                let a = abits.clamp(2, 8) as f64;
                (w * a) / 64.0 // dense reference: 8 × 8 bits
            }
        }
    }

    /// P_FG (§4.3): relative energy of a MAC whose activation operand
    /// is a pruned-weight zero. Gate-level measurement on mac-sim
    /// targets; a single 1×1 step (vs the 8×8-bit dense reference) on
    /// bit-serial arrays.
    pub fn p_fg(&self) -> f64 {
        match self.target.scaling {
            ComputeScaling::MacSim => self.rq.p_fg,
            ComputeScaling::BitSerial => 1.0 / 64.0,
        }
    }

    /// Dense 8-bit baseline energy of layer `l` (the paper's reference).
    pub fn dense_layer(&self, l: usize) -> f64 {
        self.layers[l].2 + self.layers[l].3
    }

    /// Energy of layer `l` under a compression config — eqs (4), (5).
    pub fn layer(&self, l: usize, cfg: &Compression) -> f64 {
        let (_, _, e_mem, e_comp) = self.layers[l];
        let s = cfg.sparsity.clamp(0.0, 1.0);
        let rq = self.rq_pair(cfg.bits, cfg.bits);
        let (r_mem, r_pruned, r_unpruned) = if cfg.coarse {
            (1.0 - s, 0.0, (1.0 - s) * rq) // eq (8)
        } else {
            (1.0, self.p_fg() * s, (1.0 - s) * rq) // eq (7)
        };
        e_mem * r_mem + e_comp * (r_pruned + r_unpruned)
    }

    /// Latency (cycles) of layer `l` under a compression config.
    pub fn layer_cycles(&self, l: usize, cfg: &Compression) -> f64 {
        super::latency::cycles_on(&self.layers[l].1, &self.target, cfg)
    }

    /// E_total (eq. 3) for a full per-layer configuration.
    pub fn total(&self, cfgs: &[Compression]) -> f64 {
        assert_eq!(cfgs.len(), self.layers.len());
        cfgs.iter()
            .enumerate()
            .map(|(l, c)| self.layer(l, c))
            .sum()
    }

    /// Dense 8-bit total (denominator of every energy-gain number).
    pub fn baseline(&self) -> f64 {
        (0..self.layers.len()).map(|l| self.dense_layer(l)).sum()
    }

    /// Energy gain (fraction) of a configuration w.r.t. the baseline.
    pub fn gain(&self, cfgs: &[Compression]) -> f64 {
        1.0 - self.total(cfgs) / self.baseline()
    }

    /// Latency (cycles) of a configuration — §4.2.3's "any other
    /// hardware metric" hook, backed by [`super::latency`].
    pub fn cycles(&self, cfgs: &[Compression]) -> f64 {
        assert_eq!(cfgs.len(), self.layers.len());
        (0..self.layers.len())
            .zip(cfgs)
            .map(|(l, c)| self.layer_cycles(l, c))
            .sum()
    }

    /// Latency gain (fraction) w.r.t. the dense baseline.
    pub fn latency_gain(&self, cfgs: &[Compression]) -> f64 {
        let dense = vec![Compression::dense(); self.layers.len()];
        1.0 - self.cycles(cfgs) / self.cycles(&dense)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims3() -> Vec<LayerDims> {
        vec![
            LayerDims::conv(16, 16, 3, 16, 16, 16, 3, 1),
            LayerDims::conv(16, 16, 16, 8, 8, 32, 3, 2),
            LayerDims::fc(512, 10),
        ]
    }

    fn model() -> EnergyModel {
        EnergyModel::new(dims3(), Accel::default(), RqTable::compute(1500, 7))
    }

    #[test]
    fn dense_config_is_baseline() {
        let m = model();
        let cfgs = vec![Compression::dense(); 3];
        assert!((m.total(&cfgs) - m.baseline()).abs() / m.baseline() < 1e-9);
        assert!(m.gain(&cfgs).abs() < 1e-9);
    }

    #[test]
    fn energy_never_exceeds_baseline() {
        use crate::util::proptest::forall;
        let m = model();
        forall(
            "compressed energy <= dense baseline",
            |r| {
                (0..3)
                    .map(|_| Compression {
                        sparsity: r.uniform(),
                        coarse: r.uniform() < 0.5,
                        bits: 2 + r.below(7) as u32,
                    })
                    .collect::<Vec<_>>()
            },
            |cfgs| m.total(cfgs) <= m.baseline() * (1.0 + 1e-9),
        );
    }

    #[test]
    fn coarse_beats_fine_at_same_sparsity() {
        // eq (7) vs (8): structured pruning reduces memory traffic and
        // skips pruned MACs entirely — strictly larger gains (Fig 1).
        let m = model();
        for s in [0.2, 0.5, 0.8] {
            let fine = Compression { sparsity: s, coarse: false, bits: 8 };
            let coarse = Compression { sparsity: s, coarse: true, bits: 8 };
            assert!(m.layer(0, &coarse) < m.layer(0, &fine), "s={s}");
        }
    }

    #[test]
    fn lower_bits_lower_energy() {
        let m = model();
        let mut prev = f64::INFINITY;
        for bits in [8u32, 6, 4, 2] {
            let c = Compression { sparsity: 0.0, coarse: false, bits };
            let e = m.total(&[c, c, c]);
            assert!(e <= prev + 1e-9, "bits={bits}");
            prev = e;
        }
    }

    #[test]
    fn monotone_in_sparsity() {
        let m = model();
        let mut prev = f64::INFINITY;
        for i in 0..=10 {
            let s = i as f64 / 10.0;
            let c = Compression { sparsity: s, coarse: true, bits: 8 };
            let e = m.layer(1, &c);
            assert!(e <= prev + 1e-9);
            prev = e;
        }
    }

    #[test]
    fn full_coarse_prune_zeroes_layer() {
        let m = model();
        let c = Compression { sparsity: 1.0, coarse: true, bits: 8 };
        assert!(m.layer(0, &c) < 1e-9);
    }

    #[test]
    fn bit_serial_scaling_is_the_bit_width_product() {
        let t = HwTarget::builtin("bitfusion").unwrap();
        let m = EnergyModel::for_target(dims3(), &t, RqTable::compute(400, 7));
        assert_eq!(m.rq_pair(8, 8).to_bits(), 1.0f64.to_bits());
        assert_eq!(m.rq_pair(2, 2).to_bits(), (4.0f64 / 64.0).to_bits());
        assert_eq!(m.rq_pair(4, 2).to_bits(), (8.0f64 / 64.0).to_bits());
        assert_eq!(m.p_fg().to_bits(), (1.0f64 / 64.0).to_bits());
        // exact monotone in bits, no simulation noise
        let mut prev = f64::INFINITY;
        for bits in (2..=8u32).rev() {
            let c = Compression { sparsity: 0.0, coarse: false, bits };
            let e = m.total(&[c, c, c]);
            assert!(e < prev, "bits={bits}");
            prev = e;
        }
    }

    #[test]
    fn bit_serial_dense_gain_is_zero_for_any_mac_bits() {
        // the dense baseline carries no precision scaling, so rq_pair
        // must be normalised to the 8/8 reference (== 1) even when the
        // profile's native mac_bits differs — otherwise gain(dense)
        // would be negative on low-precision bit-serial profiles
        let t = HwTarget {
            name: "bs4".into(),
            description: String::new(),
            accel: Accel { mac_bits: 4, ..Accel::default() },
            scaling: ComputeScaling::BitSerial,
        };
        let m = EnergyModel::for_target(dims3(), &t, RqTable::compute(300, 7));
        assert_eq!(m.rq_pair(8, 8).to_bits(), 1.0f64.to_bits());
        let dense = vec![Compression::dense(); 3];
        assert!(m.gain(&dense).abs() < 1e-12, "gain(dense) = {}", m.gain(&dense));
        assert!(m.latency_gain(&dense).abs() < 1e-12);
    }

    #[test]
    fn targets_disagree_on_the_same_config() {
        // the whole point of the subsystem: one configuration prices
        // differently on different hardware
        let rq = RqTable::compute(400, 7);
        let e64 = EnergyModel::for_target(
            dims3(),
            &HwTarget::builtin("eyeriss-64").unwrap(),
            rq.clone(),
        );
        let mcu = EnergyModel::for_target(
            dims3(),
            &HwTarget::builtin("mcu").unwrap(),
            rq,
        );
        assert_ne!(e64.baseline().to_bits(), mcu.baseline().to_bits());
        // the MCU's external memory dominates: its memory share of the
        // dense baseline exceeds the Eyeriss one
        let mem_share = |m: &EnergyModel| {
            let mem: f64 = (0..m.n_layers())
                .map(|l| m.mapping(l).mem_energy(m.acc()))
                .sum();
            mem / m.baseline()
        };
        assert!(mem_share(&mcu) > mem_share(&e64));
    }
}
