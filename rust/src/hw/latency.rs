//! Latency model — the paper's §4.2.3 extension hook made concrete:
//! "although we target energy efficiency, any other hardware metric
//! (e.g., latency) is seamlessly supported since it can be measured in
//! an identical manner".
//!
//! Cycle model per layer: max(compute-bound, memory-bound) — a roofline
//! over the same dataflow mapping the energy model uses:
//!
//!   t_comp = #MACs_effective / (PE_array_utilisation · #PEs)
//!   t_mem  = DRAM words / (words per cycle at the paper's 3.2 Gbps)
//!
//! Compression moves latency exactly like the energy reductions of
//! eqs (7)/(8): coarse pruning removes whole MAC lanes *and* traffic;
//! fine pruning only helps a zero-skipping datapath (we model the
//! paper's fixed Eyeriss-style array: fine-pruned MACs still occupy
//! issue slots, matching its E_comp penalty story).

use super::dataflow::Mapping;
use super::energy::Compression;
use super::Accel;

/// DRAM words (8-bit) per accelerator cycle — 3.2 Gbps @ ~1 GHz ≈ 0.4
/// words/cycle across the four corner channels (paper §5.1).
pub const DRAM_WORDS_PER_CYCLE: f64 = 0.4;

/// Cycle estimate for one layer under a compression config.
pub fn layer_cycles(m: &Mapping, acc: &Accel, cfg: &Compression) -> f64 {
    let pes = (acc.pe_rows * acc.pe_cols) as f64;
    // utilisation: output-channel × spatial tiles rarely fill the array
    // perfectly; we fold that into a fixed 70% sustained utilisation —
    // the Eyeriss paper's reported ballpark.
    let util = 0.7;
    let s = cfg.sparsity.clamp(0.0, 1.0);
    let (mac_factor, mem_factor) = if cfg.coarse {
        (1.0 - s, 1.0 - s) // pruned lanes disappear entirely (eq 8)
    } else {
        (1.0, 1.0) // fixed array: zeros still occupy slots (eq 7)
    };
    let t_comp = m.macs as f64 * mac_factor / (pes * util);
    let t_mem = m.dram as f64 * mem_factor / DRAM_WORDS_PER_CYCLE;
    t_comp.max(t_mem)
}

/// Whole-model latency (cycles) for a per-layer configuration.
pub fn total_cycles(
    mappings: &[&Mapping],
    acc: &Accel,
    cfgs: &[Compression],
) -> f64 {
    assert_eq!(mappings.len(), cfgs.len());
    mappings
        .iter()
        .zip(cfgs)
        .map(|(m, c)| layer_cycles(m, acc, c))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::dataflow::{map_layer, LayerDims};

    fn setup() -> (Mapping, Accel) {
        let acc = Accel::default();
        let d = LayerDims::conv(16, 16, 32, 16, 16, 64, 3, 1);
        (map_layer(&d, &acc), acc)
    }

    #[test]
    fn coarse_pruning_cuts_latency() {
        let (m, acc) = setup();
        let dense = layer_cycles(&m, &acc, &Compression::dense());
        let half = layer_cycles(
            &m,
            &acc,
            &Compression { sparsity: 0.5, coarse: true, bits: 8 },
        );
        assert!(half < 0.75 * dense, "coarse 50%: {half} vs {dense}");
    }

    #[test]
    fn fine_pruning_does_not_cut_latency_on_fixed_array() {
        let (m, acc) = setup();
        let dense = layer_cycles(&m, &acc, &Compression::dense());
        let fine = layer_cycles(
            &m,
            &acc,
            &Compression { sparsity: 0.5, coarse: false, bits: 8 },
        );
        assert!((fine - dense).abs() < 1e-9);
    }

    #[test]
    fn latency_positive_and_roofline_bound() {
        let (m, acc) = setup();
        let t = layer_cycles(&m, &acc, &Compression::dense());
        let pes = (acc.pe_rows * acc.pe_cols) as f64;
        assert!(t >= m.macs as f64 / pes, "cannot beat the ideal array");
        assert!(t > 0.0);
    }

    #[test]
    fn total_is_sum() {
        let (m, acc) = setup();
        let cfgs = vec![Compression::dense(); 3];
        let t3 = total_cycles(&[&m, &m, &m], &acc, &cfgs);
        let t1 = layer_cycles(&m, &acc, &Compression::dense());
        assert!((t3 - 3.0 * t1).abs() < 1e-9);
    }
}
