//! Latency model — the paper's §4.2.3 extension hook made concrete:
//! "although we target energy efficiency, any other hardware metric
//! (e.g., latency) is seamlessly supported since it can be measured in
//! an identical manner".
//!
//! Cycle model per layer: max(compute-bound, memory-bound) — a roofline
//! over the same dataflow mapping the energy model uses:
//!
//!   t_comp = #MACs_effective / (PE_array_utilisation · #PEs)
//!   t_mem  = DRAM words / (words per cycle at the paper's 3.2 Gbps)
//!
//! Compression moves latency exactly like the energy reductions of
//! eqs (7)/(8): coarse pruning removes whole MAC lanes *and* traffic;
//! fine pruning only helps a zero-skipping datapath (we model the
//! paper's fixed Eyeriss-style array: fine-pruned MACs still occupy
//! issue slots, matching its E_comp penalty story).

use super::dataflow::Mapping;
use super::energy::Compression;
use super::target::{ComputeScaling, HwTarget};
use super::Accel;

/// DRAM words (8-bit) per accelerator cycle — 3.2 Gbps @ ~1 GHz ≈ 0.4
/// words/cycle across the four corner channels (paper §5.1).
pub const DRAM_WORDS_PER_CYCLE: f64 = 0.4;

/// Cycle estimate for one layer under a hardware target's scaling
/// rule: fixed parallel arrays issue every MAC in one slot regardless
/// of precision ([`layer_cycles`], the paper's model); bit-serial
/// arrays additionally scale compute time with the product of the
/// operand bit-widths, normalised to the dense 8/8-bit reference.
pub fn cycles_on(m: &Mapping, target: &HwTarget, cfg: &Compression) -> f64 {
    match target.scaling {
        ComputeScaling::MacSim => layer_cycles(m, &target.accel, cfg),
        ComputeScaling::BitSerial => {
            let acc = &target.accel;
            let pes = (acc.pe_rows * acc.pe_cols) as f64;
            let util = 0.7;
            let s = cfg.sparsity.clamp(0.0, 1.0);
            let (mac_factor, mem_factor) = if cfg.coarse {
                (1.0 - s, 1.0 - s) // pruned lanes disappear entirely (eq 8)
            } else {
                (1.0, 1.0) // zeros still occupy serial issue slots
            };
            // normalised to the dense 8/8-bit reference, matching the
            // energy model's rq_pair so both gains share one baseline
            let b = cfg.bits.clamp(2, 8) as f64;
            let serial = (b * b) / 64.0;
            let t_comp = m.macs as f64 * mac_factor * serial / (pes * util);
            let t_mem = m.dram as f64 * mem_factor / DRAM_WORDS_PER_CYCLE;
            t_comp.max(t_mem)
        }
    }
}

/// Cycle estimate for one layer under a compression config on a fixed
/// parallel (mac-sim) array.
pub fn layer_cycles(m: &Mapping, acc: &Accel, cfg: &Compression) -> f64 {
    let pes = (acc.pe_rows * acc.pe_cols) as f64;
    // utilisation: output-channel × spatial tiles rarely fill the array
    // perfectly; we fold that into a fixed 70% sustained utilisation —
    // the Eyeriss paper's reported ballpark.
    let util = 0.7;
    let s = cfg.sparsity.clamp(0.0, 1.0);
    let (mac_factor, mem_factor) = if cfg.coarse {
        (1.0 - s, 1.0 - s) // pruned lanes disappear entirely (eq 8)
    } else {
        (1.0, 1.0) // fixed array: zeros still occupy slots (eq 7)
    };
    let t_comp = m.macs as f64 * mac_factor / (pes * util);
    let t_mem = m.dram as f64 * mem_factor / DRAM_WORDS_PER_CYCLE;
    t_comp.max(t_mem)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::dataflow::{map_layer, LayerDims};

    fn setup() -> (Mapping, Accel) {
        let acc = Accel::default();
        let d = LayerDims::conv(16, 16, 32, 16, 16, 64, 3, 1);
        (map_layer(&d, &acc), acc)
    }

    #[test]
    fn coarse_pruning_cuts_latency() {
        let (m, acc) = setup();
        let dense = layer_cycles(&m, &acc, &Compression::dense());
        let half = layer_cycles(
            &m,
            &acc,
            &Compression { sparsity: 0.5, coarse: true, bits: 8 },
        );
        assert!(half < 0.75 * dense, "coarse 50%: {half} vs {dense}");
    }

    #[test]
    fn fine_pruning_does_not_cut_latency_on_fixed_array() {
        let (m, acc) = setup();
        let dense = layer_cycles(&m, &acc, &Compression::dense());
        let fine = layer_cycles(
            &m,
            &acc,
            &Compression { sparsity: 0.5, coarse: false, bits: 8 },
        );
        assert!((fine - dense).abs() < 1e-9);
    }

    #[test]
    fn latency_positive_and_roofline_bound() {
        let (m, acc) = setup();
        let t = layer_cycles(&m, &acc, &Compression::dense());
        let pes = (acc.pe_rows * acc.pe_cols) as f64;
        assert!(t >= m.macs as f64 / pes, "cannot beat the ideal array");
        assert!(t > 0.0);
    }

    #[test]
    fn bit_serial_latency_drops_with_precision() {
        use crate::hw::target::HwTarget;
        let t = HwTarget::builtin("bitfusion").unwrap();
        let d = LayerDims::conv(16, 16, 32, 16, 16, 64, 3, 1);
        let m = map_layer(&d, &t.accel);
        let mut prev = f64::INFINITY;
        for bits in (2..=8u32).rev() {
            let c = Compression { sparsity: 0.0, coarse: false, bits };
            let cy = cycles_on(&m, &t, &c);
            assert!(cy <= prev + 1e-9, "bits={bits}");
            // never below the memory roofline
            assert!(cy + 1e-9 >= m.dram as f64 / DRAM_WORDS_PER_CYCLE);
            prev = cy;
        }
        // on a mac-sim target cycles_on IS layer_cycles, bit for bit
        let e64 = HwTarget::builtin("eyeriss-64").unwrap();
        let c = Compression { sparsity: 0.4, coarse: true, bits: 5 };
        assert_eq!(
            cycles_on(&m, &e64, &c).to_bits(),
            layer_cycles(&m, &e64.accel, &c).to_bits()
        );
    }

}
