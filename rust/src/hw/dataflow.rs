//! Eyeriss-style dataflow mapper — the NN-Dataflow substitute (§4.3).
//!
//! The paper obtains per-layer #MAC and #memory-access counts from
//! NN-Dataflow's loop-blocking/ordering search over a tiled accelerator.
//! We rebuild that abstraction level: for every layer a small exhaustive
//! search over (spatial, output-channel, input-channel) tile factors
//! picks the mapping that minimises hierarchical access energy under the
//! RF/global-buffer capacity constraints; the winning mapping's access
//! counts feed the energy model. Counts are in 8-bit words (the
//! accelerator's native datapath). The mapper is target-generic: each
//! [`crate::hw::target::HwTarget`] maps every layer against its own
//! buffer capacities and access energies, so the same model places
//! differently on `eyeriss-64` than on an `mcu`-class memory hierarchy.

use super::Accel;

/// Shape of one layer's computation (fc layers: oh = ow = k = 1).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LayerDims {
    /// input height
    pub ih: usize,
    /// input width
    pub iw: usize,
    /// input channels
    pub ci: usize,
    /// output height
    pub oh: usize,
    /// output width
    pub ow: usize,
    /// output channels
    pub co: usize,
    /// kernel size
    pub k: usize,
    /// spatial stride
    pub stride: usize,
    /// grouped convolution factor; depthwise = ci (MACs and weights scale 1/groups)
    pub groups: usize,
}

impl LayerDims {
    /// Standard convolution dims.
    pub fn conv(ih: usize, iw: usize, ci: usize, oh: usize, ow: usize, co: usize,
                k: usize, stride: usize) -> Self {
        LayerDims { ih, iw, ci, oh, ow, co, k, stride, groups: 1 }
    }

    /// Depthwise conv: co == ci, each output channel sees one input channel.
    pub fn dwconv(ih: usize, iw: usize, c: usize, oh: usize, ow: usize,
                  k: usize, stride: usize) -> Self {
        LayerDims { ih, iw, ci: c, oh, ow, co: c, k, stride, groups: c }
    }

    /// Fully-connected layer dims (1×1 spatial).
    pub fn fc(ci: usize, co: usize) -> Self {
        LayerDims { ih: 1, iw: 1, ci, oh: 1, ow: 1, co, k: 1, stride: 1, groups: 1 }
    }

    /// Multiply-accumulate count of the layer.
    pub fn macs(&self) -> u64 {
        (self.oh * self.ow * self.co * self.ci * self.k * self.k / self.groups) as u64
    }

    /// Weight count of the layer.
    pub fn weights(&self) -> u64 {
        (self.k * self.k * self.ci * self.co / self.groups) as u64
    }

    /// Input feature-map size in words.
    pub fn ifmap(&self) -> u64 {
        (self.ih * self.iw * self.ci) as u64
    }

    /// Output feature-map size in words.
    pub fn ofmap(&self) -> u64 {
        (self.oh * self.ow * self.co) as u64
    }
}

/// A chosen loop blocking and its access counts.
#[derive(Clone, Copy, Debug)]
pub struct Mapping {
    /// spatial tile (output pixels)
    pub t_hw: usize,
    /// output-channel tile
    pub t_co: usize,
    /// input-channel tile
    pub t_ci: usize,
    /// MAC count of the mapped layer
    pub macs: u64,
    /// DRAM word accesses
    pub dram: u64,
    /// global-buffer word accesses
    pub gb: u64,
    /// register-file word accesses
    pub rf: u64,
}

impl Mapping {
    /// Energy of data movement under the accelerator's access costs.
    pub fn mem_energy(&self, acc: &Accel) -> f64 {
        self.dram as f64 * acc.e_dram + self.gb as f64 * acc.e_gb
            + self.rf as f64 * acc.e_rf
    }

    /// Total accesses (#acc of eq. 4).
    pub fn accesses(&self) -> u64 {
        self.dram + self.gb + self.rf
    }
}

fn tile_candidates(dim: usize) -> Vec<usize> {
    let mut v = vec![1usize];
    let mut t = 2;
    while t < dim {
        v.push(t);
        t *= 2;
    }
    v.push(dim.max(1));
    v.dedup();
    v
}

/// Access counts for one (t_hw, t_co, t_ci) blocking.
fn eval_mapping(d: &LayerDims, acc: &Accel, t_hw: usize, t_co: usize,
                t_ci: usize) -> Option<Mapping> {
    let ohw = d.oh * d.ow;
    let n_hw = ohw.div_ceil(t_hw) as u64;
    let n_co = d.co.div_ceil(t_co) as u64;
    let n_ci = d.ci.div_ceil(t_ci) as u64;

    // GB working set for one tile pass (8-bit words):
    // ifmap tile (t_hw · stride² upper bound on receptive pixels · t_ci),
    // weight tile, psum tile (16-bit → 2 words each).
    let if_tile = (t_hw * d.stride * d.stride + d.k * d.k) * t_ci;
    let w_tile = d.k * d.k * t_ci * t_co;
    let ps_tile = 2 * t_hw * t_co;
    if if_tile + w_tile + ps_tile > acc.gb_bytes {
        return None;
    }

    // DRAM traffic:
    //   ifmap read once per output-channel pass,
    //   weights read once per spatial pass,
    //   ofmap written once; psums spilled twice per extra ci pass.
    let dram = d.ifmap() * n_co
        + d.weights() * n_hw
        + d.ofmap()
        + 2 * d.ofmap() * (n_ci.saturating_sub(1));

    // GB traffic: every operand entering the PE array crosses GB once per
    // tile pass; RF reuse keeps repeated reads local.
    let gb = d.ifmap() * n_co * (d.k * d.k) as u64 / (d.stride * d.stride).max(1) as u64
        + d.weights() * n_hw
        + 2 * d.ofmap() * n_ci;

    // RF traffic: 2 operand reads + 1 psum update per MAC, minus what the
    // PE array broadcasts spatially (per-PE reuse across the array rows).
    let spatial_reuse = (acc.pe_rows.min(d.k * d.k).max(1)) as u64;
    let rf = 3 * d.macs() / spatial_reuse.max(1);

    Some(Mapping { t_hw, t_co, t_ci, macs: d.macs(), dram, gb, rf })
}

/// Search the blocking space; returns the min-energy mapping.
pub fn map_layer(d: &LayerDims, acc: &Accel) -> Mapping {
    let mut best: Option<(f64, Mapping)> = None;
    for &t_hw in &tile_candidates(d.oh * d.ow) {
        for &t_co in &tile_candidates(d.co) {
            for &t_ci in &tile_candidates(d.ci) {
                if let Some(m) = eval_mapping(d, acc, t_hw, t_co, t_ci) {
                    let e = m.mem_energy(acc);
                    if best.map_or(true, |(be, _)| e < be) {
                        best = Some((e, m));
                    }
                }
            }
        }
    }
    // Degenerate fallback: minimal tiles always fit a sane config.
    best.map(|(_, m)| m).unwrap_or_else(|| Mapping {
        t_hw: 1,
        t_co: 1,
        t_ci: 1,
        macs: d.macs(),
        dram: d.ifmap() + d.weights() + d.ofmap(),
        gb: 2 * d.macs(),
        rf: 3 * d.macs(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_conv() -> LayerDims {
        LayerDims::conv(16, 16, 16, 16, 16, 32, 3, 1)
    }

    #[test]
    fn macs_hand_value() {
        let d = small_conv();
        assert_eq!(d.macs(), 16 * 16 * 32 * 16 * 9);
        let f = LayerDims::fc(128, 10);
        assert_eq!(f.macs(), 1280);
    }

    #[test]
    fn mapping_respects_compulsory_traffic() {
        let d = small_conv();
        let acc = Accel::default();
        let m = map_layer(&d, &acc);
        // DRAM traffic can never be below compulsory (each datum once)
        assert!(m.dram >= d.ifmap() + d.weights() + d.ofmap());
        assert!(m.rf >= d.macs() / acc.pe_rows as u64);
        assert_eq!(m.macs, d.macs());
    }

    #[test]
    fn bigger_buffer_never_hurts() {
        let d = small_conv();
        let small = Accel { gb_bytes: 8 * 1024, ..Accel::default() };
        let big = Accel { gb_bytes: 128 * 1024, ..Accel::default() };
        let em_small = map_layer(&d, &small).mem_energy(&small);
        let em_big = map_layer(&d, &big).mem_energy(&big);
        assert!(em_big <= em_small);
    }

    #[test]
    fn fc_layer_maps() {
        let d = LayerDims::fc(512, 100);
        let m = map_layer(&d, &Accel::default());
        assert!(m.dram >= d.weights());
        assert!(m.mem_energy(&Accel::default()) > 0.0);
    }

    #[test]
    fn property_energy_scales_with_layer() {
        use crate::util::proptest::forall;
        let acc = Accel::default();
        forall(
            "doubling channels does not reduce mem energy",
            |r| {
                let c = 4 + r.below(28);
                let hw = 4 + r.below(12);
                (hw, c)
            },
            |&(hw, c)| {
                let d1 = LayerDims::conv(hw, hw, c, hw, hw, c, 3, 1);
                let d2 = LayerDims::conv(hw, hw, c, hw, hw, 2 * c, 3, 1);
                let e1 = map_layer(&d1, &acc).mem_energy(&acc);
                let e2 = map_layer(&d2, &acc).mem_energy(&acc);
                e2 >= e1
            },
        );
    }
}
