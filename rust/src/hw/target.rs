//! Named hardware targets — the pluggable face of the `hw/` subsystem.
//!
//! The paper evaluates one Eyeriss-style accelerator (§5.1), but its
//! central claim — the optimal compression policy is *hardware-aware* —
//! only bites when the hardware can change: HAQ (Wang et al.) showed
//! the learned bit policy specialises per accelerator (edge vs cloud,
//! spatial vs temporal), and MCU-class targets invert the energy
//! balance entirely (DRAM-dominated). A [`HwTarget`] bundles the
//! accelerator configuration ([`Accel`]) with a [`ComputeScaling`] rule
//! describing how MAC energy responds to operand precision; built-in
//! profiles are selected by name (`--hw`, env default `HAPQ_HW`) and
//! custom ones load from JSON (`--hw-file`, via [`crate::io::json`]).
//!
//! `eyeriss-64` is the pre-refactor hardcoded `Accel::default()` target
//! and MUST stay bit-identical to it — pinned by
//! `rust/tests/hw_target.rs` against an in-test copy of the old cost
//! computation.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use super::Accel;
use crate::io::json::{self, num, obj, s, Value};

/// How MAC (compute) energy scales with operand precision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ComputeScaling {
    /// Fixed parallel multiplier: R_Q / P_FG come from the gate-level
    /// MAC switching simulator ([`super::mac_sim::RqTable`]) — the
    /// paper's model (eq. 6).
    MacSim,
    /// Bit-serial datapath (BitFusion-style): compute energy and
    /// compute cycles scale with the *product* of the operand
    /// bit-widths, normalised to the dense 8/8-bit reference, and a
    /// zeroed operand costs a single 1×1 step.
    BitSerial,
}

impl ComputeScaling {
    /// JSON/CLI spelling of the scaling rule.
    pub fn name(&self) -> &'static str {
        match self {
            ComputeScaling::MacSim => "mac-sim",
            ComputeScaling::BitSerial => "bit-serial",
        }
    }

    /// Parse a JSON/CLI spelling.
    pub fn parse(text: &str) -> Result<ComputeScaling> {
        match text {
            "mac-sim" => Ok(ComputeScaling::MacSim),
            "bit-serial" => Ok(ComputeScaling::BitSerial),
            other => bail!("unknown compute scaling `{other}` (want mac-sim|bit-serial)"),
        }
    }
}

/// A named accelerator profile: everything the cost model needs to
/// price a compression configuration on one piece of hardware.
#[derive(Clone, Debug)]
pub struct HwTarget {
    /// profile name (recorded in run JSON as `hw`)
    pub name: String,
    /// one-line description printed by `hapq hw`
    pub description: String,
    /// PE array / memory hierarchy / access energies
    pub accel: Accel,
    /// how compute energy responds to operand precision
    pub scaling: ComputeScaling,
}

/// The built-in profile names, in `hapq hw` table order.
pub const BUILTIN_TARGETS: &[&str] = &["eyeriss-64", "eyeriss-128", "bitfusion", "mcu"];

/// The default target name: `HAPQ_HW` if set and non-empty, else
/// `eyeriss-64` (the paper's accelerator).
pub fn default_hw() -> String {
    match std::env::var("HAPQ_HW") {
        Ok(v) if !v.is_empty() => v,
        _ => "eyeriss-64".to_string(),
    }
}

impl HwTarget {
    /// A built-in profile by name (`None` for unknown names).
    pub fn builtin(name: &str) -> Option<HwTarget> {
        let t = match name {
            // The paper's accelerator (§5.1, Fig 6) — numbers are
            // exactly `Accel::default()`; the golden-parity tests pin
            // this profile bit-identical to the pre-refactor path.
            "eyeriss-64" => HwTarget {
                name: name.into(),
                description: "Eyeriss-style 64x64 PE array, 32 KB global buffer (paper \
                              §5.1 — the default)"
                    .into(),
                accel: Accel::default(),
                scaling: ComputeScaling::MacSim,
            },
            // A scaled-up spatial array: 4x the PEs, 4x the buffer —
            // the "cloud" point of a HAQ-style edge/cloud sweep.
            "eyeriss-128" => HwTarget {
                name: name.into(),
                description: "scaled-up Eyeriss: 128x128 PEs, 128 KB global buffer \
                              (cloud-class spatial array)"
                    .into(),
                accel: Accel {
                    pe_rows: 128,
                    pe_cols: 128,
                    gb_bytes: 128 * 1024,
                    ..Accel::default()
                },
                scaling: ComputeScaling::MacSim,
            },
            // BitFusion-style bit-serial/bit-parallel composable array:
            // compute energy and cycles scale with the product of the
            // operand bit-widths, so low precision pays off
            // quadratically rather than through toggle statistics.
            "bitfusion" => HwTarget {
                name: name.into(),
                description: "BitFusion-style bit-serial array: compute energy/cycles \
                              scale with the product of operand bit-widths"
                    .into(),
                accel: Accel {
                    pe_rows: 32,
                    pe_cols: 32,
                    gb_bytes: 16 * 1024,
                    ..Accel::default()
                },
                scaling: ComputeScaling::BitSerial,
            },
            // Cortex-M-class MCU: a single MAC issue slot, a modest
            // SRAM standing in for the global buffer, and external
            // memory that dwarfs everything else (Deutel et al.: MCU
            // deployments are DRAM/flash-dominated).
            "mcu" => HwTarget {
                name: name.into(),
                description: "Cortex-M-class MCU: single MAC, 64 KB SRAM, external \
                              memory at 800x a MAC (DRAM-dominated)"
                    .into(),
                accel: Accel {
                    pe_rows: 1,
                    pe_cols: 1,
                    rf_bytes: 32,
                    gb_bytes: 64 * 1024,
                    mac_bits: 8,
                    e_mac: 1.0,
                    e_rf: 0.5,
                    e_gb: 1.5,
                    e_dram: 800.0,
                },
                scaling: ComputeScaling::MacSim,
            },
            _ => return None,
        };
        Some(t)
    }

    /// Resolve the CLI selection: an explicit `--hw-file` profile wins,
    /// otherwise `name` must be a built-in.
    pub fn resolve(name: &str, file: Option<&Path>) -> Result<HwTarget> {
        if let Some(path) = file {
            return Self::load(path);
        }
        Self::builtin(name).ok_or_else(|| {
            anyhow!(
                "unknown hardware target `{name}`; built-ins: {BUILTIN_TARGETS:?} \
                 (or pass a JSON profile via --hw-file)"
            )
        })
    }

    /// Load a JSON profile file (`--hw-file`).
    pub fn load(path: &Path) -> Result<HwTarget> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading hardware profile {path:?}"))?;
        Self::from_json(&json::parse(&text)?)
            .with_context(|| format!("parsing hardware profile {path:?}"))
    }

    /// Parse a profile from JSON. Only `name` is required; every other
    /// field defaults to the `eyeriss-64` value, so a profile file can
    /// describe just the deltas:
    ///
    /// ```json
    /// {"name": "my-npu", "pe_rows": 16, "pe_cols": 16,
    ///  "gb_bytes": 65536, "e_dram": 400.0, "compute": "bit-serial"}
    /// ```
    ///
    /// Note `rf_bytes` is accepted for completeness but currently
    /// descriptive only — the mapper models RF *access energy*
    /// (`e_rf`), not RF capacity (see [`Accel::rf_bytes`]).
    pub fn from_json(v: &Value) -> Result<HwTarget> {
        let name = v.req("name")?.as_str()?.to_string();
        let d = Accel::default();
        let f = |key: &str, default: f64| -> Result<f64> {
            match v.get(key) {
                Some(x) => x.as_f64(),
                None => Ok(default),
            }
        };
        // strict integer fields: reject fractional, non-finite or
        // absurd values instead of silently truncating/wrapping them
        // through `as` casts (a typo'd profile must fail loudly)
        let u = |key: &str, default: usize| -> Result<usize> {
            match v.get(key) {
                Some(x) => {
                    let raw = x.as_f64()?;
                    if !raw.is_finite() || raw.fract() != 0.0 || !(0.0..=1e12).contains(&raw)
                    {
                        bail!(
                            "hardware profile field `{key}` must be a non-negative \
                             integer, got {raw}"
                        );
                    }
                    Ok(raw as usize)
                }
                None => Ok(default),
            }
        };
        let mac_bits = u("mac_bits", d.mac_bits as usize)?;
        if !(2..=8).contains(&mac_bits) {
            bail!("hardware profile `{name}`: mac_bits must be in [2, 8], got {mac_bits}");
        }
        let accel = Accel {
            pe_rows: u("pe_rows", d.pe_rows)?,
            pe_cols: u("pe_cols", d.pe_cols)?,
            rf_bytes: u("rf_bytes", d.rf_bytes)?,
            gb_bytes: u("gb_bytes", d.gb_bytes)?,
            mac_bits: mac_bits as u32,
            e_mac: f("e_mac", d.e_mac)?,
            e_rf: f("e_rf", d.e_rf)?,
            e_gb: f("e_gb", d.e_gb)?,
            e_dram: f("e_dram", d.e_dram)?,
        };
        if accel.pe_rows == 0 || accel.pe_cols == 0 || accel.gb_bytes == 0 {
            bail!("hardware profile `{name}`: pe_rows/pe_cols/gb_bytes must be positive");
        }
        // the PE count (rows × cols) feeds usize arithmetic on the
        // latency roofline — keep it far from overflow
        if (accel.pe_rows as u64).saturating_mul(accel.pe_cols as u64) > 1u64 << 32 {
            bail!("hardware profile `{name}`: pe_rows * pe_cols must be <= 2^32");
        }
        for (key, e) in [
            ("e_mac", accel.e_mac),
            ("e_rf", accel.e_rf),
            ("e_gb", accel.e_gb),
            ("e_dram", accel.e_dram),
        ] {
            // a negative access energy would make the mapper *maximise*
            // traffic and push gains outside [0, 1] with no diagnostic
            if !e.is_finite() || e <= 0.0 {
                bail!("hardware profile `{name}`: {key} must be finite and positive, got {e}");
            }
        }
        let scaling = match v.get("compute") {
            Some(x) => ComputeScaling::parse(x.as_str()?)?,
            None => ComputeScaling::MacSim,
        };
        let description = match v.get("description") {
            Some(x) => x.as_str()?.to_string(),
            None => format!("custom profile loaded from JSON ({})", scaling.name()),
        };
        Ok(HwTarget { name, description, accel, scaling })
    }

    /// Serialise the profile to the `--hw-file` JSON schema.
    pub fn to_json(&self) -> Value {
        let a = &self.accel;
        obj(vec![
            ("name", s(&self.name)),
            ("description", s(&self.description)),
            ("pe_rows", num(a.pe_rows as f64)),
            ("pe_cols", num(a.pe_cols as f64)),
            ("rf_bytes", num(a.rf_bytes as f64)),
            ("gb_bytes", num(a.gb_bytes as f64)),
            ("mac_bits", num(a.mac_bits as f64)),
            ("e_mac", num(a.e_mac)),
            ("e_rf", num(a.e_rf)),
            ("e_gb", num(a.e_gb)),
            ("e_dram", num(a.e_dram)),
            ("compute", s(self.scaling.name())),
        ])
    }

    /// Wrap a bare [`Accel`] as an anonymous mac-sim target — the
    /// compatibility shim behind [`super::energy::EnergyModel::new`].
    pub fn custom(accel: Accel) -> HwTarget {
        HwTarget {
            name: "custom".into(),
            description: "ad-hoc Accel configuration (mac-sim scaling)".into(),
            accel,
            scaling: ComputeScaling::MacSim,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_resolve_and_default_is_eyeriss64() {
        for name in BUILTIN_TARGETS {
            let t = HwTarget::builtin(name).unwrap();
            assert_eq!(&t.name, name);
            assert!(!t.description.is_empty());
        }
        assert!(HwTarget::builtin("tpu-v9").is_none());
        assert!(HwTarget::resolve("tpu-v9", None).is_err());
        // the env default falls back to the paper's accelerator
        if std::env::var("HAPQ_HW").is_err() {
            assert_eq!(default_hw(), "eyeriss-64");
        }
    }

    #[test]
    fn json_roundtrip_preserves_every_field() {
        for name in BUILTIN_TARGETS {
            let t = HwTarget::builtin(name).unwrap();
            let back = HwTarget::from_json(&t.to_json()).unwrap();
            assert_eq!(back.name, t.name);
            assert_eq!(back.scaling, t.scaling);
            assert_eq!(back.accel.pe_rows, t.accel.pe_rows);
            assert_eq!(back.accel.pe_cols, t.accel.pe_cols);
            assert_eq!(back.accel.rf_bytes, t.accel.rf_bytes);
            assert_eq!(back.accel.gb_bytes, t.accel.gb_bytes);
            assert_eq!(back.accel.mac_bits, t.accel.mac_bits);
            assert_eq!(back.accel.e_mac.to_bits(), t.accel.e_mac.to_bits());
            assert_eq!(back.accel.e_rf.to_bits(), t.accel.e_rf.to_bits());
            assert_eq!(back.accel.e_gb.to_bits(), t.accel.e_gb.to_bits());
            assert_eq!(back.accel.e_dram.to_bits(), t.accel.e_dram.to_bits());
        }
    }

    #[test]
    fn partial_json_inherits_eyeriss64_defaults() {
        let v = json::parse(r#"{"name": "half-buffer", "gb_bytes": 16384}"#).unwrap();
        let t = HwTarget::from_json(&v).unwrap();
        let d = Accel::default();
        assert_eq!(t.accel.gb_bytes, 16384);
        assert_eq!(t.accel.pe_rows, d.pe_rows);
        assert_eq!(t.accel.e_dram, d.e_dram);
        assert_eq!(t.scaling, ComputeScaling::MacSim);
        // name is mandatory; bad scaling and degenerate arrays rejected
        assert!(HwTarget::from_json(&json::parse(r#"{"pe_rows": 4}"#).unwrap()).is_err());
        assert!(HwTarget::from_json(
            &json::parse(r#"{"name": "x", "compute": "quantum"}"#).unwrap()
        )
        .is_err());
        assert!(HwTarget::from_json(
            &json::parse(r#"{"name": "x", "pe_rows": 0}"#).unwrap()
        )
        .is_err());
        assert!(HwTarget::from_json(
            &json::parse(r#"{"name": "x", "mac_bits": 16}"#).unwrap()
        )
        .is_err());
        // negative or zero access energies are rejected, not priced
        assert!(HwTarget::from_json(
            &json::parse(r#"{"name": "x", "e_dram": -5.0}"#).unwrap()
        )
        .is_err());
        assert!(HwTarget::from_json(
            &json::parse(r#"{"name": "x", "e_rf": 0}"#).unwrap()
        )
        .is_err());
        // fractional integer fields are rejected, never truncated
        assert!(HwTarget::from_json(
            &json::parse(r#"{"name": "x", "mac_bits": 3.7}"#).unwrap()
        )
        .is_err());
        assert!(HwTarget::from_json(
            &json::parse(r#"{"name": "x", "pe_rows": 63.9}"#).unwrap()
        )
        .is_err());
        // absurd PE arrays whose product would overflow are rejected
        assert!(HwTarget::from_json(
            &json::parse(r#"{"name": "x", "pe_rows": 10000000000, "pe_cols": 10000000000}"#)
                .unwrap()
        )
        .is_err());
    }

    #[test]
    fn hw_file_wins_over_name() {
        let dir = std::env::temp_dir().join(format!("hapq-hwfile-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("npu.json");
        std::fs::write(&path, r#"{"name": "my-npu", "compute": "bit-serial"}"#).unwrap();
        let t = HwTarget::resolve("eyeriss-64", Some(path.as_path())).unwrap();
        assert_eq!(t.name, "my-npu");
        assert_eq!(t.scaling, ComputeScaling::BitSerial);
        let missing = dir.join("missing.json");
        assert!(HwTarget::resolve("eyeriss-64", Some(missing.as_path())).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
