//! Method-agnostic search checkpointing — the resumable half of the
//! [`crate::search::SearchDriver`].
//!
//! This generalises the NPZ *policy* checkpoint of
//! [`crate::rl::checkpoint`] (which persists only the composite agent's
//! networks, for the paper's on-device story) into a full **search
//! state** snapshot that works for every [`SearchStrategy`]: driver
//! progress (episode cursor, eval count, wall-clock, phase timers,
//! best-so-far, reward curve), the environment's RNG stream, and an
//! opaque strategy payload serialised through
//! [`SearchStrategy::save_state`]. Everything travels as exact bit
//! patterns ([`crate::io::bin`]), so `run → suspend → resume` produces
//! the same best solution, reward curve and eval count as an
//! uninterrupted run — the property `rust/tests/search_driver.rs` pins.
//!
//! Files are written atomically (`<path>.tmp` + rename), so a kill mid
//! write leaves the previous checkpoint intact.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::env::{Action, Applied, CompressionEnv, PhaseTimers, Solution};
use crate::io::bin::{BinReader, BinWriter};
use crate::pruning::PruneAlg;

use super::SearchStrategy;

/// File magic ("HAPQSRCH").
pub const MAGIC: &[u8; 8] = b"HAPQSRCH";
/// Format version (3: the phase timers gained `memo_s` — the
/// eval-memoization overhead slot; 2: the header gained the
/// hardware-target name).
pub const VERSION: u32 = 3;

/// Identity of a search run — written into every checkpoint and
/// validated on resume, so a checkpoint can never silently continue a
/// *different* search (other model, method, seed, budget, or hardware
/// target — replay buffers and the best-so-far were priced on one cost
/// surface and must not be continued on another).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CheckpointHeader {
    /// method string (`ours`, `amc`, …)
    pub method: String,
    /// model the search runs on
    pub model: String,
    /// RNG seed of the run
    pub seed: u64,
    /// total episode budget of the run
    pub episodes: usize,
    /// prunable-layer count (episode length)
    pub n_layers: usize,
    /// canonical JSON of the resolved hardware-target profile pricing
    /// the run's cost surface (`--hw`/`--hw-file`) — the full profile
    /// rather than its name, so an edited profile file with an
    /// unchanged name still refuses to resume
    pub hw: String,
}

/// Resumable driver progress — everything the [`super::SearchDriver`]
/// tracks *outside* the strategy.
#[derive(Clone, Debug, Default)]
pub struct SearchProgress {
    /// next episode to run (= episodes already completed)
    pub episode: usize,
    /// reward-oracle invocations consumed so far
    pub evals: u64,
    /// wall-clock seconds consumed by previous sessions
    pub elapsed_secs: f64,
    /// accumulated per-phase step timers (`hapq perf` accounting)
    pub timers: PhaseTimers,
    /// episode-reward curve recorded so far (curve-recording strategies)
    pub curve: Vec<f64>,
    /// best solution found so far
    pub best: Option<Solution>,
}

fn write_action(w: &mut BinWriter, a: &Action) {
    w.f64(a.ratio);
    w.f64(a.bits);
    w.usize(a.alg);
}

fn read_action(r: &mut BinReader) -> Result<Action> {
    Ok(Action { ratio: r.f64()?, bits: r.f64()?, alg: r.usize()? })
}

fn write_applied(w: &mut BinWriter, a: &Applied) {
    w.usize(a.alg.index());
    w.f64(a.sparsity);
    w.u32(a.bits);
    w.bool(a.overridden);
}

fn read_applied(r: &mut BinReader) -> Result<Applied> {
    Ok(Applied {
        alg: PruneAlg::from_index(r.usize()?),
        sparsity: r.f64()?,
        bits: r.u32()?,
        overridden: r.bool()?,
    })
}

/// Serialise one [`Solution`] (all `f64` metrics as exact bit patterns).
pub fn write_solution(w: &mut BinWriter, s: &Solution) {
    w.usize(s.per_layer.len());
    for a in &s.per_layer {
        write_applied(w, a);
    }
    w.usize(s.actions.len());
    for a in &s.actions {
        write_action(w, a);
    }
    w.f64(s.accuracy);
    w.f64(s.acc_loss);
    w.f64(s.energy_gain);
    w.f64(s.latency_gain);
    w.f64(s.reward);
}

/// Deserialise a [`Solution`] written by [`write_solution`].
pub fn read_solution(r: &mut BinReader) -> Result<Solution> {
    let n = r.usize()?;
    let mut per_layer = Vec::with_capacity(n.min(4096));
    for _ in 0..n {
        per_layer.push(read_applied(r)?);
    }
    let n = r.usize()?;
    let mut actions = Vec::with_capacity(n.min(4096));
    for _ in 0..n {
        actions.push(read_action(r)?);
    }
    Ok(Solution {
        per_layer,
        actions,
        accuracy: r.f64()?,
        acc_loss: r.f64()?,
        energy_gain: r.f64()?,
        latency_gain: r.f64()?,
        reward: r.f64()?,
    })
}

fn write_header(w: &mut BinWriter, h: &CheckpointHeader) {
    w.buf.extend_from_slice(MAGIC);
    w.u32(VERSION);
    w.str(&h.method);
    w.str(&h.model);
    w.u64(h.seed);
    w.usize(h.episodes);
    w.usize(h.n_layers);
    w.str(&h.hw);
}

fn read_and_check_header(r: &mut BinReader, expect: &CheckpointHeader) -> Result<()> {
    let mut magic = [0u8; 8];
    for b in magic.iter_mut() {
        *b = r.u8()?;
    }
    if &magic != MAGIC {
        bail!("not a HAPQ search checkpoint (bad magic)");
    }
    let version = r.u32()?;
    if version != VERSION {
        bail!("search checkpoint version {version} unsupported (expected {VERSION})");
    }
    let got = CheckpointHeader {
        method: r.str()?,
        model: r.str()?,
        seed: r.u64()?,
        episodes: r.usize()?,
        n_layers: r.usize()?,
        hw: r.str()?,
    };
    if &got != expect {
        bail!(
            "checkpoint belongs to a different run: saved {got:?}, this run is {expect:?} \
             — pass the matching --model/--method/--seed/--episodes/--hw or delete the file"
        );
    }
    Ok(())
}

/// The method-agnostic search checkpoint: a [`CheckpointHeader`]
/// identifying the run plus everything needed to continue it
/// ([`SearchProgress`], env RNG, strategy payload). The file format is
/// documented in the module docs; [`SearchCheckpoint::save`] and
/// [`SearchCheckpoint::load`] are the only entry points the
/// [`super::SearchDriver`] uses.
pub struct SearchCheckpoint;

impl SearchCheckpoint {
    /// Atomically write a full search checkpoint: header, driver
    /// progress, env RNG stream, and the strategy's opaque state
    /// payload.
    pub fn save(
        path: &Path,
        header: &CheckpointHeader,
        progress: &SearchProgress,
        env: &CompressionEnv,
        strategy: &dyn SearchStrategy,
    ) -> Result<()> {
        save(path, header, progress, env, strategy)
    }

    /// Load a checkpoint written by [`Self::save`]: validates the
    /// header against `expect`, restores the env RNG and the strategy
    /// state in place, and returns the driver progress to continue
    /// from.
    pub fn load(
        path: &Path,
        expect: &CheckpointHeader,
        env: &mut CompressionEnv,
        strategy: &mut dyn SearchStrategy,
    ) -> Result<SearchProgress> {
        load(path, expect, env, strategy)
    }
}

/// Serialise the phase timers field-by-field (`prune`/`quant`/`hw`/
/// `infer` seconds + the step count) — one shared layout for save and
/// load so a resumed run's `hapq perf` totals carry over bit-exactly.
fn write_timers(w: &mut BinWriter, t: &PhaseTimers) {
    w.f64(t.prune_s);
    w.f64(t.quant_s);
    w.f64(t.hw_s);
    w.f64(t.infer_s);
    w.f64(t.memo_s);
    w.u64(t.steps);
}

/// Inverse of [`write_timers`].
fn read_timers(r: &mut BinReader) -> Result<PhaseTimers> {
    Ok(PhaseTimers {
        prune_s: r.f64()?,
        quant_s: r.f64()?,
        hw_s: r.f64()?,
        infer_s: r.f64()?,
        memo_s: r.f64()?,
        steps: r.u64()?,
    })
}

fn save(
    path: &Path,
    header: &CheckpointHeader,
    progress: &SearchProgress,
    env: &CompressionEnv,
    strategy: &dyn SearchStrategy,
) -> Result<()> {
    let mut w = BinWriter::new();
    write_header(&mut w, header);
    w.usize(progress.episode);
    w.u64(progress.evals);
    w.f64(progress.elapsed_secs);
    write_timers(&mut w, &progress.timers);
    w.f64s(&progress.curve);
    match &progress.best {
        Some(sol) => {
            w.bool(true);
            write_solution(&mut w, sol);
        }
        None => w.bool(false),
    }
    env.save_rng(&mut w);
    strategy.save_state(&mut w);

    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating checkpoint dir {dir:?}"))?;
        }
    }
    let file_name = path
        .file_name()
        .and_then(|n| n.to_str())
        .context("checkpoint path has no file name")?;
    let tmp = path.with_file_name(format!("{file_name}.tmp"));
    std::fs::write(&tmp, &w.buf).with_context(|| format!("writing {tmp:?}"))?;
    std::fs::rename(&tmp, path).with_context(|| format!("renaming {tmp:?} -> {path:?}"))?;
    Ok(())
}

fn load(
    path: &Path,
    expect: &CheckpointHeader,
    env: &mut CompressionEnv,
    strategy: &mut dyn SearchStrategy,
) -> Result<SearchProgress> {
    let bytes = std::fs::read(path).with_context(|| format!("reading checkpoint {path:?}"))?;
    let mut r = BinReader::new(&bytes);
    read_and_check_header(&mut r, expect)?;
    let episode = r.usize()?;
    let evals = r.u64()?;
    let elapsed_secs = r.f64()?;
    let timers = read_timers(&mut r)?;
    let curve = r.f64s()?;
    let best = if r.bool()? { Some(read_solution(&mut r)?) } else { None };
    env.restore_rng(&mut r)?;
    strategy
        .load_state(&mut r)
        .context("restoring strategy state from checkpoint")?;
    Ok(SearchProgress { episode, evals, elapsed_secs, timers, curve, best })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timers_roundtrip_is_bit_exact() {
        // every PhaseTimers field — including hw_s, renamed from
        // energy_s — survives save/load bit-exactly, so a resumed run's
        // perf totals continue where the suspended session stopped
        let t = PhaseTimers {
            prune_s: 0.1 + 0.2, // no short decimal form
            quant_s: 1.0 / 3.0,
            hw_s: 7.25e-3,
            infer_s: f64::EPSILON,
            memo_s: 0.7 / 11.0,
            steps: u64::MAX - 7,
        };
        let mut w = BinWriter::new();
        write_timers(&mut w, &t);
        let mut r = BinReader::new(&w.buf);
        let back = read_timers(&mut r).unwrap();
        assert_eq!(back.prune_s.to_bits(), t.prune_s.to_bits());
        assert_eq!(back.quant_s.to_bits(), t.quant_s.to_bits());
        assert_eq!(back.hw_s.to_bits(), t.hw_s.to_bits());
        assert_eq!(back.infer_s.to_bits(), t.infer_s.to_bits());
        assert_eq!(back.memo_s.to_bits(), t.memo_s.to_bits());
        assert_eq!(back.steps, t.steps);
    }

    #[test]
    fn solution_roundtrip_is_bit_exact() {
        let sol = Solution {
            per_layer: vec![Applied {
                alg: PruneAlg::Bernoulli,
                sparsity: 0.1 + 0.2, // a value with no short decimal form
                bits: 5,
                overridden: true,
            }],
            actions: vec![Action { ratio: 1.0 / 3.0, bits: 0.7, alg: 6 }],
            accuracy: 0.815,
            acc_loss: 0.0851234567890123,
            energy_gain: -0.25,
            latency_gain: f64::EPSILON,
            reward: 7.25e-3,
        };
        let mut w = BinWriter::new();
        write_solution(&mut w, &sol);
        let mut r = BinReader::new(&w.buf);
        let back = read_solution(&mut r).unwrap();
        assert_eq!(back.per_layer.len(), 1);
        assert_eq!(back.per_layer[0].alg, PruneAlg::Bernoulli);
        assert_eq!(back.per_layer[0].sparsity.to_bits(), sol.per_layer[0].sparsity.to_bits());
        assert_eq!(back.actions[0].ratio.to_bits(), sol.actions[0].ratio.to_bits());
        assert_eq!(back.actions[0].alg, 6);
        assert_eq!(back.reward.to_bits(), sol.reward.to_bits());
        assert_eq!(back.latency_gain.to_bits(), sol.latency_gain.to_bits());
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn header_mismatch_is_rejected() {
        let h = CheckpointHeader {
            method: "amc".into(),
            model: "vgg11".into(),
            seed: 42,
            episodes: 100,
            n_layers: 9,
            hw: "eyeriss-64".into(),
        };
        let mut w = BinWriter::new();
        write_header(&mut w, &h);
        let mut ok = BinReader::new(&w.buf);
        assert!(read_and_check_header(&mut ok, &h).is_ok());
        let other = CheckpointHeader { seed: 43, ..h.clone() };
        let mut bad = BinReader::new(&w.buf);
        assert!(read_and_check_header(&mut bad, &other).is_err());
        // a checkpoint priced on one hardware target must refuse to
        // continue on another (mixed cost surfaces)
        let other_hw = CheckpointHeader { hw: "mcu".into(), ..h.clone() };
        let mut bad_hw = BinReader::new(&w.buf);
        assert!(read_and_check_header(&mut bad_hw, &other_hw).is_err());
        let mut not_magic = BinReader::new(b"NOTMAGIC rest");
        assert!(read_and_check_header(&mut not_magic, &h).is_err());
    }
}
