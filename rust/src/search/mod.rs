//! The unified search subsystem: one loop for *every* method.
//!
//! The paper evaluates its composite RL agent against five baselines
//! (AMC, HAQ, ASQJ, OPQ, NSGA-II) under identical budgets — yet the
//! seed code hand-rolled six episode loops with six divergent flavours
//! of eval accounting and best-solution tracking. This module is the
//! seam that collapses them:
//!
//! * [`SearchStrategy`] — the method interface over
//!   [`CompressionEnv`]: `propose` an [`Action`] for the current layer,
//!   `observe` the step result, get the finished episode's [`Solution`]
//!   in `end_episode`. Implemented by the composite agent
//!   ([`crate::rl::composite::CompositeStrategy`]) and all five
//!   baselines (`crate::baselines::*`).
//! * [`SearchDriver`] — the single owner of the episode loop: budget
//!   enforcement, best-solution selection via
//!   [`crate::baselines::better`], reward-curve recording, progress
//!   lines, wall-clock + [`crate::env::PhaseTimers`] aggregation across
//!   sessions, periodic [`checkpoint`]ing with atomic writes,
//!   `--resume` restore, and cooperative suspension (`--stop-after`).
//!
//! The driver replays the byte-exact control flow of the pre-refactor
//! loops — same env calls, same RNG draw order — so fixed-seed results
//! are bit-identical to the historical behaviour
//! (`rust/tests/search_driver.rs` pins this against golden reference
//! loops). Multi-seed fan-out (`--seeds N`) sits one level up, in
//! [`crate::coordinator::launcher`], which runs one driver per seed in
//! the worker pool and merges the reports.

pub mod archive;
pub mod checkpoint;

use std::path::PathBuf;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::env::{Action, CompressionEnv, Solution, StepResult};
use crate::io::bin::{BinReader, BinWriter};

use checkpoint::{CheckpointHeader, SearchProgress};

/// A search method driven by the [`SearchDriver`]: proposes actions,
/// observes transitions, and updates itself between episodes.
///
/// Contract (what makes driver runs bit-identical to the historical
/// hand-rolled loops): the driver calls, per episode,
/// `begin_episode(ep)` → `env.reset()` → for each layer `t`:
/// `propose(t, state)` → `env.step` → `observe` → then
/// `end_episode(ep, total, solution)`. Strategies must confine their
/// RNG use to these hooks in the order the original loops drew samples.
pub trait SearchStrategy {
    /// Method name recorded in reports and checkpoints (`ours`, `amc`…).
    fn method(&self) -> &str;

    /// Total episode budget this strategy wants from the driver.
    fn episodes(&self) -> usize;

    /// Hook before `env.reset()` of episode `ep` (config-per-episode
    /// strategies materialise their candidate here).
    fn begin_episode(&mut self, _ep: usize) {}

    /// The action for layer `t` given the current state embedding.
    fn propose(&mut self, t: usize, state: &[f32]) -> Action;

    /// Candidate actions to batch-price against the oracle *before*
    /// [`Self::propose`] is called for layer `t` (the batched-oracle
    /// hook: the driver prices them in one
    /// [`CompressionEnv::price_candidates`] call and reports the
    /// rewards via [`Self::observe_candidates`]). `None` or an empty
    /// vec skips pricing entirely — the default, which leaves every
    /// existing strategy's env call sequence byte-identical to the
    /// historical loops (pricing never mutates episode state, so
    /// opting in preserves golden parity of the steps themselves).
    fn propose_candidates(&mut self, _t: usize, _state: &[f32]) -> Option<Vec<Action>> {
        None
    }

    /// Receive the LUT rewards the candidates from
    /// [`Self::propose_candidates`] would earn (same order). Called
    /// before [`Self::propose`] for the same layer.
    fn observe_candidates(&mut self, _t: usize, _cands: &[Action], _rewards: &[f64]) {}

    /// Observe one env transition (`s` is the pre-step state, `action`
    /// what [`Self::propose`] returned). RL strategies store and learn
    /// here; analytic strategies ignore it.
    fn observe(&mut self, _s: &[f32], _action: &Action, _step: &StepResult) {}

    /// Episode `ep` finished with summed reward `total` and `sol` as
    /// the episode's final configuration.
    fn end_episode(&mut self, _ep: usize, _total: f64, _sol: &Solution) {}

    /// Does the method end with a greedy policy-extraction rollout
    /// (composite agent only)?
    fn wants_greedy_rollout(&self) -> bool {
        false
    }

    /// Greedy (no-exploration) action for the final rollout. Only
    /// called when [`Self::wants_greedy_rollout`] is true.
    fn propose_greedy(&mut self, state: &[f32]) -> Action {
        let _ = state;
        unreachable!("strategy has no greedy rollout")
    }

    /// Extra text appended to the driver's progress line (e.g. the
    /// composite agent's `rainbow=` unlock flag).
    fn progress_note(&self) -> String {
        String::new()
    }

    /// Should the driver record the per-episode reward curve? (The
    /// paper plots it for `ours` only.)
    fn records_curve(&self) -> bool {
        false
    }

    /// Serialise the complete mutable strategy state (bit-exact) into a
    /// [`checkpoint::SearchProgress`]-carrying checkpoint.
    fn save_state(&self, w: &mut BinWriter);

    /// Restore state written by [`Self::save_state`] into a
    /// same-config strategy.
    fn load_state(&mut self, r: &mut BinReader) -> Result<()>;
}

/// Driver knobs (all threaded from `RunConfig`/CLI by the coordinator).
#[derive(Clone, Debug)]
pub struct DriverConfig {
    /// model label for progress lines + checkpoint validation
    pub model: String,
    /// run seed, recorded in checkpoints for validation
    pub seed: u64,
    /// print per-episode progress lines (every 10 episodes + last)
    pub progress: bool,
    /// periodic-checkpoint file; `None` disables checkpointing
    pub checkpoint: Option<PathBuf>,
    /// episodes between periodic checkpoints (0 = only on suspension)
    pub checkpoint_every: usize,
    /// restore from `checkpoint` if the file exists before running
    pub resume: bool,
    /// suspend (checkpoint + return) after this many episodes have run
    /// in *this session* — cooperative preemption for `--stop-after`
    pub stop_after: Option<usize>,
}

impl Default for DriverConfig {
    fn default() -> Self {
        DriverConfig {
            model: String::new(),
            seed: 0,
            progress: false,
            checkpoint: None,
            checkpoint_every: 25,
            resume: false,
            stop_after: None,
        }
    }
}

/// What a driver run produced.
#[derive(Clone, Debug)]
pub struct SearchOutcome {
    /// best solution over all episodes (+ greedy rollout when the
    /// strategy has one); `None` only if zero episodes ran
    pub best: Option<Solution>,
    /// per-episode reward curve (strategies with `records_curve`)
    pub curve: Vec<f64>,
    /// episodes completed in total (across resumed sessions)
    pub episodes_run: usize,
    /// reward-oracle invocations consumed in total
    pub evals: u64,
    /// wall-clock seconds in total (previous sessions + this one)
    pub wall_secs: f64,
    /// true when the run was suspended by `stop_after` (state is in the
    /// checkpoint; re-run with `resume` to continue)
    pub suspended: bool,
}

/// The unified search loop — see the module docs.
#[derive(Clone, Debug, Default)]
pub struct SearchDriver {
    /// driver configuration
    pub cfg: DriverConfig,
}

impl SearchDriver {
    /// Driver with explicit configuration.
    pub fn new(cfg: DriverConfig) -> SearchDriver {
        SearchDriver { cfg }
    }

    /// Bare driver: no progress, no checkpointing — the configuration
    /// the in-process `baselines::*::run` wrappers use.
    pub fn plain() -> SearchDriver {
        SearchDriver::default()
    }

    fn header(&self, strategy: &dyn SearchStrategy, env: &CompressionEnv) -> CheckpointHeader {
        CheckpointHeader {
            method: strategy.method().to_string(),
            model: self.cfg.model.clone(),
            seed: self.cfg.seed,
            episodes: strategy.episodes(),
            n_layers: env.n_layers(),
            // the full resolved profile, not just the name: an edited
            // --hw-file with an unchanged name is a different cost
            // surface and must not resume
            hw: env.cost.model().target.to_json().to_string(),
        }
    }

    /// Run the strategy to completion (or suspension) against `env`.
    pub fn run(
        &self,
        env: &mut CompressionEnv,
        strategy: &mut dyn SearchStrategy,
    ) -> Result<SearchOutcome> {
        let episodes = strategy.episodes();
        let t0 = Instant::now();
        let header = self.header(strategy, env);
        let mut start_ep = 0usize;
        let mut elapsed_offset = 0.0f64;
        let mut best: Option<Solution> = None;
        let mut curve: Vec<f64> = Vec::new();

        if let Some(path) = &self.cfg.checkpoint {
            // never clobber state this run does not own: a pre-existing
            // file is either a suspended run (the user wants --resume)
            // or another run's checkpoint (which resume would reject) —
            // both deserve an explicit decision, not a silent overwrite
            if path.exists() && !self.cfg.resume {
                bail!(
                    "checkpoint {} already exists; pass --resume to continue it, \
                     or delete the file to start this search from scratch",
                    path.display()
                );
            }
        }
        if self.cfg.resume {
            let Some(path) = &self.cfg.checkpoint else {
                bail!("resume requested but no checkpoint path configured");
            };
            if path.exists() {
                let p = checkpoint::SearchCheckpoint::load(path, &header, env, strategy)?;
                start_ep = p.episode;
                elapsed_offset = p.elapsed_secs;
                env.n_evals = p.evals;
                env.timers = p.timers;
                best = p.best;
                curve = p.curve;
                if self.cfg.progress {
                    eprintln!(
                        "[{}] resumed {} at episode {start_ep}/{episodes} from {}",
                        self.cfg.model,
                        header.method,
                        path.display()
                    );
                }
            }
        }

        let mut this_session = 0usize;
        for ep in start_ep..episodes {
            if let Some(stop) = self.cfg.stop_after {
                if this_session >= stop {
                    let Some(path) = &self.cfg.checkpoint else {
                        bail!("stop-after requested but no checkpoint path configured");
                    };
                    let progress = SearchProgress {
                        episode: ep,
                        evals: env.n_evals,
                        elapsed_secs: elapsed_offset + t0.elapsed().as_secs_f64(),
                        timers: env.timers,
                        curve: curve.clone(),
                        best: best.clone(),
                    };
                    checkpoint::SearchCheckpoint::save(path, &header, &progress, env, strategy)?;
                    if self.cfg.progress {
                        eprintln!(
                            "[{}] suspended {} at episode {ep}/{episodes} -> {}",
                            self.cfg.model,
                            header.method,
                            path.display()
                        );
                    }
                    return Ok(SearchOutcome {
                        best,
                        curve,
                        episodes_run: ep,
                        evals: env.n_evals,
                        wall_secs: progress.elapsed_secs,
                        suspended: true,
                    });
                }
            }

            // --- one episode: the exact pre-refactor loop shape ---
            strategy.begin_episode(ep);
            let mut state = env.reset();
            let mut total = 0.0f64;
            let mut t = 0usize;
            #[allow(unused_assignments)]
            let mut last = None;
            loop {
                // batched-oracle hook: price the strategy's proposal
                // batch (if any) before it commits to an action —
                // pricing leaves the episode bit-identical, so the
                // default (no candidates) changes nothing
                if let Some(cands) = strategy.propose_candidates(t, &state) {
                    if !cands.is_empty() {
                        let rewards = env.price_candidates(&cands)?;
                        strategy.observe_candidates(t, &cands, &rewards);
                    }
                }
                let action = strategy.propose(t, &state);
                let step = env.step(action)?;
                strategy.observe(&state, &action, &step);
                crate::telemetry::step_event(
                    ep,
                    t,
                    step.reward,
                    step.accuracy,
                    step.energy_gain,
                );
                total += step.reward;
                state = step.state.clone();
                t += 1;
                let done = step.done;
                last = Some(step);
                if done {
                    break;
                }
            }
            let sol = env.solution(last.as_ref().unwrap());
            strategy.end_episode(ep, total, &sol);
            crate::telemetry::episode_event(
                ep,
                total,
                sol.acc_loss,
                sol.energy_gain,
                env.n_evals as u64,
            );
            if strategy.records_curve() {
                curve.push(total);
            }
            if self.cfg.progress && (ep % 10 == 0 || ep + 1 == episodes) {
                let note = strategy.progress_note();
                let model = &self.cfg.model;
                if note.is_empty() {
                    eprintln!(
                        "[{model}] ep {ep:4}  reward {total:7.2}  loss {:.3}  gain {:.3}",
                        sol.acc_loss, sol.energy_gain
                    );
                } else {
                    eprintln!(
                        "[{model}] ep {ep:4}  reward {total:7.2}  loss {:.3}  gain {:.3}  {note}",
                        sol.acc_loss, sol.energy_gain
                    );
                }
            }
            best = crate::baselines::better(best, sol);
            this_session += 1;

            if let Some(path) = &self.cfg.checkpoint {
                if self.cfg.checkpoint_every > 0
                    && (ep + 1) % self.cfg.checkpoint_every == 0
                    && ep + 1 < episodes
                {
                    let progress = SearchProgress {
                        episode: ep + 1,
                        evals: env.n_evals,
                        elapsed_secs: elapsed_offset + t0.elapsed().as_secs_f64(),
                        timers: env.timers,
                        curve: curve.clone(),
                        best: best.clone(),
                    };
                    checkpoint::SearchCheckpoint::save(path, &header, &progress, env, strategy)?;
                }
            }
        }

        // final greedy policy-extraction rollout (composite agent only)
        if strategy.wants_greedy_rollout() {
            let mut state = env.reset();
            #[allow(unused_assignments)]
            let mut last = None;
            loop {
                let action = strategy.propose_greedy(&state);
                let step = env.step(action)?;
                state = step.state.clone();
                let done = step.done;
                last = Some(step);
                if done {
                    break;
                }
            }
            let greedy = env.solution(last.as_ref().unwrap());
            best = crate::baselines::better(best, greedy);
        }

        // completed: a stale checkpoint would re-run the tail on the next
        // --resume, so tidy it away
        if let Some(path) = &self.cfg.checkpoint {
            if path.exists() {
                let _ = std::fs::remove_file(path);
            }
        }

        Ok(SearchOutcome {
            best,
            curve,
            episodes_run: episodes,
            evals: env.n_evals,
            wall_secs: elapsed_offset + t0.elapsed().as_secs_f64(),
            suspended: false,
        })
    }
}
