//! Persistent cross-target Pareto archive — the seam that makes
//! multi-seed / multi-target searches cumulative instead of throwaway.
//!
//! Every finished run (all six methods, every `--seed`, every `--hw`
//! target) feeds one [`ParetoArchive`] at `<out>/pareto.json`:
//! [`crate::coordinator::Coordinator::save_report`] records the
//! single-process runs, and the launcher folds worker reports into the
//! leader's archive in deterministic (model, method, hw, seed) order
//! after every fan-out, so `--jobs`/`--seeds` sweeps produce the same
//! archive bytes as the equivalent sequential runs. `hapq pareto`
//! queries it ("best config under 1.2% accuracy loss on mcu"), prints
//! front tables extending `hapq hw`'s cross-target comparison, and
//! exports fronts as JSON.
//!
//! Entries are keyed by **model fingerprint × hardware target**: the
//! fingerprint ([`model_fingerprint`]) hashes the dense weight bits, so
//! retrained artifacts under the same model name never pollute each
//! other's fronts, and dominance is only ever judged between runs that
//! compressed the same network for the same target. Within a group the
//! archive keeps exactly the non-dominated set under the paper's three
//! objectives — minimise `[acc_loss, -energy_gain, -latency_gain]` —
//! reusing [`crate::baselines::nsga2::dominates`] verbatim, so archive
//! contents always equal front 0 of
//! [`crate::baselines::nsga2::nondominated_sort`] over everything ever
//! inserted (`rust/tests/pareto_archive.rs` pins this, along with
//! insertion-order independence).
//!
//! Persistence uses the checkpoint discipline
//! (`search/checkpoint.rs`): write `<path>.tmp`, then atomically
//! rename. The file holds only the canonically sorted entries — no
//! session counters — so its bytes are a pure function of the entry
//! *set*, never of insertion order or fan-out interleaving. Session
//! counters (insert/evict/dominated/duplicate) live in the
//! [`MetricsRegistry`] and the trace stream instead. Concurrent
//! workers sharing one out directory may transiently lose each other's
//! in-place updates (last rename wins); the launcher's post-sweep fold
//! re-inserts every report, which makes the leader's archive
//! authoritative and self-healing.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::baselines::nsga2::{dominates, nondominated_sort};
use crate::io::json::{self, arr, num, obj, s, Value};
use crate::telemetry::{self, MetricsRegistry, MetricsSource};

/// Archive-file schema version (the JSON `schema` field).
pub const SCHEMA: u64 = 1;

/// The `kind` tag of the archive file.
pub const KIND: &str = "hapq-pareto-archive";

/// Conventional archive file name inside an output directory.
pub const ARCHIVE_FILE: &str = "pareto.json";

/// One per-layer compression decision of an archived solution.
#[derive(Clone, Debug, PartialEq)]
pub struct PerLayerPolicy {
    /// pruning algorithm name (`l2-norm`, `sensitivity`, …)
    pub alg: String,
    /// achieved weight sparsity
    pub sparsity: f64,
    /// applied precision (weights & activations)
    pub bits: u32,
}

/// One archived solution: identity, objectives, and the per-layer
/// policy needed to reproduce it.
#[derive(Clone, Debug, PartialEq)]
pub struct ArchiveEntry {
    /// model name (`vgg11`, …)
    pub model: String,
    /// dense-weight fingerprint ([`model_fingerprint`], 16 hex chars)
    pub fingerprint: String,
    /// hardware target the run was priced against
    pub hw: String,
    /// method that produced the solution (`ours`, `amc`, …)
    pub method: String,
    /// RNG seed of the producing run
    pub seed: u64,
    /// compressed-model accuracy on the test split
    pub test_acc: f64,
    /// accuracy loss vs the dense baseline on the test split (fraction;
    /// the archive's primary objective)
    pub acc_loss: f64,
    /// accuracy loss on the reward (validation) subset
    pub val_acc_loss: f64,
    /// energy gain vs the dense baseline (fraction)
    pub energy_gain: f64,
    /// latency gain vs the dense baseline (fraction)
    pub latency_gain: f64,
    /// final LUT reward of the solution
    pub reward: f64,
    /// the per-layer policy
    pub per_layer: Vec<PerLayerPolicy>,
}

impl ArchiveEntry {
    /// The minimisation objectives dominance is judged on:
    /// `[acc_loss, -energy_gain, -latency_gain]`.
    pub fn objectives(&self) -> Vec<f64> {
        vec![self.acc_loss, -self.energy_gain, -self.latency_gain]
    }

    /// True when every objective (and the reward) is finite — the
    /// archive refuses non-finite entries outright.
    pub fn is_finite(&self) -> bool {
        self.acc_loss.is_finite()
            && self.energy_gain.is_finite()
            && self.latency_gain.is_finite()
            && self.reward.is_finite()
    }

    /// Same dominance group: model fingerprint × hardware target (the
    /// model name rides along for readability and sorting).
    pub fn same_group(&self, other: &ArchiveEntry) -> bool {
        self.model == other.model
            && self.fingerprint == other.fingerprint
            && self.hw == other.hw
    }

    /// Build an entry from a run-report JSON document
    /// ([`crate::coordinator::RunReport::to_json`] schema).
    pub fn from_report(v: &Value) -> Result<ArchiveEntry> {
        let mut per_layer = Vec::new();
        for l in v.req("per_layer")?.as_arr()? {
            per_layer.push(PerLayerPolicy {
                alg: l.req("alg")?.as_str()?.to_string(),
                sparsity: l.req("sparsity")?.as_f64()?,
                bits: l.req("bits")?.as_usize()? as u32,
            });
        }
        let e = ArchiveEntry {
            model: v.req("model")?.as_str()?.to_string(),
            fingerprint: v.req("fingerprint")?.as_str()?.to_string(),
            hw: v.req("hw")?.as_str()?.to_string(),
            method: v.req("method")?.as_str()?.to_string(),
            seed: v.req("seed")?.as_f64()? as u64,
            test_acc: v.req("test_acc")?.as_f64()?,
            acc_loss: v.req("test_acc_loss")?.as_f64()?,
            val_acc_loss: v.req("val_acc_loss")?.as_f64()?,
            energy_gain: v.req("energy_gain")?.as_f64()?,
            latency_gain: v.req("latency_gain")?.as_f64()?,
            reward: v.req("reward")?.as_f64()?,
            per_layer,
        };
        Ok(e)
    }

    /// Serialise one entry (fixed key order, diff-friendly).
    pub fn to_json(&self) -> Value {
        let layers: Vec<Value> = self
            .per_layer
            .iter()
            .map(|l| {
                obj(vec![
                    ("alg", s(&l.alg)),
                    ("sparsity", num(l.sparsity)),
                    ("bits", num(l.bits as f64)),
                ])
            })
            .collect();
        obj(vec![
            ("model", s(&self.model)),
            ("fingerprint", s(&self.fingerprint)),
            ("hw", s(&self.hw)),
            ("method", s(&self.method)),
            ("seed", num(self.seed as f64)),
            ("test_acc", num(self.test_acc)),
            ("acc_loss", num(self.acc_loss)),
            ("val_acc_loss", num(self.val_acc_loss)),
            ("energy_gain", num(self.energy_gain)),
            ("latency_gain", num(self.latency_gain)),
            ("reward", num(self.reward)),
            ("per_layer", arr(layers)),
        ])
    }

    /// Parse one entry back from its [`Self::to_json`] form.
    pub fn from_json(v: &Value) -> Result<ArchiveEntry> {
        let mut per_layer = Vec::new();
        for l in v.req("per_layer")?.as_arr()? {
            per_layer.push(PerLayerPolicy {
                alg: l.req("alg")?.as_str()?.to_string(),
                sparsity: l.req("sparsity")?.as_f64()?,
                bits: l.req("bits")?.as_usize()? as u32,
            });
        }
        Ok(ArchiveEntry {
            model: v.req("model")?.as_str()?.to_string(),
            fingerprint: v.req("fingerprint")?.as_str()?.to_string(),
            hw: v.req("hw")?.as_str()?.to_string(),
            method: v.req("method")?.as_str()?.to_string(),
            seed: v.req("seed")?.as_f64()? as u64,
            test_acc: v.req("test_acc")?.as_f64()?,
            acc_loss: v.req("acc_loss")?.as_f64()?,
            val_acc_loss: v.req("val_acc_loss")?.as_f64()?,
            energy_gain: v.req("energy_gain")?.as_f64()?,
            latency_gain: v.req("latency_gain")?.as_f64()?,
            reward: v.req("reward")?.as_f64()?,
            per_layer,
        })
    }
}

/// Canonical archive order: a pure function of the entry set (never of
/// insertion order), so serialised archives are byte-stable across
/// `--jobs`/`--seeds` fan-out vs sequential runs.
fn canonical_cmp(a: &ArchiveEntry, b: &ArchiveEntry) -> std::cmp::Ordering {
    a.model
        .cmp(&b.model)
        .then_with(|| a.fingerprint.cmp(&b.fingerprint))
        .then_with(|| a.hw.cmp(&b.hw))
        .then_with(|| a.acc_loss.total_cmp(&b.acc_loss))
        .then_with(|| b.energy_gain.total_cmp(&a.energy_gain))
        .then_with(|| b.latency_gain.total_cmp(&a.latency_gain))
        .then_with(|| a.method.cmp(&b.method))
        .then_with(|| a.seed.cmp(&b.seed))
        .then_with(|| b.reward.total_cmp(&a.reward))
}

/// What happened to an inserted candidate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InsertOutcome {
    /// the candidate joined the front, evicting `evicted` entries it
    /// now dominates
    Inserted {
        /// entries the candidate evicted from its group
        evicted: usize,
    },
    /// an existing entry in the candidate's group dominates it
    Dominated,
    /// an identical entry is already archived (idempotent re-fold)
    Duplicate,
}

/// Which gain a constrained `hapq pareto` query maximises.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueryMetric {
    /// maximise `energy_gain`
    Energy,
    /// maximise `latency_gain`
    Latency,
}

impl QueryMetric {
    /// Parse a `--metric` value.
    pub fn parse(v: &str) -> Result<QueryMetric> {
        match v {
            "energy" => Ok(QueryMetric::Energy),
            "latency" => Ok(QueryMetric::Latency),
            other => bail!("--metric expects `energy` or `latency`, got `{other}`"),
        }
    }

    /// CLI name.
    pub fn name(self) -> &'static str {
        match self {
            QueryMetric::Energy => "energy",
            QueryMetric::Latency => "latency",
        }
    }

    /// The gain this metric reads off an entry.
    pub fn gain(self, e: &ArchiveEntry) -> f64 {
        match self {
            QueryMetric::Energy => e.energy_gain,
            QueryMetric::Latency => e.latency_gain,
        }
    }
}

/// The persistent non-dominated archive (see the module docs).
#[derive(Debug, Default)]
pub struct ParetoArchive {
    entries: Vec<ArchiveEntry>,
    /// entries that joined the front this session
    pub inserted: u64,
    /// entries evicted by a dominating insert this session
    pub evicted: u64,
    /// candidates rejected as dominated this session
    pub dominated: u64,
    /// exact re-inserts answered from the archive this session
    pub duplicates: u64,
}

impl ParetoArchive {
    /// An empty archive.
    pub fn new() -> ParetoArchive {
        ParetoArchive::default()
    }

    /// Load an archive file; a missing file is an empty archive.
    pub fn load(path: &Path) -> Result<ParetoArchive> {
        if !path.exists() {
            return Ok(ParetoArchive::new());
        }
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading archive {path:?}"))?;
        let v = json::parse(&text).with_context(|| format!("parsing archive {path:?}"))?;
        let kind = v.req("kind")?.as_str()?;
        if kind != KIND {
            bail!("{path:?} is not a pareto archive (kind `{kind}`)");
        }
        let schema = v.req("schema")?.as_f64()? as u64;
        if schema != SCHEMA {
            bail!("archive {path:?} has schema {schema}, this build reads {SCHEMA}");
        }
        let mut a = ParetoArchive::new();
        for e in v.req("entries")?.as_arr()? {
            a.entries.push(ArchiveEntry::from_json(e)?);
        }
        Ok(a)
    }

    /// The archived entries (canonical order after `load`/`save`;
    /// otherwise insertion order).
    pub fn entries(&self) -> &[ArchiveEntry] {
        &self.entries
    }

    /// Insert one candidate, keeping the per-group non-dominated
    /// invariant. Equal-objective candidates from different runs are
    /// all kept (equal vectors never dominate each other), which is
    /// exactly what makes the surviving set independent of insertion
    /// order. Non-finite objectives are refused.
    pub fn insert(&mut self, e: ArchiveEntry) -> Result<InsertOutcome> {
        if !e.is_finite() {
            bail!(
                "refusing non-finite archive entry for {}/{} on {} (seed {}): \
                 acc_loss={} energy_gain={} latency_gain={} reward={}",
                e.model, e.method, e.hw, e.seed,
                e.acc_loss, e.energy_gain, e.latency_gain, e.reward
            );
        }
        if self.entries.iter().any(|x| x == &e) {
            self.duplicates += 1;
            telemetry::count("archive.duplicate", 1);
            return Ok(InsertOutcome::Duplicate);
        }
        let eo = e.objectives();
        if self
            .entries
            .iter()
            .any(|x| x.same_group(&e) && dominates(&x.objectives(), &eo))
        {
            self.dominated += 1;
            telemetry::count("archive.dominated", 1);
            return Ok(InsertOutcome::Dominated);
        }
        let before = self.entries.len();
        self.entries
            .retain(|x| !(x.same_group(&e) && dominates(&eo, &x.objectives())));
        let evicted = before - self.entries.len();
        self.entries.push(e);
        self.inserted += 1;
        self.evicted += evicted as u64;
        telemetry::count("archive.insert", 1);
        if evicted > 0 {
            telemetry::count("archive.evict", evicted as u64);
        }
        Ok(InsertOutcome::Inserted { evicted })
    }

    /// Serialise the whole archive (canonically sorted entries).
    pub fn to_json(&self) -> Value {
        let mut sorted: Vec<&ArchiveEntry> = self.entries.iter().collect();
        sorted.sort_by(|a, b| canonical_cmp(a, b));
        obj(vec![
            ("schema", num(SCHEMA as f64)),
            ("kind", s(KIND)),
            ("entries", arr(sorted.iter().map(|e| e.to_json()).collect())),
        ])
    }

    /// Atomically persist the archive (`<path>.tmp` + rename, the
    /// checkpoint discipline) and leave `self.entries` in canonical
    /// order.
    pub fn save(&mut self, path: &Path) -> Result<()> {
        self.entries.sort_by(canonical_cmp);
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating archive dir {dir:?}"))?;
            }
        }
        let file_name = path
            .file_name()
            .and_then(|n| n.to_str())
            .context("archive path has no file name")?;
        let tmp = path.with_file_name(format!("{file_name}.tmp"));
        std::fs::write(&tmp, self.to_json().to_string())
            .with_context(|| format!("writing {tmp:?}"))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming {tmp:?} -> {path:?}"))?;
        Ok(())
    }

    /// Sorted distinct (model, fingerprint, hw) groups.
    pub fn groups(&self) -> Vec<(String, String, String)> {
        let mut g: Vec<(String, String, String)> = self
            .entries
            .iter()
            .map(|e| (e.model.clone(), e.fingerprint.clone(), e.hw.clone()))
            .collect();
        g.sort();
        g.dedup();
        g
    }

    /// Entries matching the filters, in canonical order. `cap` keeps
    /// only entries with `acc_loss <= cap`.
    pub fn front(
        &self,
        model: Option<&str>,
        hw: Option<&str>,
        cap: Option<f64>,
    ) -> Vec<&ArchiveEntry> {
        let mut v: Vec<&ArchiveEntry> = self
            .entries
            .iter()
            .filter(|e| model.map_or(true, |m| e.model == m))
            .filter(|e| hw.map_or(true, |h| e.hw == h))
            .filter(|e| cap.map_or(true, |c| e.acc_loss <= c))
            .collect();
        v.sort_by(|a, b| canonical_cmp(a, b));
        v
    }

    /// Best entry maximising `metric`'s gain subject to
    /// `acc_loss <= cap`, with deterministic canonical tie-breaks.
    pub fn query(
        &self,
        model: Option<&str>,
        hw: Option<&str>,
        cap: f64,
        metric: QueryMetric,
    ) -> Option<&ArchiveEntry> {
        let mut v = self.front(model, hw, Some(cap));
        v.sort_by(|a, b| {
            metric
                .gain(b)
                .total_cmp(&metric.gain(a))
                .then_with(|| canonical_cmp(a, b))
        });
        v.into_iter().next()
    }
}

impl MetricsSource for ParetoArchive {
    fn record(&self, reg: &mut MetricsRegistry) {
        reg.counter("archive.inserted", self.inserted);
        reg.counter("archive.evicted", self.evicted);
        reg.counter("archive.dominated", self.dominated);
        reg.counter("archive.duplicates", self.duplicates);
        reg.gauge("archive.entries", self.entries.len() as f64);
        reg.gauge("archive.groups", self.groups().len() as f64);
    }
}

/// Fold one run-report JSON into the archive at `path`
/// (load → insert → save; the file is only rewritten when the front
/// actually changed).
pub fn record_report(path: &Path, report: &Value) -> Result<InsertOutcome> {
    Ok(record_reports(path, std::slice::from_ref(report))?[0])
}

/// Fold a batch of run-report JSONs into the archive at `path` with a
/// single load/save round-trip. Callers pass reports in a
/// deterministic order (the launcher sorts by model/method/hw/seed);
/// the resulting file bytes are order-independent regardless.
pub fn record_reports(path: &Path, reports: &[&Value]) -> Result<Vec<InsertOutcome>> {
    let mut a = ParetoArchive::load(path)?;
    let mut outcomes = Vec::with_capacity(reports.len());
    let mut changed = false;
    for r in reports {
        let out = a.insert(ArchiveEntry::from_report(r)?)?;
        changed |= matches!(out, InsertOutcome::Inserted { .. });
        outcomes.push(out);
    }
    if changed {
        a.save(path)?;
    }
    Ok(outcomes)
}

/// FNV-1a fingerprint of a model's dense weights (the archive's group
/// key, 16 lowercase hex chars): hashes every weight tensor's f32 bit
/// pattern in prunable order, so two artifacts agree iff their dense
/// weights are bit-identical. Same construction as
/// [`crate::quant::config_fingerprint`], widened to the whole network.
pub fn model_fingerprint(w: &crate::model::Weights) -> String {
    const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = FNV_OFFSET;
    for t in &w.w {
        for v in &t.data {
            h = (h ^ v.to_bits() as u64).wrapping_mul(FNV_PRIME);
        }
    }
    format!("{h:016x}")
}

/// Check `[acc_loss, -energy_gain, -latency_gain]` front membership of
/// every archived entry against [`nondominated_sort`] — the
/// archive-invariant assertion the determinism tests use.
pub fn agrees_with_nondominated_sort(a: &ParetoArchive) -> bool {
    for (model, fp, hw) in a.groups() {
        let group: Vec<&ArchiveEntry> = a
            .entries()
            .iter()
            .filter(|e| e.model == model && e.fingerprint == fp && e.hw == hw)
            .collect();
        let objs: Vec<Vec<f64>> = group.iter().map(|e| e.objectives()).collect();
        if nondominated_sort(&objs).iter().any(|&f| f != 0) {
            return false;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(method: &str, seed: u64, loss: f64, eg: f64, lg: f64) -> ArchiveEntry {
        ArchiveEntry {
            model: "m".into(),
            fingerprint: "00000000000000aa".into(),
            hw: "eyeriss-64".into(),
            method: method.into(),
            seed,
            test_acc: 0.9 - loss,
            acc_loss: loss,
            val_acc_loss: loss * 0.9,
            energy_gain: eg,
            latency_gain: lg,
            reward: 1.0 + eg,
            per_layer: vec![PerLayerPolicy { alg: "l2-norm".into(), sparsity: 0.5, bits: 6 }],
        }
    }

    #[test]
    fn insert_keeps_nondominated_set_and_counts() {
        let mut a = ParetoArchive::new();
        assert_eq!(
            a.insert(entry("ours", 1, 0.02, 0.5, 0.4)).unwrap(),
            InsertOutcome::Inserted { evicted: 0 }
        );
        // strictly worse on every objective: rejected
        assert_eq!(a.insert(entry("amc", 2, 0.03, 0.4, 0.3)).unwrap(), InsertOutcome::Dominated);
        // trades accuracy for energy: joins the front
        assert_eq!(
            a.insert(entry("haq", 3, 0.01, 0.3, 0.2)).unwrap(),
            InsertOutcome::Inserted { evicted: 0 }
        );
        // dominates the first entry: evicts it
        assert_eq!(
            a.insert(entry("nsga2", 4, 0.015, 0.6, 0.5)).unwrap(),
            InsertOutcome::Inserted { evicted: 1 }
        );
        // exact re-insert is answered from the archive
        assert_eq!(
            a.insert(entry("nsga2", 4, 0.015, 0.6, 0.5)).unwrap(),
            InsertOutcome::Duplicate
        );
        assert_eq!(a.entries().len(), 2);
        assert_eq!((a.inserted, a.evicted, a.dominated, a.duplicates), (3, 1, 1, 1));
        assert!(agrees_with_nondominated_sort(&a));
        let mut reg = MetricsRegistry::new();
        reg.collect(&a);
        let snap = reg.snapshot();
        let counters = snap.req("counters").unwrap();
        assert_eq!(counters.req("archive.inserted").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(counters.req("archive.evicted").unwrap().as_f64().unwrap(), 1.0);
        let gauges = snap.req("gauges").unwrap();
        assert_eq!(gauges.req("archive.entries").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(gauges.req("archive.groups").unwrap().as_f64().unwrap(), 1.0);
    }

    #[test]
    fn dominance_is_scoped_to_the_fingerprint_and_target_group() {
        let mut a = ParetoArchive::new();
        a.insert(entry("ours", 1, 0.02, 0.5, 0.4)).unwrap();
        // same numbers, different target: separate front, kept
        let mut other_hw = entry("ours", 1, 0.03, 0.4, 0.3);
        other_hw.hw = "mcu".into();
        assert_eq!(a.insert(other_hw).unwrap(), InsertOutcome::Inserted { evicted: 0 });
        // dominated numbers but a different dense-weight fingerprint:
        // separate front, kept
        let mut other_fp = entry("ours", 1, 0.03, 0.4, 0.3);
        other_fp.fingerprint = "00000000000000bb".into();
        assert_eq!(a.insert(other_fp).unwrap(), InsertOutcome::Inserted { evicted: 0 });
        assert_eq!(a.groups().len(), 3);
    }

    #[test]
    fn equal_objectives_from_different_runs_all_survive() {
        let mut a = ParetoArchive::new();
        a.insert(entry("ours", 1, 0.02, 0.5, 0.4)).unwrap();
        assert_eq!(
            a.insert(entry("haq", 7, 0.02, 0.5, 0.4)).unwrap(),
            InsertOutcome::Inserted { evicted: 0 }
        );
        assert_eq!(a.entries().len(), 2);
    }

    #[test]
    fn non_finite_entries_are_refused() {
        let mut a = ParetoArchive::new();
        let e = entry("ours", 1, f64::NAN, 0.5, 0.4);
        assert!(a.insert(e).unwrap_err().to_string().contains("non-finite"));
        let e = entry("ours", 1, 0.01, f64::INFINITY, 0.4);
        assert!(a.insert(e).is_err());
        assert!(a.entries().is_empty());
    }

    #[test]
    fn save_load_roundtrip_is_canonical_and_atomic() {
        let dir = std::env::temp_dir().join(format!("hapq-archive-{}", std::process::id()));
        let path = dir.join("pareto.json");
        let mut a = ParetoArchive::new();
        a.insert(entry("haq", 3, 0.01, 0.3, 0.2)).unwrap();
        a.insert(entry("ours", 1, 0.02, 0.5, 0.4)).unwrap();
        a.save(&path).unwrap();
        assert!(!path.with_file_name("pareto.json.tmp").exists());
        let b = ParetoArchive::load(&path).unwrap();
        assert_eq!(b.entries(), a.entries());
        // bytes are a pure function of the set: reversed insertion
        // order serialises identically
        let mut c = ParetoArchive::new();
        c.insert(entry("ours", 1, 0.02, 0.5, 0.4)).unwrap();
        c.insert(entry("haq", 3, 0.01, 0.3, 0.2)).unwrap();
        assert_eq!(c.to_json().to_string(), a.to_json().to_string());
        // a missing file loads as empty; a wrong kind is refused
        assert!(ParetoArchive::load(&dir.join("absent.json")).unwrap().entries().is_empty());
        std::fs::write(dir.join("bad.json"), "{\"kind\":\"other\",\"schema\":1}").unwrap();
        assert!(ParetoArchive::load(&dir.join("bad.json")).is_err());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn entry_json_roundtrips_exactly() {
        let e = entry("ours", 42, 0.0123456789012345, 0.57, 0.41);
        let v = json::parse(&e.to_json().to_string()).unwrap();
        assert_eq!(ArchiveEntry::from_json(&v).unwrap(), e);
    }

    #[test]
    fn query_maximises_gain_under_the_loss_cap() {
        let mut a = ParetoArchive::new();
        a.insert(entry("ours", 1, 0.005, 0.3, 0.5)).unwrap();
        a.insert(entry("haq", 2, 0.012, 0.5, 0.2)).unwrap();
        a.insert(entry("amc", 3, 0.030, 0.7, 0.7)).unwrap();
        // under a 1.2% cap the 3% entry is excluded
        let best = a.query(Some("m"), Some("eyeriss-64"), 0.012, QueryMetric::Energy).unwrap();
        assert_eq!(best.method, "haq");
        let best = a.query(None, None, 0.012, QueryMetric::Latency).unwrap();
        assert_eq!(best.method, "ours");
        // an unsatisfiable cap yields no answer, not a panic
        assert!(a.query(None, None, 0.001, QueryMetric::Energy).is_none());
        // filters restrict the candidate set
        assert!(a.query(Some("other"), None, 1.0, QueryMetric::Energy).is_none());
        assert!(a.query(None, Some("mcu"), 1.0, QueryMetric::Energy).is_none());
    }

    #[test]
    fn record_report_requires_finite_objectives() {
        let dir = std::env::temp_dir().join(format!("hapq-archive-rr-{}", std::process::id()));
        let path = dir.join("pareto.json");
        let mut report = entry("ours", 1, 0.02, 0.5, 0.4).to_json();
        // from_report reads the run-JSON field names
        if let Value::Obj(kv) = &mut report {
            for (k, _) in kv.iter_mut() {
                if k == "acc_loss" {
                    *k = "test_acc_loss".into();
                }
            }
        }
        assert_eq!(record_report(&path, &report).unwrap(), InsertOutcome::Inserted { evicted: 0 });
        assert_eq!(record_report(&path, &report).unwrap(), InsertOutcome::Duplicate);
        let mut bad = report.clone();
        if let Value::Obj(kv) = &mut bad {
            for (k, v) in kv.iter_mut() {
                if k == "reward" {
                    *v = num(f64::NAN);
                }
            }
        }
        assert!(record_report(&path, &bad).is_err());
        let _ = std::fs::remove_dir_all(dir);
    }
}
