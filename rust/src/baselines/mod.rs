//! State-of-the-art comparison baselines (paper §5.2, Fig 7/9, Tab 3/4).
//!
//! All five run against the *same* environment — identical energy
//! model, quantizer, pruning kernels and accuracy oracle (whichever
//! inference backend the run selected) — which is exactly the level
//! playing field the paper's comparison assumes.
//! Per DESIGN.md §1, none of them get their original fine-tuning steps
//! (no retraining exists anywhere in this reproduction), so their
//! accuracy losses are upper bounds; the paper's qualitative ordering
//! is what we reproduce.
//!
//! Every baseline is a [`crate::search::SearchStrategy`] (`AmcStrategy`,
//! `HaqStrategy`, `AsqjStrategy`, `OpqStrategy`, `Nsga2Strategy`) run by
//! the unified [`crate::search::SearchDriver`] — the same loop that runs
//! the composite agent — so step/eval budgets, best-solution selection
//! ([`better`]), wall-clock accounting and `--resume` checkpointing are
//! identical across all six methods. The per-module `run` functions are
//! thin driver wrappers kept for the examples and benches.

pub mod amc;
pub mod asqj;
pub mod haq;
pub mod nsga2;
pub mod opq;

use crate::env::Solution;

/// Pick the better of two candidate solutions under the paper's
/// selection rule: highest reward (the LUT already encodes the
/// loss-bounded preference).
pub fn better(a: Option<Solution>, b: Solution) -> Option<Solution> {
    match a {
        None => Some(b),
        Some(a) if b.reward > a.reward => Some(b),
        keep => keep,
    }
}
