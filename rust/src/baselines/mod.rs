//! State-of-the-art comparison baselines (paper §5.2, Fig 7/9, Tab 3/4).
//!
//! All five run against the *same* environment — identical energy
//! model, quantizer, pruning kernels and accuracy oracle (whichever
//! inference backend the run selected) — which is exactly the level
//! playing field the paper's comparison assumes.
//! Per DESIGN.md §1, none of them get their original fine-tuning steps
//! (no retraining exists anywhere in this reproduction), so their
//! accuracy losses are upper bounds; the paper's qualitative ordering
//! is what we reproduce.

pub mod amc;
pub mod asqj;
pub mod haq;
pub mod nsga2;
pub mod opq;

use crate::env::{CompressionEnv, Solution};

/// Common result record for Fig 7-style reporting.
#[derive(Clone, Debug)]
pub struct BaselineRun {
    /// baseline name
    pub method: &'static str,
    /// best solution found
    pub best: Solution,
    /// reward-oracle invocations consumed (Table 3 accounting)
    pub evals: u64,
    /// wall-clock seconds spent
    pub wall_secs: f64,
}

/// Pick the better of two candidate solutions under the paper's
/// selection rule: highest reward (the LUT already encodes the
/// loss-bounded preference).
pub fn better(a: Option<Solution>, b: Solution) -> Option<Solution> {
    match a {
        None => Some(b),
        Some(a) if b.reward > a.reward => Some(b),
        keep => keep,
    }
}

/// Helper: run a closure and record wall time + eval delta.
pub fn timed<F: FnOnce(&mut CompressionEnv) -> anyhow::Result<Solution>>(
    method: &'static str,
    env: &mut CompressionEnv,
    f: F,
) -> anyhow::Result<BaselineRun> {
    let evals0 = env.n_evals;
    let t0 = std::time::Instant::now();
    let best = f(env)?;
    Ok(BaselineRun {
        method,
        best,
        evals: env.n_evals - evals0,
        wall_secs: t0.elapsed().as_secs_f64(),
    })
}
