//! HAQ baseline [17]: DDPG learns per-layer *mixed precision* only —
//! no pruning. Same hardware-aware feedback loop as our framework.

use anyhow::Result;

use crate::env::{Action, CompressionEnv, Solution};
use crate::rl::ddpg::{Ddpg, DdpgConfig};
use crate::rl::replay::Transition;
use crate::util::rng::Rng;

/// HAQ budget knobs.
pub struct HaqConfig {
    /// DDPG training episodes
    pub episodes: usize,
    /// random-exploration episodes before learning
    pub warmup: usize,
    /// RNG seed
    pub seed: u64,
}

impl Default for HaqConfig {
    fn default() -> Self {
        HaqConfig { episodes: 300, warmup: 30, seed: 0 }
    }
}

/// Run HAQ against the shared environment; returns its best solution.
pub fn run(env: &mut CompressionEnv, cfg: &HaqConfig) -> Result<Solution> {
    let mut agent = Ddpg::new(
        DdpgConfig { action_dim: 1, ..DdpgConfig::default() },
        cfg.seed ^ 0x4A9,
    );
    let mut rng = Rng::new(cfg.seed ^ 0x22);
    let mut best: Option<Solution> = None;
    for ep in 0..cfg.episodes {
        let mut s = env.reset();
        #[allow(unused_assignments)]
        let mut last = None;
        loop {
            let a = if ep < cfg.warmup {
                vec![rng.uniform() as f32]
            } else {
                agent.act(&s, true)
            };
            let action = Action { ratio: 0.0, bits: a[0] as f64, alg: 0 };
            let step = env.step(action)?;
            agent.observe(Transition {
                s: s.clone(),
                a: a.clone(),
                alg: 0,
                r: step.reward as f32,
                s2: step.state.clone(),
                done: step.done,
            });
            agent.update();
            s = step.state.clone();
            let done = step.done;
            last = Some(step);
            if done {
                break;
            }
        }
        if ep >= cfg.warmup {
            agent.decay_noise();
        }
        let sol = env.solution(last.as_ref().unwrap());
        best = super::better(best, sol);
    }
    Ok(best.unwrap())
}
