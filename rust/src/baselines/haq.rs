//! HAQ baseline [17]: DDPG learns per-layer *mixed precision* only —
//! no pruning. Same hardware-aware feedback loop as our framework, run
//! as a [`HaqStrategy`] under the unified
//! [`crate::search::SearchDriver`] loop.

use anyhow::Result;

use crate::env::{Action, CompressionEnv, Solution, StepResult};
use crate::rl::ddpg::{Ddpg, DdpgConfig};
use crate::rl::replay::Transition;
use crate::search::{SearchDriver, SearchStrategy};
use crate::util::rng::Rng;

/// HAQ budget knobs.
pub struct HaqConfig {
    /// DDPG training episodes
    pub episodes: usize,
    /// random-exploration episodes before learning
    pub warmup: usize,
    /// RNG seed
    pub seed: u64,
}

impl Default for HaqConfig {
    fn default() -> Self {
        HaqConfig { episodes: 300, warmup: 30, seed: 0 }
    }
}

/// HAQ as a [`SearchStrategy`]: 1-d DDPG over precision, no pruning.
pub struct HaqStrategy {
    agent: Ddpg,
    rng: Rng,
    episodes: usize,
    warmup: usize,
    ep: usize,
    pending: Vec<f32>,
}

impl HaqStrategy {
    /// Build the strategy exactly as the historical loop seeded it.
    pub fn new(cfg: &HaqConfig) -> HaqStrategy {
        HaqStrategy {
            agent: Ddpg::new(
                DdpgConfig { action_dim: 1, ..DdpgConfig::default() },
                cfg.seed ^ 0x4A9,
            ),
            rng: Rng::new(cfg.seed ^ 0x22),
            episodes: cfg.episodes,
            warmup: cfg.warmup,
            ep: 0,
            pending: Vec::new(),
        }
    }
}

impl SearchStrategy for HaqStrategy {
    fn method(&self) -> &str {
        "haq"
    }

    fn episodes(&self) -> usize {
        self.episodes
    }

    fn begin_episode(&mut self, ep: usize) {
        self.ep = ep;
    }

    fn propose(&mut self, _t: usize, state: &[f32]) -> Action {
        let a = if self.ep < self.warmup {
            vec![self.rng.uniform() as f32]
        } else {
            self.agent.act(state, true)
        };
        let action = Action { ratio: 0.0, bits: a[0] as f64, alg: 0 };
        self.pending = a;
        action
    }

    fn observe(&mut self, s: &[f32], _action: &Action, step: &StepResult) {
        self.agent.observe(Transition {
            s: s.to_vec(),
            a: self.pending.clone(),
            alg: 0,
            r: step.reward as f32,
            s2: step.state.clone(),
            done: step.done,
        });
        self.agent.update();
    }

    fn end_episode(&mut self, ep: usize, _total: f64, _sol: &Solution) {
        if ep >= self.warmup {
            self.agent.decay_noise();
        }
    }

    fn save_state(&self, w: &mut crate::io::bin::BinWriter) {
        self.agent.save_state(w);
        self.rng.save_state(w);
        w.f32s(&self.pending);
    }

    fn load_state(&mut self, r: &mut crate::io::bin::BinReader) -> Result<()> {
        self.agent.load_state(r)?;
        self.rng.load_state(r)?;
        self.pending = r.f32s()?;
        Ok(())
    }
}

/// Run HAQ against the shared environment; returns its best solution.
pub fn run(env: &mut CompressionEnv, cfg: &HaqConfig) -> Result<Solution> {
    let mut strategy = HaqStrategy::new(cfg);
    let outcome = SearchDriver::plain().run(env, &mut strategy)?;
    outcome.best.ok_or_else(|| anyhow::anyhow!("haq ran zero episodes"))
}
