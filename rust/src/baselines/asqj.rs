//! ASQJ baseline [24]: joint sparsity-quantization learning via ADMM.
//!
//! The original alternates gradient steps on the task loss with
//! projections onto the sparse set and the quantization grid. In this
//! no-retraining environment there are no task gradients (DESIGN.md
//! §1), so we keep the ADMM skeleton — alternating projection plus a
//! dual/multiplier update per layer — and replace the loss-gradient
//! primal step with reward feedback from the shared oracle:
//!
//!   * primal-W: project onto the fine-grained sparse set at the current
//!     per-layer ratio (weight-magnitude criterion, as in ASQJ);
//!   * primal-Q: project onto the per-channel quantization grid at the
//!     current per-layer precision;
//!   * dual: layers whose (loss, energy) trade-off improved the reward
//!     raise their compression multiplier, others back off.
//!
//! One outer ADMM iteration = one driver episode ([`AsqjStrategy`]
//! under the unified [`crate::search::SearchDriver`] loop): the episode
//! evaluates the current projection, `end_episode` runs the dual
//! update.

use anyhow::Result;

use crate::env::{Action, CompressionEnv, Solution};
use crate::pruning::PruneAlg;
use crate::search::{SearchDriver, SearchStrategy};

/// ASQJ budget knobs.
pub struct AsqjConfig {
    /// outer ADMM iterations
    pub iters: usize,
    /// dual step size
    pub rho: f64,
    /// RNG seed
    pub seed: u64,
}

impl Default for AsqjConfig {
    fn default() -> Self {
        AsqjConfig { iters: 40, rho: 0.15, seed: 0 }
    }
}

fn config_actions(sparsity: &[f64], bits: &[f64]) -> Vec<Action> {
    sparsity
        .iter()
        .zip(bits)
        .map(|(&s, &b)| Action {
            ratio: (s / crate::env::MAX_RATIO).clamp(0.0, 1.0),
            bits: b.clamp(0.0, 1.0),
            // fine-grained weight pruning — ASQJ prunes weights, not filters
            alg: PruneAlg::Level.index(),
        })
        .collect()
}

/// ASQJ as a [`SearchStrategy`]: one ADMM iteration per episode.
pub struct AsqjStrategy {
    iters: usize,
    rho: f64,
    sparsity: Vec<f64>,
    bits: Vec<f64>,
    dual: Vec<f64>,
    prev_reward: f64,
    current: Vec<Action>,
}

impl AsqjStrategy {
    /// Build the strategy for an env with `n_layers` prunable layers,
    /// starting from the historical conservative initialisation (30%
    /// sparsity, 8 bits everywhere).
    pub fn new(cfg: &AsqjConfig, n_layers: usize) -> AsqjStrategy {
        AsqjStrategy {
            iters: cfg.iters,
            rho: cfg.rho,
            sparsity: vec![0.3f64; n_layers],
            bits: vec![1.0f64; n_layers],
            dual: vec![0.0f64; n_layers],
            prev_reward: f64::NEG_INFINITY,
            current: Vec::new(),
        }
    }
}

impl SearchStrategy for AsqjStrategy {
    fn method(&self) -> &str {
        "asqj"
    }

    fn episodes(&self) -> usize {
        self.iters
    }

    fn begin_episode(&mut self, _ep: usize) {
        self.current = config_actions(&self.sparsity, &self.bits);
    }

    fn propose(&mut self, t: usize, _state: &[f32]) -> Action {
        self.current[t]
    }

    fn end_episode(&mut self, ep: usize, _total: f64, sol: &Solution) {
        let improved = sol.reward > self.prev_reward;
        self.prev_reward = sol.reward;

        // dual update: push compression harder while the reward tolerates
        // it, relax the most aggressive layers when it does not.
        for l in 0..self.dual.len() {
            if improved && sol.acc_loss < 0.05 {
                self.dual[l] += self.rho * (1.0 - sol.acc_loss * 10.0);
            } else {
                self.dual[l] -= self.rho * (0.5 + self.sparsity[l]);
            }
            self.dual[l] = self.dual[l].clamp(-2.0, 2.0);
            self.sparsity[l] = (0.3 + 0.25 * self.dual[l]).clamp(0.0, 0.85);
            self.bits[l] = (1.0 - 0.3 * self.dual[l].max(0.0) - 0.02 * (ep % 5) as f64)
                .clamp(0.0, 1.0);
        }
    }

    fn save_state(&self, w: &mut crate::io::bin::BinWriter) {
        w.f64s(&self.sparsity);
        w.f64s(&self.bits);
        w.f64s(&self.dual);
        w.f64(self.prev_reward);
    }

    fn load_state(&mut self, r: &mut crate::io::bin::BinReader) -> Result<()> {
        let sparsity = r.f64s()?;
        let bits = r.f64s()?;
        let dual = r.f64s()?;
        anyhow::ensure!(
            sparsity.len() == self.sparsity.len()
                && bits.len() == self.bits.len()
                && dual.len() == self.dual.len(),
            "asqj checkpoint layer count mismatch"
        );
        self.sparsity = sparsity;
        self.bits = bits;
        self.dual = dual;
        self.prev_reward = r.f64()?;
        Ok(())
    }
}

/// Run ASQJ against the shared environment; returns its best solution.
pub fn run(env: &mut CompressionEnv, cfg: &AsqjConfig) -> Result<Solution> {
    let mut strategy = AsqjStrategy::new(cfg, env.n_layers());
    let outcome = SearchDriver::plain().run(env, &mut strategy)?;
    outcome.best.ok_or_else(|| anyhow::anyhow!("asqj ran zero iterations"))
}
