//! ASQJ baseline [24]: joint sparsity-quantization learning via ADMM.
//!
//! The original alternates gradient steps on the task loss with
//! projections onto the sparse set and the quantization grid. In this
//! no-retraining environment there are no task gradients (DESIGN.md
//! §1), so we keep the ADMM skeleton — alternating projection plus a
//! dual/multiplier update per layer — and replace the loss-gradient
//! primal step with reward feedback from the shared oracle:
//!
//!   * primal-W: project onto the fine-grained sparse set at the current
//!     per-layer ratio (weight-magnitude criterion, as in ASQJ);
//!   * primal-Q: project onto the per-channel quantization grid at the
//!     current per-layer precision;
//!   * dual: layers whose (loss, energy) trade-off improved the reward
//!     raise their compression multiplier, others back off.

use anyhow::Result;

use crate::env::{Action, CompressionEnv, Solution};
use crate::pruning::PruneAlg;

/// ASQJ budget knobs.
pub struct AsqjConfig {
    /// outer ADMM iterations
    pub iters: usize,
    /// dual step size
    pub rho: f64,
    /// RNG seed
    pub seed: u64,
}

impl Default for AsqjConfig {
    fn default() -> Self {
        AsqjConfig { iters: 40, rho: 0.15, seed: 0 }
    }
}

fn config_actions(sparsity: &[f64], bits: &[f64]) -> Vec<Action> {
    sparsity
        .iter()
        .zip(bits)
        .map(|(&s, &b)| Action {
            ratio: (s / crate::env::MAX_RATIO).clamp(0.0, 1.0),
            bits: b.clamp(0.0, 1.0),
            // fine-grained weight pruning — ASQJ prunes weights, not filters
            alg: PruneAlg::Level.index(),
        })
        .collect()
}

/// Run ASQJ against the shared environment; returns its best solution.
pub fn run(env: &mut CompressionEnv, cfg: &AsqjConfig) -> Result<Solution> {
    let n = env.n_layers();
    // start conservative: 30% sparsity, 8 bits everywhere
    let mut sparsity = vec![0.3f64; n];
    let mut bits = vec![1.0f64; n];
    let mut dual = vec![0.0f64; n];
    let mut best: Option<Solution> = None;
    let mut prev_reward = f64::NEG_INFINITY;

    for it in 0..cfg.iters {
        let sol = env.evaluate_config(&config_actions(&sparsity, &bits))?;
        let improved = sol.reward > prev_reward;
        prev_reward = sol.reward;

        // dual update: push compression harder while the reward tolerates
        // it, relax the most aggressive layers when it does not.
        for l in 0..n {
            if improved && sol.acc_loss < 0.05 {
                dual[l] += cfg.rho * (1.0 - sol.acc_loss * 10.0);
            } else {
                dual[l] -= cfg.rho * (0.5 + sparsity[l]);
            }
            dual[l] = dual[l].clamp(-2.0, 2.0);
            sparsity[l] = (0.3 + 0.25 * dual[l]).clamp(0.0, 0.85);
            bits[l] = (1.0 - 0.3 * dual[l].max(0.0) - 0.02 * (it % 5) as f64)
                .clamp(0.0, 1.0);
        }
        best = super::better(best, sol);
    }
    Ok(best.unwrap())
}
