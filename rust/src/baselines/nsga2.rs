//! NSGA-II [45] — the heuristic-exploration comparison of §5.3.2.
//!
//! Full implementation: genome of 3·L continuous genes (ratio, bits,
//! algorithm index per layer), tournament selection, simulated binary
//! crossover, polynomial mutation, fast non-dominated sorting and
//! crowding-distance truncation. Per the paper the fitness is the
//! single inverse reward (the LUT already fuses accuracy & energy),
//! evaluated with the exact same oracle as the RL agent, and the eval
//! budget is matched to the RL episode count (55 generations × 20
//! population ≡ 1100 episodes).
//!
//! Under the unified [`crate::search::SearchDriver`] loop
//! ([`Nsga2Strategy`]) one genome evaluation = one driver episode: the
//! strategy queues the initial population, then after each fully
//! evaluated batch runs survivor selection and breeds the next
//! offspring batch — the same RNG draw order as the historical
//! generational loop, so fixed-seed results are bit-identical.

use anyhow::Result;

use crate::env::{Action, CompressionEnv, Solution};
use crate::search::{SearchDriver, SearchStrategy};
use crate::util::rng::Rng;

/// NSGA-II budget & operator knobs.
pub struct Nsga2Config {
    /// population size
    pub pop: usize,
    /// generations to evolve
    pub generations: usize,
    /// SBX distribution index
    pub eta_c: f64,
    /// polynomial-mutation distribution index
    pub eta_m: f64,
    /// per-gene mutation probability
    pub p_mut: f64,
    /// RNG seed
    pub seed: u64,
}

impl Default for Nsga2Config {
    fn default() -> Self {
        Nsga2Config { pop: 20, generations: 55, eta_c: 15.0, eta_m: 20.0, p_mut: 0.1, seed: 0 }
    }
}

#[derive(Clone)]
struct Individual {
    genes: Vec<f64>, // 3L in [0,1]
    /// objectives to MINIMISE: [-reward] (single-objective per §5.3.2,
    /// footnote 2: NSGA-II minimises, so the inverse reward is used)
    obj: Vec<f64>,
}

fn decode(genes: &[f64]) -> Vec<Action> {
    genes
        .chunks(3)
        .map(|g| Action {
            ratio: g[0],
            bits: g[1],
            // continuous gene rounded to a discrete technique index (§5.3.2)
            alg: (g[2] * 6.999) as usize,
        })
        .collect()
}

/// a dominates b (all ≤, one <) — also the dominance test of the
/// cross-run [`crate::search::archive::ParetoArchive`].
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    let mut strictly = false;
    for (x, y) in a.iter().zip(b) {
        if x > y {
            return false;
        }
        if x < y {
            strictly = true;
        }
    }
    strictly
}

/// Fast non-dominated sort; returns front index per individual.
pub fn nondominated_sort(objs: &[Vec<f64>]) -> Vec<usize> {
    let n = objs.len();
    let mut dominated_by = vec![0usize; n];
    let mut dominates_list: Vec<Vec<usize>> = vec![Vec::new(); n];
    for i in 0..n {
        for j in 0..n {
            if i != j && dominates(&objs[i], &objs[j]) {
                dominates_list[i].push(j);
            }
        }
    }
    for (i, dl) in dominates_list.iter().enumerate() {
        let _ = i;
        for &j in dl {
            dominated_by[j] += 1;
        }
    }
    let mut front = vec![usize::MAX; n];
    let mut current: Vec<usize> = (0..n).filter(|&i| dominated_by[i] == 0).collect();
    let mut f = 0;
    while !current.is_empty() {
        let mut next = Vec::new();
        for &i in &current {
            front[i] = f;
            for &j in &dominates_list[i] {
                dominated_by[j] -= 1;
                if dominated_by[j] == 0 {
                    next.push(j);
                }
            }
        }
        current = next;
        f += 1;
    }
    front
}

/// Crowding distance within one front.
pub fn crowding(objs: &[Vec<f64>], members: &[usize]) -> Vec<f64> {
    let m = objs[0].len();
    let mut dist = vec![0.0f64; members.len()];
    for k in 0..m {
        let mut order: Vec<usize> = (0..members.len()).collect();
        order.sort_by(|&a, &b| {
            objs[members[a]][k].total_cmp(&objs[members[b]][k])
        });
        let lo = objs[members[order[0]]][k];
        let hi = objs[members[*order.last().unwrap()]][k];
        let span = (hi - lo).max(1e-12);
        dist[order[0]] = f64::INFINITY;
        dist[*order.last().unwrap()] = f64::INFINITY;
        for w in 1..order.len().saturating_sub(1) {
            dist[order[w]] +=
                (objs[members[order[w + 1]]][k] - objs[members[order[w - 1]]][k]) / span;
        }
    }
    dist
}

fn sbx(a: &[f64], b: &[f64], eta: f64, rng: &mut Rng) -> (Vec<f64>, Vec<f64>) {
    let mut c1 = a.to_vec();
    let mut c2 = b.to_vec();
    for i in 0..a.len() {
        if rng.uniform() < 0.5 {
            let u = rng.uniform();
            let beta = if u <= 0.5 {
                (2.0 * u).powf(1.0 / (eta + 1.0))
            } else {
                (1.0 / (2.0 * (1.0 - u))).powf(1.0 / (eta + 1.0))
            };
            c1[i] = (0.5 * ((1.0 + beta) * a[i] + (1.0 - beta) * b[i])).clamp(0.0, 1.0);
            c2[i] = (0.5 * ((1.0 - beta) * a[i] + (1.0 + beta) * b[i])).clamp(0.0, 1.0);
        }
    }
    (c1, c2)
}

fn poly_mutate(g: &mut [f64], eta: f64, p: f64, rng: &mut Rng) {
    for x in g.iter_mut() {
        if rng.uniform() < p {
            let u = rng.uniform();
            let delta = if u < 0.5 {
                (2.0 * u).powf(1.0 / (eta + 1.0)) - 1.0
            } else {
                1.0 - (2.0 * (1.0 - u)).powf(1.0 / (eta + 1.0))
            };
            *x = (*x + delta).clamp(0.0, 1.0);
        }
    }
}

/// Which batch of genomes the strategy is currently evaluating.
const STAGE_INIT: u8 = 0;
const STAGE_OFFSPRING: u8 = 1;

/// NSGA-II as a [`SearchStrategy`] — see the module docs for the
/// episode mapping.
pub struct Nsga2Strategy {
    pop_size: usize,
    generations: usize,
    eta_c: f64,
    eta_m: f64,
    p_mut: f64,
    rng: Rng,
    /// survivors of the last completed selection (the breeding pool)
    parents: Vec<Individual>,
    /// genomes being evaluated this batch (init pop or one offspring set)
    queue: Vec<Individual>,
    queue_idx: usize,
    stage: u8,
    gen: usize,
    current: Vec<Action>,
}

impl Nsga2Strategy {
    /// Build the strategy for an env with `n_layers` prunable layers;
    /// seeds the RNG and draws the initial population exactly as the
    /// historical loop did.
    pub fn new(cfg: &Nsga2Config, n_layers: usize) -> Nsga2Strategy {
        let n_genes = 3 * n_layers;
        let mut rng = Rng::new(cfg.seed ^ 0x6A);
        let queue: Vec<Individual> = (0..cfg.pop)
            .map(|_| Individual {
                genes: (0..n_genes).map(|_| rng.uniform()).collect(),
                obj: vec![],
            })
            .collect();
        Nsga2Strategy {
            pop_size: cfg.pop,
            generations: cfg.generations,
            eta_c: cfg.eta_c,
            eta_m: cfg.eta_m,
            p_mut: cfg.p_mut,
            rng,
            parents: Vec::new(),
            queue,
            queue_idx: 0,
            stage: STAGE_INIT,
            gen: 0,
            current: Vec::new(),
        }
    }

    /// Tournament selection + SBX + mutation, breeding `pop_size`
    /// offspring from `parents` — identical RNG draw order to the
    /// historical loop.
    fn make_offspring(&mut self) -> Vec<Individual> {
        let mut offspring = Vec::with_capacity(self.pop_size);
        while offspring.len() < self.pop_size {
            let pick = |rng: &mut Rng, pop: &[Individual]| {
                let i = rng.below(pop.len());
                let j = rng.below(pop.len());
                if pop[i].obj[0] <= pop[j].obj[0] { i } else { j }
            };
            let (i, j) = (pick(&mut self.rng, &self.parents), pick(&mut self.rng, &self.parents));
            let (mut c1, mut c2) =
                sbx(&self.parents[i].genes, &self.parents[j].genes, self.eta_c, &mut self.rng);
            poly_mutate(&mut c1, self.eta_m, self.p_mut, &mut self.rng);
            poly_mutate(&mut c2, self.eta_m, self.p_mut, &mut self.rng);
            offspring.push(Individual { genes: c1, obj: vec![] });
            if offspring.len() < self.pop_size {
                offspring.push(Individual { genes: c2, obj: vec![] });
            }
        }
        offspring
    }

    /// Elitist survivor selection over parents ∪ offspring: fronts +
    /// crowding, truncated to `pop_size`.
    fn select_survivors(&mut self) {
        let mut combined = std::mem::take(&mut self.parents);
        combined.append(&mut self.queue);
        let objs: Vec<Vec<f64>> = combined.iter().map(|i| i.obj.clone()).collect();
        let fronts = nondominated_sort(&objs);
        let mut order: Vec<usize> = (0..combined.len()).collect();
        // sort by (front, -crowding)
        let max_front = fronts.iter().max().copied().unwrap_or(0);
        let mut crowd = vec![0.0f64; combined.len()];
        for f in 0..=max_front {
            let members: Vec<usize> =
                (0..combined.len()).filter(|&i| fronts[i] == f).collect();
            if members.is_empty() {
                continue;
            }
            let d = crowding(&objs, &members);
            for (mi, &i) in members.iter().enumerate() {
                crowd[i] = d[mi];
            }
        }
        order.sort_by(|&a, &b| {
            fronts[a]
                .cmp(&fronts[b])
                .then(crowd[b].total_cmp(&crowd[a]))
        });
        self.parents = order[..self.pop_size]
            .iter()
            .map(|&i| combined[i].clone())
            .collect();
    }

    fn save_individuals(xs: &[Individual], w: &mut crate::io::bin::BinWriter) {
        w.usize(xs.len());
        for ind in xs {
            w.f64s(&ind.genes);
            w.f64s(&ind.obj);
        }
    }

    fn load_individuals(r: &mut crate::io::bin::BinReader) -> Result<Vec<Individual>> {
        let n = r.usize()?;
        let mut xs = Vec::with_capacity(n.min(4096));
        for _ in 0..n {
            let genes = r.f64s()?;
            let obj = r.f64s()?;
            xs.push(Individual { genes, obj });
        }
        Ok(xs)
    }
}

impl SearchStrategy for Nsga2Strategy {
    fn method(&self) -> &str {
        "nsga2"
    }

    fn episodes(&self) -> usize {
        self.pop_size + self.generations * self.pop_size
    }

    fn begin_episode(&mut self, _ep: usize) {
        self.current = decode(&self.queue[self.queue_idx].genes);
    }

    fn propose(&mut self, t: usize, _state: &[f32]) -> Action {
        self.current[t]
    }

    fn end_episode(&mut self, _ep: usize, _total: f64, sol: &Solution) {
        self.queue[self.queue_idx].obj = vec![-sol.reward];
        self.queue_idx += 1;
        if self.queue_idx < self.queue.len() {
            return;
        }
        // batch fully evaluated: advance the generational state machine
        if self.stage == STAGE_INIT {
            self.parents = std::mem::take(&mut self.queue);
            self.stage = STAGE_OFFSPRING;
            if self.generations > 0 {
                self.queue = self.make_offspring();
            }
        } else {
            self.select_survivors(); // consumes queue into parents
            self.gen += 1;
            if self.gen < self.generations {
                self.queue = self.make_offspring();
            }
        }
        self.queue_idx = 0;
    }

    fn save_state(&self, w: &mut crate::io::bin::BinWriter) {
        self.rng.save_state(w);
        Self::save_individuals(&self.parents, w);
        Self::save_individuals(&self.queue, w);
        w.usize(self.queue_idx);
        w.u8(self.stage);
        w.usize(self.gen);
    }

    fn load_state(&mut self, r: &mut crate::io::bin::BinReader) -> Result<()> {
        self.rng.load_state(r)?;
        self.parents = Self::load_individuals(r)?;
        self.queue = Self::load_individuals(r)?;
        self.queue_idx = r.usize()?;
        self.stage = r.u8()?;
        self.gen = r.usize()?;
        Ok(())
    }
}

/// Evolve the population; returns the best individual's solution.
pub fn run(env: &mut CompressionEnv, cfg: &Nsga2Config) -> Result<Solution> {
    let mut strategy = Nsga2Strategy::new(cfg, env.n_layers());
    let outcome = SearchDriver::plain().run(env, &mut strategy)?;
    outcome.best.ok_or_else(|| anyhow::anyhow!("nsga2 evaluated zero genomes"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nondominated_sort_fronts() {
        let objs = vec![
            vec![1.0, 1.0], // dominates everything below
            vec![2.0, 2.0],
            vec![1.0, 3.0],
            vec![0.5, 4.0], // trades off against (1,1): front 0
        ];
        let f = nondominated_sort(&objs);
        assert_eq!(f[0], 0);
        assert_eq!(f[1], 1);
        assert_eq!(f[2], 1); // dominated by (1,1)
        assert_eq!(f[3], 0);
    }

    #[test]
    fn dominates_semantics() {
        assert!(dominates(&[1.0, 1.0], &[2.0, 2.0]));
        assert!(dominates(&[1.0, 2.0], &[1.0, 3.0]));
        assert!(!dominates(&[1.0, 3.0], &[3.0, 1.0]));
        assert!(!dominates(&[1.0, 1.0], &[1.0, 1.0]));
    }

    #[test]
    fn crowding_boundary_infinite() {
        let objs = vec![vec![0.0], vec![0.5], vec![1.0]];
        let d = crowding(&objs, &[0, 1, 2]);
        assert!(d[0].is_infinite() && d[2].is_infinite());
        assert!(d[1].is_finite());
    }

    #[test]
    fn operators_stay_in_unit_box() {
        let mut rng = Rng::new(1);
        let a: Vec<f64> = (0..12).map(|_| rng.uniform()).collect();
        let b: Vec<f64> = (0..12).map(|_| rng.uniform()).collect();
        for _ in 0..50 {
            let (mut c1, c2) = sbx(&a, &b, 15.0, &mut rng);
            poly_mutate(&mut c1, 20.0, 0.5, &mut rng);
            for &x in c1.iter().chain(&c2) {
                assert!((0.0..=1.0).contains(&x));
            }
        }
    }

    #[test]
    fn decode_covers_all_algorithms() {
        let genes: Vec<f64> = vec![0.5, 0.5, 0.999, 0.5, 0.5, 0.0];
        let acts = decode(&genes);
        assert_eq!(acts[0].alg, 6);
        assert_eq!(acts[1].alg, 0);
    }

    #[test]
    fn strategy_episode_budget_and_batching() {
        let cfg = Nsga2Config { pop: 4, generations: 2, seed: 9, ..Default::default() };
        let mut s = Nsga2Strategy::new(&cfg, 3);
        assert_eq!(s.episodes(), 4 + 2 * 4);
        // drive the state machine with synthetic solutions: queue sizes
        // must stay at `pop` through init + both offspring batches
        let fake = Solution {
            per_layer: vec![],
            actions: vec![],
            accuracy: 0.5,
            acc_loss: 0.1,
            energy_gain: 0.2,
            latency_gain: 0.2,
            reward: 1.0,
        };
        for ep in 0..s.episodes() {
            s.begin_episode(ep);
            assert_eq!(s.current.len(), 3);
            let a = s.propose(0, &[]);
            assert!(a.alg < 7);
            let mut sol = fake.clone();
            sol.reward = 1.0 + ep as f64 * 0.01;
            s.end_episode(ep, 0.0, &sol);
        }
        assert_eq!(s.gen, 2);
        assert_eq!(s.parents.len(), 4);
    }

    #[test]
    fn strategy_state_roundtrip_breeds_identically() {
        let cfg = Nsga2Config { pop: 4, generations: 3, seed: 5, ..Default::default() };
        let mut a = Nsga2Strategy::new(&cfg, 2);
        let fake = |r: f64| Solution {
            per_layer: vec![],
            actions: vec![],
            accuracy: 0.5,
            acc_loss: 0.1,
            energy_gain: 0.2,
            latency_gain: 0.2,
            reward: r,
        };
        // run through init + half an offspring batch, then snapshot
        for ep in 0..6 {
            a.begin_episode(ep);
            a.end_episode(ep, 0.0, &fake(ep as f64 * 0.3));
        }
        let mut w = crate::io::bin::BinWriter::new();
        a.save_state(&mut w);
        let mut b = Nsga2Strategy::new(&cfg, 2);
        let mut r = crate::io::bin::BinReader::new(&w.buf);
        b.load_state(&mut r).unwrap();
        // both must propose identical genomes for the rest of the run
        for ep in 6..a.episodes() {
            a.begin_episode(ep);
            b.begin_episode(ep);
            for t in 0..2 {
                let (x, y) = (a.propose(t, &[]), b.propose(t, &[]));
                assert_eq!(x.ratio.to_bits(), y.ratio.to_bits());
                assert_eq!(x.bits.to_bits(), y.bits.to_bits());
                assert_eq!(x.alg, y.alg);
            }
            let s = fake(ep as f64 * 0.21);
            a.end_episode(ep, 0.0, &s);
            b.end_episode(ep, 0.0, &s);
        }
    }
}
