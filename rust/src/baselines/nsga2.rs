//! NSGA-II [45] — the heuristic-exploration comparison of §5.3.2.
//!
//! Full implementation: genome of 3·L continuous genes (ratio, bits,
//! algorithm index per layer), tournament selection, simulated binary
//! crossover, polynomial mutation, fast non-dominated sorting and
//! crowding-distance truncation. Per the paper the fitness is the
//! single inverse reward (the LUT already fuses accuracy & energy),
//! evaluated with the exact same oracle as the RL agent, and the eval
//! budget is matched to the RL episode count (55 generations × 20
//! population ≡ 1100 episodes).

use anyhow::Result;

use crate::env::{Action, CompressionEnv, Solution};
use crate::util::rng::Rng;

/// NSGA-II budget & operator knobs.
pub struct Nsga2Config {
    /// population size
    pub pop: usize,
    /// generations to evolve
    pub generations: usize,
    /// SBX distribution index
    pub eta_c: f64,
    /// polynomial-mutation distribution index
    pub eta_m: f64,
    /// per-gene mutation probability
    pub p_mut: f64,
    /// RNG seed
    pub seed: u64,
}

impl Default for Nsga2Config {
    fn default() -> Self {
        Nsga2Config { pop: 20, generations: 55, eta_c: 15.0, eta_m: 20.0, p_mut: 0.1, seed: 0 }
    }
}

#[derive(Clone)]
struct Individual {
    genes: Vec<f64>, // 3L in [0,1]
    /// objectives to MINIMISE: [-reward] (single-objective per §5.3.2,
    /// footnote 2: NSGA-II minimises, so the inverse reward is used)
    obj: Vec<f64>,
    sol: Option<Solution>,
}

fn decode(genes: &[f64]) -> Vec<Action> {
    genes
        .chunks(3)
        .map(|g| Action {
            ratio: g[0],
            bits: g[1],
            // continuous gene rounded to a discrete technique index (§5.3.2)
            alg: (g[2] * 6.999) as usize,
        })
        .collect()
}

fn evaluate(env: &mut CompressionEnv, ind: &mut Individual) -> Result<()> {
    let sol = env.evaluate_config(&decode(&ind.genes))?;
    ind.obj = vec![-sol.reward];
    ind.sol = Some(sol);
    Ok(())
}

/// a dominates b (all ≤, one <).
fn dominates(a: &[f64], b: &[f64]) -> bool {
    let mut strictly = false;
    for (x, y) in a.iter().zip(b) {
        if x > y {
            return false;
        }
        if x < y {
            strictly = true;
        }
    }
    strictly
}

/// Fast non-dominated sort; returns front index per individual.
pub fn nondominated_sort(objs: &[Vec<f64>]) -> Vec<usize> {
    let n = objs.len();
    let mut dominated_by = vec![0usize; n];
    let mut dominates_list: Vec<Vec<usize>> = vec![Vec::new(); n];
    for i in 0..n {
        for j in 0..n {
            if i != j && dominates(&objs[i], &objs[j]) {
                dominates_list[i].push(j);
            }
        }
    }
    for (i, dl) in dominates_list.iter().enumerate() {
        let _ = i;
        for &j in dl {
            dominated_by[j] += 1;
        }
    }
    let mut front = vec![usize::MAX; n];
    let mut current: Vec<usize> = (0..n).filter(|&i| dominated_by[i] == 0).collect();
    let mut f = 0;
    while !current.is_empty() {
        let mut next = Vec::new();
        for &i in &current {
            front[i] = f;
            for &j in &dominates_list[i] {
                dominated_by[j] -= 1;
                if dominated_by[j] == 0 {
                    next.push(j);
                }
            }
        }
        current = next;
        f += 1;
    }
    front
}

/// Crowding distance within one front.
pub fn crowding(objs: &[Vec<f64>], members: &[usize]) -> Vec<f64> {
    let m = objs[0].len();
    let mut dist = vec![0.0f64; members.len()];
    for k in 0..m {
        let mut order: Vec<usize> = (0..members.len()).collect();
        order.sort_by(|&a, &b| {
            objs[members[a]][k].partial_cmp(&objs[members[b]][k]).unwrap()
        });
        let lo = objs[members[order[0]]][k];
        let hi = objs[members[*order.last().unwrap()]][k];
        let span = (hi - lo).max(1e-12);
        dist[order[0]] = f64::INFINITY;
        dist[*order.last().unwrap()] = f64::INFINITY;
        for w in 1..order.len().saturating_sub(1) {
            dist[order[w]] +=
                (objs[members[order[w + 1]]][k] - objs[members[order[w - 1]]][k]) / span;
        }
    }
    dist
}

fn sbx(a: &[f64], b: &[f64], eta: f64, rng: &mut Rng) -> (Vec<f64>, Vec<f64>) {
    let mut c1 = a.to_vec();
    let mut c2 = b.to_vec();
    for i in 0..a.len() {
        if rng.uniform() < 0.5 {
            let u = rng.uniform();
            let beta = if u <= 0.5 {
                (2.0 * u).powf(1.0 / (eta + 1.0))
            } else {
                (1.0 / (2.0 * (1.0 - u))).powf(1.0 / (eta + 1.0))
            };
            c1[i] = (0.5 * ((1.0 + beta) * a[i] + (1.0 - beta) * b[i])).clamp(0.0, 1.0);
            c2[i] = (0.5 * ((1.0 - beta) * a[i] + (1.0 + beta) * b[i])).clamp(0.0, 1.0);
        }
    }
    (c1, c2)
}

fn poly_mutate(g: &mut [f64], eta: f64, p: f64, rng: &mut Rng) {
    for x in g.iter_mut() {
        if rng.uniform() < p {
            let u = rng.uniform();
            let delta = if u < 0.5 {
                (2.0 * u).powf(1.0 / (eta + 1.0)) - 1.0
            } else {
                1.0 - (2.0 * (1.0 - u)).powf(1.0 / (eta + 1.0))
            };
            *x = (*x + delta).clamp(0.0, 1.0);
        }
    }
}

/// Evolve the population; returns the best individual's solution.
pub fn run(env: &mut CompressionEnv, cfg: &Nsga2Config) -> Result<Solution> {
    let n_genes = 3 * env.n_layers();
    let mut rng = Rng::new(cfg.seed ^ 0x6A);
    let mut pop: Vec<Individual> = (0..cfg.pop)
        .map(|_| Individual {
            genes: (0..n_genes).map(|_| rng.uniform()).collect(),
            obj: vec![],
            sol: None,
        })
        .collect();
    for ind in pop.iter_mut() {
        evaluate(env, ind)?;
    }
    let mut best: Option<Solution> = None;
    for ind in &pop {
        best = super::better(best, ind.sol.clone().unwrap());
    }

    for _gen in 0..cfg.generations {
        // tournament selection + SBX + mutation -> offspring
        let mut offspring = Vec::with_capacity(cfg.pop);
        while offspring.len() < cfg.pop {
            let pick = |rng: &mut Rng, pop: &[Individual]| {
                let i = rng.below(pop.len());
                let j = rng.below(pop.len());
                if pop[i].obj[0] <= pop[j].obj[0] { i } else { j }
            };
            let (i, j) = (pick(&mut rng, &pop), pick(&mut rng, &pop));
            let (mut c1, mut c2) = sbx(&pop[i].genes, &pop[j].genes, cfg.eta_c, &mut rng);
            poly_mutate(&mut c1, cfg.eta_m, cfg.p_mut, &mut rng);
            poly_mutate(&mut c2, cfg.eta_m, cfg.p_mut, &mut rng);
            offspring.push(Individual { genes: c1, obj: vec![], sol: None });
            if offspring.len() < cfg.pop {
                offspring.push(Individual { genes: c2, obj: vec![], sol: None });
            }
        }
        for ind in offspring.iter_mut() {
            evaluate(env, ind)?;
            best = super::better(best, ind.sol.clone().unwrap());
        }
        // elitist survivor selection: fronts + crowding
        let mut combined = pop;
        combined.append(&mut offspring);
        let objs: Vec<Vec<f64>> = combined.iter().map(|i| i.obj.clone()).collect();
        let fronts = nondominated_sort(&objs);
        let mut order: Vec<usize> = (0..combined.len()).collect();
        // sort by (front, -crowding)
        let max_front = fronts.iter().max().copied().unwrap_or(0);
        let mut crowd = vec![0.0f64; combined.len()];
        for f in 0..=max_front {
            let members: Vec<usize> =
                (0..combined.len()).filter(|&i| fronts[i] == f).collect();
            if members.is_empty() {
                continue;
            }
            let d = crowding(&objs, &members);
            for (mi, &i) in members.iter().enumerate() {
                crowd[i] = d[mi];
            }
        }
        order.sort_by(|&a, &b| {
            fronts[a]
                .cmp(&fronts[b])
                .then(crowd[b].partial_cmp(&crowd[a]).unwrap())
        });
        pop = order[..cfg.pop]
            .iter()
            .map(|&i| combined[i].clone())
            .collect();
    }
    Ok(best.unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nondominated_sort_fronts() {
        let objs = vec![
            vec![1.0, 1.0], // dominates everything below
            vec![2.0, 2.0],
            vec![1.0, 3.0],
            vec![0.5, 4.0], // trades off against (1,1): front 0
        ];
        let f = nondominated_sort(&objs);
        assert_eq!(f[0], 0);
        assert_eq!(f[1], 1);
        assert_eq!(f[2], 1); // dominated by (1,1)
        assert_eq!(f[3], 0);
    }

    #[test]
    fn dominates_semantics() {
        assert!(dominates(&[1.0, 1.0], &[2.0, 2.0]));
        assert!(dominates(&[1.0, 2.0], &[1.0, 3.0]));
        assert!(!dominates(&[1.0, 3.0], &[3.0, 1.0]));
        assert!(!dominates(&[1.0, 1.0], &[1.0, 1.0]));
    }

    #[test]
    fn crowding_boundary_infinite() {
        let objs = vec![vec![0.0], vec![0.5], vec![1.0]];
        let d = crowding(&objs, &[0, 1, 2]);
        assert!(d[0].is_infinite() && d[2].is_infinite());
        assert!(d[1].is_finite());
    }

    #[test]
    fn operators_stay_in_unit_box() {
        let mut rng = Rng::new(1);
        let a: Vec<f64> = (0..12).map(|_| rng.uniform()).collect();
        let b: Vec<f64> = (0..12).map(|_| rng.uniform()).collect();
        for _ in 0..50 {
            let (mut c1, c2) = sbx(&a, &b, 15.0, &mut rng);
            poly_mutate(&mut c1, 20.0, 0.5, &mut rng);
            for &x in c1.iter().chain(&c2) {
                assert!((0.0..=1.0).contains(&x));
            }
        }
    }

    #[test]
    fn decode_covers_all_algorithms() {
        let genes: Vec<f64> = vec![0.5, 0.5, 0.999, 0.5, 0.5, 0.0];
        let acts = decode(&genes);
        assert_eq!(acts[0].alg, 6);
        assert_eq!(acts[1].alg, 0);
    }
}
