//! AMC baseline [15]: DDPG learns a per-layer *channel-pruning ratio*
//! only. Fixed L1-ranked structured pruning, fixed 8-bit quantization
//! (the paper quantizes AMC's float output to 8 bits for fairness,
//! §5.2). Uses the same DDPG core as our framework with a 1-d action.

use anyhow::Result;

use crate::env::{Action, CompressionEnv, Solution};
use crate::pruning::PruneAlg;
use crate::rl::ddpg::{Ddpg, DdpgConfig};
use crate::rl::replay::Transition;
use crate::util::rng::Rng;

/// AMC budget knobs.
pub struct AmcConfig {
    /// DDPG training episodes
    pub episodes: usize,
    /// random-exploration episodes before learning
    pub warmup: usize,
    /// RNG seed
    pub seed: u64,
}

impl Default for AmcConfig {
    fn default() -> Self {
        AmcConfig { episodes: 300, warmup: 30, seed: 0 }
    }
}

/// Run AMC against the shared environment; returns its best solution.
pub fn run(env: &mut CompressionEnv, cfg: &AmcConfig) -> Result<Solution> {
    let mut agent = Ddpg::new(
        DdpgConfig { action_dim: 1, ..DdpgConfig::default() },
        cfg.seed ^ 0xA3C,
    );
    let mut rng = Rng::new(cfg.seed ^ 0x11);
    let mut best: Option<Solution> = None;
    for ep in 0..cfg.episodes {
        let mut s = env.reset();
        #[allow(unused_assignments)]
        let mut last = None;
        loop {
            let a = if ep < cfg.warmup {
                vec![rng.uniform() as f32]
            } else {
                agent.act(&s, true)
            };
            let action = Action {
                ratio: a[0] as f64,
                bits: 1.0, // -> 8 bits
                alg: PruneAlg::L1Ranked.index(),
            };
            let step = env.step(action)?;
            agent.observe(Transition {
                s: s.clone(),
                a: a.clone(),
                alg: action.alg,
                r: step.reward as f32,
                s2: step.state.clone(),
                done: step.done,
            });
            agent.update();
            s = step.state.clone();
            let done = step.done;
            last = Some(step);
            if done {
                break;
            }
        }
        if ep >= cfg.warmup {
            agent.decay_noise();
        }
        let sol = env.solution(last.as_ref().unwrap());
        best = super::better(best, sol);
    }
    Ok(best.unwrap())
}
