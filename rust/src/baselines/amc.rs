//! AMC baseline [15]: DDPG learns a per-layer *channel-pruning ratio*
//! only. Fixed L1-ranked structured pruning, fixed 8-bit quantization
//! (the paper quantizes AMC's float output to 8 bits for fairness,
//! §5.2). Uses the same DDPG core as our framework with a 1-d action,
//! run as an [`AmcStrategy`] under the unified
//! [`crate::search::SearchDriver`] loop.

use anyhow::Result;

use crate::env::{Action, CompressionEnv, Solution, StepResult};
use crate::pruning::PruneAlg;
use crate::rl::ddpg::{Ddpg, DdpgConfig};
use crate::rl::replay::Transition;
use crate::search::{SearchDriver, SearchStrategy};
use crate::util::rng::Rng;

/// AMC budget knobs.
pub struct AmcConfig {
    /// DDPG training episodes
    pub episodes: usize,
    /// random-exploration episodes before learning
    pub warmup: usize,
    /// RNG seed
    pub seed: u64,
}

impl Default for AmcConfig {
    fn default() -> Self {
        AmcConfig { episodes: 300, warmup: 30, seed: 0 }
    }
}

/// AMC as a [`SearchStrategy`]: 1-d DDPG over the pruning ratio, bits
/// pinned to 8, algorithm pinned to L1-ranked structured pruning.
pub struct AmcStrategy {
    agent: Ddpg,
    rng: Rng,
    episodes: usize,
    warmup: usize,
    ep: usize,
    pending: Vec<f32>,
}

impl AmcStrategy {
    /// Build the strategy exactly as the historical loop seeded it.
    pub fn new(cfg: &AmcConfig) -> AmcStrategy {
        AmcStrategy {
            agent: Ddpg::new(
                DdpgConfig { action_dim: 1, ..DdpgConfig::default() },
                cfg.seed ^ 0xA3C,
            ),
            rng: Rng::new(cfg.seed ^ 0x11),
            episodes: cfg.episodes,
            warmup: cfg.warmup,
            ep: 0,
            pending: Vec::new(),
        }
    }
}

impl SearchStrategy for AmcStrategy {
    fn method(&self) -> &str {
        "amc"
    }

    fn episodes(&self) -> usize {
        self.episodes
    }

    fn begin_episode(&mut self, ep: usize) {
        self.ep = ep;
    }

    fn propose(&mut self, _t: usize, state: &[f32]) -> Action {
        let a = if self.ep < self.warmup {
            vec![self.rng.uniform() as f32]
        } else {
            self.agent.act(state, true)
        };
        let action = Action {
            ratio: a[0] as f64,
            bits: 1.0, // -> 8 bits
            alg: PruneAlg::L1Ranked.index(),
        };
        self.pending = a;
        action
    }

    fn observe(&mut self, s: &[f32], action: &Action, step: &StepResult) {
        self.agent.observe(Transition {
            s: s.to_vec(),
            a: self.pending.clone(),
            alg: action.alg,
            r: step.reward as f32,
            s2: step.state.clone(),
            done: step.done,
        });
        self.agent.update();
    }

    fn end_episode(&mut self, ep: usize, _total: f64, _sol: &Solution) {
        if ep >= self.warmup {
            self.agent.decay_noise();
        }
    }

    fn save_state(&self, w: &mut crate::io::bin::BinWriter) {
        self.agent.save_state(w);
        self.rng.save_state(w);
        w.f32s(&self.pending);
    }

    fn load_state(&mut self, r: &mut crate::io::bin::BinReader) -> Result<()> {
        self.agent.load_state(r)?;
        self.rng.load_state(r)?;
        self.pending = r.f32s()?;
        Ok(())
    }
}

/// Run AMC against the shared environment; returns its best solution.
pub fn run(env: &mut CompressionEnv, cfg: &AmcConfig) -> Result<Solution> {
    let mut strategy = AmcStrategy::new(cfg);
    let outcome = SearchDriver::plain().run(env, &mut strategy)?;
    outcome.best.ok_or_else(|| anyhow::anyhow!("amc ran zero episodes"))
}
