//! OPQ baseline [18]: one-shot analytical pruning-quantization.
//!
//! OPQ derives per-layer pruning masks and quantization steps from the
//! pretrained weights alone via a Lagrangian error model — no training
//! data. We implement the same analytics on our weight statistics:
//!
//!   * pruning: a single global magnitude threshold λ on σ-normalised
//!     weights induces each layer's sparsity (the Lagrangian stationary
//!     point of the layerwise L2 error under a global budget);
//!   * quantization: water-filling bit allocation — layers with larger
//!     dynamic range get more bits, minimising Σ MSE under an average
//!     bit budget.
//!
//! The original then fine-tunes (5 epochs on CIFAR, 1 on ImageNet);
//! that step does not exist here (DESIGN.md §1) which matches how the
//! paper frames OPQ's reliance on fine-tuning on harder datasets.
//! A small sweep over (global budget, bit budget) picks the best
//! reward, mirroring the paper's operating-point selection — one
//! operating point per driver episode ([`OpqStrategy`] under the
//! unified [`crate::search::SearchDriver`] loop).

use anyhow::Result;

use crate::env::{Action, CompressionEnv, Solution, MAX_BITS, MIN_BITS};
use crate::pruning::PruneAlg;
use crate::search::{SearchDriver, SearchStrategy};

/// OPQ operating-point sweep.
pub struct OpqConfig {
    /// global sparsity budgets to sweep
    pub budgets: Vec<f64>,
    /// average-bit budgets to sweep
    pub bit_budgets: Vec<f64>,
}

impl Default for OpqConfig {
    fn default() -> Self {
        OpqConfig {
            budgets: vec![0.2, 0.35, 0.5, 0.65],
            bit_budgets: vec![5.0, 6.0, 7.0],
        }
    }
}

/// Per-layer sparsity from a global σ-normalised magnitude threshold.
fn sparsity_allocation(env: &CompressionEnv, global: f64) -> Vec<f64> {
    let n = env.n_layers();
    // per-layer |w|/σ distributions — find the λ whose induced total
    // sparsity matches the budget (bisection on the pooled distribution)
    let mut normed: Vec<Vec<f32>> = Vec::with_capacity(n);
    let weights = env.dense_weights();
    for t in weights.w.iter() {
        let sigma = (t.l2() / (t.len() as f32).sqrt()).max(1e-8);
        normed.push(t.data.iter().map(|x| x.abs() / sigma).collect());
    }
    let mut pooled: Vec<f32> = normed.iter().flatten().copied().collect();
    pooled.sort_unstable_by(|a, b| a.total_cmp(b));
    let k = ((pooled.len() as f64) * global) as usize;
    let lambda = pooled[k.min(pooled.len() - 1)];
    normed
        .iter()
        .map(|layer| {
            let below = layer.iter().filter(|&&x| x < lambda).count();
            (below as f64 / layer.len().max(1) as f64).min(0.88)
        })
        .collect()
}

/// Water-filling bit allocation: bits_l = B + ½log₂(σ_l²/geomean σ²).
fn bit_allocation(env: &CompressionEnv, avg_bits: f64) -> Vec<f64> {
    let weights = env.dense_weights();
    let vars: Vec<f64> = weights
        .w
        .iter()
        .map(|t| {
            let mm = t.channel_minmax(false);
            let range: f64 = mm
                .iter()
                .filter(|(a, b)| a.is_finite() && b.is_finite())
                .map(|(a, b)| (b - a) as f64)
                .sum::<f64>()
                / mm.len().max(1) as f64;
            (range * range).max(1e-12)
        })
        .collect();
    let log_gm = vars.iter().map(|v| v.ln()).sum::<f64>() / vars.len() as f64;
    vars.iter()
        .map(|v| {
            let b = avg_bits + 0.5 * (v.ln() - log_gm) / std::f64::consts::LN_2;
            b.clamp(MIN_BITS as f64, MAX_BITS as f64)
        })
        .collect()
}

/// OPQ as a [`SearchStrategy`]: the whole (budget × bit-budget) sweep
/// is derived analytically from the dense weights at construction, one
/// operating point per episode. Stateless between episodes, so its
/// checkpoint payload is empty.
pub struct OpqStrategy {
    configs: Vec<Vec<Action>>,
    ep: usize,
}

impl OpqStrategy {
    /// Precompute the sweep in the historical order (budgets outer,
    /// bit-budgets inner) from the env's dense weights.
    pub fn new(env: &CompressionEnv, cfg: &OpqConfig) -> OpqStrategy {
        let mut configs = Vec::with_capacity(cfg.budgets.len() * cfg.bit_budgets.len());
        for &budget in &cfg.budgets {
            let sp = sparsity_allocation(env, budget);
            for &bb in &cfg.bit_budgets {
                let bits = bit_allocation(env, bb);
                let actions: Vec<Action> = sp
                    .iter()
                    .zip(&bits)
                    .map(|(&s, &b)| Action {
                        ratio: (s / crate::env::MAX_RATIO).clamp(0.0, 1.0),
                        bits: ((b - MIN_BITS as f64) / (MAX_BITS - MIN_BITS) as f64)
                            .clamp(0.0, 1.0),
                        alg: PruneAlg::Level.index(),
                    })
                    .collect();
                configs.push(actions);
            }
        }
        OpqStrategy { configs, ep: 0 }
    }
}

impl SearchStrategy for OpqStrategy {
    fn method(&self) -> &str {
        "opq"
    }

    fn episodes(&self) -> usize {
        self.configs.len()
    }

    fn begin_episode(&mut self, ep: usize) {
        self.ep = ep;
    }

    fn propose(&mut self, t: usize, _state: &[f32]) -> Action {
        self.configs[self.ep][t]
    }

    fn save_state(&self, _w: &mut crate::io::bin::BinWriter) {
        // the sweep is a pure function of the dense weights — nothing to
        // persist; a resumed strategy recomputes identical configs
    }

    fn load_state(&mut self, _r: &mut crate::io::bin::BinReader) -> Result<()> {
        Ok(())
    }
}

/// Run OPQ's analytical allocation sweep; returns its best solution.
pub fn run(env: &mut CompressionEnv, cfg: &OpqConfig) -> Result<Solution> {
    let mut strategy = OpqStrategy::new(env, cfg);
    let outcome = SearchDriver::plain().run(env, &mut strategy)?;
    outcome.best.ok_or_else(|| anyhow::anyhow!("opq swept zero operating points"))
}
