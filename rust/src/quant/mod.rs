//! Post-training weight quantization (paper §4.1): per-channel,
//! asymmetric, linear. Applied *after* pruning — zeros stay exactly
//! zero (they are skipped/penalised by the energy model, not part of
//! the quantization grid), and the per-channel (min, max) grid is
//! computed over the surviving weights only, which is precisely the
//! "centroid-based quantization benefits from a pruned model" effect
//! the paper cites from Deep Compression [26].
//!
//! Activation quantization lives in the inference backend — baked into
//! the exported HLO graph (L2) on the PJRT path, and implemented by
//! [`crate::runtime::native`] on the default path — parameterised per
//! layer by the `act_bits` input; see python/compile/kernels/ref.py
//! for the shared grid math.
//!
//! Both paths snap through ONE implementation: [`grid::QuantGrid`].
//! `runtime::fake_quant` (activations) and [`quantize_weights`] used to
//! duplicate the clipped-linear-snap expression; the agreement test at
//! the bottom of this file pins them to the shared helper.

pub mod grid;

pub use grid::QuantGrid;

use crate::tensor::Tensor;

/// FNV-1a offset basis (64-bit).
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a prime (64-bit).
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

#[inline]
fn fnv1a_u32(mut h: u64, word: u32) -> u64 {
    for shift in [0u32, 8, 16, 24] {
        h ^= ((word >> shift) & 0xff) as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Canonical per-layer **config fingerprint**: a 64-bit FNV-1a hash of
/// the layer's weight tensor *bit patterns* (so `-0.0` vs `0.0` and
/// every rounding decision are distinguished — the same exactness
/// standard as the bit-identity contracts) followed by the bit pattern
/// of the layer's activation precision (`act_bits` travels the oracle
/// seam as `f32`). One (pruning mask ⊕ quantized values ⊕ bits)
/// configuration maps to one key, which is what makes it safe as the
/// cache key for the search-loop memoization subsystem: the exec
/// engine's `PackCache` (a `PackedLayer` is a pure function of
/// `(weights, grid)` and the grid is a pure function of
/// `(bits, act_scale, act_signed)` — the latter two constants per
/// layer) and the environment's `EvalCache` (which keys on the
/// whole-network fingerprint vector, exact-compared).
pub fn config_fingerprint(w: &Tensor, act_bits: f32) -> u64 {
    let mut h = FNV_OFFSET;
    for v in &w.data {
        h = fnv1a_u32(h, v.to_bits());
    }
    fnv1a_u32(h, act_bits.to_bits())
}

/// Fake-quantize `w` in place to `bits` per channel. Returns the mean
/// squared quantization error (used by the OPQ baseline's analytics).
pub fn quantize_weights(w: &mut Tensor, bits: u32) -> f64 {
    let bits = bits.clamp(2, 8);
    let levels = ((1u32 << bits) - 1) as f32;
    let mm = w.channel_minmax(false);
    let c = w.out_channels(false);
    let mut err = 0.0f64;
    let mut n = 0usize;
    for i in 0..w.data.len() {
        let x = w.data[i];
        if x == 0.0 {
            continue; // pruned weights stay pruned
        }
        let (mn, mx) = mm[i % c.max(1)];
        if !mn.is_finite() || !mx.is_finite() || mx <= mn {
            continue; // degenerate channel (single value / all pruned)
        }
        // the survivors' (min, max) bound x, so the grid clamp inside
        // `snap` is an exact no-op and this stays bit-identical to the
        // historical unclamped expression
        let step = (mx - mn) / levels;
        let q = QuantGrid::new(mn, mx, step).snap(x);
        // never quantize a surviving weight to exactly 0 — that would
        // silently change the sparsity the energy model was told about
        let q = if q == 0.0 { step.copysign(x).max(f32::MIN_POSITIVE) } else { q };
        err += ((q - x) as f64).powi(2);
        n += 1;
        w.data[i] = q;
    }
    if n > 0 {
        err / n as f64
    } else {
        0.0
    }
}

/// Quantization MSE *without* mutating (analytic baselines).
pub fn quant_error(w: &Tensor, bits: u32) -> f64 {
    let mut tmp = w.clone();
    quantize_weights(&mut tmp, bits)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Tensor {
        Tensor::new(vec![4, 2], vec![0.1, -1.0, 0.5, 2.0, -0.3, 0.7, 0.9, -0.2])
    }

    #[test]
    fn error_shrinks_with_bits() {
        // near-monotone: min/max grid alignment can wiggle adjacent
        // precisions by a hair, but the trend must be strongly down
        let w = toy();
        let mut prev = f64::INFINITY;
        for bits in [2u32, 3, 4, 5, 6, 7, 8] {
            let e = quant_error(&w, bits);
            assert!(e <= prev * 1.5 + 1e-12, "bits={bits} err={e} prev={prev}");
            prev = e.min(prev);
        }
        assert!(quant_error(&w, 8) < 0.01 * quant_error(&w, 2));
    }

    #[test]
    fn zeros_stay_zero() {
        let mut w = toy();
        w.data[0] = 0.0;
        w.data[5] = 0.0;
        let before = w.sparsity();
        quantize_weights(&mut w, 3);
        assert_eq!(w.sparsity(), before);
        assert_eq!(w.data[0], 0.0);
        assert_eq!(w.data[5], 0.0);
    }

    #[test]
    fn survivors_never_become_zero() {
        let mut w = Tensor::new(vec![3, 1], vec![-0.5, 0.001, 0.5]);
        quantize_weights(&mut w, 2);
        assert!(w.data.iter().all(|&x| x != 0.0), "{:?}", w.data);
    }

    #[test]
    fn values_on_channel_grid() {
        let mut w = toy();
        quantize_weights(&mut w, 3);
        let mm = toy().channel_minmax(false);
        for (i, &x) in w.data.iter().enumerate() {
            let (mn, mx) = mm[i % 2];
            let step = (mx - mn) / 7.0;
            let r = (x - mn) / step;
            assert!(
                (r - r.round()).abs() < 1e-4 || x != 0.0 && (x.abs() - step.abs()).abs() < 1e-4,
                "w[{i}]={x} not on grid (mn={mn} step={step})"
            );
        }
    }

    #[test]
    fn eight_bit_nearly_lossless() {
        let w = toy();
        let e = quant_error(&w, 8);
        let scale: f64 = w.data.iter().map(|x| (*x as f64).powi(2)).sum::<f64>() / 8.0;
        assert!(e < 1e-4 * scale, "e={e}");
    }

    #[test]
    fn weight_and_activation_quantizers_agree_on_the_shared_grid() {
        // cross-module agreement: quantize_weights (per-channel weight
        // grid) and runtime::fake_quant (activation grid) must snap
        // identically when handed the same (lo, hi, step) — both now
        // route through quant::grid::QuantGrid, and this test keeps
        // them from drifting apart again.
        use crate::runtime::native::fake_quant;
        use crate::util::proptest::{forall, gen_weights};
        forall(
            "quantize_weights == fake_quant on the channel grid",
            |r| (gen_weights(r, 48), 2 + r.below(7) as u32),
            |(data, bits)| {
                // single output channel -> one grid over all weights
                let mut w = Tensor::new(vec![data.len(), 1], data.clone());
                quantize_weights(&mut w, *bits);
                let (mn, mx) = Tensor::new(vec![data.len(), 1], data.clone())
                    .channel_minmax(false)[0];
                if !mn.is_finite() || !mx.is_finite() || mx <= mn {
                    return true; // degenerate channel: both paths pass through
                }
                let step = (mx - mn) / ((1u32 << bits.clamp(2, 8)) - 1) as f32;
                let mut fq = data.clone();
                fake_quant(&mut fq, mn, mx, step);
                data.iter().zip(&w.data).zip(&fq).all(|((&x0, &qw), &qa)| {
                    // skip pruned zeros (weight path preserves them) and
                    // snaps the never-zero rule rewrote
                    x0 == 0.0 || qa == 0.0 || qw == qa
                })
            },
        );
    }

    #[test]
    fn fingerprint_separates_masks_values_and_bits() {
        let w = toy();
        let base = config_fingerprint(&w, 4.0);
        // deterministic
        assert_eq!(base, config_fingerprint(&toy(), 4.0));
        // bits are part of the key
        assert_ne!(base, config_fingerprint(&w, 5.0));
        // a mask change (prune one weight) changes the key
        let mut masked = toy();
        masked.data[3] = 0.0;
        assert_ne!(base, config_fingerprint(&masked, 4.0));
        // a value-only change (same mask) changes the key
        let mut tweaked = toy();
        tweaked.data[3] *= 1.5;
        assert_ne!(base, config_fingerprint(&tweaked, 4.0));
        // bit patterns, not float equality: -0.0 != 0.0
        let mut neg = toy();
        neg.data[0] = 0.0;
        let mut pos = toy();
        pos.data[0] = -0.0;
        assert_ne!(config_fingerprint(&neg, 4.0), config_fingerprint(&pos, 4.0));
    }

    #[test]
    fn fingerprint_tracks_the_prune_quant_pipeline() {
        // the intended call pattern: fingerprint after prune+quant —
        // identical pipelines yield identical keys, different ratios
        // or precisions yield different keys
        use crate::pruning::{prune, PruneAlg, PruneCtx};
        use crate::util::rng::Rng;
        let mk = |ratio: f64, bits: u32| {
            let mut w = Tensor::new(vec![16, 4], (0..64).map(|i| (i as f32).sin()).collect());
            let sal = Tensor::zeros(vec![64]);
            let mut rng = Rng::new(7);
            let mut ctx = PruneCtx { saliency: &sal, chsq: &[], dwconv: false, rng: &mut rng };
            prune(&mut w, PruneAlg::Level, ratio, &mut ctx);
            quantize_weights(&mut w, bits);
            config_fingerprint(&w, bits as f32)
        };
        assert_eq!(mk(0.5, 4), mk(0.5, 4));
        assert_ne!(mk(0.5, 4), mk(0.3, 4));
        assert_ne!(mk(0.5, 4), mk(0.5, 6));
    }

    #[test]
    fn property_idempotent() {
        use crate::util::proptest::{forall, gen_weights};
        forall(
            "quantize twice == quantize once",
            |r| (gen_weights(r, 64), 2 + r.below(7) as u32),
            |(data, bits)| {
                let mut w1 = Tensor::new(vec![data.len()], data.clone());
                quantize_weights(&mut w1, *bits);
                let mut w2 = w1.clone();
                quantize_weights(&mut w2, *bits);
                w1.data
                    .iter()
                    .zip(&w2.data)
                    .all(|(a, b)| (a - b).abs() <= 1e-5 * a.abs().max(1e-3))
            },
        );
    }
}
