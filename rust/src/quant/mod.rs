//! Post-training weight quantization (paper §4.1): per-channel,
//! asymmetric, linear. Applied *after* pruning — zeros stay exactly
//! zero (they are skipped/penalised by the energy model, not part of
//! the quantization grid), and the per-channel (min, max) grid is
//! computed over the surviving weights only, which is precisely the
//! "centroid-based quantization benefits from a pruned model" effect
//! the paper cites from Deep Compression [26].
//!
//! Activation quantization lives in the inference backend — baked into
//! the exported HLO graph (L2) on the PJRT path, and implemented by
//! [`crate::runtime::native`] on the default path — parameterised per
//! layer by the `act_bits` input; see python/compile/kernels/ref.py
//! for the shared grid math.
//!
//! Both paths snap through ONE implementation: [`grid::QuantGrid`].
//! `runtime::fake_quant` (activations) and [`quantize_weights`] used to
//! duplicate the clipped-linear-snap expression; the agreement test at
//! the bottom of this file pins them to the shared helper.

pub mod grid;

pub use grid::QuantGrid;

use crate::tensor::Tensor;

/// Fake-quantize `w` in place to `bits` per channel. Returns the mean
/// squared quantization error (used by the OPQ baseline's analytics).
pub fn quantize_weights(w: &mut Tensor, bits: u32) -> f64 {
    let bits = bits.clamp(2, 8);
    let levels = ((1u32 << bits) - 1) as f32;
    let mm = w.channel_minmax(false);
    let c = w.out_channels(false);
    let mut err = 0.0f64;
    let mut n = 0usize;
    for i in 0..w.data.len() {
        let x = w.data[i];
        if x == 0.0 {
            continue; // pruned weights stay pruned
        }
        let (mn, mx) = mm[i % c.max(1)];
        if !mn.is_finite() || !mx.is_finite() || mx <= mn {
            continue; // degenerate channel (single value / all pruned)
        }
        // the survivors' (min, max) bound x, so the grid clamp inside
        // `snap` is an exact no-op and this stays bit-identical to the
        // historical unclamped expression
        let step = (mx - mn) / levels;
        let q = QuantGrid::new(mn, mx, step).snap(x);
        // never quantize a surviving weight to exactly 0 — that would
        // silently change the sparsity the energy model was told about
        let q = if q == 0.0 { step.copysign(x).max(f32::MIN_POSITIVE) } else { q };
        err += ((q - x) as f64).powi(2);
        n += 1;
        w.data[i] = q;
    }
    if n > 0 {
        err / n as f64
    } else {
        0.0
    }
}

/// Quantization MSE *without* mutating (analytic baselines).
pub fn quant_error(w: &Tensor, bits: u32) -> f64 {
    let mut tmp = w.clone();
    quantize_weights(&mut tmp, bits)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Tensor {
        Tensor::new(vec![4, 2], vec![0.1, -1.0, 0.5, 2.0, -0.3, 0.7, 0.9, -0.2])
    }

    #[test]
    fn error_shrinks_with_bits() {
        // near-monotone: min/max grid alignment can wiggle adjacent
        // precisions by a hair, but the trend must be strongly down
        let w = toy();
        let mut prev = f64::INFINITY;
        for bits in [2u32, 3, 4, 5, 6, 7, 8] {
            let e = quant_error(&w, bits);
            assert!(e <= prev * 1.5 + 1e-12, "bits={bits} err={e} prev={prev}");
            prev = e.min(prev);
        }
        assert!(quant_error(&w, 8) < 0.01 * quant_error(&w, 2));
    }

    #[test]
    fn zeros_stay_zero() {
        let mut w = toy();
        w.data[0] = 0.0;
        w.data[5] = 0.0;
        let before = w.sparsity();
        quantize_weights(&mut w, 3);
        assert_eq!(w.sparsity(), before);
        assert_eq!(w.data[0], 0.0);
        assert_eq!(w.data[5], 0.0);
    }

    #[test]
    fn survivors_never_become_zero() {
        let mut w = Tensor::new(vec![3, 1], vec![-0.5, 0.001, 0.5]);
        quantize_weights(&mut w, 2);
        assert!(w.data.iter().all(|&x| x != 0.0), "{:?}", w.data);
    }

    #[test]
    fn values_on_channel_grid() {
        let mut w = toy();
        quantize_weights(&mut w, 3);
        let mm = toy().channel_minmax(false);
        for (i, &x) in w.data.iter().enumerate() {
            let (mn, mx) = mm[i % 2];
            let step = (mx - mn) / 7.0;
            let r = (x - mn) / step;
            assert!(
                (r - r.round()).abs() < 1e-4 || x != 0.0 && (x.abs() - step.abs()).abs() < 1e-4,
                "w[{i}]={x} not on grid (mn={mn} step={step})"
            );
        }
    }

    #[test]
    fn eight_bit_nearly_lossless() {
        let w = toy();
        let e = quant_error(&w, 8);
        let scale: f64 = w.data.iter().map(|x| (*x as f64).powi(2)).sum::<f64>() / 8.0;
        assert!(e < 1e-4 * scale, "e={e}");
    }

    #[test]
    fn weight_and_activation_quantizers_agree_on_the_shared_grid() {
        // cross-module agreement: quantize_weights (per-channel weight
        // grid) and runtime::fake_quant (activation grid) must snap
        // identically when handed the same (lo, hi, step) — both now
        // route through quant::grid::QuantGrid, and this test keeps
        // them from drifting apart again.
        use crate::runtime::native::fake_quant;
        use crate::util::proptest::{forall, gen_weights};
        forall(
            "quantize_weights == fake_quant on the channel grid",
            |r| (gen_weights(r, 48), 2 + r.below(7) as u32),
            |(data, bits)| {
                // single output channel -> one grid over all weights
                let mut w = Tensor::new(vec![data.len(), 1], data.clone());
                quantize_weights(&mut w, *bits);
                let (mn, mx) = Tensor::new(vec![data.len(), 1], data.clone())
                    .channel_minmax(false)[0];
                if !mn.is_finite() || !mx.is_finite() || mx <= mn {
                    return true; // degenerate channel: both paths pass through
                }
                let step = (mx - mn) / ((1u32 << bits.clamp(2, 8)) - 1) as f32;
                let mut fq = data.clone();
                fake_quant(&mut fq, mn, mx, step);
                data.iter().zip(&w.data).zip(&fq).all(|((&x0, &qw), &qa)| {
                    // skip pruned zeros (weight path preserves them) and
                    // snaps the never-zero rule rewrote
                    x0 == 0.0 || qa == 0.0 || qw == qa
                })
            },
        );
    }

    #[test]
    fn property_idempotent() {
        use crate::util::proptest::{forall, gen_weights};
        forall(
            "quantize twice == quantize once",
            |r| (gen_weights(r, 64), 2 + r.below(7) as u32),
            |(data, bits)| {
                let mut w1 = Tensor::new(vec![data.len()], data.clone());
                quantize_weights(&mut w1, *bits);
                let mut w2 = w1.clone();
                quantize_weights(&mut w2, *bits);
                w1.data
                    .iter()
                    .zip(&w2.data)
                    .all(|(a, b)| (a - b).abs() <= 1e-5 * a.abs().max(1e-3))
            },
        );
    }
}
