//! The one uniform quantization grid — shared by activation fake-quant
//! (`runtime::fake_quant`), per-channel weight quantization
//! (`quant::quantize_weights`) and the integer fast-path kernel
//! (`runtime/native` + `nn/mat`).
//!
//! Historically the activation and weight paths computed the snapping
//! math independently; any drift between them would silently break the
//! "weights arrive already fake-quantized" contract the backends rely
//! on. [`QuantGrid`] owns that math now, and a cross-module agreement
//! test (`quant/mod.rs`) pins the two callers to it.
//!
//! The integer kernel additionally leans on an exactness property of
//! this type: [`QuantGrid::snap`] reconstructs its result as
//! `r * step + lo` where `r` is an exact small-integer-valued f32, and
//! [`QuantGrid::value`] performs the *same* two f32 operations on the
//! integer code — so `value(code(x)) == snap(x)` **bitwise**, which is
//! what lets the int path store activations as i16 codes and still
//! produce logits bit-identical to the f32 reference forward.

/// A uniform linear quantization grid over `[lo, hi]` with spacing
/// `step`: the representable points are `lo + n·step` for integer
/// codes `n` in `0..=levels`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantGrid {
    /// lower clip point (grid point of code 0)
    pub lo: f32,
    /// upper clip point
    pub hi: f32,
    /// spacing between adjacent grid points
    pub step: f32,
}

impl QuantGrid {
    /// Wrap the `(lo, hi, step)` triple the callers already pass around.
    pub fn new(lo: f32, hi: f32, step: f32) -> QuantGrid {
        QuantGrid { lo, hi, step }
    }

    /// A grid that cannot snap anything: zero/negative/non-finite step
    /// (zero calibration scale, an all-equal weight channel). Callers
    /// pass values through unchanged on degenerate grids.
    pub fn degenerate(&self) -> bool {
        self.step <= 0.0 || !self.step.is_finite()
    }

    /// Number of steps between `lo` and `hi` (0 on degenerate grids).
    /// For the activation grids of `quant_params` and the per-channel
    /// weight grids this is `2^bits - 1 ≤ 255`.
    pub fn levels(&self) -> usize {
        if self.degenerate() {
            return 0;
        }
        let l = ((self.hi - self.lo) / self.step).round();
        if l.is_finite() && l >= 0.0 {
            l as usize
        } else {
            0
        }
    }

    /// Clipped linear snap of `x` onto the grid — the exact expression
    /// both `runtime::fake_quant` and `quant::quantize_weights` have
    /// always computed, now in one place.
    #[inline]
    pub fn snap(&self, x: f32) -> f32 {
        ((x.clamp(self.lo, self.hi) - self.lo) / self.step).round() * self.step + self.lo
    }

    /// Integer code of `x` on the grid: the same rounded quantity
    /// [`Self::snap`] multiplies back, kept as an integer. Saturates at
    /// the i16 range (real grids stay ≤ 255). `±inf` clamps to the
    /// grid boundary exactly as [`Self::snap`] does; `NaN` has no
    /// integer code (the cast saturates it to 0) — see the int-kernel
    /// caveat in `runtime/native.rs` module docs.
    #[inline]
    pub fn code(&self, x: f32) -> i16 {
        ((x.clamp(self.lo, self.hi) - self.lo) / self.step).round() as i16
    }

    /// The f32 value of grid code `n` — **bit-identical** to what
    /// [`Self::snap`] produces for any `x` with `code(x) == n`, because
    /// `n as f32` is exact for `|n| ≤ 2^24` and the two arithmetic ops
    /// match `snap`'s reconstruction exactly.
    #[inline]
    pub fn value(&self, code: i16) -> f32 {
        (code as f32) * self.step + self.lo
    }

    /// Dequantization table for the integer kernel, indexed by
    /// `code + 1`: entry 0 is the exact `0.0` used for structural zeros
    /// (SAME-padding positions), entry `n + 1` is [`Self::value`]`(n)`.
    /// `None` when the grid is degenerate or too fine to tabulate
    /// (callers fall back to the f32 path).
    pub fn lut(&self) -> Option<Vec<f32>> {
        let levels = self.levels();
        if self.degenerate() || levels == 0 || levels > 255 {
            return None;
        }
        let mut t = Vec::with_capacity(levels + 2);
        t.push(0.0);
        for n in 0..=levels {
            t.push(self.value(n as i16));
        }
        Some(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snap_matches_hand_values() {
        // grid [0, 2] step 0.5 — the historical fake_quant fixture
        let g = QuantGrid::new(0.0, 2.0, 0.5);
        assert_eq!(g.snap(0.6), 0.5);
        assert_eq!(g.snap(0.76), 1.0);
        assert_eq!(g.snap(3.0), 2.0); // clips high
        assert_eq!(g.snap(-1.0), 0.0); // clips low
        assert_eq!(g.levels(), 4);
    }

    #[test]
    fn degenerate_grids_are_flagged() {
        assert!(QuantGrid::new(0.0, 0.0, 0.0).degenerate());
        assert!(QuantGrid::new(0.0, 1.0, -0.5).degenerate());
        assert!(QuantGrid::new(0.0, 1.0, f32::NAN).degenerate());
        assert!(QuantGrid::new(0.0, 1.0, f32::INFINITY).degenerate());
        assert!(!QuantGrid::new(-1.0, 1.0, 0.25).degenerate());
        assert_eq!(QuantGrid::new(0.0, 0.0, 0.0).levels(), 0);
        assert_eq!(QuantGrid::new(0.0, 0.0, 0.0).lut(), None);
    }

    #[test]
    fn value_of_code_reproduces_snap_bitwise() {
        // the property the int kernel's bit-exactness rests on
        let g = QuantGrid::new(-1.3, 1.3, 2.6 / 7.0);
        for &x in &[-2.0f32, -1.3, -0.61, -0.2, 0.0, 0.17, 0.9, 1.3, 5.0] {
            let snapped = g.snap(x);
            assert_eq!(g.value(g.code(x)), snapped, "x={x}");
            // snapped values are fixed points of the code/value pair
            assert_eq!(g.value(g.code(snapped)), snapped, "x={x}");
        }
    }

    #[test]
    fn lut_is_sentinel_plus_all_levels() {
        let g = QuantGrid::new(0.0, 1.0, 1.0 / 3.0);
        let lut = g.lut().unwrap();
        assert_eq!(lut.len(), 2 + g.levels());
        assert_eq!(lut[0], 0.0);
        for n in 0..=g.levels() {
            assert_eq!(lut[n + 1], g.value(n as i16));
        }
    }
}
