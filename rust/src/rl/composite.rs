//! The composite agent (paper §4.2, Fig 4): DDPG supplies the
//! continuous (ratio, precision) action and its actor's last hidden
//! layer is the feature input to Rainbow, which supplies the discrete
//! pruning-algorithm action.
//!
//! The reward-monitoring scheme of §4.2.2 keeps Rainbow frozen through
//! the primary exploratory period: random pruning techniques are
//! sampled (removing bias toward any technique) until the episode-
//! reward moving average shows consistent improvement; then Rainbow is
//! unlocked and takes over using the already-mature DDPG features.
//! Rainbow's loss never back-propagates into the DDPG actor.

use crate::env::{Action, Solution, StepResult};
use crate::pruning::PruneAlg;
use crate::search::SearchStrategy;
use crate::util::rng::Rng;

use super::ddpg::{Ddpg, DdpgConfig};
use super::rainbow::{Rainbow, RainbowConfig};
use super::replay::Transition;

/// Composite-agent configuration (DDPG + Rainbow + unlock monitor).
#[derive(Clone, Debug)]
pub struct CompositeConfig {
    /// DDPG hyper-parameters
    pub ddpg: DdpgConfig,
    /// Rainbow hyper-parameters (feat_dim is overwritten to match DDPG)
    pub rainbow: RainbowConfig,
    /// episodes of pure exploration before any unlock check (paper: 100)
    pub warmup_episodes: usize,
    /// sliding window length for the reward monitor
    pub monitor_window: usize,
    /// unlock when mean(recent half) > mean(older half)·(1+margin)
    pub unlock_margin: f64,
    /// hard unlock point (never stay frozen forever)
    pub max_frozen_episodes: usize,
}

impl Default for CompositeConfig {
    fn default() -> Self {
        CompositeConfig {
            ddpg: DdpgConfig::default(),
            rainbow: RainbowConfig::default(),
            warmup_episodes: 100,
            monitor_window: 40,
            unlock_margin: 0.02,
            max_frozen_episodes: 300,
        }
    }
}

/// The paper's composite agent (Fig 4).
pub struct CompositeAgent {
    /// configuration
    pub cfg: CompositeConfig,
    /// continuous half: (pruning ratio, precision)
    pub ddpg: Ddpg,
    /// discrete half: pruning-algorithm selection
    pub rainbow: Rainbow,
    /// episodes finished so far
    pub episode: usize,
    /// has the §4.2.2 reward monitor unlocked Rainbow yet?
    pub rainbow_unlocked: bool,
    reward_history: Vec<f64>,
    rng: Rng,
}

impl CompositeAgent {
    /// Build both agents; Rainbow's input is wired to the DDPG feature tap.
    pub fn new(mut cfg: CompositeConfig, seed: u64) -> CompositeAgent {
        cfg.rainbow.feat_dim = cfg.ddpg.hidden;
        CompositeAgent {
            ddpg: Ddpg::new(cfg.ddpg.clone(), seed ^ 0xD0),
            rainbow: Rainbow::new(cfg.rainbow.clone(), seed ^ 0x5A),
            episode: 0,
            rainbow_unlocked: false,
            reward_history: Vec::new(),
            rng: Rng::new(seed ^ 0xC0),
            cfg,
        }
    }

    /// Warm-up = pure random exploration for DDPG too (paper §5.1: the
    /// first 100 episodes constitute the warm-up).
    fn in_warmup(&self) -> bool {
        self.episode < self.cfg.warmup_episodes
    }

    /// Choose the full 3-part action for the current layer state.
    pub fn act(&mut self, state: &[f32]) -> Action {
        let cont = if self.in_warmup() {
            vec![self.rng.uniform() as f32, self.rng.uniform() as f32]
        } else {
            self.ddpg.act(state, true)
        };
        let alg = if self.rainbow_unlocked {
            let feats = self.ddpg.features(state);
            self.rainbow.act(&feats)
        } else {
            // frozen Rainbow: unbiased random technique sampling (§4.2.2)
            self.rng.below(PruneAlg::ALL.len())
        };
        Action { ratio: cont[0] as f64, bits: cont[1] as f64, alg }
    }

    /// Greedy (no-noise) action for final policy extraction.
    pub fn act_greedy(&mut self, state: &[f32]) -> Action {
        let cont = self.ddpg.act_greedy(state);
        let feats = self.ddpg.features(state);
        self.rainbow.set_eval(true);
        let alg = self.rainbow.act(&feats);
        self.rainbow.set_eval(false);
        Action { ratio: cont[0] as f64, bits: cont[1] as f64, alg }
    }

    /// Store the step and update both agents (rewards are fed at every
    /// step — Rainbow requires an update before each action, §4.2.2).
    pub fn observe_and_update(
        &mut self,
        s: &[f32],
        action: &Action,
        reward: f64,
        s2: &[f32],
        done: bool,
    ) {
        self.ddpg.observe(Transition {
            s: s.to_vec(),
            a: vec![action.ratio as f32, action.bits as f32],
            alg: action.alg,
            r: reward as f32,
            s2: s2.to_vec(),
            done,
        });
        self.ddpg.update();
        // Rainbow consumes the *post-update* DDPG features (Fig 4: after
        // DDPG is updated, its actor hidden layer feeds Rainbow).
        let f = self.ddpg.features(s);
        let f2 = self.ddpg.features(s2);
        self.rainbow.observe(f, action.alg, reward as f32, f2, done);
        if self.rainbow_unlocked {
            self.rainbow.update();
        }
    }

    /// Per-episode bookkeeping: noise decay, β anneal, reward monitor.
    pub fn end_episode(&mut self, episode_reward: f64, total_episodes: usize) {
        self.episode += 1;
        self.reward_history.push(episode_reward);
        if self.episode >= self.cfg.warmup_episodes {
            self.ddpg.decay_noise();
        }
        let frac = self.episode as f64 / total_episodes.max(1) as f64;
        self.ddpg.replay.anneal_beta(frac);
        self.rainbow.replay.anneal_beta(frac);

        if !self.rainbow_unlocked {
            self.check_unlock();
        }
    }

    /// Serialise the complete composite state (both sub-agents in full,
    /// the reward monitor history, unlock flag, episode counter, RNG)
    /// for bit-exact search resume — the method-specific payload of a
    /// [`crate::search::checkpoint::SearchCheckpoint`].
    pub fn save_state(&self, w: &mut crate::io::bin::BinWriter) {
        self.ddpg.save_state(w);
        self.rainbow.save_state(w);
        w.usize(self.episode);
        w.bool(self.rainbow_unlocked);
        w.f64s(&self.reward_history);
        self.rng.save_state(w);
    }

    /// Restore a state written by [`Self::save_state`] into a
    /// same-config agent.
    pub fn load_state(&mut self, r: &mut crate::io::bin::BinReader) -> anyhow::Result<()> {
        self.ddpg.load_state(r)?;
        self.rainbow.load_state(r)?;
        self.episode = r.usize()?;
        self.rainbow_unlocked = r.bool()?;
        self.reward_history = r.f64s()?;
        self.rng.load_state(r)?;
        Ok(())
    }

    /// Reward monitor (§4.2.2): unlock once the moving average shows
    /// consistent improvement (or after a hard cap, so a flat reward
    /// landscape cannot freeze Rainbow forever).
    fn check_unlock(&mut self) {
        if self.episode < self.cfg.warmup_episodes + self.cfg.monitor_window {
            if self.episode >= self.cfg.max_frozen_episodes {
                self.rainbow_unlocked = true;
            }
            return;
        }
        let w = self.cfg.monitor_window;
        let recent = &self.reward_history[self.reward_history.len() - w / 2..];
        let older =
            &self.reward_history[self.reward_history.len() - w..self.reward_history.len() - w / 2];
        let mr: f64 = recent.iter().sum::<f64>() / recent.len() as f64;
        let mo: f64 = older.iter().sum::<f64>() / older.len() as f64;
        let improved = mr > mo + self.cfg.unlock_margin * mo.abs().max(0.1);
        if improved || self.episode >= self.cfg.max_frozen_episodes {
            self.rainbow_unlocked = true;
        }
    }
}

/// The composite agent as a [`SearchStrategy`] — `ours` (and its
/// ablation variants) under the unified [`crate::search::SearchDriver`]
/// loop. Wraps a [`CompositeAgent`] and ends the run with the paper's
/// greedy policy-extraction rollout.
pub struct CompositeStrategy {
    /// the underlying composite agent (exposed so the coordinator can
    /// export the NPZ policy checkpoint after the run)
    pub agent: CompositeAgent,
    method: String,
    greedy_alg_override: Option<PruneAlg>,
    total_episodes: usize,
}

impl CompositeStrategy {
    /// Wrap an agent for a run of `episodes` episodes (method `ours`).
    pub fn new(agent: CompositeAgent, episodes: usize) -> CompositeStrategy {
        CompositeStrategy {
            agent,
            method: "ours".to_string(),
            greedy_alg_override: None,
            total_episodes: episodes,
        }
    }

    /// Override the method string recorded in reports/checkpoints
    /// (ablation variants: `ours-latency`, `ours-norainbow`, …).
    pub fn with_method(mut self, method: &str) -> CompositeStrategy {
        self.method = method.to_string();
        self
    }

    /// Force a single pruning algorithm in the greedy rollout (the
    /// `SingleAlg` ablation, paper §3.1 motivation).
    pub fn with_greedy_alg(mut self, alg: PruneAlg) -> CompositeStrategy {
        self.greedy_alg_override = Some(alg);
        self
    }
}

impl SearchStrategy for CompositeStrategy {
    fn method(&self) -> &str {
        &self.method
    }

    fn episodes(&self) -> usize {
        self.total_episodes
    }

    fn propose(&mut self, _t: usize, state: &[f32]) -> Action {
        self.agent.act(state)
    }

    fn observe(&mut self, s: &[f32], action: &Action, step: &StepResult) {
        self.agent
            .observe_and_update(s, action, step.reward, &step.state, step.done);
    }

    fn end_episode(&mut self, _ep: usize, total: f64, _sol: &Solution) {
        self.agent.end_episode(total, self.total_episodes);
    }

    fn wants_greedy_rollout(&self) -> bool {
        true
    }

    fn propose_greedy(&mut self, state: &[f32]) -> Action {
        let mut action = self.agent.act_greedy(state);
        if let Some(alg) = self.greedy_alg_override {
            action.alg = alg.index();
        }
        action
    }

    fn progress_note(&self) -> String {
        format!("rainbow={}", self.agent.rainbow_unlocked)
    }

    fn records_curve(&self) -> bool {
        true
    }

    fn save_state(&self, w: &mut crate::io::bin::BinWriter) {
        self.agent.save_state(w);
    }

    fn load_state(&mut self, r: &mut crate::io::bin::BinReader) -> anyhow::Result<()> {
        self.agent.load_state(r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> CompositeConfig {
        CompositeConfig {
            ddpg: DdpgConfig { hidden: 32, batch: 16, replay_cap: 128, ..DdpgConfig::default() },
            rainbow: RainbowConfig {
                hidden: 16,
                atoms: 11,
                batch: 16,
                replay_cap: 128,
                ..RainbowConfig::default()
            },
            warmup_episodes: 3,
            monitor_window: 6,
            unlock_margin: 0.0,
            max_frozen_episodes: 30,
            ..CompositeConfig::default()
        }
    }

    #[test]
    fn warmup_is_random_then_policy() {
        let mut agent = CompositeAgent::new(small_cfg(), 3);
        assert!(agent.in_warmup());
        let s = vec![0.5; crate::env::STATE_DIM];
        let a = agent.act(&s);
        assert!((0.0..=1.0).contains(&a.ratio));
        assert!(a.alg < PruneAlg::ALL.len());
    }

    #[test]
    fn unlocks_on_improving_reward() {
        let mut agent = CompositeAgent::new(small_cfg(), 4);
        for ep in 0..12 {
            agent.end_episode(ep as f64, 40); // strictly improving
        }
        assert!(agent.rainbow_unlocked, "monitor should unlock Rainbow");
    }

    #[test]
    fn stays_frozen_on_flat_reward_until_cap() {
        let mut agent = CompositeAgent::new(small_cfg(), 5);
        for _ in 0..20 {
            agent.end_episode(1.0, 40);
        }
        assert!(!agent.rainbow_unlocked);
        for _ in 0..12 {
            agent.end_episode(1.0, 40);
        }
        assert!(agent.rainbow_unlocked, "hard cap must unlock");
    }

    #[test]
    fn full_loop_smoke() {
        let mut agent = CompositeAgent::new(small_cfg(), 6);
        let s = vec![0.2; crate::env::STATE_DIM];
        let s2 = vec![0.3; crate::env::STATE_DIM];
        for i in 0..40 {
            let a = agent.act(&s);
            agent.observe_and_update(&s, &a, 0.5, &s2, i % 4 == 3);
            if i % 4 == 3 {
                agent.end_episode(2.0, 10);
            }
        }
        let g = agent.act_greedy(&s);
        assert!(g.alg < PruneAlg::ALL.len());
    }
}
