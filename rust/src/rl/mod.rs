//! The composite RL agent (paper §4.2): DDPG for the continuous
//! (pruning-ratio, precision) actions, Rainbow for the discrete
//! pruning-algorithm action, both fed from prioritized replay, glued by
//! the DDPG-actor feature tap and the reward-monitor unlock.

pub mod checkpoint;
pub mod composite;
pub mod ddpg;
pub mod rainbow;
pub mod replay;
