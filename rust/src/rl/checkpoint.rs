//! Composite-agent *policy* checkpointing: the agent's networks (DDPG
//! actor/critic + targets, Rainbow online/target nets, exploration
//! schedule, unlock state) serialise to a single NPZ file via
//! [`crate::io::npz`].
//!
//! Enables the paper's on-device-optimization story (§4): a trained
//! policy can move to the embedded target without redoing the warm-up.
//! Replay buffers and optimiser moments are deliberately not persisted
//! — NPZ is f32-only and a policy transplanted onto a *different*
//! environment should not inherit stale experiences.
//!
//! This is distinct from the method-agnostic **search** checkpoint
//! ([`crate::search::checkpoint`]), which snapshots the *complete*
//! mid-run search state (any strategy, replay, Adam moments, RNG
//! streams, driver progress) bit-exactly so `--resume` reproduces an
//! uninterrupted run. Use that for suspending/resuming searches; use
//! this for exporting a learned policy.

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::io::npz::{save_npz, Npz};
use crate::tensor::Tensor;

use super::composite::CompositeAgent;

/// Write the agent to `path` (.npz).
pub fn save(agent: &CompositeAgent, path: &Path) -> Result<()> {
    let mut blobs: Vec<(String, Tensor)> = Vec::new();
    agent.ddpg.export(&mut blobs);
    agent.rainbow.export(&mut blobs);
    blobs.push((
        "composite.meta".into(),
        Tensor::new(
            vec![2],
            vec![agent.episode as f32, agent.rainbow_unlocked as u32 as f32],
        ),
    ));
    let refs: Vec<(String, &Tensor)> =
        blobs.iter().map(|(k, t)| (k.clone(), t)).collect();
    save_npz(path, &refs)
}

/// Load a checkpoint into an existing (same-config) agent.
pub fn load(agent: &mut CompositeAgent, path: &Path) -> Result<()> {
    let npz = Npz::load(path)?;
    let get = |k: &str| -> Result<Tensor> {
        npz.entries
            .get(k)
            .ok_or_else(|| anyhow!("checkpoint missing `{k}`"))?
            .to_tensor()
    };
    agent.ddpg.import(&get)?;
    agent.rainbow.import(&get)?;
    let meta = get("composite.meta")?;
    agent.episode = meta.data[0] as usize;
    agent.rainbow_unlocked = meta.data[1] != 0.0;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rl::composite::{CompositeAgent, CompositeConfig};
    use crate::rl::ddpg::DdpgConfig;
    use crate::rl::rainbow::RainbowConfig;

    fn cfg() -> CompositeConfig {
        CompositeConfig {
            ddpg: DdpgConfig { hidden: 24, batch: 8, replay_cap: 64, ..DdpgConfig::default() },
            rainbow: RainbowConfig {
                hidden: 12,
                atoms: 11,
                batch: 8,
                replay_cap: 64,
                ..RainbowConfig::default()
            },
            warmup_episodes: 1,
            ..CompositeConfig::default()
        }
    }

    #[test]
    fn roundtrip_restores_policy() {
        let dir = std::env::temp_dir().join("hapq_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("agent.npz");

        let mut a = CompositeAgent::new(cfg(), 5);
        // burn in some training so weights differ from init
        let s = vec![0.4f32; crate::env::STATE_DIM];
        let s2 = vec![0.6f32; crate::env::STATE_DIM];
        for i in 0..30 {
            let act = a.act(&s);
            a.observe_and_update(&s, &act, 0.7, &s2, i % 5 == 4);
            if i % 5 == 4 {
                a.end_episode(1.0, 10);
            }
        }
        a.rainbow_unlocked = true;
        save(&a, &path).unwrap();

        let mut b = CompositeAgent::new(cfg(), 999); // different seed/init
        let before = b.ddpg.act_greedy(&s);
        load(&mut b, &path).unwrap();
        let after = b.ddpg.act_greedy(&s);
        let a_out = a.ddpg.act_greedy(&s);
        assert_ne!(before, after, "load must change the policy");
        assert_eq!(after, a_out, "restored policy must match saved one");
        assert!(b.rainbow_unlocked);
        assert_eq!(b.episode, a.episode);
    }

    #[test]
    fn load_rejects_wrong_shapes() {
        let dir = std::env::temp_dir().join("hapq_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("agent.npz");
        let a = CompositeAgent::new(cfg(), 5);
        save(&a, &path).unwrap();

        let mut big = CompositeAgent::new(
            CompositeConfig {
                ddpg: DdpgConfig { hidden: 48, ..DdpgConfig::default() },
                ..cfg()
            },
            5,
        );
        assert!(load(&mut big, &path).is_err());
    }
}
