//! Prioritized experience replay (paper §4.2: both agent components use
//! one, "to favor experiences with higher temporal difference error").
//!
//! Sum-tree proportional sampling with importance-sampling weights
//! (Schaul et al.), α/β defaults from the Rainbow paper.

use crate::util::rng::Rng;

/// One stored transition. `a` carries the continuous action (DDPG) and
/// `alg` the discrete one (Rainbow) — each agent reads its half.
#[derive(Clone, Debug)]
pub struct Transition {
    /// state (or feature vector, for Rainbow)
    pub s: Vec<f32>,
    /// continuous action (empty for Rainbow transitions)
    pub a: Vec<f32>,
    /// discrete pruning-algorithm action
    pub alg: usize,
    /// (n-step) reward
    pub r: f32,
    /// successor state / features
    pub s2: Vec<f32>,
    /// episode terminated at this step?
    pub done: bool,
}

/// Array-backed sum tree over leaf priorities.
struct SumTree {
    n: usize,
    tree: Vec<f64>,
}

impl SumTree {
    fn new(n: usize) -> Self {
        SumTree { n, tree: vec![0.0; 2 * n] }
    }

    fn set(&mut self, i: usize, p: f64) {
        let mut idx = self.n + i;
        let delta = p - self.tree[idx];
        while idx > 0 {
            self.tree[idx] += delta;
            idx /= 2;
        }
    }

    fn get(&self, i: usize) -> f64 {
        self.tree[self.n + i]
    }

    fn total(&self) -> f64 {
        self.tree[1]
    }

    /// Find the leaf whose prefix-sum interval contains `v`.
    fn find(&self, mut v: f64) -> usize {
        let mut idx = 1;
        while idx < self.n {
            let left = 2 * idx;
            if v <= self.tree[left] || self.tree[left + 1] <= 0.0 {
                idx = left;
            } else {
                v -= self.tree[left];
                idx = left + 1;
            }
        }
        idx - self.n
    }
}

/// Proportional prioritized replay buffer.
pub struct PrioritizedReplay {
    cap: usize,
    data: Vec<Transition>,
    tree: SumTree,
    pos: usize,
    alpha: f64,
    /// importance-sampling exponent (annealed toward 1)
    pub beta: f64,
    max_pri: f64,
}

impl PrioritizedReplay {
    /// Empty buffer with the given capacity.
    pub fn new(cap: usize) -> Self {
        PrioritizedReplay {
            cap,
            data: Vec::with_capacity(cap),
            tree: SumTree::new(cap.next_power_of_two()),
            pos: 0,
            alpha: 0.6,
            beta: 0.4,
            max_pri: 1.0,
        }
    }

    /// Stored transition count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when nothing is stored yet.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Insert with max priority (new experiences sampled at least once).
    pub fn push(&mut self, t: Transition) {
        let p = self.max_pri.powf(self.alpha);
        if self.data.len() < self.cap {
            self.data.push(t);
            self.tree.set(self.data.len() - 1, p);
        } else {
            self.data[self.pos] = t;
            self.tree.set(self.pos, p);
            self.pos = (self.pos + 1) % self.cap;
        }
    }

    /// Sample `batch` indices with IS weights (normalised to max 1).
    pub fn sample(&self, batch: usize, rng: &mut Rng) -> (Vec<usize>, Vec<f32>) {
        let n = self.data.len();
        assert!(n > 0);
        let total = self.tree.total().max(1e-12);
        let mut idx = Vec::with_capacity(batch);
        let mut w = Vec::with_capacity(batch);
        let seg = total / batch as f64;
        for b in 0..batch {
            let v = seg * (b as f64 + rng.uniform());
            let i = self.tree.find(v.min(total - 1e-9)).min(n - 1);
            let p = (self.tree.get(i) / total).max(1e-12);
            idx.push(i);
            w.push(((n as f64 * p).powf(-self.beta)) as f32);
        }
        let wmax = w.iter().cloned().fold(f32::MIN, f32::max).max(1e-12);
        w.iter_mut().for_each(|x| *x /= wmax);
        (idx, w)
    }

    /// Borrow a stored transition by index.
    pub fn get(&self, i: usize) -> &Transition {
        &self.data[i]
    }

    /// Feed back |TD error| for the sampled indices.
    pub fn update_priorities(&mut self, idx: &[usize], td: &[f32]) {
        for (&i, &e) in idx.iter().zip(td) {
            let p = (e.abs() as f64 + 1e-3).min(100.0);
            self.max_pri = self.max_pri.max(p);
            self.tree.set(i, p.powf(self.alpha));
        }
    }

    /// Anneal β toward 1 (standard PER schedule).
    pub fn anneal_beta(&mut self, frac: f64) {
        self.beta = 0.4 + 0.6 * frac.clamp(0.0, 1.0);
    }

    /// Serialise the complete buffer state for bit-exact search resume:
    /// transitions, ring position, β/max-priority, and the sum tree
    /// **verbatim** — internal tree nodes are the floating-point sum of
    /// an incremental update history, so rebuilding them from the
    /// leaves could differ in the last ulp and shift a sample.
    pub fn save_state(&self, w: &mut crate::io::bin::BinWriter) {
        w.usize(self.cap);
        w.usize(self.pos);
        w.f64(self.alpha);
        w.f64(self.beta);
        w.f64(self.max_pri);
        w.usize(self.data.len());
        for t in &self.data {
            w.f32s(&t.s);
            w.f32s(&t.a);
            w.usize(t.alg);
            w.f32(t.r);
            w.f32s(&t.s2);
            w.bool(t.done);
        }
        w.usize(self.tree.n);
        w.f64s(&self.tree.tree);
    }

    /// Restore a state written by [`Self::save_state`] into a buffer of
    /// the same capacity.
    pub fn load_state(&mut self, r: &mut crate::io::bin::BinReader) -> anyhow::Result<()> {
        let cap = r.usize()?;
        anyhow::ensure!(
            cap == self.cap,
            "replay checkpoint capacity {cap} != configured {}",
            self.cap
        );
        self.pos = r.usize()?;
        self.alpha = r.f64()?;
        self.beta = r.f64()?;
        self.max_pri = r.f64()?;
        let n = r.usize()?;
        anyhow::ensure!(n <= cap, "replay checkpoint holds {n} > cap {cap} transitions");
        self.data.clear();
        for _ in 0..n {
            let s = r.f32s()?;
            let a = r.f32s()?;
            let alg = r.usize()?;
            let rew = r.f32()?;
            let s2 = r.f32s()?;
            let done = r.bool()?;
            self.data.push(Transition { s, a, alg, r: rew, s2, done });
        }
        let tn = r.usize()?;
        anyhow::ensure!(tn == self.tree.n, "replay checkpoint tree width mismatch");
        let tree = r.f64s()?;
        anyhow::ensure!(tree.len() == self.tree.tree.len(), "replay tree length mismatch");
        self.tree.tree = tree;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tr(r: f32) -> Transition {
        Transition { s: vec![r], a: vec![0.0], alg: 0, r, s2: vec![r], done: false }
    }

    #[test]
    fn sum_tree_prefix_find() {
        let mut t = SumTree::new(4);
        t.set(0, 1.0);
        t.set(1, 2.0);
        t.set(2, 3.0);
        t.set(3, 4.0);
        assert_eq!(t.total(), 10.0);
        assert_eq!(t.find(0.5), 0);
        assert_eq!(t.find(1.5), 1);
        assert_eq!(t.find(3.5), 2);
        assert_eq!(t.find(9.9), 3);
    }

    #[test]
    fn ring_buffer_wraps() {
        let mut r = PrioritizedReplay::new(4);
        for i in 0..10 {
            r.push(tr(i as f32));
        }
        assert_eq!(r.len(), 4);
        // newest 4 survive: 6,7,8,9 in some ring order
        let vals: Vec<f32> = (0..4).map(|i| r.get(i).r).collect();
        for v in [6.0, 7.0, 8.0, 9.0] {
            assert!(vals.contains(&v), "{vals:?}");
        }
    }

    #[test]
    fn high_priority_sampled_more() {
        let mut r = PrioritizedReplay::new(8);
        for i in 0..8 {
            r.push(tr(i as f32));
        }
        // index 3 gets huge TD error
        r.update_priorities(&[3], &[50.0]);
        r.update_priorities(&[0, 1, 2, 4, 5, 6, 7], &[0.01; 7]);
        let mut rng = Rng::new(5);
        let mut count3 = 0;
        let mut total = 0;
        for _ in 0..200 {
            let (idx, _) = r.sample(4, &mut rng);
            count3 += idx.iter().filter(|&&i| i == 3).count();
            total += 4;
        }
        assert!(
            count3 as f64 / total as f64 > 0.5,
            "index 3 sampled {count3}/{total}"
        );
    }

    #[test]
    fn state_roundtrip_samples_identically() {
        let mut a = PrioritizedReplay::new(8);
        for i in 0..11 {
            a.push(tr(i as f32)); // wraps: exercises pos + ring state
        }
        a.update_priorities(&[1, 3], &[4.0, 0.2]);
        a.anneal_beta(0.35);
        let mut w = crate::io::bin::BinWriter::new();
        a.save_state(&mut w);
        let mut b = PrioritizedReplay::new(8);
        let mut r = crate::io::bin::BinReader::new(&w.buf);
        b.load_state(&mut r).unwrap();
        assert_eq!(a.len(), b.len());
        let mut rng_a = Rng::new(77);
        let mut rng_b = Rng::new(77);
        for _ in 0..50 {
            let (ia, wa) = a.sample(4, &mut rng_a);
            let (ib, wb) = b.sample(4, &mut rng_b);
            assert_eq!(ia, ib);
            for (x, y) in wa.iter().zip(&wb) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        // capacity mismatch is rejected
        let mut c = PrioritizedReplay::new(16);
        let mut r2 = crate::io::bin::BinReader::new(&w.buf);
        assert!(c.load_state(&mut r2).is_err());
    }

    #[test]
    fn is_weights_bounded() {
        let mut r = PrioritizedReplay::new(16);
        for i in 0..16 {
            r.push(tr(i as f32));
        }
        let mut rng = Rng::new(9);
        let (_, w) = r.sample(8, &mut rng);
        assert!(w.iter().all(|&x| x > 0.0 && x <= 1.0 + 1e-6), "{w:?}");
    }
}
