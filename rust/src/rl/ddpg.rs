//! DDPG agent (paper §4.2.1): actor-critic over the continuous 2-d
//! action (pruning ratio, precision), target networks, truncated-normal
//! exploration noise, prioritized replay.
//!
//! Hyper-parameters follow §5.1 verbatim: 3×300 hidden layers, actor lr
//! 1e-3 / critic lr 1e-4, noise σ₀ = 0.6 with ×0.99 per-episode decay
//! after warm-up, γ = 1, batch 64, replay capacity 1000.

use crate::nn::mat::Mat;
use crate::nn::{Act, Mlp};
use crate::util::rng::Rng;

use super::replay::{PrioritizedReplay, Transition};

/// DDPG hyper-parameters (§5.1 defaults).
#[derive(Clone, Debug)]
pub struct DdpgConfig {
    /// state embedding dimension
    pub state_dim: usize,
    /// continuous action dimension (2 = ratio + precision)
    pub action_dim: usize,
    /// hidden width of actor & critic (paper: 300)
    pub hidden: usize,
    /// actor learning rate
    pub actor_lr: f32,
    /// critic learning rate
    pub critic_lr: f32,
    /// Polyak target-update coefficient
    pub tau: f32,
    /// discount factor (paper: 1)
    pub gamma: f32,
    /// replay sample batch
    pub batch: usize,
    /// replay capacity
    pub replay_cap: usize,
    /// initial truncated-normal exploration σ
    pub noise_init: f64,
    /// per-episode σ decay after warm-up
    pub noise_decay: f64,
}

impl Default for DdpgConfig {
    fn default() -> Self {
        DdpgConfig {
            state_dim: crate::env::STATE_DIM,
            action_dim: 2,
            hidden: 300,
            actor_lr: 1e-3,
            critic_lr: 1e-4,
            tau: 0.01,
            gamma: 1.0,
            batch: 64,
            replay_cap: 1000,
            noise_init: 0.6,
            noise_decay: 0.99,
        }
    }
}

/// The DDPG actor-critic agent.
pub struct Ddpg {
    /// hyper-parameters
    pub cfg: DdpgConfig,
    /// the policy network (sigmoid head onto the unit box)
    pub actor: Mlp,
    /// the Q network over [state, action]
    pub critic: Mlp,
    target_actor: Mlp,
    target_critic: Mlp,
    /// prioritized experience replay
    pub replay: PrioritizedReplay,
    /// current exploration σ
    pub noise: f64,
    t: u64,
    rng: Rng,
}

impl Ddpg {
    /// Build actor/critic + targets from the config.
    pub fn new(cfg: DdpgConfig, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let h = cfg.hidden;
        // actor: s -> [ratio, bits] in [0,1]^2 (sigmoid head)
        let actor = Mlp::new(
            &[cfg.state_dim, h, h, h, cfg.action_dim],
            &[Act::Relu, Act::Relu, Act::Relu, Act::Sigmoid],
            &mut rng,
        );
        // critic: [s, a] -> Q
        let critic = Mlp::new(
            &[cfg.state_dim + cfg.action_dim, h, h, h, 1],
            &[Act::Relu, Act::Relu, Act::Relu, Act::None],
            &mut rng,
        );
        let target_actor = actor.clone();
        let target_critic = critic.clone();
        Ddpg {
            replay: PrioritizedReplay::new(cfg.replay_cap),
            noise: cfg.noise_init,
            t: 0,
            rng,
            actor,
            critic,
            target_actor,
            target_critic,
            cfg,
        }
    }

    /// Deterministic policy output for one state.
    pub fn act_greedy(&self, s: &[f32]) -> Vec<f32> {
        let x = Mat::from_vec(1, s.len(), s.to_vec());
        self.actor.forward(&x).d
    }

    /// Exploratory action: truncated-normal noise around the policy
    /// (§4.2.1), clamped to the unit box.
    pub fn act(&mut self, s: &[f32], explore: bool) -> Vec<f32> {
        let mut a = self.act_greedy(s);
        if explore {
            for x in a.iter_mut() {
                *x = self
                    .rng
                    .trunc_normal(*x as f64, self.noise, 0.0, 1.0) as f32;
            }
        }
        a
    }

    /// Last hidden layer of the actor — the feature tap the Rainbow
    /// agent consumes (§4.2.2, Fig 4).
    pub fn features(&self, s: &[f32]) -> Vec<f32> {
        let x = Mat::from_vec(1, s.len(), s.to_vec());
        // hidden index: layer (depth-2) output == last hidden
        self.actor.hidden(&x, self.actor.layers.len() - 2).d
    }

    /// Width of the feature tap ([`Self::features`]).
    pub fn feature_dim(&self) -> usize {
        self.cfg.hidden
    }

    /// Store one transition in replay.
    pub fn observe(&mut self, tr: Transition) {
        self.replay.push(tr);
    }

    /// Decay exploration noise once per episode (after warm-up).
    pub fn decay_noise(&mut self) {
        self.noise *= self.cfg.noise_decay;
    }

    /// Export agent parameters (actor/critic + targets) for checkpointing.
    pub fn export(&self, out: &mut Vec<(String, crate::tensor::Tensor)>) {
        self.actor.export("ddpg.actor", out);
        self.critic.export("ddpg.critic", out);
        self.target_actor.export("ddpg.target_actor", out);
        self.target_critic.export("ddpg.target_critic", out);
        out.push((
            "ddpg.meta".into(),
            crate::tensor::Tensor::new(vec![2], vec![self.noise as f32, self.t as f32]),
        ));
    }

    /// Import a checkpoint written by [`Self::export`]. Replay contents
    /// are deliberately not persisted (fresh experiences are cheap and
    /// stale ones harmful after environment changes).
    pub fn import(
        &mut self,
        get: &dyn Fn(&str) -> anyhow::Result<crate::tensor::Tensor>,
    ) -> anyhow::Result<()> {
        self.actor.import("ddpg.actor", get)?;
        self.critic.import("ddpg.critic", get)?;
        self.target_actor.import("ddpg.target_actor", get)?;
        self.target_critic.import("ddpg.target_critic", get)?;
        let meta = get("ddpg.meta")?;
        self.noise = meta.data[0] as f64;
        self.t = meta.data[1] as u64;
        Ok(())
    }

    /// Serialise the complete agent (all four nets with Adam moments,
    /// replay buffer, exploration schedule, RNG) for bit-exact search
    /// resume. Contrast with [`Self::export`], the lossy f32 NPZ policy
    /// export that deliberately drops replay and optimiser state.
    pub fn save_state(&self, w: &mut crate::io::bin::BinWriter) {
        self.actor.save_state(w);
        self.critic.save_state(w);
        self.target_actor.save_state(w);
        self.target_critic.save_state(w);
        self.replay.save_state(w);
        w.f64(self.noise);
        w.u64(self.t);
        self.rng.save_state(w);
    }

    /// Restore a state written by [`Self::save_state`] into a
    /// same-config agent.
    pub fn load_state(&mut self, r: &mut crate::io::bin::BinReader) -> anyhow::Result<()> {
        self.actor.load_state(r)?;
        self.critic.load_state(r)?;
        self.target_actor.load_state(r)?;
        self.target_critic.load_state(r)?;
        self.replay.load_state(r)?;
        self.noise = r.f64()?;
        self.t = r.u64()?;
        self.rng.load_state(r)?;
        Ok(())
    }

    /// One gradient update from replay; returns the critic TD loss.
    pub fn update(&mut self) -> Option<f32> {
        let b = self.cfg.batch;
        if self.replay.len() < b {
            return None;
        }
        self.t += 1;
        let (idx, isw) = self.replay.sample(b, &mut self.rng);
        let sd = self.cfg.state_dim;
        let ad = self.cfg.action_dim;

        // batched tensors
        let mut s = Mat::zeros(b, sd);
        let mut s2 = Mat::zeros(b, sd);
        let mut sa = Mat::zeros(b, sd + ad);
        let mut r = vec![0f32; b];
        let mut done = vec![false; b];
        for (bi, &i) in idx.iter().enumerate() {
            let tr = self.replay.get(i);
            s.d[bi * sd..(bi + 1) * sd].copy_from_slice(&tr.s);
            s2.d[bi * sd..(bi + 1) * sd].copy_from_slice(&tr.s2);
            sa.d[bi * (sd + ad)..bi * (sd + ad) + sd].copy_from_slice(&tr.s);
            sa.d[bi * (sd + ad) + sd..(bi + 1) * (sd + ad)].copy_from_slice(&tr.a);
            r[bi] = tr.r;
            done[bi] = tr.done;
        }

        // target: y = r + γ (1-done) Q'(s2, μ'(s2))
        let a2 = self.target_actor.forward(&s2);
        let mut s2a2 = Mat::zeros(b, sd + ad);
        for bi in 0..b {
            s2a2.d[bi * (sd + ad)..bi * (sd + ad) + sd]
                .copy_from_slice(s2.row_slice(bi));
            s2a2.d[bi * (sd + ad) + sd..(bi + 1) * (sd + ad)]
                .copy_from_slice(a2.row_slice(bi));
        }
        let q2 = self.target_critic.forward(&s2a2);
        let y: Vec<f32> = (0..b)
            .map(|bi| {
                r[bi] + if done[bi] { 0.0 } else { self.cfg.gamma * q2.at(bi, 0) }
            })
            .collect();

        // critic update (IS-weighted MSE)
        let cache = self.critic.forward_cached(&sa);
        let q = cache.outs.last().unwrap().clone();
        let mut dq = Mat::zeros(b, 1);
        let mut td = vec![0f32; b];
        let mut loss = 0.0;
        for bi in 0..b {
            let e = q.at(bi, 0) - y[bi];
            td[bi] = e;
            let wgt = isw[bi] / b as f32;
            *dq.at_mut(bi, 0) = e * wgt;
            loss += 0.5 * e * e * wgt;
        }
        self.critic.zero_grad();
        self.critic.backward(&cache, &dq);
        self.critic.adam(self.cfg.critic_lr, self.t as f32);
        self.replay.update_priorities(&idx, &td);

        // actor update: ascend Q(s, μ(s))
        let acache = self.actor.forward_cached(&s);
        let a = acache.outs.last().unwrap().clone();
        let mut sa2 = Mat::zeros(b, sd + ad);
        for bi in 0..b {
            sa2.d[bi * (sd + ad)..bi * (sd + ad) + sd].copy_from_slice(s.row_slice(bi));
            sa2.d[bi * (sd + ad) + sd..(bi + 1) * (sd + ad)]
                .copy_from_slice(a.row_slice(bi));
        }
        let ccache = self.critic.forward_cached(&sa2);
        let ones = Mat::full(b, 1, -1.0 / b as f32); // maximize Q => minimize -Q
        self.critic.zero_grad(); // grads only used to get dQ/da
        let dinput = self.critic.backward(&ccache, &ones);
        // slice out dQ/da
        let mut da = Mat::zeros(b, ad);
        for bi in 0..b {
            da.d[bi * ad..(bi + 1) * ad]
                .copy_from_slice(&dinput.d[bi * (sd + ad) + sd..(bi + 1) * (sd + ad)]);
        }
        self.actor.zero_grad();
        self.actor.backward(&acache, &da);
        self.actor.adam(self.cfg.actor_lr, self.t as f32);
        self.critic.zero_grad(); // don't leak actor-pass grads into next step

        // polyak targets
        self.target_actor.soft_update_from(&self.actor, self.cfg.tau);
        self.target_critic.soft_update_from(&self.critic, self.cfg.tau);
        Some(loss)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A 1-step bandit-ish control problem: state in R^14, best action is
    /// a = clamp(state[0..2]); reward = -(a - target)^2. DDPG must push
    /// its policy toward the target.
    #[test]
    fn learns_simple_bandit() {
        let cfg = DdpgConfig {
            batch: 32,
            replay_cap: 512,
            noise_init: 0.4,
            actor_lr: 3e-3,
            critic_lr: 3e-3,
            hidden: 32,
            ..DdpgConfig::default()
        };
        let mut agent = Ddpg::new(cfg, 7);
        let mut rng = Rng::new(1);
        let mut final_err = f64::MAX;
        for ep in 0..600 {
            let mut s = vec![0f32; crate::env::STATE_DIM];
            s[0] = rng.uniform() as f32;
            s[1] = rng.uniform() as f32;
            let target = [s[0] * 0.5 + 0.25, 0.8 - 0.5 * s[1]];
            let a = agent.act(&s, true);
            let r = -((a[0] - target[0]).powi(2) + (a[1] - target[1]).powi(2));
            agent.observe(Transition {
                s: s.clone(),
                a: a.clone(),
                alg: 0,
                r,
                s2: vec![0.0; crate::env::STATE_DIM],
                done: true,
            });
            agent.update();
            if ep % 10 == 0 {
                agent.decay_noise();
            }
            if ep > 550 {
                let g = agent.act_greedy(&s);
                final_err = ((g[0] - target[0]).powi(2) + (g[1] - target[1]).powi(2))
                    .sqrt() as f64;
            }
        }
        assert!(final_err < 0.35, "policy error {final_err}");
    }

    #[test]
    fn features_have_hidden_dim() {
        let agent = Ddpg::new(DdpgConfig::default(), 3);
        let f = agent.features(&vec![0.1; crate::env::STATE_DIM]);
        assert_eq!(f.len(), 300);
    }

    #[test]
    fn noise_decays() {
        let mut agent = Ddpg::new(DdpgConfig::default(), 3);
        let n0 = agent.noise;
        agent.decay_noise();
        assert!(agent.noise < n0);
    }

    #[test]
    fn actions_in_unit_box() {
        let mut agent = Ddpg::new(DdpgConfig::default(), 4);
        for i in 0..50 {
            let s = vec![(i as f32 * 0.13).sin(); crate::env::STATE_DIM];
            let a = agent.act(&s, true);
            assert!(a.iter().all(|&x| (0.0..=1.0).contains(&x)), "{a:?}");
        }
    }
}
