//! Rainbow agent (paper §4.2.2) for the discrete pruning-algorithm
//! action: Double Q-learning + dueling heads + noisy nets + C51
//! distributional output + prioritized replay + n-step returns.
//!
//! Its input is NOT the raw env state: it consumes the output of the
//! DDPG actor's feature extractor (the last hidden layer), per Fig 4 —
//! "the Rainbow model learns to associate abstract features of pruning
//! and quantization with the best fitted technique". The loss does not
//! back-propagate into the DDPG actor (§4.2.2).

use crate::nn::mat::Mat;
use crate::nn::{act_backward, act_forward, Act, Dense, NoisyDense};
use crate::pruning::PruneAlg;
use crate::util::rng::Rng;

use super::replay::{PrioritizedReplay, Transition};

/// Rainbow hyper-parameters.
#[derive(Clone, Debug)]
pub struct RainbowConfig {
    /// input feature dimension (= DDPG actor hidden width)
    pub feat_dim: usize,
    /// trunk hidden width
    pub hidden: usize,
    /// discrete action count (= number of pruning algorithms)
    pub n_actions: usize,
    /// C51 distribution support size
    pub atoms: usize,
    /// support lower bound
    pub v_min: f32,
    /// support upper bound
    pub v_max: f32,
    /// learning rate
    pub lr: f32,
    /// discount factor (paper: 1)
    pub gamma: f32,
    /// replay sample batch
    pub batch: usize,
    /// replay capacity
    pub replay_cap: usize,
    /// n-step return length
    pub n_step: usize,
    /// target-network sync period (updates)
    pub target_sync: u64,
}

impl Default for RainbowConfig {
    fn default() -> Self {
        RainbowConfig {
            feat_dim: 300,
            hidden: 128,
            n_actions: PruneAlg::ALL.len(),
            atoms: 51,
            v_min: -8.0,
            v_max: 12.0,
            lr: 6.25e-5 * 4.0, // Rainbow lr scaled for the small net
            gamma: 1.0,
            batch: 64,
            replay_cap: 1000,
            n_step: 3,
            target_sync: 100,
        }
    }
}

struct Net {
    trunk: Dense,
    value: NoisyDense,
    adv: NoisyDense,
}

impl Net {
    fn new(cfg: &RainbowConfig, rng: &mut Rng) -> Net {
        Net {
            trunk: Dense::new(cfg.feat_dim, cfg.hidden, rng),
            value: NoisyDense::new(cfg.hidden, cfg.atoms, rng),
            adv: NoisyDense::new(cfg.hidden, cfg.n_actions * cfg.atoms, rng),
        }
    }

    fn resample(&mut self, rng: &mut Rng) {
        self.value.resample(rng);
        self.adv.resample(rng);
    }

    fn set_noisy(&mut self, on: bool) {
        self.value.noisy = on;
        self.adv.noisy = on;
    }

    /// Returns (h post-relu, per-action atom log-probabilities flattened
    /// [b, nA*Z] as probabilities p, and the pre-softmax logits).
    fn forward(&self, cfg: &RainbowConfig, f: &Mat) -> (Mat, Mat, Mat) {
        let mut h = self.trunk.forward(f);
        act_forward(Act::Relu, &mut h);
        let v = self.value.forward(&h); // [b, Z]
        let a = self.adv.forward(&h); // [b, nA*Z]
        let (na, z) = (cfg.n_actions, cfg.atoms);
        let b = f.r;
        let mut logits = Mat::zeros(b, na * z);
        for bi in 0..b {
            for zi in 0..z {
                let mut mean = 0.0f32;
                for ai in 0..na {
                    mean += a.at(bi, ai * z + zi);
                }
                mean /= na as f32;
                for ai in 0..na {
                    *logits.at_mut(bi, ai * z + zi) =
                        v.at(bi, zi) + a.at(bi, ai * z + zi) - mean;
                }
            }
        }
        // softmax over atoms per action
        let mut p = logits.clone();
        for bi in 0..b {
            for ai in 0..na {
                let row = &mut p.d[bi * na * z + ai * z..bi * na * z + (ai + 1) * z];
                let m = row.iter().cloned().fold(f32::MIN, f32::max);
                let mut sum = 0.0;
                for x in row.iter_mut() {
                    *x = (*x - m).exp();
                    sum += *x;
                }
                for x in row.iter_mut() {
                    *x /= sum;
                }
            }
        }
        (h, p, logits)
    }

    fn zero_grad(&mut self) {
        self.trunk.zero_grad();
        self.value.zero_grad();
        self.adv.zero_grad();
    }

    fn adam(&mut self, lr: f32, t: f32) {
        self.trunk.adam(lr, t);
        self.value.adam(lr, t);
        self.adv.adam(lr, t);
    }

    fn clone_weights_from(&mut self, src: &Net) {
        self.trunk.soft_update_from(&src.trunk, 1.0);
        self.value.soft_update_from(&src.value, 1.0);
        self.adv.soft_update_from(&src.adv, 1.0);
    }

    fn export(&self, prefix: &str, out: &mut Vec<(String, crate::tensor::Tensor)>) {
        self.trunk.export(&format!("{prefix}.trunk"), out);
        self.value.export(&format!("{prefix}.value"), out);
        self.adv.export(&format!("{prefix}.adv"), out);
    }

    fn import(
        &mut self,
        prefix: &str,
        get: &dyn Fn(&str) -> anyhow::Result<crate::tensor::Tensor>,
    ) -> anyhow::Result<()> {
        self.trunk.import(&format!("{prefix}.trunk"), get)?;
        self.value.import(&format!("{prefix}.value"), get)?;
        self.adv.import(&format!("{prefix}.adv"), get)?;
        Ok(())
    }

    fn save_state(&self, w: &mut crate::io::bin::BinWriter) {
        self.trunk.save_state(w);
        self.value.save_state(w);
        self.adv.save_state(w);
    }

    fn load_state(&mut self, r: &mut crate::io::bin::BinReader) -> anyhow::Result<()> {
        self.trunk.load_state(r)?;
        self.value.load_state(r)?;
        self.adv.load_state(r)?;
        Ok(())
    }
}

/// The Rainbow distributional agent.
pub struct Rainbow {
    /// hyper-parameters
    pub cfg: RainbowConfig,
    online: Net,
    target: Net,
    /// prioritized experience replay
    pub replay: PrioritizedReplay,
    support: Vec<f32>,
    /// pending n-step window: (features, action, reward)
    pending: Vec<(Vec<f32>, usize, f32)>,
    t: u64,
    rng: Rng,
}

impl Rainbow {
    /// Build online + target nets and the C51 support.
    pub fn new(cfg: RainbowConfig, seed: u64) -> Rainbow {
        let mut rng = Rng::new(seed);
        let online = Net::new(&cfg, &mut rng);
        let mut target = Net::new(&cfg, &mut rng);
        target.clone_weights_from(&online);
        let z = cfg.atoms;
        let support = (0..z)
            .map(|i| cfg.v_min + (cfg.v_max - cfg.v_min) * i as f32 / (z - 1) as f32)
            .collect();
        Rainbow {
            replay: PrioritizedReplay::new(cfg.replay_cap),
            support,
            pending: Vec::new(),
            t: 0,
            rng,
            online,
            target,
            cfg,
        }
    }

    /// Expected Q per action for one feature vector.
    pub fn q_values(&mut self, f: &[f32]) -> Vec<f32> {
        self.online.resample(&mut self.rng);
        let x = Mat::from_vec(1, f.len(), f.to_vec());
        let (_, p, _) = self.online.forward(&self.cfg, &x);
        let (na, z) = (self.cfg.n_actions, self.cfg.atoms);
        (0..na)
            .map(|ai| {
                (0..z)
                    .map(|zi| p.at(0, ai * z + zi) * self.support[zi])
                    .sum::<f32>()
            })
            .collect()
    }

    /// Greedy action under the (noisy — exploration comes from the noise)
    /// online network.
    pub fn act(&mut self, f: &[f32]) -> usize {
        let q = self.q_values(f);
        q.iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(0)
    }

    /// Record a step; n-step transitions are assembled internally (γ = 1
    /// per §5.1 makes the n-step return a plain sum).
    pub fn observe(&mut self, f: Vec<f32>, action: usize, r: f32, f2: Vec<f32>, done: bool) {
        self.pending.push((f, action, r));
        let n = self.cfg.n_step;
        if self.pending.len() >= n {
            let ret: f32 = self.pending[self.pending.len() - n..]
                .iter()
                .map(|(_, _, r)| *r)
                .sum();
            let (s, a, _) = self.pending[self.pending.len() - n].clone();
            self.replay.push(Transition {
                s,
                a: vec![],
                alg: a,
                r: ret,
                s2: f2.clone(),
                done,
            });
        }
        if done {
            // flush the shorter tails
            let len = self.pending.len();
            let lo = len.saturating_sub(n - 1);
            for i in lo..len {
                let ret: f32 = self.pending[i..].iter().map(|(_, _, r)| *r).sum();
                let (s, a, _) = self.pending[i].clone();
                self.replay.push(Transition {
                    s,
                    a: vec![],
                    alg: a,
                    r: ret,
                    s2: f2.clone(),
                    done: true,
                });
            }
            self.pending.clear();
        }
    }

    /// One distributional-RL update; returns mean cross-entropy loss.
    pub fn update(&mut self) -> Option<f32> {
        let b = self.cfg.batch;
        if self.replay.len() < b {
            return None;
        }
        self.t += 1;
        let (idx, isw) = self.replay.sample(b, &mut self.rng);
        let fd = self.cfg.feat_dim;
        let (na, z) = (self.cfg.n_actions, self.cfg.atoms);
        let dz = (self.cfg.v_max - self.cfg.v_min) / (z - 1) as f32;

        let mut s = Mat::zeros(b, fd);
        let mut s2 = Mat::zeros(b, fd);
        let mut acts = vec![0usize; b];
        let mut rews = vec![0f32; b];
        let mut dones = vec![false; b];
        for (bi, &i) in idx.iter().enumerate() {
            let tr = self.replay.get(i);
            s.d[bi * fd..(bi + 1) * fd].copy_from_slice(&tr.s);
            s2.d[bi * fd..(bi + 1) * fd].copy_from_slice(&tr.s2);
            acts[bi] = tr.alg;
            rews[bi] = tr.r;
            dones[bi] = tr.done;
        }

        // --- target distribution (Double DQN + C51 projection) ---
        self.online.resample(&mut self.rng);
        let (_, p2_online, _) = self.online.forward(&self.cfg, &s2);
        self.target.resample(&mut self.rng);
        let (_, p2_target, _) = self.target.forward(&self.cfg, &s2);
        let gamma_n = self.cfg.gamma.powi(self.cfg.n_step as i32);
        let mut m = Mat::zeros(b, z);
        for bi in 0..b {
            // a* from the online net
            let mut best_a = 0;
            let mut best_q = f32::MIN;
            for ai in 0..na {
                let q: f32 = (0..z)
                    .map(|zi| p2_online.at(bi, ai * z + zi) * self.support[zi])
                    .sum();
                if q > best_q {
                    best_q = q;
                    best_a = ai;
                }
            }
            for zi in 0..z {
                let pz = p2_target.at(bi, best_a * z + zi);
                let tz = (rews[bi]
                    + if dones[bi] { 0.0 } else { gamma_n * self.support[zi] })
                    .clamp(self.cfg.v_min, self.cfg.v_max);
                let pos = (tz - self.cfg.v_min) / dz;
                let lo = pos.floor() as usize;
                let hi = pos.ceil() as usize;
                if lo == hi {
                    *m.at_mut(bi, lo) += pz;
                } else {
                    *m.at_mut(bi, lo) += pz * (hi as f32 - pos);
                    *m.at_mut(bi, hi.min(z - 1)) += pz * (pos - lo as f32);
                }
            }
        }

        // --- online forward + cross-entropy backward ---
        self.online.resample(&mut self.rng);
        let (h, p, _) = self.online.forward(&self.cfg, &s);
        let mut dlogits = Mat::zeros(b, na * z);
        let mut td = vec![0f32; b];
        let mut loss = 0.0f32;
        for bi in 0..b {
            let a = acts[bi];
            let wgt = isw[bi] / b as f32;
            let mut ce = 0.0f32;
            for zi in 0..z {
                let pi = p.at(bi, a * z + zi).max(1e-8);
                let mi = m.at(bi, zi);
                ce -= mi * pi.ln();
                *dlogits.at_mut(bi, a * z + zi) = (pi - mi) * wgt;
            }
            td[bi] = ce;
            loss += ce * wgt;
        }
        self.replay.update_priorities(&idx, &td);

        // dueling backward: dV = Σ_a dlogits, dA = dlogits - mean_a dlogits
        let mut dv = Mat::zeros(b, z);
        let mut da = Mat::zeros(b, na * z);
        for bi in 0..b {
            for zi in 0..z {
                let mut sum = 0.0f32;
                for ai in 0..na {
                    sum += dlogits.at(bi, ai * z + zi);
                }
                *dv.at_mut(bi, zi) = sum;
                let mean = sum / na as f32;
                for ai in 0..na {
                    *da.at_mut(bi, ai * z + zi) = dlogits.at(bi, ai * z + zi) - mean;
                }
            }
        }
        self.online.zero_grad();
        let dh_v = self.online.value.backward(&h, &dv);
        let dh_a = self.online.adv.backward(&h, &da);
        let mut dh = dh_v;
        dh.add_assign(&dh_a);
        act_backward(Act::Relu, &h, &mut dh);
        let _ = self.online.trunk.backward(&s, &dh);
        self.online.adam(self.cfg.lr, self.t as f32);

        if self.t % self.cfg.target_sync == 0 {
            self.target.clone_weights_from(&self.online);
        }
        Some(loss)
    }

    /// Export agent parameters for checkpointing.
    pub fn export(&self, out: &mut Vec<(String, crate::tensor::Tensor)>) {
        self.online.export("rainbow.online", out);
        self.target.export("rainbow.target", out);
        out.push((
            "rainbow.meta".into(),
            crate::tensor::Tensor::new(vec![1], vec![self.t as f32]),
        ));
    }

    /// Import a checkpoint written by [`Self::export`].
    pub fn import(
        &mut self,
        get: &dyn Fn(&str) -> anyhow::Result<crate::tensor::Tensor>,
    ) -> anyhow::Result<()> {
        self.online.import("rainbow.online", get)?;
        self.target.import("rainbow.target", get)?;
        self.t = get("rainbow.meta")?.data[0] as u64;
        Ok(())
    }

    /// Disable noise (greedy evaluation mode).
    pub fn set_eval(&mut self, eval: bool) {
        self.online.set_noisy(!eval);
    }

    /// Serialise the complete agent (online + target nets with Adam
    /// moments and current noise draws, replay, the pending n-step
    /// window, step counter, RNG) for bit-exact search resume.
    pub fn save_state(&self, w: &mut crate::io::bin::BinWriter) {
        self.online.save_state(w);
        self.target.save_state(w);
        self.replay.save_state(w);
        w.usize(self.pending.len());
        for (f, a, r) in &self.pending {
            w.f32s(f);
            w.usize(*a);
            w.f32(*r);
        }
        w.u64(self.t);
        self.rng.save_state(w);
    }

    /// Restore a state written by [`Self::save_state`] into a
    /// same-config agent.
    pub fn load_state(&mut self, r: &mut crate::io::bin::BinReader) -> anyhow::Result<()> {
        self.online.load_state(r)?;
        self.target.load_state(r)?;
        self.replay.load_state(r)?;
        let n = r.usize()?;
        self.pending.clear();
        for _ in 0..n {
            let f = r.f32s()?;
            let a = r.usize()?;
            let rew = r.f32()?;
            self.pending.push((f, a, rew));
        }
        self.t = r.u64()?;
        self.rng.load_state(r)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Contextual bandit: feature f ∈ R^8; the correct discrete action is
    /// determined by which of 3 slots of f is largest. Reward 1/0.
    #[test]
    fn learns_contextual_bandit() {
        let cfg = RainbowConfig {
            feat_dim: 8,
            hidden: 32,
            n_actions: 3,
            atoms: 21,
            v_min: -1.0,
            v_max: 2.0,
            lr: 2e-3,
            batch: 32,
            replay_cap: 512,
            n_step: 1,
            target_sync: 50,
            ..RainbowConfig::default()
        };
        let mut agent = Rainbow::new(cfg, 11);
        let mut rng = Rng::new(3);
        for _ in 0..900 {
            let mut f = vec![0f32; 8];
            for x in f.iter_mut() {
                *x = rng.uniform() as f32;
            }
            let best = f[..3]
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .unwrap()
                .0;
            let a = agent.act(&f);
            let r = if a == best { 1.0 } else { 0.0 };
            agent.observe(f, a, r, vec![0.0; 8], true);
            agent.update();
        }
        // evaluate greedily
        agent.set_eval(true);
        let mut correct = 0;
        for _ in 0..100 {
            let mut f = vec![0f32; 8];
            for x in f.iter_mut() {
                *x = rng.uniform() as f32;
            }
            let best = f[..3]
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .unwrap()
                .0;
            if agent.act(&f) == best {
                correct += 1;
            }
        }
        assert!(correct > 65, "bandit accuracy {correct}/100");
    }

    #[test]
    fn n_step_assembles_returns() {
        let cfg = RainbowConfig {
            feat_dim: 2,
            n_step: 3,
            replay_cap: 64,
            ..RainbowConfig::default()
        };
        let mut agent = Rainbow::new(cfg, 1);
        for i in 0..5 {
            let done = i == 4;
            agent.observe(vec![i as f32, 0.0], 0, 1.0, vec![i as f32 + 1.0, 0.0], done);
        }
        // 5 steps with n=3: windows (0..3),(1..4),(2..5) + tail flush (3..5),(4..5)
        assert_eq!(agent.replay.len(), 5);
        let rs: Vec<f32> = (0..agent.replay.len()).map(|i| agent.replay.get(i).r).collect();
        assert!(rs.contains(&3.0) && rs.contains(&2.0) && rs.contains(&1.0), "{rs:?}");
    }

    #[test]
    fn q_values_finite_and_sized() {
        let mut agent = Rainbow::new(RainbowConfig::default(), 5);
        let q = agent.q_values(&vec![0.3; 300]);
        assert_eq!(q.len(), PruneAlg::ALL.len());
        assert!(q.iter().all(|x| x.is_finite()));
    }
}
