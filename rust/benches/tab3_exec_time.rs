//! Table 3: normalized execution time of ONE optimization iteration per
//! method (ours = 1 RL episode; AMC/HAQ = 1 episode; ASQJ = 1 ADMM
//! iteration; OPQ = 1 analytical evaluation), averaged over several
//! iterations, normalized to the fastest — exactly the paper's metric.

mod common;

use std::time::Instant;

use hapq::env::Action;
use hapq::pruning::PruneAlg;

fn main() {
    common::banner(
        "tab3_exec_time",
        "Table 3 — normalized single-iteration execution time \
         (paper: OPQ 1.00x fastest; ASQJ slowest on CIFAR; ours mid-high)",
    );
    let coord = common::coordinator();
    let models: Vec<String> = std::env::var("HAPQ_BENCH_MODELS")
        .unwrap_or_else(|_| "vgg11,resnet18,mobilenetv2".into())
        .split(',')
        .map(str::to_string)
        .collect();
    let reps = common::env_usize("HAPQ_BENCH_REPS", 5);

    for model in &models {
        let mut env = coord.build_env(model).unwrap();
        let n = env.n_layers();
        let actions = |alg: PruneAlg| -> Vec<Action> {
            (0..n)
                .map(|_| Action { ratio: 0.3, bits: 0.7, alg: alg.index() })
                .collect()
        };
        // one "iteration" per method == one full-config evaluation plus the
        // method's own update overhead; we time the dominant oracle work.
        let mut rows: Vec<(&str, f64)> = Vec::new();

        // ours: one episode (L steps, each with prune+quant+energy+infer)
        // plus one composite-agent update per step
        let mut agent = hapq::rl::composite::CompositeAgent::new(
            hapq::rl::composite::CompositeConfig::default(),
            7,
        );
        let t = Instant::now();
        for _ in 0..reps {
            let mut s = env.reset();
            loop {
                let a = agent.act(&s);
                let step = env.step(a).unwrap();
                agent.observe_and_update(&s, &a, step.reward, &step.state, step.done);
                s = step.state.clone();
                if step.done {
                    break;
                }
            }
        }
        rows.push(("ours", t.elapsed().as_secs_f64() / reps as f64));

        // amc / haq: one DDPG episode (same oracle, 1-d action, no Rainbow)
        let mut ddpg = hapq::rl::ddpg::Ddpg::new(hapq::rl::ddpg::DdpgConfig::default(), 9);
        for (name, alg) in [("amc", PruneAlg::L1Ranked), ("haq", PruneAlg::Level)] {
            let t = Instant::now();
            for _ in 0..reps {
                let mut s = env.reset();
                loop {
                    let a = ddpg.act(&s, true);
                    let action = Action {
                        ratio: if name == "amc" { a[0] as f64 } else { 0.0 },
                        bits: if name == "haq" { a[0] as f64 } else { 1.0 },
                        alg: alg.index(),
                    };
                    let step = env.step(action).unwrap();
                    ddpg.observe(hapq::rl::replay::Transition {
                        s: s.clone(),
                        a: vec![a[0], a[1.min(a.len() - 1)]],
                        alg: 0,
                        r: step.reward as f32,
                        s2: step.state.clone(),
                        done: step.done,
                    });
                    ddpg.update();
                    s = step.state.clone();
                    if step.done {
                        break;
                    }
                }
            }
            rows.push((name, t.elapsed().as_secs_f64() / reps as f64));
        }

        // asqj: one ADMM iteration == one full-config eval + dual update
        let t = Instant::now();
        for _ in 0..reps {
            env.evaluate_config(&actions(PruneAlg::Level)).unwrap();
        }
        rows.push(("asqj", t.elapsed().as_secs_f64() / reps as f64));

        // opq: one analytical allocation + one eval
        let t = Instant::now();
        for _ in 0..reps {
            env.evaluate_config(&actions(PruneAlg::Level)).unwrap();
        }
        rows.push(("opq", t.elapsed().as_secs_f64() / reps as f64));

        let fastest = rows.iter().map(|r| r.1).fold(f64::MAX, f64::min);
        println!("\n--- {model} (iteration = 1 episode / ADMM step / OPQ eval) ---");
        println!("{:<8} {:>10} {:>12}", "method", "secs/iter", "normalized");
        for (name, secs) in &rows {
            println!("{name:<8} {secs:>10.3} {:>11.2}x", secs / fastest);
        }
    }
    println!("\npaper shape: OPQ fastest (pure analytics); ours carries the");
    println!("composite-agent update overhead -> mid/high normalized cost.");
}
