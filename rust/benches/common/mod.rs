//! Shared plumbing for the figure/table bench harnesses (criterion is
//! not vendored; these are `harness = false` binaries that print the
//! paper-style rows and basic timing).
//!
//! All benches default to scaled-down budgets appropriate for the
//! single-core CI box; set `HAPQ_BENCH_EPISODES` (and `--episodes` on
//! the CLI equivalents) to approach the paper's 1100-episode setting.

use hapq::config::RunConfig;
use hapq::coordinator::Coordinator;

pub fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

pub fn bench_config() -> RunConfig {
    let episodes = env_usize("HAPQ_BENCH_EPISODES", 10);
    RunConfig {
        episodes,
        warmup: (episodes / 5).max(2),
        reward_subset: env_usize("HAPQ_BENCH_SUBSET", 128),
        test_subset: 512,
        out: "results/bench".into(),
        ..RunConfig::default()
    }
}

pub fn coordinator() -> Coordinator {
    Coordinator::new(bench_config()).expect("run `make artifacts` before `cargo bench`")
}

pub fn banner(name: &str, paper: &str) {
    println!("\n==================================================================");
    println!("bench: {name}");
    println!("paper: {paper}");
    println!("==================================================================");
}
