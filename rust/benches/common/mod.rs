//! Shared plumbing for the figure/table bench harnesses (criterion is
//! not vendored; these are `harness = false` binaries that print the
//! paper-style rows and basic timing).
//!
//! All benches default to scaled-down budgets appropriate for the
//! single-core CI box; set `HAPQ_BENCH_EPISODES` (and `--episodes` on
//! the CLI equivalents) to approach the paper's 1100-episode setting.

use hapq::config::RunConfig;
use hapq::coordinator::Coordinator;
use hapq::io::json::{self, Value};

pub fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Time `iters` calls of `f` and print the paper-style row; returns
/// seconds per iteration.
#[allow(dead_code)]
pub fn time<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    let t = std::time::Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t.elapsed().as_secs_f64() / iters as f64;
    println!("{name:<38} {:>10.3} ms/iter  ({iters} iters)", per * 1e3);
    per
}

/// Parity-before-timing convention (EXPERIMENTS.md §Perf): every
/// timed pair of equivalent computations asserts bitwise-identical
/// results *first*, so a speedup row can never hide a semantics
/// divergence. f32 buffers compare by `to_bits`.
#[allow(dead_code)]
pub fn assert_f32_bits_eq(label: &str, a: &[f32], b: &[f32]) {
    assert_eq!(a.len(), b.len(), "{label}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{label}: bit-parity violated at index {i} ({x} vs {y})"
        );
    }
}

/// [`assert_f32_bits_eq`] for f64 results (accuracies, gains).
#[allow(dead_code)]
pub fn assert_f64_bits_eq(label: &str, a: &[f64], b: &[f64]) {
    assert_eq!(a.len(), b.len(), "{label}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{label}: bit-parity violated at index {i} ({x} vs {y})"
        );
    }
}

/// Machine-readable bench collector: every timed row, rows-per-second
/// rate, and speedup ratio lands in `BENCH_<name>.json` at the repo
/// root so the perf trajectory is comparable across PRs
/// (EXPERIMENTS.md §Perf documents the schema).
#[allow(dead_code)]
pub struct BenchJson {
    name: &'static str,
    rows: Vec<(String, f64)>,
    rates: Vec<(String, f64)>,
    speedups: Vec<(String, f64)>,
}

#[allow(dead_code)]
impl BenchJson {
    pub fn new(name: &'static str) -> BenchJson {
        BenchJson { name, rows: Vec::new(), rates: Vec::new(), speedups: Vec::new() }
    }

    /// [`time`] + record the seconds-per-iteration row.
    pub fn timed<F: FnMut()>(&mut self, name: &str, iters: usize, f: F) -> f64 {
        let per = time(name, iters, f);
        self.rows.push((name.to_string(), per));
        per
    }

    /// Record a throughput rate (e.g. GEMM output rows per second).
    pub fn rate(&mut self, key: &str, rows_per_sec: f64) {
        println!("{:<38} {:>10.0} rows/s", format!("  -> {key}"), rows_per_sec);
        self.rates.push((key.to_string(), rows_per_sec));
    }

    /// Record and print a `baseline / fast` speedup ratio under a
    /// stable snake_case key (CI greps for these).
    pub fn speedup(&mut self, key: &str, baseline_secs: f64, fast_secs: f64) -> f64 {
        let x = baseline_secs / fast_secs.max(1e-12);
        println!("{:<38} {:>9.2}x", format!("  -> {key}"), x);
        self.speedups.push((key.to_string(), x));
        x
    }

    /// Write `BENCH_<name>.json` at the repo root (one directory above
    /// the crate manifest).
    pub fn write(&self) {
        let kv = |pairs: &[(String, f64)]| {
            json::obj(pairs.iter().map(|(k, v)| (k.as_str(), json::num(*v))).collect())
        };
        let doc: Value = json::obj(vec![
            ("bench", json::s(self.name)),
            ("schema", json::num(1.0)),
            ("secs_per_iter", kv(&self.rows)),
            ("rows_per_sec", kv(&self.rates)),
            ("speedups", kv(&self.speedups)),
        ]);
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("..")
            .join(format!("BENCH_{}.json", self.name));
        match std::fs::write(&path, doc.to_string()) {
            Ok(()) => println!("\nwrote {}", path.display()),
            Err(e) => println!("\nfailed to write {}: {e}", path.display()),
        }
    }
}

pub fn bench_config() -> RunConfig {
    let episodes = env_usize("HAPQ_BENCH_EPISODES", 10);
    RunConfig {
        episodes,
        warmup: (episodes / 5).max(2),
        reward_subset: env_usize("HAPQ_BENCH_SUBSET", 128),
        test_subset: 512,
        out: "results/bench".into(),
        ..RunConfig::default()
    }
}

pub fn coordinator() -> Coordinator {
    Coordinator::new(bench_config()).expect("run `make artifacts` before `cargo bench`")
}

pub fn banner(name: &str, paper: &str) {
    println!("\n==================================================================");
    println!("bench: {name}");
    println!("paper: {paper}");
    println!("==================================================================");
}
