//! Fig 2b: uniform vs per-layer mixed-precision quantization Pareto
//! fronts for ResNet18 (paper: mixed reaches 38.1% energy gain at 0.5%
//! loss vs 9.4% for uniform).

mod common;

use hapq::coordinator::figures::{self, pareto};

fn main() {
    common::banner(
        "fig2b_mixed_vs_uniform",
        "Fig 2b — uniform vs mixed per-layer precision Pareto, ResNet18",
    );
    let coord = common::coordinator();
    let mut env = coord.build_env("resnet18").unwrap();
    let samples = common::env_usize("HAPQ_BENCH_MIXED_SAMPLES", 24);
    let t0 = std::time::Instant::now();
    let pts = figures::fig2b_points(&mut env, samples, 42).unwrap();

    let mut uni = Vec::new();
    let mut mix = Vec::new();
    for p in &pts {
        println!(
            "{:<8} loss {:>6.2}%  gain {:>6.2}%",
            p.kind, p.acc_loss * 100.0, p.energy_gain * 100.0
        );
        if p.kind == "uniform" {
            uni.push((p.acc_loss, p.energy_gain));
        } else {
            mix.push((p.acc_loss, p.energy_gain));
        }
    }
    println!("\nuniform Pareto front:");
    for (l, g) in pareto(&uni) {
        println!("  loss {:>6.2}%  gain {:>6.2}%", l * 100.0, g * 100.0);
    }
    println!("mixed Pareto front:");
    for (l, g) in pareto(&mix) {
        println!("  loss {:>6.2}%  gain {:>6.2}%", l * 100.0, g * 100.0);
    }
    // the paper's claim: at matched small loss, mixed gains exceed uniform
    let best_uni_lowloss = uni
        .iter()
        .filter(|(l, _)| *l < 0.02)
        .map(|(_, g)| *g)
        .fold(0.0f64, f64::max);
    let best_mix_lowloss = mix
        .iter()
        .filter(|(l, _)| *l < 0.02)
        .map(|(_, g)| *g)
        .fold(0.0f64, f64::max);
    println!(
        "\nat <2% loss: uniform best gain {:.1}%, mixed best gain {:.1}% (paper: mixed wins)",
        best_uni_lowloss * 100.0,
        best_mix_lowloss * 100.0
    );
    println!("[{:.1}s]", t0.elapsed().as_secs_f64());
}
