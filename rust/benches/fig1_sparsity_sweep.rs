//! Fig 1: accuracy loss (triangles) and energy gain (diamonds) vs
//! sparsity for fine-grained (Level [4]) and coarse-grained
//! (L1-Ranked [7]) pruning across three architectures.

mod common;

use hapq::coordinator::figures;

fn main() {
    common::banner(
        "fig1_sparsity_sweep",
        "Fig 1 — acc-loss & energy-gain vs sparsity, fine vs coarse, \
         VGG / ResNet / MobileNetV2",
    );
    let coord = common::coordinator();
    // Fig 1 uses VGG16 / ResNet50 / MobileNetV2; fall back to whatever
    // subset exists in the manifest.
    let models = figures::fig1_models(&coord);
    let points: Vec<f64> = (1..=9).map(|i| i as f64 / 10.0).collect();
    for model in &models {
        let t0 = std::time::Instant::now();
        let mut env = coord.build_env(model).unwrap();
        println!("\n--- {model} (baseline acc {:.3}) ---", env.baseline_acc);
        println!("{:<12} {:>9} {:>10} {:>12}", "alg", "sparsity", "acc-loss", "energy-gain");
        for r in figures::fig1_sweep(&mut env, &points).unwrap() {
            println!(
                "{:<12} {:>9.1} {:>9.2}% {:>11.2}%",
                r.alg, r.sparsity, r.acc_loss * 100.0, r.energy_gain * 100.0
            );
        }
        println!("[{model}: {:.1}s]", t0.elapsed().as_secs_f64());
    }
    println!("\nexpected shape (paper): coarse-grained has higher energy gain AND");
    println!("higher accuracy loss at equal sparsity; sensitivity is model-specific.");
}
