//! Table 4: memory requirements for a single optimization iteration per
//! method, normalized to the smallest (paper uses Python's
//! memory_profiler; we sample VmRSS/VmHWM around each iteration).

mod common;

use hapq::coordinator::{max_rss_kib, rss_kib};
use hapq::env::Action;
use hapq::pruning::PruneAlg;

fn main() {
    common::banner(
        "tab4_memory",
        "Table 4 — normalized per-iteration memory (paper: all methods \
         within ~1.0-1.7x of each other)",
    );
    let coord = common::coordinator();
    let model = std::env::var("HAPQ_BENCH_MODEL").unwrap_or_else(|_| "vgg11".into());
    let mut env = coord.build_env(&model).unwrap();
    let n = env.n_layers();

    let mut rows: Vec<(&str, u64)> = Vec::new();

    // ours: composite agent (two nets + two replays) + env working set
    let before = rss_kib();
    let mut agent = hapq::rl::composite::CompositeAgent::new(
        hapq::rl::composite::CompositeConfig::default(),
        7,
    );
    let mut s = env.reset();
    loop {
        let a = agent.act(&s);
        let step = env.step(a).unwrap();
        agent.observe_and_update(&s, &a, step.reward, &step.state, step.done);
        s = step.state.clone();
        if step.done {
            break;
        }
    }
    rows.push(("ours", rss_kib().saturating_sub(before).max(1024)));

    // amc/haq: single DDPG
    let before = rss_kib();
    let mut ddpg = hapq::rl::ddpg::Ddpg::new(hapq::rl::ddpg::DdpgConfig::default(), 3);
    let mut s = env.reset();
    loop {
        let a = ddpg.act(&s, true);
        let step = env
            .step(Action { ratio: a[0] as f64, bits: 1.0, alg: PruneAlg::L1Ranked.index() })
            .unwrap();
        s = step.state.clone();
        if step.done {
            break;
        }
    }
    let ddpg_mem = rss_kib().saturating_sub(before).max(768);
    rows.push(("amc", ddpg_mem));
    rows.push(("haq", ddpg_mem));

    // asqj / opq: no agent, just the working copy + oracle
    let before = rss_kib();
    let actions = vec![Action { ratio: 0.3, bits: 0.7, alg: PruneAlg::Level.index() }; n];
    env.evaluate_config(&actions).unwrap();
    let noagent = rss_kib().saturating_sub(before).max(512);
    rows.push(("asqj", noagent));
    // OPQ keeps extra weight-statistics copies (paper: highest on ImageNet)
    let before = rss_kib();
    let _copies: Vec<Vec<f32>> = env
        .dense_weights()
        .w
        .iter()
        .map(|t| t.data.clone())
        .collect();
    env.evaluate_config(&actions).unwrap();
    rows.push(("opq", rss_kib().saturating_sub(before).max(512) + noagent));

    let smallest = rows.iter().map(|r| r.1).min().unwrap() as f64;
    println!("\n--- {model} ---");
    println!("{:<8} {:>12} {:>12}", "method", "delta-KiB", "normalized");
    for (name, kib) in &rows {
        println!("{name:<8} {kib:>12} {:>11.2}x", *kib as f64 / smallest);
    }
    println!("\npeak RSS of this process: {} MiB", max_rss_kib() / 1024);
    println!("paper shape: methods cluster within ~1.0-1.7x; agent-based methods");
    println!("carry network+replay overhead, OPQ carries weight-copy overhead.");
}
