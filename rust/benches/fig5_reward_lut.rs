//! Fig 5: heatmap of the 40x40 LUT-based reward (printed sub-sampled at
//! 25% resolution, exactly like the paper's figure).

mod common;

use hapq::coordinator::figures;
use hapq::env::lut::RewardLut;

fn main() {
    common::banner(
        "fig5_reward_lut",
        "Fig 5 — LUT reward heatmap: high for loss<10%, small negative \
         near (0 gain, 0 loss), strongly negative beyond 10% loss",
    );
    let t0 = std::time::Instant::now();
    let grid = figures::fig5_heatmap(4);
    println!("rows: acc loss 0..100% (down), cols: energy gain 0..100% (right)\n");
    for (i, row) in grid.iter().enumerate() {
        let label = (i as f64) * 4.0 / 40.0 * 100.0;
        let cells: Vec<String> = row.iter().map(|v| format!("{v:6.2}")).collect();
        println!("loss {label:5.1}% | {}", cells.join(" "));
    }
    // structural assertions mirroring §4.2.3
    let lut = RewardLut::paper();
    assert!(lut.reward(0.02, 0.6) > lut.reward(0.08, 0.6));
    assert!(lut.reward(0.12, 0.9) < 0.0);
    assert!(lut.reward(0.0, 0.0) < 0.0 && lut.reward(0.0, 0.0) > -0.5);
    println!("\nstructural checks passed [{:.3}s]", t0.elapsed().as_secs_f64());
}
