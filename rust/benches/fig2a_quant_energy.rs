//! Fig 2a: accelerator energy reduction when quantizing below 8 bits on
//! a fixed-precision 8-bit MAC array (reduction from toggling only).

mod common;

use hapq::coordinator::figures;

fn main() {
    common::banner(
        "fig2a_quant_energy",
        "Fig 2a — energy reduction vs (Qw, Qa) on an 8-bit Eyeriss-based \
         accelerator; paper reports ~29% at 5/5 bits",
    );
    let coord = common::coordinator();
    let env = coord.build_env("vgg11").unwrap();
    let t0 = std::time::Instant::now();
    let grid = figures::fig2a_grid(&env);
    println!("{:>3} {:>3} {:>11}", "Qw", "Qa", "reduction");
    for (qw, qa, red) in &grid {
        println!("{qw:>3} {qa:>3} {:>10.2}%", red * 100.0);
    }
    let r55 = grid.iter().find(|(w, a, _)| *w == 5 && *a == 5).unwrap().2;
    let r88 = grid.iter().find(|(w, a, _)| *w == 8 && *a == 8).unwrap().2;
    println!("\npaper anchor: 5/5 bits -> 29% reduction; measured: {:.1}%", r55 * 100.0);
    println!("8/8 bits must be 0%: measured {:.2}%", r88 * 100.0);
    println!("MAC-sim P_FG (paper: 0.2): {:.3}", env.cost.model().p_fg());
    println!("[{:.2}s]", t0.elapsed().as_secs_f64());
}
