//! Fig 9: composite RL agent vs NSGA-II under the SAME evaluation
//! budget (paper: 1100 episodes ≡ 55 generations × 20 population; the
//! GA lands in the high-loss region, the RL agent stays inside the
//! high-accuracy band).

mod common;

fn main() {
    common::banner(
        "fig9_nsga2",
        "Fig 9 — ours vs NSGA-II at matched evaluation budget",
    );
    let coord = common::coordinator();
    let models: Vec<String> = std::env::var("HAPQ_BENCH_MODELS")
        .unwrap_or_else(|_| "vgg11".into())
        .split(',')
        .map(str::to_string)
        .collect();
    println!(
        "{:<12} {:<8} {:>11} {:>13} {:>8} {:>8}",
        "model", "method", "energy-gain", "test-acc-loss", "evals", "secs"
    );
    for model in &models {
        for method in ["ours", "nsga2"] {
            let report = if method == "ours" {
                coord.compress(model, false)
            } else {
                coord.run_baseline(model, method)
            };
            match report {
                Ok(r) => {
                    println!(
                        "{:<12} {:<8} {:>10.1}% {:>12.2}% {:>8} {:>7.1}s",
                        model,
                        method,
                        r.best.energy_gain * 100.0,
                        r.test_acc_loss() * 100.0,
                        r.evals,
                        r.wall_secs
                    );
                    let _ = coord.save_report(&r);
                }
                Err(e) => println!("{model:<12} {method:<8} FAILED: {e:#}"),
            }
        }
    }
    println!("\npaper expectation: NSGA-II reaches high energy gain but fails the");
    println!("accuracy bound; the RL agent keeps loss inside the useful region.");
}
