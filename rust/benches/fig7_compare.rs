//! Fig 7: energy gain vs accuracy loss for OURS (a) and the baselines
//! AMC (b), HAQ (c), ASQJ (d), OPQ (e).
//!
//! Scaled-down by default (HAPQ_BENCH_EPISODES=10, two c10 models); the
//! full grid is `hapq compare --models all --episodes 1100`.

mod common;

fn main() {
    common::banner(
        "fig7_compare",
        "Fig 7 — ours vs AMC/HAQ/ASQJ/OPQ, energy gain vs top-1 loss",
    );
    let coord = common::coordinator();
    let models: Vec<String> = std::env::var("HAPQ_BENCH_MODELS")
        .unwrap_or_else(|_| "vgg11,resnet18".into())
        .split(',')
        .map(str::to_string)
        .collect();
    println!(
        "{:<12} {:<8} {:>11} {:>13} {:>8} {:>8}",
        "model", "method", "energy-gain", "test-acc-loss", "evals", "secs"
    );
    let mut ours_gain = Vec::new();
    let mut base_gain = Vec::new();
    for model in &models {
        for method in ["ours", "amc", "haq", "asqj", "opq"] {
            let report = if method == "ours" {
                coord.compress(model, false)
            } else {
                coord.run_baseline(model, method)
            };
            match report {
                Ok(r) => {
                    println!(
                        "{:<12} {:<8} {:>10.1}% {:>12.2}% {:>8} {:>7.1}s",
                        model,
                        method,
                        r.best.energy_gain * 100.0,
                        r.test_acc_loss() * 100.0,
                        r.evals,
                        r.wall_secs
                    );
                    if method == "ours" {
                        ours_gain.push(r.best.energy_gain);
                    } else {
                        base_gain.push(r.best.energy_gain);
                    }
                    let _ = coord.save_report(&r);
                }
                Err(e) => println!("{model:<12} {method:<8} FAILED: {e:#}"),
            }
        }
    }
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
    println!(
        "\nmean energy gain — ours: {:.1}%, baselines: {:.1}% (paper: ours wins; \
         gains scale with episode budget)",
        mean(&ours_gain) * 100.0,
        mean(&base_gain) * 100.0
    );
}
