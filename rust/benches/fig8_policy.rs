//! Fig 8: the learned per-layer (pruning ratio, precision, algorithm)
//! policy for ResNet18 — the paper's qualitative analysis: conservative
//! coarse pruning early, fine-grained aggressive pruning on the FC
//! head, shortcut layers barely pruned but heavily quantized.

mod common;

use hapq::coordinator::figures;
use hapq::model::Op;

fn main() {
    common::banner(
        "fig8_policy",
        "Fig 8 — per-layer pruning/quantization decisions, ResNet18",
    );
    let coord = common::coordinator();
    let t0 = std::time::Instant::now();
    let report = coord.compress("resnet18", false).expect("compress resnet18");
    let (arch, _, _) = coord.load_arch("resnet18").unwrap();
    println!(
        "{:<6} {:<10} {:<6} {:<12} {:>9} {:>6}",
        "layer", "name", "kind", "alg", "sparsity", "bits"
    );
    for (i, alg, sp, bits) in figures::fig8_rows(&report) {
        let name = &arch.prunable[i];
        let l = arch.layer(name).unwrap();
        let kind = match l.op {
            Op::Fc => "fc",
            Op::DwConv => "dw",
            _ => "conv",
        };
        println!("{i:<6} {name:<10} {kind:<6} {alg:<12} {sp:>9.2} {bits:>6}");
    }
    println!(
        "\nresult: gain {:.1}%, test loss {:.2}%  [{:.1}s]",
        report.best.energy_gain * 100.0,
        report.test_acc_loss() * 100.0,
        t0.elapsed().as_secs_f64()
    );
    let _ = coord.save_report(&report);
}
