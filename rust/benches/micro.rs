//! Micro-benchmarks of the L3 hot path (the §Perf foundation):
//! component latencies that make up one RL step —
//! prune + quantize + energy + oracle inference + agent update.

mod common;

use std::time::Instant;

use hapq::env::Action;
use hapq::hw::dataflow::{map_layer, LayerDims};
use hapq::hw::mac_sim::RqTable;
use hapq::hw::Accel;
use hapq::io::json;
use hapq::model::{ModelArch, Weights};
use hapq::nn::mat::{CodeMat, Mat, PackedMat};
use hapq::pruning::{prune, PruneAlg, PruneCtx};
use hapq::quant::{quantize_weights, QuantGrid};
use hapq::runtime::native::quant_params;
use hapq::runtime::{EvalData, InferenceBackend, KernelKind, NativeBackend};
use hapq::tensor::Tensor;
use hapq::util::rng::Rng;

fn time<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    let t = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t.elapsed().as_secs_f64() / iters as f64;
    println!("{name:<38} {:>10.3} ms/iter  ({iters} iters)", per * 1e3);
    per
}

fn main() {
    common::banner("micro", "hot-path component latencies (EXPERIMENTS.md §Perf)");

    // --- hw substrates ---
    time("mac_sim: RqTable::compute(4000)", 3, || {
        let t = RqTable::compute(4000, 1);
        std::hint::black_box(&t);
    });
    let acc = Accel::default();
    let dims = LayerDims::conv(16, 16, 64, 16, 16, 128, 3, 1);
    time("dataflow: map_layer (64->128ch conv)", 200, || {
        std::hint::black_box(map_layer(&dims, &acc));
    });

    // --- pruning/quant on a vgg-sized tensor ---
    let mut rng = Rng::new(5);
    let w0 = Tensor::new(
        vec![3, 3, 96, 128],
        (0..3 * 3 * 96 * 128).map(|_| rng.normal() as f32 * 0.1).collect(),
    );
    let sal = Tensor::full(w0.shape.clone(), 0.5);
    for alg in [PruneAlg::Level, PruneAlg::L1Ranked, PruneAlg::Splicing] {
        let name = format!("prune {:<10} (110k weights)", alg.name());
        time(&name, 20, || {
            let mut w = w0.clone();
            let chsq = vec![1.0f32; 128];
            let mut r = Rng::new(9);
            let mut ctx = PruneCtx { saliency: &sal, chsq: &chsq, dwconv: false, rng: &mut r };
            std::hint::black_box(prune(&mut w, alg, 0.5, &mut ctx));
        });
    }
    time("quantize_weights 4-bit (110k weights)", 20, || {
        let mut w = w0.clone();
        std::hint::black_box(quantize_weights(&mut w, 4));
    });

    // --- RL update ---
    let mut agent = hapq::rl::ddpg::Ddpg::new(hapq::rl::ddpg::DdpgConfig::default(), 3);
    let mut r = Rng::new(4);
    for _ in 0..128 {
        let s: Vec<f32> = (0..hapq::env::STATE_DIM).map(|_| r.uniform() as f32).collect();
        agent.observe(hapq::rl::replay::Transition {
            s: s.clone(),
            a: vec![0.3, 0.5],
            alg: 0,
            r: 0.1,
            s2: s,
            done: false,
        });
    }
    time("ddpg update (batch 64, 3x300 nets)", 10, || {
        agent.update();
    });
    let mut rb = hapq::rl::rainbow::Rainbow::new(hapq::rl::rainbow::RainbowConfig::default(), 5);
    for _ in 0..128 {
        let f: Vec<f32> = (0..300).map(|_| r.uniform() as f32).collect();
        rb.observe(f.clone(), 2, 0.3, f, false);
    }
    time("rainbow update (batch 64, C51x7)", 10, || {
        rb.update();
    });

    // --- hardware cost model: cached vs scratch + per-target rows ---
    cost_rows();

    // --- exec engine: incremental + threaded oracle (artifact-free) ---
    engine_rows();

    // --- int vs f32 kernel: GEMM + oracle end-to-end (artifact-free) ---
    kernel_rows();

    // --- full env step & episode (needs artifacts) ---
    if let Ok(coord) = std::panic::catch_unwind(common::coordinator) {
        let mut env = coord.build_env("vgg11").unwrap();
        let n = env.n_layers();
        let mut k = 0usize;
        time("env full step (prune+quant+E+infer)", 20, || {
            if k % n == 0 {
                env.reset();
            }
            let _ = env
                .step(Action { ratio: 0.3, bits: 0.7, alg: k % 7 })
                .unwrap();
            k += 1;
        });
        let actions: Vec<Action> =
            (0..n).map(|l| Action { ratio: 0.3, bits: 0.7, alg: l % 7 }).collect();
        time("env full episode (vgg11, 10 layers)", 5, || {
            env.evaluate_config(&actions).unwrap();
        });
    } else {
        println!("(artifacts missing — skipping env-level timings)");
    }
}

/// Cost-query throughput on the RL hot path (EXPERIMENTS.md §Perf):
/// the incremental `CostCache` vs the scratch `EnergyModel` over a
/// VGG-ish 12-layer stack, walking one layer per step like an episode
/// does, plus a per-target energy-gain row for every built-in hardware
/// target. Gains are asserted bit-identical before any timing (same
/// convention as the int-kernel rows).
fn cost_rows() {
    use hapq::hw::cost::{CostCache, CostModel};
    use hapq::hw::energy::{Compression, EnergyModel};
    use hapq::hw::target::{HwTarget, BUILTIN_TARGETS};

    let rq = RqTable::compute(1500, 7);
    let mut dims_v = vec![LayerDims::conv(32, 32, 3, 32, 32, 32, 3, 1)];
    for i in 0..10 {
        let hw = 32 >> (i / 3).min(3);
        let c = 32 << (i / 3).min(2);
        dims_v.push(LayerDims::conv(hw, hw, c, hw, hw, c, 3, 1));
    }
    dims_v.push(LayerDims::fc(512, 10));
    let n = dims_v.len();

    let t64 = HwTarget::builtin("eyeriss-64").unwrap();
    let em = EnergyModel::for_target(dims_v.clone(), &t64, rq.clone());
    let mut scratch = em.clone();
    let mut cache = CostCache::new(em);

    // an RL-episode walk: one layer's config changes per step
    let mut wrng = Rng::new(3);
    let walk: Vec<(usize, Compression)> = (0..4 * n)
        .map(|i| {
            (
                i % n,
                Compression {
                    sparsity: wrng.uniform(),
                    coarse: wrng.uniform() < 0.5,
                    bits: 2 + wrng.below(7) as u32,
                },
            )
        })
        .collect();

    // parity before timing: cached == scratch bitwise along the walk
    let mut cfgs = vec![Compression::dense(); n];
    for (l, c) in &walk {
        cfgs[*l] = *c;
        assert_eq!(
            cache.energy_gain(&cfgs).to_bits(),
            CostModel::energy_gain(&mut scratch, &cfgs).to_bits(),
            "cost-cache energy parity violated in the bench setup"
        );
        assert_eq!(
            cache.latency_gain(&cfgs).to_bits(),
            CostModel::latency_gain(&mut scratch, &cfgs).to_bits(),
            "cost-cache latency parity violated in the bench setup"
        );
    }

    let t_scratch = time("cost query scratch (12-layer walk)", 300, || {
        for (l, c) in &walk {
            cfgs[*l] = *c;
            std::hint::black_box(CostModel::energy_gain(&mut scratch, &cfgs));
            std::hint::black_box(CostModel::latency_gain(&mut scratch, &cfgs));
        }
    });
    let t_cached = time("cost query cached  (12-layer walk)", 300, || {
        for (l, c) in &walk {
            cfgs[*l] = *c;
            std::hint::black_box(cache.energy_gain(&cfgs));
            std::hint::black_box(cache.latency_gain(&cfgs));
        }
    });
    println!(
        "{:<38} {:>9.2}x",
        "  -> cost-cache speedup",
        t_scratch / t_cached.max(1e-12)
    );

    // per-target energy-gain rows at the hapq-hw reference config
    let ref_cfgs = vec![Compression { sparsity: 0.5, coarse: true, bits: 4 }; n];
    for name in BUILTIN_TARGETS {
        let t = HwTarget::builtin(name).unwrap();
        let mut tm = EnergyModel::for_target(dims_v.clone(), &t, rq.clone());
        let gain = tm.gain(&ref_cfgs);
        let row = format!("energy_gain [{name}] (s=.5/4b)");
        time(&row, 200, || {
            std::hint::black_box(CostModel::energy_gain(&mut tm, &ref_cfgs));
        });
        println!("{:<38} {:>9.1}%", format!("  -> {name} gain"), gain * 100.0);
    }
}

/// The shared synthetic 5-node conv net (16x16x3, 64 examples) behind
/// the engine and kernel rows.
fn bench5_setup() -> (ModelArch, Weights, Tensor, Vec<i64>) {
    const ARCH: &str = r#"{
      "name": "bench5", "dataset": "synth-bench", "input": [16, 16, 3],
      "classes": 10, "batch": 32,
      "layers": [
        {"name": "c1", "op": "conv", "inputs": ["input"], "k": 3, "stride": 1,
         "relu": true, "in_shape": [16,16,3], "out_shape": [16,16,16],
         "in_ch": 3, "out_ch": 16},
        {"name": "c2", "op": "conv", "inputs": ["c1"], "k": 3, "stride": 1,
         "relu": true, "in_shape": [16,16,16], "out_shape": [16,16,16],
         "in_ch": 16, "out_ch": 16},
        {"name": "c3", "op": "conv", "inputs": ["c2"], "k": 3, "stride": 2,
         "relu": true, "in_shape": [16,16,16], "out_shape": [8,8,16],
         "in_ch": 16, "out_ch": 16},
        {"name": "gap", "op": "gap", "inputs": ["c3"], "in_shape": [8,8,16],
         "out_shape": [16]},
        {"name": "f1", "op": "fc", "inputs": ["gap"], "relu": false,
         "in_shape": [16], "out_shape": [10], "in_ch": 16, "out_ch": 10}
      ],
      "prunable": ["c1", "c2", "c3", "f1"],
      "dep_groups": [],
      "act_scales": [0.5, 0.5, 0.5, 0.5],
      "act_signed": [true, false, false, false],
      "acc_int8": 0.0, "n_params": 0
    }"#;
    let arch = ModelArch::from_json(&json::parse(ARCH).unwrap()).unwrap();
    let mut rng = Rng::new(17);
    let mut rand_t = |shape: Vec<usize>| {
        let n: usize = shape.iter().product();
        Tensor::new(shape, (0..n).map(|_| (rng.normal() * 0.3) as f32).collect())
    };
    let weights = Weights {
        w: vec![
            rand_t(vec![3, 3, 3, 16]),
            rand_t(vec![3, 3, 16, 16]),
            rand_t(vec![3, 3, 16, 16]),
            rand_t(vec![16, 10]),
        ],
        b: vec![
            rand_t(vec![16]),
            rand_t(vec![16]),
            rand_t(vec![16]),
            rand_t(vec![10]),
        ],
        sal: vec![
            Tensor::full(vec![3, 3, 3, 16], 1.0),
            Tensor::full(vec![3, 3, 16, 16], 1.0),
            Tensor::full(vec![3, 3, 16, 16], 1.0),
            Tensor::full(vec![16, 10], 1.0),
        ],
        chsq: vec![vec![1.0; 16], vec![1.0; 16], vec![1.0; 16], vec![1.0; 10]],
    };
    let n_ex = 64;
    let images = rand_t(vec![n_ex, 16, 16, 3]);
    let labels: Vec<i64> = (0..n_ex).map(|i| (i % 10) as i64).collect();
    (arch, weights, images, labels)
}

/// Timing the `runtime/exec` engine on [`bench5_setup`]: full recompute
/// vs incremental resume vs a multi-thread pool — the §Perf evidence
/// that ships with CI, no artifacts needed. Results are bit-identical
/// across all three rows.
fn engine_rows() {
    let (arch, weights, images, labels) = bench5_setup();
    let mk_backend = |threads: usize| {
        let data =
            EvalData::from_arrays(&arch, &images, &labels, labels.len(), arch.batch).unwrap();
        NativeBackend::with_threads(&arch, data, threads).unwrap()
    };
    let bits = [6.0f32, 6.0, 6.0, 6.0];

    let b1 = mk_backend(1);
    time("oracle full recompute (5-node, 64 ex)", 10, || {
        b1.invalidate_all();
        std::hint::black_box(b1.accuracy(&weights, &bits).unwrap());
    });
    time("oracle incremental, last layer dirty", 10, || {
        b1.invalidate(3);
        std::hint::black_box(b1.accuracy(&weights, &bits).unwrap());
    });
    time("oracle incremental, mid layer dirty", 10, || {
        b1.invalidate(1);
        std::hint::black_box(b1.accuracy(&weights, &bits).unwrap());
    });
    let b4 = mk_backend(4);
    time("oracle full recompute, 4 threads", 10, || {
        b4.invalidate_all();
        std::hint::black_box(b4.accuracy(&weights, &bits).unwrap());
    });
    time("oracle incremental + 4 threads, mid dirty", 10, || {
        b4.invalidate(1);
        std::hint::black_box(b4.accuracy(&weights, &bits).unwrap());
    });
}

/// Int vs f32 kernel (EXPERIMENTS.md §Perf): a raw GEMM row and the
/// oracle end-to-end on [`bench5_setup`] with *compressed* weights
/// (50% pruned + 4-bit quantized — the tensors the reward oracle
/// actually scores). Logits are bit-identical across the kernel rows
/// (rust/tests/kernel_conformance.rs); only wall-clock may differ.
fn kernel_rows() {
    // --- raw GEMM: f32 matmul vs packed code matmul, 1024x288 · 288x64,
    //     4-bit activations, 50% of weight rows pruned ---
    let (lo, hi, step) = quant_params(4.0, 0.5, false);
    let grid = QuantGrid::new(lo, hi, step);
    let lut = grid.lut().unwrap();
    let mut rng = Rng::new(23);
    let (rows, kdim, ndim) = (1024usize, 288usize, 64usize);
    let codes = CodeMat {
        r: rows,
        c: kdim,
        // ~50% exact zeros, like post-ReLU activations
        d: (0..rows * kdim)
            .map(|_| if rng.uniform() < 0.5 { 0 } else { 1 + rng.below(grid.levels()) as i16 })
            .collect(),
    };
    let acts = Mat::from_vec(
        rows,
        kdim,
        codes.d.iter().map(|&c| lut[(c + 1) as usize]).collect(),
    );
    let wdense: Vec<f32> = (0..kdim * ndim)
        .map(|i| if (i / ndim) % 2 == 0 { 0.0 } else { rng.normal() as f32 * 0.1 })
        .collect();
    let wmat = Mat::from_vec(kdim, ndim, wdense.clone());
    let packed = PackedMat::pack(kdim, ndim, &wdense);
    let t_f32 = time("gemm f32 1024x288x64 (50% pruned w)", 20, || {
        std::hint::black_box(acts.matmul(&wmat));
    });
    let t_int = time("gemm int 1024x288x64 (packed+codes)", 20, || {
        std::hint::black_box(packed.code_matmul(&codes, &lut));
    });
    println!("{:<38} {:>9.2}x", "  -> int GEMM speedup", t_f32 / t_int.max(1e-12));

    // --- oracle end-to-end: same engine, both kernels ---
    let (arch, mut weights, images, labels) = bench5_setup();
    for wt in weights.w.iter_mut() {
        let sal = Tensor::full(wt.shape.clone(), 1.0);
        let chsq = vec![1.0f32; wt.out_channels(false)];
        let mut prng = Rng::new(31);
        let mut ctx = PruneCtx { saliency: &sal, chsq: &chsq, dwconv: false, rng: &mut prng };
        prune(wt, PruneAlg::Level, 0.5, &mut ctx);
        quantize_weights(wt, 4);
    }
    let mk = |kernel: KernelKind| {
        let data =
            EvalData::from_arrays(&arch, &images, &labels, labels.len(), arch.batch).unwrap();
        NativeBackend::with_options(&arch, data, 1, kernel).unwrap()
    };
    let bits = [4.0f32, 4.0, 4.0, 4.0];
    let bf = mk(KernelKind::F32);
    let bi = mk(KernelKind::Int);
    assert_eq!(
        bf.engine_logits(&weights, &bits).unwrap(),
        bi.engine_logits(&weights, &bits).unwrap(),
        "kernel parity violated in the bench setup"
    );
    let tf = time("oracle e2e full recompute, f32 kernel", 10, || {
        bf.invalidate_all();
        std::hint::black_box(bf.accuracy(&weights, &bits).unwrap());
    });
    let ti = time("oracle e2e full recompute, int kernel", 10, || {
        bi.invalidate_all();
        std::hint::black_box(bi.accuracy(&weights, &bits).unwrap());
    });
    println!("{:<38} {:>9.2}x", "  -> int oracle speedup", tf / ti.max(1e-12));
    time("oracle e2e mid dirty, f32 kernel", 10, || {
        bf.invalidate(1);
        std::hint::black_box(bf.accuracy(&weights, &bits).unwrap());
    });
    time("oracle e2e mid dirty, int kernel", 10, || {
        bi.invalidate(1);
        std::hint::black_box(bi.accuracy(&weights, &bits).unwrap());
    });
}
