//! Micro-benchmarks of the L3 hot path (the §Perf foundation):
//! component latencies that make up one RL step —
//! prune + quantize + energy + oracle inference + agent update.
//!
//! Conventions (EXPERIMENTS.md §Perf):
//! - every timed pair of equivalent computations asserts bitwise
//!   parity *before* timing (`common::assert_f32_bits_eq`), so a
//!   speedup row can never mask a semantics divergence;
//! - all rows, rows-per-second rates and speedup ratios are also
//!   written machine-readably to `BENCH_micro.json` at the repo root
//!   (`common::BenchJson`), so CI can diff the perf trajectory.

mod common;

use std::sync::Arc;

use common::{assert_f32_bits_eq, assert_f64_bits_eq, BenchJson};
use hapq::env::Action;
use hapq::hw::dataflow::{map_layer, LayerDims};
use hapq::hw::mac_sim::RqTable;
use hapq::hw::Accel;
use hapq::io::json;
use hapq::model::{ModelArch, Weights};
use hapq::nn::mat::{CodeMat, Mat, PackedMat, DEFAULT_GEMM_TILE};
use hapq::pruning::{prune, PruneAlg, PruneCtx};
use hapq::quant::{quantize_weights, QuantGrid};
use hapq::runtime::native::quant_params;
use hapq::runtime::{Candidate, EvalData, InferenceBackend, KernelKind, NativeBackend};
use hapq::tensor::Tensor;
use hapq::util::rng::Rng;

fn main() {
    common::banner("micro", "hot-path component latencies (EXPERIMENTS.md §Perf)");
    let mut bj = BenchJson::new("micro");
    let bj = &mut bj;

    // --- hw substrates ---
    bj.timed("mac_sim: RqTable::compute(4000)", 3, || {
        let t = RqTable::compute(4000, 1);
        std::hint::black_box(&t);
    });
    let acc = Accel::default();
    let dims = LayerDims::conv(16, 16, 64, 16, 16, 128, 3, 1);
    bj.timed("dataflow: map_layer (64->128ch conv)", 200, || {
        std::hint::black_box(map_layer(&dims, &acc));
    });

    // --- pruning/quant on a vgg-sized tensor ---
    let mut rng = Rng::new(5);
    let w0 = Tensor::new(
        vec![3, 3, 96, 128],
        (0..3 * 3 * 96 * 128).map(|_| rng.normal() as f32 * 0.1).collect(),
    );
    let sal = Tensor::full(w0.shape.clone(), 0.5);
    for alg in [PruneAlg::Level, PruneAlg::L1Ranked, PruneAlg::Splicing] {
        let name = format!("prune {:<10} (110k weights)", alg.name());
        bj.timed(&name, 20, || {
            let mut w = w0.clone();
            let chsq = vec![1.0f32; 128];
            let mut r = Rng::new(9);
            let mut ctx = PruneCtx { saliency: &sal, chsq: &chsq, dwconv: false, rng: &mut r };
            std::hint::black_box(prune(&mut w, alg, 0.5, &mut ctx));
        });
    }
    bj.timed("quantize_weights 4-bit (110k weights)", 20, || {
        let mut w = w0.clone();
        std::hint::black_box(quantize_weights(&mut w, 4));
    });

    // --- RL update ---
    let mut agent = hapq::rl::ddpg::Ddpg::new(hapq::rl::ddpg::DdpgConfig::default(), 3);
    let mut r = Rng::new(4);
    for _ in 0..128 {
        let s: Vec<f32> = (0..hapq::env::STATE_DIM).map(|_| r.uniform() as f32).collect();
        agent.observe(hapq::rl::replay::Transition {
            s: s.clone(),
            a: vec![0.3, 0.5],
            alg: 0,
            r: 0.1,
            s2: s,
            done: false,
        });
    }
    bj.timed("ddpg update (batch 64, 3x300 nets)", 10, || {
        agent.update();
    });
    let mut rb = hapq::rl::rainbow::Rainbow::new(hapq::rl::rainbow::RainbowConfig::default(), 5);
    for _ in 0..128 {
        let f: Vec<f32> = (0..300).map(|_| r.uniform() as f32).collect();
        rb.observe(f.clone(), 2, 0.3, f, false);
    }
    bj.timed("rainbow update (batch 64, C51x7)", 10, || {
        rb.update();
    });

    // --- hardware cost model: cached vs scratch + per-target rows ---
    cost_rows(bj);

    // --- exec engine: incremental + threaded oracle (artifact-free) ---
    engine_rows(bj);

    // --- int vs f32 kernel: GEMM + oracle end-to-end (artifact-free) ---
    kernel_rows(bj);

    // --- batched candidate pricing vs serial one-at-a-time ---
    batched_rows(bj);

    // --- search-loop memoization: eval memo, pack cache, scratch arena ---
    memo_rows(bj);

    // --- work-stealing shard scheduler + parallel dirty-layer packing ---
    sched_rows(bj);

    // --- full env step & episode (needs artifacts) ---
    if let Ok(coord) = std::panic::catch_unwind(common::coordinator) {
        let mut env = coord.build_env("vgg11").unwrap();
        let n = env.n_layers();
        let mut k = 0usize;
        bj.timed("env full step (prune+quant+E+infer)", 20, || {
            if k % n == 0 {
                env.reset();
            }
            let _ = env
                .step(Action { ratio: 0.3, bits: 0.7, alg: k % 7 })
                .unwrap();
            k += 1;
        });
        let actions: Vec<Action> =
            (0..n).map(|l| Action { ratio: 0.3, bits: 0.7, alg: l % 7 }).collect();
        bj.timed("env full episode (vgg11, 10 layers)", 5, || {
            env.evaluate_config(&actions).unwrap();
        });
    } else {
        println!("(artifacts missing — skipping env-level timings)");
    }

    bj.write();
}

/// Cost-query throughput on the RL hot path (EXPERIMENTS.md §Perf):
/// the incremental `CostCache` vs the scratch `EnergyModel` over a
/// VGG-ish 12-layer stack, walking one layer per step like an episode
/// does, plus a per-target energy-gain row for every built-in hardware
/// target. Gains are asserted bit-identical before any timing (same
/// convention as the int-kernel rows).
fn cost_rows(bj: &mut BenchJson) {
    use hapq::hw::cost::{CostCache, CostModel};
    use hapq::hw::energy::{Compression, EnergyModel};
    use hapq::hw::target::{HwTarget, BUILTIN_TARGETS};

    let rq = RqTable::compute(1500, 7);
    let mut dims_v = vec![LayerDims::conv(32, 32, 3, 32, 32, 32, 3, 1)];
    for i in 0..10 {
        let hw = 32 >> (i / 3).min(3);
        let c = 32 << (i / 3).min(2);
        dims_v.push(LayerDims::conv(hw, hw, c, hw, hw, c, 3, 1));
    }
    dims_v.push(LayerDims::fc(512, 10));
    let n = dims_v.len();

    let t64 = HwTarget::builtin("eyeriss-64").unwrap();
    let em = EnergyModel::for_target(dims_v.clone(), &t64, rq.clone());
    let mut scratch = em.clone();
    let mut cache = CostCache::new(em);

    // an RL-episode walk: one layer's config changes per step
    let mut wrng = Rng::new(3);
    let walk: Vec<(usize, Compression)> = (0..4 * n)
        .map(|i| {
            (
                i % n,
                Compression {
                    sparsity: wrng.uniform(),
                    coarse: wrng.uniform() < 0.5,
                    bits: 2 + wrng.below(7) as u32,
                },
            )
        })
        .collect();

    // parity before timing: cached == scratch bitwise along the walk
    let mut cfgs = vec![Compression::dense(); n];
    for (l, c) in &walk {
        cfgs[*l] = *c;
        assert_eq!(
            cache.energy_gain(&cfgs).to_bits(),
            CostModel::energy_gain(&mut scratch, &cfgs).to_bits(),
            "cost-cache energy parity violated in the bench setup"
        );
        assert_eq!(
            cache.latency_gain(&cfgs).to_bits(),
            CostModel::latency_gain(&mut scratch, &cfgs).to_bits(),
            "cost-cache latency parity violated in the bench setup"
        );
    }

    let t_scratch = bj.timed("cost query scratch (12-layer walk)", 300, || {
        for (l, c) in &walk {
            cfgs[*l] = *c;
            std::hint::black_box(CostModel::energy_gain(&mut scratch, &cfgs));
            std::hint::black_box(CostModel::latency_gain(&mut scratch, &cfgs));
        }
    });
    let t_cached = bj.timed("cost query cached  (12-layer walk)", 300, || {
        for (l, c) in &walk {
            cfgs[*l] = *c;
            std::hint::black_box(cache.energy_gain(&cfgs));
            std::hint::black_box(cache.latency_gain(&cfgs));
        }
    });
    bj.speedup("cost_cached_vs_scratch", t_scratch, t_cached);

    // per-target energy-gain rows at the hapq-hw reference config
    let ref_cfgs = vec![Compression { sparsity: 0.5, coarse: true, bits: 4 }; n];
    for name in BUILTIN_TARGETS {
        let t = HwTarget::builtin(name).unwrap();
        let mut tm = EnergyModel::for_target(dims_v.clone(), &t, rq.clone());
        let gain = tm.gain(&ref_cfgs);
        let row = format!("energy_gain [{name}] (s=.5/4b)");
        bj.timed(&row, 200, || {
            std::hint::black_box(CostModel::energy_gain(&mut tm, &ref_cfgs));
        });
        println!("{:<38} {:>9.1}%", format!("  -> {name} gain"), gain * 100.0);
    }
}

/// The shared synthetic 5-node conv net (16x16x3, 64 examples) behind
/// the engine and kernel rows.
fn bench5_setup() -> (ModelArch, Weights, Tensor, Vec<i64>) {
    const ARCH: &str = r#"{
      "name": "bench5", "dataset": "synth-bench", "input": [16, 16, 3],
      "classes": 10, "batch": 32,
      "layers": [
        {"name": "c1", "op": "conv", "inputs": ["input"], "k": 3, "stride": 1,
         "relu": true, "in_shape": [16,16,3], "out_shape": [16,16,16],
         "in_ch": 3, "out_ch": 16},
        {"name": "c2", "op": "conv", "inputs": ["c1"], "k": 3, "stride": 1,
         "relu": true, "in_shape": [16,16,16], "out_shape": [16,16,16],
         "in_ch": 16, "out_ch": 16},
        {"name": "c3", "op": "conv", "inputs": ["c2"], "k": 3, "stride": 2,
         "relu": true, "in_shape": [16,16,16], "out_shape": [8,8,16],
         "in_ch": 16, "out_ch": 16},
        {"name": "gap", "op": "gap", "inputs": ["c3"], "in_shape": [8,8,16],
         "out_shape": [16]},
        {"name": "f1", "op": "fc", "inputs": ["gap"], "relu": false,
         "in_shape": [16], "out_shape": [10], "in_ch": 16, "out_ch": 10}
      ],
      "prunable": ["c1", "c2", "c3", "f1"],
      "dep_groups": [],
      "act_scales": [0.5, 0.5, 0.5, 0.5],
      "act_signed": [true, false, false, false],
      "acc_int8": 0.0, "n_params": 0
    }"#;
    let arch = ModelArch::from_json(&json::parse(ARCH).unwrap()).unwrap();
    let mut rng = Rng::new(17);
    let mut rand_t = |shape: Vec<usize>| {
        let n: usize = shape.iter().product();
        Tensor::new(shape, (0..n).map(|_| (rng.normal() * 0.3) as f32).collect())
    };
    let weights = Weights {
        w: vec![
            rand_t(vec![3, 3, 3, 16]),
            rand_t(vec![3, 3, 16, 16]),
            rand_t(vec![3, 3, 16, 16]),
            rand_t(vec![16, 10]),
        ],
        b: vec![
            rand_t(vec![16]),
            rand_t(vec![16]),
            rand_t(vec![16]),
            rand_t(vec![10]),
        ],
        sal: vec![
            Tensor::full(vec![3, 3, 3, 16], 1.0),
            Tensor::full(vec![3, 3, 16, 16], 1.0),
            Tensor::full(vec![3, 3, 16, 16], 1.0),
            Tensor::full(vec![16, 10], 1.0),
        ],
        chsq: vec![vec![1.0; 16], vec![1.0; 16], vec![1.0; 16], vec![1.0; 10]],
    };
    let n_ex = 64;
    let images = rand_t(vec![n_ex, 16, 16, 3]);
    let labels: Vec<i64> = (0..n_ex).map(|i| (i % 10) as i64).collect();
    (arch, weights, images, labels)
}

/// 50% prune + 4-bit quantize every prunable layer of [`bench5_setup`]
/// weights — the tensors the reward oracle actually scores.
fn compress5(weights: &mut Weights) {
    for wt in weights.w.iter_mut() {
        let sal = Tensor::full(wt.shape.clone(), 1.0);
        let chsq = vec![1.0f32; wt.out_channels(false)];
        let mut prng = Rng::new(31);
        let mut ctx = PruneCtx { saliency: &sal, chsq: &chsq, dwconv: false, rng: &mut prng };
        prune(wt, PruneAlg::Level, 0.5, &mut ctx);
        quantize_weights(wt, 4);
    }
}

/// Timing the `runtime/exec` engine on [`bench5_setup`]: full recompute
/// vs incremental resume vs a multi-thread pool — the §Perf evidence
/// that ships with CI, no artifacts needed. Results are bit-identical
/// across all three rows.
fn engine_rows(bj: &mut BenchJson) {
    let (arch, weights, images, labels) = bench5_setup();
    let n_ex = labels.len() as f64;
    let mk_backend = |threads: usize| {
        let data =
            EvalData::from_arrays(&arch, &images, &labels, labels.len(), arch.batch).unwrap();
        NativeBackend::with_threads(&arch, data, threads).unwrap()
    };
    let bits = [6.0f32, 6.0, 6.0, 6.0];

    let b1 = mk_backend(1);
    let t_full = bj.timed("oracle full recompute (5-node, 64 ex)", 10, || {
        b1.invalidate_all();
        std::hint::black_box(b1.accuracy(&weights, &bits).unwrap());
    });
    bj.rate("oracle_full_examples_per_sec", n_ex / t_full);
    bj.timed("oracle incremental, last layer dirty", 10, || {
        b1.invalidate(3);
        std::hint::black_box(b1.accuracy(&weights, &bits).unwrap());
    });
    bj.timed("oracle incremental, mid layer dirty", 10, || {
        b1.invalidate(1);
        std::hint::black_box(b1.accuracy(&weights, &bits).unwrap());
    });
    let b4 = mk_backend(4);
    bj.timed("oracle full recompute, 4 threads", 10, || {
        b4.invalidate_all();
        std::hint::black_box(b4.accuracy(&weights, &bits).unwrap());
    });
    bj.timed("oracle incremental + 4 threads, mid dirty", 10, || {
        b4.invalidate(1);
        std::hint::black_box(b4.accuracy(&weights, &bits).unwrap());
    });
}

/// Int vs f32 kernel (EXPERIMENTS.md §Perf): raw GEMM rows (f32 dense,
/// scalar int, blocked/tiled int) and the oracle end-to-end on
/// [`bench5_setup`] with *compressed* weights (50% pruned + 4-bit
/// quantized — the tensors the reward oracle actually scores). Every
/// timed pair asserts bit-parity first; the blocked kernel is required
/// bitwise-identical to the scalar path at every tile size
/// (rust/tests/kernel_conformance.rs), so only wall-clock may differ.
fn kernel_rows(bj: &mut BenchJson) {
    // --- raw GEMM: f32 matmul vs packed code matmul, 1024x288 · 288x64,
    //     4-bit activations, 50% of weight rows pruned ---
    let (lo, hi, step) = quant_params(4.0, 0.5, false);
    let grid = QuantGrid::new(lo, hi, step);
    let lut = grid.lut().unwrap();
    let mut rng = Rng::new(23);
    let (rows, kdim, ndim) = (1024usize, 288usize, 64usize);
    let codes = CodeMat {
        r: rows,
        c: kdim,
        // ~50% exact zeros, like post-ReLU activations
        d: (0..rows * kdim)
            .map(|_| if rng.uniform() < 0.5 { 0 } else { 1 + rng.below(grid.levels()) as i16 })
            .collect(),
    };
    let acts = Mat::from_vec(
        rows,
        kdim,
        codes.d.iter().map(|&c| lut[(c + 1) as usize]).collect(),
    );
    let wdense: Vec<f32> = (0..kdim * ndim)
        .map(|i| if (i / ndim) % 2 == 0 { 0.0 } else { rng.normal() as f32 * 0.1 })
        .collect();
    let wmat = Mat::from_vec(kdim, ndim, wdense.clone());
    let packed = PackedMat::pack(kdim, ndim, &wdense);

    // parity before timing, uniformly: the int path must reproduce the
    // f32 path bitwise, and blocked must reproduce scalar bitwise
    let y_f32 = acts.matmul(&wmat);
    let y_int = packed.code_matmul(&codes, &lut);
    let y_scalar = packed.code_matmul_scalar(&codes, &lut);
    let y_blocked = packed.code_matmul_tiled(&codes, &lut, DEFAULT_GEMM_TILE);
    assert_f32_bits_eq("raw GEMM f32 vs int", &y_f32.d, &y_int.d);
    assert_f32_bits_eq("raw GEMM blocked vs scalar", &y_scalar.d, &y_blocked.d);

    let t_f32 = bj.timed("gemm f32 1024x288x64 (50% pruned w)", 20, || {
        std::hint::black_box(acts.matmul(&wmat));
    });
    let t_scalar = bj.timed("gemm int scalar (reference path)", 20, || {
        std::hint::black_box(packed.code_matmul_scalar(&codes, &lut));
    });
    let t_blocked = bj.timed("gemm int blocked (tile=64, 8 lanes)", 20, || {
        std::hint::black_box(packed.code_matmul_tiled(&codes, &lut, DEFAULT_GEMM_TILE));
    });
    bj.rate("gemm_f32", rows as f64 / t_f32);
    bj.rate("gemm_int_scalar", rows as f64 / t_scalar);
    bj.rate("gemm_int_blocked", rows as f64 / t_blocked);
    bj.speedup("gemm_int_vs_f32", t_f32, t_blocked);
    bj.speedup("gemm_blocked_vs_scalar", t_scalar, t_blocked);

    // --- oracle end-to-end: same engine, both kernels ---
    let (arch, mut weights, images, labels) = bench5_setup();
    compress5(&mut weights);
    let mk = |kernel: KernelKind| {
        let data =
            EvalData::from_arrays(&arch, &images, &labels, labels.len(), arch.batch).unwrap();
        NativeBackend::with_options(&arch, data, 1, kernel).unwrap()
    };
    let bits = [4.0f32, 4.0, 4.0, 4.0];
    let bf = mk(KernelKind::F32);
    let bi = mk(KernelKind::Int);
    let lf = bf.engine_logits(&weights, &bits).unwrap();
    let li = bi.engine_logits(&weights, &bits).unwrap();
    assert_f32_bits_eq("oracle e2e f32 vs int logits", &lf, &li);
    let tf = bj.timed("oracle e2e full recompute, f32 kernel", 10, || {
        bf.invalidate_all();
        std::hint::black_box(bf.accuracy(&weights, &bits).unwrap());
    });
    let ti = bj.timed("oracle e2e full recompute, int kernel", 10, || {
        bi.invalidate_all();
        std::hint::black_box(bi.accuracy(&weights, &bits).unwrap());
    });
    bj.speedup("oracle_int_vs_f32", tf, ti);
    bj.timed("oracle e2e mid dirty, f32 kernel", 10, || {
        bf.invalidate(1);
        std::hint::black_box(bf.accuracy(&weights, &bits).unwrap());
    });
    bj.timed("oracle e2e mid dirty, int kernel", 10, || {
        bi.invalidate(1);
        std::hint::black_box(bi.accuracy(&weights, &bits).unwrap());
    });
}

/// Batched candidate pricing (tentpole of the blocked-GEMM PR): the
/// engine prices K per-layer candidate configs per forward shard in
/// one pass, reusing the shared activation-checkpoint prefix, vs the
/// serial swap-eval-restore loop (the `InferenceBackend` trait
/// default, inlined here because `NativeBackend` overrides it with the
/// batched fast path). Accuracies are asserted bit-identical before
/// timing.
fn batched_rows(bj: &mut BenchJson) {
    let (arch, weights0, images, labels) = bench5_setup();
    let mut weights = weights0.clone();
    compress5(&mut weights);
    let data =
        EvalData::from_arrays(&arch, &images, &labels, labels.len(), arch.batch).unwrap();
    let backend = NativeBackend::with_options(&arch, data, 1, KernelKind::Int).unwrap();
    let bits = [4.0f32, 4.0, 4.0, 4.0];

    // K=8 candidate configs for the mid conv layer (prunable index 1),
    // spanning prune ratios and bit widths like a proposal batch would
    let cands: Vec<Candidate> = (0..8)
        .map(|k| {
            let mut wt = weights0.w[1].clone();
            let sal = Tensor::full(wt.shape.clone(), 1.0);
            let chsq = vec![1.0f32; wt.out_channels(false)];
            let mut prng = Rng::new(100 + k as u64);
            let mut ctx =
                PruneCtx { saliency: &sal, chsq: &chsq, dwconv: false, rng: &mut prng };
            prune(&mut wt, PruneAlg::Level, 0.2 + 0.07 * k as f32, &mut ctx);
            let cbits = 2 + (k % 7) as u32;
            quantize_weights(&mut wt, cbits);
            Candidate {
                layer: 1,
                w: Arc::new(wt),
                b: Arc::new(weights0.b[1].clone()),
                bits: cbits as f32,
            }
        })
        .collect();

    // serial semantics: swap the layer in, invalidate, score, restore
    let serial = |w0: &Weights, bits0: &[f32]| -> Vec<f64> {
        let mut w = w0.clone();
        let mut bits = bits0.to_vec();
        cands
            .iter()
            .map(|c| {
                let (ow, ob, obits) = (w.w[c.layer].clone(), w.b[c.layer].clone(), bits[c.layer]);
                backend.invalidate(c.layer);
                w.w[c.layer] = (*c.w).clone();
                w.b[c.layer] = (*c.b).clone();
                bits[c.layer] = c.bits;
                let acc = backend.accuracy(&w, &bits).unwrap();
                w.w[c.layer] = ow;
                w.b[c.layer] = ob;
                bits[c.layer] = obits;
                backend.invalidate(c.layer);
                acc
            })
            .collect()
    };

    // parity before timing: batched == serial bitwise
    let acc_serial = serial(&weights, &bits);
    let acc_batch = backend.accuracy_batch(&weights, &bits, &cands).unwrap();
    assert_f64_bits_eq("oracle batched vs serial accuracies", &acc_serial, &acc_batch);

    let t_serial = bj.timed("oracle price 8 cands, serial loop", 5, || {
        std::hint::black_box(serial(&weights, &bits));
    });
    let t_batch = bj.timed("oracle price 8 cands, batched pass", 5, || {
        std::hint::black_box(backend.accuracy_batch(&weights, &bits, &cands).unwrap());
    });
    bj.rate("oracle_batched_cands_per_sec", cands.len() as f64 / t_batch);
    bj.speedup("oracle_batched_vs_serial", t_serial, t_batch);
}

/// Search-loop memoization rows (EXPERIMENTS.md §Perf item 8): the
/// eval memo on a revisit-heavy RL walk, the config-fingerprinted pack
/// cache against unconditional re-packing, and the thread-local
/// code-plane arena against fresh allocation. Parity is asserted
/// bitwise before every timing — memoization must never change a
/// result, only skip recomputing it.
fn memo_rows(bj: &mut BenchJson) {
    use hapq::env::CompressionEnv;
    use hapq::hw::energy::EnergyModel;
    use hapq::runtime::native::set_scratch_arena;
    use hapq::runtime::{InferenceSession, MemoConfig};

    let on = MemoConfig { enabled: true, pack_cap: 256, eval_cap: 4096 };
    let mk_env = |memo: MemoConfig| -> CompressionEnv {
        let (arch, weights, images, labels) = bench5_setup();
        let data =
            EvalData::from_arrays(&arch, &images, &labels, labels.len(), arch.batch).unwrap();
        let backend = NativeBackend::with_memo(&arch, data, 1, KernelKind::Int, memo).unwrap();
        let session = InferenceSession::from_backend(Box::new(backend));
        let energy = EnergyModel::new(
            arch.layer_dims().unwrap(),
            hapq::hw::Accel::default(),
            RqTable::compute(300, 3),
        );
        let mut env = CompressionEnv::new(arch, weights, energy, session, 11).unwrap();
        env.set_memo(memo);
        env
    };

    // a revisit-heavy RL walk: 3 distinct whole-network configs, each
    // visited 4 times — the pattern a converging agent produces
    let n = 4;
    let configs: Vec<Vec<Action>> = (0..3)
        .map(|v| {
            (0..n)
                .map(|l| Action {
                    ratio: 0.15 + 0.1 * v as f64,
                    bits: 0.5 + 0.12 * v as f64,
                    alg: (l + v) % 7,
                })
                .collect()
        })
        .collect();
    let walk: Vec<usize> = (0..12).map(|i| i % 3).collect();
    let run = |env: &mut CompressionEnv| -> Vec<f64> {
        let mut out = Vec::new();
        for &c in &walk {
            let sol = env.evaluate_config(&configs[c]).unwrap();
            out.extend([sol.accuracy, sol.acc_loss, sol.energy_gain, sol.reward]);
        }
        out
    };

    let mut hot = mk_env(on);
    let mut cold = mk_env(MemoConfig::off());
    // parity before timing: every solution field bitwise-equal along
    // the walk, memo on vs off
    let (sols_hot, sols_cold) = (run(&mut hot), run(&mut cold));
    assert_f64_bits_eq("memo on vs off walk solutions", &sols_hot, &sols_cold);
    assert!(hot.memo_hits > 0, "revisit walk produced no memo hits");

    let t_cold = bj.timed("oracle walk 12 revisit evals, memo off", 5, || {
        std::hint::black_box(run(&mut cold));
    });
    let t_hot = bj.timed("oracle walk 12 revisit evals, memo on", 5, || {
        std::hint::black_box(run(&mut hot));
    });
    bj.speedup("oracle_memo_vs_cold", t_cold, t_hot);

    // pack-cache hit vs re-pack: two weight versions revisited with
    // full invalidation — the memoized engine re-stages packs from the
    // fingerprint cache, the cold engine rebuilds them every visit
    let (arch, weights, images, labels) = bench5_setup();
    let mut w2 = weights.clone();
    compress5(&mut w2);
    let bits = [4.0f32, 4.0, 4.0, 4.0];
    let mk = |memo: MemoConfig| {
        let data =
            EvalData::from_arrays(&arch, &images, &labels, labels.len(), arch.batch).unwrap();
        NativeBackend::with_memo(&arch, data, 1, KernelKind::Int, memo).unwrap()
    };
    let bhot = mk(on);
    let bcold = mk(MemoConfig::off());
    for w in [&weights, &w2, &weights] {
        bhot.invalidate_all();
        bcold.invalidate_all();
        assert_f32_bits_eq(
            "pack cache vs re-pack logits",
            &bhot.engine_logits(w, &bits).unwrap(),
            &bcold.engine_logits(w, &bits).unwrap(),
        );
    }
    let mut flip = false;
    let t_repack = bj.timed("oracle revisit 2 configs, re-pack", 10, || {
        flip = !flip;
        let w = if flip { &weights } else { &w2 };
        bcold.invalidate_all();
        std::hint::black_box(bcold.accuracy(w, &bits).unwrap());
    });
    let mut flip = false;
    let t_cached = bj.timed("oracle revisit 2 configs, pack cache", 10, || {
        flip = !flip;
        let w = if flip { &weights } else { &w2 };
        bhot.invalidate_all();
        std::hint::black_box(bhot.accuracy(w, &bits).unwrap());
    });
    bj.speedup("pack_cache_vs_repack", t_repack, t_cached);

    // scratch arena vs fresh allocation on the int kernel's code-plane
    // extraction (full recompute so every layer re-runs im2col)
    let bar = mk(on);
    set_scratch_arena(false);
    let l_fresh = bar.engine_logits(&w2, &bits).unwrap();
    set_scratch_arena(true);
    bar.invalidate_all();
    let l_arena = bar.engine_logits(&w2, &bits).unwrap();
    assert_f32_bits_eq("arena vs fresh-alloc logits", &l_fresh, &l_arena);
    set_scratch_arena(false);
    let t_fresh = bj.timed("oracle full recompute, fresh allocs", 10, || {
        bar.invalidate_all();
        std::hint::black_box(bar.accuracy(&w2, &bits).unwrap());
    });
    set_scratch_arena(true);
    let t_arena = bj.timed("oracle full recompute, scratch arena", 10, || {
        bar.invalidate_all();
        std::hint::black_box(bar.accuracy(&w2, &bits).unwrap());
    });
    bj.speedup("arena_vs_fresh_alloc", t_fresh, t_arena);
}

/// Work-stealing shard scheduler rows (EXPERIMENTS.md §Perf items 9–10):
/// steal vs static claim order on deliberately skewed shard sizes, and
/// the dirty-layer pack fan-out vs the serial restage loop. Logits are
/// asserted bit-identical before any timing — the scheduler is a pure
/// performance knob (`rust/tests/exec_engine.rs`).
fn sched_rows(bj: &mut BenchJson) {
    use hapq::runtime::{MemoConfig, SchedKind};

    // --- steal vs static on skewed shards: 16 shards of rows
    //     [24,2,2,2] x 4 at 4 threads — the static round-robin pins
    //     every 24-row shard onto worker 0 (96 of the 120 rows) while
    //     workers 1..3 finish their 8 rows and idle; stealing drains
    //     the backlog ---
    let (arch, mut weights, images5, labels5) = bench5_setup();
    compress5(&mut weights);
    let bits = [4.0f32, 4.0, 4.0, 4.0];
    let per = 16 * 16 * 3;
    let n_ex = 120usize;
    let mut rng = Rng::new(41);
    let images: Vec<f32> = (0..n_ex * per).map(|_| (rng.normal() * 0.3) as f32).collect();
    let labels: Vec<i64> = (0..n_ex).map(|i| (i % 10) as i64).collect();
    let batch = 24usize;
    let rows_pattern: Vec<usize> = (0..4).flat_map(|_| [24usize, 2, 2, 2]).collect();
    let mk = |sched: SchedKind| {
        let mut image_batches = Vec::new();
        let mut label_batches = Vec::new();
        let mut i = 0usize;
        for &rows in &rows_pattern {
            // pad to the executor batch size by repeating the first row
            // (padded rows are ignored at scoring time)
            let mut buf = Vec::with_capacity(batch * per);
            buf.extend_from_slice(&images[i * per..(i + rows) * per]);
            while buf.len() < batch * per {
                buf.extend_from_slice(&images[i * per..i * per + per]);
            }
            image_batches.push(buf);
            label_batches.push(labels[i..i + rows].to_vec());
            i += rows;
        }
        let data = EvalData {
            batch,
            input: arch.input,
            image_batches,
            label_batches,
            n_examples: n_ex,
        };
        NativeBackend::with_sched(&arch, data, 4, KernelKind::Int, MemoConfig::default(), sched)
            .unwrap()
    };
    let bs = mk(SchedKind::Static);
    let bw = mk(SchedKind::Steal);
    assert_f32_bits_eq(
        "sched steal vs static logits (skewed shards)",
        &bs.engine_logits(&weights, &bits).unwrap(),
        &bw.engine_logits(&weights, &bits).unwrap(),
    );
    let t_static = bj.timed("oracle skewed shards, static sched", 10, || {
        bs.invalidate_all();
        std::hint::black_box(bs.accuracy(&weights, &bits).unwrap());
    });
    let t_steal = bj.timed("oracle skewed shards, steal sched", 10, || {
        bw.invalidate_all();
        std::hint::black_box(bw.accuracy(&weights, &bits).unwrap());
    });
    bj.speedup("steal_vs_static_skewed", t_static, t_steal);

    // --- pack fan-out vs the serial restage loop: memo off so every
    //     query rebuilds all four packs; bench5's shards are balanced,
    //     so the delta isolates the packing prong ---
    let mk2 = |sched: SchedKind| {
        let data =
            EvalData::from_arrays(&arch, &images5, &labels5, labels5.len(), arch.batch).unwrap();
        NativeBackend::with_sched(&arch, data, 4, KernelKind::Int, MemoConfig::off(), sched)
            .unwrap()
    };
    let ps = mk2(SchedKind::Static);
    let pw = mk2(SchedKind::Steal);
    assert_f32_bits_eq(
        "pack fan-out vs serial logits",
        &ps.engine_logits(&weights, &bits).unwrap(),
        &pw.engine_logits(&weights, &bits).unwrap(),
    );
    let t_serial = bj.timed("oracle full recompute, serial pack", 10, || {
        ps.invalidate_all();
        std::hint::black_box(ps.accuracy(&weights, &bits).unwrap());
    });
    let t_fan = bj.timed("oracle full recompute, pack fan-out", 10, || {
        pw.invalidate_all();
        std::hint::black_box(pw.accuracy(&weights, &bits).unwrap());
    });
    bj.speedup("pack_parallel_vs_serial", t_serial, t_fan);
}
