//! Micro-benchmarks of the L3 hot path (the §Perf foundation):
//! component latencies that make up one RL step —
//! prune + quantize + energy + oracle inference + agent update.

mod common;

use std::time::Instant;

use hapq::env::Action;
use hapq::hw::dataflow::{map_layer, LayerDims};
use hapq::hw::mac_sim::RqTable;
use hapq::hw::Accel;
use hapq::pruning::{prune, PruneAlg, PruneCtx};
use hapq::quant::quantize_weights;
use hapq::tensor::Tensor;
use hapq::util::rng::Rng;

fn time<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    let t = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t.elapsed().as_secs_f64() / iters as f64;
    println!("{name:<38} {:>10.3} ms/iter  ({iters} iters)", per * 1e3);
    per
}

fn main() {
    common::banner("micro", "hot-path component latencies (EXPERIMENTS.md §Perf)");

    // --- hw substrates ---
    time("mac_sim: RqTable::compute(4000)", 3, || {
        let t = RqTable::compute(4000, 1);
        std::hint::black_box(&t);
    });
    let acc = Accel::default();
    let dims = LayerDims::conv(16, 16, 64, 16, 16, 128, 3, 1);
    time("dataflow: map_layer (64->128ch conv)", 200, || {
        std::hint::black_box(map_layer(&dims, &acc));
    });

    // --- pruning/quant on a vgg-sized tensor ---
    let mut rng = Rng::new(5);
    let w0 = Tensor::new(
        vec![3, 3, 96, 128],
        (0..3 * 3 * 96 * 128).map(|_| rng.normal() as f32 * 0.1).collect(),
    );
    let sal = Tensor::full(w0.shape.clone(), 0.5);
    for alg in [PruneAlg::Level, PruneAlg::L1Ranked, PruneAlg::Splicing] {
        let name = format!("prune {:<10} (110k weights)", alg.name());
        time(&name, 20, || {
            let mut w = w0.clone();
            let chsq = vec![1.0f32; 128];
            let mut r = Rng::new(9);
            let mut ctx = PruneCtx { saliency: &sal, chsq: &chsq, dwconv: false, rng: &mut r };
            std::hint::black_box(prune(&mut w, alg, 0.5, &mut ctx));
        });
    }
    time("quantize_weights 4-bit (110k weights)", 20, || {
        let mut w = w0.clone();
        std::hint::black_box(quantize_weights(&mut w, 4));
    });

    // --- RL update ---
    let mut agent = hapq::rl::ddpg::Ddpg::new(hapq::rl::ddpg::DdpgConfig::default(), 3);
    let mut r = Rng::new(4);
    for _ in 0..128 {
        let s: Vec<f32> = (0..hapq::env::STATE_DIM).map(|_| r.uniform() as f32).collect();
        agent.observe(hapq::rl::replay::Transition {
            s: s.clone(),
            a: vec![0.3, 0.5],
            alg: 0,
            r: 0.1,
            s2: s,
            done: false,
        });
    }
    time("ddpg update (batch 64, 3x300 nets)", 10, || {
        agent.update();
    });
    let mut rb = hapq::rl::rainbow::Rainbow::new(hapq::rl::rainbow::RainbowConfig::default(), 5);
    for _ in 0..128 {
        let f: Vec<f32> = (0..300).map(|_| r.uniform() as f32).collect();
        rb.observe(f.clone(), 2, 0.3, f, false);
    }
    time("rainbow update (batch 64, C51x7)", 10, || {
        rb.update();
    });

    // --- full env step & episode (needs artifacts) ---
    if let Ok(coord) = std::panic::catch_unwind(common::coordinator) {
        let mut env = coord.build_env("vgg11").unwrap();
        let n = env.n_layers();
        let mut k = 0usize;
        time("env full step (prune+quant+E+infer)", 20, || {
            if k % n == 0 {
                env.reset();
            }
            let _ = env
                .step(Action { ratio: 0.3, bits: 0.7, alg: k % 7 })
                .unwrap();
            k += 1;
        });
        let actions: Vec<Action> =
            (0..n).map(|l| Action { ratio: 0.3, bits: 0.7, alg: l % 7 }).collect();
        time("env full episode (vgg11, 10 layers)", 5, || {
            env.evaluate_config(&actions).unwrap();
        });
    } else {
        println!("(artifacts missing — skipping env-level timings)");
    }
}
