//! Property tests for the incremental, multi-threaded evaluation
//! engine (`runtime/exec`), seeded through `util`'s xoshiro proptest
//! harness: on random mini-graphs **with branches** (residual add,
//! optional channel concat, optional depthwise branch) the engine must
//! be (a) bit-identical across thread counts and (b) bit-identical to
//! a from-scratch forward after arbitrary invalidate sequences —
//! single-layer weight mutations, unhinted activation-precision
//! changes, and full episode-reset style `invalidate_all`s.

use std::collections::HashMap;

use hapq::model::{Layer, ModelArch, Op, Weights};
use hapq::runtime::{EvalData, InferenceBackend, KernelKind, MemoConfig, NativeBackend, SchedKind};
use hapq::tensor::Tensor;
use hapq::util::proptest::forall;
use hapq::util::rng::Rng;

/// One randomly generated branched mini-model + evaluation data.
struct Fixture {
    seed: u64,
    arch: ModelArch,
    weights: Weights,
    act_bits: Vec<f32>,
    images: Tensor,
    labels: Vec<i64>,
}

impl std::fmt::Debug for Fixture {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Fixture {{ seed: {:#x}, layers: {:?}, batch: {}, examples: {}, act_bits: {:?} }}",
            self.seed,
            self.arch.layers.iter().map(|l| (&l.name, l.op)).collect::<Vec<_>>(),
            self.arch.batch,
            self.labels.len(),
            self.act_bits,
        )
    }
}

fn conv_layer(
    name: &str,
    inputs: Vec<String>,
    k: usize,
    relu: bool,
    in_ch: usize,
    out_ch: usize,
) -> Layer {
    Layer {
        name: name.to_string(),
        op: Op::Conv,
        inputs,
        k,
        stride: 1,
        relu,
        in_shape: vec![6, 6, in_ch],
        out_shape: vec![6, 6, out_ch],
        in_ch,
        out_ch,
    }
}

fn rand_tensor(rng: &mut Rng, shape: Vec<usize>, scale: f64) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::new(shape, (0..n).map(|_| (rng.normal() * scale) as f32).collect())
}

fn gen_fixture(rng: &mut Rng) -> Fixture {
    let seed = rng.next_u64();
    let cin = 1 + rng.below(3); // input channels 1..=3
    let classes = 2 + rng.below(3); // 2..=4
    let c1 = 2 + rng.below(3); // trunk channels 2..=4
    let k1 = [1usize, 3][rng.below(2)];
    let dw_branch = rng.below(2) == 0; // branch b2: depthwise or 1x1 conv
    let with_concat = rng.below(2) == 0;
    let n_ex = 3 + rng.below(4); // 3..=6 examples
    let batch = 2 + rng.below(3); // 2..=4 -> often multiple batches

    // graph: input -> a -> {b1, b2} -> add [-> concat(add, a)] -> gap -> f
    let mut layers = vec![
        conv_layer("a", vec!["input".into()], k1, true, cin, c1),
        conv_layer("b1", vec!["a".into()], 3, rng.below(2) == 0, c1, c1),
    ];
    if dw_branch {
        layers.push(Layer {
            name: "b2".into(),
            op: Op::DwConv,
            inputs: vec!["a".into()],
            k: 3,
            stride: 1,
            relu: rng.below(2) == 0,
            in_shape: vec![6, 6, c1],
            out_shape: vec![6, 6, c1],
            in_ch: c1,
            out_ch: c1,
        });
    } else {
        layers.push(conv_layer("b2", vec!["a".into()], 1, rng.below(2) == 0, c1, c1));
    }
    layers.push(Layer {
        name: "add".into(),
        op: Op::Add,
        inputs: vec!["b1".into(), "b2".into()],
        k: 1,
        stride: 1,
        relu: true,
        in_shape: vec![6, 6, c1],
        out_shape: vec![6, 6, c1],
        in_ch: c1,
        out_ch: c1,
    });
    let mut fc_in = c1;
    let mut gap_src = "add".to_string();
    if with_concat {
        layers.push(Layer {
            name: "cat".into(),
            op: Op::Concat,
            inputs: vec!["add".into(), "a".into()],
            k: 1,
            stride: 1,
            relu: false,
            in_shape: vec![6, 6, c1],
            out_shape: vec![6, 6, 2 * c1],
            in_ch: c1,
            out_ch: 2 * c1,
        });
        fc_in = 2 * c1;
        gap_src = "cat".to_string();
    }
    layers.push(Layer {
        name: "gap".into(),
        op: Op::Gap,
        inputs: vec![gap_src],
        k: 1,
        stride: 1,
        relu: false,
        in_shape: vec![6, 6, fc_in],
        out_shape: vec![fc_in],
        in_ch: fc_in,
        out_ch: fc_in,
    });
    layers.push(Layer {
        name: "f".into(),
        op: Op::Fc,
        inputs: vec!["gap".into()],
        k: 1,
        stride: 1,
        relu: false,
        in_shape: vec![fc_in],
        out_shape: vec![classes],
        in_ch: fc_in,
        out_ch: classes,
    });

    let prunable: Vec<String> = vec!["a".into(), "b1".into(), "b2".into(), "f".into()];
    let prunable_idx: HashMap<String, usize> =
        prunable.iter().enumerate().map(|(i, n)| (n.clone(), i)).collect();
    let n_p = prunable.len();
    let arch = ModelArch {
        name: "propgraph".into(),
        dataset: "synth-prop".into(),
        input: [6, 6, cin],
        classes,
        batch,
        layers,
        prunable,
        prunable_idx,
        dep_groups: vec![],
        act_scales: (0..n_p).map(|_| rng.range(0.3, 1.0) as f32).collect(),
        act_signed: vec![true, false, false, false],
        acc_int8: 0.0,
        n_params: 0,
    };

    let w_shapes: Vec<Vec<usize>> = vec![
        vec![k1, k1, cin, c1],
        vec![3, 3, c1, c1],
        if dw_branch { vec![3, 3, 1, c1] } else { vec![1, 1, c1, c1] },
        vec![fc_in, classes],
    ];
    let out_chs = [c1, c1, c1, classes];
    let mut w = Vec::new();
    let mut b = Vec::new();
    let mut sal = Vec::new();
    let mut chsq = Vec::new();
    for (shape, &oc) in w_shapes.into_iter().zip(&out_chs) {
        w.push(rand_tensor(rng, shape.clone(), 0.5));
        b.push(rand_tensor(rng, vec![oc], 0.2));
        sal.push(Tensor::full(shape, 1.0));
        chsq.push(vec![1.0f32; oc]);
    }
    let weights = Weights { w, b, sal, chsq };

    let act_bits: Vec<f32> = (0..n_p).map(|_| (2 + rng.below(7)) as f32).collect();
    let images = rand_tensor(rng, vec![n_ex, 6, 6, cin], 0.8);
    let labels: Vec<i64> = (0..n_ex).map(|_| rng.below(classes) as i64).collect();
    Fixture { seed, arch, weights, act_bits, images, labels }
}

fn backend(fx: &Fixture, threads: usize) -> NativeBackend {
    let data =
        EvalData::from_arrays(&fx.arch, &fx.images, &fx.labels, 1000, fx.arch.batch).unwrap();
    NativeBackend::with_threads(&fx.arch, data, threads).unwrap()
}

#[test]
fn threaded_accuracy_is_bit_identical_to_single_thread() {
    forall("threads {1,4} produce bitwise-equal logits", gen_fixture, |fx| {
        let b1 = backend(fx, 1);
        let b4 = backend(fx, 4);
        let l1 = b1.engine_logits(&fx.weights, &fx.act_bits).unwrap();
        let l4 = b4.engine_logits(&fx.weights, &fx.act_bits).unwrap();
        let a1 = b1.accuracy(&fx.weights, &fx.act_bits).unwrap();
        let a4 = b4.accuracy(&fx.weights, &fx.act_bits).unwrap();
        l1 == l4 && a1 == a4
    });
}

#[test]
fn incremental_matches_from_scratch_after_arbitrary_invalidate_sequences() {
    forall("incremental == from-scratch across branches", gen_fixture, |fx| {
        let n = fx.arch.prunable.len();
        // vary the incremental engine's thread count too (1..=3)
        let inc = backend(fx, 1 + (fx.seed % 3) as usize);
        let mut weights = fx.weights.clone();
        let mut bits = fx.act_bits.clone();
        let mut rng = Rng::new(fx.seed);
        if inc.engine_logits(&weights, &bits).unwrap()
            != backend(fx, 1).engine_logits(&weights, &bits).unwrap()
        {
            return false;
        }
        for _round in 0..4 {
            match rng.below(3) {
                0 => {
                    // mutate ONE layer's weights (the RL-step pattern)
                    let i = rng.below(n);
                    for v in weights.w[i].data.iter_mut() {
                        *v = *v * 1.5 + 0.01;
                    }
                    inc.invalidate(i);
                }
                1 => {
                    // change one layer's precision WITHOUT a hint — the
                    // engine must notice via its act-bits diff
                    let i = rng.below(n);
                    bits[i] = (2 + rng.below(7)) as f32;
                }
                _ => {
                    // episode reset: everything changes at once
                    for wt in weights.w.iter_mut() {
                        for v in wt.data.iter_mut() {
                            *v *= 0.8;
                        }
                    }
                    inc.invalidate_all();
                }
            }
            let scratch = backend(fx, 1);
            if inc.engine_logits(&weights, &bits).unwrap()
                != scratch.engine_logits(&weights, &bits).unwrap()
            {
                return false;
            }
            if inc.accuracy(&weights, &bits).unwrap()
                != scratch.accuracy(&weights, &bits).unwrap()
            {
                return false;
            }
        }
        true
    });
}

#[test]
fn memoized_engine_is_bit_identical_to_memo_off_across_threads_and_kernels() {
    // the perf contract of the search-loop memoization (ISSUE 8): a
    // backend with the config-fingerprinted pack cache enabled must
    // produce bitwise the same logits and accuracy as one with every
    // cache disabled, over revisit-heavy walks — the RL-search pattern
    // where the agent keeps returning to configurations it already
    // evaluated — at every (thread count, kernel) combination
    forall("memo on == memo off over revisit-heavy walks", gen_fixture, |fx| {
        let n = fx.arch.prunable.len();
        // three weight snapshots the walk cycles through: revisits give
        // the memoized backend pack-cache hits the cold one never sees
        let snapshots: Vec<Weights> = (0..3)
            .map(|s| {
                let mut w = fx.weights.clone();
                for wt in w.w.iter_mut() {
                    for v in wt.data.iter_mut() {
                        *v = *v * (1.0 + s as f32 * 0.25) + 0.01 * s as f32;
                    }
                }
                w
            })
            .collect();
        for &threads in &[1usize, 4] {
            for &kernel in &[KernelKind::F32, KernelKind::Int] {
                let data = || {
                    EvalData::from_arrays(&fx.arch, &fx.images, &fx.labels, 1000, fx.arch.batch)
                        .unwrap()
                };
                // small pack cap: with 4 prunable layers x 3 snapshots
                // the cache also exercises LRU eviction mid-walk
                let memo = MemoConfig { enabled: true, pack_cap: 8, eval_cap: 64 };
                let hot = NativeBackend::with_memo(&fx.arch, data(), threads, kernel, memo)
                    .unwrap();
                let cold =
                    NativeBackend::with_memo(&fx.arch, data(), threads, kernel, MemoConfig::off())
                        .unwrap();
                let mut rng = Rng::new(fx.seed ^ (threads as u64) ^ ((kernel as u64) << 8));
                let mut cur = 0usize;
                for _step in 0..8 {
                    match rng.below(4) {
                        // revisit a snapshot (episode-reset pattern)
                        s @ 0..=2 => {
                            cur = s;
                            hot.invalidate_all();
                            cold.invalidate_all();
                        }
                        // spurious single-layer invalidate: weights are
                        // unchanged, so the hot backend must serve the
                        // re-staged pack from cache and still match the
                        // cold backend's rebuild bit for bit
                        _ => {
                            let i = rng.below(n);
                            hot.invalidate(i);
                            cold.invalidate(i);
                        }
                    }
                    let w = &snapshots[cur];
                    if hot.engine_logits(w, &fx.act_bits).unwrap()
                        != cold.engine_logits(w, &fx.act_bits).unwrap()
                    {
                        return false;
                    }
                    if hot.accuracy(w, &fx.act_bits).unwrap()
                        != cold.accuracy(w, &fx.act_bits).unwrap()
                    {
                        return false;
                    }
                }
            }
        }
        true
    });
}

/// Deliberately skewed evaluation data: one fat batch holding most of
/// the examples plus single-row tail batches. Under `--sched static`
/// this loads one worker's preferred range far heavier than the rest —
/// exactly the imbalance the work-stealing scheduler exists to drain.
fn skewed_data(fx: &Fixture) -> EvalData {
    let [h, w, c] = fx.arch.input;
    let per = h * w * c;
    let n_ex = fx.labels.len();
    let fat = (n_ex - 2).max(1); // 3..=6 examples -> fat batch of 1..=4
    let mut rows_per_batch = vec![fat];
    rows_per_batch.extend(std::iter::repeat(1).take(n_ex - fat));
    let batch = fat.max(1);
    let mut image_batches = Vec::new();
    let mut label_batches = Vec::new();
    let mut i = 0usize;
    for rows in rows_per_batch {
        // pad to the executor batch size by repeating the first row
        // (padded rows are ignored at scoring time, as in from_arrays)
        let mut buf = Vec::with_capacity(batch * per);
        buf.extend_from_slice(&fx.images.data[i * per..(i + rows) * per]);
        while buf.len() < batch * per {
            buf.extend_from_slice(&fx.images.data[i * per..i * per + per]);
        }
        image_batches.push(buf);
        label_batches.push(fx.labels[i..i + rows].to_vec());
        i += rows;
    }
    EvalData { batch, input: [h, w, c], image_batches, label_batches, n_examples: n_ex }
}

#[test]
fn steal_scheduler_is_bit_identical_to_static_across_threads_and_kernels() {
    // the perf contract of the work-stealing shard scheduler (ISSUE
    // 10): whatever order workers claim (or steal) shards in, and
    // whether the dirty-layer packs were fanned across the pool or
    // built serially, the logits, correct counts and pack-cache stats
    // must match the static broadcast bit for bit — on skewed shard
    // sizes, at every (thread count, kernel) combination, across
    // arbitrary dirty sequences
    forall("steal == static over dirty sequences", gen_fixture, |fx| {
        let n = fx.arch.prunable.len();
        for &threads in &[1usize, 4] {
            for &kernel in &[KernelKind::F32, KernelKind::Int] {
                let mk = |sched| {
                    NativeBackend::with_sched(
                        &fx.arch,
                        skewed_data(fx),
                        threads,
                        kernel,
                        MemoConfig::default(),
                        sched,
                    )
                    .unwrap()
                };
                let st = mk(SchedKind::Static);
                let wk = mk(SchedKind::Steal);
                let mut weights = fx.weights.clone();
                let mut bits = fx.act_bits.clone();
                let mut rng = Rng::new(fx.seed ^ (threads as u64) ^ ((kernel as u64) << 8));
                for _round in 0..4 {
                    match rng.below(3) {
                        0 => {
                            // RL-step pattern: one layer's weights move
                            let i = rng.below(n);
                            for v in weights.w[i].data.iter_mut() {
                                *v = *v * 1.25 + 0.01;
                            }
                            st.invalidate(i);
                            wk.invalidate(i);
                        }
                        1 => {
                            // unhinted precision change
                            let i = rng.below(n);
                            bits[i] = (2 + rng.below(7)) as f32;
                        }
                        _ => {
                            // episode reset
                            for wt in weights.w.iter_mut() {
                                for v in wt.data.iter_mut() {
                                    *v *= 0.9;
                                }
                            }
                            st.invalidate_all();
                            wk.invalidate_all();
                        }
                    }
                    if st.engine_logits(&weights, &bits).unwrap()
                        != wk.engine_logits(&weights, &bits).unwrap()
                    {
                        return false;
                    }
                    if st.accuracy(&weights, &bits).unwrap()
                        != wk.accuracy(&weights, &bits).unwrap()
                    {
                        return false;
                    }
                }
                // claim order must not perturb the bookkeeping either:
                // every shard is evaluated exactly once per query and
                // the pack-cache walk of record is serial under both
                // schedulers, so computed/reused/hit/miss totals agree
                let (a, b) = (st.stats(), wk.stats());
                if (a.layers_computed, a.layers_reused) != (b.layers_computed, b.layers_reused) {
                    return false;
                }
                if (a.pack_hits, a.pack_misses) != (b.pack_hits, b.pack_misses) {
                    return false;
                }
            }
        }
        true
    });
}

#[test]
fn engine_logits_match_the_reference_forward_on_branched_graphs() {
    // the engine against the stateless from-scratch interpreter path
    // (NativeBackend::logits), batch by batch, bitwise
    forall("engine == reference interpreter", gen_fixture, |fx| {
        let b = backend(fx, 2);
        let engine = b.engine_logits(&fx.weights, &fx.act_bits).unwrap();
        let classes = fx.arch.classes;
        let batch = fx.arch.batch;
        let mut reference = Vec::new();
        let n_batches = fx.labels.len().div_ceil(batch);
        for bi in 0..n_batches {
            let rows = (fx.labels.len() - bi * batch).min(batch);
            let full = b.logits(&fx.weights, &fx.act_bits, bi).unwrap();
            reference.extend_from_slice(&full[..rows * classes]); // drop padded rows
        }
        engine == reference
    });
}
